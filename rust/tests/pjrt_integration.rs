//! End-to-end integration: the AOT-compiled JAX/Pallas graph executed via
//! PJRT from Rust must agree bit-for-bit with the native Rust golden model.
//!
//! Requires `make artifacts` **and** a build with the `xla` feature;
//! otherwise these tests skip gracefully (the stub runtime reports
//! `BackendUnavailable`, a missing artifact dir reports `Artifacts`).

use posit_div::division::golden;
use posit_div::posit::{mask, Posit};
use posit_div::runtime::Runtime;
use posit_div::testkit::Rng;
use posit_div::PositError;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Load the runtime or skip the test with a note. Only *environmental*
/// conditions skip — artifacts not built yet, or a build without the
/// `xla` feature. Anything else (e.g. a PJRT client/compile failure with
/// artifacts present) is a real regression and must fail the test.
fn load_or_skip() -> Option<Runtime> {
    match Runtime::load(artifacts_dir()) {
        Ok(rt) => Some(rt),
        Err(e @ (PositError::Artifacts { .. } | PositError::BackendUnavailable { .. })) => {
            eprintln!("skipping PJRT integration test: {e}");
            None
        }
        Err(e) => panic!("PJRT runtime failed to load with artifacts present: {e}"),
    }
}

#[test]
fn pjrt_graph_matches_rust_golden() {
    let Some(rt) = load_or_skip() else { return };
    let mut rng = Rng::seeded(0x9187);
    for &n in &[16u32, 32] {
        for round in 0..4 {
            let len = [256usize, 100, 1024, 2500][round];
            let x: Vec<u64> = (0..len).map(|_| rng.next_u64() & mask(n)).collect();
            let d: Vec<u64> = (0..len).map(|_| rng.next_u64() & mask(n)).collect();
            let got = rt.divide_bits(n, &x, &d).unwrap();
            for i in 0..len {
                let want = golden::divide(
                    Posit::from_bits(n, x[i]),
                    Posit::from_bits(n, d[i]),
                )
                .result
                .to_bits();
                assert_eq!(got[i], want, "n={n} x={:#x} d={:#x}", x[i], d[i]);
            }
        }
    }
}

#[test]
fn pjrt_specials() {
    let Some(rt) = load_or_skip() else { return };
    let n = 16;
    let nar = 1u64 << (n - 1);
    let one = 1u64 << (n - 2);
    let x = vec![0, 0, nar, one, one];
    let d = vec![one, 0, one, nar, 0];
    let q = rt.divide_bits(n, &x, &d).unwrap();
    assert_eq!(q, vec![0, nar, nar, nar, nar]);
}
