"""L2 model tests: the full decode→kernel→encode graph against the
reference graph, float sanity, special cases, and the AOT export."""

import json
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import posit_codec as codec


def posit_to_float(bits, n):
    """Exact float value of posit patterns (n ≤ 32 ⇒ f64 exact)."""
    z, na, s, sc, sig = codec.decode(np.asarray(bits, dtype=np.int64), n)
    f = codec.frac_bits(n)
    v = np.array(sig, float) / (1 << f) * 2.0 ** np.array(sc, float)
    v = np.where(np.array(s), -v, v)
    v = np.where(np.array(z), 0.0, v)
    return np.where(np.array(na), np.nan, v)


@pytest.mark.parametrize("n", [16, 32])
def test_kernel_graph_equals_reference_graph(n):
    rng = np.random.default_rng(n * 7)
    for _ in range(6):
        x = rng.integers(0, 1 << n, size=256, dtype=np.int64)
        d = rng.integers(0, 1 << n, size=256, dtype=np.int64)
        qk = model.divide_batch(jnp.asarray(x), jnp.asarray(d), n)
        qr = model.reference_divide(jnp.asarray(x), jnp.asarray(d), n)
        np.testing.assert_array_equal(np.array(qk), np.array(qr))


def test_specials_p16():
    n = 16
    nar = 1 << (n - 1)
    one = 1 << (n - 2)
    x = np.array([0, 0, nar, one, one, 0], dtype=np.int64)
    d = np.array([one, 0, one, nar, 0, nar], dtype=np.int64)
    pad = 256 - len(x)
    x = np.concatenate([x, np.full(pad, one, dtype=np.int64)])
    d = np.concatenate([d, np.full(pad, one, dtype=np.int64)])
    q = np.array(model.divide_batch(jnp.asarray(x), jnp.asarray(d), n))
    assert q[0] == 0          # 0/1 = 0
    assert q[1] == nar        # 0/0 = NaR
    assert q[2] == nar        # NaR/1
    assert q[3] == nar        # 1/NaR
    assert q[4] == nar        # 1/0
    assert q[5] == nar        # 0/NaR
    assert (q[6:] == one).all()  # 1/1 = 1


@pytest.mark.parametrize("n", [16, 32])
def test_float_accuracy(n):
    rng = np.random.default_rng(3)
    x = rng.integers(0, 1 << n, size=256, dtype=np.int64)
    d = rng.integers(0, 1 << n, size=256, dtype=np.int64)
    q = np.array(model.divide_batch(jnp.asarray(x), jnp.asarray(d), n))
    xv, dv, qv = (posit_to_float(a, n) for a in (x, d, q))
    want = xv / dv
    skip = np.isnan(want) | (dv == 0) | np.isnan(qv)
    # posit precision tapers toward the extremes (long regimes leave few
    # fraction bits, and saturation clamps at maxpos/minpos): restrict the
    # tight check to the well-conditioned band where p16/p32 carry at
    # least ~6 fraction bits.
    band = (np.abs(want) > 2.0**-20) & (np.abs(want) < 2.0**20) & ~skip
    rel = np.abs(qv[band] - want[band]) / np.abs(want[band])
    assert np.median(rel) < 2.0 ** -(codec.frac_bits(n) - 1)
    assert (rel < 2.0**-6).all()


def test_signs():
    n = 16
    one = 1 << (n - 2)
    neg_one = (-one) & ((1 << n) - 1)
    x = np.full(256, one, dtype=np.int64)
    d = np.full(256, neg_one, dtype=np.int64)
    q = np.array(model.divide_batch(jnp.asarray(x), jnp.asarray(d), n))
    assert (q == neg_one).all()
    q2 = np.array(model.divide_batch(jnp.asarray(d), jnp.asarray(d), n))
    assert (q2 == one).all()


def test_aot_lowering_emits_hlo_text():
    text = aot.lower_variant(16, 256)
    assert "ENTRY" in text and "HloModule" in text
    # fori_loop keeps the module compact — sanity-bound its size
    assert len(text) < 500_000


def test_aot_manifest_matches_variants(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest) == len(aot.VARIANTS)
    for name, meta in manifest.items():
        assert (tmp_path / name).exists()
        assert meta["inputs"] == 2


def test_jit_cache_stability():
    # repeated calls with the same static config must not retrace into
    # different results (paranoia check for cache-key bugs)
    n = 16
    rng = np.random.default_rng(11)
    x = rng.integers(0, 1 << n, size=256, dtype=np.int64)
    d = rng.integers(0, 1 << n, size=256, dtype=np.int64)
    a = np.array(model.divide_batch(jnp.asarray(x), jnp.asarray(d), n))
    b = np.array(model.divide_batch(jnp.asarray(x), jnp.asarray(d), n))
    np.testing.assert_array_equal(a, b)


def test_x64_is_enabled():
    assert jax.config.read("jax_enable_x64")
