//! Typed errors for the public library surface.
//!
//! The crate used to panic on width/argument failures and leak `anyhow`
//! errors from the runtime and the coordinator. Every fallible public
//! entry point now returns [`PositError`]; panics remain only for internal
//! invariants (e.g. [`crate::posit::Posit::from_bits`] documents its
//! width assertion, mirroring the hardware's "illegal configuration"
//! contract).

use std::time::Duration;

/// Crate-wide result alias over [`PositError`].
pub type Result<T> = core::result::Result<T, PositError>;

/// Everything that can go wrong at the library surface.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PositError {
    /// Requested posit width outside the supported `[MIN_N, MAX_N]` range.
    WidthOutOfRange { n: u32 },
    /// Two operands (or an operand and a context) disagree on width.
    WidthMismatch { expected: u32, got: u32 },
    /// Batch slices passed to `divide_batch`/`run_batch` have
    /// inconsistent lengths (lanes `a`/`b` map to the `xs`/`ds` fields).
    BatchShapeMismatch { xs: usize, ds: usize, out: usize },
    /// An extra batch operand lane (e.g. lane `c` of `MulAdd`, or lane
    /// `b` of a `Dot` reduction that must match lane `a` element for
    /// element) has the wrong length.
    BatchLaneMismatch { lane: &'static str, expected: usize, got: usize },
    /// An operation received the wrong number of operand lanes (e.g.
    /// `Sqrt` is unary, `MulAdd` ternary; reductions count *lanes*, so
    /// `Dot` is binary however long its vectors are).
    ArityMismatch { op: &'static str, expected: usize, got: usize },
    /// A forced fast-tier batch kernel cannot serve the requested
    /// `(width, op)` (e.g. the Posit8 table path at n = 16, or the SWAR
    /// path at a width without packed kernels). Forcing never falls back
    /// silently — benches and tests must measure the kernel they asked
    /// for.
    UnsupportedFastPath { path: &'static str, op: &'static str, n: u32 },
    /// The Approx tier has no registered bounded-error kernel for the
    /// requested `(op, width)` (only `div`/`sqrt`/`mul` at n ∈ {8, 16, 32}
    /// carry declared ulp specs), or a forced fast path was combined with
    /// the Approx tier.
    UnsupportedApprox { op: &'static str, n: u32 },
    /// A requested execution backend cannot run in this build/environment
    /// (e.g. the PJRT runtime without the `xla` feature).
    BackendUnavailable { reason: String },
    /// AOT artifact discovery or loading failed.
    Artifacts { detail: String },
    /// A backend accepted work but failed while executing it.
    Execution { detail: String },
    /// The division service has shut down (or its leader thread is gone).
    ServiceStopped,
    /// Admission control shed this request: the target shard's bounded
    /// in-flight queue was at capacity. The request was **not** enqueued;
    /// back off and resubmit. (`inflight` is the queue depth observed at
    /// admission time.)
    ServiceOverloaded { shard: usize, inflight: usize, capacity: usize },
    /// A wire-protocol frame was malformed: bad magic, unsupported
    /// version, oversized or truncated payload, unknown frame kind or
    /// opcode, or operand bits outside the negotiated posit width.
    Protocol { detail: String },
    /// A network operation (connect, socket read) exceeded its configured
    /// timeout. The connection's stream state is indeterminate after a
    /// timeout — a resilient caller must discard the connection and
    /// retry on a fresh one (ops are pure, so replay is safe).
    Timeout { what: String, after: Duration },
    /// The request's end-to-end deadline had already expired when the
    /// service looked at it; it was dropped *before* admission, without
    /// consuming a shard slot. `waited_ms` is how stale the request was.
    DeadlineExceeded { deadline_ms: u32, waited_ms: u32 },
}

impl core::fmt::Display for PositError {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        match self {
            PositError::WidthOutOfRange { n } => write!(
                f,
                "posit width {n} out of supported range [{},{}]",
                crate::posit::MIN_N,
                crate::posit::MAX_N
            ),
            PositError::WidthMismatch { expected, got } => {
                write!(f, "posit width mismatch: expected Posit{expected}, got Posit{got}")
            }
            PositError::BatchShapeMismatch { xs, ds, out } => write!(
                f,
                "batch shape mismatch: xs.len()={xs}, ds.len()={ds}, out.len()={out}"
            ),
            PositError::BatchLaneMismatch { lane, expected, got } => write!(
                f,
                "batch lane mismatch: lane {lane} has length {got}, expected {expected}"
            ),
            PositError::ArityMismatch { op, expected, got } => {
                write!(f, "op {op} takes {expected} operand lane(s), got {got}")
            }
            PositError::UnsupportedFastPath { path, op, n } => {
                write!(f, "fast path {path} cannot serve op {op} at Posit{n}")
            }
            PositError::UnsupportedApprox { op, n } => {
                write!(f, "approx tier has no bounded-error kernel for op {op} at Posit{n}")
            }
            PositError::BackendUnavailable { reason } => {
                write!(f, "backend unavailable: {reason}")
            }
            PositError::Artifacts { detail } => write!(f, "{detail}"),
            PositError::Execution { detail } => write!(f, "execution failed: {detail}"),
            PositError::ServiceStopped => write!(f, "division service stopped"),
            PositError::ServiceOverloaded { shard, inflight, capacity } => write!(
                f,
                "service overloaded: shard {shard} at {inflight}/{capacity} in-flight \
                 requests, request shed"
            ),
            PositError::Protocol { detail } => write!(f, "wire protocol error: {detail}"),
            PositError::Timeout { what, after } => {
                write!(f, "timed out after {after:?}: {what}")
            }
            PositError::DeadlineExceeded { deadline_ms, waited_ms } => write!(
                f,
                "deadline exceeded: {deadline_ms} ms budget, request {waited_ms} ms old at \
                 admission; dropped without consuming a slot"
            ),
        }
    }
}

impl std::error::Error for PositError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        assert!(PositError::WidthOutOfRange { n: 3 }.to_string().contains("width 3"));
        assert!(PositError::WidthMismatch { expected: 16, got: 32 }
            .to_string()
            .contains("Posit16"));
        let e = PositError::BatchShapeMismatch { xs: 1, ds: 2, out: 3 };
        assert!(e.to_string().contains("xs.len()=1"));
        let e = PositError::ArityMismatch { op: "sqrt", expected: 1, got: 2 };
        assert!(e.to_string().contains("sqrt") && e.to_string().contains("1"));
        let e = PositError::BatchLaneMismatch { lane: "c", expected: 4, got: 2 };
        assert!(e.to_string().contains("lane c"));
        let e = PositError::UnsupportedFastPath { path: "table", op: "div", n: 16 };
        assert!(e.to_string().contains("table") && e.to_string().contains("Posit16"));
        let e = PositError::UnsupportedApprox { op: "add", n: 16 };
        assert!(e.to_string().contains("add") && e.to_string().contains("Posit16"));
        assert!(PositError::Artifacts { detail: "no artifacts found".into() }
            .to_string()
            .contains("no artifacts"));
        let e = PositError::ServiceOverloaded { shard: 3, inflight: 128, capacity: 128 };
        assert!(e.to_string().contains("shard 3") && e.to_string().contains("128/128"));
        let e = PositError::Protocol { detail: "truncated frame".into() };
        assert!(e.to_string().contains("truncated frame"));
        let e = PositError::Timeout {
            what: "connect 127.0.0.1:9".into(),
            after: Duration::from_secs(5),
        };
        assert!(e.to_string().contains("timed out after 5s"), "{e}");
        assert!(e.to_string().contains("connect 127.0.0.1:9"));
        let e = PositError::DeadlineExceeded { deadline_ms: 50, waited_ms: 300 };
        assert!(e.to_string().contains("50 ms budget"), "{e}");
        assert!(e.to_string().contains("300 ms old"));
    }

    /// A forced-path rejection must name the requested path and the op
    /// verbatim — operators grep serve logs for these strings.
    #[test]
    fn unsupported_fast_path_message_names_path_and_op() {
        let e = PositError::UnsupportedFastPath { path: "simd", op: "mul_add", n: 32 };
        assert_eq!(e.to_string(), "fast path simd cannot serve op mul_add at Posit32");
        let e = PositError::UnsupportedApprox { op: "dot", n: 64 };
        assert_eq!(e.to_string(), "approx tier has no bounded-error kernel for op dot at Posit64");
    }

    #[test]
    fn is_std_error() {
        fn takes_error(_: &dyn std::error::Error) {}
        takes_error(&PositError::ServiceStopped);
    }
}
