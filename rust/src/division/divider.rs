//! Reusable, zero-alloc division contexts.
//!
//! [`Algorithm::engine`] boxes a fresh `dyn DivEngine` on every call —
//! fine for one-off experiments, wrong for a hot serving path. A
//! [`Divider`] is constructed **once** per (width, algorithm), holds the
//! concrete engine inline (enum dispatch, no heap indirection on the call
//! path), and caches the width-derived state the wrapper would otherwise
//! recompute: iteration count, pipelined latency, the operand mask, and —
//! for the Newton baseline — the seed-reciprocal table, its only
//! allocation, paid at construction.
//!
//! The batch entry point [`Divider::divide_batch`] is the single code
//! path shared by the coordinator's native worker pool, the benches and
//! the examples, so every layer measures the same loop.

use super::{
    exec, iterations, latency_cycles, newton::Newton, nrd::Nrd, srt2::Srt2, srt2_cs::Srt2Cs,
    srt4_cs::Srt4Cs, srt4_scaled::Srt4Scaled, Algorithm, DivEngine, Division, FracQuotient,
};
use crate::error::{PositError, Result};
use crate::posit::{mask, Posit, MAX_N, MIN_N};

/// Concrete engine storage: static dispatch, no `Box`.
enum EngineAny {
    Nrd(Nrd),
    Srt2(Srt2),
    Srt2Cs(Srt2Cs),
    Srt4Cs(Srt4Cs),
    Srt4Scaled(Srt4Scaled),
    Newton(Newton),
}

/// A reusable division context for one posit width and one algorithm.
///
/// ```
/// use posit_div::division::{Algorithm, Divider};
/// use posit_div::posit::Posit;
///
/// let div = Divider::new(32, Algorithm::Srt4CsOfFr)?;
/// let q = div.divide(Posit::from_f64(32, 355.0), Posit::from_f64(32, 113.0))?;
/// assert!((q.result.to_f64() - 355.0 / 113.0).abs() < 1e-6);
/// # Ok::<(), posit_div::PositError>(())
/// ```
pub struct Divider {
    n: u32,
    alg: Algorithm,
    engine: EngineAny,
    iterations: u32,
    cycles: u32,
    mask: u64,
}

impl Divider {
    /// Build a context for `Posit<n, 2>` division with `alg`.
    ///
    /// All width-derived state (iterations, latency, Newton seed table)
    /// is computed here, once.
    pub fn new(n: u32, alg: Algorithm) -> Result<Divider> {
        if !(MIN_N..=MAX_N).contains(&n) {
            return Err(PositError::WidthOutOfRange { n });
        }
        let engine = match alg {
            Algorithm::Nrd => EngineAny::Nrd(Nrd::new()),
            Algorithm::NrdAsap23 => EngineAny::Nrd(Nrd::asap23()),
            Algorithm::Srt2 => EngineAny::Srt2(Srt2::new()),
            Algorithm::Srt2Cs => EngineAny::Srt2Cs(Srt2Cs::plain()),
            Algorithm::Srt2CsOf => EngineAny::Srt2Cs(Srt2Cs::with_otf()),
            Algorithm::Srt2CsOfFr => EngineAny::Srt2Cs(Srt2Cs::with_otf_fr()),
            Algorithm::Srt4Cs => EngineAny::Srt4Cs(Srt4Cs::plain()),
            Algorithm::Srt4CsOf => EngineAny::Srt4Cs(Srt4Cs::with_otf()),
            Algorithm::Srt4CsOfFr => EngineAny::Srt4Cs(Srt4Cs::with_otf_fr()),
            Algorithm::Srt4Scaled => EngineAny::Srt4Scaled(Srt4Scaled::new()),
            Algorithm::Newton => EngineAny::Newton(Newton::new()),
        };
        let iters = match alg.radix() {
            Some(r) => iterations(n, r),
            None => 0,
        };
        // `latency_cycles` would build a throwaway Newton (and its seed
        // LUT) just to ask for the cycle count — use the engine we
        // already hold instead.
        let cycles = match &engine {
            EngineAny::Newton(e) => e.cycles(n),
            _ => latency_cycles(n, alg),
        };
        Ok(Divider { n, alg, engine, iterations: iters, cycles, mask: mask(n) })
    }

    /// The default serving context: the paper's optimized radix-4 unit.
    pub fn standard(n: u32) -> Result<Divider> {
        Divider::new(n, Algorithm::DEFAULT)
    }

    /// Posit width this context divides.
    #[inline]
    pub fn width(&self) -> u32 {
        self.n
    }

    /// The algorithm variant.
    #[inline]
    pub fn algorithm(&self) -> Algorithm {
        self.alg
    }

    /// Cached recurrence iteration count (0 for the Newton baseline, whose
    /// step count is data-independent but reported per division).
    #[inline]
    pub fn iterations(&self) -> u32 {
        self.iterations
    }

    /// Cached pipelined latency in cycles (paper §III-E3).
    #[inline]
    pub fn latency_cycles(&self) -> u32 {
        self.cycles
    }

    /// One full posit division with metadata. Errors on operand width
    /// mismatch instead of panicking.
    #[inline]
    pub fn divide(&self, x: Posit, d: Posit) -> Result<Division> {
        if x.width() != self.n {
            return Err(PositError::WidthMismatch { expected: self.n, got: x.width() });
        }
        if d.width() != self.n {
            return Err(PositError::WidthMismatch { expected: self.n, got: d.width() });
        }
        Ok(exec::divide_with(self, x, d))
    }

    /// Divide two raw `n`-bit patterns (high garbage bits are masked off —
    /// the same contract as the PJRT graph). This is the batch-path inner
    /// loop.
    #[inline]
    pub fn divide_bits(&self, x: u64, d: u64) -> u64 {
        let x = Posit::from_bits(self.n, x & self.mask);
        let d = Posit::from_bits(self.n, d & self.mask);
        exec::divide_with(self, x, d).result.to_bits()
    }

    /// Batch-first division over raw bit patterns: `out[i] = xs[i] / ds[i]`.
    ///
    /// Bit-identical to calling [`Divider::divide`] element-wise; the
    /// coordinator's native backend, the benches and the examples all go
    /// through this one loop.
    pub fn divide_batch(&self, xs: &[u64], ds: &[u64], out: &mut [u64]) -> Result<()> {
        if xs.len() != ds.len() || xs.len() != out.len() {
            return Err(PositError::BatchShapeMismatch {
                xs: xs.len(),
                ds: ds.len(),
                out: out.len(),
            });
        }
        for ((x, d), o) in xs.iter().zip(ds.iter()).zip(out.iter_mut()) {
            *o = self.divide_bits(*x, *d);
        }
        Ok(())
    }

    /// [`Divider::divide_batch`] spread over `threads` scoped workers
    /// (contiguous chunks, results written in place — ordering preserved),
    /// matching the coordinator's previous always-parallel behavior.
    pub fn divide_batch_parallel(
        &self,
        xs: &[u64],
        ds: &[u64],
        out: &mut [u64],
        threads: usize,
    ) -> Result<()> {
        if xs.len() != ds.len() || xs.len() != out.len() {
            return Err(PositError::BatchShapeMismatch {
                xs: xs.len(),
                ds: ds.len(),
                out: out.len(),
            });
        }
        let threads = threads.max(1);
        if threads == 1 || xs.len() <= 1 {
            return self.divide_batch(xs, ds, out);
        }
        let chunk = xs.len().div_ceil(threads).max(1);
        std::thread::scope(|s| {
            for ((cx, cd), co) in
                xs.chunks(chunk).zip(ds.chunks(chunk)).zip(out.chunks_mut(chunk))
            {
                s.spawn(move || {
                    self.divide_batch(cx, cd, co).expect("equal chunk lengths");
                });
            }
        });
        Ok(())
    }
}

impl core::fmt::Debug for Divider {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        f.debug_struct("Divider")
            .field("n", &self.n)
            .field("algorithm", &self.alg)
            .field("iterations", &self.iterations)
            .field("latency_cycles", &self.cycles)
            .finish()
    }
}

/// A `Divider` is itself a [`DivEngine`], so it drops into every API that
/// takes one (the DSP example, the cross-check harnesses) with static
/// dispatch inside.
impl DivEngine for Divider {
    fn name(&self) -> &'static str {
        match &self.engine {
            EngineAny::Nrd(e) => e.name(),
            EngineAny::Srt2(e) => e.name(),
            EngineAny::Srt2Cs(e) => e.name(),
            EngineAny::Srt4Cs(e) => e.name(),
            EngineAny::Srt4Scaled(e) => e.name(),
            EngineAny::Newton(e) => e.name(),
        }
    }

    fn algorithm(&self) -> Algorithm {
        self.alg
    }

    fn fraction_divide(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
        match &self.engine {
            EngineAny::Nrd(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Srt2(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Srt2Cs(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Srt4Cs(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Srt4Scaled(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Newton(e) => e.fraction_divide(n, x_sig, d_sig),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;
    use crate::testkit::Rng;

    #[test]
    fn rejects_bad_width() {
        assert_eq!(
            Divider::new(3, Algorithm::Nrd).err(),
            Some(PositError::WidthOutOfRange { n: 3 })
        );
        assert_eq!(
            Divider::new(65, Algorithm::Nrd).err(),
            Some(PositError::WidthOutOfRange { n: 65 })
        );
        assert!(Divider::new(4, Algorithm::Nrd).is_ok());
        assert!(Divider::new(64, Algorithm::Srt4CsOfFr).is_ok());
    }

    #[test]
    fn rejects_width_mismatch() {
        let div = Divider::new(16, Algorithm::Srt2Cs).unwrap();
        let err = div.divide(Posit::one(32), Posit::one(32)).unwrap_err();
        assert_eq!(err, PositError::WidthMismatch { expected: 16, got: 32 });
        let err = div.divide(Posit::one(16), Posit::one(8)).unwrap_err();
        assert_eq!(err, PositError::WidthMismatch { expected: 16, got: 8 });
    }

    #[test]
    fn rejects_batch_shape_mismatch() {
        let div = Divider::new(16, Algorithm::Srt2Cs).unwrap();
        let mut out = [0u64; 2];
        let err = div.divide_batch(&[1, 2, 3], &[1, 2, 3], &mut out).unwrap_err();
        assert_eq!(err, PositError::BatchShapeMismatch { xs: 3, ds: 3, out: 2 });
        let err = div.divide_batch(&[1, 2], &[1], &mut out).unwrap_err();
        assert_eq!(err, PositError::BatchShapeMismatch { xs: 2, ds: 1, out: 2 });
    }

    #[test]
    fn caches_match_free_functions() {
        for n in [8u32, 16, 32, 64] {
            for alg in Algorithm::TABLE_IV {
                let div = Divider::new(n, alg).unwrap();
                assert_eq!(div.iterations(), iterations(n, alg.radix().unwrap()));
                assert_eq!(div.latency_cycles(), latency_cycles(n, alg));
                assert_eq!(div.width(), n);
                assert_eq!(div.algorithm(), alg);
            }
        }
    }

    #[test]
    fn scalar_and_batch_agree_with_golden() {
        let mut rng = Rng::seeded(0xD1F);
        for n in [8u32, 16, 32] {
            let div = Divider::standard(n).unwrap();
            let xs: Vec<u64> = (0..200).map(|_| rng.next_u64()).collect();
            let ds: Vec<u64> = (0..200).map(|_| rng.next_u64()).collect();
            let mut out = vec![0u64; xs.len()];
            div.divide_batch(&xs, &ds, &mut out).unwrap();
            for i in 0..xs.len() {
                let x = Posit::from_bits(n, xs[i] & mask(n));
                let d = Posit::from_bits(n, ds[i] & mask(n));
                let want = golden::divide(x, d).result.to_bits();
                assert_eq!(out[i], want, "batch n={n} i={i}");
                assert_eq!(div.divide(x, d).unwrap().result.to_bits(), want, "scalar n={n} i={i}");
            }
        }
    }

    #[test]
    fn parallel_batch_is_bit_identical() {
        let mut rng = Rng::seeded(0x9A);
        let div = Divider::standard(16).unwrap();
        let xs: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
        let ds: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
        let mut serial = vec![0u64; xs.len()];
        let mut parallel = vec![0u64; xs.len()];
        div.divide_batch(&xs, &ds, &mut serial).unwrap();
        div.divide_batch_parallel(&xs, &ds, &mut parallel, 4).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn divider_is_a_div_engine() {
        let div = Divider::new(16, Algorithm::Srt4CsOfFr).unwrap();
        let e: &dyn DivEngine = &div;
        assert_eq!(e.name(), "SRT r4 CS OF FR");
        assert_eq!(e.algorithm(), Algorithm::Srt4CsOfFr);
        let d = e.divide(Posit::one(16), Posit::one(16));
        assert_eq!(d.result, Posit::one(16));
    }
}
