//! Newton–Raphson multiplicative divider — the baseline the paper's §I/§II
//! position digit recurrence against (PACoGen [3] and [10] use this
//! scheme). Quadratic convergence: each step doubles the accurate bits but
//! costs two full-width multiplications; [16]'s finding (digit recurrence
//! is more energy-efficient) is reproduced by the hardware model.
//!
//! The implementation is exact: after the NR iterations produce an
//! approximate reciprocal, a remainder-based fix-up step delivers the
//! correctly truncated quotient and sticky, so the engine is bit-compatible
//! with the golden model (as a real divider must be).

use super::{Algorithm, DivEngine, FracQuotient};
use crate::posit::frac_bits;

/// Bits of the seed reciprocal lookup table (indexed by the divisor's top
/// fraction bits, PACoGen-style).
const LUT_INDEX_BITS: u32 = 7;
const LUT_VALUE_BITS: u32 = 8;

/// Newton–Raphson divider.
pub struct Newton {
    /// Seed table: approximate 1/d for d ∈ [1,2), 8-bit output.
    lut: Vec<u32>,
}

impl Newton {
    pub fn new() -> Self {
        // seed[i] ≈ 2^LUT_VALUE_BITS / midpoint of [1 + i/128, 1 + (i+1)/128)
        let entries = 1usize << LUT_INDEX_BITS;
        let mut lut = Vec::with_capacity(entries);
        for i in 0..entries as u64 {
            // midpoint m = 1 + (2i+1)/256; y = round(256/m) ∈ (128, 256]
            let num = 256u64 << (LUT_VALUE_BITS + 1); // 2·256·2^8
            let den = 256 + 2 * i + 1;
            lut.push((((num / den) + 1) / 2) as u32);
        }
        Newton { lut }
    }

    /// NR steps needed to reach F+4 accurate bits from the 8-bit seed.
    /// (Takes `&self` so callers hold an instantiated engine; the count
    /// depends only on the format.)
    pub fn nr_steps(&self, n: u32) -> u32 {
        let target = frac_bits(n) + 4;
        let mut bits = LUT_VALUE_BITS - 1; // seed accuracy ≈ 7 bits
        let mut steps = 0;
        while bits < target {
            bits *= 2;
            steps += 1;
        }
        steps
    }

    /// Cycle model: decode(1) + LUT(1) + 2 mults per NR step + final
    /// multiply(2) + remainder fix-up(1) + round/encode(1).
    pub fn cycles(&self, n: u32) -> u32 {
        2 + 2 * self.nr_steps(n) + 4
    }
}

impl Default for Newton {
    fn default() -> Self {
        Self::new()
    }
}

impl DivEngine for Newton {
    fn name(&self) -> &'static str {
        "Newton-Raphson"
    }

    fn algorithm(&self) -> Algorithm {
        Algorithm::Newton
    }

    fn fraction_divide(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
        let f = frac_bits(n);
        debug_assert!(x_sig >> f == 1 && d_sig >> f == 1);
        // Working precision for the reciprocal: P fractional bits.
        let p = f + 8;
        // Seed from the divisor's top fraction bits (d ∈ [1,2)).
        let idx = if f >= LUT_INDEX_BITS {
            (d_sig >> (f - LUT_INDEX_BITS)) & ((1 << LUT_INDEX_BITS) - 1)
        } else {
            (d_sig << (LUT_INDEX_BITS - f)) & ((1 << LUT_INDEX_BITS) - 1)
        } as usize;
        // y ≈ 1/d ∈ (1/2, 1] in Q(p): seed has 8 bits.
        let mut y: u128 = (self.lut[idx] as u128) << (p - LUT_VALUE_BITS);
        let d_q = (d_sig as u128) << (p - f); // d in Q(p), ∈ [2^p, 2^(p+1))

        let steps = self.nr_steps(n);
        for _ in 0..steps {
            // y' = y·(2 − d·y): all in Q(p). Products can exceed 128 bits
            // for n = 64, so use the 256-bit multiply-shift.
            let dy = mulshift(d_q, y, p); // Q(p), ≈ 1
            let two_minus = (2u128 << p).wrapping_sub(dy);
            y = mulshift(y, two_minus, p);
        }

        // Candidate quotient with `prec = n` fraction bits (like golden).
        let prec = n;
        // q ≈ x·y: x in Q(f) → x·y in Q(f+p) → shift to Q(prec).
        let mut q = ((x_sig as u128) * y) >> (f + p - prec);
        // Exact remainder fix-up: r = x·2^prec − q·d (in units of d's Q(f)).
        let num = (x_sig as u128) << prec;
        let mut r = num as i128 - (q * d_sig as u128) as i128;
        let mut fixups = 0;
        while r < 0 {
            q -= 1;
            r += d_sig as i128;
            fixups += 1;
            assert!(fixups < 8, "NR approximation too coarse");
        }
        while r >= d_sig as i128 {
            q += 1;
            r -= d_sig as i128;
            fixups += 1;
            assert!(fixups < 8, "NR approximation too coarse");
        }
        FracQuotient { mag: q, frac_bits: prec, sticky: r != 0, iterations: steps }
    }
}

/// `(a · b) >> s` with a full 256-bit intermediate product.
fn mulshift(a: u128, b: u128, s: u32) -> u128 {
    debug_assert!(s < 128);
    let (a_hi, a_lo) = ((a >> 64) as u64 as u128, a as u64 as u128);
    let (b_hi, b_lo) = ((b >> 64) as u64 as u128, b as u64 as u128);
    let ll = a_lo * b_lo;
    let lh = a_lo * b_hi;
    let hl = a_hi * b_lo;
    let hh = a_hi * b_hi;
    // assemble: product = hh·2^128 + (lh+hl)·2^64 + ll
    let mid = lh.wrapping_add(hl);
    let mid_carry = (mid < lh) as u128; // into 2^128
    let lo = ll.wrapping_add(mid << 64);
    let lo_carry = (lo < ll) as u128;
    let hi = hh + (mid >> 64) + (mid_carry << 64) + lo_carry;
    debug_assert!(hi >> s == 0 || s == 0, "mulshift overflow: result exceeds 128 bits");
    if s == 0 {
        lo
    } else {
        (lo >> s) | (hi << (128 - s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;
    use crate::posit::mask;

    #[test]
    fn nr_step_counts() {
        let e = Newton::new();
        assert_eq!(e.nr_steps(16), 2); // 7 -> 14 -> 28 ≥ 15
        assert_eq!(e.nr_steps(32), 3); // ≥ 31
        assert_eq!(e.nr_steps(64), 4); // ≥ 63
    }

    #[test]
    fn newton_equals_golden_random_all_widths() {
        let mut rng = crate::testkit::Rng::seeded(0x400);
        let e = Newton::new();
        for &n in &[8u32, 10, 16, 24, 32, 48, 64] {
            let f = frac_bits(n);
            for _ in 0..4000 {
                let x = (1 << f) | (rng.next_u64() & mask(f));
                let d = (1 << f) | (rng.next_u64() & mask(f));
                let q = e.fraction_divide(n, x, d);
                let g = golden::frac_divide(n, x, d);
                assert_eq!((q.mag, q.sticky), (g.mag, g.sticky), "n={n} x={x:#x} d={d:#x}");
            }
        }
    }

    #[test]
    fn newton_full_divide_p8_exhaustive() {
        let e = Newton::new();
        let n = 8;
        for xb in 0..=mask(n) {
            for db in 0..=mask(n) {
                let x = crate::posit::Posit::from_bits(n, xb);
                let d = crate::posit::Posit::from_bits(n, db);
                assert_eq!(e.divide(x, d).result, golden::divide(x, d).result, "{x:?}/{d:?}");
            }
        }
    }
}
