//! Ablation: radix-4 digit set a=2 (ρ=2/3, the paper's choice) vs a=3
//! (ρ=1, maximum redundancy). a=3 simplifies selection (wider containment
//! bands) but requires generating the 3d divisor multiple — an extra adder
//! on the multiple path. The derivation proves both feasible and shows
//! the table sizes; the slice-cost model quantifies the trade.

use posit_div::division::selection::derive_radix4_thresholds;
use posit_div::hardware::components as c;
use posit_div::hardware::Cost;

fn main() {
    for a in [2i64, 3] {
        match derive_radix4_thresholds(a) {
            Some(rows) => {
                println!("a={a} (ρ={a}/3): feasible; thresholds per interval = {}", rows[0].len());
                for (i, row) in rows.iter().enumerate() {
                    println!("  d∈[{}/16,{}/16): {row:?} (1/16 units)", i + 8, i + 9);
                }
            }
            None => println!("a={a}: infeasible at 4-bit estimate granularity"),
        }
    }

    // Hardware trade at the iteration slice (w = 34-bit Posit32 datapath):
    let w = 34;
    let a2_slice = c::est_adder(7)
        .then(c::sel::radix4_table())
        .then(c::mux4(w))
        .then(c::csa(w));
    // a=3: one fewer comparator level in selection, but a 3d generator
    // (d + 2d via an extra CSA level) and a wider multiple mux.
    let a3_slice = c::est_adder(7)
        .then(Cost::new(120.0, 3.0)) // simpler selection PLA
        .then(c::csa(w)) // 3d = d + 2d
        .then(c::mux4(w).then(c::mux2(w))) // 7-way multiple select
        .then(c::csa(w));
    println!("\nslice cost @w={w}: a=2 area {:.0} GE delay {:.0}τ | a=3 area {:.0} GE delay {:.0}τ",
        a2_slice.area, a2_slice.delay, a3_slice.area, a3_slice.delay);
    println!("-> a=2 wins on the slice ({}τ shallower, {:.0} GE smaller): the paper's choice",
        a3_slice.delay - a2_slice.delay, a3_slice.area - a2_slice.area);
    assert!(a2_slice.delay < a3_slice.delay && a2_slice.area < a3_slice.area);
}
