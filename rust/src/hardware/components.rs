//! Unit-gate component library.
//!
//! Each function returns the [`Cost`] (area in gate-equivalents, delay in
//! unit-gate τ) of a datapath component at a given bit width, using the
//! classic unit-gate conventions (FA: 7 GE / 4τ, 2-input gate: 1 GE / 1τ,
//! XOR: 2.2 GE / 2τ, DFF: 5.5 GE) plus log-depth models for prefix adders,
//! shifters and counters. [`designs`](super::designs) composes these into
//! the paper's divider variants.

/// Area (GE) and critical-path delay (τ) of a component.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub area: f64,
    pub delay: f64,
}

impl Cost {
    pub const ZERO: Cost = Cost { area: 0.0, delay: 0.0 };

    pub fn new(area: f64, delay: f64) -> Cost {
        Cost { area, delay }
    }

    /// Serial composition: areas add, delays add.
    pub fn then(self, next: Cost) -> Cost {
        Cost { area: self.area + next.area, delay: self.delay + next.delay }
    }

    /// Parallel composition: areas add, delay is the max.
    pub fn beside(self, other: Cost) -> Cost {
        Cost { area: self.area + other.area, delay: self.delay.max(other.delay) }
    }

    /// Replicate `k` instances in parallel (same path depth).
    pub fn times(self, k: f64) -> Cost {
        Cost { area: self.area * k, delay: self.delay }
    }

    /// Area only (off the critical path).
    pub fn area_only(self) -> Cost {
        Cost { area: self.area, delay: 0.0 }
    }
}

#[inline]
fn lg(w: u32) -> f64 {
    (w.max(2) as f64).log2().ceil()
}

/// 3:2 carry-save adder row (one FA per bit).
pub fn csa(w: u32) -> Cost {
    Cost::new(7.0 * w as f64, 4.0)
}

/// Parallel-prefix (Kogge-Stone-class) carry-propagate adder — what a
/// timing-driven synthesis run instantiates.
pub fn cpa_prefix(w: u32) -> Cost {
    let wf = w as f64;
    Cost::new(3.0 * wf + 2.5 * wf * lg(w), 2.0 * lg(w) + 4.0)
}

/// Ripple-carry adder — what an area-optimizing run with *no timing
/// constraint* instantiates (the paper's combinational synthesis mode).
pub fn cpa_ripple(w: u32) -> Cost {
    Cost::new(7.0 * w as f64, 2.0 * w as f64 + 2.0)
}

/// Adder selection mirroring the synthesis mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdderStyle {
    /// Min-area mapping (combinational, unconstrained): ripple carry.
    AreaOptimized,
    /// Timing-driven mapping (pipelined @1.5 GHz): parallel prefix.
    TimingDriven,
}

/// Carry-propagate adder in the given style.
pub fn cpa(style: AdderStyle, w: u32) -> Cost {
    match style {
        AdderStyle::AreaOptimized => cpa_ripple(w),
        AdderStyle::TimingDriven => cpa_prefix(w),
    }
}

/// Short carry-select adder for selection-function estimates (w ≤ 8):
/// shallow and cheap because both carry polarities are precomputed.
pub fn est_adder(w: u32) -> Cost {
    debug_assert!(w <= 8);
    Cost::new(10.0 * w as f64, 4.0 + w as f64 / 2.0)
}

/// 2:1 multiplexer row.
pub fn mux2(w: u32) -> Cost {
    Cost::new(3.0 * w as f64, 2.0)
}

/// 4:1 one-hot multiplexer row (AOI implementation).
pub fn mux4(w: u32) -> Cost {
    Cost::new(8.0 * w as f64, 3.0)
}

/// Conditional inverter row (XOR with a control line).
pub fn xor_row(w: u32) -> Cost {
    Cost::new(2.2 * w as f64, 2.0)
}

/// Register (DFF) row — area only; the sequencing overhead lives in
/// `Tech::reg_overhead_tau`.
pub fn reg(w: u32) -> Cost {
    Cost::new(5.5 * w as f64, 0.0)
}

/// Leading-zero counter (for posit regime decode / normalization).
pub fn lzc(w: u32) -> Cost {
    Cost::new(2.0 * w as f64 + 0.5 * w as f64 * lg(w), 2.0 * lg(w) + 2.0)
}

/// Logarithmic barrel shifter.
pub fn shifter(w: u32) -> Cost {
    Cost::new(3.0 * w as f64 * lg(w), 2.0 * lg(w))
}

/// Zero-detect over a conventional word (NOR reduction tree).
pub fn zero_tree(w: u32) -> Cost {
    Cost::new(1.2 * w as f64, lg(w) + 1.0)
}

/// §III-B2 sign+zero lookahead network over a carry-save pair: an XOR/OR
/// preprocessing row feeding a pruned prefix tree (carries only, no sum
/// muxes) — faster and smaller than resolving with a full CPA + zero tree.
pub fn cs_sign_zero_lookahead(w: u32) -> Cost {
    let wf = w as f64;
    Cost::new(4.5 * wf + 1.2 * wf * lg(w), 2.0 * lg(w) + 2.0)
}

/// Selection-function logic (after the estimate adder).
pub mod sel {
    use super::Cost;

    /// Eq. (26)/(27): a handful of gates on ≤4 bits.
    pub fn radix2() -> Cost {
        Cost::new(10.0, 2.0)
    }

    /// Eq. (28): the 8×4 `m_k(d̂)` threshold PLA + comparators.
    pub fn radix4_table() -> Cost {
        Cost::new(170.0, 4.0)
    }

    /// Eq. (29): five fixed thresholds on 6 bits.
    pub fn radix4_const() -> Cost {
        Cost::new(35.0, 2.0)
    }

    /// Table I scaling-factor selection (3 bits → 2 shift amounts).
    pub fn scaling_factor() -> Cost {
        Cost::new(25.0, 2.0)
    }
}

/// Array multiplier with a CSA reduction tree and prefix final adder
/// (for the Newton–Raphson baseline).
pub fn multiplier(w: u32) -> Cost {
    let wf = w as f64;
    // partial products w², reduction ~log3/2 depth, final CPA 2w bits
    let tree_levels = (wf.log2() / (1.5f64).log2()).ceil();
    Cost::new(1.5 * wf * wf + 7.0 * wf * (wf - 2.0).max(1.0), 4.0 * tree_levels)
        .then(cpa_prefix(2 * w))
}

/// Reciprocal seed lookup table (2^idx × out bits, as synthesized logic).
pub fn lut(index_bits: u32, out_bits: u32) -> Cost {
    let words = (1u64 << index_bits) as f64;
    Cost::new(0.4 * words * out_bits as f64, 2.0 * index_bits as f64 / 2.0 + 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn composition_laws() {
        let a = Cost::new(10.0, 5.0);
        let b = Cost::new(20.0, 3.0);
        assert_eq!(a.then(b), Cost::new(30.0, 8.0));
        assert_eq!(a.beside(b), Cost::new(30.0, 5.0));
        assert_eq!(a.times(3.0), Cost::new(30.0, 5.0));
    }

    #[test]
    fn adder_scaling_is_logarithmic() {
        // prefix adder: doubling width adds a constant ~2τ.
        let d32 = cpa_prefix(32).delay;
        let d64 = cpa_prefix(64).delay;
        assert!((d64 - d32 - 2.0).abs() < 1e-9);
        // area grows superlinearly
        assert!(cpa_prefix(64).area > 2.0 * cpa_prefix(32).area * 0.9);
    }

    #[test]
    fn csa_depth_is_constant() {
        assert_eq!(csa(16).delay, csa(128).delay);
    }

    #[test]
    fn lookahead_cheaper_than_resolve() {
        // FR's termination advantage: lookahead sign/zero vs full CPA +
        // zero tree, at every paper width's datapath.
        for w in [18u32, 34, 66] {
            let fr = cs_sign_zero_lookahead(w);
            let slow = cpa_prefix(w).then(zero_tree(w));
            assert!(fr.delay < slow.delay, "w={w}");
            assert!(fr.area < slow.area, "w={w}");
        }
    }

    #[test]
    fn estimate_adders_shallow() {
        // The whole point of truncated estimates: far shallower than the
        // full-width CPA they replace.
        assert!(est_adder(4).delay < cpa_prefix(34).delay / 2.0);
        assert!(est_adder(7).delay < cpa_prefix(34).delay);
    }

    #[test]
    fn multiplier_dominates_adders() {
        assert!(multiplier(28).area > 10.0 * cpa_prefix(28).area);
    }
}
