//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [positional...] [--flag[=| ]value] [--switch]`.
//!
//! A `--flag` consumes the next token as its value when that token is not
//! itself a flag — where "not a flag" means it doesn't start with `-`,
//! *or* it parses as a (possibly negative) number, so `--offset -3` and
//! `--scale -1.5` work while `--csv -x` leaves `-x` alone (it becomes a
//! positional, available for downstream diagnostics).

use std::collections::HashMap;

/// Can `tok` serve as the value of a preceding `--flag`?
fn looks_like_value(tok: &str) -> bool {
    if tok.starts_with("--") {
        return false;
    }
    match tok.strip_prefix('-') {
        // `-1`, `-1.5e3` are negative-number values; `-x`, `-` are not.
        Some(rest) => rest.parse::<f64>().is_ok(),
        None => true,
    }
}

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|p| looks_like_value(p)) {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.flags.insert(name.to_string(), "true".to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process arguments.
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Typed flag with default; exits with a message on parse failure.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.flags.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("invalid value for --{name}: {v:?}");
                std::process::exit(2);
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("serve x y");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["x", "y"]);
    }

    #[test]
    fn flags_with_values() {
        let a = parse("synth --n 32 --mode=pipe --csv");
        assert_eq!(a.get("n", 0u32), 32);
        assert_eq!(a.flag("mode"), Some("pipe"));
        assert!(a.has("csv"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn switch_before_positional_is_greedy() {
        // documented behavior: `--flag value` consumes the next token
        let a = parse("run --threads 8 trailing");
        assert_eq!(a.get("threads", 0u32), 8);
        assert_eq!(a.positional, vec!["trailing"]);
    }

    #[test]
    fn typed_defaults() {
        let a = parse("x");
        assert_eq!(a.get("missing", 7u64), 7);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("sweep --offset -3 --scale -1.5 --csv");
        assert_eq!(a.get("offset", 0i64), -3);
        assert_eq!(a.get("scale", 0.0f64), -1.5);
        assert!(a.has("csv"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn switch_before_dash_token_stays_a_switch() {
        // `-x` is not a number, so `--verbose` must not swallow it.
        let a = parse("run --verbose -x after");
        assert_eq!(a.flag("verbose"), Some("true"));
        assert_eq!(a.positional, vec!["-x", "after"]);
        // a lone `-` is conventionally a positional (stdin), not a value
        let a = parse("run --verbose -");
        assert_eq!(a.flag("verbose"), Some("true"));
        assert_eq!(a.positional, vec!["-"]);
    }

    #[test]
    fn negative_value_then_positional() {
        let a = parse("divide --n 16 -2.5 0.5");
        assert_eq!(a.get("n", 0u32), 16);
        assert_eq!(a.positional, vec!["-2.5", "0.5"]);
    }
}
