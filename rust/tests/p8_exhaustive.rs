//! Exhaustive Posit8 gates for the serving datapaths: every one of the
//! 256×256 bit-pattern pairs through the default division engine
//! (SRT r4 CS OF FR) against the exact golden model — at the
//! full-division level and at the fraction-recurrence level
//! (`golden::frac_divide`) — plus every one of the 256 patterns through
//! the sqrt unit against the exact-rational golden (`golden_sqrt`), and
//! the whole pattern space again through the **Fast tier**'s
//! width-monomorphized kernels (the serving default under `Auto`).
//!
//! The engine-level sweeps are `#[ignore]`d for local `cargo test` (the
//! tier-1 suite already covers Posit8 exhaustively across all engines in
//! `engines_cross.rs` and the sqrt engine in its module tests); CI runs
//! them explicitly with `cargo test --test p8_exhaustive -- --ignored`
//! so the serving datapaths are gated on every push. The **table-path**
//! sweep below runs un-ignored: a constant-time lookup per case makes
//! the full 65k-pair space per op cheap enough for tier-1. The
//! **vector-path** sweep (explicit AVX2/NEON kernels behind the `vsimd`
//! feature) runs un-ignored as well, skipping gracefully on hosts where
//! `Unit::with_exec(.., FastPath::Vector)` is a typed refusal. The
//! **quire-dot** sweep also runs un-ignored: every two-term Posit8 dot
//! is a couple of 128-bit adds per tier, well inside the tier-1 budget.
//! The **approx-tier** sweep runs un-ignored too: it is the machine
//! check of the bounded-error contract — every registered approx kernel
//! over the whole pattern space, observed ulp error ≤ the declared
//! [`ApproxSpec::max_ulp`], specials bit-exact.

// The division gates deliberately run through the deprecated `Divider`
// wrapper so the legacy entry point stays pinned bit-exact.
#![allow(deprecated)]

use posit_div::division::sqrt::golden_sqrt;
use posit_div::division::{golden, Algorithm, DivEngine, Divider};
use posit_div::posit::{mask, Posit, Unpacked};
use posit_div::testkit::rational;
use posit_div::unit::{ExecTier, FastPath, Op, Unit};

/// Exhaustive Posit8 **table-path** gate — runs un-`#[ignore]`d in
/// tier-1: the lazily-built op tables (`division::p8_tables`) already
/// verify every entry against golden at construction, and this sweep
/// additionally drives all 256×256 pattern pairs per binary op (and all
/// 256 patterns for sqrt) through the *dispatch* (`Unit::run_batch` with
/// the table kernel forced), re-checking each result against the exact
/// references — 65k cases per op is well inside a tier-1 budget.
#[test]
fn p8_table_path_matches_exact_references_on_all_pattern_pairs() {
    let n = 8;
    let p = |bits: u64| Posit::from_bits(n, bits);
    let bs: Vec<u64> = (0..=mask(n)).collect();
    let mut out = vec![0u64; bs.len()];
    for op in [Op::DIV, Op::Mul, Op::Add, Op::Sub] {
        let unit = Unit::with_exec(n, op, ExecTier::Fast, FastPath::Table)
            .expect("binary Posit8 ops are tabulated");
        for a in 0..=mask(n) {
            let avec = vec![a; bs.len()];
            unit.run_batch(&avec, &bs, &[], &mut out).expect("equal lanes");
            for (i, &got) in out.iter().enumerate() {
                let b = bs[i];
                let want = match op {
                    Op::Div { .. } => golden::divide(p(a), p(b)).result.to_bits(),
                    Op::Mul => p(a).mul(p(b)).to_bits(),
                    Op::Add => p(a).add(p(b)).to_bits(),
                    _ => p(a).sub(p(b)).to_bits(),
                };
                assert_eq!(got, want, "{op} table path: {a:#04x}, {b:#04x}");
            }
        }
    }
    // sqrt: the whole pattern space in one batch
    let sqrt = Unit::with_exec(n, Op::Sqrt, ExecTier::Fast, FastPath::Table)
        .expect("sqrt is tabulated");
    sqrt.run_batch(&bs, &[], &[], &mut out).expect("equal lanes");
    for (i, &got) in out.iter().enumerate() {
        assert_eq!(got, golden_sqrt(p(bs[i])).result.to_bits(), "sqrt table path: {:#04x}", bs[i]);
    }
    // and the ternary op correctly has no table
    assert!(Unit::with_exec(n, Op::MulAdd, ExecTier::Fast, FastPath::Table).is_err());
}

/// Exhaustive Posit8 **vector-path** gate — runs un-`#[ignore]`d in
/// tier-1: all 256×256 pattern pairs per binary op through
/// `Unit::run_batch` with the explicit AVX2/NEON kernel forced
/// (`FastPath::Vector`), re-checking each result against the exact
/// references. On hosts without the `vsimd` feature or a detected
/// vector ISA, `Unit::with_exec` refuses with a typed error and the
/// sweep skips gracefully — the gate then proves only the refusal
/// shape, never a wrong bit.
#[test]
fn p8_vector_path_matches_exact_references_on_all_pattern_pairs() {
    let n = 8;
    let p = |bits: u64| Posit::from_bits(n, bits);
    let bs: Vec<u64> = (0..=mask(n)).collect();
    let mut out = vec![0u64; bs.len()];
    for op in [Op::DIV, Op::Mul, Op::Add, Op::Sub] {
        let Ok(unit) = Unit::with_exec(n, op, ExecTier::Fast, FastPath::Vector) else {
            continue; // no vsimd feature / no detected vector ISA
        };
        for a in 0..=mask(n) {
            let avec = vec![a; bs.len()];
            unit.run_batch(&avec, &bs, &[], &mut out).expect("equal lanes");
            for (i, &got) in out.iter().enumerate() {
                let b = bs[i];
                let want = match op {
                    Op::Div { .. } => golden::divide(p(a), p(b)).result.to_bits(),
                    Op::Mul => p(a).mul(p(b)).to_bits(),
                    Op::Add => p(a).add(p(b)).to_bits(),
                    _ => p(a).sub(p(b)).to_bits(),
                };
                assert_eq!(got, want, "{op} vector path: {a:#04x}, {b:#04x}");
            }
        }
    }
    // sqrt and mul_add are never vector-served — a typed refusal whether
    // or not the host has a vector ISA
    assert!(Unit::with_exec(n, Op::Sqrt, ExecTier::Fast, FastPath::Vector).is_err());
    assert!(Unit::with_exec(n, Op::MulAdd, ExecTier::Fast, FastPath::Vector).is_err());
}

/// Exhaustive Posit8 **quire-dot** gate — runs un-`#[ignore]`d in
/// tier-1: every one of the 256×256 pattern pairs as the two-term dot
/// `round(a·b + b·a)` through `Op::Dot`'s `Unit::run_batch` on **both**
/// tiers (Fast = in-register i128 accumulator, Datapath = limb quire),
/// checked against the exact-rational reference (`testkit::rational`,
/// bignum dyadics — no quire code, no floats). Two-term dots cover every
/// product magnitude the quire can see at Posit8 (maxpos² down to
/// minpos²), every sign combination, exact cancellation, and NaR/zero
/// propagation; each case is a couple of wide adds, so the full space
/// fits the tier-1 budget.
#[test]
fn p8_quire_dot_matches_rational_golden_on_all_pattern_pairs() {
    let n = 8;
    let p = |bits: u64| Posit::from_bits(n, bits);
    let fast = Unit::with_tier(n, Op::Dot, ExecTier::Fast).expect("standard width");
    let dp = Unit::with_tier(n, Op::Dot, ExecTier::Datapath).expect("standard width");
    let mut out = [0u64];
    for a in 0..=mask(n) {
        for b in 0..=mask(n) {
            let want = rational::dot(&[p(a), p(b)], &[p(b), p(a)]).to_bits();
            fast.run_batch(&[a, b], &[b, a], &[], &mut out).expect("matched lanes");
            assert_eq!(out[0], want, "fast dot([{a:#04x},{b:#04x}],[{b:#04x},{a:#04x}])");
            dp.run_batch(&[a, b], &[b, a], &[], &mut out).expect("matched lanes");
            assert_eq!(out[0], want, "datapath dot([{a:#04x},{b:#04x}],[{b:#04x},{a:#04x}])");
        }
    }
}

/// Exhaustive Posit8 **approx-tier** gate — runs un-`#[ignore]`d in
/// tier-1: every registered bounded-error kernel (div, mul over all
/// 256×256 pattern pairs; sqrt over all 256 patterns) through
/// `Unit::run_batch` with the tier pinned to `Approx`, asserting
///
///   * the observed ulp error against the exact reference never
///     exceeds the kernel's declared [`ApproxSpec::max_ulp`] — the
///     machine check behind the spec registry,
///   * special inputs (NaR operands, zeros, negative radicands, zero
///     divisors) produce **bit-exact** results — the approx contract
///     only relaxes real-lane rounding, never special semantics,
///   * the batch kernels and the scalar dispatch (`run_bits`) agree
///     bit-for-bit, so the SWAR-style lanes serve the same function.
#[test]
fn p8_approx_tier_stays_within_declared_ulp_bounds_on_all_patterns() {
    let n = 8;
    let p = |bits: u64| Posit::from_bits(n, bits);
    let bs: Vec<u64> = (0..=mask(n)).collect();
    let mut out = vec![0u64; bs.len()];
    for op in [Op::DIV, Op::Mul] {
        let spec = op.approx_spec(n).expect("div and mul register Posit8 approx kernels");
        assert_eq!(spec.n, n);
        let unit = Unit::with_tier(n, op, ExecTier::Approx).expect("standard width");
        let mut worst = 0u64;
        for a in 0..=mask(n) {
            let avec = vec![a; bs.len()];
            unit.run_batch(&avec, &bs, &[], &mut out).expect("equal lanes");
            for (i, &got) in out.iter().enumerate() {
                let b = bs[i];
                assert_eq!(
                    got,
                    unit.run_bits(a, b, 0),
                    "{op} approx batch vs scalar: {a:#04x}, {b:#04x}"
                );
                let want = match op {
                    Op::Div { .. } => golden::divide(p(a), p(b)).result,
                    _ => p(a).mul(p(b)),
                };
                let special = p(a).is_nar() || p(b).is_nar() || p(a).is_zero() || p(b).is_zero();
                if special {
                    assert_eq!(
                        got,
                        want.to_bits(),
                        "{op} approx must be bit-exact on specials: {a:#04x}, {b:#04x}"
                    );
                } else {
                    let dist = p(got).ulp_distance(want);
                    assert!(
                        dist <= spec.max_ulp,
                        "{op} approx {a:#04x}, {b:#04x}: {dist} ulp > declared {}",
                        spec.max_ulp
                    );
                    worst = worst.max(dist);
                }
            }
        }
        assert!(worst <= spec.max_ulp, "{op}: observed {worst} > declared {}", spec.max_ulp);
    }
    // sqrt: the whole pattern space in one batch
    let spec = Op::Sqrt.approx_spec(n).expect("sqrt registers a Posit8 approx kernel");
    let sqrt = Unit::with_tier(n, Op::Sqrt, ExecTier::Approx).expect("standard width");
    sqrt.run_batch(&bs, &[], &[], &mut out).expect("equal lanes");
    for (i, &got) in out.iter().enumerate() {
        let v = p(bs[i]);
        assert_eq!(got, sqrt.run_bits(bs[i], 0, 0), "sqrt approx batch vs scalar: {:#04x}", bs[i]);
        let want = golden_sqrt(v).result;
        if v.is_nar() || v.is_zero() || v.is_negative() {
            assert_eq!(got, want.to_bits(), "sqrt approx special: {:#04x}", bs[i]);
        } else {
            let dist = p(got).ulp_distance(want);
            assert!(
                dist <= spec.max_ulp,
                "sqrt approx {:#04x}: {dist} ulp > declared {}",
                bs[i],
                spec.max_ulp
            );
        }
    }
}

#[test]
#[ignore = "exhaustive CI gate; run with `cargo test --test p8_exhaustive -- --ignored`"]
fn p8_default_engine_matches_golden_on_all_pattern_pairs() {
    let n = 8;
    let div = Divider::new(n, Algorithm::DEFAULT).expect("standard width");
    assert_eq!(div.algorithm(), Algorithm::Srt4CsOfFr, "default engine changed; update gate");
    for xb in 0..=mask(n) {
        let x = Posit::from_bits(n, xb);
        for db in 0..=mask(n) {
            let d = Posit::from_bits(n, db);
            let want = golden::divide(x, d).result;
            let got = div.divide(x, d).expect("width matches").result;
            assert_eq!(
                got, want,
                "{}: {x:?}/{d:?} -> {got:?}, golden {want:?}",
                div.name()
            );
        }
    }
}

#[test]
#[ignore = "exhaustive CI gate; run with `cargo test --test p8_exhaustive -- --ignored`"]
fn p8_sqrt_unit_matches_exact_rational_golden_on_all_patterns() {
    let n = 8;
    let unit = Unit::new(n, Op::Sqrt).expect("standard width");
    for vb in 0..=mask(n) {
        let v = Posit::from_bits(n, vb);
        // `golden_sqrt` is the exact reference: integer ⌊√·⌋ on the full
        // radicand plus a single pattern-space rounding.
        let want = golden_sqrt(v);
        let got = unit.run(&[v]).expect("width matches");
        assert_eq!(
            got.result, want.result,
            "sqrt unit: {v:?} -> {:?}, golden {:?}",
            got.result, want.result
        );
        // the unit reports real digit-recurrence work for real inputs
        if !v.is_nar() && !v.is_zero() && !v.is_negative() {
            assert_eq!(got.iterations, unit.iterations(), "{v:?}");
        } else {
            assert_eq!(got.iterations, 0, "{v:?} takes the special fast path");
        }
    }
}

/// Exhaustive Fast-tier gate: every Posit8 pattern pair through the
/// width-monomorphized fast kernels — division and the binary arithmetic
/// ops against the exact references, sqrt over all 256 patterns, and
/// mul-add with a directed third lane. The serving default (`Auto`)
/// resolves batch traffic to exactly these kernels.
#[test]
#[ignore = "exhaustive CI gate; run with `cargo test --test p8_exhaustive -- --ignored`"]
fn p8_fast_tier_matches_exact_references_on_all_pattern_pairs() {
    let n = 8;
    let p = |bits: u64| Posit::from_bits(n, bits);
    let units: Vec<Unit> = [Op::DIV, Op::Mul, Op::Add, Op::Sub, Op::MulAdd, Op::Sqrt]
        .into_iter()
        .map(|op| Unit::with_tier(n, op, ExecTier::Fast).expect("standard width"))
        .collect();
    let c_directed = [0u64, 1 << (n - 1), 1 << (n - 2), mask(n - 1)];
    for a in 0..=mask(n) {
        for b in 0..=mask(n) {
            for unit in &units {
                match unit.op() {
                    Op::Div { .. } => {
                        let want = golden::divide(p(a), p(b)).result.to_bits();
                        assert_eq!(unit.run_bits(a, b, 0), want, "div {a:#x}/{b:#x}");
                    }
                    Op::Mul => {
                        assert_eq!(
                            unit.run_bits(a, b, 0),
                            p(a).mul(p(b)).to_bits(),
                            "mul {a:#x}*{b:#x}"
                        );
                    }
                    Op::Add => {
                        assert_eq!(
                            unit.run_bits(a, b, 0),
                            p(a).add(p(b)).to_bits(),
                            "add {a:#x}+{b:#x}"
                        );
                    }
                    Op::Sub => {
                        assert_eq!(
                            unit.run_bits(a, b, 0),
                            p(a).sub(p(b)).to_bits(),
                            "sub {a:#x}-{b:#x}"
                        );
                    }
                    Op::MulAdd => {
                        for c in c_directed {
                            assert_eq!(
                                unit.run_bits(a, b, c),
                                p(a).mul_add(p(b), p(c)).to_bits(),
                                "mul_add {a:#x}*{b:#x}+{c:#x}"
                            );
                        }
                    }
                    Op::Sqrt => {
                        if b == 0 {
                            assert_eq!(
                                unit.run_bits(a, 0, 0),
                                golden_sqrt(p(a)).result.to_bits(),
                                "sqrt {a:#x}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
#[ignore = "exhaustive CI gate; run with `cargo test --test p8_exhaustive -- --ignored`"]
fn p8_fraction_recurrence_matches_frac_divide_on_all_real_pairs() {
    let n = 8;
    let div = Divider::new(n, Algorithm::DEFAULT).expect("standard width");
    for xb in 0..=mask(n) {
        let x = Posit::from_bits(n, xb);
        for db in 0..=mask(n) {
            let d = Posit::from_bits(n, db);
            let (Unpacked::Real(a), Unpacked::Real(b)) = (x.unpack(), d.unpack()) else {
                continue; // specials never reach the fraction datapath
            };
            let want = golden::frac_divide(n, a.sig, b.sig);
            let got = div.fraction_divide(n, a.sig, b.sig);
            // Engines may carry more or fewer fraction bits than the
            // golden's fixed n; compare at the coarser precision with
            // dropped bits folded into sticky.
            let fb = got.frac_bits.min(want.frac_bits);
            assert_eq!(
                got.refine_to(fb),
                want.refine_to(fb),
                "sig {:#x}/{:#x} (from {x:?}/{d:?}): engine {got:?}, golden {want:?}",
                a.sig,
                b.sig
            );
        }
    }
}
