//! Rendering of the paper's evaluation artifacts (Tables I–IV, Figs. 4–9,
//! the §IV comparison against [14]) as text tables and CSV.

use super::synth::{combinational, pipelined, Mode, SynthReport};
use super::tech::{Tech, TSMC28};
use crate::division::{iterations, latency_cycles, Algorithm};

/// The three formats the paper evaluates.
pub const FORMATS: [u32; 3] = [16, 32, 64];

/// Figure id for a synthesis sweep (paper numbering).
pub fn figure_id(n: u32, mode: Mode) -> &'static str {
    match (n, mode) {
        (16, Mode::Combinational) => "Fig. 4",
        (32, Mode::Combinational) => "Fig. 5",
        (64, Mode::Combinational) => "Fig. 6",
        (16, Mode::Pipelined) => "Fig. 7",
        (32, Mode::Pipelined) => "Fig. 8",
        (64, Mode::Pipelined) => "Fig. 9",
        _ => "custom",
    }
}

/// Run the full design-matrix sweep for one figure.
pub fn sweep(n: u32, mode: Mode, tech: &Tech) -> Vec<SynthReport> {
    Algorithm::TABLE_IV
        .iter()
        .map(|&a| match mode {
            Mode::Combinational => combinational(a, n, tech),
            Mode::Pipelined => pipelined(a, n, tech),
        })
        .collect()
}

/// Render one figure's sweep as an aligned text table.
pub fn render_figure(n: u32, mode: Mode, tech: &Tech) -> String {
    let rows = sweep(n, mode, tech);
    let mut out = String::new();
    out.push_str(&format!(
        "{} — {} {}-bit posit dividers (28 nm model)\n",
        figure_id(n, mode),
        match mode {
            Mode::Combinational => "combinational",
            Mode::Pipelined => "pipelined @1.5GHz",
        },
        n
    ));
    out.push_str(&format!(
        "{:<18} {:>12} {:>10} {:>8} {:>12} {:>10} {:>12}\n",
        "design", "area [µm²]", "delay[ns]", "cycles", "latency[ns]", "power[mW]", "energy[pJ]"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<18} {:>12.1} {:>10.3} {:>8} {:>12.2} {:>10.3} {:>12.3}{}\n",
            r.alg.label(),
            r.area_um2,
            r.delay_ns,
            r.cycles,
            r.latency_ns,
            r.power_mw,
            r.energy_pj,
            if r.timing_met { "" } else { "  (!timing)" }
        ));
    }
    out
}

/// CSV export of a sweep (one line per design).
pub fn sweep_csv(n: u32, mode: Mode, tech: &Tech) -> String {
    let mut out =
        String::from("figure,design,n,mode,area_um2,delay_ns,cycles,latency_ns,power_mw,energy_pj\n");
    for r in sweep(n, mode, tech) {
        out.push_str(&format!(
            "{},{},{},{:?},{:.2},{:.4},{},{:.3},{:.4},{:.4}\n",
            figure_id(n, mode),
            r.alg.label(),
            r.n,
            r.mode,
            r.area_um2,
            r.delay_ns,
            r.cycles,
            r.latency_ns,
            r.power_mw,
            r.energy_pj
        ));
    }
    out
}

/// Table II: iterations and latency per format and radix.
pub fn render_table2() -> String {
    let mut out = String::from(
        "Table II — iterations / latency (pipelined cycles)\n\
         format    sig.bits   r2 iters  r2 latency  r4 iters  r4 latency\n",
    );
    for n in FORMATS {
        out.push_str(&format!(
            "Posit{:<5} {:>8} {:>9} {:>11} {:>9} {:>11}\n",
            n,
            crate::posit::sig_bits(n),
            iterations(n, 2),
            latency_cycles(n, Algorithm::Srt2Cs),
            iterations(n, 4),
            latency_cycles(n, Algorithm::Srt4Cs),
        ));
    }
    out
}

/// The §IV comparison against [14] (ASAP'23): our NRD and SRT-CS designs
/// vs the two's-complement-decoded NRD baseline.
pub struct Asap23Comparison {
    pub n: u32,
    pub nrd_area_delta_pct: f64,
    pub nrd_delay_delta_pct: f64,
    pub srtcs_delay_delta_pct: f64,
    pub srtcs_area_delta_pct: f64,
    pub srtcs_energy_delta_pct: f64,
}

/// Compute the comparison rows (combinational designs, like the paper).
pub fn asap23_comparison(tech: &Tech) -> Vec<Asap23Comparison> {
    FORMATS
        .iter()
        .map(|&n| {
            let base = combinational(Algorithm::NrdAsap23, n, tech);
            let nrd = combinational(Algorithm::Nrd, n, tech);
            let srtcs = combinational(Algorithm::Srt2CsOfFr, n, tech);
            let pct = |ours: f64, theirs: f64| (ours / theirs - 1.0) * 100.0;
            Asap23Comparison {
                n,
                nrd_area_delta_pct: pct(nrd.area_um2, base.area_um2),
                nrd_delay_delta_pct: pct(nrd.delay_ns, base.delay_ns),
                srtcs_delay_delta_pct: pct(srtcs.delay_ns, base.delay_ns),
                srtcs_area_delta_pct: pct(srtcs.area_um2, base.area_um2),
                srtcs_energy_delta_pct: pct(srtcs.energy_pj, base.energy_pj),
            }
        })
        .collect()
}

pub fn render_asap23(tech: &Tech) -> String {
    let mut out = String::from(
        "§IV comparison vs [14] (two's-complement NRD baseline), combinational\n\
         format   NRD area    NRD delay   SRT-CS delay  SRT-CS area  SRT-CS energy\n",
    );
    for c in asap23_comparison(tech) {
        out.push_str(&format!(
            "Posit{:<4} {:>+9.1}% {:>+10.1}% {:>+12.1}% {:>+11.1}% {:>+13.1}%\n",
            c.n,
            c.nrd_area_delta_pct,
            c.nrd_delay_delta_pct,
            c.srtcs_delay_delta_pct,
            c.srtcs_area_delta_pct,
            c.srtcs_energy_delta_pct
        ));
    }
    out
}

/// Render everything (the `synth` CLI subcommand).
pub fn render_all() -> String {
    let tech = TSMC28;
    let mut out = String::new();
    out.push_str(&render_table2());
    out.push('\n');
    for mode in [Mode::Combinational, Mode::Pipelined] {
        for n in FORMATS {
            out.push_str(&render_figure(n, mode, &tech));
            out.push('\n');
        }
    }
    out.push_str(&render_asap23(&tech));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figures_render_all_designs() {
        let t = TSMC28;
        for mode in [Mode::Combinational, Mode::Pipelined] {
            for n in FORMATS {
                let s = render_figure(n, mode, &t);
                for a in Algorithm::TABLE_IV {
                    assert!(s.contains(a.label()), "{mode:?} n={n} missing {}", a.label());
                }
            }
        }
    }

    #[test]
    fn csv_well_formed() {
        let t = TSMC28;
        let csv = sweep_csv(32, Mode::Pipelined, &t);
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 1 + Algorithm::TABLE_IV.len());
        let ncols = lines[0].split(',').count();
        for l in &lines[1..] {
            assert_eq!(l.split(',').count(), ncols);
        }
    }

    /// The paper's §IV headline: vs [14], NRD saves area and delay; the
    /// optimized SRT-CS saves large delay/energy at small area cost, with
    /// savings growing with the format width.
    #[test]
    fn asap23_comparison_shape() {
        let rows = asap23_comparison(&TSMC28);
        for c in &rows {
            assert!(c.nrd_area_delta_pct < 0.0, "NRD must save area vs [14]");
            assert!(c.nrd_delay_delta_pct < 0.0, "NRD must save delay vs [14]");
            assert!(c.srtcs_delay_delta_pct < -30.0, "SRT-CS large delay cut");
            // paper: +16.8/13.8/12% — the unit-gate model over-weights the
            // CS/OF fixed overheads, landing higher; the claim preserved is
            // "moderate area overhead against a multi-x delay/energy win"
            assert!(
                c.srtcs_area_delta_pct > 0.0 && c.srtcs_area_delta_pct < 70.0,
                "SRT-CS moderate area overhead, got {}",
                c.srtcs_area_delta_pct
            );
            assert!(c.srtcs_energy_delta_pct < -30.0, "SRT-CS large energy cut");
        }
        // savings grow with width (paper: 40.6% → 62.1% → 75.6% delay)
        assert!(rows[2].srtcs_delay_delta_pct < rows[1].srtcs_delay_delta_pct);
        assert!(rows[1].srtcs_delay_delta_pct < rows[0].srtcs_delay_delta_pct);
        assert!(rows[2].srtcs_energy_delta_pct < rows[1].srtcs_energy_delta_pct);
    }

    #[test]
    fn table2_contents() {
        let s = render_table2();
        assert!(s.contains("14") && s.contains("30") && s.contains("62"));
        assert!(s.contains("Posit64"));
    }
}
