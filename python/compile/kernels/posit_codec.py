"""Vectorized Posit⟨n,2⟩ codec in pure jnp (build-time only).

Mirrors the Rust `posit::fields` / `posit::round` modules bit-for-bit:
decode uses the sign-magnitude convention (two's complement first), encode
rounds in pattern space (guard/sticky on the regime‖exponent‖fraction bit
string) with saturation at maxpos/minpos and never rounding to 0 or NaR.

Supports n ≤ 32 (the int64 pattern frame needs rl + 2 + sfb ≤ 63 bits);
Posit64 is served natively by the Rust engines.

All lanes are int64; widths are static Python ints so everything traces
into a single XLA computation.
"""

import jax
import jax.numpy as jnp
from jax import lax

jax.config.update("jax_enable_x64", True)

ES = 2


def frac_bits(n: int) -> int:
    """Worst-case fraction bits of a Posit⟨n,2⟩ (n-5, clamped)."""
    return max(n - 5, 0)


def mask(w: int) -> int:
    return (1 << w) - 1


def decode(bits, n: int):
    """Decode n-bit patterns (int64 lanes, low n bits significant).

    Returns (is_zero, is_nar, sign, scale, sig):
      sign  : bool lanes
      scale : int64, 4k + e
      sig   : int64, (1 << F) | fraction  — significand in [1,2) at F
              fraction bits, F = frac_bits(n).
    """
    bits = jnp.asarray(bits, jnp.int64) & mask(n)
    f = frac_bits(n)
    is_zero = bits == 0
    is_nar = bits == (1 << (n - 1))
    sign = (bits >> (n - 1)) & 1 == 1
    magnitude = jnp.where(sign, (-bits) & mask(n), bits)

    # left-align the n-1 body bits in a uint64 word
    body = (magnitude & mask(n - 1)).astype(jnp.uint64) << (64 - (n - 1))
    r0 = (body >> 63) == 1
    inverted = jnp.where(r0, ~body, body)
    run = jnp.minimum(lax.clz(inverted), jnp.uint64(n - 1)).astype(jnp.int64)
    k = jnp.where(r0, run - 1, -run)

    consumed = jnp.minimum(run + 1, n - 1)
    rem = (n - 1) - consumed  # bits left for exponent + fraction
    # tail: rem bits, right-aligned (shift count is lane-dependent)
    tail = jnp.where(
        rem > 0,
        ((body << consumed.astype(jnp.uint64)) >> (64 - rem).astype(jnp.uint64)).astype(
            jnp.int64
        ),
        0,
    )
    eb = jnp.minimum(rem, ES)
    e = jnp.where(eb > 0, (tail >> (rem - eb)) << (ES - eb), 0)
    fb = rem - eb
    frac = (tail & ((1 << fb) - 1)) << (f - fb)

    scale = 4 * k + e
    sig = (1 << f) | frac
    return is_zero, is_nar, sign, scale, sig


def encode(sign, scale, sig, sfb: int, sticky, n: int):
    """Encode to n-bit patterns with pattern-space round-to-nearest-even.

    `sig` lanes must be normalized to [1,2): hidden bit at position `sfb`.
    Requires rl_max + 2 + sfb ≤ 63, i.e. sfb ≤ 62 - n.
    """
    assert sfb <= 62 - n, f"pattern frame overflow: sfb={sfb}, n={n}"
    sig = jnp.asarray(sig, jnp.int64)
    scale = jnp.asarray(scale, jnp.int64)
    sticky = jnp.asarray(sticky, jnp.bool_)

    k = scale >> ES
    e = scale & mask(ES)

    sat_hi = k >= n - 2  # |v| >= maxpos ⇒ clamp to maxpos
    sat_lo = k <= -(n - 1)  # 0 < |v| <= minpos boundary ⇒ minpos
    # clamp k so the frame below stays in range for saturated lanes
    k_c = jnp.clip(k, -(n - 2), n - 3)

    # unbounded body as an integer: regime ‖ e ‖ frac
    regime_val = jnp.where(k_c >= 0, (2 << (k_c + 1)) - 2, 1)
    rl = jnp.where(k_c >= 0, k_c + 2, 1 - k_c)
    frac = sig & mask(sfb)
    body = (((regime_val << ES) | e) << sfb) | frac
    length = rl + ES + sfb

    shift = length - (n - 1)  # ≥ 2 always (rl ≥ 2, sfb ≥ ... )
    m = body >> shift
    g = (body >> (shift - 1)) & 1
    rest = (body & ((1 << (shift - 1)) - 1)) != 0
    rest = rest | sticky
    m = m + jnp.where((g == 1) & (rest | (m & 1 == 1)), 1, 0)

    # never 0, never NaR
    m = jnp.clip(m, 1, mask(n - 1))
    # saturation
    m = jnp.where(sat_hi, mask(n - 1), m)
    m = jnp.where(sat_lo, 1, m)

    return jnp.where(sign, (-m) & mask(n), m)
