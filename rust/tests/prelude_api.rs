//! Integration coverage for the redesigned public surface: the `prelude`
//! import, typed posits (round-trip conversions, operators, ordering),
//! the zero-alloc `Divider`, and the golden cross-check that the batch
//! path is bit-identical to the scalar path for every Table IV algorithm.

// This suite deliberately exercises the deprecated `Divider` wrapper to
// pin its compatibility contract.
#![allow(deprecated)]

use posit_div::division::golden;
use posit_div::posit::mask;
use posit_div::prelude::*;
use posit_div::testkit::{self, gen, Config, Rng};

#[test]
fn snippets_style_usage_compiles_and_is_accurate() {
    // the acceptance-criterion one-liner
    let q = P32::round_from(355.0) / P32::round_from(113.0);
    assert!((q.to_f64() - 355.0 / 113.0).abs() < 1e-6);

    // constants, comparisons, conversions
    assert!(P16::MIN_POSITIVE < P16::ONE && P16::ONE < P16::MAXPOS);
    let x: P16 = 2.5f64.round_into();
    assert_eq!((x + P16::ONE).to_f64(), 3.5);
    assert_eq!(P8::ONE.to_bits(), 0b0100_0000);
}

#[test]
fn typed_roundtrip_via_f64_p8_p16_p32() {
    // f64 holds every posit ≤ 32 exactly: to_f64 → round_from must be the
    // identity on every non-NaR pattern.
    let mut rng = Rng::seeded(0xF64);
    for _ in 0..20_000 {
        let p8 = P8::from_bits(rng.next_u64() & mask(8));
        if !p8.is_nar() {
            assert_eq!(P8::round_from(p8.to_f64()), p8, "{p8:?}");
        }
        let p16 = P16::from_bits(rng.next_u64() & mask(16));
        if !p16.is_nar() {
            assert_eq!(P16::round_from(p16.to_f64()), p16, "{p16:?}");
        }
        let p32 = P32::from_bits(rng.next_u64() & mask(32));
        if !p32.is_nar() {
            assert_eq!(P32::round_from(p32.to_f64()), p32, "{p32:?}");
        }
    }
}

#[test]
fn typed_p64_bits_roundtrip_and_order() {
    // P64's to_f64 is lossy (59 > 52 significand bits), so pin the
    // bit-level API and the ordering instead.
    let mut rng = Rng::seeded(0x64);
    let mut bits: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
    // posit order == sign-extended integer order of the pattern
    bits.sort_by_key(|&b| b as i64);
    let mut prev: Option<P64> = None;
    for &b in &bits {
        let p = P64::from_bits(b);
        assert_eq!(p.to_bits(), b);
        if let Some(q) = prev {
            assert!(q <= p, "typed order must match pattern order");
        }
        prev = Some(p);
    }
    // and the f64 path is still a *rounding* (total order preserved)
    assert!(P64::round_from(1.5) < P64::round_from(2.5));
    assert_eq!(P64::round_from(1.0), P64::ONE);
}

#[test]
fn typed_operators_match_runtime_posit_ops() {
    // operators on P16 must be bit-identical to the runtime-width calls
    testkit::forall_ns(
        Config::cases(10_000).with_seed(0x0905),
        |rng| (gen::real_posit(rng, 16), gen::real_posit(rng, 16)),
        |&(a, b)| {
            let (ta, tb) = (P16::from_posit(a).unwrap(), P16::from_posit(b).unwrap());
            if (ta + tb).as_posit() != a.add(b) {
                return Err("add mismatch".into());
            }
            if (ta - tb).as_posit() != a.sub(b) {
                return Err("sub mismatch".into());
            }
            if (ta * tb).as_posit() != a.mul(b) {
                return Err("mul mismatch".into());
            }
            if (-ta).as_posit() != a.neg() {
                return Err("neg mismatch".into());
            }
            if !b.is_zero() {
                let want = golden::divide(a, b).result;
                if (ta / tb).as_posit() != want {
                    return Err("div mismatch vs golden".into());
                }
            }
            // ordering agrees with total_cmp
            if (ta < tb) != a.total_cmp(b).is_lt() {
                return Err("ordering mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn divide_batch_matches_scalar_and_golden_all_table_iv() {
    // The acceptance criterion: divide_batch agrees element-for-element
    // with golden-backed scalar divide across all Table IV variants.
    let mut rng = Rng::seeded(0xBA7C);
    for n in [8u32, 16, 32, 64] {
        let xs: Vec<u64> = (0..300).map(|_| rng.next_u64() & mask(n)).collect();
        let ds: Vec<u64> = (0..300).map(|_| rng.next_u64() & mask(n)).collect();
        for alg in Algorithm::TABLE_IV {
            let ctx = Divider::new(n, alg).expect("valid width");
            let mut out = vec![0u64; xs.len()];
            ctx.divide_batch(&xs, &ds, &mut out).expect("equal lengths");
            for (i, ((&xb, &db), &got)) in
                xs.iter().zip(ds.iter()).zip(out.iter()).enumerate()
            {
                let x = Posit::from_bits(n, xb);
                let d = Posit::from_bits(n, db);
                let scalar = ctx.divide(x, d).expect("width matches").result.to_bits();
                let want = golden::divide(x, d).result.to_bits();
                assert_eq!(got, scalar, "{} batch!=scalar n={n} i={i}", alg.label());
                assert_eq!(got, want, "{} batch!=golden n={n} i={i}", alg.label());
            }
        }
    }
}

#[test]
fn divide_batch_parallel_matches_serial_all_table_iv() {
    let mut rng = Rng::seeded(0x9A12);
    let n = 16;
    let xs: Vec<u64> = (0..777).map(|_| rng.next_u64() & mask(n)).collect();
    let ds: Vec<u64> = (0..777).map(|_| rng.next_u64() & mask(n)).collect();
    for alg in Algorithm::TABLE_IV {
        let ctx = Divider::new(n, alg).expect("valid width");
        let mut serial = vec![0u64; xs.len()];
        let mut par = vec![0u64; xs.len()];
        ctx.divide_batch(&xs, &ds, &mut serial).expect("equal lengths");
        ctx.divide_batch_parallel(&xs, &ds, &mut par, 3).expect("equal lengths");
        assert_eq!(serial, par, "{}", alg.label());
    }
}

#[test]
fn typed_errors_on_the_public_surface() {
    assert_eq!(Divider::new(2, Algorithm::Nrd).err(), Some(PositError::WidthOutOfRange { n: 2 }));
    let ctx = Divider::new(16, Algorithm::Nrd).unwrap();
    assert_eq!(
        ctx.divide(Posit::from_f64(32, 1.0), Posit::from_f64(32, 2.0)).err(),
        Some(PositError::WidthMismatch { expected: 16, got: 32 })
    );
    let mut out = vec![0u64; 3];
    assert_eq!(
        ctx.divide_batch(&[1, 2], &[3, 4], &mut out).err(),
        Some(PositError::BatchShapeMismatch { xs: 2, ds: 2, out: 3 })
    );
    // errors render for humans
    let msg = PositError::WidthMismatch { expected: 16, got: 32 }.to_string();
    assert!(msg.contains("Posit16") && msg.contains("Posit32"));
}
