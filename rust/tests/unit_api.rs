//! Integration coverage for the operation-generic execution surface:
//! every [`Op`] end-to-end through [`Unit::run_batch`] *and* the
//! coordinator [`Client`], division bit-identical to the legacy
//! `Divider` wrapper, and the typed/arity error contract.

use posit_div::posit::mask;
use posit_div::prelude::*;
use posit_div::testkit::Rng;
use posit_div::workload::{self, OpMix};

/// Raw lanes for a batch of `count` random patterns at width `n`.
fn lanes(rng: &mut Rng, n: u32, count: usize) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
    let mut lane = |_: u32| (0..count).map(|_| rng.next_u64() & mask(n)).collect::<Vec<u64>>();
    (lane(0), lane(1), lane(2))
}

#[test]
fn every_op_round_trips_through_run_batch() {
    let mut rng = Rng::seeded(0xAB1);
    for n in [8u32, 16, 32, 64] {
        let (a, b, c) = lanes(&mut rng, n, 250);
        for op in Op::DEFAULTS {
            let unit = Unit::new(n, op).expect("valid width");
            let (lb, lc): (&[u64], &[u64]) = match op.arity() {
                1 => (&[], &[]),
                2 => (&b, &[]),
                _ => (&b, &c),
            };
            let mut out = vec![0u64; a.len()];
            unit.run_batch(&a, lb, lc, &mut out).expect("equal lanes");
            let mut parallel = vec![0u64; a.len()];
            unit.run_batch_parallel(&a, lb, lc, &mut parallel, 3).expect("equal lanes");
            assert_eq!(out, parallel, "{op} n={n} parallel != serial");
            for i in 0..a.len() {
                let operands: Vec<Posit> = [a[i], b[i], c[i]]
                    .iter()
                    .take(op.arity())
                    .map(|&bits| Posit::from_bits(n, bits))
                    .collect();
                let req = OpRequest::new(op, &operands).expect("arity matches");
                // `OpRequest::golden` is the shared exact-reference table
                // (pinned against an independent per-op table in the
                // unit module's own tests)
                let want = req.golden();
                assert_eq!(out[i], want.to_bits(), "{op} n={n} i={i} batch != reference");
                let scalar = unit.run(&operands).expect("width matches");
                assert_eq!(scalar.result.to_bits(), want.to_bits(), "{op} n={n} i={i} scalar");
            }
        }
    }
}

#[test]
#[allow(deprecated)]
fn unit_division_is_bit_identical_to_divider() {
    let mut rng = Rng::seeded(0xD1D);
    for n in [8u32, 16, 32] {
        let (xs, ds, _) = lanes(&mut rng, n, 300);
        for alg in Algorithm::TABLE_IV {
            let unit = Unit::new(n, Op::Div { alg }).expect("valid width");
            let div = Divider::new(n, alg).expect("valid width");
            let mut unit_out = vec![0u64; xs.len()];
            let mut div_out = vec![0u64; xs.len()];
            unit.run_batch(&xs, &ds, &[], &mut unit_out).expect("equal lanes");
            div.divide_batch(&xs, &ds, &mut div_out).expect("equal lengths");
            assert_eq!(unit_out, div_out, "{} n={n}", alg.label());
            // scalar metadata parity too
            let x = Posit::from_bits(n, xs[0]);
            let d = Posit::from_bits(n, ds[0]);
            let a = unit.run(&[x, d]).expect("width matches");
            let b = div.divide(x, d).expect("width matches");
            assert_eq!((a.result, a.iterations, a.cycles), (b.result, b.iterations, b.cycles));
        }
    }
}

#[test]
fn typed_sqrt_and_prelude_exports() {
    // P8..P64 sqrt routes through the same engine the unit serves.
    let engine = SqrtEngine::new();
    let mut rng = Rng::seeded(0x50);
    for _ in 0..2000 {
        let p16 = P16::from_bits(rng.next_u64() & mask(16));
        assert_eq!(p16.sqrt().as_posit(), engine.sqrt(p16.as_posit()).result);
        let p64 = P64::from_bits(rng.next_u64());
        assert_eq!(p64.sqrt().as_posit(), engine.sqrt(p64.as_posit()).result);
    }
    assert_eq!(P32::round_from(2.25).sqrt(), P32::round_from(1.5));
    assert!(P8::round_from(-4.0).sqrt().is_nar());
    // golden_sqrt and SqrtResult are reachable from the prelude
    let r: SqrtResult = golden_sqrt(Posit::from_f64(16, 4.0));
    assert_eq!(r.result.to_f64(), 2.0);
}

#[test]
fn exec_tier_is_part_of_the_public_surface() {
    // ExecTier comes from the prelude; with_tier builds pinned units and
    // the resolution rules are observable.
    let auto = Unit::new(16, Op::DIV).expect("valid width");
    assert_eq!(auto.tier(), ExecTier::Auto);
    assert_eq!(auto.batch_tier(), ExecTier::Fast);
    assert_eq!(auto.scalar_tier(), ExecTier::Datapath);
    let fast = Unit::with_tier(16, Op::DIV, ExecTier::Fast).expect("valid width");
    let dp = Unit::with_tier(16, Op::DIV, ExecTier::Datapath).expect("valid width");
    // the three tiers agree bit-for-bit on a quick sample
    let mut rng = Rng::seeded(0x71E5);
    for _ in 0..200 {
        let (a, b) = (rng.next_u64(), rng.next_u64());
        let want = dp.run_bits(a, b, 0);
        assert_eq!(fast.run_bits(a, b, 0), want);
        assert_eq!(auto.run_bits(a, b, 0), want);
    }
    // service config carries a tier
    let cfg = ServiceConfig { tier: ExecTier::Fast, ..ServiceConfig::default() };
    assert_eq!(cfg.tier, ExecTier::Fast);
}

#[test]
fn arity_width_and_lane_errors_are_typed() {
    let sqrt = Unit::new(16, Op::Sqrt).expect("valid width");
    assert_eq!(
        sqrt.run(&[Posit::one(16), Posit::one(16)]).err(),
        Some(PositError::ArityMismatch { op: "sqrt", expected: 1, got: 2 })
    );
    assert_eq!(
        sqrt.run(&[Posit::one(32)]).err(),
        Some(PositError::WidthMismatch { expected: 16, got: 32 })
    );
    let fma = Unit::new(16, Op::MulAdd).expect("valid width");
    let mut out = [0u64; 2];
    assert_eq!(
        fma.run_batch(&[1, 2], &[3, 4], &[5], &mut out).err(),
        Some(PositError::BatchLaneMismatch { lane: "c", expected: 2, got: 1 })
    );
    let div = Unit::new(16, Op::DIV).expect("valid width");
    assert_eq!(
        div.run_batch(&[1, 2, 3], &[1, 2, 3], &[], &mut out).err(),
        Some(PositError::BatchShapeMismatch { xs: 3, ds: 3, out: 2 })
    );
    assert_eq!(Unit::new(3, Op::Sqrt).err(), Some(PositError::WidthOutOfRange { n: 3 }));
}

#[test]
fn client_serves_every_op_and_counts_it() {
    let svc = DivisionService::start(ServiceConfig {
        n: 16,
        backend: Backend::Native { alg: Algorithm::DEFAULT, threads: 2 },
        policy: BatchPolicy { max_batch: 64, max_wait: std::time::Duration::from_micros(100) },
        tier: ExecTier::Auto,
    })
    .expect("native service starts");
    let client = svc.client();
    let mut rng = Rng::seeded(0xC11E);
    let mut reqs = Vec::new();
    for _ in 0..60 {
        let real = |rng: &mut Rng| loop {
            let p = Posit::from_bits(16, rng.next_u64() & mask(16));
            if !p.is_nar() {
                return p;
            }
        };
        let (x, y, z) = (real(&mut rng), real(&mut rng), real(&mut rng));
        reqs.push(OpRequest::div(x, y));
        reqs.push(OpRequest::div_with(Algorithm::Srt2Cs, x, y));
        reqs.push(OpRequest::sqrt(x.abs()));
        reqs.push(OpRequest::mul(x, y));
        reqs.push(OpRequest::add(x, y));
        reqs.push(OpRequest::sub(x, y));
        reqs.push(OpRequest::mul_add(x, y, z));
    }
    let results = client.submit_ops(&reqs).expect("service running").wait().expect("running");
    assert_eq!(results.len(), reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        assert_eq!(results[i], req.golden(), "{} i={i}", req.op);
    }
    let m = svc.metrics();
    assert_eq!(m.ops.get(Op::DIV), 120, "both div algorithms share the div bucket");
    assert_eq!(m.ops.get(Op::Sqrt), 60);
    assert_eq!(m.ops.get(Op::Mul), 60);
    assert_eq!(m.ops.get(Op::Add), 60);
    assert_eq!(m.ops.get(Op::Sub), 60);
    assert_eq!(m.ops.get(Op::MulAdd), 60);
    svc.shutdown();
}

#[test]
fn mixed_workload_through_client_matches_references() {
    let n = 32;
    let svc = DivisionService::start(ServiceConfig {
        n,
        backend: Backend::Native { alg: Algorithm::DEFAULT, threads: 4 },
        policy: BatchPolicy::default(),
        tier: ExecTier::Auto,
    })
    .expect("native service starts");
    let client = svc.client();
    let mut wl = workload::MixedOps::new(n, OpMix::DEFAULT, 0x314);
    let reqs = workload::take_requests(&mut wl, 500);
    let results = client.submit_ops(&reqs).expect("service running").wait().expect("running");
    for (i, req) in reqs.iter().enumerate() {
        assert_eq!(results[i], req.golden(), "{} i={i}", req.op);
    }
    svc.shutdown();
}
