//! The legacy division-only context — now a thin wrapper over the
//! operation-generic [`Unit`](crate::unit::Unit).
//!
//! [`Divider`] was the crate's original zero-alloc hot-path context,
//! hard-wired to division. The execution surface has since been
//! generalized: [`crate::unit::Unit`] serves every op (`Div`, `Sqrt`,
//! `Mul`, `Add`, `Sub`, `MulAdd`) through the same batch-first entry
//! points, and a `Unit` built with [`crate::unit::Op::Div`] is exactly
//! what a `Divider` used to be — same engines, same caches, bit-identical
//! results. `Divider` remains as a deprecated alias so existing callers
//! keep compiling; new code should construct a `Unit`.

use super::{Algorithm, DivEngine, Division, FracQuotient};
use crate::error::Result;
use crate::posit::Posit;
use crate::unit::{Op, Unit};

/// A reusable division context for one posit width and one algorithm.
///
/// Deprecated: this is now a thin wrapper over a [`Unit`] with
/// [`Op::Div`]; build that directly for new code (it also serves sqrt,
/// mul, add/sub and mul-add through the same batch-first surface).
///
/// ```
/// use posit_div::division::{Algorithm, Divider};
/// use posit_div::posit::Posit;
///
/// # #[allow(deprecated)]
/// let div = Divider::new(32, Algorithm::Srt4CsOfFr)?;
/// let q = div.divide(Posit::from_f64(32, 355.0), Posit::from_f64(32, 113.0))?;
/// assert!((q.result.to_f64() - 355.0 / 113.0).abs() < 1e-6);
/// # Ok::<(), posit_div::PositError>(())
/// ```
#[deprecated(
    since = "0.3.0",
    note = "use `Unit::new(n, Op::Div { alg })` — the operation-generic context"
)]
pub struct Divider(Unit);

#[allow(deprecated)]
impl Divider {
    /// Build a context for `Posit<n, 2>` division with `alg`.
    pub fn new(n: u32, alg: Algorithm) -> Result<Divider> {
        Ok(Divider(Unit::new(n, Op::Div { alg })?))
    }

    /// The default serving context: the paper's optimized radix-4 unit.
    pub fn standard(n: u32) -> Result<Divider> {
        Divider::new(n, Algorithm::DEFAULT)
    }

    /// Posit width this context divides.
    #[inline]
    pub fn width(&self) -> u32 {
        self.0.width()
    }

    /// The algorithm variant.
    #[inline]
    pub fn algorithm(&self) -> Algorithm {
        self.0.algorithm().expect("a Divider always wraps a division unit")
    }

    /// Cached recurrence iteration count (0 for the Newton baseline).
    #[inline]
    pub fn iterations(&self) -> u32 {
        self.0.iterations()
    }

    /// Cached pipelined latency in cycles (paper §III-E3).
    #[inline]
    pub fn latency_cycles(&self) -> u32 {
        self.0.latency_cycles()
    }

    /// The wrapped operation-generic context.
    #[inline]
    pub fn as_unit(&self) -> &Unit {
        &self.0
    }

    /// One full posit division with metadata. Errors on operand width
    /// mismatch instead of panicking.
    #[inline]
    pub fn divide(&self, x: Posit, d: Posit) -> Result<Division> {
        self.0.run(&[x, d])
    }

    /// Divide two raw `n`-bit patterns (high garbage bits are masked off).
    #[inline]
    pub fn divide_bits(&self, x: u64, d: u64) -> u64 {
        self.0.run_bits(x, d, 0)
    }

    /// Batch-first division over raw bit patterns: `out[i] = xs[i] / ds[i]`.
    pub fn divide_batch(&self, xs: &[u64], ds: &[u64], out: &mut [u64]) -> Result<()> {
        self.0.run_batch(xs, ds, &[], out)
    }

    /// [`Divider::divide_batch`] split into `threads` chunks on the
    /// shared crate-level worker pool.
    pub fn divide_batch_parallel(
        &self,
        xs: &[u64],
        ds: &[u64],
        out: &mut [u64],
        threads: usize,
    ) -> Result<()> {
        self.0.run_batch_parallel(xs, ds, &[], out, threads)
    }
}

#[allow(deprecated)]
impl core::fmt::Debug for Divider {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        f.debug_struct("Divider")
            .field("n", &self.width())
            .field("algorithm", &self.algorithm())
            .field("iterations", &self.iterations())
            .field("latency_cycles", &self.latency_cycles())
            .finish()
    }
}

/// A `Divider` is itself a [`DivEngine`], so it drops into every API that
/// takes one with static dispatch inside.
#[allow(deprecated)]
impl DivEngine for Divider {
    fn name(&self) -> &'static str {
        self.0.engine_name()
    }

    fn algorithm(&self) -> Algorithm {
        Divider::algorithm(self)
    }

    fn fraction_divide(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
        self.0
            .as_div_engine()
            .expect("a Divider always wraps a division unit")
            .fraction_divide(n, x_sig, d_sig)
    }
}

#[allow(deprecated)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::{golden, iterations, latency_cycles};
    use crate::error::PositError;
    use crate::posit::mask;
    use crate::testkit::Rng;

    #[test]
    fn rejects_bad_width() {
        assert_eq!(
            Divider::new(3, Algorithm::Nrd).err(),
            Some(PositError::WidthOutOfRange { n: 3 })
        );
        assert_eq!(
            Divider::new(65, Algorithm::Nrd).err(),
            Some(PositError::WidthOutOfRange { n: 65 })
        );
        assert!(Divider::new(4, Algorithm::Nrd).is_ok());
        assert!(Divider::new(64, Algorithm::Srt4CsOfFr).is_ok());
    }

    #[test]
    fn rejects_width_mismatch() {
        let div = Divider::new(16, Algorithm::Srt2Cs).unwrap();
        let err = div.divide(Posit::one(32), Posit::one(32)).unwrap_err();
        assert_eq!(err, PositError::WidthMismatch { expected: 16, got: 32 });
        let err = div.divide(Posit::one(16), Posit::one(8)).unwrap_err();
        assert_eq!(err, PositError::WidthMismatch { expected: 16, got: 8 });
    }

    #[test]
    fn rejects_batch_shape_mismatch() {
        let div = Divider::new(16, Algorithm::Srt2Cs).unwrap();
        let mut out = [0u64; 2];
        let err = div.divide_batch(&[1, 2, 3], &[1, 2, 3], &mut out).unwrap_err();
        assert_eq!(err, PositError::BatchShapeMismatch { xs: 3, ds: 3, out: 2 });
        let err = div.divide_batch(&[1, 2], &[1], &mut out).unwrap_err();
        assert_eq!(err, PositError::BatchShapeMismatch { xs: 2, ds: 1, out: 2 });
    }

    #[test]
    fn caches_match_free_functions() {
        for n in [8u32, 16, 32, 64] {
            for alg in Algorithm::TABLE_IV {
                let div = Divider::new(n, alg).unwrap();
                assert_eq!(div.iterations(), iterations(n, alg.radix().unwrap()));
                assert_eq!(div.latency_cycles(), latency_cycles(n, alg));
                assert_eq!(div.width(), n);
                assert_eq!(div.algorithm(), alg);
            }
        }
    }

    #[test]
    fn scalar_and_batch_agree_with_golden() {
        let mut rng = Rng::seeded(0xD1F);
        for n in [8u32, 16, 32] {
            let div = Divider::standard(n).unwrap();
            let xs: Vec<u64> = (0..200).map(|_| rng.next_u64()).collect();
            let ds: Vec<u64> = (0..200).map(|_| rng.next_u64()).collect();
            let mut out = vec![0u64; xs.len()];
            div.divide_batch(&xs, &ds, &mut out).unwrap();
            for i in 0..xs.len() {
                let x = Posit::from_bits(n, xs[i] & mask(n));
                let d = Posit::from_bits(n, ds[i] & mask(n));
                let want = golden::divide(x, d).result.to_bits();
                assert_eq!(out[i], want, "batch n={n} i={i}");
                assert_eq!(div.divide(x, d).unwrap().result.to_bits(), want, "scalar n={n} i={i}");
            }
        }
    }

    #[test]
    fn parallel_batch_is_bit_identical() {
        let mut rng = Rng::seeded(0x9A);
        let div = Divider::standard(16).unwrap();
        let xs: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
        let ds: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
        let mut serial = vec![0u64; xs.len()];
        let mut parallel = vec![0u64; xs.len()];
        div.divide_batch(&xs, &ds, &mut serial).unwrap();
        div.divide_batch_parallel(&xs, &ds, &mut parallel, 4).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn divider_is_a_div_engine() {
        let div = Divider::new(16, Algorithm::Srt4CsOfFr).unwrap();
        let e: &dyn DivEngine = &div;
        assert_eq!(e.name(), "SRT r4 CS OF FR");
        assert_eq!(e.algorithm(), Algorithm::Srt4CsOfFr);
        let d = e.divide(Posit::one(16), Posit::one(16));
        assert_eq!(d.result, Posit::one(16));
    }

    #[test]
    fn wrapper_is_bit_identical_to_the_unit() {
        let mut rng = Rng::seeded(0x1DE);
        let n = 16;
        for alg in Algorithm::TABLE_IV {
            let div = Divider::new(n, alg).unwrap();
            let unit = Unit::new(n, Op::Div { alg }).unwrap();
            for _ in 0..500 {
                let (x, d) = (rng.next_u64(), rng.next_u64());
                assert_eq!(div.divide_bits(x, d), unit.run_bits(x, d, 0), "{}", alg.label());
            }
            assert_eq!(div.as_unit().op(), Op::Div { alg });
        }
    }
}
