//! Figs. 7-9: pipelined synthesis sweeps at the paper's 1.5 GHz target —
//! thin shim over [`posit_div::bench::suites`], where the suite body
//! lives so the same code runs under `cargo bench --bench fig7_9_pipelined`
//! and `posit-div bench fig7_9_pipelined` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("fig7_9_pipelined");
}
