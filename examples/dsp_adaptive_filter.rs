//! DSP workload: an NLMS adaptive filter (system identification) running
//! entirely in posit arithmetic — the division-heavy signal-processing
//! scenario the paper's introduction motivates.
//!
//! The NLMS update `w += µ·e·x / (ε + ‖x‖²)` performs one division per
//! sample. We identify an unknown 8-tap FIR channel from a noisy stream at
//! Posit16 and Posit32, once per division engine, and report:
//!   * convergence (residual error) — identical across engines, because
//!     every engine is bit-exact,
//!   * the divider cycle count spent (Table II in action: radix-4 halves
//!     the division cycles of the filter).
//!
//! ```sh
//! cargo run --release --example dsp_adaptive_filter
//! ```

use posit_div::division::{Algorithm, DivEngine};
use posit_div::posit::Posit;
use posit_div::testkit::Rng;
use posit_div::unit::{Op, Unit};

const TAPS: usize = 8;
const SAMPLES: usize = 4000;
const MU: f64 = 0.5;

/// One NLMS run in Posit⟨n,2⟩ with the given division engine.
/// Returns (final MSE over the last 10%, total divider cycles).
fn nlms(n: u32, engine: &dyn DivEngine, seed: u64) -> (f64, u64) {
    let mut rng = Rng::seeded(seed);
    // unknown channel
    let channel: Vec<f64> = (0..TAPS).map(|_| rng.f64_unit() * 2.0 - 1.0).collect();

    let mut w: Vec<Posit> = vec![Posit::zero(n); TAPS];
    let mut x_hist = [0.0f64; TAPS];
    let mu = Posit::from_f64(n, MU);
    let eps = Posit::from_f64(n, 1e-3);

    let mut cycles = 0u64;
    let mut err_acc = 0.0;
    let mut err_count = 0;

    for t in 0..SAMPLES {
        // new input sample, shift the delay line
        x_hist.rotate_right(1);
        x_hist[0] = rng.f64_unit() * 2.0 - 1.0;
        let x: Vec<Posit> = x_hist.iter().map(|&v| Posit::from_f64(n, v)).collect();

        // desired = channel(x) + noise
        let noise = (rng.f64_unit() - 0.5) * 1e-3;
        let desired: f64 =
            channel.iter().zip(&x_hist).map(|(c, v)| c * v).sum::<f64>() + noise;
        let d_p = Posit::from_f64(n, desired);

        // filter output y = w·x (posit arithmetic)
        let mut y = Posit::zero(n);
        for i in 0..TAPS {
            y = y.add(w[i].mul(x[i]));
        }
        let e = d_p.sub(y);

        // normalization: ‖x‖² + ε, then THE division
        let mut norm = eps;
        for xi in &x {
            norm = norm.add(xi.mul(*xi));
        }
        let g = engine.divide(e.mul(mu), norm); // (µ·e) / (ε + ‖x‖²)
        cycles += g.cycles as u64;

        // w += g * x
        for i in 0..TAPS {
            w[i] = w[i].add(g.result.mul(x[i]));
        }

        if t >= SAMPLES * 9 / 10 {
            let ef = e.to_f64();
            err_acc += ef * ef;
            err_count += 1;
        }
    }
    (err_acc / err_count as f64, cycles)
}

fn main() {
    println!("NLMS system identification, {TAPS} taps, {SAMPLES} samples, µ={MU}");
    for n in [16u32, 32] {
        println!("\nPosit{n}:");
        println!(
            "{:<18} {:>14} {:>16} {:>22}",
            "divider", "final MSE", "divider cycles", "divisions/cycle note"
        );
        let mut baseline_cycles = None;
        for alg in [
            Algorithm::Nrd,
            Algorithm::Srt2Cs,
            Algorithm::Srt4CsOfFr,
            Algorithm::Srt4Scaled,
            Algorithm::Newton,
        ] {
            // one reusable unit per engine — a division `Unit` exposes
            // its engine as a `DivEngine`, so it drops straight into the
            // filter loop
            let ctx = Unit::new(n, Op::Div { alg }).expect("standard width");
            let engine = ctx.as_div_engine().expect("division unit");
            let (mse, cycles) = nlms(n, engine, 0xD5B);
            let note = match baseline_cycles {
                None => {
                    baseline_cycles = Some(cycles);
                    "baseline (NRD)".to_string()
                }
                Some(b) => format!("{:.2}x fewer cycles", b as f64 / cycles as f64),
            };
            println!("{:<18} {:>14.3e} {:>16} {:>22}", ctx.engine_name(), mse, cycles, note);
        }
        println!("(identical MSE across engines = bit-exact divisions; only latency differs)");
    }
}
