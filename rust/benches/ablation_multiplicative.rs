//! Digit recurrence vs multiplicative (Newton-Raphson) division —
//! thin shim over [`posit_div::bench::suites`], where the suite body
//! lives so the same code runs under `cargo bench --bench ablation_multiplicative`
//! and `posit-div bench ablation_multiplicative` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("ablation_multiplicative");
}
