//! Posit addition, subtraction and multiplication.
//!
//! Division is *not* here — it is the paper's subject and lives in
//! [`crate::division`] with one engine per algorithm variant. Add/mul are
//! needed by the DSP example workloads and by the Newton–Raphson baseline
//! divider (which iterates multiplications).
//!
//! Both operations follow the standard hardware recipe: decode, exact wide
//! integer arithmetic with guard bits + sticky, single pattern-space
//! rounding via [`crate::posit::round::encode_round`].

use super::{frac_bits, round::encode_round, Posit, Unpacked};

/// Guard bits carried through alignment in addition (guard/round + sticky).
const G: u32 = 3;

impl Posit {
    /// Correctly-rounded posit multiplication.
    pub fn mul(self, rhs: Posit) -> Posit {
        assert_eq!(self.n, rhs.n, "width mismatch");
        let n = self.n;
        let (a, b) = match (self.unpack(), rhs.unpack()) {
            (Unpacked::NaR, _) | (_, Unpacked::NaR) => return Posit::nar(n),
            (Unpacked::Zero, _) | (_, Unpacked::Zero) => return Posit::zero(n),
            (Unpacked::Real(a), Unpacked::Real(b)) => (a, b),
        };
        let fb = frac_bits(n);
        let prod = (a.sig as u128) * (b.sig as u128); // value = prod / 2^(2fb) in [1,4)
        let sign = a.sign ^ b.sign;
        let scale = a.scale + b.scale;
        if prod >> (2 * fb + 1) != 0 {
            // in [2,4): one more fraction bit, scale up by one.
            encode_round(n, sign, scale + 1, prod, 2 * fb + 1, false)
        } else {
            encode_round(n, sign, scale, prod, 2 * fb, false)
        }
    }

    /// Correctly-rounded posit addition.
    pub fn add(self, rhs: Posit) -> Posit {
        assert_eq!(self.n, rhs.n, "width mismatch");
        let n = self.n;
        let (a, b) = match (self.unpack(), rhs.unpack()) {
            (Unpacked::NaR, _) | (_, Unpacked::NaR) => return Posit::nar(n),
            (Unpacked::Zero, _) => return rhs,
            (_, Unpacked::Zero) => return self,
            (Unpacked::Real(a), Unpacked::Real(b)) => (a, b),
        };
        let fb = frac_bits(n);

        // Order by scale so `hi` dominates; align `lo` down with sticky.
        let (hi, lo) = if a.scale >= b.scale { (a, b) } else { (b, a) };
        let shift = (hi.scale - lo.scale) as u32;

        let hi_mag = (hi.sig as i128) << G;
        let (lo_mag, dropped) = if shift >= fb + 1 + G {
            (0i128, true) // lo entirely below the guard bits
        } else {
            let full = (lo.sig as i128) << G;
            let kept = full >> shift;
            (kept, full & ((1i128 << shift) - 1) != 0)
        };
        let subtracting = hi.sign != lo.sign;
        // When subtracting, dropped bits mean the true |lo| is *larger* than
        // its truncation: bump the truncated magnitude so the remainder sign
        // stays positive and sticky represents a positive deficit.
        let lo_adj = if subtracting && dropped { lo_mag + 1 } else { lo_mag };

        let hi_signed = if hi.sign { -hi_mag } else { hi_mag };
        let lo_signed = if lo.sign { -lo_adj } else { lo_adj };
        let sum = hi_signed + lo_signed;

        if sum == 0 {
            // Exact cancellation of the kept bits. `dropped` here is
            // defensive (provably unreachable: the G guard zeros of `full`
            // keep `lo_adj < hi_mag` whenever bits were dropped) — if it
            // ever fired the true value would be a sub-ulp residue with
            // hi's sign, which posit rounds to ±minpos, never to zero.
            if dropped {
                let m = Posit::minpos(n);
                return if hi.sign { m.neg() } else { m };
            }
            return Posit::zero(n);
        }
        let sign = sum < 0;
        let mag = sum.unsigned_abs();
        // Fraction point currently at fb + G bits below the top of hi.sig's
        // hidden 1; renormalize to the actual MSB.
        let msb = 127 - mag.leading_zeros();
        let scale = hi.scale + msb as i32 - (fb + G) as i32;
        encode_round(n, sign, scale, mag, msb, dropped)
    }

    /// Correctly-rounded posit subtraction.
    #[inline]
    pub fn sub(self, rhs: Posit) -> Posit {
        self.add(rhs.neg())
    }

    /// Fused-style helper `self*a + b` built from mul+add (NOT a quire —
    /// two roundings). Used by example workloads only.
    #[inline]
    pub fn mul_add(self, a: Posit, b: Posit) -> Posit {
        self.mul(a).add(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::mask;

    /// f64 is exact for posit8 operands and their sums/products, so
    /// from_f64(exact) is the correctly rounded reference.
    #[test]
    fn add_exhaustive_posit8() {
        let n = 8;
        for xa in 0..=mask(n) {
            let pa = Posit::from_bits(n, xa);
            for xb in 0..=mask(n) {
                let pb = Posit::from_bits(n, xb);
                let got = pa.add(pb);
                if pa.is_nar() || pb.is_nar() {
                    assert!(got.is_nar());
                    continue;
                }
                let want = Posit::from_f64(n, pa.to_f64() + pb.to_f64());
                assert_eq!(got, want, "{pa:?} + {pb:?}");
            }
        }
    }

    #[test]
    fn mul_exhaustive_posit8() {
        let n = 8;
        for xa in 0..=mask(n) {
            let pa = Posit::from_bits(n, xa);
            for xb in 0..=mask(n) {
                let pb = Posit::from_bits(n, xb);
                let got = pa.mul(pb);
                if pa.is_nar() || pb.is_nar() {
                    assert!(got.is_nar());
                    continue;
                }
                let want = Posit::from_f64(n, pa.to_f64() * pb.to_f64());
                assert_eq!(got, want, "{pa:?} * {pb:?}");
            }
        }
    }

    /// Exact i128 reference for posit16 addition (sig ≤ 12 bits, scale span
    /// ≤ 112 ⇒ fits i128), checked on a random sample.
    #[test]
    fn add_random_posit16_exact_reference() {
        let n = 16;
        let mut rng = crate::testkit::Rng::seeded(0xADD16);
        for _ in 0..200_000 {
            let pa = Posit::from_bits(n, rng.next_u64() & mask(n));
            let pb = Posit::from_bits(n, rng.next_u64() & mask(n));
            if pa.is_nar() || pb.is_nar() || pa.is_zero() || pb.is_zero() {
                continue;
            }
            let (a, b) = (pa.decode(), pb.decode());
            let fb = crate::posit::frac_bits(n);
            // exact signed fixed-point sum at scale min(sa,sb)-fb
            let base = a.scale.min(b.scale);
            let av = (a.sig as i128) << (a.scale - base) as u32;
            let bv = (b.sig as i128) << (b.scale - base) as u32;
            let sum = if a.sign { -av } else { av } + if b.sign { -bv } else { bv };
            let want = if sum == 0 {
                Posit::zero(n)
            } else {
                let mag = sum.unsigned_abs();
                let msb = 127 - mag.leading_zeros();
                crate::posit::round::encode_round(
                    n,
                    sum < 0,
                    base + msb as i32 - fb as i32,
                    mag,
                    msb,
                    false,
                )
            };
            assert_eq!(pa.add(pb), want, "{pa:?} + {pb:?}");
        }
    }

    #[test]
    fn algebraic_identities_random_p32() {
        let n = 32;
        let mut rng = crate::testkit::Rng::seeded(0xA1DE);
        for _ in 0..50_000 {
            let pa = Posit::from_bits(n, rng.next_u64() & mask(n));
            let pb = Posit::from_bits(n, rng.next_u64() & mask(n));
            if pa.is_nar() || pb.is_nar() {
                continue;
            }
            // commutativity (bit-exact)
            assert_eq!(pa.add(pb), pb.add(pa));
            assert_eq!(pa.mul(pb), pb.mul(pa));
            // identity / absorbing elements
            assert_eq!(pa.add(Posit::zero(n)), pa);
            assert_eq!(pa.mul(Posit::one(n)), pa);
            // x - x = 0 exactly
            assert!(pa.sub(pa).is_zero());
            // negation distributes
            assert_eq!(pa.neg().add(pb.neg()), pa.add(pb).neg());
        }
    }

    #[test]
    fn no_overflow_to_nar() {
        let n = 16;
        let m = Posit::maxpos(n);
        assert_eq!(m.add(m), m); // saturates, never NaR
        assert_eq!(m.mul(m), m);
        assert_eq!(m.neg().mul(m), m.neg());
        let tiny = Posit::minpos(n);
        assert_eq!(tiny.mul(tiny), tiny); // underflow saturates at minpos
    }
}
