//! # posit-div — Digit-Recurrence Posit Division
//!
//! A full reproduction of *"Digit-Recurrence Posit Division"* (Murillo,
//! Villalba-Moreno, Del Barrio, Botella — CS.AR 2025): radix-2 and radix-4
//! SRT-family division units for posit arithmetic, together with every
//! substrate the paper's evaluation depends on:
//!
//! * [`posit`] — a complete Posit⟨n, es=2⟩ arithmetic library (decode,
//!   encode, correct rounding, conversions, add/sub/mul) for 4 ≤ n ≤ 64.
//! * [`division`] — the paper's contribution: bit-exact, datapath-level
//!   digit-recurrence dividers (NRD, SRT, SRT-CS, SRT-CS-OF, SRT-CS-OF-FR;
//!   radix 2 and radix 4, with and without operand scaling), plus a
//!   Newton–Raphson multiplicative baseline, an exact golden reference,
//!   and a digit-recurrence square-root extension ([`division::sqrt`]).
//! * [`hardware`] — a unit-gate 28 nm synthesis cost model that elaborates
//!   each divider design into a component netlist and regenerates the
//!   paper's area/delay/power/energy figures (Figs. 4–9) and latency
//!   tables (Table II).
//! * [`coordinator`] — the L3 service: a dynamic batcher + worker pool
//!   that serves division requests from either the native Rust engines or
//!   an AOT-compiled JAX/Pallas kernel through PJRT ([`runtime`]).
//! * [`bench`] / [`testkit`] — self-contained micro-benchmark and
//!   property-testing harnesses (criterion / proptest are unavailable in
//!   the offline build environment).
//!
//! ## Quickstart
//!
//! (`no_run`: doctest binaries don't inherit the workspace rpath to
//! `libxla_extension.so`; `examples/quickstart.rs` runs the same code.)
//!
//! ```no_run
//! use posit_div::posit::Posit;
//! use posit_div::division::{DivEngine, Algorithm};
//!
//! let x = Posit::from_f64(32, 355.0);
//! let d = Posit::from_f64(32, 113.0);
//! let engine = Algorithm::Srt4Cs.engine();
//! let q = engine.divide(x, d).result;
//! assert!((q.to_f64() - 355.0 / 113.0).abs() < 1e-6);
//! ```

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod division;
pub mod hardware;
pub mod posit;
pub mod runtime;
pub mod testkit;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
