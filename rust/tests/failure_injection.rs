//! Failure injection: the service and runtime must fail loudly (with
//! *typed* errors) at startup on bad configuration, and keep serving
//! through client-side misbehavior.

use std::time::Duration;

use posit_div::coordinator::{Backend, BatchPolicy, DivisionService, ServiceConfig};
use posit_div::division::Algorithm;
use posit_div::posit::Posit;
use posit_div::runtime::Runtime;
use posit_div::unit::ExecTier;
use posit_div::PositError;

#[test]
fn runtime_missing_dir_errors() {
    let err = match Runtime::load("/nonexistent/artifacts") {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(matches!(err, PositError::Artifacts { .. }), "{err}");
    assert!(err.to_string().contains("artifact"), "{err}");
}

#[test]
fn runtime_empty_dir_errors() {
    let dir = std::env::temp_dir().join("posit-div-empty-artifacts");
    let _ = std::fs::create_dir_all(&dir);
    let err = match Runtime::load(&dir) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(matches!(err, PositError::Artifacts { .. }), "{err}");
    assert!(err.to_string().contains("no artifacts"), "{err}");
}

#[test]
fn service_startup_fails_on_unusable_pjrt_backend() {
    // A syntactically-valid artifact name with garbage content: startup
    // must fail either at compile time (xla feature) or because the PJRT
    // backend is unavailable in this build — never hang or panic.
    let dir = std::env::temp_dir().join("posit-div-corrupt-artifacts");
    let _ = std::fs::create_dir_all(&dir);
    std::fs::write(dir.join("div_p16_b256.hlo.txt"), "this is not HLO").unwrap();
    let res = DivisionService::start(ServiceConfig {
        n: 16,
        backend: Backend::Pjrt { artifacts_dir: dir.clone() },
        policy: BatchPolicy::default(),
        tier: ExecTier::Auto,
    });
    match res {
        Err(PositError::Execution { .. }) | Err(PositError::BackendUnavailable { .. }) => {}
        other => panic!("corrupt artifact must fail startup with a typed error: {other:?}"),
    }
}

#[test]
fn service_start_rejects_bad_width() {
    let res = DivisionService::start(ServiceConfig {
        n: 3,
        backend: Backend::Native { alg: Algorithm::Srt2Cs, threads: 1 },
        policy: BatchPolicy::default(),
        tier: ExecTier::Auto,
    });
    assert_eq!(res.err(), Some(PositError::WidthOutOfRange { n: 3 }));
}

#[test]
fn service_survives_dropped_response_receivers() {
    let svc = DivisionService::start(ServiceConfig {
        n: 16,
        backend: Backend::Native { alg: Algorithm::Srt2Cs, threads: 2 },
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(50) },
        tier: ExecTier::Auto,
    })
    .unwrap();
    let client = svc.client();
    // submit and immediately drop the pending handles: the leader must
    // not panic when responding into closed channels
    for _ in 0..100 {
        drop(client.submit(Posit::one(16), Posit::one(16)).unwrap());
    }
    // service still works afterwards
    assert_eq!(client.divide(Posit::one(16), Posit::one(16)).unwrap(), Posit::one(16));
    svc.shutdown();
}

#[test]
fn service_width_mismatch_is_typed_error_not_panic() {
    let svc = DivisionService::start(ServiceConfig {
        n: 16,
        backend: Backend::Native { alg: Algorithm::Srt2Cs, threads: 1 },
        policy: BatchPolicy::default(),
        tier: ExecTier::Auto,
    })
    .unwrap();
    let client = svc.client();
    assert_eq!(
        client.submit(Posit::one(32), Posit::one(32)).err(),
        Some(PositError::WidthMismatch { expected: 16, got: 32 })
    );
    // the service keeps running after the rejected submission
    assert_eq!(client.divide(Posit::one(16), Posit::one(16)).unwrap(), Posit::one(16));
    svc.shutdown();
}
