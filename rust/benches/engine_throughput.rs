//! Measured software throughput of every division engine at every format —
//! the L3 perf baseline tracked in EXPERIMENTS.md §Perf.
//!
//! Two paths per (format, algorithm), both through a pre-built zero-alloc
//! [`Divider`] (no per-call `Box<dyn DivEngine>` on the hot loop):
//!   * scalar: `Divider::divide` per pair,
//!   * batch:  `Divider::divide_batch` over the whole working set — the
//!     exact loop the coordinator's native backend runs.

use posit_div::bench::{bench_batched, black_box, Config, Runner};
use posit_div::division::{Algorithm, DivEngine, Divider};
use posit_div::posit::{mask, Posit};
use posit_div::testkit::Rng;

fn main() {
    let mut runner = Runner::new("engine throughput (div/s), 256-pair working set");
    let mut rng = Rng::seeded(0xB21C);
    for n in [8u32, 16, 32, 64] {
        let pairs: Vec<(Posit, Posit)> = (0..256)
            .map(|_| {
                (
                    Posit::from_bits(n, rng.next_u64() & mask(n)),
                    Posit::from_bits(n, (rng.next_u64() & mask(n)) | 1),
                )
            })
            .collect();
        let xs: Vec<u64> = pairs.iter().map(|p| p.0.to_bits()).collect();
        let ds: Vec<u64> = pairs.iter().map(|p| p.1.to_bits()).collect();
        let mut out = vec![0u64; xs.len()];
        for alg in Algorithm::ALL {
            if alg.radix() == Some(4) && n < 8 {
                continue;
            }
            let ctx = Divider::new(n, alg).expect("standard width");
            runner.add(bench_batched(
                &format!("Posit{n:<2} {} scalar", ctx.name()),
                Config::default(),
                pairs.len() as u64,
                || {
                    for &(x, d) in &pairs {
                        black_box(ctx.divide(x, d).expect("width matches").result);
                    }
                },
            ));
            runner.add(bench_batched(
                &format!("Posit{n:<2} {} batch", ctx.name()),
                Config::default(),
                xs.len() as u64,
                || {
                    ctx.divide_batch(&xs, &ds, &mut out).expect("equal lengths");
                    black_box(&out);
                },
            ));
        }
    }
    runner.finish();
}
