//! Synthesis-style evaluation of elaborated designs — the model standing in
//! for the paper's Synopsys DC runs (Figs. 4–9).
//!
//! Two mappings, matching the paper's §IV:
//! * **combinational** — the recurrence fully unrolled in logic, no timing
//!   constraint: critical path = decode + (scaling) + It·slice +
//!   termination + encode; power reported at a fixed virtual toggle clock,
//!   energy = power × delay (the paper's power-delay product).
//! * **pipelined** — one iteration per cycle at a 1.5 GHz target: the
//!   recurrence is unrolled into `It` register-separated stages (initiation
//!   interval 1), which is why the iteration count shows up in the
//!   *sequential* area exactly as §IV observes. Energy = power × clock
//!   period (PDP at the achieved frequency).

use super::components::AdderStyle;
use super::designs::{elaborate_styled, Design};
use super::tech::Tech;
use crate::division::Algorithm;

/// Virtual toggle clock for combinational power reports (GHz). Relative
/// numbers are what matter; this mirrors DC's default-activity report.
const COMB_VIRTUAL_GHZ: f64 = 0.2;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Combinational,
    Pipelined,
}

/// One synthesis result row (one bar-group of Figs. 4–9).
#[derive(Clone, Debug)]
pub struct SynthReport {
    pub alg: Algorithm,
    pub n: u32,
    pub mode: Mode,
    pub area_ge: f64,
    pub area_um2: f64,
    /// Combinational: critical-path delay. Pipelined: achieved cycle time.
    pub delay_ns: f64,
    /// Pipeline latency in cycles (1 for combinational).
    pub cycles: u32,
    /// End-to-end latency of one division.
    pub latency_ns: f64,
    pub power_mw: f64,
    /// Energy per division (power-delay product, paper convention).
    pub energy_pj: f64,
    /// Pipelined only: whether the 1.5 GHz target was met.
    pub timing_met: bool,
    /// Name of the stage owning the critical path.
    pub critical_stage: &'static str,
}

/// Evaluate the combinational mapping.
pub fn combinational(alg: Algorithm, n: u32, tech: &Tech) -> SynthReport {
    // unconstrained synthesis -> min-area (ripple) adder structures
    let d = elaborate_styled(alg, n, AdderStyle::AreaOptimized);
    let it = d.iterations as f64;

    let mut area = d.decode.area + d.termination.area + d.encode.area + d.slice.area * it;
    let mut delay =
        d.decode.delay + d.termination.delay + d.encode.delay + d.slice.delay * it;
    if let Some(s) = &d.scaling {
        area += s.area;
        delay += s.delay;
    }

    let (critical_stage, _) = critical_of(&d, d.slice.delay * it);
    let delay_ns = tech.delay_ns(delay);
    // Glitch activity: unrolled combinational logic re-evaluates every
    // level on each input transition, so switching power grows with logic
    // depth (ripple chains glitch massively; shallow CS logic doesn't) —
    // the effect that makes the paper's CS designs big energy winners.
    let glitch = 1.0 + delay / 200.0;
    let power_mw = tech.power_mw(area * glitch, COMB_VIRTUAL_GHZ);
    SynthReport {
        alg,
        n,
        mode: Mode::Combinational,
        area_ge: area,
        area_um2: tech.area_um2(area),
        delay_ns,
        cycles: 1,
        latency_ns: delay_ns,
        power_mw,
        energy_pj: power_mw * delay_ns, // mW·ns = pJ
        timing_met: true,
        critical_stage,
    }
}

/// Evaluate the pipelined mapping at the paper's 1.5 GHz target.
pub fn pipelined(alg: Algorithm, n: u32, tech: &Tech) -> SynthReport {
    // timing-driven synthesis -> prefix adder structures
    let d = elaborate_styled(alg, n, AdderStyle::TimingDriven);
    let budget = tech.pipeline_period_tau();

    // Stage delays (each +register overhead).
    let mut stages: Vec<(&'static str, f64)> = vec![
        ("decode", d.decode.delay),
        ("iteration", d.slice.delay),
        ("termination", d.termination.delay),
        ("encode", d.encode.delay),
    ];
    if let Some(s) = &d.scaling {
        stages.push(("scaling", s.delay));
    }

    // Area: Newton reuses one multiplicative slice iteratively (the
    // standard NR mapping); digit-recurrence designs unroll It stages with
    // pipeline registers (II = 1), so registers scale with It — the §IV
    // observation that radix-4 cuts sequential area.
    let (slice_area, slice_regs) = if alg == Algorithm::Newton {
        (d.slice.area, d.state_bits as f64 * 5.5)
    } else {
        (
            d.slice.area * d.iterations as f64,
            d.state_bits as f64 * 5.5 * d.iterations as f64,
        )
    };
    let mut area = d.decode.area
        + slice_area
        + slice_regs
        + d.termination.area
        + d.encode.area
        + (4 * d.n) as f64 * 5.5; // I/O + control registers
    if let Some(s) = &d.scaling {
        area += s.area + d.state_bits as f64 * 5.5;
    }

    let (critical_stage, worst) = stages
        .iter()
        .copied()
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();
    let cycle_tau = worst + tech.reg_overhead_tau;
    let timing_met = cycle_tau <= budget;
    // Clock at the target if met, else at the achievable rate.
    let period_ns = if timing_met {
        1.0 / Tech::PIPELINE_GHZ
    } else {
        tech.delay_ns(cycle_tau)
    };
    let f_ghz = 1.0 / period_ns;
    let power_mw = tech.power_mw(area, f_ghz);
    SynthReport {
        alg,
        n,
        mode: Mode::Pipelined,
        area_ge: area,
        area_um2: tech.area_um2(area),
        delay_ns: period_ns,
        cycles: d.cycles,
        latency_ns: period_ns * d.cycles as f64,
        power_mw,
        energy_pj: power_mw * period_ns, // PDP at the achieved clock
        timing_met,
        critical_stage,
    }
}

fn critical_of(d: &Design, recurrence_total: f64) -> (&'static str, f64) {
    let mut best = ("recurrence", recurrence_total);
    for (name, v) in [
        ("decode", d.decode.delay),
        ("termination", d.termination.delay),
        ("encode", d.encode.delay),
        ("scaling", d.scaling.as_ref().map(|c| c.delay).unwrap_or(0.0)),
    ] {
        if v > best.1 {
            best = (name, v);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::Algorithm as A;
    use crate::hardware::tech::TSMC28;

    fn comb(a: A, n: u32) -> SynthReport {
        combinational(a, n, &TSMC28)
    }
    fn pipe(a: A, n: u32) -> SynthReport {
        pipelined(a, n, &TSMC28)
    }

    /// §IV: "The NRD and plain SRT radix-2 designs generally occupy the
    /// least area."
    #[test]
    fn nrd_and_srt2_least_area_combinational() {
        for n in [16u32, 32, 64] {
            let base = comb(A::Nrd, n).area_ge.min(comb(A::Srt2, n).area_ge);
            for a in [A::Srt2Cs, A::Srt2CsOf, A::Srt2CsOfFr, A::Srt4CsOf, A::Srt4Scaled] {
                assert!(comb(a, n).area_ge > base, "{a:?} n={n}");
            }
        }
    }

    /// §IV: "the most significant delay reduction is obtained in the CS
    /// variant" (combinational, radix-2 chain NRD→SRT→CS→OF→FR).
    #[test]
    fn cs_is_the_big_delay_cut() {
        for n in [16u32, 32, 64] {
            let chain = [A::Nrd, A::Srt2, A::Srt2Cs, A::Srt2CsOf, A::Srt2CsOfFr];
            let delays: Vec<f64> = chain.iter().map(|&a| comb(a, n).delay_ns).collect();
            // largest single improvement step is SRT→CS
            let mut steps: Vec<f64> = delays.windows(2).map(|w| w[0] - w[1]).collect();
            let cs_step = steps.remove(1);
            for s in steps {
                assert!(cs_step > s, "n={n}: CS step {cs_step} vs other {s}");
            }
        }
    }

    /// §IV: OF slightly increases combinational radix-2 delay.
    #[test]
    fn of_slightly_slower_on_radix2_combinational() {
        for n in [16u32, 32, 64] {
            let cs = comb(A::Srt2Cs, n).delay_ns;
            let of = comb(A::Srt2CsOf, n).delay_ns;
            assert!(of > cs, "n={n}");
            assert!(of < cs * 1.15, "n={n}: increase should be slight");
        }
    }

    /// §IV: radix-4 combinational "tends to" occupy less area than radix-2
    /// at the same optimization level (half the replicated slices). The
    /// paper notes the effect is "more pronounced for larger datapaths" —
    /// at 16 bits the radix-4 selection table does not amortize, so the
    /// claim is asserted for 32/64 bits.
    #[test]
    fn radix4_less_area_combinational() {
        for n in [32u32, 64] {
            assert!(comb(A::Srt4Cs, n).area_ge < comb(A::Srt2Cs, n).area_ge, "n={n}");
            assert!(comb(A::Srt4CsOf, n).area_ge < comb(A::Srt2CsOf, n).area_ge, "n={n}");
        }
    }

    /// §IV: radix-4 is faster than radix-2 in delay (combinational).
    #[test]
    fn radix4_faster_combinational() {
        for n in [16u32, 32, 64] {
            assert!(comb(A::Srt4Cs, n).delay_ns < comb(A::Srt2Cs, n).delay_ns);
        }
    }

    /// §IV: every pipelined design meets the 1.5 GHz target, and the
    /// critical path is the final conversion/rounding — except the scaled
    /// design, whose longest path is the scaling stage.
    #[test]
    fn pipelined_timing_and_critical_paths() {
        for n in [16u32, 32, 64] {
            for a in A::TABLE_IV {
                let r = pipe(a, n);
                assert!(r.timing_met, "{a:?} n={n} missed 1.5 GHz");
                if a == A::Srt4Scaled {
                    assert_eq!(r.critical_stage, "scaling", "n={n}");
                } else if a.uses_fast_remainder() {
                    // the optimized designs: §IV "the critical path is not
                    // in the iterative stages, but in the final posit
                    // conversion and rounding phase"
                    assert_eq!(r.critical_stage, "encode", "{a:?} n={n}");
                } else {
                    // non-FR designs may be bounded by the CPA-based
                    // termination instead; never by the iteration slice
                    assert_ne!(r.critical_stage, "iteration", "{a:?} n={n}");
                }
            }
        }
    }

    /// §IV: pipelined radix-4 is a significantly more energy-efficient
    /// solution (fewer stages ⇒ less sequential area ⇒ less power at the
    /// same clock; plus fewer cycles per division).
    #[test]
    fn radix4_pipelined_energy_win() {
        for n in [16u32, 32, 64] {
            let r2 = pipe(A::Srt2CsOfFr, n);
            let r4 = pipe(A::Srt4CsOfFr, n);
            assert!(r4.area_ge < r2.area_ge, "n={n}");
            assert!(r4.power_mw < r2.power_mw, "n={n}");
            assert!(r4.latency_ns < r2.latency_ns, "n={n}");
        }
    }

    /// [16]'s finding the paper leans on: digit recurrence beats the
    /// multiplicative method on energy and area.
    #[test]
    fn digit_recurrence_beats_newton() {
        for n in [16u32, 32, 64] {
            let srt = comb(A::Srt4CsOfFr, n);
            let nr = comb(A::Newton, n);
            assert!(srt.area_ge < nr.area_ge, "n={n}");
            assert!(srt.energy_pj < nr.energy_pj, "n={n}");
        }
    }

    /// Larger datapaths amortize the radix-4 overhead (§IV: "such an
    /// overhead is amortized for larger datapaths").
    #[test]
    fn radix4_advantage_grows_with_width() {
        let ratio = |n: u32| comb(A::Srt4CsOfFr, n).energy_pj / comb(A::Srt2CsOfFr, n).energy_pj;
        assert!(ratio(64) < ratio(16));
    }
}
