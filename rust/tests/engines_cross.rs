//! Cross-engine integration: every Table IV engine (plus baselines) must
//! agree with the exact golden model — exhaustively at Posit8, on dense
//! divisor sweeps at Posit16, and on random samples at every width up to
//! Posit64 (where f64-based references can no longer help).
//!
//! All engines run through pre-built [`Divider`] contexts — the same
//! zero-alloc path the coordinator and the benches use (and, since the
//! op-generic redesign, a compatibility pin on the deprecated wrapper).

#![allow(deprecated)]

use posit_div::division::{golden, Algorithm, DivEngine, Divider};
use posit_div::posit::{mask, Posit};
use posit_div::testkit::Rng;

fn dividers(n: u32) -> Vec<Divider> {
    Algorithm::ALL.iter().map(|&a| Divider::new(n, a).expect("valid width")).collect()
}

#[test]
fn all_engines_exhaustive_posit8() {
    let n = 8;
    let dividers = dividers(n);
    for xb in 0..=mask(n) {
        for db in 0..=mask(n) {
            let x = Posit::from_bits(n, xb);
            let d = Posit::from_bits(n, db);
            let want = golden::divide(x, d).result;
            for ctx in &dividers {
                let got = ctx.divide(x, d).expect("width matches").result;
                assert_eq!(got, want, "{}: {x:?}/{d:?}", ctx.name());
            }
        }
    }
}

#[test]
fn all_engines_dense_divisor_sweep_posit16() {
    // fixed interesting dividends x all divisors (2^16 each)
    let n = 16;
    let dividers = dividers(n);
    let xs = [
        Posit::one(n),
        Posit::from_f64(n, 1.0 + 2.0f64.powi(-11)), // longest fraction
        Posit::from_f64(n, 1.9990234375),
        Posit::maxpos(n),
        Posit::minpos(n).neg(),
    ];
    for x in xs {
        for db in 0..=mask(n) {
            let d = Posit::from_bits(n, db);
            let want = golden::divide(x, d).result;
            for ctx in &dividers {
                let got = ctx.divide(x, d).expect("width matches").result;
                assert_eq!(got, want, "{}: {x:?}/{d:?}", ctx.name());
            }
        }
    }
}

#[test]
fn all_engines_random_all_widths() {
    let mut rng = Rng::seeded(0xAC70);
    for &n in &[10u32, 16, 24, 32, 48, 64] {
        let dividers = dividers(n);
        for _ in 0..4_000 {
            let x = Posit::from_bits(n, rng.next_u64() & mask(n));
            let d = Posit::from_bits(n, rng.next_u64() & mask(n));
            let want = golden::divide(x, d).result;
            for ctx in &dividers {
                let got = ctx.divide(x, d).expect("width matches").result;
                assert_eq!(got, want, "{}: n={n} {x:?}/{d:?}", ctx.name());
            }
        }
    }
}

#[test]
fn iteration_and_cycle_metadata_consistent() {
    let mut rng = Rng::seeded(7);
    for &n in &[16u32, 32, 64] {
        for alg in Algorithm::TABLE_IV {
            let ctx = Divider::new(n, alg).expect("valid width");
            let x = Posit::from_bits(n, (rng.next_u64() & mask(n)) | 1).abs();
            let d = Posit::from_bits(n, (rng.next_u64() & mask(n)) | 1).abs();
            let div = ctx.divide(x, d).expect("width matches");
            assert_eq!(div.iterations, posit_div::division::iterations(n, alg.radix().unwrap()));
            assert_eq!(div.iterations, ctx.iterations());
            assert_eq!(div.cycles, posit_div::division::latency_cycles(n, alg));
            assert_eq!(div.cycles, ctx.latency_cycles());
        }
    }
}
