//! End-to-end driver (the EXPERIMENTS.md §E2E run): the full three-layer
//! stack serving batched posit-unit requests.
//!
//!   L3 Rust coordinator (router + dynamic batcher + metrics)
//!     -> PJRT backend: the AOT-compiled L2 JAX graph containing the
//!        L1 Pallas radix-4 SRT kernel (artifacts/, built once by
//!        `make artifacts`; needs the `xla` feature — skipped otherwise)
//!     -> native backend: the bit-exact Rust engines behind cached per-op
//!        `Unit` contexts (division, sqrt, mul, add/sub, mul-add, and the
//!        quire reductions dot/fused-sum/axpy)
//!
//! Serves a DSP-trace division workload on Posit16 and Posit32 through
//! both backends via the typed `Client` handle, then a mixed op-tagged
//! stream through the native backend, then the same mixed stream one
//! layer further out: over TCP loopback through the sharded serving
//! tier (`Server`/`ServiceClient`, docs/SERVING.md). Every response is
//! verified against the exact references; throughput and latency are
//! reported. (The old division-only `Divider` plays no part here — it
//! is deprecated in favor of `Unit` behind the coordinator.)
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_divide
//! ```

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use posit_div::division::golden;
use posit_div::prelude::*;
use posit_div::workload::{self, OpMix, Workload};

const REQUESTS: usize = 50_000;

fn run(n: u32, backend: Backend, label: &str) {
    let policy = BatchPolicy { max_batch: 1024, max_wait: Duration::from_micros(200) };
    let cfg = ServiceConfig { n, backend, policy, tier: ExecTier::Auto };
    let svc = match DivisionService::start(cfg) {
        Ok(svc) => svc,
        Err(e) => {
            eprintln!("[skip] {label} Posit{n}: {e}");
            return;
        }
    };
    let client = svc.client();

    let mut wl = workload::DspTrace::new(n, 0xE2E0 + n as u64);
    let pairs = workload::take(&mut wl, REQUESTS);

    let t0 = Instant::now();
    let results = client
        .submit_batch(&pairs)
        .expect("service running")
        .wait()
        .expect("service running");
    let wall = t0.elapsed();

    // full verification against the exact golden model
    let mut checked = 0;
    for (i, &(x, d)) in pairs.iter().enumerate() {
        assert_eq!(results[i], golden::divide(x, d).result, "{label} {x:?}/{d:?}");
        checked += 1;
    }

    let m = client.metrics();
    println!("\n[{label}] Posit{n}: {REQUESTS} requests in {wall:.2?}");
    println!("  throughput     : {:>12.0} div/s", REQUESTS as f64 / wall.as_secs_f64());
    println!("  batch latency  : {}", m.batch_latency.summary());
    println!(
        "  batches        : {} (mean fill {:.1}%)",
        m.batches.load(Ordering::Relaxed),
        100.0 * m.mean_batch_fill(1024)
    );
    println!("  verified       : {checked}/{REQUESTS} bit-exact vs golden model");
    svc.shutdown();
}

/// Mixed op-tagged traffic through the native backend: the service groups
/// each dynamic batch per op and runs every group on its cached unit —
/// including the quire reductions (dot/fsum/axpy), which carry their
/// vector lanes per request (`serve --mix dot:2,fsum:1,axpy:1` from the
/// CLI exercises the same path).
fn run_mixed(n: u32) {
    let policy = BatchPolicy { max_batch: 1024, max_wait: Duration::from_micros(200) };
    let backend = Backend::Native { alg: Algorithm::DEFAULT, threads: 4 };
    let cfg = ServiceConfig { n, backend, policy, tier: ExecTier::Auto };
    let svc = DivisionService::start(cfg).expect("native backend always starts");
    let client = svc.client();

    let mix = OpMix::parse("div:6,sqrt:2,mul:4,add:4,sub:2,fma:2,dot:2,fsum:1,axpy:1")
        .expect("literal mix parses");
    let mut wl = workload::MixedOps::new(n, mix, 0xE2E0 + n as u64);
    let reqs = workload::take_requests(&mut wl, REQUESTS);

    let t0 = Instant::now();
    let results = client
        .submit_ops(&reqs)
        .expect("service running")
        .wait()
        .expect("service running");
    let wall = t0.elapsed();

    // full verification against the exact golden references
    for (i, req) in reqs.iter().enumerate() {
        assert_eq!(results[i], req.golden(), "mixed {} i={i}", req.op);
    }

    let m = client.metrics();
    println!("\n[native mixed ops] Posit{n}: {REQUESTS} requests in {wall:.2?}");
    println!("  throughput     : {:>12.0} op/s", REQUESTS as f64 / wall.as_secs_f64());
    println!("  batch latency  : {}", m.batch_latency.summary());
    println!("  ops            : {}", m.ops.summary());
    println!("  verified       : {REQUESTS}/{REQUESTS} bit-exact vs exact references");
    svc.shutdown();
}

/// The same mixed stream through the networked serving tier: a sharded
/// TCP server on loopback (router → shards → units, docs/SERVING.md)
/// driven by the wire-protocol client. `posit-div serve --listen` /
/// `posit-div client` run this exact path between processes; here both
/// ends live in one process for a self-contained demo.
fn run_networked(n: u32) {
    let mut cfg = ShardConfig::default();
    cfg.service.n = n;
    let shards = cfg.shards;
    let server = Server::bind("127.0.0.1:0", cfg).expect("bind loopback");
    let mut client = ServiceClient::connect(server.local_addr(), n).expect("connect loopback");

    let mix = OpMix::parse("div:6,sqrt:2,mul:4,add:4,sub:2,fma:2,dot:2,fsum:1,axpy:1")
        .expect("literal mix parses");
    let mut wl = workload::MixedOps::new(n, mix, 0xE2E0 + n as u64);
    let reqs = workload::take_requests(&mut wl, REQUESTS / 5);

    let t0 = Instant::now();
    let results = client.run_ops(&reqs).expect("loopback transport");
    let wall = t0.elapsed();
    for (i, (req, res)) in reqs.iter().zip(&results).enumerate() {
        let got = res.as_ref().expect("no shed below the admission budget");
        assert_eq!(*got, req.golden(), "networked {} i={i}", req.op);
    }

    client.shutdown_server().expect("shutdown frame");
    let svc = server.wait();
    println!("\n[sharded tcp] Posit{n}: {} requests in {wall:.2?}", reqs.len());
    println!(
        "  throughput     : {:>12.0} op/s over loopback ({shards} shards)",
        reqs.len() as f64 / wall.as_secs_f64()
    );
    print!("{}", svc.counters_render());
    println!("  verified       : {0}/{0} bit-exact vs exact references", reqs.len());
    svc.shutdown();
}

fn main() {
    println!("=== end-to-end: three-layer posit unit service ===");
    for n in [16u32, 32] {
        run(
            n,
            Backend::Native { alg: Algorithm::DEFAULT, threads: 4 },
            "native rust engine (SRT r4 CS OF FR)",
        );
        run(
            n,
            Backend::Pjrt { artifacts_dir: "artifacts".into() },
            "PJRT: AOT JAX/Pallas kernel",
        );
        run_mixed(n);
    }
    run_networked(16);
    println!("\nall served responses verified bit-exact against the exact references");
}
