//! Cycle-accurate simulator of the pipelined divider units.
//!
//! The synthesis model (`synth::pipelined`) prices the unrolled pipeline
//! statically; this simulator *executes* it: a division enters the decode
//! stage, advances one stage per cycle through (scaling,) It iteration
//! stages, termination and encode, with initiation interval 1. It
//! validates dynamically what the paper's Table II states statically —
//! per-division latency — and answers the questions a deployment cares
//! about: throughput at full occupancy and latency under bursty arrivals.

use crate::division::{latency_cycles, Algorithm};

/// One simulated in-flight division.
#[derive(Clone, Copy, Debug)]
struct InFlight {
    id: u64,
    issued_cycle: u64,
    stages_left: u32,
}

/// Statistics from a simulation run.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub completed: u64,
    pub cycles: u64,
    pub stalled_cycles: u64,
    pub min_latency: u64,
    pub max_latency: u64,
    pub sum_latency: u64,
    /// Mean number of occupied stages per cycle.
    pub mean_occupancy: f64,
}

impl SimStats {
    pub fn mean_latency(&self) -> f64 {
        self.sum_latency as f64 / self.completed.max(1) as f64
    }
    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.cycles.max(1) as f64
    }
}

/// The pipelined divider: a shift-register of stage occupancy. One new
/// division may be accepted per cycle (II = 1).
pub struct PipelineSim {
    pub alg: Algorithm,
    pub n: u32,
    depth: u32,
    in_flight: Vec<InFlight>,
    next_id: u64,
    cycle: u64,
    occupancy_acc: u64,
    stats: SimStats,
}

impl PipelineSim {
    pub fn new(alg: Algorithm, n: u32) -> Self {
        let depth = latency_cycles(n, alg);
        PipelineSim {
            alg,
            n,
            depth,
            in_flight: Vec::with_capacity(depth as usize),
            next_id: 0,
            cycle: 0,
            occupancy_acc: 0,
            stats: SimStats { min_latency: u64::MAX, ..Default::default() },
        }
    }

    /// Pipeline depth in stages (= Table II latency in cycles).
    pub fn depth(&self) -> u32 {
        self.depth
    }

    /// Advance one clock. `issue` = a new division arrives this cycle.
    /// Returns the ids completing this cycle.
    pub fn tick(&mut self, issue: bool) -> Vec<u64> {
        self.cycle += 1;
        let mut done = Vec::new();
        for f in &mut self.in_flight {
            f.stages_left -= 1;
            if f.stages_left == 0 {
                let lat = self.cycle - f.issued_cycle;
                self.stats.completed += 1;
                self.stats.sum_latency += lat;
                self.stats.min_latency = self.stats.min_latency.min(lat);
                self.stats.max_latency = self.stats.max_latency.max(lat);
                done.push(f.id);
            }
        }
        self.in_flight.retain(|f| f.stages_left > 0);
        if issue {
            // II = 1: the decode stage is free every cycle by construction
            self.in_flight.push(InFlight {
                id: self.next_id,
                issued_cycle: self.cycle,
                stages_left: self.depth,
            });
            self.next_id += 1;
        } else {
            self.stats.stalled_cycles += 1;
        }
        self.occupancy_acc += self.in_flight.len() as u64;
        done
    }

    /// Run a closed workload of `count` divisions arriving per `gap`
    /// pattern (gap = 0 ⇒ back-to-back) and drain.
    pub fn run(mut self, count: u64, gap: u64) -> SimStats {
        let mut issued = 0;
        let mut since = gap; // issue immediately
        while self.stats.completed < count {
            let issue = issued < count && since >= gap;
            if issue {
                issued += 1;
                since = 0;
            } else {
                since += 1;
            }
            self.tick(issue);
        }
        let mut s = self.stats.clone();
        s.cycles = self.cycle;
        s.mean_occupancy = self.occupancy_acc as f64 / self.cycle.max(1) as f64;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_equals_table2_depth() {
        for n in [16u32, 32, 64] {
            for alg in [Algorithm::Srt2Cs, Algorithm::Srt4Cs, Algorithm::Srt4Scaled] {
                let stats = PipelineSim::new(alg, n).run(100, 0);
                assert_eq!(stats.min_latency, latency_cycles(n, alg) as u64, "{alg:?} n={n}");
                assert_eq!(stats.max_latency, stats.min_latency, "II=1: constant latency");
            }
        }
    }

    #[test]
    fn back_to_back_throughput_approaches_one_per_cycle() {
        let stats = PipelineSim::new(Algorithm::Srt4CsOfFr, 32).run(10_000, 0);
        assert!(stats.throughput() > 0.99, "got {}", stats.throughput());
        // steady-state occupancy ≈ depth
        assert!(stats.mean_occupancy > 0.95 * latency_cycles(32, Algorithm::Srt4CsOfFr) as f64);
    }

    #[test]
    fn sparse_arrivals_keep_latency_but_cut_throughput() {
        let gap = 10;
        let stats = PipelineSim::new(Algorithm::Srt2Cs, 16).run(1_000, gap);
        assert_eq!(stats.min_latency, 17); // Table II
        assert!(stats.throughput() < 0.12);
    }

    /// The paper's energy argument, dynamically: at equal clock and equal
    /// request rate, radix-4 holds ~half the in-flight state of radix-2 —
    /// fewer live registers ⇒ proportional dynamic-energy cut.
    #[test]
    fn radix4_halves_in_flight_state() {
        let r2 = PipelineSim::new(Algorithm::Srt2Cs, 32).run(20_000, 0);
        let r4 = PipelineSim::new(Algorithm::Srt4Cs, 32).run(20_000, 0);
        let ratio = r4.mean_occupancy / r2.mean_occupancy;
        assert!((0.5..0.65).contains(&ratio), "occupancy ratio {ratio}");
        assert!(r4.mean_latency() < 0.6 * r2.mean_latency());
    }
}
