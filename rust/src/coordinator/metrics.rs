//! Service metrics: counters and a log-bucketed latency histogram
//! (hand-rolled — no external metrics crates in the offline build).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::unit::{ExecTier, FastPath, Op};

/// Power-of-two-bucketed latency histogram, lock-free on the record path.
/// Bucket i counts samples in [2^i, 2^(i+1)) nanoseconds, i < 48.
pub struct Histogram {
    buckets: [AtomicU64; 48],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let idx = (63 - ns.max(1).leading_zeros()).min(47) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }

    /// Approximate quantile from the bucket distribution (upper bound of
    /// the bucket containing the q-th sample).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(1u64 << (i + 1));
            }
        }
        self.max()
    }

    pub fn summary(&self) -> String {
        format!(
            "count={} mean={:?} p50<={:?} p99<={:?} p999<={:?} max={:?}",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.99),
            self.quantile(0.999),
            self.max()
        )
    }

    /// Fold another histogram's samples into this one (used to aggregate
    /// per-shard histograms into a fleet view). Both sides may be live;
    /// the merge is a relaxed snapshot, like every other read here.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = theirs.load(Ordering::Relaxed);
            if v > 0 {
                mine.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns.fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_ns.fetch_max(other.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// Which execution lane ultimately served a request: the Fast kernels,
/// the cycle-accurate Datapath engines, the PJRT graph, or the
/// bounded-error Approx kernels. This is the *resolved* serving lane
/// (`ExecTier::Auto` never appears here), the second axis of the
/// [`LatencyPanel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServedBy {
    Fast,
    Datapath,
    Pjrt,
    Approx,
}

impl ServedBy {
    /// All lanes, in [`ServedBy::index`] order.
    pub const ALL: [ServedBy; 4] =
        [ServedBy::Fast, ServedBy::Datapath, ServedBy::Pjrt, ServedBy::Approx];

    /// Map a *resolved* native tier to its lane.
    pub fn from_tier(tier: ExecTier) -> ServedBy {
        match tier {
            ExecTier::Fast | ExecTier::Auto => ServedBy::Fast,
            ExecTier::Datapath => ServedBy::Datapath,
            ExecTier::Approx => ServedBy::Approx,
        }
    }

    fn index(self) -> usize {
        match self {
            ServedBy::Fast => 0,
            ServedBy::Datapath => 1,
            ServedBy::Pjrt => 2,
            ServedBy::Approx => 3,
        }
    }

    /// Stable lowercase name (`fast`, `datapath`, `pjrt`, `approx`).
    pub fn name(self) -> &'static str {
        match self {
            ServedBy::Fast => "fast",
            ServedBy::Datapath => "datapath",
            ServedBy::Pjrt => "pjrt",
            ServedBy::Approx => "approx",
        }
    }
}

/// SLO telemetry: one end-to-end latency [`Histogram`] per
/// (operation kind × serving lane). Recorded by the coordinator leader at
/// response time (enqueue → response, the latency a client observes),
/// read as p50/p99/p999 by `serve`, the service bench rows and the soak
/// tests.
pub struct LatencyPanel {
    /// `[op kind][lane]`, indexed by [`Op::kind_index`] ×
    /// [`ServedBy::index`].
    cells: [[Histogram; 4]; 9],
}

impl Default for LatencyPanel {
    fn default() -> Self {
        LatencyPanel { cells: std::array::from_fn(|_| std::array::from_fn(|_| Histogram::new())) }
    }
}

impl LatencyPanel {
    pub fn record(&self, op: Op, lane: ServedBy, d: Duration) {
        self.cells[op.kind_index()][lane.index()].record(d);
    }

    /// The histogram for one (op kind, lane) cell.
    pub fn get(&self, op: Op, lane: ServedBy) -> &Histogram {
        &self.cells[op.kind_index()][lane.index()]
    }

    /// Every cell that has served traffic, as `(op, lane, histogram)` in
    /// stable kind × lane order.
    pub fn nonempty(&self) -> Vec<(Op, ServedBy, &Histogram)> {
        let mut out = Vec::new();
        for op in Op::KINDS {
            for lane in ServedBy::ALL {
                let h = self.get(op, lane);
                if h.count() > 0 {
                    out.push((op, lane, h));
                }
            }
        }
        out
    }

    /// Fold every cell of another panel into this one (per-shard →
    /// fleet aggregation).
    pub fn merge_from(&self, other: &LatencyPanel) {
        for (mine, theirs) in self.cells.iter().zip(other.cells.iter()) {
            for (m, t) in mine.iter().zip(theirs.iter()) {
                if t.count() > 0 {
                    m.merge_from(t);
                }
            }
        }
    }

    /// All samples across ops for one lane, merged into a fresh
    /// histogram (the "mixed traffic" tail for that lane).
    pub fn lane_aggregate(&self, lane: ServedBy) -> Histogram {
        let agg = Histogram::new();
        for op in Op::KINDS {
            let h = self.get(op, lane);
            if h.count() > 0 {
                agg.merge_from(h);
            }
        }
        agg
    }

    /// Multi-line render of every nonempty cell:
    /// `div x fast: n=... p50<=... p99<=... p999<=... max=...`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (op, lane, h) in self.nonempty() {
            out.push_str(&format!(
                "{} x {}: n={} p50<={:?} p99<={:?} p999<={:?} max={:?}\n",
                op.name(),
                lane.name(),
                h.count(),
                h.quantile(0.50),
                h.quantile(0.99),
                h.quantile(0.999),
                h.max()
            ));
        }
        if out.is_empty() {
            out.push_str("(no traffic)\n");
        }
        out
    }
}

/// Per-operation-kind request counters (division counts one bucket
/// regardless of algorithm).
#[derive(Default)]
pub struct OpCounters {
    pub div: AtomicU64,
    pub sqrt: AtomicU64,
    pub mul: AtomicU64,
    pub add: AtomicU64,
    pub sub: AtomicU64,
    pub mul_add: AtomicU64,
    pub dot: AtomicU64,
    pub fused_sum: AtomicU64,
    pub axpy: AtomicU64,
}

impl OpCounters {
    fn counter(&self, op: Op) -> &AtomicU64 {
        match op {
            Op::Div { .. } => &self.div,
            Op::Sqrt => &self.sqrt,
            Op::Mul => &self.mul,
            Op::Add => &self.add,
            Op::Sub => &self.sub,
            Op::MulAdd => &self.mul_add,
            Op::Dot => &self.dot,
            Op::FusedSum => &self.fused_sum,
            Op::Axpy => &self.axpy,
        }
    }

    pub fn record(&self, op: Op) {
        self.counter(op).fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self, op: Op) -> u64 {
        self.counter(op).load(Ordering::Relaxed)
    }

    pub fn summary(&self) -> String {
        format!(
            "div={} sqrt={} mul={} add={} sub={} mul_add={} dot={} fsum={} axpy={}",
            self.div.load(Ordering::Relaxed),
            self.sqrt.load(Ordering::Relaxed),
            self.mul.load(Ordering::Relaxed),
            self.add.load(Ordering::Relaxed),
            self.sub.load(Ordering::Relaxed),
            self.mul_add.load(Ordering::Relaxed),
            self.dot.load(Ordering::Relaxed),
            self.fused_sum.load(Ordering::Relaxed),
            self.axpy.load(Ordering::Relaxed),
        )
    }
}

/// Requests served per execution tier: the fast kernels, the
/// cycle-accurate datapath engines, or the PJRT graph. The fast tier is
/// further split per serving kernel (`fast_table`/`fast_vector`/
/// `fast_simd` — the construction-verified lookup tables, the explicit
/// AVX2/NEON vector kernels and the SWAR lane-packed kernels; the
/// remainder of `fast` ran on the scalar-fast kernels).
#[derive(Default)]
pub struct TierCounters {
    pub fast: AtomicU64,
    /// Fast-tier requests served by the construction-verified lookup
    /// tables — Posit8 whole-op or Posit16 seed (a subset of `fast`).
    pub fast_table: AtomicU64,
    /// Fast-tier requests served by the explicit AVX2/NEON vector
    /// kernels (a subset of `fast`).
    pub fast_vector: AtomicU64,
    /// Fast-tier requests served by the SWAR lane-packed kernels
    /// (a subset of `fast`).
    pub fast_simd: AtomicU64,
    pub datapath: AtomicU64,
    pub pjrt: AtomicU64,
    /// Requests served by the bounded-error Approx kernels.
    pub approx: AtomicU64,
}

impl TierCounters {
    /// Record `count` requests served by a *resolved* native tier
    /// (`Auto` is resolved by the unit before it gets here).
    pub fn record(&self, tier: ExecTier, count: u64) {
        debug_assert_ne!(tier, ExecTier::Auto, "record the resolved tier");
        match tier {
            ExecTier::Fast | ExecTier::Auto => self.fast.fetch_add(count, Ordering::Relaxed),
            ExecTier::Datapath => self.datapath.fetch_add(count, Ordering::Relaxed),
            ExecTier::Approx => self.approx.fetch_add(count, Ordering::Relaxed),
        };
    }

    /// Record which Fast kernel served `count` already-`record`ed
    /// fast-tier requests (`Unit::resolve_fast_path`); scalar-fast
    /// requests are the `fast` remainder and need no sub-counter.
    pub fn record_fast_path(&self, path: FastPath, count: u64) {
        match path {
            FastPath::Table => {
                self.fast_table.fetch_add(count, Ordering::Relaxed);
            }
            FastPath::Vector => {
                self.fast_vector.fetch_add(count, Ordering::Relaxed);
            }
            FastPath::Simd => {
                self.fast_simd.fetch_add(count, Ordering::Relaxed);
            }
            _ => {}
        }
    }

    /// Record `count` requests served by the PJRT graph.
    pub fn record_pjrt(&self, count: u64) {
        self.pjrt.fetch_add(count, Ordering::Relaxed);
    }

    /// Requests served by a native tier (`Auto` reads the fast counter).
    pub fn get(&self, tier: ExecTier) -> u64 {
        match tier {
            ExecTier::Fast | ExecTier::Auto => self.fast.load(Ordering::Relaxed),
            ExecTier::Datapath => self.datapath.load(Ordering::Relaxed),
            ExecTier::Approx => self.approx.load(Ordering::Relaxed),
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "fast={} (table={} vector={} simd={}) datapath={} pjrt={} approx={}",
            self.fast.load(Ordering::Relaxed),
            self.fast_table.load(Ordering::Relaxed),
            self.fast_vector.load(Ordering::Relaxed),
            self.fast_simd.load(Ordering::Relaxed),
            self.datapath.load(Ordering::Relaxed),
            self.pjrt.load(Ordering::Relaxed),
            self.approx.load(Ordering::Relaxed),
        )
    }
}

/// One op kind's observed Approx-tier error telemetry, as a relaxed
/// snapshot of the sampled audit lanes (the coordinator recomputes every
/// k-th approx-served lane on the exact tier and records the observed
/// ulp distance here).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ApproxErrorStats {
    /// Audited lanes.
    pub count: u64,
    /// Largest observed ulp error.
    pub max: u64,
    /// Sum of observed ulp errors (mean = `sum / count`).
    pub sum: u64,
    /// Audited lanes whose observed error exceeded the kernel's
    /// *declared* bound — a contract violation; should stay 0.
    pub over: u64,
}

impl ApproxErrorStats {
    /// Mean observed ulp error over the audited lanes.
    pub fn mean(&self) -> f64 {
        self.sum as f64 / self.count.max(1) as f64
    }
}

#[derive(Default)]
struct ApproxErrorCell {
    count: AtomicU64,
    max: AtomicU64,
    sum: AtomicU64,
    over: AtomicU64,
}

/// Observed-error telemetry for the Approx tier, one cell per op kind
/// ([`Op::kind_index`]). Lock-free on the record path, like every other
/// panel here.
#[derive(Default)]
pub struct ApproxErrorPanel {
    cells: [ApproxErrorCell; 9],
}

impl ApproxErrorPanel {
    /// Record one audited lane: the observed ulp distance from the exact
    /// result, checked against the kernel's declared bound.
    pub fn record(&self, op: Op, ulp: u64, declared_max: u64) {
        let c = &self.cells[op.kind_index()];
        c.count.fetch_add(1, Ordering::Relaxed);
        c.max.fetch_max(ulp, Ordering::Relaxed);
        c.sum.fetch_add(ulp, Ordering::Relaxed);
        if ulp > declared_max {
            c.over.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot one op kind's stats.
    pub fn get(&self, op: Op) -> ApproxErrorStats {
        let c = &self.cells[op.kind_index()];
        ApproxErrorStats {
            count: c.count.load(Ordering::Relaxed),
            max: c.max.load(Ordering::Relaxed),
            sum: c.sum.load(Ordering::Relaxed),
            over: c.over.load(Ordering::Relaxed),
        }
    }

    /// Fold another panel into this one (per-shard → fleet aggregation).
    pub fn merge_from(&self, other: &ApproxErrorPanel) {
        for (mine, theirs) in self.cells.iter().zip(other.cells.iter()) {
            mine.count.fetch_add(theirs.count.load(Ordering::Relaxed), Ordering::Relaxed);
            mine.max.fetch_max(theirs.max.load(Ordering::Relaxed), Ordering::Relaxed);
            mine.sum.fetch_add(theirs.sum.load(Ordering::Relaxed), Ordering::Relaxed);
            mine.over.fetch_add(theirs.over.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }

    /// One line per op kind with audited traffic:
    /// `div: audited=... max_ulp=... mean_ulp=... over=...`.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for op in Op::KINDS {
            let s = self.get(op);
            if s.count > 0 {
                out.push_str(&format!(
                    "{}: audited={} max_ulp={} mean_ulp={:.2} over={}\n",
                    op.name(),
                    s.count,
                    s.max,
                    s.mean(),
                    s.over
                ));
            }
        }
        if out.is_empty() {
            out.push_str("(no approx traffic)\n");
        }
        out
    }
}

/// Aggregated service counters.
#[derive(Default)]
pub struct Metrics {
    /// Per-request end-to-end latency (enqueue → response).
    pub request_latency: Histogram,
    /// Per-batch execution latency at the backend.
    pub batch_latency: Histogram,
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub special_results: AtomicU64,
    /// Requests served, split by operation kind.
    pub ops: OpCounters,
    /// Requests served, split by execution tier.
    pub tiers: TierCounters,
    /// End-to-end latency per (op kind × serving lane) — the SLO panel.
    pub latency: LatencyPanel,
    /// Observed Approx-tier error per op kind, from the sampled audit.
    pub approx_errors: ApproxErrorPanel,
    /// Requests shed by admission control (`ServiceOverloaded`): counted
    /// by the sharded router against the target shard's metrics, never
    /// enqueued, never part of `requests`.
    pub shed: AtomicU64,
    /// Brown-out degradations, split by operation kind: requests the
    /// sharded router forcibly routed to the Approx tier because the
    /// shard's inflight crossed its soft watermark and the request
    /// declared an ulp tolerance ([`crate::unit::Op::degrades_approx`]).
    /// Degraded requests still complete and still count in `requests`;
    /// this panel is the ladder's first rung, ahead of `shed`.
    pub degraded: OpCounters,
    /// Requests dropped at admission because their end-to-end deadline
    /// budget had already elapsed (`DeadlineExceeded`): like `shed`,
    /// never enqueued and never part of `requests` — but unlike `shed`,
    /// they never held an admission slot at all.
    pub deadline_drops: AtomicU64,
}

impl Metrics {
    pub fn mean_batch_fill(&self, max_batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        let r = self.requests.load(Ordering::Relaxed);
        r as f64 / b as f64 / max_batch as f64
    }

    /// Total brown-out degradations across all op kinds.
    pub fn degraded_total(&self) -> u64 {
        Op::KINDS.iter().map(|&op| self.degraded.get(op)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_nanos(i * 100));
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.quantile(0.99) <= h.max() * 2);
        assert!(h.mean().as_nanos() > 0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn op_counters_bucket_by_kind() {
        let c = OpCounters::default();
        c.record(Op::DIV);
        c.record(Op::Div { alg: crate::division::Algorithm::Nrd });
        c.record(Op::Sqrt);
        c.record(Op::MulAdd);
        c.record(Op::Dot);
        c.record(Op::Dot);
        c.record(Op::FusedSum);
        c.record(Op::Axpy);
        assert_eq!(c.get(Op::DIV), 2, "division buckets ignore the algorithm");
        assert_eq!(c.get(Op::Sqrt), 1);
        assert_eq!(c.get(Op::Mul), 0);
        assert_eq!(c.get(Op::MulAdd), 1);
        assert_eq!(c.get(Op::Dot), 2);
        assert_eq!(c.get(Op::FusedSum), 1);
        assert_eq!(c.get(Op::Axpy), 1);
        let s = c.summary();
        assert!(s.contains("div=2") && s.contains("mul_add=1"), "{s}");
        assert!(s.contains("dot=2") && s.contains("fsum=1") && s.contains("axpy=1"), "{s}");
    }

    #[test]
    fn tier_counters_bucket_and_summarize() {
        let t = TierCounters::default();
        t.record(ExecTier::Fast, 100);
        t.record(ExecTier::Datapath, 7);
        t.record_pjrt(3);
        assert_eq!(t.get(ExecTier::Fast), 100);
        assert_eq!(t.get(ExecTier::Datapath), 7);
        assert_eq!(t.pjrt.load(Ordering::Relaxed), 3);
        let s = t.summary();
        assert!(s.contains("fast=100") && s.contains("datapath=7") && s.contains("pjrt=3"), "{s}");
    }

    #[test]
    fn fast_path_counters_split_the_fast_tier() {
        let t = TierCounters::default();
        t.record(ExecTier::Fast, 110);
        t.record_fast_path(FastPath::Table, 50);
        t.record_fast_path(FastPath::Vector, 20);
        t.record_fast_path(FastPath::Simd, 30);
        // scalar-fast requests are the remainder; recording them is a no-op
        t.record_fast_path(FastPath::Scalar, 10);
        assert_eq!(t.fast.load(Ordering::Relaxed), 110);
        assert_eq!(t.fast_table.load(Ordering::Relaxed), 50);
        assert_eq!(t.fast_vector.load(Ordering::Relaxed), 20);
        assert_eq!(t.fast_simd.load(Ordering::Relaxed), 30);
        let s = t.summary();
        assert!(s.contains("table=50") && s.contains("vector=20") && s.contains("simd=30"), "{s}");
    }

    #[test]
    fn histogram_merge_accumulates() {
        let a = Histogram::new();
        let b = Histogram::new();
        for i in 1..=100u64 {
            a.record(Duration::from_nanos(i * 10));
            b.record(Duration::from_micros(i));
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 200);
        assert!(a.max() >= b.max());
        assert!(a.quantile(0.999) >= a.quantile(0.5));
        assert!(a.summary().contains("p999<="), "{}", a.summary());
    }

    #[test]
    fn latency_panel_buckets_by_op_and_lane() {
        let p = LatencyPanel::default();
        p.record(Op::DIV, ServedBy::Fast, Duration::from_micros(10));
        p.record(Op::Div { alg: crate::division::Algorithm::Nrd }, ServedBy::Fast,
                 Duration::from_micros(20));
        p.record(Op::DIV, ServedBy::Datapath, Duration::from_micros(30));
        p.record(Op::Sqrt, ServedBy::Pjrt, Duration::from_micros(40));
        assert_eq!(p.get(Op::DIV, ServedBy::Fast).count(), 2, "algorithm-blind");
        assert_eq!(p.get(Op::DIV, ServedBy::Datapath).count(), 1);
        assert_eq!(p.get(Op::Sqrt, ServedBy::Pjrt).count(), 1);
        assert_eq!(p.get(Op::Mul, ServedBy::Fast).count(), 0);
        let cells = p.nonempty();
        assert_eq!(cells.len(), 3);
        assert!(p.render().contains("div x fast"), "{}", p.render());
        // lane aggregate folds ops together
        assert_eq!(p.lane_aggregate(ServedBy::Fast).count(), 2);
        // panel merge folds cell-wise
        let q = LatencyPanel::default();
        q.merge_from(&p);
        q.merge_from(&p);
        assert_eq!(q.get(Op::DIV, ServedBy::Fast).count(), 4);
    }

    #[test]
    fn served_by_maps_resolved_tiers() {
        assert_eq!(ServedBy::from_tier(ExecTier::Fast), ServedBy::Fast);
        assert_eq!(ServedBy::from_tier(ExecTier::Datapath), ServedBy::Datapath);
        assert_eq!(ServedBy::from_tier(ExecTier::Approx), ServedBy::Approx);
        for (i, lane) in ServedBy::ALL.iter().enumerate() {
            assert_eq!(lane.index(), i);
        }
        assert_eq!(ServedBy::Pjrt.name(), "pjrt");
        assert_eq!(ServedBy::Approx.name(), "approx");
    }

    #[test]
    fn approx_error_panel_records_and_merges() {
        let p = ApproxErrorPanel::default();
        p.record(Op::DIV, 1, 4);
        p.record(Op::DIV, 3, 4);
        p.record(Op::Div { alg: crate::division::Algorithm::Nrd }, 0, 4);
        p.record(Op::Sqrt, 9, 4); // over the declared bound
        let d = p.get(Op::DIV);
        assert_eq!((d.count, d.max, d.sum, d.over), (3, 3, 4, 0));
        assert!((d.mean() - 4.0 / 3.0).abs() < 1e-9);
        let s = p.get(Op::Sqrt);
        assert_eq!((s.count, s.max, s.over), (1, 9, 1));
        assert_eq!(p.get(Op::Mul), ApproxErrorStats::default());
        let out = p.summary();
        assert!(out.contains("div: audited=3 max_ulp=3"), "{out}");
        assert!(out.contains("sqrt: audited=1 max_ulp=9") && out.contains("over=1"), "{out}");
        // fleet aggregation folds cell-wise
        let q = ApproxErrorPanel::default();
        q.merge_from(&p);
        q.merge_from(&p);
        let d = q.get(Op::DIV);
        assert_eq!((d.count, d.max, d.sum), (6, 3, 8));
        assert_eq!(ApproxErrorPanel::default().summary(), "(no approx traffic)\n");
    }

    #[test]
    fn tier_counters_count_the_approx_lane() {
        let t = TierCounters::default();
        t.record(ExecTier::Approx, 12);
        t.record(ExecTier::Fast, 3);
        assert_eq!(t.get(ExecTier::Approx), 12);
        assert!(t.summary().contains("approx=12"), "{}", t.summary());
    }

    #[test]
    fn degraded_panel_and_deadline_drops() {
        let m = Metrics::default();
        assert_eq!(m.degraded_total(), 0);
        m.degraded.record(Op::DIV);
        m.degraded.record(Op::Div { alg: crate::division::Algorithm::Nrd });
        m.degraded.record(Op::Sqrt);
        assert_eq!(m.degraded.get(Op::DIV), 2, "degradations bucket algorithm-blind");
        assert_eq!(m.degraded.get(Op::Sqrt), 1);
        assert_eq!(m.degraded.get(Op::Mul), 0);
        assert_eq!(m.degraded_total(), 3);
        m.deadline_drops.fetch_add(2, Ordering::Relaxed);
        assert_eq!(m.deadline_drops.load(Ordering::Relaxed), 2);
        // the ladder's rungs are independent counters
        assert_eq!(m.shed.load(Ordering::Relaxed), 0);
        assert_eq!(m.requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn record_is_thread_safe() {
        let h = std::sync::Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..10_000 {
                        h.record(Duration::from_nanos(i));
                    }
                });
            }
        });
        assert_eq!(h.count(), 80_000);
    }
}
