//! Digit-recurrence posit division — the paper's contribution.
//!
//! Every algorithm of the paper's Table IV is implemented as a bit-exact,
//! datapath-level engine that steps the same registers the hardware holds
//! (residual in two's-complement or carry-save form, quotient in signed-
//! digit, conventional or on-the-fly converted form) and therefore produces
//! the same digit sequence, the same cycle counts (Table II) and the same
//! final posit as the RTL the paper synthesizes.
//!
//! | engine | paper name | radix | residual | quotient conversion | termination |
//! |--------|------------|-------|----------|---------------------|-------------|
//! | [`nrd::Nrd`]              | NRD           | 2 | non-redundant | sign-digit accumulate | CPA |
//! | [`srt2::Srt2`]            | SRT           | 2 | non-redundant | P−N subtract | CPA |
//! | [`srt2_cs::Srt2Cs`]       | SRT CS        | 2 | carry-save | P−N subtract | CPA |
//! | [`srt2_cs::Srt2Cs`]+OF    | SRT CS OF     | 2 | carry-save | on-the-fly | CPA sign |
//! | [`srt2_cs::Srt2Cs`]+OF+FR | SRT CS OF FR  | 2 | carry-save | on-the-fly | lookahead |
//! | [`srt4_cs::Srt4Cs`] (±OF/FR) | SRT CS (OF, FR) | 4 | carry-save | table SEL Eq.(28) | as above |
//! | [`srt4_scaled::Srt4Scaled`]  | radix-4 + scaling | 4 | carry-save | SEL Eq.(29) | as above |
//! | [`newton::Newton`]        | (multiplicative baseline, §I) | — | — | — | remainder fix-up |
//!
//! The shared wrapper ([`exec`]) handles everything around the fraction
//! recurrence: special cases, the sign/exponent path of Eqs. (7)–(9),
//! normalization, and the regime-aware rounding of §III-F.
//!
//! [`fastpath`] is the serving counterpart: width-monomorphized,
//! branch-light kernels that compute the same truncated quotient + sticky
//! by direct fixed-point arithmetic, bit-identical to every engine
//! above, with a vectorized batch layer on top — exhaustive Posit8
//! operation tables ([`p8_tables`]), Posit16 reciprocal/root seed tables
//! ([`p16_tables`]), runtime-detected explicit vector-ISA kernels
//! ([`vector`]) and SWAR lane-packed kernels ([`simd`]) — dispatched per
//! batch by [`fastpath::FastPath`]. [`crate::unit::ExecTier`] picks
//! between the engines and the fast kernels.
//!
//! [`approx`] is the bounded-error counterpart: reciprocal/rsqrt-seeded
//! single-Newton-step division and square root plus truncated-fraction
//! multiplication, each registered with a declared max-ulp contract
//! ([`approx::ApproxSpec`]) and served by `ExecTier::Approx` for
//! requests that opt in via a per-request accuracy policy.

pub mod approx;
pub mod carry_save;
pub mod divider;
pub mod exec;
pub mod fastpath;
pub mod golden;
pub mod newton;
pub mod nrd;
pub mod otf;
pub mod p16_tables;
pub mod p8_tables;
pub mod scaling;
pub mod selection;
pub mod simd;
pub mod sqrt;
pub mod srt2;
pub mod srt2_cs;
pub mod srt4_cs;
pub mod srt4_scaled;
pub mod vector;

use crate::posit::Posit;

#[allow(deprecated)]
pub use divider::Divider;

/// The division algorithm variants evaluated by the paper (Table IV), plus
/// the two baselines used in its related-work comparisons.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Non-restoring division, radix-2 (Algorithm 1) — the paper's baseline.
    Nrd,
    /// NRD with the two's-complement decoding of [14] (ASAP'23): signed
    /// significands cost one extra iteration. Comparison target C1.
    NrdAsap23,
    /// SRT radix-2, non-redundant residual, digit set {-1,0,1}, Eq. (26).
    Srt2,
    /// SRT radix-2, carry-save residual, Eq. (27).
    Srt2Cs,
    /// + on-the-fly quotient conversion (Eqs. (18)–(19)).
    Srt2CsOf,
    /// + fast sign/zero detection of the final residual.
    Srt2CsOfFr,
    /// SRT radix-4, carry-save residual, digit set {-2..2}, SEL Eq. (28).
    Srt4Cs,
    Srt4CsOf,
    Srt4CsOfFr,
    /// SRT radix-4 with operand scaling (Table I), SEL Eq. (29).
    Srt4Scaled,
    /// Newton–Raphson multiplicative divider (PACoGen-style baseline).
    Newton,
}

impl Algorithm {
    /// The default serving algorithm: the paper's optimized radix-4 unit
    /// (what the typed-posit `Div` operator and `Divider::standard` use).
    pub const DEFAULT: Algorithm = Algorithm::Srt4CsOfFr;

    /// All variants, in the paper's presentation order.
    pub const ALL: [Algorithm; 11] = [
        Algorithm::Nrd,
        Algorithm::NrdAsap23,
        Algorithm::Srt2,
        Algorithm::Srt2Cs,
        Algorithm::Srt2CsOf,
        Algorithm::Srt2CsOfFr,
        Algorithm::Srt4Cs,
        Algorithm::Srt4CsOf,
        Algorithm::Srt4CsOfFr,
        Algorithm::Srt4Scaled,
        Algorithm::Newton,
    ];

    /// The digit-recurrence designs of Table IV (what Figs. 4–9 sweep).
    pub const TABLE_IV: [Algorithm; 9] = [
        Algorithm::Nrd,
        Algorithm::Srt2,
        Algorithm::Srt2Cs,
        Algorithm::Srt2CsOf,
        Algorithm::Srt2CsOfFr,
        Algorithm::Srt4Cs,
        Algorithm::Srt4CsOf,
        Algorithm::Srt4CsOfFr,
        Algorithm::Srt4Scaled,
    ];

    /// Radix of the recurrence (None for the multiplicative baseline).
    pub fn radix(self) -> Option<u32> {
        match self {
            Algorithm::Nrd
            | Algorithm::NrdAsap23
            | Algorithm::Srt2
            | Algorithm::Srt2Cs
            | Algorithm::Srt2CsOf
            | Algorithm::Srt2CsOfFr => Some(2),
            Algorithm::Srt4Cs
            | Algorithm::Srt4CsOf
            | Algorithm::Srt4CsOfFr
            | Algorithm::Srt4Scaled => Some(4),
            Algorithm::Newton => None,
        }
    }

    pub fn uses_carry_save(self) -> bool {
        !matches!(
            self,
            Algorithm::Nrd | Algorithm::NrdAsap23 | Algorithm::Srt2 | Algorithm::Newton
        )
    }

    pub fn uses_otf(self) -> bool {
        matches!(
            self,
            Algorithm::Srt2CsOf
                | Algorithm::Srt2CsOfFr
                | Algorithm::Srt4CsOf
                | Algorithm::Srt4CsOfFr
                | Algorithm::Srt4Scaled
        )
    }

    pub fn uses_fast_remainder(self) -> bool {
        matches!(self, Algorithm::Srt2CsOfFr | Algorithm::Srt4CsOfFr | Algorithm::Srt4Scaled)
    }

    pub fn uses_scaling(self) -> bool {
        matches!(self, Algorithm::Srt4Scaled)
    }

    /// Short name as used in the paper's figures.
    pub fn label(self) -> &'static str {
        match self {
            Algorithm::Nrd => "NRD",
            Algorithm::NrdAsap23 => "NRD [14]",
            Algorithm::Srt2 => "SRT r2",
            Algorithm::Srt2Cs => "SRT r2 CS",
            Algorithm::Srt2CsOf => "SRT r2 CS OF",
            Algorithm::Srt2CsOfFr => "SRT r2 CS OF FR",
            Algorithm::Srt4Cs => "SRT r4 CS",
            Algorithm::Srt4CsOf => "SRT r4 CS OF",
            Algorithm::Srt4CsOfFr => "SRT r4 CS OF FR",
            Algorithm::Srt4Scaled => "SRT r4 scaled",
            Algorithm::Newton => "Newton-Raphson",
        }
    }
}

/// Number of digit-recurrence iterations for a Posit⟨n,2⟩ at a given radix
/// (paper Eq. (31) with h from Eq. (30)). Matches Table II:
/// r2 → n−2 (14/30/62), r4 → ⌈(n−1)/2⌉ (8/16/32).
pub fn iterations(n: u32, radix: u32) -> u32 {
    let h = match radix {
        2 => n - 2, // h = n − 1 − ⌊ρ⌋ with ρ = 1
        4 => n - 1, // ρ = 2/3 < 1
        r => panic!("unsupported radix {r}"),
    };
    h.div_ceil(radix.ilog2())
}

/// Pipelined latency in cycles (paper §III-E3): one cycle per iteration
/// plus decode, termination and encode; +1 when operand scaling is used.
pub fn latency_cycles(n: u32, alg: Algorithm) -> u32 {
    match alg {
        Algorithm::Newton => newton::Newton::new().cycles(n),
        Algorithm::NrdAsap23 => iterations(n, 2) + 1 + 3,
        a => iterations(n, a.radix().unwrap()) + 3 + if a.uses_scaling() { 1 } else { 0 },
    }
}

/// Result of the fraction recurrence: the quotient of two significands in
/// [1,2), delivered as a fixed-point value `q = mag / 2^frac_bits ∈ (1/2,2)`
/// plus the "remainder non-zero" sticky condition and cycle metadata.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FracQuotient {
    /// Quotient magnitude; value = mag / 2^frac_bits ∈ (1/2, 2).
    pub mag: u128,
    /// Position of the binary point in `mag`.
    pub frac_bits: u32,
    /// True iff the final remainder was non-zero (the rounding sticky bit).
    pub sticky: bool,
    /// Digit-recurrence iterations executed (Table II column).
    pub iterations: u32,
}

/// A completed posit division with execution metadata.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Division {
    pub result: Posit,
    /// Recurrence iterations (0 for special-case fast paths).
    pub iterations: u32,
    /// Total pipeline cycles per §III-E3.
    pub cycles: u32,
}

/// A posit division engine.
///
/// `fraction_divide` is the per-algorithm datapath core (operating on
/// significands); `divide` wraps it with the common posit front/back end
/// (implemented once in [`exec`] and shared by every engine — exactly like
/// the hardware, where decode/encode blocks are common to all variants).
pub trait DivEngine {
    /// Engine name for reports.
    fn name(&self) -> &'static str;

    /// Which Table IV variant this is.
    fn algorithm(&self) -> Algorithm;

    /// Divide two significands `x_sig, d_sig ∈ [2^F, 2^(F+1))` (posit
    /// significands in [1,2) with `F = frac_bits(n)`), returning the exact
    /// truncated quotient and sticky. Must equal [`golden::frac_divide`]
    /// bit-for-bit.
    fn fraction_divide(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient;

    /// Full posit division (specials, exponents, normalize, round).
    fn divide(&self, x: Posit, d: Posit) -> Division {
        exec::divide_with(self, x, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_counts_match_table2() {
        // Paper Table II.
        assert_eq!(iterations(16, 2), 14);
        assert_eq!(iterations(32, 2), 30);
        assert_eq!(iterations(64, 2), 62);
        assert_eq!(iterations(16, 4), 8);
        assert_eq!(iterations(32, 4), 16);
        assert_eq!(iterations(64, 4), 32);
    }

    #[test]
    fn latency_matches_table2() {
        assert_eq!(latency_cycles(16, Algorithm::Srt2Cs), 17);
        assert_eq!(latency_cycles(32, Algorithm::Srt2Cs), 33);
        assert_eq!(latency_cycles(64, Algorithm::Srt2Cs), 65);
        assert_eq!(latency_cycles(16, Algorithm::Srt4Cs), 11);
        assert_eq!(latency_cycles(32, Algorithm::Srt4Cs), 19);
        assert_eq!(latency_cycles(64, Algorithm::Srt4Cs), 35);
        // scaling costs one extra cycle
        assert_eq!(latency_cycles(16, Algorithm::Srt4Scaled), 12);
        // [14]'s decode costs one extra iteration
        assert_eq!(latency_cycles(16, Algorithm::NrdAsap23), 18);
    }

    #[test]
    fn algorithm_flags_match_table4() {
        use Algorithm::*;
        assert!(!Nrd.uses_carry_save() && !Nrd.uses_otf() && !Nrd.uses_fast_remainder());
        assert!(!Srt2.uses_carry_save());
        assert!(Srt2Cs.uses_carry_save() && !Srt2Cs.uses_otf());
        assert!(Srt2CsOf.uses_otf() && !Srt2CsOf.uses_fast_remainder());
        assert!(Srt2CsOfFr.uses_fast_remainder());
        assert!(Srt4Scaled.uses_scaling());
        assert_eq!(Srt4Cs.radix(), Some(4));
        assert_eq!(Newton.radix(), None);
    }
}
