//! Elaboration of every Table IV divider design into component netlists.
//!
//! Each design is described by its pipeline *stages* (decode, optional
//! scaling, the recurrence slice, termination, encode) built from
//! [`components`](super::components). [`synth`](super::synth) then costs a
//! design either **combinationally** (slices replicated `It` times, no
//! registers, delays chained) or **pipelined** (one slice + state
//! registers per stage boundary, one iteration per cycle at the 1.5 GHz
//! target — the paper's two evaluation modes).

use super::components::{self as c, sel, AdderStyle, Cost};
use crate::division::{iterations, latency_cycles, Algorithm};
use crate::posit::frac_bits;

/// Widths of the recurrence datapath for a given algorithm/format —
/// consistent with the engines' fixed-point layouts (§III-E1).
pub fn residual_width(alg: Algorithm, n: u32) -> u32 {
    let f = frac_bits(n);
    match alg.radix() {
        Some(2) => f + 2 + 4,                       // FW = F+2, sign + 3 integer bits
        Some(4) if alg.uses_scaling() => f + 6 + 4, // FW = F+6
        Some(4) => f + 3 + 4,                       // FW = F+3
        Some(r) => panic!("unsupported radix {r}"),
        None => f + 9,                              // Newton: Q(f+8) reciprocal path
    }
}

/// Quotient length h (Eq. (30)).
pub fn quotient_bits(alg: Algorithm, n: u32) -> u32 {
    match alg.radix() {
        Some(2) => n - 2,
        Some(4) => n - 1,
        Some(r) => panic!("unsupported radix {r}"),
        None => n,
    }
}

/// Divisor-multiple generation {0, ±d}: conditional invert + zero mask.
fn multiple_gen_r2(w: u32) -> Cost {
    c::xor_row(w).then(Cost::new(1.0 * w as f64, 1.0))
}

/// Divisor-multiple generation {0, ±d, ±2d}: 2:1 shift mux + invert + mask.
fn multiple_gen_r4(w: u32) -> Cost {
    c::mux2(w).then(c::xor_row(w)).then(Cost::new(1.0 * w as f64, 1.0))
}

/// A fully-elaborated design, stage by stage.
#[derive(Clone, Debug)]
pub struct Design {
    pub alg: Algorithm,
    pub n: u32,
    /// Posit field extraction: sign handling, regime LZC, fraction align.
    pub decode: Cost,
    /// Operand pre-scaling stage (Table I), if any.
    pub scaling: Option<Cost>,
    /// One digit-recurrence iteration (selection + multiple gen + update +
    /// quotient path update).
    pub slice: Cost,
    /// Iteration count (Table II).
    pub iterations: u32,
    /// Recurrence state carried between iterations (bits to register in
    /// the pipelined mapping): residual (1 or 2 words) + quotient regs.
    pub state_bits: u32,
    /// Sign/zero of final residual, correction, sticky.
    pub termination: Cost,
    /// Normalization, regime/exponent assembly, rounding, two's comp.
    pub encode: Cost,
    /// Pipelined latency in cycles (§III-E3).
    pub cycles: u32,
}

/// Elaborate `alg` at width `n` with the timing-driven mapping (the
/// pipelined synthesis mode).
pub fn elaborate(alg: Algorithm, n: u32) -> Design {
    elaborate_styled(alg, n, AdderStyle::TimingDriven)
}

/// Elaborate `alg` at width `n`, choosing adder structures per the
/// synthesis mode (area-optimized ripple vs timing-driven prefix — what an
/// unconstrained vs 1.5 GHz-constrained DC run instantiates).
pub fn elaborate_styled(alg: Algorithm, n: u32, style: AdderStyle) -> Design {
    let f = frac_bits(n);
    let w = residual_width(alg, n);
    let h = quotient_bits(alg, n);
    let cpa = |w: u32| c::cpa(style, w);

    // ---- shared front/back end (Fig. 2) ----
    // decode: regime LZC on the conditionally-inverted word (the +1 of the
    // two's complement is a cheap parallel fix-up) + fraction alignment
    // shift, both operands in parallel; scale subtraction (Eq. 7) is a
    // narrow adder off the critical path.
    let one_decode = c::xor_row(n)
        .then(c::lzc(n))
        .then(c::shifter(n))
        .then(Cost::new(2.0 * n as f64, 4.0)); // +1 fix-up / hidden bit
    let decode = one_decode.beside(one_decode).then(cpa(12).area_only());

    // encode: normalization shift + regime/exponent assembly + a compound
    // round-increment/negate adder (one CPA + selection) + saturation.
    let encode = c::shifter(n)
        .then(Cost::new(2.0 * n as f64, 3.0)) // regime assembly muxes
        .then(cpa(n)) // compound rounding/negation increment
        .then(c::xor_row(n))
        .then(Cost::new(1.5 * n as f64, 2.0)); // saturation / special mux

    // ---- per-variant recurrence slice ----
    let (slice, state_bits, uses_cs) = match alg {
        Algorithm::Nrd | Algorithm::NrdAsap23 => {
            // digit ∈ {−1,1}: ±d is a conditional invert (+ carry-in);
            // sign comes free from the previous CPA's MSB.
            let s = c::xor_row(w).then(cpa(w));
            (s, w + h, false)
        }
        Algorithm::Srt2 => {
            // Eq. (26) on 2 MSBs + {0,±d} gen (invert + zero-AND) + CPA
            let s = sel::radix2().then(multiple_gen_r2(w)).then(cpa(w));
            (s, w + 2 * h, false)
        }
        Algorithm::Srt2Cs | Algorithm::Srt2CsOf | Algorithm::Srt2CsOfFr => {
            // 4-bit estimate adder + Eq. (27) + {0,±d} gen + CSA; the
            // second residual word costs wiring/buffering, not logic.
            let s = c::est_adder(4)
                .then(sel::radix2())
                .then(multiple_gen_r2(w))
                .then(c::csa(w))
                .beside(Cost::new(1.5 * w as f64, 0.0)); // 2nd-word routing
            (s, 2 * w + 2 * h, true)
        }
        Algorithm::Srt4Cs | Algorithm::Srt4CsOf | Algorithm::Srt4CsOfFr => {
            // 7-bit estimate adder + m_k(d̂) table + {0,±d,±2d} gen + CSA
            let s = c::est_adder(7)
                .then(sel::radix4_table())
                .then(multiple_gen_r4(w))
                .then(c::csa(w))
                .beside(Cost::new(1.5 * w as f64, 0.0));
            (s, 2 * w + 2 * h, true)
        }
        Algorithm::Srt4Scaled => {
            // 6-bit estimate + Eq. (29) constants + {0,±d,±2d} gen + CSA
            let s = c::est_adder(6)
                .then(sel::radix4_const())
                .then(multiple_gen_r4(w))
                .then(c::csa(w))
                .beside(Cost::new(1.5 * w as f64, 0.0));
            (s, 2 * w + 2 * h, true)
        }
        Algorithm::Newton => {
            // one NR step = two multiplications (modelled as the slice;
            // iterations = NR steps, each 2 cycles in the cycle model)
            let mul = c::multiplier((f + 8).min(64));
            (mul.then(mul), 2 * (f + 9), false)
        }
    };

    // On-the-fly conversion adds the Q/QD concatenation muxes to the slice
    // (two muxes of average width h/2, driven by the digit — a wide fanout
    // that costs a few τ, which is the "slight delay increase" the paper
    // observes on the radix-2 combinational designs where the recurrence
    // slice itself is very shallow).
    let slice = if alg.uses_otf() {
        slice
            .beside(Cost::new(3.0 * h as f64 + 12.0, 0.0)) // Q/QD muxes
            .then(Cost::new(0.0, 2.0)) // digit fanout + select buffering
    } else {
        slice
    };

    // ---- scaling stage (Table I): select M, then one CSA level + CPA for
    // each operand (shift-add; exact, 3 extra fraction bits), plus the
    // buffering needed to broadcast the scaled divisor to the recurrence
    // and termination datapaths — which is why this stage ends up the
    // longest path of the pipelined scaled design (§IV).
    let scaling = alg.uses_scaling().then(|| {
        sel::scaling_factor()
            .then(c::csa(w).beside(c::csa(w)))
            .then(cpa(w).beside(cpa(w)))
            .then(c::mux2(w).beside(c::mux2(w)))
            .then(Cost::new(3.0 * w as f64, 10.0)) // broadcast buffering
    });

    // ---- termination (§III-F): final sign + zero (sticky) + correction ----
    let termination = if alg == Algorithm::Newton {
        // final q = x·y multiply, exact remainder q·d (second multiplier
        // reused), fix-up compare + sticky
        c::multiplier((f + 8).min(64)).then(cpa(w)).then(c::zero_tree(w))
    } else if uses_cs {
        if alg.uses_fast_remainder() {
            // lookahead sign + zero networks; correction via OTF select
            c::cs_sign_zero_lookahead(w).then(c::mux2(h))
        } else if alg.uses_otf() {
            // resolve with CPA (sign + zero tree); correction via OTF select
            cpa(w).then(c::zero_tree(w)).then(c::mux2(h))
        } else {
            // residual resolve (sign + sticky zero) in parallel with the
            // signed-digit conversion subtract P−N (a compound adder
            // producing q and q−1); the sign then selects — the two CPAs
            // are independent, so the path is their max, not their sum.
            cpa(w)
                .then(c::zero_tree(w))
                .beside(cpa(h).then(c::mux2(h)))
        }
    } else {
        // non-redundant residual: sign is free; zero tree + quotient
        // conversion/decrement CPA
        c::zero_tree(w).then(cpa(h))
    };

    Design {
        alg,
        n,
        decode,
        scaling,
        slice,
        iterations: match alg {
            Algorithm::Newton => crate::division::newton::Newton::new().nr_steps(n),
            Algorithm::NrdAsap23 => iterations(n, 2) + 1,
            a => iterations(n, a.radix().unwrap()),
        },
        state_bits,
        termination,
        encode,
        cycles: latency_cycles(n, alg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_match_engine_layouts() {
        // r2: FW+4 = F+6; r4: F+7; scaled: F+10 — the same layouts the
        // bit-exact engines use.
        assert_eq!(residual_width(Algorithm::Srt2Cs, 32), 27 + 6);
        assert_eq!(residual_width(Algorithm::Srt4Cs, 32), 27 + 7);
        assert_eq!(residual_width(Algorithm::Srt4Scaled, 32), 27 + 10);
    }

    #[test]
    fn cs_slice_shallower_than_cpa_slice() {
        // The §III-B1 claim: CS iteration beats the CPA iteration at every
        // format, and the gap grows with n.
        for n in [16u32, 32, 64] {
            let plain = elaborate(Algorithm::Srt2, n).slice.delay;
            let cs = elaborate(Algorithm::Srt2Cs, n).slice.delay;
            assert!(cs < plain, "n={n}: {cs} !< {plain}");
        }
        let gap16 = elaborate(Algorithm::Srt2, 16).slice.delay
            - elaborate(Algorithm::Srt2Cs, 16).slice.delay;
        let gap64 = elaborate(Algorithm::Srt2, 64).slice.delay
            - elaborate(Algorithm::Srt2Cs, 64).slice.delay;
        assert!(gap64 > gap16);
    }

    #[test]
    fn radix4_slice_deeper_but_half_iterations() {
        for n in [16u32, 32, 64] {
            let r2 = elaborate(Algorithm::Srt2Cs, n);
            let r4 = elaborate(Algorithm::Srt4Cs, n);
            assert!(r4.slice.delay > r2.slice.delay);
            assert!(r4.iterations * 2 <= r2.iterations + 2);
            // total recurrence delay still favors radix-4
            assert!(
                r4.slice.delay * (r4.iterations as f64)
                    < r2.slice.delay * (r2.iterations as f64)
            );
        }
    }

    #[test]
    fn fr_termination_shallower() {
        for n in [16u32, 32, 64] {
            let of = elaborate(Algorithm::Srt4CsOf, n);
            let fr = elaborate(Algorithm::Srt4CsOfFr, n);
            assert!(fr.termination.delay < of.termination.delay, "n={n}");
        }
    }

    #[test]
    fn scaled_selection_cheaper_slice() {
        // apples to apples: the scaled engine includes OF, so compare
        // against the OF radix-4 variant.
        for n in [16u32, 32, 64] {
            let t = elaborate(Algorithm::Srt4CsOfFr, n);
            let s = elaborate(Algorithm::Srt4Scaled, n);
            assert!(s.slice.delay < t.slice.delay, "n={n}");
            assert!(s.slice.area < t.slice.area, "n={n}");
            assert!(s.scaling.is_some() && t.scaling.is_none());
        }
    }
}
