"""L2: the batched posit-division compute graph.

decode (jnp) -> radix-4 SRT fraction recurrence (the L1 Pallas kernel) ->
normalize + round + encode (jnp), with full special-case handling. One
`jax.jit`-able function per (format, batch) pair; `aot.py` lowers it to
HLO text once, and the Rust runtime executes it via PJRT with Python
nowhere on the request path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import posit_codec as codec
from .kernels import ref
from .kernels import srt_div

jax.config.update("jax_enable_x64", True)


@functools.partial(jax.jit, static_argnames=("n", "use_kernel", "block"))
def divide_batch(x_bits, d_bits, n: int, use_kernel: bool = True, block: int = srt_div.BLOCK):
    """Posit division of two int batches of n-bit patterns.

    Returns int64 lanes holding the n-bit quotient patterns. `use_kernel`
    selects the Pallas recurrence (the system under test) vs the pure-jnp
    exact oracle (the reference graph used in A/B tests).
    """
    f = codec.frac_bits(n)
    xz, xn, xs, xscale, xsig = codec.decode(x_bits, n)
    dz, dn, ds, dscale, dsig = codec.decode(d_bits, n)

    if use_kernel:
        q_mag, sticky = srt_div.fraction_divide(xsig, dsig, n, block)
        qfb = 2 * srt_div.iterations(n) - 2
    else:
        q_mag, sticky = ref.fraction_divide(xsig, dsig, n)
        qfb = n

    # Normalization (Fig. 2): q in (1/2, 2) -> [1, 2), adjusting the scale.
    t = xscale - dscale
    ge_one = (q_mag >> qfb) != 0
    scale = jnp.where(ge_one, t, t - 1)
    sfb = jnp.where(ge_one, qfb, qfb - 1)
    # common fixed sfb for the encoder: shift lanes so the hidden bit sits
    # at position qfb for all of them (value doubled where q < 1, which the
    # scale decrement exactly compensates)
    mag_norm = jnp.where(ge_one, q_mag, q_mag << 1)
    del sfb

    # The encoder's pattern frame needs qfb <= 62 - n; refine precision to
    # F+1 fraction bits below the hidden one (enough for any rounding
    # position) and fold the rest into sticky.
    keep = f + 1
    drop = qfb - keep
    assert drop >= 0
    sticky = sticky | ((mag_norm & ((1 << drop) - 1)) != 0) if drop else sticky
    mag_kept = mag_norm >> drop

    q = codec.encode(xs ^ ds, scale, mag_kept, keep, sticky, n)

    # Special cases (paper Eqs. (3)-(6)): NaR if either input is NaR or the
    # divisor is zero; zero if the dividend is zero.
    nar = xn | dn | dz
    q = jnp.where(xz, 0, q)
    q = jnp.where(nar, 1 << (n - 1), q)
    return q


def reference_divide(x_bits, d_bits, n: int):
    """The A/B reference graph (exact oracle, no Pallas)."""
    return divide_batch(x_bits, d_bits, n, use_kernel=False)
