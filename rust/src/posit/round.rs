//! Posit encoding with correct rounding.
//!
//! The 2022 Posit Standard rounds in *pattern space*: the unbounded
//! regime‖exponent‖fraction bit string is truncated to the n−1 magnitude
//! bits and rounded to nearest with ties-to-even on the pattern, never
//! producing zero or NaR from a non-zero real (saturation at `maxpos` /
//! `minpos`). This is what the paper's termination step (§III-F, Table III)
//! implements in hardware: the rounding position *depends on the regime
//! length* of the result, which is why rounding cannot be fused into the
//! last recurrence iteration as in IEEE floating-point.

use super::{mask, Posit, ES};

/// Encode `(-1)^sign · 2^scale · sig/2^sfb` (with `sig` in [2^sfb, 2^(sfb+1)),
/// i.e. a normalized significand in [1,2)) into a Posit⟨n,2⟩ with
/// round-to-nearest-even. `sticky` ORs in any discarded lower bits (e.g. the
/// non-zero-remainder condition of a division).
///
/// `#[inline]` so the width-monomorphized fast-tier kernels
/// ([`crate::division::fastpath`]) can const-fold on `n`.
#[inline]
pub fn encode_round(n: u32, sign: bool, scale: i32, sig: u128, sfb: u32, sticky: bool) -> Posit {
    debug_assert!(sfb < 127, "significand too wide");
    debug_assert!(sig >> sfb == 1, "significand not normalized to [1,2): sig={sig:#x} sfb={sfb}");

    let k = scale >> ES; // floor division (arithmetic shift), Eq. (9)
    let e = (scale & ((1 << ES) - 1)) as u128; // Eq. (8)

    // Saturation: regime cannot be represented at all.
    if k >= n as i32 - 2 {
        // value >= maxpos (or rounds down onto it): clamp, never NaR.
        let m = Posit::maxpos(n);
        return if sign { m.neg() } else { m };
    }
    if k <= -(n as i32 - 1) {
        // 0 < value <= minpos boundary: round up to minpos, never to zero.
        let m = Posit::minpos(n);
        return if sign { m.neg() } else { m };
    }

    let rl: u32 = if k >= 0 { k as u32 + 2 } else { (-k) as u32 + 1 };

    // Hot path (§Perf): the body fits a single machine word for every
    // engine-produced significand at n ≤ 32. Bit-identical to the u128
    // frame below (see round::tests::narrow_frame_matches_wide).
    if rl + ES + sfb <= 63 && sig <= u64::MAX as u128 {
        return encode_round_u64(n, sign, k, (scale & ((1 << ES) - 1)) as u64, sig as u64, sfb, sticky, rl);
    }

    // Fold fraction LSBs into sticky so the body fits the 128-bit frame.
    let mut frac = sig & mask128(sfb);
    let mut fb = sfb;
    let mut st = sticky;
    while rl + ES + fb > 128 {
        st |= frac & 1 != 0;
        frac >>= 1;
        fb -= 1;
    }

    // Build the unbounded body left-aligned in a 128-bit frame.
    let mut acc: u128 = 0;
    let mut pos: u32 = 128; // next free bit goes at pos-1
    let push = |acc: &mut u128, pos: &mut u32, val: u128, width: u32| {
        if width == 0 {
            return;
        }
        *pos -= width;
        *acc |= (val & mask128(width)) << *pos;
    };
    if k >= 0 {
        // k+1 ones then a terminating zero.
        push(&mut acc, &mut pos, mask128(k as u32 + 1), k as u32 + 1);
        push(&mut acc, &mut pos, 0, 1);
    } else {
        // -k zeros then a terminating one.
        push(&mut acc, &mut pos, 0, (-k) as u32);
        push(&mut acc, &mut pos, 1, 1);
    }
    push(&mut acc, &mut pos, e, ES);
    push(&mut acc, &mut pos, frac, fb);

    // Magnitude = top n-1 bits; everything below is guard/round/sticky.
    let mag_shift = 128 - (n - 1);
    let mut m = (acc >> mag_shift) as u64;
    let below = acc & mask128(mag_shift);
    let guard = below >> (mag_shift - 1) != 0;
    let rest = below & mask128(mag_shift - 1) != 0 || st;

    if guard && (rest || m & 1 == 1) {
        m += 1;
    }
    // Never round a non-zero real to zero or onto NaR.
    if m == 0 {
        m = 1;
    }
    if m > mask(n - 1) {
        m = mask(n - 1);
    }

    let bits = if sign { m.wrapping_neg() & mask(n) } else { m };
    Posit::from_bits(n, bits)
}

/// Single-word encoder core (rl + 2 + sfb ≤ 63).
#[allow(clippy::too_many_arguments)]
#[inline]
fn encode_round_u64(
    n: u32,
    sign: bool,
    k: i32,
    e: u64,
    sig: u64,
    sfb: u32,
    sticky: bool,
    rl: u32,
) -> Posit {
    // body = regime ‖ e ‖ frac, right-aligned
    let regime: u64 = if k >= 0 { (2 << (k as u32 + 1)) - 2 } else { 1 };
    let frac = sig & ((1u64 << sfb) - 1);
    let body = ((regime << ES) | e) << sfb | frac;
    let len = rl + ES + sfb;
    let mut m = if len >= n {
        // bits drop below the pattern: round on guard/rest/sticky
        let shift = len - (n - 1);
        let mut m = body >> shift;
        let guard = (body >> (shift - 1)) & 1 != 0;
        let rest = body & ((1u64 << (shift - 1)) - 1) != 0 || sticky;
        if guard && (rest || m & 1 == 1) {
            m += 1;
        }
        m
    } else {
        // short significand (e.g. after cancellation in addition): the
        // pattern is exact up to sticky, which lies below the guard —
        // never rounds up
        body << (n - 1 - len)
    };
    m = m.clamp(1, mask(n - 1));
    let bits = if sign { m.wrapping_neg() & mask(n) } else { m };
    Posit::from_bits(n, bits)
}

/// Encode an exactly-representable decoded value (used by round-trip tests
/// and by arithmetic whose significand is already at native width).
pub fn encode_exact(n: u32, sign: bool, scale: i32, sig: u64) -> Posit {
    encode_round(n, sign, scale, sig as u128, super::frac_bits(n), false)
}

#[inline]
const fn mask128(w: u32) -> u128 {
    if w >= 128 {
        u128::MAX
    } else {
        (1u128 << w) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::frac_bits;

    #[test]
    fn encode_one_and_two() {
        for n in [6u32, 8, 16, 32, 64] {
            let fb = frac_bits(n);
            assert_eq!(encode_exact(n, false, 0, 1 << fb), Posit::one(n));
            let two = encode_exact(n, false, 1, 1 << fb);
            assert_eq!(two.to_f64(), 2.0);
            assert_eq!(encode_exact(n, true, 0, 1 << fb), Posit::one(n).neg());
        }
    }

    #[test]
    fn saturation_to_maxpos_minpos() {
        for n in [8u32, 16, 32] {
            let fb = frac_bits(n);
            let huge = encode_round(n, false, 4 * (n as i32), 1 << fb, fb, false);
            assert_eq!(huge, Posit::maxpos(n));
            let tiny = encode_round(n, false, -4 * (n as i32), 1 << fb, fb, true);
            assert_eq!(tiny, Posit::minpos(n));
            let hugeneg = encode_round(n, true, 4 * (n as i32), 1 << fb, fb, false);
            assert_eq!(hugeneg, Posit::maxpos(n).neg());
        }
    }

    #[test]
    fn rounding_ties_to_even() {
        // Posit8: 1 + 1/16 has frac 0001|0 at 3 fraction bits: guard=1,
        // rest=0 -> tie -> round to even (stay at 1.0).
        let p = encode_round(8, false, 0, (1 << 4) | 1, 4, false);
        assert_eq!(p, Posit::one(8));
        // 1 + 3/16: frac 0011 -> guard=1, m odd -> round up to 1.25.
        let p = encode_round(8, false, 0, (1 << 4) | 3, 4, false);
        assert_eq!(p.to_f64(), 1.25);
        // 1 + 1/16 with sticky: no longer a tie -> round up to 1.125.
        let p = encode_round(8, false, 0, (1 << 4) | 1, 4, true);
        assert_eq!(p.to_f64(), 1.125);
    }

    #[test]
    fn rounding_position_follows_regime() {
        // The same significand rounds differently depending on the regime —
        // the Table III phenomenon. Posit10, sig = 1.111101 (6 fraction
        // bits), sticky set (remainder != 0).
        let sig = 0b1_111101u128;
        // scale T=5 (k=1,e=1): fraction field has 4 bits -> 1111|01(s) ->
        // guard=0 -> truncate to 1111. (Table III, example 1)
        let q1 = encode_round(10, false, 5, sig, 6, true);
        assert_eq!(q1.to_bits(), 0b0110011111);
        // scale T=9 (k=2,e=1): fraction field has 3 bits -> 111|101(s) ->
        // guard=1, rest!=0 -> increment: 111+1 carries into the exponent.
        // (Table III, example 2)
        let q2 = encode_round(10, false, 9, sig, 6, true);
        assert_eq!(q2.to_bits(), 0b0111010000);
    }

    #[test]
    fn no_real_rounds_to_nar_exhaustive_p8() {
        // Encode every (scale, sig) in a lattice and check the result is a
        // real pattern.
        for scale in -40..=40 {
            for frac in 0..8u128 {
                let p = encode_round(8, true, scale, (1 << 3) | frac, 3, false);
                assert!(!p.is_nar() && !p.is_zero());
            }
        }
    }

    #[test]
    fn wide_significand_folding() {
        // A 100-bit significand must fold into sticky without panicking and
        // round identically to its 60-bit prefix + sticky.
        let n = 16;
        let sig_small: u128 = (1 << 20) | 0x4_2187;
        let wide = (sig_small << 80) | 0x1234;
        let a = encode_round(n, false, -9, wide, 100, false);
        let b = encode_round(n, false, -9, sig_small, 20, true);
        assert_eq!(a, b);
    }
}

#[cfg(test)]
mod narrow_frame_tests {
    use super::*;
    use crate::testkit::Rng;

    /// The u64 fast frame must agree with the u128 frame on every input
    /// that qualifies for it.
    #[test]
    fn narrow_frame_matches_wide() {
        let mut rng = Rng::seeded(0xF4A);
        for _ in 0..200_000 {
            let n = rng.range_inclusive(6, 32) as u32;
            let sfb = rng.range_inclusive(crate::posit::frac_bits(n).max(1) as u64, 40) as u32;
            let scale = rng.range_i64(-(4 * n as i64), 4 * n as i64) as i32;
            let sig = (1u128 << sfb) | (rng.next_u64() as u128 & ((1u128 << sfb) - 1));
            let sticky = rng.chance(1, 2);
            let sign = rng.chance(1, 2);
            // compute through the public entry (fast path may trigger)
            let got = encode_round(n, sign, scale, sig, sfb, sticky);
            // force the wide frame by widening the significand beyond u64
            // (shift up by 60 with sticky-preserving zeros)
            let wide = encode_round(n, sign, scale, sig << 60, sfb + 60, sticky);
            assert_eq!(got, wide, "n={n} scale={scale} sfb={sfb}");
        }
    }
}
