//! Field extraction (decode) of posit patterns — Fig. 1 / Eq. (2) of the
//! paper.
//!
//! Decoding follows the *sign-magnitude* convention the paper adopts for
//! division (§III-C): a negative posit is two's-complemented first, then the
//! magnitude is decoded. (The alternative two's-complement decode of [14]
//! yields signed significands in [-2,-1)∪[1,2) and costs the recurrence an
//! extra iteration — implemented separately in `division::nrd` for the
//! comparison benchmark.)

use super::{frac_bits, mask, Posit, ES};

/// A decoded (non-special) posit: `(-1)^sign · 2^scale · sig/2^FB` with
/// `sig` normalized to `FB = frac_bits(n)` fraction bits plus the hidden 1,
/// i.e. `sig ∈ [2^FB, 2^(FB+1))` representing a significand in [1, 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decoded {
    pub sign: bool,
    /// Combined scale `4k + e`.
    pub scale: i32,
    /// Significand `1.f` as an integer with `frac_bits(n)` fraction bits.
    pub sig: u64,
    /// Width of the posit this came from.
    pub n: u32,
}

impl Decoded {
    /// Regime value `k = ⌊scale/4⌋` (arithmetic shift).
    #[inline]
    pub fn regime(&self) -> i32 {
        self.scale >> ES
    }

    /// Exponent field `e = scale mod 4`.
    #[inline]
    pub fn exponent(&self) -> u32 {
        (self.scale & ((1 << ES) - 1)) as u32
    }

    /// Fraction bits (below the hidden one).
    #[inline]
    pub fn fraction(&self) -> u64 {
        self.sig & mask(frac_bits(self.n))
    }

    /// Significand as a float in [1, 2).
    #[inline]
    pub fn sig_f64(&self) -> f64 {
        self.sig as f64 / (1u64 << frac_bits(self.n)) as f64
    }
}

/// Result of decoding: either a special value or fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unpacked {
    Zero,
    NaR,
    Real(Decoded),
}

impl Posit {
    /// Full decode with special-case detection.
    ///
    /// `#[inline]` (like [`Posit::decode`] and the encoder) so the
    /// width-monomorphized fast-tier kernels
    /// ([`crate::division::fastpath`]) can const-fold the shift/mask
    /// arithmetic on `n`.
    #[inline]
    pub fn unpack(self) -> Unpacked {
        if self.is_zero() {
            Unpacked::Zero
        } else if self.is_nar() {
            Unpacked::NaR
        } else {
            Unpacked::Real(self.decode())
        }
    }

    /// Decode a non-special posit into sign/scale/significand.
    ///
    /// Panics on zero/NaR (callers handle specials first — exactly like the
    /// hardware, where the special detector runs in parallel with decode).
    #[inline]
    pub fn decode(self) -> Decoded {
        assert!(!self.is_zero() && !self.is_nar(), "decode of special value");
        let n = self.width();
        let sign = self.sign_bit();
        // Sign-magnitude: two's complement negative patterns first
        // (branchless: xor with the extended sign + add the sign bit).
        let ext = 0u64.wrapping_sub(sign as u64);
        let magnitude = ((self.to_bits() ^ ext).wrapping_add(sign as u64)) & mask(n);

        // Body: the n-1 bits below the sign, left-aligned into a u64 so the
        // run-length count is width-independent.
        let body = (magnitude & mask(n - 1)) << (64 - (n - 1));
        let r0 = body >> 63 != 0;
        // Length of the run of identical leading bits (branchless invert).
        let run = (body ^ 0u64.wrapping_sub(r0 as u64)).leading_zeros().min(n - 1);
        let k: i32 = if r0 { run as i32 - 1 } else { -(run as i32) };

        // Bits past the run and its terminator (the terminator may be
        // missing when the run reaches the end of the word, e.g. maxpos).
        let consumed = (run + 1).min(n - 1);
        let rem = n - 1 - consumed; // bits available for exponent+fraction
        let tail = if rem == 0 { 0 } else { (body << consumed) >> (64 - rem) };

        // Exponent: up to ES bits from the top of the tail; if truncated,
        // the available bits are the MSBs of e (missing LSBs are zero).
        let eb = rem.min(ES);
        let e = if eb == 0 { 0 } else { (tail >> (rem - eb)) << (ES - eb) } as u32;

        // Fraction: whatever is left, aligned up to the worst-case width.
        let fb = rem - eb;
        let frac = (tail & mask(fb)) << (frac_bits(n) - fb);

        Decoded { sign, scale: 4 * k + e as i32, sig: (1u64 << frac_bits(n)) | frac, n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dec(n: u32, bits: u64) -> Decoded {
        Posit::from_bits(n, bits).decode()
    }

    #[test]
    fn decode_one() {
        for n in [6u32, 8, 10, 16, 32, 64] {
            let d = dec(n, 1u64 << (n - 2));
            assert_eq!(d.scale, 0);
            assert_eq!(d.sig, 1u64 << frac_bits(n));
            assert!(!d.sign);
        }
    }

    #[test]
    fn decode_maxpos_minpos() {
        for n in [8u32, 16, 32, 64] {
            let mx = dec(n, mask(n - 1));
            assert_eq!(mx.scale, 4 * (n as i32 - 2), "maxpos scale n={n}");
            assert_eq!(mx.sig, 1u64 << frac_bits(n));
            let mn = dec(n, 1);
            assert_eq!(mn.scale, -4 * (n as i32 - 2), "minpos scale n={n}");
            assert_eq!(mn.sig, 1u64 << frac_bits(n));
        }
    }

    #[test]
    fn decode_posit8_examples() {
        // Posit⟨8,2⟩: 0b01000001 = 1 + 1/4? body=1000001: regime=10 (k=0),
        // e=00, frac=001 of 3 bits -> sig = 1 + 1/8.
        let d = dec(8, 0b0100_0001);
        assert_eq!(d.scale, 0);
        assert_eq!(d.sig_f64(), 1.125);
        // 0b00110000: regime 01 (k=-1), e=10, f=000 -> 2^(-4+2)=0.25
        let d = dec(8, 0b0011_0000);
        assert_eq!(d.scale, -2);
        assert_eq!(d.sig_f64(), 1.0);
    }

    #[test]
    fn decode_negative_two() {
        // -2.0 in posit: 2.0 = 0b0100..0 with e=1? scale(2.0)=1:
        // pattern: sign 0, regime 10 (k=0), e=01, frac 0.
        for n in [8u32, 16, 32] {
            let two = Posit::from_bits(n, 0b01001 << (n - 5));
            assert_eq!(two.to_f64(), 2.0);
            let m2 = two.neg();
            let d = m2.decode();
            assert!(d.sign);
            assert_eq!(d.scale, 1);
            assert_eq!(d.sig, 1 << frac_bits(n));
        }
    }

    #[test]
    fn truncated_exponent_bits_are_msbs() {
        // n=8, pattern 0b0000_0101: body 0000101 -> run of 4 zeros, k=-4,
        // terminator 1, rem=2 bits "01" -> e = 0b01 << 0? eb=2 -> e=1.
        let d = dec(8, 0b0000_0101);
        assert_eq!(d.scale, -16 + 1);
        // n=8, 0b0000_0011: run of 5 zeros, k=-5, rem=1 bit "1" -> e=0b10=2.
        let d = dec(8, 0b0000_0011);
        assert_eq!(d.scale, -20 + 2);
    }

    #[test]
    fn decode_encode_roundtrip_exhaustive_small() {
        // Every real pattern decodes and re-encodes to itself (n = 6..12).
        for n in [6u32, 8, 10, 12] {
            for bits in 0..=mask(n) {
                let p = Posit::from_bits(n, bits);
                if p.is_zero() || p.is_nar() {
                    continue;
                }
                let d = p.decode();
                let back = crate::posit::round::encode_exact(n, d.sign, d.scale, d.sig);
                assert_eq!(back, p, "n={n} bits={bits:#b} decoded={d:?}");
            }
        }
    }

    #[test]
    fn decode_encode_roundtrip_random_wide() {
        let mut rng = crate::testkit::Rng::seeded(0xDEC0DE);
        for n in [16u32, 24, 32, 48, 64] {
            for _ in 0..20_000 {
                let bits = rng.next_u64() & mask(n);
                let p = Posit::from_bits(n, bits);
                if p.is_zero() || p.is_nar() {
                    continue;
                }
                let d = p.decode();
                let back = crate::posit::round::encode_exact(n, d.sign, d.scale, d.sig);
                assert_eq!(back, p, "n={n} bits={bits:#x}");
            }
        }
    }
}
