use posit_div::division::srt4_cs::Srt4Cs;
use posit_div::division::{Algorithm, DivEngine};
use posit_div::posit::frac_bits;
use posit_div::posit::{mask, Posit};
use posit_div::testkit::Rng;
use std::time::Instant;
fn main() {
    let mut rng = Rng::seeded(1);
    for n in [16u32, 32] {
        let pairs: Vec<(Posit, Posit)> = (0..4096).map(|_| {
            (Posit::from_bits(n, rng.next_u64() & mask(n)),
             Posit::from_bits(n, (rng.next_u64() & mask(n)) | 1))
        }).collect();
        let e = Algorithm::Srt4CsOfFr.engine();
        // warm
        for &(x, d) in &pairs { std::hint::black_box(e.divide(x, d).result); }
        let mut best = f64::MAX;
        for _ in 0..40 {
            let t0 = Instant::now();
            for &(x, d) in &pairs { std::hint::black_box(e.divide(x, d).result); }
            best = best.min(t0.elapsed().as_secs_f64() / pairs.len() as f64);
        }
        println!("Posit{n} srt4csoffr: {:.0} ns/div ({:.2} Mdiv/s)", best * 1e9, 1e-6 / best);

        // u128 reference recurrence (the pre-optimization path), fraction
        // stage only, for the §Perf before/after ablation
        let wide = Srt4Cs::with_otf_fr();
        let f = frac_bits(n);
        let sigs: Vec<(u64, u64)> = (0..4096)
            .map(|_| ((1 << f) | (rng.next_u64() & ((1 << f) - 1)), (1 << f) | (rng.next_u64() & ((1 << f) - 1))))
            .collect();
        for (name, use_wide) in [("u128 ref", true), ("u64 fast", false)] {
            let mut best = f64::MAX;
            for _ in 0..20 {
                let t0 = Instant::now();
                for &(x, d) in &sigs {
                    if use_wide {
                        std::hint::black_box(wide.frac_divide_wide_for_bench(n, x, d));
                    } else {
                        std::hint::black_box(wide.fraction_divide(n, x, d));
                    }
                }
                best = best.min(t0.elapsed().as_secs_f64() / sigs.len() as f64);
            }
            println!("  fraction stage ({name}): {:.0} ns", best * 1e9);
        }
    }
}
