//! Carry-save (redundant) arithmetic for the residual datapath.
//!
//! The paper's first optimization (§III-B1) keeps the partial remainder as
//! a sum/carry pair so each iteration's `rw(i) − d·q_{i+1}` is a single 3:2
//! compressor (O(1) depth) instead of a carry-propagate subtraction
//! (O(log n) depth). This module models the CS words exactly as the
//! hardware holds them: two's-complement words of a fixed datapath width,
//! wrapping modulo 2^W — any width shortfall would corrupt results and be
//! caught by the golden-model tests.
//!
//! It also implements the §III-B2 optimization: *sign and zero detection
//! lookahead* over a CS pair, without converting to conventional form —
//! the zero detector is the classic gate identity `a+b ≡ 0 (mod 2^W) ⇔
//! (a⊕b) = ((a∨b)≪1)`, and the sign detector is a Kogge–Stone carry
//! lookahead into the MSB. Both are verified against plain addition.

/// Mask with the low `w` bits set.
#[inline]
pub const fn wmask(w: u32) -> u128 {
    if w >= 128 {
        u128::MAX
    } else {
        (1u128 << w) - 1
    }
}

/// Sign-extend the low `w` bits of `v` to i128.
#[inline]
pub const fn sext(v: u128, w: u32) -> i128 {
    ((v << (128 - w)) as i128) >> (128 - w)
}

/// A carry-save pair: value = (s + c) mod 2^w, two's complement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsPair {
    pub s: u128,
    pub c: u128,
    pub w: u32,
}

impl CsPair {
    /// Non-redundant initial value (c = 0), e.g. `ws(0) = x/2, wc(0) = 0`.
    pub fn from_value(v: i128, w: u32) -> Self {
        CsPair { s: (v as u128) & wmask(w), c: 0, w }
    }

    /// 3:2 compress with a third addend and an injected carry-in bit.
    ///
    /// Computes `(s + c + add + cin) mod 2^w` in redundant form:
    /// `s' = s ⊕ c ⊕ add`, `c' = majority(s,c,add) ≪ 1 | cin`. The LSB of
    /// the shifted carry word is always free, which is where the hardware
    /// injects the +1 of a two's-complement subtraction.
    #[inline]
    pub fn csa(self, add: u128, cin: bool) -> Self {
        let m = wmask(self.w);
        let sum = self.s ^ self.c ^ (add & m);
        let maj = (self.s & self.c) | (self.s & add) | (self.c & add);
        CsPair { s: sum & m, c: ((maj << 1) | cin as u128) & m, w: self.w }
    }

    /// Left shift both words (the `r·w(i)` step), dropping overflow bits —
    /// exactly what the wired shift does in hardware.
    #[inline]
    pub fn shl(self, k: u32) -> Self {
        let m = wmask(self.w);
        CsPair { s: (self.s << k) & m, c: (self.c << k) & m, w: self.w }
    }

    /// Convert to conventional two's complement (the slow CPA the redundant
    /// representation avoids in the loop; used at termination).
    #[inline]
    pub fn resolve(self) -> i128 {
        sext(self.s.wrapping_add(self.c) & wmask(self.w), self.w)
    }

    /// Truncated estimate: `⌊s/2^drop⌋ + ⌊c/2^drop⌋` computed by a narrow
    /// `(w − drop)`-bit adder whose carry-out is discarded, exactly like
    /// the selection hardware: each word truncated *separately* (estimate
    /// error < 2·2^−t), slices added modulo `2^(w−drop)` and reinterpreted
    /// as two's complement. The wrap is lossless because the true shifted
    /// residual always fits the slice range.
    #[inline]
    pub fn estimate(self, drop: u32) -> i64 {
        let bits = self.w - drop;
        debug_assert!(bits <= 63, "estimate slice wider than i64");
        let sum = (self.s >> drop).wrapping_add(self.c >> drop);
        sext(sum & wmask(bits), bits) as i64
    }

    /// Zero detection without carry propagation (§III-B2):
    /// `s + c ≡ 0 (mod 2^w)` ⇔ `(s ⊕ c) = ((s ∨ c) ≪ 1)` (within w bits).
    #[inline]
    pub fn is_zero_lookahead(self) -> bool {
        let m = wmask(self.w);
        (self.s ^ self.c) == ((self.s | self.c) << 1) & m
    }

    /// Sign detection via Kogge–Stone carry lookahead into the MSB — the
    /// log-depth network the FR optimization builds instead of a full CPA.
    pub fn sign_lookahead(self) -> bool {
        let w = self.w;
        let m = wmask(w);
        let a = self.s & m;
        let b = self.c & m;
        // generate/propagate per bit
        let mut g = a & b;
        let mut p = a ^ b;
        // parallel-prefix: after ⌈log2 w⌉ doublings, g holds the carry
        // *out of* each position i (into position i+1).
        let mut span = 1;
        while span < w {
            g |= p & (g << span);
            p &= p << span;
            span <<= 1;
        }
        // carry into MSB = carry out of bit w-2
        let carry_in_msb = (g >> (w - 2)) & 1;
        let msb = ((a ^ b) >> (w - 1)) & 1;
        (msb ^ carry_in_msb) & 1 == 1
    }

    /// Zero detection of `s + c + add` (three-input): one CSA level feeds
    /// the two-input lookahead. Used for the sticky bit of a corrected
    /// remainder (`w(It) + d`).
    #[inline]
    pub fn is_zero_with_addend(self, add: u128) -> bool {
        self.csa(add, false).is_zero_lookahead()
    }
}


/// Narrow (u64) carry-save pair for datapaths that fit a machine word
/// (width ≤ 64 covers every format up to Posit62 on the radix-4 path) —
/// the release-mode hot path; semantics identical to [`CsPair`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsPair64 {
    pub s: u64,
    pub c: u64,
    pub w: u32,
}

#[inline]
pub const fn wmask64(w: u32) -> u64 {
    if w >= 64 {
        u64::MAX
    } else {
        (1u64 << w) - 1
    }
}

#[inline]
pub const fn sext64(v: u64, w: u32) -> i64 {
    ((v << (64 - w)) as i64) >> (64 - w)
}

impl CsPair64 {
    #[inline]
    pub fn from_value(v: i64, w: u32) -> Self {
        CsPair64 { s: (v as u64) & wmask64(w), c: 0, w }
    }

    #[inline]
    pub fn csa(self, add: u64, cin: bool) -> Self {
        let m = wmask64(self.w);
        let sum = self.s ^ self.c ^ (add & m);
        let maj = (self.s & self.c) | (self.s & add) | (self.c & add);
        CsPair64 { s: sum & m, c: ((maj << 1) | cin as u64) & m, w: self.w }
    }

    #[inline]
    pub fn shl(self, k: u32) -> Self {
        let m = wmask64(self.w);
        CsPair64 { s: (self.s << k) & m, c: (self.c << k) & m, w: self.w }
    }

    #[inline]
    pub fn resolve(self) -> i64 {
        sext64(self.s.wrapping_add(self.c) & wmask64(self.w), self.w)
    }

    #[inline]
    pub fn estimate(self, drop: u32) -> i64 {
        let bits = self.w - drop;
        let sum = (self.s >> drop).wrapping_add(self.c >> drop);
        sext64(sum & wmask64(bits), bits)
    }

    #[inline]
    pub fn is_zero_lookahead(self) -> bool {
        let m = wmask64(self.w);
        (self.s ^ self.c) == ((self.s | self.c) << 1) & m
    }

    #[inline]
    pub fn sign_lookahead(self) -> bool {
        // value-identical to the wide network (verified against resolve)
        self.resolve() < 0
    }

    #[inline]
    pub fn is_zero_with_addend(self, add: u64) -> bool {
        self.csa(add, false).is_zero_lookahead()
    }

    /// Widen to the reference representation (tests).
    pub fn widen(self) -> CsPair {
        CsPair { s: self.s as u128, c: self.c as u128, w: self.w }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn rand_pair(rng: &mut Rng, w: u32) -> CsPair {
        CsPair {
            s: (rng.next_u64() as u128 | (rng.next_u64() as u128) << 64) & wmask(w),
            c: (rng.next_u64() as u128 | (rng.next_u64() as u128) << 64) & wmask(w),
            w,
        }
    }

    #[test]
    fn csa_preserves_value() {
        let mut rng = Rng::seeded(0xC5A);
        for _ in 0..50_000 {
            let w = rng.range_inclusive(8, 100) as u32;
            let p = rand_pair(&mut rng, w);
            let add = (rng.next_u64() as u128) & wmask(w);
            let cin = rng.chance(1, 2);
            let q = p.csa(add, cin);
            let want = (p.s.wrapping_add(p.c).wrapping_add(add).wrapping_add(cin as u128))
                & wmask(w);
            let got = q.s.wrapping_add(q.c) & wmask(w);
            assert_eq!(got, want, "w={w} p={p:?} add={add:#x} cin={cin}");
        }
    }

    #[test]
    fn shl_matches_value_shift_mod_2w() {
        let mut rng = Rng::seeded(0x511);
        for _ in 0..20_000 {
            let w = rng.range_inclusive(8, 100) as u32;
            let p = rand_pair(&mut rng, w);
            let k = rng.range_inclusive(0, 3) as u32;
            let got = p.shl(k).s.wrapping_add(p.shl(k).c) & wmask(w);
            let want = (p.s.wrapping_add(p.c) << k) & wmask(w);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn zero_lookahead_equals_true_zero() {
        let mut rng = Rng::seeded(0x0);
        for _ in 0..100_000 {
            let w = rng.range_inclusive(4, 100) as u32;
            // Bias toward actual zeros: make c = -s half the time.
            let mut p = rand_pair(&mut rng, w);
            if rng.chance(1, 2) {
                p.c = (p.s.wrapping_neg()) & wmask(w);
            }
            assert_eq!(
                p.is_zero_lookahead(),
                p.s.wrapping_add(p.c) & wmask(w) == 0,
                "{p:?}"
            );
        }
    }

    #[test]
    fn sign_lookahead_equals_true_sign() {
        let mut rng = Rng::seeded(0x51);
        for _ in 0..100_000 {
            let w = rng.range_inclusive(4, 100) as u32;
            let p = rand_pair(&mut rng, w);
            assert_eq!(p.sign_lookahead(), p.resolve() < 0, "{p:?}");
        }
    }

    #[test]
    fn zero_with_addend() {
        let mut rng = Rng::seeded(0x3);
        for _ in 0..50_000 {
            let w = rng.range_inclusive(4, 90) as u32;
            let mut p = rand_pair(&mut rng, w);
            let add = (rng.next_u64() as u128) & wmask(w);
            if rng.chance(1, 2) {
                // force s+c+add == 0
                p.c = (p.s.wrapping_add(add)).wrapping_neg() & wmask(w);
            }
            let want = p.s.wrapping_add(p.c).wrapping_add(add) & wmask(w) == 0;
            assert_eq!(p.is_zero_with_addend(add), want);
        }
    }

    #[test]
    fn estimate_is_sum_of_floors_mod_slice() {
        let mut rng = Rng::seeded(0xE5);
        for _ in 0..50_000 {
            let w = rng.range_inclusive(10, 100) as u32;
            let p = rand_pair(&mut rng, w);
            let drop = rng.range_inclusive(w.saturating_sub(60).max(1) as u64, (w - 2) as u64) as u32;
            let bits = w - drop;
            let full = (sext(p.s, w) >> drop) + (sext(p.c, w) >> drop);
            let want = sext((full as u128) & wmask(bits), bits);
            assert_eq!(p.estimate(drop) as i128, want);
        }
    }

    #[test]
    fn estimate_error_bound() {
        // ⌊s⌋ + ⌊c⌋ ≤ s + c < ⌊s⌋ + ⌊c⌋ + 2 (in units of 2^drop): the
        // CS-truncation error bound every selection function relies on.
        let mut rng = Rng::seeded(0xEE);
        for _ in 0..50_000 {
            let w = 40;
            let p = rand_pair(&mut rng, w);
            let drop = 10;
            let est = p.estimate(drop) as i128;
            let true_val = p.resolve();
            let lo = est << drop;
            // value may wrap mod 2^w; compare modulo
            let diff = (true_val - lo) & wmask(w) as i128;
            assert!(diff < (2 << drop), "err {diff} too large");
        }
    }
}

#[cfg(test)]
mod tests64 {
    use super::*;
    use crate::testkit::Rng;

    #[test]
    fn narrow_pair_equals_wide_pair() {
        let mut rng = Rng::seeded(0x64);
        for _ in 0..100_000 {
            let w = rng.range_inclusive(8, 64) as u32;
            let p64 = CsPair64 {
                s: rng.next_u64() & wmask64(w),
                c: rng.next_u64() & wmask64(w),
                w,
            };
            let p = p64.widen();
            let add = rng.next_u64() & wmask64(w);
            let cin = rng.chance(1, 2);
            assert_eq!(p64.csa(add, cin).widen(), p.csa(add as u128, cin));
            assert_eq!(p64.shl(2).widen(), p.shl(2));
            assert_eq!(p64.resolve() as i128, p.resolve());
            let drop = rng.range_inclusive(2, (w - 2).min(60) as u64) as u32;
            assert_eq!(p64.estimate(drop), p.estimate(drop));
            assert_eq!(p64.is_zero_lookahead(), p.is_zero_lookahead());
            assert_eq!(p64.sign_lookahead(), p.sign_lookahead());
            assert_eq!(p64.is_zero_with_addend(add), p.is_zero_with_addend(add as u128));
        }
    }
}
