//! Table II iteration/latency checks plus per-radix division rates —
//! thin shim over [`posit_div::bench::suites`], where the suite body
//! lives so the same code runs under `cargo bench --bench table2_iterations`
//! and `posit-div bench table2_iterations` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("table2_iterations");
}
