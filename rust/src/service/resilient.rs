//! Fault-tolerant serving client: one logical request stream fanned
//! over N endpoints with per-endpoint circuit breakers, bounded retry
//! with seeded backoff jitter, and duplicate-free completion.
//!
//! The [`ResilientClient`] sits where a plain [`ServiceClient`] is too
//! brittle: endpoints restart, networks drop frames, servers brown out.
//! Its contract (normative; `docs/SERVING.md` § Failure semantics):
//!
//! * **Safe replay.** Every op is pure — same operands, same bits — so
//!   retrying a request whose fate is unknown (timeout, dead socket) is
//!   always correct. What must *not* happen is one logical request
//!   counting twice: replies are matched by wire id and replies for
//!   already-settled ids are discarded
//!   ([`ServiceClient::read_reply_for`]), and a retry never reuses the
//!   connection whose reply-stream state is unknown — the poisoned
//!   connection is dropped whole, taking any late original reply with
//!   it. Zero duplicate completions, by construction.
//! * **Circuit breaking.** Per endpoint, three states: `Closed` (normal;
//!   consecutive transport failures count up), `Open` (after
//!   [`BreakerConfig::failure_threshold`] failures — traffic avoids the
//!   endpoint until [`BreakerConfig::open_cooldown`] passes), `HalfOpen`
//!   (one probe request; success closes the breaker, failure re-opens
//!   it). A request only fails over, it never waits for a cooldown while
//!   another endpoint is healthy.
//! * **Bounded retry.** At most [`RetryPolicy::max_retries`] retries per
//!   logical request, exponential backoff from
//!   [`RetryPolicy::base_backoff`] capped at
//!   [`RetryPolicy::max_backoff`], jitter drawn from a seeded
//!   [`Rng`] — test runs with equal seeds back off identically.
//! * **Typed, not retried.** Request-shape errors (width mismatch,
//!   unsupported op/width) fail fast: retrying cannot fix them.
//!   [`PositError::ServiceOverloaded`] and
//!   [`PositError::DeadlineExceeded`] *are* retried (the next attempt
//!   restarts the deadline budget server-side) but counted separately —
//!   they are the server protecting itself, not the network failing.

use std::net::SocketAddr;
use std::thread;
use std::time::{Duration, Instant};

use super::net::{ConnectOptions, ServiceClient};
use crate::error::{PositError, Result};
use crate::posit::Posit;
use crate::testkit::Rng;
use crate::unit::{Accuracy, OpRequest};

/// Retry budget and backoff shape for one logical request.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (so `max_retries + 1` attempts
    /// total).
    pub max_retries: u32,
    /// First backoff; doubles each retry.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Seeds the jitter stream — equal seeds, equal backoff schedules.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 8,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(500),
            seed: 0x5EED,
        }
    }
}

/// Per-endpoint circuit-breaker tuning.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive transport failures that open the breaker.
    pub failure_threshold: u32,
    /// How long an open breaker rejects traffic before allowing one
    /// half-open probe.
    pub open_cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { failure_threshold: 3, open_cooldown: Duration::from_millis(250) }
    }
}

/// Circuit-breaker state of one endpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Breaker {
    /// Serving; `fails` consecutive transport failures so far.
    Closed { fails: u32 },
    /// Not serving until the cooldown instant passes.
    Open { until: Instant },
    /// One probe request in flight decides open vs closed.
    HalfOpen,
}

struct Endpoint {
    addr: SocketAddr,
    conn: Option<ServiceClient>,
    breaker: Breaker,
}

/// Aggregate counters of one client's lifetime (see
/// [`ResilientClient::report`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ResilientReport {
    /// Logical requests offered via `run_op`/`run_requests`.
    pub offered: u64,
    /// Logical requests that returned `Ok`.
    pub completed: u64,
    /// Logical requests that exhausted their retry budget (or hit a
    /// non-retryable error).
    pub failed: u64,
    /// Retry attempts (beyond each request's first attempt).
    pub retries: u64,
    /// Fresh connections established (first connects and reconnects).
    pub connects: u64,
    /// Closed→Open and HalfOpen→Open breaker transitions.
    pub breaker_opens: u64,
    /// Replies for already-settled ids discarded by the dedup layer —
    /// duplicates that were *seen and suppressed*, never surfaced.
    pub duplicates_discarded: u64,
    /// Replies flagged brown-out-degraded by the server.
    pub degraded: u64,
    /// Retries caused by [`PositError::ServiceOverloaded`].
    pub shed_retries: u64,
    /// Retries caused by [`PositError::DeadlineExceeded`].
    pub deadline_retries: u64,
    /// Sampled completions that disagreed with [`OpRequest::golden`]
    /// beyond their accuracy budget ([`ResilientClient::run_requests`]).
    pub verify_failures: u64,
}

impl ResilientReport {
    pub fn summary(&self) -> String {
        format!(
            "offered={} completed={} failed={} retries={} connects={} breaker_opens={} \
             duplicates_discarded={} degraded={} shed_retries={} deadline_retries={} \
             verify_failures={}",
            self.offered,
            self.completed,
            self.failed,
            self.retries,
            self.connects,
            self.breaker_opens,
            self.duplicates_discarded,
            self.degraded,
            self.shed_retries,
            self.deadline_retries,
            self.verify_failures,
        )
    }
}

/// A client over N interchangeable endpoints (every endpoint serves the
/// same width and the same pure ops). Not thread-safe, like the
/// [`ServiceClient`] it wraps — one per driver thread.
pub struct ResilientClient {
    n: u32,
    endpoints: Vec<Endpoint>,
    policy: RetryPolicy,
    breaker_cfg: BreakerConfig,
    opts: ConnectOptions,
    rng: Rng,
    cursor: usize,
    stats: ResilientReport,
}

impl ResilientClient {
    /// Build a client over `endpoints` (at least one) at posit width
    /// `n`. Connections are opened lazily, per endpoint, on first use —
    /// a dead endpoint costs nothing until traffic routes at it.
    pub fn new(
        endpoints: &[SocketAddr],
        n: u32,
        policy: RetryPolicy,
        breaker: BreakerConfig,
        opts: ConnectOptions,
    ) -> Result<ResilientClient> {
        if endpoints.is_empty() {
            return Err(PositError::Execution {
                detail: "resilient client needs at least one endpoint".into(),
            });
        }
        Ok(ResilientClient {
            n,
            endpoints: endpoints
                .iter()
                .map(|&addr| Endpoint { addr, conn: None, breaker: Breaker::Closed { fails: 0 } })
                .collect(),
            policy,
            breaker_cfg: breaker,
            opts,
            rng: Rng::seeded(policy.seed),
            cursor: 0,
            stats: ResilientReport::default(),
        })
    }

    /// Lifetime counters so far.
    pub fn report(&self) -> ResilientReport {
        let mut r = self.stats;
        // live connections still hold their dedup/degraded tallies
        for ep in &self.endpoints {
            if let Some(c) = &ep.conn {
                r.duplicates_discarded += c.stale_replies();
                r.degraded += c.degraded_replies();
            }
        }
        r
    }

    /// Endpoints currently breaker-open.
    pub fn open_breakers(&self) -> usize {
        self.endpoints
            .iter()
            .filter(|e| matches!(e.breaker, Breaker::Open { .. }))
            .count()
    }

    /// Can this error be fixed by trying again (possibly elsewhere)?
    /// Transport faults and server self-protection are retryable;
    /// request-shape errors are not.
    fn retryable(e: &PositError) -> bool {
        matches!(
            e,
            PositError::Timeout { .. }
                | PositError::Execution { .. }
                | PositError::Protocol { .. }
                | PositError::ServiceStopped
                | PositError::ServiceOverloaded { .. }
                | PositError::DeadlineExceeded { .. }
        )
    }

    /// One logical request: route, retry within policy, never complete
    /// twice. The error of the last attempt surfaces when the budget is
    /// exhausted.
    pub fn run_op(&mut self, req: &OpRequest) -> Result<Posit> {
        self.stats.offered += 1;
        let mut attempt = 0u32;
        loop {
            match self.try_once(req) {
                Ok(p) => {
                    self.stats.completed += 1;
                    return Ok(p);
                }
                Err(e) if !Self::retryable(&e) => {
                    self.stats.failed += 1;
                    return Err(e);
                }
                Err(e) => {
                    match e {
                        PositError::ServiceOverloaded { .. } => self.stats.shed_retries += 1,
                        PositError::DeadlineExceeded { .. } => self.stats.deadline_retries += 1,
                        _ => {}
                    }
                    if attempt >= self.policy.max_retries {
                        self.stats.failed += 1;
                        return Err(e);
                    }
                    attempt += 1;
                    self.stats.retries += 1;
                    self.backoff(attempt);
                }
            }
        }
    }

    /// Exponential backoff with seeded jitter: `base · 2^(attempt-1)`
    /// capped at `max_backoff`, then jittered to 50–100% of that so
    /// retry storms decorrelate — deterministically, per seed.
    fn backoff(&mut self, attempt: u32) {
        let exp = self
            .policy
            .base_backoff
            .saturating_mul(1u32 << attempt.saturating_sub(1).min(20))
            .min(self.policy.max_backoff);
        let micros = exp.as_micros().min(u128::from(u64::MAX)) as u64;
        if micros == 0 {
            return;
        }
        let jittered = micros / 2 + self.rng.below(micros / 2 + 1);
        thread::sleep(Duration::from_micros(jittered));
    }

    /// Pick the next endpoint the breaker allows, round-robin from the
    /// cursor. Open breakers past their cooldown become half-open (one
    /// probe). If *every* breaker is open and cooling, sleep out the
    /// nearest cooldown — progress beats failing fast when there is
    /// nowhere to fail over to.
    fn pick(&mut self) -> usize {
        loop {
            let k = self.endpoints.len();
            for off in 0..k {
                let i = (self.cursor + off) % k;
                match self.endpoints[i].breaker {
                    Breaker::Closed { .. } | Breaker::HalfOpen => {
                        self.cursor = (i + 1) % k;
                        return i;
                    }
                    Breaker::Open { until } => {
                        if Instant::now() >= until {
                            self.endpoints[i].breaker = Breaker::HalfOpen;
                            self.cursor = (i + 1) % k;
                            return i;
                        }
                    }
                }
            }
            let nearest = self
                .endpoints
                .iter()
                .filter_map(|e| match e.breaker {
                    Breaker::Open { until } => Some(until),
                    _ => None,
                })
                .min()
                .expect("all endpoints open implies an open cooldown");
            thread::sleep(nearest.saturating_duration_since(Instant::now()));
        }
    }

    /// A transport success closes the endpoint's breaker.
    fn on_success(&mut self, i: usize) {
        self.endpoints[i].breaker = Breaker::Closed { fails: 0 };
    }

    /// A transport failure poisons the endpoint's connection (dropping
    /// it, and with it any in-flight reply whose fate is unknown) and
    /// advances the breaker.
    fn on_transport_failure(&mut self, i: usize) {
        self.poison(i);
        let cfg = self.breaker_cfg;
        let ep = &mut self.endpoints[i];
        ep.breaker = match ep.breaker {
            Breaker::Closed { fails } if fails + 1 < cfg.failure_threshold => {
                Breaker::Closed { fails: fails + 1 }
            }
            Breaker::Closed { .. } | Breaker::HalfOpen => {
                self.stats.breaker_opens += 1;
                Breaker::Open { until: Instant::now() + cfg.open_cooldown }
            }
            open @ Breaker::Open { .. } => open,
        };
    }

    /// Drop an endpoint's connection, folding its dedup/degraded
    /// counters into the lifetime stats first.
    fn poison(&mut self, i: usize) {
        if let Some(c) = self.endpoints[i].conn.take() {
            self.stats.duplicates_discarded += c.stale_replies();
            self.stats.degraded += c.degraded_replies();
        }
    }

    fn try_once(&mut self, req: &OpRequest) -> Result<Posit> {
        let i = self.pick();
        if self.endpoints[i].conn.is_none() {
            match ServiceClient::connect_with(self.endpoints[i].addr, self.n, self.opts) {
                Ok(c) => {
                    self.stats.connects += 1;
                    self.endpoints[i].conn = Some(c);
                }
                Err(e) => {
                    self.on_transport_failure(i);
                    return Err(e);
                }
            }
        }
        let conn = self.endpoints[i].conn.as_mut().expect("connected above");
        let id = match conn.send_request(req) {
            Ok(id) => id,
            Err(e) => {
                self.on_transport_failure(i);
                return Err(e);
            }
        };
        match conn.read_reply_for(id) {
            // transport-level failure: the reply stream is unknown,
            // poison the whole connection
            Err(e) => {
                self.on_transport_failure(i);
                Err(e)
            }
            // per-request server answer: the connection is healthy
            // (it just carried a well-formed reply), win or lose
            Ok(result) => {
                self.on_success(i);
                result
            }
        }
    }

    /// Drive a request list through [`ResilientClient::run_op`],
    /// verifying every `verify_every`-th completion (0 = never) against
    /// [`OpRequest::golden`] within its accuracy budget. Returns the
    /// lifetime report (including prior traffic on this client).
    pub fn run_requests(&mut self, reqs: &[OpRequest], verify_every: usize) -> ResilientReport {
        for (i, req) in reqs.iter().enumerate() {
            let verify = verify_every != 0 && i % verify_every == 0;
            match self.run_op(req) {
                Ok(p) => {
                    if verify {
                        let tol = match req.accuracy() {
                            Accuracy::Exact => 0u64,
                            Accuracy::Ulp(k) => u64::from(k),
                        };
                        // a degraded reply may stretch to its kernel's
                        // declared bound; widen to the loosest registered
                        // contract rather than miscounting it
                        let declared =
                            req.op.approx_spec(self.n).map_or(0, |s| s.max_ulp);
                        if p.ulp_distance(req.golden()) > tol.max(declared) {
                            self.stats.verify_failures += 1;
                        }
                    }
                }
                Err(_) => {} // already counted in failed
            }
        }
        self.report()
    }

    /// Drop every live connection (the server sees EOF and reaps it);
    /// breaker state and lifetime stats survive.
    pub fn close_connections(&mut self) {
        for i in 0..self.endpoints.len() {
            self.poison(i);
        }
    }

    /// Ask every reachable endpoint's server process to shut down
    /// (best-effort; used by CLI drains).
    pub fn shutdown_endpoints(&mut self) {
        for i in 0..self.endpoints.len() {
            self.poison(i);
            let addr = self.endpoints[i].addr;
            if let Ok(c) = ServiceClient::connect_with(addr, self.n, self.opts) {
                let _ = c.shutdown_server();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Breaker state machine: threshold consecutive failures open it,
    /// cooldown expiry half-opens it, a probe success closes it, a probe
    /// failure re-opens it.
    #[test]
    fn breaker_transitions() {
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let breaker = BreakerConfig {
            failure_threshold: 2,
            open_cooldown: Duration::from_millis(50),
        };
        let policy = RetryPolicy {
            max_retries: 0,
            base_backoff: Duration::from_micros(100),
            max_backoff: Duration::from_millis(1),
            seed: 1,
        };
        let opts = ConnectOptions {
            connect_timeout: Some(Duration::from_millis(200)),
            read_timeout: Some(Duration::from_millis(200)),
        };
        let mut rc = ResilientClient::new(&[dead], 16, policy, breaker, opts).unwrap();
        assert!(ResilientClient::new(&[], 16, policy, breaker, opts).is_err());

        let req = OpRequest::sqrt(Posit::one(16));
        // two failed attempts (threshold) open the breaker exactly once
        assert!(rc.run_op(&req).is_err());
        assert_eq!(rc.open_breakers(), 0);
        assert!(rc.run_op(&req).is_err());
        assert_eq!(rc.open_breakers(), 1);
        assert_eq!(rc.report().breaker_opens, 1);

        // after the cooldown the next attempt is a half-open probe; its
        // failure re-opens (second open transition)
        thread::sleep(Duration::from_millis(60));
        assert!(rc.run_op(&req).is_err());
        assert_eq!(rc.open_breakers(), 1);
        assert_eq!(rc.report().breaker_opens, 2);
        let r = rc.report();
        assert_eq!(r.offered, 3);
        assert_eq!(r.failed, 3);
        assert_eq!(r.completed, 0);
    }

    /// Request-shape errors must fail fast, not burn the retry budget.
    #[test]
    fn non_retryable_errors_fail_fast() {
        assert!(!ResilientClient::retryable(&PositError::WidthMismatch {
            expected: 16,
            got: 32
        }));
        assert!(!ResilientClient::retryable(&PositError::UnsupportedApprox {
            op: "add",
            n: 16
        }));
        assert!(ResilientClient::retryable(&PositError::Timeout {
            what: "socket read".into(),
            after: Duration::from_millis(1),
        }));
        assert!(ResilientClient::retryable(&PositError::ServiceOverloaded {
            shard: 0,
            inflight: 1,
            capacity: 1,
        }));
        assert!(ResilientClient::retryable(&PositError::DeadlineExceeded {
            deadline_ms: 5,
            waited_ms: 10,
        }));
    }

    /// Backoff is deterministic per seed and bounded by the ceiling.
    #[test]
    fn backoff_is_seeded_and_bounded() {
        let draws = |seed: u64| -> Vec<u64> {
            let mut rng = Rng::seeded(seed);
            (0..8).map(|_| rng.below(1000)).collect()
        };
        assert_eq!(draws(7), draws(7));
        assert_ne!(draws(7), draws(8));
        // the exponential cap: by attempt 20+ the shift saturates
        let policy = RetryPolicy::default();
        let exp = policy.base_backoff.saturating_mul(1u32 << 20).min(policy.max_backoff);
        assert_eq!(exp, policy.max_backoff);
    }
}
