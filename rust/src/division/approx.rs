//! Approx-tier kernels: bounded-error serving datapaths with declared
//! ulp contracts.
//!
//! The Fast tier ([`super::fastpath`]) is bit-identical to the Table IV
//! engines by construction; this module deliberately is not. Following
//! the approximate posit multiply-divide unit of arXiv:2605.24665 and the
//! fixed-posit formats of arXiv:2104.04763, each kernel here trades a
//! *bounded* amount of accuracy for a shorter, fully branch-free lane
//! body:
//!
//! * **division** — a 256-entry reciprocal seed table (12-bit entries,
//!   indexed by the top 8 divisor fraction bits) refined by a single
//!   Newton–Raphson step in Q30, then one multiply — no long division,
//!   no per-bit recurrence;
//! * **square root** — a 384-entry Q30 reciprocal-square-root seed table
//!   over the radicand range `[1,4)` plus one NR step and a final
//!   multiply — no integer-square-root iteration;
//! * **multiplication** — a truncated-fraction multiply keeping the top
//!   [`MUL_KEEP`] significand bits per operand (narrower widths are
//!   untouched and therefore exact), dropped bits folded into sticky.
//!
//! The lane body also applies a *fixed-regime clamp* (the fixed-posit
//! device): the result scale is clamped branch-free to
//! `±max_scale(n)` before encoding, so the regime range is bounded by
//! arithmetic rather than control flow and the lane kernel is
//! straight-line from decode to [`encode_round`].
//!
//! **Contract.** Every `(op, width)` kernel is registered in [`spec`]
//! with a declared worst-case error bound ([`ApproxSpec::max_ulp`],
//! measured against the correctly-rounded golden references). The bound
//! is machine-checked: exhaustively over all operand pairs at Posit8
//! (`tests/p8_exhaustive.rs`) and by seeded sweeps at Posit16/Posit32
//! (this module's tests). Special patterns (zero, NaR, negative
//! radicand) bypass the arithmetic entirely through the *same* special
//! pre-pass as the Fast tier and are therefore bit-exact in all modes.
//!
//! The serving surface is [`crate::unit::ExecTier::Approx`]; requests
//! opt in per call via `Accuracy::Ulp(k)` and are routed here only when
//! a registered spec satisfies `max_ulp <= k`.

use std::sync::OnceLock;

use crate::posit::{frac_bits, mask, max_scale, round::encode_round, sig_bits, Posit};

use super::fastpath::{special, Kind};

/// Widths with registered approx kernels. The kernels hold every
/// intermediate in a `u64` (seeds are Q30, products stay below 2^62),
/// which caps the supported width at 32 bits; 8/16/32 are the
/// monomorphized serving widths.
pub const WIDTHS: [u32; 3] = [8, 16, 32];

/// Significand bits kept per operand by the truncated-fraction multiply.
/// Chosen so the lane multiply is at most 36×36 bits; widths whose full
/// significand already fits (Posit8, Posit16) are not truncated and the
/// kernel is exact there (the declared bound still applies).
pub const MUL_KEEP: u32 = 18;

/// A registered approx kernel's contract: the op it serves, the posit
/// width, and the declared worst-case error in ulps against the
/// correctly-rounded exact result. The bounds are fixed constants —
/// measured exhaustively at Posit8 and by directed + random sweeps at
/// Posit16/Posit32, then declared with at least 2× headroom — and the
/// test gates assert observed ≤ declared.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ApproxSpec {
    /// Op kind the kernel serves (`Div`, `Sqrt` or `Mul`).
    pub kind: Kind,
    /// Posit width the bound is declared at.
    pub n: u32,
    /// Declared worst-case |result − exact| in ulps (pattern distance).
    pub max_ulp: u64,
}

/// The kernel registry: `Some(spec)` iff an approx kernel exists for
/// `(kind, n)`. Routing (`Accuracy::Ulp(k)`) admits a request here only
/// when `spec.max_ulp <= k`.
pub fn spec(kind: Kind, n: u32) -> Option<ApproxSpec> {
    let max_ulp = match (kind, n) {
        // div: seed (≤2^-8.8 rel) + one NR step → ≤ ~2^-17.5 rel error.
        (Kind::Div, 8) => 2,
        (Kind::Div, 16) => 4,
        (Kind::Div, 32) => 4096,
        // mul: exact below MUL_KEEP significand bits, truncated at P32.
        (Kind::Mul, 8) => 1,
        (Kind::Mul, 16) => 1,
        (Kind::Mul, 32) => 8192,
        // sqrt: rsqrt seed + one NR step, error ~1.5× the seed² term.
        (Kind::Sqrt, 8) => 1,
        (Kind::Sqrt, 16) => 4,
        (Kind::Sqrt, 32) => 2048,
        _ => return None,
    };
    Some(ApproxSpec { kind, n, max_ulp })
}

/// Branch-free fixed-regime clamp (the fixed-posit device): bound the
/// result scale to the representable regime range by arithmetic min/max
/// instead of letting the encoder's saturation branches fire. Identical
/// results (the encoder saturates to the same maxpos/minpos), but the
/// lane body stays straight-line.
#[inline(always)]
fn clamp_scale(n: u32, scale: i32) -> i32 {
    let ms = max_scale(n);
    scale.clamp(-ms, ms)
}

/// Round-half-up fixed-point reciprocal: `⌊(2^k + den/2) / den⌋` — the
/// shared constructor for every reciprocal-style seed table (the Q12 and
/// Q30 LUTs below and the exhaustive Posit16 reciprocal table in
/// [`super::p16_tables`]). `2^k + den/2` must fit a `u64`.
#[inline]
pub(crate) fn fixed_recip(k: u32, den: u64) -> u64 {
    ((1u64 << k) + den / 2) / den
}

/// 256-entry reciprocal seed table: entry `i` is `2^12/d` rounded, for
/// `d` the midpoint of `[1 + i/256, 1 + (i+1)/256)`. Values lie in
/// `(2^11, 2^12)`. Integer-only construction (no floats in any kernel).
fn recip_lut() -> &'static [u32; 256] {
    static LUT: OnceLock<[u32; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            // 2^12 · 2/(2·(256+i)+1), i.e. 1/midpoint in Q12, rounded.
            let den = 513 + 2 * i as u64;
            *slot = fixed_recip(21, den) as u32;
        }
        t
    })
}

/// 384-entry reciprocal-square-root seed table over the radicand range
/// `[1, 4)`: entry `i` is `2^30/√v` rounded at the bucket midpoint
/// `v = (2·(128+i)+1)/256`. Values lie in `(2^29, 2^30)`.
fn rsqrt_lut() -> &'static [u32; 384] {
    static LUT: OnceLock<[u32; 384]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0u32; 384];
        for (i, slot) in t.iter_mut().enumerate() {
            let m = 2 * (128 + i as u64) + 1; // 256·v at the midpoint
            // 2^30/√(m/256) = 2^34/√m, via the integer square root.
            let s = super::sqrt::isqrt_u128((m as u128) << 40) as u64; // √m in Q20
            *slot = fixed_recip(54, s) as u32;
        }
        t
    })
}

/// Approximate division for one real (non-special) lane: reciprocal
/// seed + one Q30 Newton–Raphson step + one multiply.
#[inline(always)]
fn div_real(n: u32, xb: u64, db: u64) -> u64 {
    let a = Posit::from_bits(n, xb).decode();
    let b = Posit::from_bits(n, db).decode();
    let f = frac_bits(n);
    // Seed from the top 8 divisor fraction bits: y ≈ 1/d in Q30.
    let idx = ((b.sig << 8) >> f) as usize & 0xFF;
    let y = (recip_lut()[idx] as u64) << 18;
    // One NR step in Q30: y₁ = y·(2 − d·y).
    let d_q = b.sig << (30 - f);
    let dy = (d_q * y) >> 30;
    let two_minus = (2u64 << 30) - dy;
    let y1 = (y * two_minus) >> 30;
    // q = x_sig · y₁ in Q(f+30); normalize by the leading bit.
    let q = a.sig * y1;
    let top = 63 - q.leading_zeros();
    let scale = clamp_scale(n, a.scale - b.scale + top as i32 - (f + 30) as i32);
    encode_round(n, a.sign ^ b.sign, scale, q as u128, top, true).to_bits()
}

/// Truncated-fraction multiply for one real lane: keep the top
/// [`MUL_KEEP`] significand bits per operand, fold the dropped bits
/// into sticky.
#[inline(always)]
fn mul_real(n: u32, xb: u64, db: u64) -> u64 {
    let a = Posit::from_bits(n, xb).decode();
    let b = Posit::from_bits(n, db).decode();
    let k = sig_bits(n).min(MUL_KEEP);
    let sh = sig_bits(n) - k;
    let (ah, bh) = (a.sig >> sh, b.sig >> sh);
    let sticky = a.sig & mask(sh) != 0 || b.sig & mask(sh) != 0;
    let p = ah * bh; // in [2^(2k−2), 2^2k)
    let top = 63 - p.leading_zeros();
    let scale = clamp_scale(n, a.scale + b.scale + top as i32 - (2 * k - 2) as i32);
    encode_round(n, a.sign ^ b.sign, scale, p as u128, top, sticky).to_bits()
}

/// Approximate square root for one real positive lane: rsqrt seed over
/// the odd/even-normalized radicand `[1,4)` + one NR step, then
/// `√r = r · rsqrt(r)`.
#[inline(always)]
fn sqrt_real(n: u32, vb: u64) -> u64 {
    let d = Posit::from_bits(n, vb).decode();
    let f = frac_bits(n);
    // Absorb an odd scale into the radicand: r ∈ [1,4) in Q28.
    let odd = (d.scale & 1) as u32;
    let r_q28 = (d.sig << odd) << (28 - f);
    let sp = d.scale - odd as i32;
    // Seed y ≈ 1/√r in Q30 from the top radicand bits.
    let idx = ((r_q28 >> 21) - 128) as usize;
    let y = rsqrt_lut()[idx] as u64;
    // One NR step: y₁ = y·(3 − r·y²)/2.
    let y2 = (y * y) >> 30;
    let ry2 = (r_q28 * y2) >> 28;
    let three_minus = 3 * (1u64 << 30) - ry2;
    let y1 = (y * three_minus) >> 31;
    // √r = r·y₁ in Q30 ∈ [2^29, 2^31]; normalize by the leading bit.
    let s_q30 = (r_q28 * y1) >> 28;
    let top = 63 - s_q30.leading_zeros();
    let scale = clamp_scale(n, (sp >> 1) + top as i32 - 30);
    encode_round(n, false, scale, s_q30 as u128, top, true).to_bits()
}

/// Real-lane kernel dispatch. Only the registered kinds are reachable:
/// the unit constructor rejects `(op, width)` pairs without a [`spec`].
#[inline(always)]
fn real_lane(n: u32, kind: Kind, a: u64, b: u64) -> u64 {
    debug_assert!(spec(kind, n).is_some(), "unregistered approx kernel {kind:?} n={n}");
    match kind {
        Kind::Div => div_real(n, a, b),
        Kind::Sqrt => sqrt_real(n, a),
        _ => mul_real(n, a, b),
    }
}

/// The scalar approx kernel for one lane: the Fast tier's *exact*
/// special pre-pass (zero/NaR/negative-radicand lanes are bit-exact in
/// every mode), then the bounded-error arithmetic kernel. High garbage
/// bits are masked off — the same contract as the other tiers.
pub fn scalar_bits(n: u32, kind: Kind, a: u64, b: u64, c: u64) -> u64 {
    let m = mask(n);
    let (a, b, c) = (a & m, b & m, c & m);
    match special(n, kind, a, b, c) {
        Some(r) => r,
        None => real_lane(n, kind, a, b),
    }
}

/// The shared batch body: the Fast tier's lane-splitting special
/// pre-pass, then the dense branch-free kernel loop over real lanes
/// (the index vector is only materialized once a special shows up).
#[inline(always)]
fn batch_generic(n: u32, kind: Kind, a: &[u64], b: &[u64], out: &mut [u64]) {
    let m = mask(n);
    let len = out.len();
    debug_assert_eq!(a.len(), len, "lane a pre-validated by the caller");
    let get = |lane: &[u64], i: usize| if lane.is_empty() { 0 } else { lane[i] & m };

    let mut real: Vec<u32> = Vec::new();
    let mut any_special = false;
    for i in 0..len {
        let (x, y) = (a[i] & m, get(b, i));
        match special(n, kind, x, y, 0) {
            Some(r) => {
                if !any_special {
                    any_special = true;
                    real.reserve(len);
                    real.extend(0..i as u32);
                }
                out[i] = r;
            }
            None if any_special => real.push(i as u32),
            None => {}
        }
    }

    if !any_special {
        for i in 0..len {
            out[i] = real_lane(n, kind, a[i] & m, get(b, i));
        }
    } else {
        for &i in &real {
            let i = i as usize;
            out[i] = real_lane(n, kind, a[i] & m, get(b, i));
        }
    }
}

/// Width- and op-monomorphized batch kernel (masks, shifts and the op
/// dispatch const-fold, mirroring the Fast tier's `select`).
fn batch_mono<const N: u32, const K: u8>(a: &[u64], b: &[u64], out: &mut [u64]) {
    let kind = match K {
        0 => Kind::Div,
        1 => Kind::Sqrt,
        _ => Kind::Mul,
    };
    batch_generic(N, kind, a, b, out)
}

/// Batch execution: `out[i] = op(a[i], b[i])` (b empty for sqrt), on a
/// monomorphized kernel for the registered widths. Lane lengths must be
/// pre-validated by the caller (the unit's shared lane check does).
pub fn run_batch(n: u32, kind: Kind, a: &[u64], b: &[u64], out: &mut [u64]) {
    let f: fn(&[u64], &[u64], &mut [u64]) = match (n, kind) {
        (8, Kind::Div) => batch_mono::<8, 0>,
        (8, Kind::Sqrt) => batch_mono::<8, 1>,
        (8, Kind::Mul) => batch_mono::<8, 2>,
        (16, Kind::Div) => batch_mono::<16, 0>,
        (16, Kind::Sqrt) => batch_mono::<16, 1>,
        (16, Kind::Mul) => batch_mono::<16, 2>,
        (32, Kind::Div) => batch_mono::<32, 0>,
        (32, Kind::Sqrt) => batch_mono::<32, 1>,
        (32, Kind::Mul) => batch_mono::<32, 2>,
        _ => {
            debug_assert!(false, "unregistered approx batch {kind:?} n={n}");
            return out.iter_mut().enumerate().for_each(|(i, o)| {
                *o = scalar_bits(n, kind, a[i], if b.is_empty() { 0 } else { b[i] }, 0)
            });
        }
    };
    f(a, b, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;
    use crate::division::sqrt::golden_sqrt;
    use crate::testkit::Rng;

    const KINDS: [Kind; 3] = [Kind::Div, Kind::Sqrt, Kind::Mul];

    fn reference(n: u32, kind: Kind, a: u64, b: u64) -> u64 {
        let p = |bits: u64| Posit::from_bits(n, bits);
        match kind {
            Kind::Div => golden::divide(p(a), p(b)).result.to_bits(),
            Kind::Sqrt => golden_sqrt(p(a)).result.to_bits(),
            _ => p(a).mul(p(b)).to_bits(),
        }
    }

    fn ulp(n: u32, x: u64, y: u64) -> u64 {
        Posit::from_bits(n, x).ulp_distance(Posit::from_bits(n, y))
    }

    #[test]
    fn seed_tables_are_in_range() {
        for (i, &y) in recip_lut().iter().enumerate() {
            assert!((1 << 11) < y && y <= (1 << 12), "recip[{i}] = {y}");
        }
        for (i, &y) in rsqrt_lut().iter().enumerate() {
            assert!((1 << 29) < y && y <= (1 << 30), "rsqrt[{i}] = {y}");
        }
    }

    #[test]
    fn registry_covers_exactly_the_supported_grid() {
        for n in WIDTHS {
            for kind in KINDS {
                let s = spec(kind, n).expect("registered");
                assert_eq!((s.kind, s.n), (kind, n));
                assert!(s.max_ulp >= 1);
            }
        }
        assert!(spec(Kind::Add, 16).is_none());
        assert!(spec(Kind::MulAdd, 16).is_none());
        assert!(spec(Kind::Div, 64).is_none());
        assert!(spec(Kind::Div, 10).is_none());
    }

    #[test]
    fn specials_are_bit_exact_in_every_mode() {
        for n in WIDTHS {
            let nar = 1u64 << (n - 1);
            for kind in KINDS {
                for &(a, b) in &[(0u64, 0u64), (nar, 1), (1, nar), (0, 1), (1, 0), (nar, nar)] {
                    assert_eq!(
                        scalar_bits(n, kind, a, b, 0),
                        crate::division::fastpath::scalar_bits(n, kind, a, b, 0),
                        "{kind:?} n={n} a={a:#x} b={b:#x}"
                    );
                }
                // negative radicand → NaR, bit-exact
                if kind == Kind::Sqrt {
                    let neg = nar | 1;
                    assert_eq!(scalar_bits(n, kind, neg, 0, 0), nar);
                }
            }
        }
    }

    /// Seeded sweep: observed error ≤ the declared spec at every
    /// registered width (the exhaustive Posit8 gate lives in
    /// `tests/p8_exhaustive.rs`).
    #[test]
    fn seeded_sweeps_stay_within_declared_specs() {
        let mut rng = Rng::seeded(0xA77A);
        for n in WIDTHS {
            let nar = 1u64 << (n - 1);
            for kind in KINDS {
                let bound = spec(kind, n).expect("registered").max_ulp;
                let mut worst = 0u64;
                for _ in 0..20_000 {
                    let (mut a, mut b) = (rng.next_u64() & mask(n), rng.next_u64() & mask(n));
                    if kind == Kind::Sqrt {
                        a &= !nar; // positive radicand
                        if a == 0 {
                            a = 1;
                        }
                        b = 0;
                    }
                    let got = scalar_bits(n, kind, a, b, 0);
                    let want = reference(n, kind, a, b);
                    let d = ulp(n, got, want);
                    worst = worst.max(d);
                    assert!(d <= bound, "{kind:?} n={n} a={a:#x} b={b:#x}: {d} ulp > {bound}");
                }
                assert!(worst <= bound);
            }
        }
    }

    /// Directed sweep at the seed-table bucket edges, where the seed
    /// error peaks: divisor significands on both sides of every LUT
    /// boundary against random dividends.
    #[test]
    fn lut_bucket_edges_stay_within_declared_specs() {
        let mut rng = Rng::seeded(0xB0B5);
        for n in WIDTHS {
            let f = frac_bits(n);
            let bound = spec(Kind::Div, n).expect("registered").max_ulp;
            for i in 0..256u64 {
                for off in 0..2u64 {
                    let sig = (1u64 << f) | (((i << f) >> 8).wrapping_add(off) & mask(f));
                    let b = encode_round(n, false, 0, sig as u128, f, false).to_bits();
                    for _ in 0..8 {
                        let a = {
                            let x = rng.next_u64() & mask(n);
                            if x == 0 || x == 1 << (n - 1) {
                                1
                            } else {
                                x
                            }
                        };
                        let d = ulp(n, scalar_bits(n, Kind::Div, a, b, 0), reference(n, Kind::Div, a, b));
                        assert!(d <= bound, "n={n} a={a:#x} b={b:#x}: {d} ulp > {bound}");
                    }
                }
            }
        }
    }

    #[test]
    fn batch_matches_scalar_with_and_without_specials() {
        let mut rng = Rng::seeded(0xBA7C);
        for n in WIDTHS {
            for kind in KINDS {
                let lane = |rng: &mut Rng, sprinkle: bool| -> Vec<u64> {
                    (0..257)
                        .map(|i| {
                            if sprinkle && i % 17 == 0 {
                                [0u64, 1 << (n - 1)][i / 17 % 2]
                            } else {
                                rng.next_u64() & mask(n)
                            }
                        })
                        .collect()
                };
                for sprinkle in [false, true] {
                    let a = lane(&mut rng, sprinkle);
                    let b = if kind == Kind::Sqrt { Vec::new() } else { lane(&mut rng, sprinkle) };
                    let mut out = vec![0u64; a.len()];
                    run_batch(n, kind, &a, &b, &mut out);
                    for i in 0..a.len() {
                        let bi = if b.is_empty() { 0 } else { b[i] };
                        assert_eq!(
                            out[i],
                            scalar_bits(n, kind, a[i], bi, 0),
                            "{kind:?} n={n} i={i} sprinkle={sprinkle}"
                        );
                    }
                }
            }
        }
    }

    /// The fixed-regime clamp is semantically a no-op: results that
    /// drive the scale past the representable range still saturate to
    /// maxpos/minpos exactly like the exact tiers.
    #[test]
    fn saturation_matches_exact_tiers() {
        for n in WIDTHS {
            let maxpos = mask(n - 1);
            let minpos = 1u64;
            // maxpos/minpos overflows the scale range → saturates
            let got = scalar_bits(n, Kind::Div, maxpos, minpos, 0);
            assert_eq!(got, reference(n, Kind::Div, maxpos, minpos));
            let got = scalar_bits(n, Kind::Mul, maxpos, maxpos, 0);
            assert_eq!(got, reference(n, Kind::Mul, maxpos, maxpos));
            let got = scalar_bits(n, Kind::Div, minpos, maxpos, 0);
            assert_eq!(got, reference(n, Kind::Div, minpos, maxpos));
        }
    }
}
