//! Benchmark subsystem (criterion is unavailable offline).
//!
//! Layers, bottom up:
//!
//! * the micro-bench substrate — adaptive-iteration timing with warmup,
//!   outlier-robust statistics (median of sample means: [`bench`],
//!   [`bench_batched`], [`Config`]) and the aligned-table [`Runner`];
//! * [`report`] — the structured report model (suite, git rev, config,
//!   per-measurement rows) with hand-rolled JSON ser/de ([`json`]) and
//!   schema validation;
//! * [`baseline`] — load/compare against a committed `BENCH_<suite>.json`
//!   with a configurable regression threshold;
//! * [`suites`] — the bodies of all ten `harness = false` bench targets;
//! * [`harness`] — the shared flag-parsing/gating entry point used by the
//!   bench shims and the `posit-div bench` subcommand.
//!
//! The workflow (profiles, baseline refresh, CI gating) is documented in
//! EXPERIMENTS.md §Perf.

pub mod baseline;
pub mod harness;
pub mod json;
pub mod report;
pub mod suites;

use std::time::{Duration, Instant};

use report::Entry;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Mean time per operation (median across samples).
    pub per_op: Duration,
    /// Operations per second.
    pub ops_per_sec: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub warmup: Duration,
    pub sample_time: Duration,
    pub samples: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            warmup: Duration::from_millis(100),
            sample_time: Duration::from_millis(60),
            samples: 7,
        }
    }
}

impl Config {
    /// Faster settings for long-running end-to-end benches.
    pub fn quick() -> Config {
        Config {
            warmup: Duration::from_millis(30),
            sample_time: Duration::from_millis(30),
            samples: 3,
        }
    }
}

/// Timing profile: `Full` is the default measurement-grade configuration,
/// `Quick` the CI-smoke configuration. Selected per run via `--profile`
/// (or `--quick`/`--full`), falling back to `$POSIT_BENCH_PROFILE`.
/// Profiles shrink timing budgets and workload sizes, never row sets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    Quick,
    Full,
}

impl Profile {
    pub fn parse(s: &str) -> Option<Profile> {
        match s.to_ascii_lowercase().as_str() {
            "quick" => Some(Profile::Quick),
            "full" => Some(Profile::Full),
            _ => None,
        }
    }

    /// `$POSIT_BENCH_PROFILE`, if set and valid.
    pub fn from_env() -> Option<Profile> {
        std::env::var("POSIT_BENCH_PROFILE").ok().and_then(|v| Profile::parse(&v))
    }

    pub fn name(self) -> &'static str {
        match self {
            Profile::Quick => "quick",
            Profile::Full => "full",
        }
    }

    pub fn config(self) -> Config {
        match self {
            Profile::Quick => Config::quick(),
            Profile::Full => Config::default(),
        }
    }
}

/// Time `op` (which performs `batch` logical operations per call).
pub fn bench_batched<F: FnMut()>(name: &str, cfg: Config, batch: u64, mut op: F) -> Measurement {
    // Warmup + calibration: how many calls fit in sample_time?
    let w0 = Instant::now();
    let mut calls = 0u64;
    while w0.elapsed() < cfg.warmup {
        op();
        calls += 1;
    }
    let per_call = cfg.warmup.as_secs_f64() / calls.max(1) as f64;
    let iters = ((cfg.sample_time.as_secs_f64() / per_call).ceil() as u64).max(1);

    let mut means: Vec<f64> = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            op();
        }
        means.push(t0.elapsed().as_secs_f64() / (iters * batch) as f64);
    }
    means.sort_by(|a, b| a.total_cmp(b));
    let median = means[means.len() / 2];
    Measurement {
        name: name.to_string(),
        per_op: Duration::from_secs_f64(median),
        ops_per_sec: 1.0 / median,
        samples: cfg.samples,
        iters_per_sample: iters,
    }
}

/// Time a single-op closure.
pub fn bench<F: FnMut()>(name: &str, cfg: Config, op: F) -> Measurement {
    bench_batched(name, cfg, 1, op)
}

/// Collects rows and renders an aligned report; [`Runner::entries`] feeds
/// the structured [`report::Report`].
#[derive(Default)]
pub struct Runner {
    title: String,
    entries: Vec<Entry>,
}

impl Runner {
    pub fn new(title: &str) -> Runner {
        Runner { title: title.to_string(), entries: Vec::new() }
    }

    fn announce(m: &Measurement) {
        println!("  measured {:<40} {:>12.2?}/op {:>14.0} op/s", m.name, m.per_op, m.ops_per_sec);
    }

    /// Register an untagged measurement (no width/algorithm/path metadata).
    pub fn add(&mut self, m: Measurement) {
        Self::announce(&m);
        self.entries.push(Entry::from_measurement(&m));
    }

    /// Register a measurement with report metadata attached.
    pub fn add_tagged(
        &mut self,
        m: Measurement,
        width: Option<u32>,
        algorithm: Option<&str>,
        path: &str,
    ) {
        Self::announce(&m);
        self.entries.push(Entry::tagged(&m, width, algorithm, path));
    }

    /// Register a pre-built row (service and hardware-model suites build
    /// rows directly; they print their own tables, so this is silent).
    pub fn add_entry(&mut self, e: Entry) {
        self.entries.push(e);
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, cfg: Config, op: F) {
        let m = bench(name, cfg, op);
        self.add(m);
    }

    /// Rows registered so far, in registration order.
    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "\n== {} ==\n{:<42} {:>14} {:>16}\n",
            self.title, "benchmark", "time/op", "ops/s"
        );
        for e in &self.entries {
            out.push_str(&format!(
                "{:<42} {:>14.2?} {:>16.0}\n",
                e.name,
                Duration::from_secs_f64(e.per_op_ns * 1e-9),
                e.ops_per_sec
            ));
        }
        out
    }

    pub fn finish(&self) {
        print!("{}", self.report());
    }
}

/// A compiler fence so the optimizer cannot delete benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = Config {
            warmup: Duration::from_millis(5),
            sample_time: Duration::from_millis(5),
            samples: 3,
        };
        let mut acc = 0u64;
        let m = bench("noop-ish", cfg, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.per_op < Duration::from_micros(10));
        assert!(m.ops_per_sec > 1e5);
    }

    #[test]
    fn batched_accounting() {
        let cfg = Config {
            warmup: Duration::from_millis(5),
            sample_time: Duration::from_millis(5),
            samples: 3,
        };
        let m = bench_batched("batch", cfg, 1000, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        // per-op must be ~1/1000 of the call time
        assert!(m.per_op < Duration::from_micros(1));
    }

    #[test]
    fn runner_report_contains_rows() {
        let mut r = Runner::new("t");
        r.add(Measurement {
            name: "x".into(),
            per_op: Duration::from_nanos(10),
            ops_per_sec: 1e8,
            samples: 1,
            iters_per_sample: 1,
        });
        assert!(r.report().contains("x"));
    }

    #[test]
    fn tagged_rows_carry_metadata() {
        let mut r = Runner::new("t");
        let m = Measurement {
            name: "Posit16 NRD batch".into(),
            per_op: Duration::from_nanos(250),
            ops_per_sec: 4e6,
            samples: 3,
            iters_per_sample: 100,
        };
        r.add_tagged(m.clone(), Some(16), Some("NRD"), "batch");
        r.add(m);
        assert_eq!(r.entries().len(), 2);
        assert_eq!(r.entries()[0].width, Some(16));
        assert_eq!(r.entries()[0].algorithm.as_deref(), Some("NRD"));
        assert_eq!(r.entries()[1].width, None);
        // per_op_ns is derived from the Duration
        assert!((r.entries()[0].per_op_ns - 250.0).abs() < 1e-9);
    }

    #[test]
    fn profile_parsing_and_configs() {
        assert_eq!(Profile::parse("quick"), Some(Profile::Quick));
        assert_eq!(Profile::parse("FULL"), Some(Profile::Full));
        assert_eq!(Profile::parse("warp"), None);
        assert_eq!(Profile::Quick.name(), "quick");
        assert!(Profile::Quick.config().samples <= Profile::Full.config().samples);
    }
}
