//! Posit⟨n, es=2⟩ arithmetic (2022 Posit Standard), for 4 ≤ n ≤ 64.
//!
//! The paper (and the 2022 standard) fix `es = 2`; the total width `n` is a
//! runtime parameter so a single implementation covers Posit8 … Posit64 as
//! well as odd widths such as the Posit10 used by the paper's Table III
//! worked examples.
//!
//! A posit bit pattern is an `n`-bit two's-complement integer stored in the
//! low bits of a `u64`. Two patterns are special: `0…0` is zero and `10…0`
//! is NaR (Not a Real). Every other pattern encodes
//! `(-1)^s · 2^(4k+e) · (1+f)` per Eq. (2) of the paper, where `k` is the
//! run-length-encoded regime, `e` the 2-bit exponent and `f` the fraction.
//!
//! Modules:
//! * [`fields`] — decoding into sign/scale/significand ([`Decoded`]).
//! * [`round`] — encoding with the standard's round-to-nearest-even on the
//!   bit pattern (guard/sticky), saturating at `maxpos`/`minpos`.
//! * [`convert`] — correctly-rounded `f64` ↔ posit conversion.
//! * [`arith`] — add/sub/mul (needed by the DSP examples and the
//!   Newton–Raphson baseline divider).

pub mod arith;
pub mod convert;
pub mod fields;
pub mod round;
pub mod typed;

pub use fields::{Decoded, Unpacked};
pub use typed::{RoundFrom, RoundInto, P16, P32, P64, P8};

/// Exponent field width fixed by the 2022 Posit Standard (and the paper).
pub const ES: u32 = 2;

/// Minimum / maximum supported posit width.
pub const MIN_N: u32 = 4;
pub const MAX_N: u32 = 64;

/// A posit number: an `n`-bit pattern in the low bits of `bits`.
///
/// Invariants: `MIN_N <= n <= MAX_N` and `bits` has no bits set at or above
/// position `n`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Posit {
    bits: u64,
    n: u32,
}

/// Bit mask with the low `n` bits set.
#[inline]
pub const fn mask(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Worst-case number of fraction bits of a Posit⟨n,2⟩: `n - 5`
/// (sign + 2-bit minimum regime + 2-bit exponent), clamped at zero for tiny
/// widths. All significands in this crate are normalized to this width.
#[inline]
pub const fn frac_bits(n: u32) -> u32 {
    if n > 5 {
        n - 5
    } else {
        0
    }
}

/// Number of significand bits (hidden 1 + fraction): `n - 4` for n > 5.
#[inline]
pub const fn sig_bits(n: u32) -> u32 {
    frac_bits(n) + 1
}

/// Maximum representable scale (4k+e) of a Posit⟨n,2⟩: `4(n-2)`.
///
/// The largest finite posit is `maxpos = 2^(4(n-2))`: its regime run
/// consumes all n−1 bits after the sign (k = n−2), leaving no exponent
/// bits, so e = 0 and the scale is exactly `4(n-2)` — not `4(n-2)+3`,
/// which a regime/exponent field count alone would suggest.
#[inline]
pub const fn max_scale(n: u32) -> i32 {
    4 * (n as i32 - 2)
}

impl Posit {
    /// Construct from a raw `n`-bit pattern (low bits of `bits`).
    ///
    /// Panics if `n` is out of range; high garbage bits are masked off.
    #[inline]
    pub fn from_bits(n: u32, bits: u64) -> Self {
        assert!(
            (MIN_N..=MAX_N).contains(&n),
            "posit width {n} out of supported range [{MIN_N},{MAX_N}]"
        );
        Posit { bits: bits & mask(n), n }
    }

    /// The raw `n`-bit pattern.
    #[inline]
    pub fn to_bits(self) -> u64 {
        self.bits
    }

    /// Total width in bits.
    #[inline]
    pub fn width(self) -> u32 {
        self.n
    }

    /// The zero posit (pattern `0…0`).
    #[inline]
    pub fn zero(n: u32) -> Self {
        Posit::from_bits(n, 0)
    }

    /// NaR — Not a Real (pattern `10…0`).
    #[inline]
    pub fn nar(n: u32) -> Self {
        Posit::from_bits(n, 1u64 << (n - 1))
    }

    /// Largest positive posit `maxpos = 2^(4(n-2))` (pattern `01…1`).
    #[inline]
    pub fn maxpos(n: u32) -> Self {
        Posit::from_bits(n, mask(n - 1))
    }

    /// Smallest positive posit `minpos = 2^(-4(n-2))` (pattern `0…01`).
    #[inline]
    pub fn minpos(n: u32) -> Self {
        Posit::from_bits(n, 1)
    }

    /// The posit encoding 1.0 (pattern `010…0`).
    #[inline]
    pub fn one(n: u32) -> Self {
        Posit::from_bits(n, 1u64 << (n - 2))
    }

    #[inline]
    pub fn is_zero(self) -> bool {
        self.bits == 0
    }

    #[inline]
    pub fn is_nar(self) -> bool {
        self.bits == 1u64 << (self.n - 1)
    }

    /// Sign bit of the pattern (true ⇒ negative for non-special values).
    #[inline]
    pub fn sign_bit(self) -> bool {
        (self.bits >> (self.n - 1)) & 1 == 1
    }

    /// True for strictly negative real values (NaR and zero excluded).
    #[inline]
    pub fn is_negative(self) -> bool {
        self.sign_bit() && !self.is_nar()
    }

    /// Arithmetic negation: exact for every posit (two's complement of the
    /// pattern). `-0 = 0`, `-NaR = NaR`.
    #[inline]
    pub fn neg(self) -> Self {
        Posit::from_bits(self.n, self.bits.wrapping_neg() & mask(self.n))
    }

    /// Absolute value.
    #[inline]
    pub fn abs(self) -> Self {
        if self.is_negative() {
            self.neg()
        } else {
            self
        }
    }

    /// The pattern interpreted as a sign-extended signed integer. Posit
    /// ordering coincides with this integer ordering (NaR smallest) — the
    /// property the paper highlights as removing comparator hardware.
    #[inline]
    pub fn to_signed(self) -> i64 {
        let shift = 64 - self.n;
        ((self.bits << shift) as i64) >> shift
    }

    /// Total order: NaR < negative reals < 0 < positive reals.
    #[inline]
    pub fn total_cmp(self, other: Posit) -> core::cmp::Ordering {
        assert_eq!(self.n, other.n, "comparing posits of different widths");
        self.to_signed().cmp(&other.to_signed())
    }

    /// Next representable posit up (pattern + 1), saturating at maxpos.
    #[inline]
    pub fn next_up(self) -> Self {
        if self.bits == mask(self.n - 1) {
            return self; // maxpos: never step onto NaR
        }
        Posit::from_bits(self.n, self.bits.wrapping_add(1) & mask(self.n))
    }

    /// Next representable posit down (pattern − 1), saturating past NaR.
    #[inline]
    pub fn next_down(self) -> Self {
        let nar = 1u64 << (self.n - 1);
        if self.bits == nar.wrapping_add(1) & mask(self.n) {
            return self;
        }
        Posit::from_bits(self.n, self.bits.wrapping_sub(1) & mask(self.n))
    }

    /// Units-in-last-place distance between two posits of the same width
    /// (patterns are monotone in value, so this is meaningful).
    #[inline]
    pub fn ulp_distance(self, other: Posit) -> u64 {
        assert_eq!(self.n, other.n);
        (self.to_signed() - other.to_signed()).unsigned_abs()
    }
}

impl core::fmt::Debug for Posit {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        if self.is_nar() {
            write!(f, "Posit{}(NaR)", self.n)
        } else {
            write!(
                f,
                "Posit{}({:#0width$b} = {})",
                self.n,
                self.bits,
                self.to_f64(),
                width = self.n as usize + 2
            )
        }
    }
}

impl core::fmt::Display for Posit {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        if self.is_nar() {
            write!(f, "NaR")
        } else {
            write!(f, "{}", self.to_f64())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn special_patterns() {
        for n in [4u32, 8, 10, 16, 32, 64] {
            assert!(Posit::zero(n).is_zero());
            assert!(Posit::nar(n).is_nar());
            assert!(!Posit::zero(n).is_nar());
            assert!(!Posit::nar(n).is_zero());
            assert_eq!(Posit::one(n).to_f64(), 1.0);
            assert_eq!(Posit::one(n).neg().to_f64(), -1.0);
        }
    }

    #[test]
    fn neg_is_involution() {
        let n = 16;
        for bits in 0..=mask(n) {
            let p = Posit::from_bits(n, bits);
            assert_eq!(p.neg().neg(), p, "bits={bits:#x}");
        }
    }

    #[test]
    fn nar_and_zero_are_self_negations() {
        for n in [8u32, 16, 32, 64] {
            assert_eq!(Posit::nar(n).neg(), Posit::nar(n));
            assert_eq!(Posit::zero(n).neg(), Posit::zero(n));
        }
    }

    #[test]
    fn ordering_matches_value_ordering_posit8() {
        // Exhaustive over Posit8: integer order must equal value order.
        let n = 8;
        let mut last: Option<(i64, f64)> = None;
        // iterate patterns in signed order: NaR .. maxpos
        for signed in -(1i64 << (n - 1))..=(mask(n - 1) as i64) {
            let p = Posit::from_bits(n, (signed as u64) & mask(n));
            if p.is_nar() {
                continue;
            }
            let v = p.to_f64();
            if let Some((ls, lv)) = last {
                assert!(lv < v, "order violation at signed {ls} -> {signed}: {lv} !< {v}");
            }
            last = Some((signed, v));
        }
    }

    #[test]
    fn next_up_saturates() {
        let n = 16;
        assert_eq!(Posit::maxpos(n).next_up(), Posit::maxpos(n));
        let minneg = Posit::from_bits(n, (1u64 << (n - 1)) + 1); // most negative real
        assert_eq!(minneg.next_down(), minneg);
    }

    #[test]
    fn maxpos_minpos_values() {
        assert_eq!(Posit::maxpos(8).to_f64(), (2.0f64).powi(24));
        assert_eq!(Posit::minpos(8).to_f64(), (2.0f64).powi(-24));
        assert_eq!(Posit::maxpos(16).to_f64(), (2.0f64).powi(56));
        assert_eq!(Posit::minpos(16).to_f64(), (2.0f64).powi(-56));
    }

    #[test]
    #[should_panic]
    fn width_out_of_range_panics() {
        let _ = Posit::from_bits(3, 0);
    }

    #[test]
    fn max_scale_matches_maxpos_decode() {
        // Pin the doc contract: max_scale(n) is exactly the decoded scale
        // of maxpos (and minpos mirrors it), for every standard width.
        for n in [8u32, 16, 32, 64] {
            assert_eq!(max_scale(n), 4 * (n as i32 - 2));
            assert_eq!(Posit::maxpos(n).decode().scale, max_scale(n));
            assert_eq!(Posit::minpos(n).decode().scale, -max_scale(n));
        }
        // and the value itself where f64 is exact (sig = 1.0 always is)
        assert_eq!(Posit::maxpos(8).to_f64(), (2.0f64).powi(max_scale(8)));
        assert_eq!(Posit::maxpos(64).to_f64(), (2.0f64).powi(max_scale(64)));
    }
}
