//! Measured software throughput of every division engine at every format —
//! thin shim over [`posit_div::bench::suites`], where the suite body
//! lives so the same code runs under `cargo bench --bench engine_throughput`
//! and `posit-div bench engine_throughput` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("engine_throughput");
}
