//! The posit-standard **quire**: a width-parameterized fixed-point
//! accumulator wide enough to hold any sum of posit products *exactly*,
//! deferring the single rounding to the final posit conversion.
//!
//! For posit⟨n, es=2⟩ the extreme product magnitudes are `maxpos²` =
//! 2^(8(n−2)) and `minpos²` = 2^(−8(n−2)), so an accumulator whose LSB
//! weighs 2^QMIN with QMIN = −(8(n−2) + 2·fb) (fb = fraction bits)
//! represents every product of two reals as an *integer* multiple of its
//! LSB. [`Quire`] backs that integer with a small LSB-first two's
//! complement `u64` limb vector of 2n² bits (128 / 512 / 2048 bits for
//! P8 / P16 / P32, clamped to ≥ 128 so the narrow widths keep product
//! range plus headroom), leaving ≥ 23 carry-headroom bits above the
//! widest product — millions of accumulations before wraparound.
//!
//! Exactness contract: for inputs free of NaR, [`Quire::to_posit`] after
//! any sequence of [`Quire::add_product`] / [`Quire::add_posit`] calls
//! within the headroom budget equals the exact rational sum rounded once
//! to nearest-even in pattern space — bit-identical to the independent
//! bignum-rational golden in [`crate::testkit::rational`]. In particular
//! the result is invariant under permutation of the accumulation order,
//! which no fold of individually-rounded posit ops can promise.
//!
//! NaR latches: accumulating anything involving NaR poisons the quire and
//! `to_posit` returns NaR, matching the standard's quire semantics.
//!
//! The free functions [`dot`], [`fused_sum`], [`axpy`] and the blocked
//! [`gemm`] are the workload-facing reductions; the serving layer reaches
//! them through `Op::Dot` / `Op::FusedSum` / `Op::Axpy` on
//! [`crate::unit::Unit`] and the coordinator client.

use crate::error::{PositError, Result};
use crate::posit::round::encode_round;
use crate::posit::{frac_bits, Posit, Unpacked, MAX_N, MIN_N};

/// Weight (base-2 exponent) of the quire's least-significant bit:
/// `minpos² = 2^QMIN · 2^(2·fb)`'s lowest product bit lands exactly here.
fn qmin(n: u32) -> i32 {
    -(8 * (n as i32 - 2) + 2 * frac_bits(n) as i32)
}

/// Limb count: 2n² bits per the 2^(n²/2) dynamic-range rule, clamped to
/// two limbs so n < 8 still covers `maxpos²` plus a sign/carry margin.
fn quire_limbs(n: u32) -> usize {
    ((((2 * n * n) as usize) + 63) / 64).max(2)
}

/// Widths whose whole quire fits one `i128` register — the Fast tier's
/// in-register accumulator is bit-identical there (same 128-bit two's
/// complement wrap as the two-limb backing).
pub(crate) fn fits_in_register(n: u32) -> bool {
    quire_limbs(n) <= 2
}

/// A posit-standard exact accumulator for one posit width.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Quire {
    n: u32,
    nar: bool,
    /// LSB-first two's complement limbs; bit k weighs 2^(QMIN + k).
    limbs: Vec<u64>,
}

impl Quire {
    /// A zeroed quire for posit width `n` (4..=64).
    pub fn new(n: u32) -> Result<Quire> {
        if !(MIN_N..=MAX_N).contains(&n) {
            return Err(PositError::WidthOutOfRange { n });
        }
        Ok(Quire { n, nar: false, limbs: vec![0; quire_limbs(n)] })
    }

    /// The posit width this quire accumulates.
    pub fn width(&self) -> u32 {
        self.n
    }

    /// Total accumulator width in bits.
    pub fn bits(&self) -> u32 {
        64 * self.limbs.len() as u32
    }

    /// Reset to exact zero (also clears a latched NaR).
    pub fn clear(&mut self) {
        self.nar = false;
        self.limbs.fill(0);
    }

    /// True once any NaR operand has been accumulated.
    pub fn is_nar(&self) -> bool {
        self.nar
    }

    /// True when the accumulator holds exact zero (and no NaR).
    pub fn is_zero(&self) -> bool {
        !self.nar && self.limbs.iter().all(|&w| w == 0)
    }

    /// Accumulate the exact product `a · b` (no rounding). NaR operands
    /// latch NaR; zero operands are no-ops.
    pub fn add_product(&mut self, a: Posit, b: Posit) {
        assert_eq!(a.width(), self.n, "quire width mismatch");
        assert_eq!(b.width(), self.n, "quire width mismatch");
        match (a.unpack(), b.unpack()) {
            (Unpacked::NaR, _) | (_, Unpacked::NaR) => self.nar = true,
            (Unpacked::Zero, _) | (_, Unpacked::Zero) => {}
            (Unpacked::Real(da), Unpacked::Real(db)) => {
                let fb = frac_bits(self.n) as i32;
                let mag = (da.sig as u128) * (db.sig as u128);
                let shift = (da.scale + db.scale - 2 * fb - qmin(self.n)) as u32;
                self.accumulate(mag, shift, da.sign ^ db.sign);
            }
        }
    }

    /// Accumulate the posit value itself, exactly.
    pub fn add_posit(&mut self, p: Posit) {
        assert_eq!(p.width(), self.n, "quire width mismatch");
        match p.unpack() {
            Unpacked::NaR => self.nar = true,
            Unpacked::Zero => {}
            Unpacked::Real(d) => {
                let fb = frac_bits(self.n) as i32;
                let shift = (d.scale - fb - qmin(self.n)) as u32;
                self.accumulate(d.sig as u128, shift, d.sign);
            }
        }
    }

    /// Accumulate `-p`, exactly (posit negation is exact).
    pub fn sub_posit(&mut self, p: Posit) {
        self.add_posit(p.neg());
    }

    fn accumulate(&mut self, mag: u128, shift: u32, negative: bool) {
        let li = (shift / 64) as usize;
        let words = shifted_words(mag, shift % 64);
        if negative {
            self.sub_words(li, words);
        } else {
            self.add_words(li, words);
        }
    }

    fn add_words(&mut self, li: usize, words: [u64; 3]) {
        let len = self.limbs.len();
        let mut carry = 0u64;
        for (k, w) in words.into_iter().enumerate() {
            if li + k >= len {
                break; // in-range posit data never lands here (headroom)
            }
            let (s1, c1) = self.limbs[li + k].overflowing_add(w);
            let (s2, c2) = s1.overflowing_add(carry);
            self.limbs[li + k] = s2;
            carry = (c1 | c2) as u64;
        }
        let mut i = li + 3;
        while carry != 0 && i < len {
            let (s, c) = self.limbs[i].overflowing_add(carry);
            self.limbs[i] = s;
            carry = c as u64;
            i += 1;
        }
        // a carry off the top wraps, like the hardware register would
    }

    fn sub_words(&mut self, li: usize, words: [u64; 3]) {
        let len = self.limbs.len();
        let mut borrow = 0u64;
        for (k, w) in words.into_iter().enumerate() {
            if li + k >= len {
                break;
            }
            let (d1, b1) = self.limbs[li + k].overflowing_sub(w);
            let (d2, b2) = d1.overflowing_sub(borrow);
            self.limbs[li + k] = d2;
            borrow = (b1 | b2) as u64;
        }
        let mut i = li + 3;
        while borrow != 0 && i < len {
            let (d, b) = self.limbs[i].overflowing_sub(borrow);
            self.limbs[i] = d;
            borrow = b as u64;
            i += 1;
        }
    }

    /// The single rounding: convert the exact fixed-point value to the
    /// nearest posit (ties to even in pattern space), NaR if latched.
    pub fn to_posit(&self) -> Posit {
        if self.nar {
            return Posit::nar(self.n);
        }
        let negative = self.limbs.last().copied().unwrap_or(0) >> 63 == 1;
        let storage;
        let mag: &[u64] = if negative {
            storage = negate_limbs(&self.limbs);
            &storage
        } else {
            &self.limbs
        };
        let Some(top) = mag.iter().rposition(|&w| w != 0) else {
            return Posit::zero(self.n);
        };
        // global index of the most significant set bit
        let g = top as u32 * 64 + (63 - mag[top].leading_zeros());
        // a ≤127-bit window below it; everything lower folds into sticky
        let lo = g.saturating_sub(126);
        let sig = bit_range(mag, lo, g);
        let sticky = any_bit_below(mag, lo);
        encode_round(self.n, negative, qmin(self.n) + g as i32, sig, g - lo, sticky)
    }
}

/// `mag << off` (off < 64) spread over three 64-bit words, LSB-first.
fn shifted_words(mag: u128, off: u32) -> [u64; 3] {
    let lo = mag as u64;
    let hi = (mag >> 64) as u64;
    if off == 0 {
        [lo, hi, 0]
    } else {
        [lo << off, (lo >> (64 - off)) | (hi << off), hi >> (64 - off)]
    }
}

/// Two's complement negation of an LSB-first limb vector.
fn negate_limbs(limbs: &[u64]) -> Vec<u64> {
    let mut out = Vec::with_capacity(limbs.len());
    let mut carry = 1u64;
    for &w in limbs {
        let (v, c) = (!w).overflowing_add(carry);
        out.push(v);
        carry = c as u64;
    }
    out
}

/// Bits `lo..=hi` of an LSB-first magnitude (hi − lo ≤ 126).
fn bit_range(mag: &[u64], lo: u32, hi: u32) -> u128 {
    let mut v: u128 = 0;
    for i in (lo / 64) as usize..=(hi / 64) as usize {
        let base = i as u32 * 64;
        let limb = mag[i] as u128;
        if base >= lo {
            v |= limb << (base - lo);
        } else {
            v |= limb >> (lo - base);
        }
    }
    let width = hi - lo + 1;
    if width < 128 {
        v &= (1u128 << width) - 1;
    }
    v
}

/// True when any bit strictly below `lo` is set.
fn any_bit_below(mag: &[u64], lo: u32) -> bool {
    let limb = (lo / 64) as usize;
    if mag[..limb].iter().any(|&w| w != 0) {
        return true;
    }
    let rem = lo % 64;
    rem > 0 && mag[limb] & ((1u64 << rem) - 1) != 0
}

fn check_lane(name: &'static str, len: usize, expected: usize) -> Result<()> {
    if len != expected {
        return Err(PositError::BatchLaneMismatch {
            lane: name,
            expected: expected.max(1),
            got: len,
        });
    }
    Ok(())
}

fn common_width(lanes: &[&[Posit]]) -> Result<u32> {
    let mut width = None;
    for lane in lanes {
        for p in *lane {
            match width {
                None => width = Some(p.width()),
                Some(w) if p.width() != w => {
                    return Err(PositError::WidthMismatch { expected: w, got: p.width() })
                }
                _ => {}
            }
        }
    }
    width.ok_or(PositError::BatchLaneMismatch { lane: "a", expected: 1, got: 0 })
}

/// Exact dot product: `round(Σ aᵢ·bᵢ)` with one final rounding.
pub fn dot(a: &[Posit], b: &[Posit]) -> Result<Posit> {
    check_lane("b", b.len(), a.len())?;
    let n = common_width(&[a, b])?;
    let mut q = Quire::new(n)?;
    for (&x, &y) in a.iter().zip(b) {
        q.add_product(x, y);
    }
    Ok(q.to_posit())
}

/// Exact sum: `round(Σ xᵢ)` with one final rounding — permutation
/// invariant, unlike a fold of rounded `add`s.
pub fn fused_sum(xs: &[Posit]) -> Result<Posit> {
    let n = common_width(&[xs])?;
    let mut q = Quire::new(n)?;
    for &x in xs {
        q.add_posit(x);
    }
    Ok(q.to_posit())
}

/// Exact fused `round(Σᵢ (α·xᵢ + yᵢ))`: the scaled vector and the added
/// vector accumulate in one quire, one final rounding.
pub fn axpy(alpha: Posit, xs: &[Posit], ys: &[Posit]) -> Result<Posit> {
    check_lane("b", ys.len(), xs.len())?;
    let n = common_width(&[&[alpha], xs, ys])?;
    if xs.is_empty() {
        return Err(PositError::BatchLaneMismatch { lane: "a", expected: 1, got: 0 });
    }
    let mut q = Quire::new(n)?;
    for (&x, &y) in xs.iter().zip(ys) {
        q.add_product(alpha, x);
        q.add_posit(y);
    }
    Ok(q.to_posit())
}

/// Blocked quire GEMM: row-major `a` (m×k) times row-major `b` (k×p),
/// each output entry one exact quire dot (a single rounding per entry).
/// Column tiles of `b` share a strip of persistent quires across the k
/// loop so the inner walk stays sequential in both operands.
pub fn gemm(a: &[Posit], b: &[Posit], m: usize, k: usize, p: usize) -> Result<Vec<Posit>> {
    check_lane("a", a.len(), m * k)?;
    check_lane("b", b.len(), k * p)?;
    let n = common_width(&[a, b])?;
    const JB: usize = 8;
    let mut out = vec![Posit::zero(n); m * p];
    let mut tile: Vec<Quire> = (0..JB).map(|_| Quire::new(n)).collect::<Result<_>>()?;
    for j0 in (0..p).step_by(JB) {
        let jw = JB.min(p - j0);
        for i in 0..m {
            for q in tile.iter_mut().take(jw) {
                q.clear();
            }
            for t in 0..k {
                let av = a[i * k + t];
                for (jj, q) in tile.iter_mut().take(jw).enumerate() {
                    q.add_product(av, b[t * p + j0 + jj]);
                }
            }
            for (jj, q) in tile.iter().take(jw).enumerate() {
                out[i * p + j0 + jj] = q.to_posit();
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Bit-level kernels for the serving tiers (`unit.rs`). The register
// variants keep the whole quire in one i128 — valid exactly when the limb
// backing is two words, so wraparound semantics stay bit-identical.

fn nar_bits(n: u32) -> u64 {
    1u64 << (n - 1)
}

fn i128_fixed_to_bits(n: u32, acc: i128) -> u64 {
    if acc == 0 {
        return 0;
    }
    let negative = acc < 0;
    let mag = acc.unsigned_abs();
    let msb = 127 - mag.leading_zeros();
    let (sig, sfb, sticky) = if msb == 127 {
        (mag >> 1, 126, mag & 1 != 0)
    } else {
        (mag, msb, false)
    };
    encode_round(n, negative, qmin(n) + msb as i32, sig, sfb, sticky).to_bits()
}

/// In-register dot kernel (n with a two-limb quire only).
fn dot_bits_reg(n: u32, a: &[u64], b: &[u64]) -> u64 {
    debug_assert!(fits_in_register(n));
    let fb = frac_bits(n) as i32;
    let qm = qmin(n);
    let mut acc: i128 = 0;
    for (&ab, &bb) in a.iter().zip(b) {
        let (pa, pb) = (Posit::from_bits(n, ab), Posit::from_bits(n, bb));
        match (pa.unpack(), pb.unpack()) {
            (Unpacked::NaR, _) | (_, Unpacked::NaR) => return nar_bits(n),
            (Unpacked::Zero, _) | (_, Unpacked::Zero) => {}
            (Unpacked::Real(da), Unpacked::Real(db)) => {
                let mag = (da.sig as u128 * db.sig as u128) as i128;
                let v = mag.wrapping_shl((da.scale + db.scale - 2 * fb - qm) as u32);
                acc = acc.wrapping_add(if da.sign ^ db.sign { v.wrapping_neg() } else { v });
            }
        }
    }
    i128_fixed_to_bits(n, acc)
}

fn fused_sum_bits_reg(n: u32, xs: &[u64]) -> u64 {
    debug_assert!(fits_in_register(n));
    let fb = frac_bits(n) as i32;
    let qm = qmin(n);
    let mut acc: i128 = 0;
    for &xb in xs {
        match Posit::from_bits(n, xb).unpack() {
            Unpacked::NaR => return nar_bits(n),
            Unpacked::Zero => {}
            Unpacked::Real(d) => {
                let v = (d.sig as i128).wrapping_shl((d.scale - fb - qm) as u32);
                acc = acc.wrapping_add(if d.sign { v.wrapping_neg() } else { v });
            }
        }
    }
    i128_fixed_to_bits(n, acc)
}

/// Datapath-tier dot: the limb quire, any width.
pub(crate) fn dot_bits(n: u32, a: &[u64], b: &[u64]) -> u64 {
    let mut q = Quire::new(n).expect("unit widths are validated");
    for (&ab, &bb) in a.iter().zip(b) {
        q.add_product(Posit::from_bits(n, ab), Posit::from_bits(n, bb));
    }
    q.to_posit().to_bits()
}

pub(crate) fn fused_sum_bits(n: u32, xs: &[u64]) -> u64 {
    let mut q = Quire::new(n).expect("unit widths are validated");
    for &xb in xs {
        q.add_posit(Posit::from_bits(n, xb));
    }
    q.to_posit().to_bits()
}

pub(crate) fn axpy_bits(n: u32, alpha: u64, xs: &[u64], ys: &[u64]) -> u64 {
    let pa = Posit::from_bits(n, alpha);
    let mut q = Quire::new(n).expect("unit widths are validated");
    for (&xb, &yb) in xs.iter().zip(ys) {
        q.add_product(pa, Posit::from_bits(n, xb));
        q.add_posit(Posit::from_bits(n, yb));
    }
    q.to_posit().to_bits()
}

/// Fast-tier dot: in-register accumulator where the quire fits one
/// `i128`, otherwise the same limb walk (bit-identical either way).
pub(crate) fn dot_bits_fast(n: u32, a: &[u64], b: &[u64]) -> u64 {
    if fits_in_register(n) {
        dot_bits_reg(n, a, b)
    } else {
        dot_bits(n, a, b)
    }
}

pub(crate) fn fused_sum_bits_fast(n: u32, xs: &[u64]) -> u64 {
    if fits_in_register(n) {
        fused_sum_bits_reg(n, xs)
    } else {
        fused_sum_bits(n, xs)
    }
}

pub(crate) fn axpy_bits_fast(n: u32, alpha: u64, xs: &[u64], ys: &[u64]) -> u64 {
    if fits_in_register(n) {
        let fb = frac_bits(n) as i32;
        let qm = qmin(n);
        let pa = Posit::from_bits(n, alpha);
        if pa.is_nar() {
            return nar_bits(n);
        }
        let mut acc: i128 = 0;
        for (&xb, &yb) in xs.iter().zip(ys) {
            let (px, py) = (Posit::from_bits(n, xb), Posit::from_bits(n, yb));
            if px.is_nar() || py.is_nar() {
                return nar_bits(n);
            }
            if !pa.is_zero() && !px.is_zero() {
                let (da, dx) = (pa.decode(), px.decode());
                let mag = (da.sig as u128 * dx.sig as u128) as i128;
                let v = mag.wrapping_shl((da.scale + dx.scale - 2 * fb - qm) as u32);
                acc = acc.wrapping_add(if da.sign ^ dx.sign { v.wrapping_neg() } else { v });
            }
            if !py.is_zero() {
                let d = py.decode();
                let v = (d.sig as i128).wrapping_shl((d.scale - fb - qm) as u32);
                acc = acc.wrapping_add(if d.sign { v.wrapping_neg() } else { v });
            }
        }
        i128_fixed_to_bits(n, acc)
    } else {
        axpy_bits(n, alpha, xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::mask;
    use crate::testkit::Rng;

    #[test]
    fn quire_geometry_matches_the_standard() {
        for (n, bits) in [(8u32, 128u32), (16, 512), (32, 2048)] {
            assert_eq!(Quire::new(n).unwrap().bits(), bits);
        }
        // narrow widths clamp to two limbs, still covering maxpos²
        assert_eq!(Quire::new(4).unwrap().bits(), 128);
        assert!(Quire::new(3).is_err());
        assert!(Quire::new(65).is_err());
        assert!(fits_in_register(8) && !fits_in_register(9));
    }

    #[test]
    fn single_values_round_trip_exactly() {
        for n in [8u32, 16, 32] {
            for bits in 0..=mask(8) {
                let p = Posit::from_bits(n, bits);
                let mut q = Quire::new(n).unwrap();
                q.add_posit(p);
                assert_eq!(q.to_posit(), p, "n={n} bits={bits:#x}");
                // and one·p as a product
                q.clear();
                q.add_product(Posit::one(n), p);
                assert_eq!(q.to_posit(), p, "n={n} 1*{bits:#x}");
            }
        }
    }

    #[test]
    fn exact_cancellation_and_nar_latching() {
        let n = 16;
        let mut q = Quire::new(n).unwrap();
        let x = Posit::from_f64(n, 1.5);
        let y = Posit::from_f64(n, -123.25);
        q.add_posit(x);
        q.add_posit(y);
        q.sub_posit(y);
        q.sub_posit(x);
        assert!(q.is_zero());
        assert_eq!(q.to_posit(), Posit::zero(n));
        q.add_posit(Posit::nar(n));
        assert!(q.is_nar() && q.to_posit().is_nar());
        q.clear();
        assert!(q.is_zero());
    }

    #[test]
    fn extreme_products_stay_in_range() {
        for n in [4u32, 8, 16, 32] {
            let maxpos = Posit::maxpos(n);
            let minpos = Posit::minpos(n);
            let mut q = Quire::new(n).unwrap();
            q.add_product(maxpos, maxpos);
            // maxpos² saturates back to maxpos on rounding
            assert_eq!(q.to_posit(), maxpos, "n={n}");
            q.clear();
            q.add_product(minpos, minpos);
            // minpos² is below minpos; posit rounding never hits zero
            assert_eq!(q.to_posit(), minpos, "n={n}");
            q.clear();
            q.add_product(maxpos, maxpos);
            q.add_product(maxpos.neg(), maxpos);
            assert!(q.is_zero(), "n={n}: exact cancellation of maxpos²");
        }
    }

    #[test]
    fn dot_is_permutation_invariant_and_fold_is_not_promised() {
        let n = 16;
        let mut rng = Rng::seeded(0xD07);
        for _ in 0..200 {
            let k = 3 + rng.below(8) as usize;
            let mut a: Vec<Posit> = (0..k)
                .map(|_| Posit::from_bits(n, rng.next_u64() & mask(n)))
                .filter(|p| !p.is_nar())
                .collect();
            while a.len() < k {
                a.push(Posit::one(n));
            }
            let b: Vec<Posit> = a.iter().rev().copied().collect();
            let fwd = dot(&a, &b).unwrap();
            let mut ar: Vec<Posit> = a.clone();
            let mut br: Vec<Posit> = b.clone();
            ar.reverse();
            br.reverse();
            assert_eq!(fwd, dot(&ar, &br).unwrap());
        }
    }

    #[test]
    fn register_kernels_match_limb_kernels() {
        let n = 8;
        let mut rng = Rng::seeded(0x2E6);
        for _ in 0..500 {
            let k = 1 + rng.below(12) as usize;
            let a: Vec<u64> = (0..k).map(|_| rng.next_u64() & mask(n)).collect();
            let b: Vec<u64> = (0..k).map(|_| rng.next_u64() & mask(n)).collect();
            let alpha = rng.next_u64() & mask(n);
            assert_eq!(dot_bits_fast(n, &a, &b), dot_bits(n, &a, &b));
            assert_eq!(fused_sum_bits_fast(n, &a), fused_sum_bits(n, &a));
            assert_eq!(axpy_bits_fast(n, alpha, &a, &b), axpy_bits(n, alpha, &a, &b));
        }
    }

    #[test]
    fn reduction_shape_errors_are_typed() {
        let n = 16;
        let one = Posit::one(n);
        assert!(matches!(
            dot(&[one, one], &[one]),
            Err(PositError::BatchLaneMismatch { lane: "b", expected: 2, got: 1 })
        ));
        assert!(matches!(
            fused_sum(&[]),
            Err(PositError::BatchLaneMismatch { lane: "a", .. })
        ));
        assert!(matches!(
            dot(&[one], &[Posit::one(8)]),
            Err(PositError::WidthMismatch { expected: 16, got: 8 })
        ));
    }

    #[test]
    fn gemm_entries_are_quire_dots() {
        let n = 16;
        let mut rng = Rng::seeded(0x6E);
        let (m, k, p) = (3usize, 17usize, 11usize);
        let real = |rng: &mut Rng| loop {
            let p = Posit::from_bits(n, rng.next_u64() & mask(n));
            if !p.is_nar() {
                return p;
            }
        };
        let a: Vec<Posit> = (0..m * k).map(|_| real(&mut rng)).collect();
        let b: Vec<Posit> = (0..k * p).map(|_| real(&mut rng)).collect();
        let c = gemm(&a, &b, m, k, p).unwrap();
        for i in 0..m {
            for j in 0..p {
                let row: Vec<Posit> = (0..k).map(|t| a[i * k + t]).collect();
                let col: Vec<Posit> = (0..k).map(|t| b[t * p + j]).collect();
                assert_eq!(c[i * p + j], dot(&row, &col).unwrap(), "({i},{j})");
            }
        }
        assert!(gemm(&a, &b, m, k + 1, p).is_err());
    }
}
