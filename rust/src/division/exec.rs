//! The common posit division wrapper (Fig. 2 of the paper).
//!
//! Everything outside the fraction recurrence is identical for every
//! algorithm and implemented once here, mirroring the shared decode /
//! exponent-subtract / normalize / round blocks of the hardware:
//!
//! 1. special-case detection (zero, NaR),
//! 2. sign: `s_Q = s_X ⊕ s_D`,
//! 3. scale subtraction `T = 4(k_X − k_D) + e_X − e_D` (Eq. (7)) — the
//!    regime/exponent split of Eqs. (8)–(9) happens inside the encoder,
//! 4. the per-algorithm significand recurrence (`DivEngine::fraction_divide`),
//! 5. normalization (`q ∈ [1/2,2) → [1,2)`, decrementing the exponent), and
//! 6. regime-aware rounding with the remainder sticky (§III-F, Table III).

use super::{latency_cycles, DivEngine, Division};
use crate::posit::{round::encode_round, Posit, Unpacked};

/// Cycles consumed by the special-case fast path (decode + detect + encode).
/// Shared with [`crate::unit`], whose single-pass arithmetic ops model
/// their latency as this cost plus datapath stages.
pub const SPECIAL_CYCLES: u32 = 3;

/// Run a full posit division through `engine`'s fraction datapath.
pub fn divide_with<E: DivEngine + ?Sized>(engine: &E, x: Posit, d: Posit) -> Division {
    assert_eq!(x.width(), d.width(), "operand width mismatch");
    let n = x.width();
    let (a, b) = match (x.unpack(), d.unpack()) {
        (Unpacked::NaR, _) | (_, Unpacked::NaR) | (_, Unpacked::Zero) => {
            return Division { result: Posit::nar(n), iterations: 0, cycles: SPECIAL_CYCLES }
        }
        (Unpacked::Zero, _) => {
            return Division { result: Posit::zero(n), iterations: 0, cycles: SPECIAL_CYCLES }
        }
        (Unpacked::Real(a), Unpacked::Real(b)) => (a, b),
    };

    let fq = engine.fraction_divide(n, a.sig, b.sig);
    debug_assert!(fq.mag >> (fq.frac_bits - 1) != 0, "quotient below 1/2: {fq:?}");
    debug_assert!(fq.mag >> (fq.frac_bits + 1) == 0, "quotient ≥ 2: {fq:?}");

    let sign = a.sign ^ b.sign;
    let t = a.scale - b.scale; // Eq. (7)
    // Normalization (§III-F step 3): q ∈ [1/2,1) ⇒ shift left / decrement.
    let (scale, sfb) = if fq.mag >> fq.frac_bits != 0 {
        (t, fq.frac_bits)
    } else {
        (t - 1, fq.frac_bits - 1)
    };
    Division {
        result: encode_round(n, sign, scale, fq.mag, sfb, fq.sticky),
        iterations: fq.iterations,
        cycles: latency_cycles(n, engine.algorithm()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::{Algorithm, FracQuotient};

    /// A fake engine delegating to the golden fraction divider: checks the
    /// wrapper logic in isolation.
    struct GoldenEngine;
    impl DivEngine for GoldenEngine {
        fn name(&self) -> &'static str {
            "golden-wrapped"
        }
        fn algorithm(&self) -> Algorithm {
            Algorithm::Nrd
        }
        fn fraction_divide(&self, n: u32, x: u64, d: u64) -> FracQuotient {
            crate::division::golden::frac_divide(n, x, d)
        }
    }

    #[test]
    fn wrapper_specials() {
        let n = 16;
        let e = GoldenEngine;
        let one = Posit::one(n);
        assert!(e.divide(one, Posit::zero(n)).result.is_nar());
        assert!(e.divide(Posit::nar(n), one).result.is_nar());
        assert!(e.divide(Posit::zero(n), one).result.is_zero());
        assert_eq!(e.divide(Posit::zero(n), Posit::zero(n)).result, Posit::nar(n));
        assert_eq!(e.divide(one, Posit::zero(n)).cycles, SPECIAL_CYCLES);
    }

    #[test]
    fn wrapper_matches_golden_divide_p8_exhaustive() {
        let n = 8;
        let e = GoldenEngine;
        for xb in 0..=crate::posit::mask(n) {
            for db in 0..=crate::posit::mask(n) {
                let x = Posit::from_bits(n, xb);
                let d = Posit::from_bits(n, db);
                assert_eq!(
                    e.divide(x, d).result,
                    crate::division::golden::divide(x, d).result,
                    "{x:?}/{d:?}"
                );
            }
        }
    }

    #[test]
    fn signs_and_exponents() {
        let n = 32;
        let e = GoldenEngine;
        let cases: [(f64, f64); 8] = [
            (355.0, 113.0),
            (-355.0, 113.0),
            (355.0, -113.0),
            (-355.0, -113.0),
            (1.0, 3.0),
            (1e6, 1e-6),
            (6.25e-2, 5.0e3),
            (2.0, 2.0),
        ];
        for (xv, dv) in cases {
            let x = Posit::from_f64(n, xv);
            let d = Posit::from_f64(n, dv);
            let q = e.divide(x, d).result;
            // correct rounding is checked exhaustively elsewhere; here we
            // sanity-check the exponent/sign plumbing: the result must be
            // within 1 ulp of the f64 quotient rounded to posit (relative
            // accuracy shrinks with long regimes, e.g. 1e6/1e-6).
            let want = Posit::from_f64(n, xv / dv);
            assert!(q.ulp_distance(want) <= 1, "{xv}/{dv} -> {} want {}", q.to_f64(), want.to_f64());
            assert_eq!(q.is_negative(), (xv / dv) < 0.0);
        }
    }
}
