//! Quotient-digit selection functions (§III-D).
//!
//! All selections operate on *truncated* residual estimates, in the exact
//! bit positions the paper states:
//!
//! * radix-2, non-redundant (Eq. (26)): shifted residual truncated to one
//!   fractional bit (units of 1/2) — constants ±1/2.
//! * radix-2, carry-save (Eq. (27)): each CS word truncated to 3 integer +
//!   1 fractional bit, added (4-bit adder) — estimate error < 2·2^−1.
//! * radix-4, carry-save (Eq. (28)): divisor truncated to 4 fractional
//!   bits (8 intervals of [1/2,1)), estimate to 4 fractional bits (units
//!   of 1/16); the `m_k(d̂)` constants are *derived* at construction from
//!   the exact containment conditions of Ercegovac & Lang and verified
//!   feasible — see [`Srt4Table::derive`].
//! * radix-4 scaled (Eq. (29)): divisor-independent constants on a 6-bit
//!   estimate (3 integer + 3 fractional, units of 1/8).
//!
//! Digit-set redundancy: ρ = a/(r−1) (Eq. (12)); radix-2 uses a=1 (ρ=1),
//! radix-4 uses the minimally-redundant a=2 (ρ=2/3) as the paper chooses.

/// Eq. (26): radix-2, non-redundant residual. `t` = shifted residual
/// truncated to 1 fractional bit, i.e. `t = ⌊2w(i) · 2⌋` in units of 1/2.
#[inline]
pub fn sel_srt2_nonredundant(t: i64) -> i32 {
    if t >= 1 {
        // 2w(i) ≥ 1/2
        1
    } else if t >= -1 {
        // −1/2 ≤ 2w(i) < 1/2
        0
    } else {
        -1
    }
}

/// Eq. (27): radix-2, carry-save residual. `t` = sum of the two CS words
/// each truncated to 1 fractional bit (units of 1/2; estimate error < 1).
#[inline]
pub fn sel_srt2_cs(t: i64) -> i32 {
    if t >= 0 {
        1
    } else if t == -1 {
        // t = −1/2
        0
    } else {
        // −5/2 < 2w(i) < −1
        -1
    }
}

/// Eq. (29): radix-4 with scaled operands (divisor ∈ [1−1/64, 1+1/8]).
/// `t` = CS estimate truncated to 3 fractional bits (units of 1/8).
#[inline]
pub fn sel_srt4_scaled(t: i64) -> i32 {
    if t >= 12 {
        // ≥ 3/2
        2
    } else if t >= 4 {
        // ≥ 1/2
        1
    } else if t >= -4 {
        // ≥ −1/2
        0
    } else if t >= -13 {
        // ≥ −13/8
        -1
    } else {
        -2
    }
}

/// Radix-4, a=2 selection table (Eq. (28)): thresholds `m_k(d̂)` for
/// k ∈ {−1, 0, 1, 2}, in units of 1/16, one row per divisor interval
/// `d ∈ [i/16, (i+1)/16)`, i = 8..15. Digit −2 is chosen below `m_{−1}`.
#[derive(Clone, Debug)]
pub struct Srt4Table {
    /// `m[i-8] = [m_{-1}, m_0, m_1, m_2]` in sixteenths.
    pub m: [[i32; 4]; 8],
}

/// ρ numerator/denominator for a=2, r=4: ρ = 2/3.
const RHO_NUM: i64 = 2;
const RHO_DEN: i64 = 3;

impl Srt4Table {
    /// Derive feasible selection constants from the containment conditions.
    ///
    /// For each divisor interval `[d_lo, d_hi] = [i, i+1]/16` and digit k,
    /// the threshold `m_k` (units 1/16) must satisfy:
    ///
    /// * containment-from-below: `m_k/16 ≥ L_k(d) = (k−ρ)d` for all d in
    ///   the interval, and
    /// * containment-from-above of the digit-(k−1) region:
    ///   `(m_k + 1)/16 ≤ U_{k−1}(d) = (k−1+ρ)d` for all d — the `+1`
    ///   absorbs the carry-save estimate error (< 2/16) minus the estimate
    ///   granularity (1/16): a residual with estimate `t ≤ m_k − 1` has
    ///   true value `y < (m_k + 1)/16`.
    ///
    /// The derivation uses exact integer arithmetic (everything is a
    /// multiple of 1/48) and panics if any interval is infeasible — i.e.
    /// it *proves* the P-D diagram feasibility the paper relies on.
    pub fn derive() -> Srt4Table {
        let mut m = [[0i32; 4]; 8];
        for i in 8..16i64 {
            for (slot, k) in (-1i64..=2).enumerate() {
                // L_k(d)·48 = (3k−2)·d16·3 /3… work in units of 1/48:
                // L_k(d) = (k − 2/3)·(d16/16) → ·48 = (3k−2)·d16.
                let lnum = 3 * k - RHO_NUM; // (3k−2), since ρ=2/3
                let l_at = |d16: i64| lnum * d16; // in 1/48 units... (·RHO_DEN/16 scale)
                let lmax = l_at(i).max(l_at(i + 1));
                // lower bound in 1/16 units: m_k ≥ lmax/3 → ceil
                let lb = div_ceil_i64(lmax, RHO_DEN);

                // U_{k−1}(d)·48 = (3(k−1)+2)·d16 = (3k−1)·d16.
                let unum = 3 * k - 1;
                let u_at = |d16: i64| unum * d16;
                let umin = u_at(i).min(u_at(i + 1));
                // (m_k + 1)/16 ≤ umin/48 ⇔ 3(m_k+1) ≤ umin ⇔
                // m_k ≤ ⌊(umin − 3)/3⌋.
                let ub = div_floor_i64(umin - RHO_DEN, RHO_DEN);

                assert!(
                    lb <= ub,
                    "SRT-4 selection infeasible: interval {i}/16, digit {k}: [{lb},{ub}]"
                );
                // Pick the smallest feasible threshold (any feasible value
                // is correct; smaller thresholds bias toward larger digits).
                m[(i - 8) as usize][slot] = lb as i32;
            }
            // Thresholds must be strictly increasing for max-select.
            let row = m[(i - 8) as usize];
            assert!(row[0] < row[1] && row[1] < row[2] && row[2] < row[3], "non-monotone {row:?}");
        }
        Srt4Table { m }
    }

    /// Select digit for divisor interval index `dhat ∈ [8,15]` (the 4-bit
    /// truncation of d ∈ [1/2,1)) and residual estimate `t` in 1/16 units.
    #[inline]
    pub fn select(&self, dhat: u32, t: i64) -> i32 {
        debug_assert!((8..16).contains(&dhat));
        let row = &self.m[dhat as usize - 8];
        if t >= row[3] as i64 {
            2
        } else if t >= row[2] as i64 {
            1
        } else if t >= row[1] as i64 {
            0
        } else if t >= row[0] as i64 {
            -1
        } else {
            -2
        }
    }
}

/// Generalized radix-4 threshold derivation for digit set [-a, a]
/// (ρ = a/3): returns, per divisor interval i ∈ [8,15], the thresholds
/// m_k for k ∈ [-a+1, a] in 1/16 units, or None if some interval is
/// infeasible at the 4-bit estimate granularity. Used by the a=2 vs a=3
/// ablation (the paper picks a=2; a=3 trades easier selection for a 3d
/// multiple generator).
pub fn derive_radix4_thresholds(a: i64) -> Option<Vec<Vec<i32>>> {
    assert!((2..=3).contains(&a));
    let rho_num = a; // ρ = a/3
    let mut rows = Vec::new();
    for i in 8..16i64 {
        let mut row = Vec::new();
        let mut prev = i64::MIN;
        for k in (-a + 1)..=a {
            let lnum = 3 * k - rho_num;
            let lmax = (lnum * i).max(lnum * (i + 1));
            let lb = div_ceil_i64(lmax, 3);
            let unum = 3 * (k - 1) + rho_num;
            let umin = (unum * i).min(unum * (i + 1));
            let ub = div_floor_i64(umin - 3, 3);
            if lb > ub || lb <= prev {
                return None;
            }
            prev = lb;
            row.push(lb as i32);
        }
        rows.push(row);
    }
    Some(rows)
}

/// Global table (derived once; the hardware holds it as a small PLA).
pub fn srt4_table() -> &'static Srt4Table {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Srt4Table> = OnceLock::new();
    TABLE.get_or_init(Srt4Table::derive)
}

#[inline]
fn div_ceil_i64(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    if a >= 0 {
        (a + b - 1) / b
    } else {
        a / b
    }
}

#[inline]
fn div_floor_i64(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    if a >= 0 {
        a / b
    } else {
        -((-a + b - 1) / b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srt2_nonredundant_matches_eq26() {
        // t in units of 1/2 (floor-truncated 2w).
        assert_eq!(sel_srt2_nonredundant(3), 1); // 2w in [3/2,2)
        assert_eq!(sel_srt2_nonredundant(1), 1); // [1/2,1)
        assert_eq!(sel_srt2_nonredundant(0), 0); // [0,1/2)
        assert_eq!(sel_srt2_nonredundant(-1), 0); // [-1/2,0)
        assert_eq!(sel_srt2_nonredundant(-2), -1); // [-1,-1/2)
        assert_eq!(sel_srt2_nonredundant(-4), -1);
    }

    #[test]
    fn srt2_cs_matches_eq27() {
        assert_eq!(sel_srt2_cs(3), 1);
        assert_eq!(sel_srt2_cs(0), 1);
        assert_eq!(sel_srt2_cs(-1), 0);
        assert_eq!(sel_srt2_cs(-2), -1);
        assert_eq!(sel_srt2_cs(-5), -1);
    }

    #[test]
    fn srt4_scaled_matches_eq29() {
        assert_eq!(sel_srt4_scaled(24), 2); // 3
        assert_eq!(sel_srt4_scaled(12), 2); // 3/2
        assert_eq!(sel_srt4_scaled(11), 1); // 11/8
        assert_eq!(sel_srt4_scaled(4), 1); // 1/2
        assert_eq!(sel_srt4_scaled(3), 0); // 3/8
        assert_eq!(sel_srt4_scaled(-4), 0); // -1/2
        assert_eq!(sel_srt4_scaled(-5), -1); // -5/8
        assert_eq!(sel_srt4_scaled(-13), -1); // -13/8
        assert_eq!(sel_srt4_scaled(-14), -2); // -7/4
        assert_eq!(sel_srt4_scaled(-26), -2); // -13/4
    }

    #[test]
    fn srt4_table_is_feasible_and_sane() {
        let t = srt4_table();
        // Spot-check against the classic Ercegovac–Lang shape: m_2 for the
        // first interval (d ∈ [1/2, 9/16)) is 12/16 = 3/4.
        assert_eq!(t.m[0][3], 12);
        // Rows are monotone in d for positive digits: larger divisors push
        // positive thresholds up.
        for k in 0..4 {
            for i in 1..8 {
                if t.m[i][k] < t.m[i - 1][k] {
                    // thresholds may plateau but for m_2 must not decrease
                    assert!(k != 3, "m_2 decreased: {:?}", t.m);
                }
            }
        }
    }

    /// Exhaustive verification of the derived radix-4 table against the
    /// exact containment condition — the "P-D diagram" check. For every
    /// divisor on a fine grid and every reachable residual y = 4w(i) with
    /// |w(i)| ≤ ρd, the digit k chosen from the truncated CS estimate must
    /// keep |y − k·d| ≤ ρd.
    #[test]
    fn srt4_table_pd_diagram_exhaustive() {
        let table = srt4_table();
        // work in units of 1/3840 = 1/(16·240): d grid step 1/240 keeps
        // everything integral: d = j/240, y values on 1/256 grid scaled.
        // Simpler: rational check with i128: d_num/d_den, y_num/y_den.
        let yden = 1i128 << 10; // y grid 1/1024
        for d1920 in 960..1920i128 {
            // d = d1920/1920 ∈ [1/2, 1)
            let dhat = (d1920 * 16 / 1920) as u32; // 4-bit truncation
            // y ∈ [−8/3 d, 8/3 d]: iterate y on the 1/1024 grid
            let ymax = 8 * d1920 * yden / (3 * 1920); // floor of 8/3 d · yden
            let mut y = -ymax;
            while y <= ymax {
                // CS truncated estimate: the pair of words can place the
                // estimate anywhere in (y·16/yden − 2, y·16/yden]: check the
                // worst cases t = ⌈16y/yden⌉−2 … ⌊16y/yden⌋.
                let tfloor = div_floor_i64((y * 16) as i64, yden as i64);
                for t in (tfloor - 1)..=tfloor {
                    // estimate t reachable iff y − t/16 ∈ [0, 2/16)
                    // i.e. t ≤ 16y/yden < t+2
                    let lhs = t as i128 * yden;
                    if !(lhs <= 16 * y && 16 * y < lhs + 2 * yden) {
                        continue;
                    }
                    let k = table.select(dhat, t) as i128;
                    // containment: |y − k·d| ≤ ρ·d ⇔
                    // |y·3·1920 − k·d1920·3·yden| ≤ 2·d1920·yden
                    let lhs2 = (3 * y * 1920 - 3 * k * d1920 * yden).abs();
                    assert!(
                        lhs2 <= 2 * d1920 * yden,
                        "containment violated: d={d1920}/1920 y={y}/{yden} t={t} k={k}"
                    );
                }
                y += 1;
            }
        }
    }

    #[test]
    fn div_helpers() {
        assert_eq!(div_ceil_i64(7, 3), 3);
        assert_eq!(div_ceil_i64(-7, 3), -2);
        assert_eq!(div_ceil_i64(6, 3), 2);
        assert_eq!(div_floor_i64(7, 3), 2);
        assert_eq!(div_floor_i64(-7, 3), -3);
        assert_eq!(div_floor_i64(-6, 3), -2);
    }
}

#[cfg(test)]
mod dump_table {
    #[test]
    #[ignore]
    fn print_table() {
        let t = super::srt4_table();
        for row in &t.m {
            println!("{row:?}");
        }
    }
}

#[cfg(test)]
mod ablation_tests {
    use super::*;

    #[test]
    fn generalized_derivation_matches_table_for_a2() {
        let rows = derive_radix4_thresholds(2).expect("a=2 feasible");
        let t = srt4_table();
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.as_slice(), &t.m[i], "interval {}", i + 8);
        }
    }

    #[test]
    fn a3_is_also_feasible_with_wider_digit_set() {
        // maximum redundancy ρ=1: feasible, 6 thresholds per interval
        let rows = derive_radix4_thresholds(3).expect("a=3 feasible");
        assert_eq!(rows[0].len(), 6);
    }
}
