//! PJRT runtime: load and execute the AOT-compiled division graphs.
//!
//! `make artifacts` (the only step that runs Python) lowers the L2 JAX
//! graph to HLO *text* under `artifacts/`; this module loads those files
//! through the `xla` crate (`PjRtClient::cpu` → `HloModuleProto::
//! from_text_file` → compile → execute), caching one compiled executable
//! per (format, batch) variant. After that, division requests run entirely
//! in-process with Python nowhere on the path.
//!
//! ## The `xla` feature
//!
//! The PJRT client lives behind `#[cfg(feature = "xla")]`. The feature is
//! **off by default** because the offline build environment has neither
//! the `xla` crate nor `libxla_extension.so`; enabling it requires
//! supplying the crate (vendored or `[patch]`-ed) in addition to
//! `--features xla`. Without it, artifact *discovery* still works (it is
//! pure std), but [`Runtime::load`] returns
//! [`PositError::BackendUnavailable`] so callers — the coordinator, the
//! e2e bench, the integration tests — degrade gracefully to the native
//! engines.

use std::path::{Path, PathBuf};

use crate::error::{PositError, Result};

/// One AOT-compiled variant: `div_p{n}_b{batch}.hlo.txt`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Variant {
    pub n: u32,
    pub batch: usize,
    pub path: PathBuf,
}

/// Parse `div_p{n}_b{batch}.hlo.txt` names (manifest-free discovery, so a
/// partially-written manifest can never wedge the service).
pub fn parse_artifact_name(name: &str) -> Option<(u32, usize)> {
    let rest = name.strip_prefix("div_p")?.strip_suffix(".hlo.txt")?;
    let (n, b) = rest.split_once("_b")?;
    Some((n.parse().ok()?, b.parse().ok()?))
}

/// Discover artifacts in a directory.
pub fn discover(dir: &Path) -> Result<Vec<Variant>> {
    let entries = std::fs::read_dir(dir).map_err(|e| PositError::Artifacts {
        detail: format!("artifact dir {dir:?} (run `make artifacts`): {e}"),
    })?;
    let mut out = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| PositError::Artifacts {
            detail: format!("reading artifact dir {dir:?}: {e}"),
        })?;
        let name = entry.file_name();
        if let Some((n, batch)) = parse_artifact_name(&name.to_string_lossy()) {
            out.push(Variant { n, batch, path: entry.path() });
        }
    }
    out.sort_by_key(|v| (v.n, v.batch));
    if out.is_empty() {
        return Err(PositError::Artifacts {
            detail: format!("no artifacts found in {dir:?} (run `make artifacts`)"),
        });
    }
    Ok(out)
}

/// Pick the smallest variant of format `n` with batch ≥ `len` (falling
/// back to the largest available — callers then chunk).
fn select_variant<'a>(variants: &'a [Variant], n: u32, len: usize) -> Result<&'a Variant> {
    let mut candidates: Vec<&Variant> = variants.iter().filter(|v| v.n == n).collect();
    if candidates.is_empty() {
        let mut formats: Vec<u32> = variants.iter().map(|v| v.n).collect();
        formats.dedup();
        return Err(PositError::Artifacts {
            detail: format!("no artifact for Posit{n} (have {formats:?})"),
        });
    }
    candidates.sort_by_key(|v| v.batch);
    Ok(candidates.iter().find(|v| v.batch >= len).unwrap_or_else(|| {
        candidates.last().expect("candidates is non-empty")
    }))
}

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::{Arc, Mutex};

    use super::{discover, select_variant, Variant};
    use crate::error::{PositError, Result};
    use crate::posit::{mask, Posit};

    fn exec_err(detail: String) -> PositError {
        PositError::Execution { detail }
    }

    /// The PJRT execution runtime.
    pub struct Runtime {
        client: xla::PjRtClient,
        variants: Vec<Variant>,
        compiled: Mutex<HashMap<(u32, usize), Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        /// CPU PJRT client over the artifacts in `dir`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
            let variants = discover(dir.as_ref())?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| exec_err(format!("PJRT client: {e:?}")))?;
            Ok(Runtime { client, variants, compiled: Mutex::new(HashMap::new()) })
        }

        /// Formats available in the artifact set.
        pub fn formats(&self) -> Vec<u32> {
            let mut ns: Vec<u32> = self.variants.iter().map(|v| v.n).collect();
            ns.dedup();
            ns
        }

        /// Pick the best variant for a (format, batch-length) request.
        pub fn variant_for(&self, n: u32, len: usize) -> Result<&Variant> {
            select_variant(&self.variants, n, len)
        }

        fn executable(&self, v: &Variant) -> Result<Arc<xla::PjRtLoadedExecutable>> {
            let key = (v.n, v.batch);
            if let Some(exe) = self.compiled.lock().unwrap().get(&key) {
                return Ok(exe.clone());
            }
            // compile outside the lock (slow), insert after
            let proto = xla::HloModuleProto::from_text_file(
                v.path.to_str().ok_or_else(|| exec_err("non-utf8 path".into()))?,
            )
            .map_err(|e| exec_err(format!("parse {:?}: {e:?}", v.path)))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Arc::new(
                self.client
                    .compile(&comp)
                    .map_err(|e| exec_err(format!("compile {:?}: {e:?}", v.path)))?,
            );
            self.compiled.lock().unwrap().entry(key).or_insert_with(|| exe.clone());
            Ok(exe)
        }

        /// Warm the compile cache for every variant of format `n`.
        pub fn warmup(&self, n: u32) -> Result<()> {
            for v in self.variants.clone().iter().filter(|v| v.n == n) {
                self.executable(v)?;
            }
            Ok(())
        }

        /// Execute one batched division of n-bit patterns. Inputs shorter
        /// than the variant batch are padded (with 1.0/1.0) and truncated
        /// on return; longer inputs are chunked.
        pub fn divide_bits(&self, n: u32, x: &[u64], d: &[u64]) -> Result<Vec<u64>> {
            if x.len() != d.len() {
                return Err(PositError::BatchShapeMismatch {
                    xs: x.len(),
                    ds: d.len(),
                    out: x.len(),
                });
            }
            let v = self.variant_for(n, x.len())?.clone();
            let exe = self.executable(&v)?;
            let mut out = Vec::with_capacity(x.len());
            let one = 1i64 << (n - 2);
            for (cx, cd) in x.chunks(v.batch).zip(d.chunks(v.batch)) {
                let mut xv: Vec<i64> = cx.iter().map(|&b| (b & mask(n)) as i64).collect();
                let mut dv: Vec<i64> = cd.iter().map(|&b| (b & mask(n)) as i64).collect();
                xv.resize(v.batch, one);
                dv.resize(v.batch, one);
                let xl = xla::Literal::vec1(&xv);
                let dl = xla::Literal::vec1(&dv);
                let result = exe
                    .execute::<xla::Literal>(&[xl, dl])
                    .map_err(|e| exec_err(format!("execute: {e:?}")))?[0][0]
                    .to_literal_sync()
                    .map_err(|e| exec_err(format!("fetch: {e:?}")))?;
                let tuple =
                    result.to_tuple1().map_err(|e| exec_err(format!("untuple: {e:?}")))?;
                let q: Vec<i64> =
                    tuple.to_vec().map_err(|e| exec_err(format!("to_vec: {e:?}")))?;
                out.extend(q[..cx.len()].iter().map(|&b| b as u64 & mask(n)));
            }
            Ok(out)
        }

        /// Typed wrapper over [`Runtime::divide_bits`].
        pub fn divide(&self, x: &[Posit], d: &[Posit]) -> Result<Vec<Posit>> {
            let n = x.first().map(|p| p.width()).unwrap_or(16);
            let xb: Vec<u64> = x.iter().map(|p| p.to_bits()).collect();
            let db: Vec<u64> = d.iter().map(|p| p.to_bits()).collect();
            Ok(self
                .divide_bits(n, &xb, &db)?
                .into_iter()
                .map(|b| Posit::from_bits(n, b))
                .collect())
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::Runtime;

/// Stub runtime compiled when the `xla` feature is off: artifact
/// discovery still runs (and still reports artifact problems precisely),
/// but loading always ends in [`PositError::BackendUnavailable`], so this
/// type is never actually constructed.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    variants: Vec<Variant>,
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    fn unavailable() -> PositError {
        PositError::BackendUnavailable {
            reason: "PJRT runtime requires the `xla` feature (and the vendored xla crate); \
                     rebuild with `--features xla` or use the native backend"
                .to_string(),
        }
    }

    /// Discover artifacts, then report that no PJRT client exists in this
    /// build. Artifact errors (missing dir, empty dir) surface first so
    /// misconfiguration is still diagnosed exactly.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let _variants = discover(dir.as_ref())?;
        Err(Self::unavailable())
    }

    /// Formats available in the artifact set.
    pub fn formats(&self) -> Vec<u32> {
        let mut ns: Vec<u32> = self.variants.iter().map(|v| v.n).collect();
        ns.dedup();
        ns
    }

    /// Pick the best variant for a (format, batch-length) request.
    pub fn variant_for(&self, n: u32, len: usize) -> Result<&Variant> {
        select_variant(&self.variants, n, len)
    }

    pub fn warmup(&self, _n: u32) -> Result<()> {
        Err(Self::unavailable())
    }

    pub fn divide_bits(&self, _n: u32, _x: &[u64], _d: &[u64]) -> Result<Vec<u64>> {
        Err(Self::unavailable())
    }

    pub fn divide(
        &self,
        _x: &[crate::posit::Posit],
        _d: &[crate::posit::Posit],
    ) -> Result<Vec<crate::posit::Posit>> {
        Err(Self::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_name_parsing() {
        assert_eq!(parse_artifact_name("div_p16_b256.hlo.txt"), Some((16, 256)));
        assert_eq!(parse_artifact_name("div_p32_b1024.hlo.txt"), Some((32, 1024)));
        assert_eq!(parse_artifact_name("manifest.json"), None);
        assert_eq!(parse_artifact_name("div_p16.hlo.txt"), None);
        assert_eq!(parse_artifact_name("div_pXX_bYY.hlo.txt"), None);
    }

    #[test]
    fn select_variant_prefers_smallest_fitting_batch() {
        let v = |n, batch| Variant { n, batch, path: PathBuf::new() };
        let variants = vec![v(16, 256), v(16, 1024), v(32, 256)];
        assert_eq!(select_variant(&variants, 16, 100).unwrap().batch, 256);
        assert_eq!(select_variant(&variants, 16, 300).unwrap().batch, 1024);
        // nothing big enough: fall back to the largest, callers chunk
        assert_eq!(select_variant(&variants, 16, 5000).unwrap().batch, 1024);
        assert!(matches!(
            select_variant(&variants, 64, 1),
            Err(PositError::Artifacts { .. })
        ));
    }

    // Integration tests that need built artifacts live in
    // rust/tests/pjrt_integration.rs (they require `make artifacts` and
    // the `xla` feature).
}
