//! One-stop import for the public API.
//!
//! ```
//! use posit_div::prelude::*;
//!
//! // typed posits with operators
//! let q = P32::round_from(355.0) / P32::round_from(113.0);
//! assert!((q.to_f64() - 355.0 / 113.0).abs() < 1e-6);
//!
//! // a reusable, zero-alloc division context with a batch-first API
//! let div = Divider::new(16, Algorithm::Srt4Cs)?;
//! let mut out = [0u64; 2];
//! div.divide_batch(&[P16::ONE.to_bits(); 2], &[P16::ONE.to_bits(); 2], &mut out)?;
//! assert_eq!(out, [P16::ONE.to_bits(); 2]);
//! # Ok::<(), posit_div::PositError>(())
//! ```

pub use crate::coordinator::{
    Backend, BatchHandle, BatchPolicy, Client, DivisionService, Pending, ServiceConfig,
};
pub use crate::division::{Algorithm, DivEngine, Divider, Division};
pub use crate::error::{PositError, Result};
pub use crate::posit::{Posit, RoundFrom, RoundInto, P16, P32, P64, P8};
