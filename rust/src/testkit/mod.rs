//! Property-based testing substrate.
//!
//! The offline crate set has no `proptest`/`quickcheck`, so this module
//! provides the pieces the test-suite needs: a fast deterministic PRNG
//! ([`Rng`], SplitMix64), a `forall` runner with greedy shrinking
//! ([`forall`]), and posit-aware generators ([`gen`]).

pub mod gen;
pub mod rational;

/// SplitMix64 PRNG — tiny, fast, full-period, deterministic across
/// platforms. Good enough statistical quality for test-case generation and
/// benchmark workloads (not for cryptography).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn seeded(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (n > 0), by rejection to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        if lo == 0 && hi == u64::MAX {
            return self.next_u64();
        }
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[lo, hi]` inclusive over signed values.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo.wrapping_add(self.below((hi.wrapping_sub(lo) as u64).wrapping_add(1).max(1)) as i64)
    }

    #[inline]
    pub fn chance(&mut self, p_num: u64, p_den: u64) -> bool {
        self.below(p_den) < p_num
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick one element of a non-empty slice.
    #[inline]
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Split off an independent generator (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::seeded(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }
}

/// Configuration for [`forall`].
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 1000, seed: 0x5EED_0000_0000_0001, max_shrink_steps: 2000 }
    }
}

impl Config {
    pub fn cases(n: usize) -> Self {
        Config { cases: n, ..Default::default() }
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Run `prop` over `cfg.cases` generated inputs; on failure, greedily
/// shrink using `shrink` (candidate producer) and panic with the minimal
/// failing input and the seed to reproduce.
pub fn forall<T, G, S, P>(cfg: Config, generate: G, shrink: S, prop: P)
where
    T: Clone + core::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::seeded(cfg.seed);
    for case in 0..cfg.cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in shrink(&best) {
                    steps += 1;
                    if steps >= cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break; // no candidate fails: local minimum
            }
            panic!(
                "property failed (case {case}/{}, seed {:#x}):\n  input (shrunk): {best:?}\n  original: {input:?}\n  error: {best_msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// `forall` without shrinking.
pub fn forall_ns<T, G, P>(cfg: Config, generate: G, prop: P)
where
    T: Clone + core::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    forall(cfg, generate, |_| Vec::new(), prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall_ns(Config::cases(100), |r| r.next_u32(), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall_ns(Config::cases(100), |r| r.below(10), |&v| {
            if v < 9 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn shrinking_finds_minimum() {
        // Property: v < 57. Shrinker: halve. Minimal failing value under
        // halving from any failing v is 57..=..., greedy shrink should
        // reach something < 114.
        let result = std::panic::catch_unwind(|| {
            forall(
                Config::cases(1000),
                |r| r.below(10_000),
                |&v| {
                    let mut c = Vec::new();
                    if v > 0 {
                        c.push(v / 2);
                        c.push(v - 1);
                    }
                    c
                },
                |&v| if v < 57 { Ok(()) } else { Err(format!("{v} >= 57")) },
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("(shrunk): 57"), "greedy shrink reached 57: {msg}");
    }

    #[test]
    fn f64_unit_in_range() {
        let mut rng = Rng::seeded(3);
        for _ in 0..1000 {
            let v = rng.f64_unit();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
