//! Figs. 4-6: combinational synthesis sweeps for all Table IV designs —
//! thin shim over [`posit_div::bench::suites`], where the suite body
//! lives so the same code runs under `cargo bench --bench fig4_6_combinational`
//! and `posit-div bench fig4_6_combinational` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("fig4_6_combinational");
}
