//! SRT radix-4 with carry-save residual — the paper's headline contribution
//! (first radix-4 digit-recurrence posit divider).
//!
//! Minimally-redundant digit set {−2,…,2} (a = 2, ρ = 2/3): divisor
//! multiples are {±d, ±2d} (a shift — no 3d generation, the reason the
//! paper picks a=2 over a=3). Quotient-digit selection follows Eq. (28):
//! a 4-bit truncation of the divisor picks a row of `m_k` constants
//! ([`crate::division::selection::Srt4Table`]) compared against a 7-bit
//! carry-save estimate of the shifted residual. Halves the iteration count
//! of every radix-2 variant (Table II).

use super::carry_save::{CsPair, CsPair64};
use super::otf::Otf;
use super::selection::srt4_table;
use super::{iterations, Algorithm, DivEngine, FracQuotient};
use crate::posit::frac_bits;

/// SRT radix-4, carry-save residual, with optional OF / FR optimizations.
pub struct Srt4Cs {
    use_otf: bool,
    use_fr: bool,
}

impl Srt4Cs {
    pub fn plain() -> Self {
        Srt4Cs { use_otf: false, use_fr: false }
    }
    pub fn with_otf() -> Self {
        Srt4Cs { use_otf: true, use_fr: false }
    }
    pub fn with_otf_fr() -> Self {
        Srt4Cs { use_otf: true, use_fr: true }
    }
}

impl DivEngine for Srt4Cs {
    fn name(&self) -> &'static str {
        match (self.use_otf, self.use_fr) {
            (false, _) => "SRT r4 CS",
            (true, false) => "SRT r4 CS OF",
            (true, true) => "SRT r4 CS OF FR",
        }
    }

    fn algorithm(&self) -> Algorithm {
        match (self.use_otf, self.use_fr) {
            (false, _) => Algorithm::Srt4Cs,
            (true, false) => Algorithm::Srt4CsOf,
            (true, true) => Algorithm::Srt4CsOfFr,
        }
    }

    fn fraction_divide(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
        let f = frac_bits(n);
        assert!(n >= 8, "radix-4 engines require n >= 8 (4-bit divisor truncation)");
        debug_assert!(x_sig >> f == 1 && d_sig >> f == 1);
        let it = iterations(n, 4);

        // [1/2,1) convention; FW = F+3 fractional bits so that
        // w(0) = x/4 = x_sig exactly; sign + 3 integer bits of headroom
        // (|4w| < 8/3): total datapath FW+4 — the paper's
        // n−2+log2(r)−⌊ρ⌋ plus the sign-magnitude convention's offset.
        let fw = f + 3;
        let width = fw + 4;
        // Hot path: the whole datapath fits one machine word for n ≤ 57
        // (§Perf: ~1.7x over the u128 reference path; bit-identical, see
        // narrow_path_equals_wide_path).
        if width <= 64 {
            self.frac_divide_narrow(n, x_sig, d_sig, fw, width, it)
        } else {
            self.frac_divide_wide(n, x_sig, d_sig, fw, width, it)
        }
    }
}

impl Srt4Cs {
    /// Reference (u128) datapath — kept for the §Perf ablation and for
    /// widths whose datapath exceeds one machine word.
    #[doc(hidden)]
    pub fn frac_divide_wide_for_bench(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
        let f = frac_bits(n);
        let fw = f + 3;
        self.frac_divide_wide(n, x_sig, d_sig, fw, fw + 4, iterations(n, 4))
    }

    fn frac_divide_wide(
        &self,
        n: u32,
        x_sig: u64,
        d_sig: u64,
        fw: u32,
        width: u32,
        it: u32,
    ) -> FracQuotient {
        let f = frac_bits(n);
        let table = srt4_table();
        let d_fp = (d_sig as u128) << 2;
        // Eq. (28) divisor truncation: 4 fractional bits of d ∈ [1/2,1).
        let dhat = (d_sig >> (f - 3)) as u32;
        debug_assert!((8..16).contains(&dhat));

        let mut w = CsPair::from_value(x_sig as i128, width);
        let mut q_acc: i128 = 0;
        let mut otf = Otf::new(2);

        for _ in 0..it {
            let shifted = w.shl(2);
            // 7-bit estimate: each word truncated to 4 fractional bits.
            let t = shifted.estimate(fw - 4);
            debug_assert!((-64..64).contains(&t), "estimate {t} overflows 7-bit slice");
            let digit = table.select(dhat, t);
            w = match digit {
                2 => shifted.csa(!(d_fp << 1), true),
                1 => shifted.csa(!d_fp, true),
                -1 => shifted.csa(d_fp, false),
                -2 => shifted.csa(d_fp << 1, false),
                _ => shifted,
            };
            if self.use_otf {
                otf.push(digit);
            } else {
                q_acc = 4 * q_acc + digit as i128;
            }
            // ρ = 2/3 bound: 3|w| ≤ 2d.
            debug_assert!(
                3 * w.resolve().abs() <= 2 * d_fp as i128,
                "SRT4-CS residual out of bound"
            );
        }

        let (neg, rem_zero) = if self.use_fr {
            let neg = w.sign_lookahead();
            let zero =
                if neg { w.is_zero_with_addend(d_fp) } else { w.is_zero_lookahead() };
            (neg, zero)
        } else {
            let r = w.resolve();
            let rem = if r < 0 { r + d_fp as i128 } else { r };
            (r < 0, rem == 0)
        };

        let mag = if self.use_otf {
            otf.result(neg)
        } else {
            (q_acc - neg as i128) as u128
        };
        // q_total = 4·q(It) = mag·2^−(2It−2) ∈ (1/2, 2).
        FracQuotient {
            mag,
            frac_bits: 2 * it - 2,
            sticky: !rem_zero,
            iterations: it,
        }
    }

    /// Machine-word datapath — bit-identical to the wide path (§Perf).
    ///
    /// Fully branchless inner loop: the quotient digit is data-dependent
    /// and mispredicts badly as a 5-way branch, so the divisor-multiple
    /// selection, the CSA subtraction and the on-the-fly conversion are
    /// all computed with masks and conditional moves.
    fn frac_divide_narrow(
        &self,
        n: u32,
        x_sig: u64,
        d_sig: u64,
        fw: u32,
        width: u32,
        it: u32,
    ) -> FracQuotient {
        let f = frac_bits(n);
        let table = srt4_table();
        let d_fp = d_sig << 2;
        let dhat = (d_sig >> (f - 3)) as u32;
        debug_assert!((8..16).contains(&dhat));
        let row = &table.m[dhat as usize - 8];
        let (m_n1, m_0, m_1, m_2) =
            (row[0] as i64, row[1] as i64, row[2] as i64, row[3] as i64);

        let m = super::carry_save::wmask64(width);
        let drop = fw - 4;
        let slice_bits = width - drop; // 8-bit slice; sign-extend constant
        let slice_sign = 1u64 << (slice_bits - 1);
        let slice_mask = (1u64 << slice_bits) - 1;

        let (mut ws, mut wc) = (x_sig & m, 0u64);
        let (mut q, mut qd) = (0u64, 0u64);
        let mut q_acc: i64 = 0;

        for _ in 0..it {
            let sws = (ws << 2) & m;
            let swc = (wc << 2) & m;
            // 7-bit slice estimate (wrapping slice add + sign extension)
            let sum = (sws >> drop).wrapping_add(swc >> drop) & slice_mask;
            let t = (sum ^ slice_sign) as i64 - slice_sign as i64;
            // digit = -2 + #(thresholds <= t): branchless comparisons
            let digit = (t >= m_n1) as i32 + (t >= m_0) as i32 + (t >= m_1) as i32
                + (t >= m_2) as i32
                - 2;
            // multiple magnitude: 0, d, or 2d — all mask arithmetic
            let ad = digit.unsigned_abs() as u64; // 0, 1, 2
            let nonzero = 0u64.wrapping_sub((ad != 0) as u64);
            let mag = (d_fp << (ad >> 1)) & nonzero;
            // subtract positive multiples: one's complement + carry-in
            let negm = 0u64.wrapping_sub((digit > 0) as u64);
            let addend = (mag ^ negm) & m;
            let cin = (digit > 0) as u64;
            // 3:2 compression
            let x1 = sws ^ swc ^ addend;
            let maj = (sws & swc) | (sws & addend) | (swc & addend);
            ws = x1 & m;
            wc = ((maj << 1) | cin) & m;
            if self.use_otf {
                // Eqs. (18)-(19), branchless: both concatenation sources
                // are selected by sign tests the compiler turns into cmovs
                let base_q = if digit >= 0 { q } else { qd };
                let base_qd = if digit > 0 { q } else { qd };
                q = (base_q << 2) | (digit & 3) as u64;
                qd = (base_qd << 2) | ((digit - 1) & 3) as u64;
            } else {
                q_acc = 4 * q_acc + digit as i64;
            }
        }

        let w = CsPair64 { s: ws, c: wc, w: width };
        let (neg, rem_zero) = if self.use_fr {
            let neg = w.sign_lookahead();
            let zero =
                if neg { w.is_zero_with_addend(d_fp) } else { w.is_zero_lookahead() };
            (neg, zero)
        } else {
            let r = w.resolve();
            let rem = if r < 0 { r + d_fp as i64 } else { r };
            (r < 0, rem == 0)
        };

        let mag = if self.use_otf {
            (if neg { qd } else { q }) as u128
        } else {
            (q_acc - neg as i64) as u128
        };
        FracQuotient { mag, frac_bits: 2 * it - 2, sticky: !rem_zero, iterations: it }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;
    use crate::posit::mask;

    fn engines() -> [Srt4Cs; 3] {
        [Srt4Cs::plain(), Srt4Cs::with_otf(), Srt4Cs::with_otf_fr()]
    }

    #[test]
    fn srt4cs_equals_golden_random_all_widths() {
        let mut rng = crate::testkit::Rng::seeded(0x47C5);
        for e in engines() {
            for &n in &[8u32, 10, 16, 24, 32, 48, 64] {
                let f = frac_bits(n);
                for _ in 0..3000 {
                    let x = (1 << f) | (rng.next_u64() & mask(f));
                    let d = (1 << f) | (rng.next_u64() & mask(f));
                    let q = e.fraction_divide(n, x, d);
                    let (g, gs) = golden::frac_divide(n, x, d).refine_to(q.frac_bits);
                    assert_eq!(
                        (q.mag, q.sticky),
                        (g, gs),
                        "{} n={n} x={x:#x} d={d:#x}",
                        e.name()
                    );
                }
            }
        }
    }

    #[test]
    fn srt4cs_full_divide_p8_exhaustive() {
        for e in engines() {
            let n = 8;
            for xb in 0..=mask(n) {
                for db in 0..=mask(n) {
                    let x = crate::posit::Posit::from_bits(n, xb);
                    let d = crate::posit::Posit::from_bits(n, db);
                    assert_eq!(
                        e.divide(x, d).result,
                        golden::divide(x, d).result,
                        "{} {x:?}/{d:?}",
                        e.name()
                    );
                }
            }
        }
    }

    #[test]
    fn srt4_halves_iterations() {
        let e4 = Srt4Cs::plain();
        let f = frac_bits(32);
        let q = e4.fraction_divide(32, 1 << f, (1 << f) | 1234567);
        assert_eq!(q.iterations, 16); // Table II
    }
}

#[cfg(test)]
mod narrow_tests {
    use super::*;
    use crate::posit::mask;

    #[test]
    fn narrow_path_equals_wide_path() {
        let mut rng = crate::testkit::Rng::seeded(0x6464);
        for e in [Srt4Cs::plain(), Srt4Cs::with_otf(), Srt4Cs::with_otf_fr()] {
            for &n in &[8u32, 16, 32, 48] {
                let f = frac_bits(n);
                let fw = f + 3;
                let width = fw + 4;
                assert!(width <= 64, "test formats must use the narrow path");
                let it = iterations(n, 4);
                for _ in 0..5000 {
                    let x = (1 << f) | (rng.next_u64() & mask(f));
                    let d = (1 << f) | (rng.next_u64() & mask(f));
                    assert_eq!(
                        e.frac_divide_narrow(n, x, d, fw, width, it),
                        e.frac_divide_wide(n, x, d, fw, width, it),
                        "{} n={n} x={x:#x} d={d:#x}",
                        e.name()
                    );
                }
            }
        }
    }
}
