//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [positional...] [--flag[=| ]value] [--switch]`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|p| !p.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.flags.insert(name.to_string(), "true".to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process arguments.
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Typed flag with default; exits with a message on parse failure.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.flags.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("invalid value for --{name}: {v:?}");
                std::process::exit(2);
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("serve x y");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["x", "y"]);
    }

    #[test]
    fn flags_with_values() {
        let a = parse("synth --n 32 --mode=pipe --csv");
        assert_eq!(a.get("n", 0u32), 32);
        assert_eq!(a.flag("mode"), Some("pipe"));
        assert!(a.has("csv"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn switch_before_positional_is_greedy() {
        // documented behavior: `--flag value` consumes the next token
        let a = parse("run --threads 8 trailing");
        assert_eq!(a.get("threads", 0u32), 8);
        assert_eq!(a.positional, vec!["trailing"]);
    }

    #[test]
    fn typed_defaults() {
        let a = parse("x");
        assert_eq!(a.get("missing", 7u64), 7);
    }
}
