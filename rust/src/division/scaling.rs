//! Operand scaling (§III-B4, Table I).
//!
//! The divisor is multiplied by a factor `M ≈ 1/d` chosen from its three
//! fractional bits, bringing the scaled divisor into `[1 − 1/64, 1 + 1/8]`
//! so the radix-4 quotient-digit selection no longer depends on the divisor
//! (Eq. (29)). `M` decomposes as `1 + a·2^−p (+ b·2^−q)`, so the hardware
//! scales with a shift-add (one CSA level + one adder), not a multiplier.
//! The dividend is scaled by the same `M` (quotient unchanged).

/// Table I: scaling factor in eighths, indexed by the three fractional
/// bits `b₁b₂b₃` of the divisor `d = 0.1b₁b₂b₃xxx…` ∈ [1/2, 1).
///
/// `M8[idx] = 8·M`: {2, 1.75, 1.625, 1.5, 1.375, 1.25, 1.125, 1.125}.
pub const M8: [u32; 8] = [16, 14, 13, 12, 11, 10, 9, 9];

/// Shift-add decomposition of each factor (Table I "Components"): `M·v` is
/// computed as `v + (v >> s1) + (v >> s2)` (s2 = 0 means absent).
/// E.g. M = 1.75 = 1 + 1/4 + 1/2.
pub const COMPONENTS: [(u32, u32); 8] = [
    (1, 1), // 2      = 1 + 1/2 + 1/2
    (2, 1), // 1.75   = 1 + 1/4 + 1/2
    (1, 3), // 1.625  = 1 + 1/2 + 1/8
    (1, 0), // 1.5    = 1 + 1/2
    (2, 3), // 1.375  = 1 + 1/4 + 1/8
    (2, 0), // 1.25   = 1 + 1/4
    (3, 0), // 1.125  = 1 + 1/8
    (3, 0), // 1.125  = 1 + 1/8
];

/// Select the Table I row from a significand with `fb` fraction bits
/// representing `d ∈ [1/2, 1)` (i.e. `sig ∈ [2^(fb−1), 2^fb)`): the index
/// is the three bits below the leading 1.
#[inline]
pub fn table_index(sig: u128, fb: u32) -> usize {
    debug_assert!(sig >> (fb - 1) == 1, "divisor not in [1/2,1)");
    ((sig >> (fb - 4)) & 0b111) as usize
}

/// Scale `v` (any fixed-point magnitude) by the Table I factor for `idx`,
/// using the shift-add decomposition. `v` must carry at least 3 fractional
/// guard bits for the result to be exact.
#[inline]
pub fn scale(v: u128, idx: usize) -> u128 {
    let (s1, s2) = COMPONENTS[idx];
    let mut out = v + (v >> s1);
    if s2 != 0 {
        out += v >> s2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn components_match_factors() {
        for idx in 0..8 {
            let (s1, s2) = COMPONENTS[idx];
            let mut m8 = 8 + (8 >> s1);
            if s2 != 0 {
                m8 += 8 >> s2;
            }
            assert_eq!(m8, M8[idx], "row {idx}");
        }
    }

    #[test]
    fn scale_equals_multiplication_by_m8() {
        for idx in 0..8 {
            for v in [8u128, 64, 123 << 3, 0xABCD << 3] {
                // v has ≥3 guard bits (multiple of 8): exact.
                assert_eq!(scale(v, idx), v * M8[idx] as u128 / 8, "idx={idx} v={v}");
            }
        }
    }

    /// The paper's guarantee: for every divisor d ∈ [1/2, 1), the scaled
    /// divisor M·d lies in [1 − 1/64, 1 + 1/8] ([33], [34]). Verified
    /// exhaustively on a fine grid in exact integer arithmetic.
    #[test]
    fn scaled_divisor_in_range_exhaustive() {
        // d = j / 2^16 for all j in [2^15, 2^16): M·d·512 must be in
        // [504, 576] (63/64·512 … 9/8·512).
        for j in (1u64 << 15)..(1u64 << 16) {
            let idx = ((j >> 12) & 0b111) as usize;
            let scaled512 = j as u128 * M8[idx] as u128; // d·2^16 · 8M = M·d·2^19; /2^10 → ·512
            let lo = 504u128 << 10;
            let hi = 576u128 << 10;
            assert!(
                (lo..=hi).contains(&scaled512),
                "d={j}/65536 idx={idx}: M·d·2^19 = {scaled512} outside [{lo},{hi}]"
            );
        }
    }

    #[test]
    fn table_index_extracts_bits() {
        // d = 0.1011xxx: sig with fb=7: 0b1011_000 -> index 0b011 = 3.
        assert_eq!(table_index(0b1011000, 7), 3);
        assert_eq!(table_index(0b1000000, 7), 0);
        assert_eq!(table_index(0b1111111, 7), 7);
    }
}
