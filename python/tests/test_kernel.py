"""Kernel-vs-oracle: the Pallas radix-4 SRT recurrence must reproduce the
exact integer division oracle bit-for-bit — the core L1 correctness
signal, swept across formats, block shapes and adversarial operands."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import posit_codec as codec
from compile.kernels import ref, srt_div


def rand_sigs(rng, n, lanes):
    f = codec.frac_bits(n)
    return (
        ((1 << f) | rng.integers(0, 1 << f, size=lanes)).astype(np.int64),
        ((1 << f) | rng.integers(0, 1 << f, size=lanes)).astype(np.int64),
    )


def check(xs, ds, n, block=srt_div.BLOCK):
    qk, st_ = srt_div.fraction_divide(jnp.asarray(xs), jnp.asarray(ds), n, block)
    qfb = 2 * srt_div.iterations(n) - 2
    qr, sr = ref.fraction_divide(jnp.asarray(xs), jnp.asarray(ds), n)
    qr, sr = ref.refine(qr, sr, n, qfb)
    np.testing.assert_array_equal(np.array(qk), np.array(qr))
    np.testing.assert_array_equal(np.array(st_).astype(bool), np.array(sr))


@pytest.mark.parametrize("n", [8, 16, 24, 32])
def test_kernel_equals_oracle_random(n):
    rng = np.random.default_rng(n)
    for _ in range(8):
        xs, ds = rand_sigs(rng, n, 256)
        check(xs, ds, n)


@pytest.mark.parametrize("block", [64, 128, 256])
def test_block_shapes_equivalent(block):
    n = 16
    rng = np.random.default_rng(99)
    xs, ds = rand_sigs(rng, n, 1024)
    check(xs, ds, n, block)


@pytest.mark.parametrize("n", [16, 32])
def test_adversarial_operands(n):
    f = codec.frac_bits(n)
    one = 1 << f
    top = (1 << (f + 1)) - 1
    cases = [
        (one, one),          # exact 1.0
        (top, top),          # exact 1.0 with max fractions
        (one, top),          # q slightly above 1/2
        (top, one),          # q slightly below 2
        (one, one | 1),      # long non-terminating quotient
        (one | 1, one),      # exact in few bits
        (one | (1 << (f - 1)), one | (1 << (f - 1)) | 1),
        (3 << (f - 1), one), # 1.5 / 1.0
    ]
    lanes = srt_div.BLOCK
    reps = (lanes + len(cases) - 1) // len(cases)
    arr = (cases * reps)[:lanes]
    xs = np.array([c[0] for c in arr], dtype=np.int64)
    ds = np.array([c[1] for c in arr], dtype=np.int64)
    check(xs, ds, n)


def test_exact_divisions_have_clear_sticky():
    n = 16
    f = codec.frac_bits(n)
    lanes = srt_div.BLOCK
    # x = d * small power of two fractions: q exact
    ds = np.full(lanes, (1 << f) | (1 << (f - 1)), dtype=np.int64)  # 1.5
    xs = ds.copy()  # q = 1 exactly
    _, st_ = srt_div.fraction_divide(jnp.asarray(xs), jnp.asarray(ds), n)
    assert not np.array(st_).astype(bool).any()


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_kernel_oracle_hypothesis_p16(data):
    n = 16
    f = codec.frac_bits(n)
    lanes = srt_div.BLOCK
    frac = st.integers(0, (1 << f) - 1)
    xs = np.array(data.draw(st.lists(frac, min_size=lanes, max_size=lanes)), dtype=np.int64)
    ds = np.array(data.draw(st.lists(frac, min_size=lanes, max_size=lanes)), dtype=np.int64)
    check((1 << f) | xs, (1 << f) | ds, n)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, (1 << 27) - 1), st.integers(0, (1 << 27) - 1))
def test_kernel_oracle_hypothesis_p32_scalarish(xf, df):
    n = 32
    f = codec.frac_bits(n)
    lanes = srt_div.BLOCK
    xs = np.full(lanes, (1 << f) | xf, dtype=np.int64)
    ds = np.full(lanes, (1 << f) | df, dtype=np.int64)
    check(xs, ds, n)


def test_quotient_always_normalizable():
    # q in (1/2, 2): top two bits of the result must not both be zero.
    n = 16
    rng = np.random.default_rng(5)
    xs, ds = rand_sigs(rng, n, 512)
    qk, _ = srt_div.fraction_divide(jnp.asarray(xs), jnp.asarray(ds), n)
    qfb = 2 * srt_div.iterations(n) - 2
    q = np.array(qk)
    assert (q >> (qfb - 1) != 0).all()
    assert (q >> (qfb + 1) == 0).all()
