//! Property-based integration tests over the full division pipeline,
//! including the strongest check in the suite: round-to-nearest
//! correctness verified by exact rational comparison against
//! pattern-space midpoints (independent of the encode path) — plus
//! correctly-rounded references for the arithmetic ops the
//! operation-generic unit serves (mul/add/sub at n ∈ {8, 16, 32}) and
//! the quire reductions (permutation invariance, and a constructed case
//! where a rounding-per-step fold provably loses bits the quire keeps).

// Division properties run through the deprecated `Divider` wrapper on
// purpose — they pin the legacy context's behavior.
#![allow(deprecated)]

use posit_div::division::{golden, Algorithm, Divider};
use posit_div::posit::{frac_bits, mask, round::encode_round, Posit};
use posit_div::quire;
use posit_div::testkit::{self, gen, rational, Config, Rng};

#[test]
fn golden_is_correctly_rounded_p16_random() {
    // verify_nearest does an exact rational nearest-posit check.
    testkit::forall(
        Config::cases(20_000).with_seed(0x4EA1),
        |rng| gen::division_operands(rng, 16),
        gen::shrink_pair,
        |&(x, d)| {
            if x.is_zero() {
                return Ok(());
            }
            let q = golden::divide(x, d).result;
            golden::verify_nearest(x, d, q);
            Ok(())
        },
    );
}

#[test]
fn division_identities() {
    // one pre-built context per width, like a real caller would hold
    let ctxs: Vec<Divider> = [8u32, 16, 32]
        .iter()
        .map(|&n| Divider::new(n, Algorithm::DEFAULT).expect("valid width"))
        .collect();
    testkit::forall(
        Config::cases(20_000),
        |rng| {
            let i = *rng.choose(&[0usize, 1, 2]);
            gen::division_operands(rng, [8u32, 16, 32][i])
        },
        gen::shrink_pair,
        |&(x, d)| {
            let n = x.width();
            let ctx = ctxs.iter().find(|c| c.width() == n).expect("width covered");
            let div = |a: Posit, b: Posit| ctx.divide(a, b).expect("width matches").result;
            // x / 1 = x
            if div(x, Posit::one(n)) != x {
                return Err("x/1 != x".into());
            }
            // x / x = 1 for nonzero x
            if !x.is_zero() && div(x, x) != Posit::one(n) {
                return Err("x/x != 1".into());
            }
            // (-x)/d = -(x/d) — negation is exact in posits
            let q = div(x, d);
            if div(x.neg(), d) != q.neg() {
                return Err("(-x)/d != -(x/d)".into());
            }
            if div(x, d.neg()) != q.neg() {
                return Err("x/(-d) != -(x/d)".into());
            }
            Ok(())
        },
    );
}

#[test]
fn division_by_powers_of_two_is_exact_shift() {
    // x / 2^k only changes the scale: exact unless it saturates.
    let ctx = Divider::new(16, Algorithm::Srt2Cs).expect("valid width");
    testkit::forall(
        Config::cases(5_000),
        |rng| {
            let x = gen::nonzero_posit(rng, 16);
            let k = rng.range_i64(-8, 8);
            (x, k)
        },
        |_| Vec::new(),
        |&(x, k)| {
            let n = 16;
            let d = Posit::from_f64(n, (k as f64).exp2());
            let q = ctx.divide(x, d).expect("width matches").result;
            let want = golden::divide(x, d).result;
            if q != want {
                return Err(format!("mismatch for 2^{k}"));
            }
            // and the value matches the f64 shift when in range
            let expect = x.to_f64() / (k as f64).exp2();
            let via = Posit::from_f64(n, expect);
            if via != q {
                return Err(format!("2^{k} shift not exact: {} vs {}", q, via));
            }
            Ok(())
        },
    );
}

/// Exact multiplication reference, independent of `arith.rs`'s
/// normalization branches: full-width significand product, one
/// pattern-space rounding through the shared encoder.
fn exact_mul_reference(n: u32, pa: Posit, pb: Posit) -> Posit {
    let (a, b) = (pa.decode(), pb.decode());
    let fb = frac_bits(n) as i32;
    let prod = (a.sig as u128) * (b.sig as u128);
    let msb = 127 - prod.leading_zeros();
    encode_round(n, a.sign ^ b.sign, a.scale + b.scale + msb as i32 - 2 * fb, prod, msb, false)
}

/// Exact addition reference: signed fixed-point sum at the smaller
/// operand's scale. `None` when the scale span exceeds the i128 headroom
/// — the caller then asserts full absorption (the tiny operand is far
/// below half an ulp of the big one, so the sum must round to the big
/// operand exactly).
fn exact_add_reference(n: u32, pa: Posit, pb: Posit) -> Option<Posit> {
    let (a, b) = (pa.decode(), pb.decode());
    let fb = frac_bits(n) as i32;
    let base = a.scale.min(b.scale);
    if a.scale.max(b.scale) - base > 96 {
        return None; // sig (≤ 29 bits at n=32) + span must stay below 127
    }
    let av = (a.sig as i128) << (a.scale - base) as u32;
    let bv = (b.sig as i128) << (b.scale - base) as u32;
    let sum = if a.sign { -av } else { av } + if b.sign { -bv } else { bv };
    Some(if sum == 0 {
        Posit::zero(n)
    } else {
        let mag = sum.unsigned_abs();
        let msb = 127 - mag.leading_zeros();
        encode_round(n, sum < 0, base + msb as i32 - fb, mag, msb, false)
    })
}

#[test]
fn mul_add_sub_match_correctly_rounded_f64_reference_p8_p16() {
    // Why f64 is a correctly rounded reference here: p8/p16 significands
    // carry ≤ 4/12 bits, so every product (≤ 24 significant bits) is
    // exact in f64, and for sums either the two operands overlap within
    // f64's 53-bit window (exact sum, including every tie: a half-ulp
    // offset adds one significant bit, not fifty) or the small operand
    // sits ≥ 2^28 below half an ulp of the big one, where both the exact
    // sum and the f64-rounded sum round to the same posit.
    for n in [8u32, 16] {
        let mut rng = Rng::seeded(0xF0 + n as u64);
        for _ in 0..60_000 {
            let pa = Posit::from_bits(n, rng.next_u64() & mask(n));
            let pb = Posit::from_bits(n, rng.next_u64() & mask(n));
            if pa.is_nar() || pb.is_nar() {
                assert!(pa.mul(pb).is_nar() && pa.add(pb).is_nar() && pa.sub(pb).is_nar());
                continue;
            }
            let (af, bf) = (pa.to_f64(), pb.to_f64());
            assert_eq!(pa.mul(pb), Posit::from_f64(n, af * bf), "{pa:?} * {pb:?}");
            assert_eq!(pa.add(pb), Posit::from_f64(n, af + bf), "{pa:?} + {pb:?}");
            assert_eq!(pa.sub(pb), Posit::from_f64(n, af - bf), "{pa:?} - {pb:?}");
        }
    }
}

#[test]
fn mul_matches_exact_integer_reference_p16_p32() {
    // At n = 32 the 56-bit significand product no longer fits f64, so the
    // bit-exact check runs against the exact integer reference; the f64
    // product must still land within 1 ulp (double rounding).
    for n in [16u32, 32] {
        let mut rng = Rng::seeded(0x3216 + n as u64);
        for _ in 0..40_000 {
            let pa = Posit::from_bits(n, rng.next_u64() & mask(n));
            let pb = Posit::from_bits(n, rng.next_u64() & mask(n));
            if pa.is_nar() || pb.is_nar() || pa.is_zero() || pb.is_zero() {
                continue;
            }
            let got = pa.mul(pb);
            assert_eq!(got, exact_mul_reference(n, pa, pb), "{pa:?} * {pb:?}");
            let via_f64 = Posit::from_f64(n, pa.to_f64() * pb.to_f64());
            assert!(got.ulp_distance(via_f64) <= 1, "{pa:?} * {pb:?} f64 drift");
        }
    }
}

#[test]
fn add_sub_match_exact_integer_reference_p32() {
    let n = 32;
    let mut rng = Rng::seeded(0xADD32);
    for _ in 0..60_000 {
        let pa = Posit::from_bits(n, rng.next_u64() & mask(n));
        let pb = Posit::from_bits(n, rng.next_u64() & mask(n));
        if pa.is_nar() || pb.is_nar() || pa.is_zero() || pb.is_zero() {
            continue;
        }
        for (got, rhs) in [(pa.add(pb), pb), (pa.sub(pb), pb.neg())] {
            match exact_add_reference(n, pa, rhs) {
                Some(want) => assert_eq!(got, want, "{pa:?} (+) {rhs:?}"),
                None => {
                    // span > 96: the small operand is ≥ 2^67 below half an
                    // ulp of the big one — the exact sum rounds to the big
                    // operand unchanged.
                    let hi =
                        if pa.decode().scale >= rhs.decode().scale { pa } else { rhs };
                    assert_eq!(got, hi, "{pa:?} (+) {rhs:?} must absorb");
                }
            }
        }
    }
}

/// In-place Fisher–Yates driven by the deterministic testkit RNG — the
/// quire properties need the *same* permutation applied to both dot
/// operand vectors, so the shuffle works on an index vector.
fn shuffled_indices(rng: &mut Rng, k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..k).collect();
    for i in (1..k).rev() {
        idx.swap(i, rng.below(i as u64 + 1) as usize);
    }
    idx
}

#[test]
fn quire_reductions_are_permutation_invariant_p16_p32() {
    // The quire accumulates in exact fixed point, so the result of a
    // reduction cannot depend on summation order — unlike any
    // rounding-per-step fold. Checked against the independent
    // exact-rational reference on the original order, then re-run on a
    // random permutation of the terms.
    for n in [16u32, 32] {
        let mut rng = Rng::seeded(0x5EED + n as u64);
        for _ in 0..400 {
            let k = 2 + rng.below(14) as usize;
            let a: Vec<Posit> = (0..k).map(|_| gen::real_posit(&mut rng, n)).collect();
            let b: Vec<Posit> = (0..k).map(|_| gen::real_posit(&mut rng, n)).collect();
            let alpha = gen::real_posit(&mut rng, n);

            let d = quire::dot(&a, &b).expect("matched lanes");
            assert_eq!(d, rational::dot(&a, &b), "dot vs rational, n={n}");
            let s = quire::fused_sum(&a).expect("non-empty");
            assert_eq!(s, rational::fused_sum(&a), "fsum vs rational, n={n}");
            let ax = quire::axpy(alpha, &a, &b).expect("matched lanes");
            assert_eq!(ax, rational::axpy(alpha, &a, &b), "axpy vs rational, n={n}");

            let idx = shuffled_indices(&mut rng, k);
            let ap: Vec<Posit> = idx.iter().map(|&i| a[i]).collect();
            let bp: Vec<Posit> = idx.iter().map(|&i| b[i]).collect();
            assert_eq!(quire::dot(&ap, &bp).expect("matched lanes"), d, "dot order, n={n}");
            assert_eq!(quire::fused_sum(&ap).expect("non-empty"), s, "fsum order, n={n}");
            assert_eq!(
                quire::axpy(alpha, &ap, &bp).expect("matched lanes"),
                ax,
                "axpy order, n={n}"
            );
        }
    }
}

#[test]
fn quire_is_exact_where_naive_fold_provably_rounds_p16_p32() {
    // The constructed case the quire exists for. At width n the posits in
    // [1, 2) carry fb = frac_bits(n) fraction bits, so the ulp at 1.0 is
    // 2^-fb and anything strictly below the half-ulp 2^-(fb+1) is
    // absorbed by a rounded add. Take t = 2^-(fb+2) — a quarter ulp,
    // exactly representable (its own regime is short enough to keep
    // fraction bits at both widths). Then:
    //   naive: 1.0 (+) t rounds back to 1.0 at every step — four adds of
    //          t leave 1.0 unchanged;
    //   exact: 1 + 4t = 1 + 2^-fb is exactly one ulp above 1.0 and
    //          exactly representable, so the deferred rounding returns it.
    // The fold loses the entire tail; the quire provably cannot.
    for n in [16u32, 32] {
        let fb = frac_bits(n) as i32;
        let one = Posit::one(n);
        let t = Posit::from_f64(n, (-(fb + 2) as f64).exp2());
        assert!(!t.is_zero(), "quarter-ulp must be representable at n={n}");
        assert_eq!(t.to_f64(), (-(fb + 2) as f64).exp2(), "t must be exact at n={n}");
        let xs = [one, t, t, t, t];

        // the naive rounding-per-step fold absorbs every tiny term
        let mut naive = Posit::zero(n);
        for x in xs {
            naive = naive.add(x);
        }
        assert_eq!(naive, one, "each quarter-ulp add must absorb at n={n}");

        // the quire keeps them all: one ulp above 1.0, bit-exact vs the
        // rational reference — and provably != the naive fold
        let exact = quire::fused_sum(&xs).expect("non-empty");
        assert_eq!(exact, rational::fused_sum(&xs), "quire vs rational, n={n}");
        assert_eq!(exact, Posit::from_f64(n, 1.0 + (-fb as f64).exp2()), "n={n}");
        assert_ne!(exact, naive, "n={n}: the fold must lose the tail");

        // same story through the dot product (all-ones second vector)
        let ones = [one; 5];
        assert_eq!(quire::dot(&xs, &ones).expect("matched lanes"), exact, "dot, n={n}");
    }
}

#[test]
fn nar_and_zero_propagation_all_engines() {
    for alg in Algorithm::ALL {
        for n in [8u32, 16, 32] {
            let ctx = Divider::new(n, alg).expect("valid width");
            let div = |a: Posit, b: Posit| ctx.divide(a, b).expect("width matches").result;
            let one = Posit::one(n);
            assert!(div(one, Posit::zero(n)).is_nar(), "{alg:?}");
            assert!(div(Posit::nar(n), one).is_nar(), "{alg:?}");
            assert!(div(one, Posit::nar(n)).is_nar(), "{alg:?}");
            assert!(div(Posit::zero(n), one).is_zero(), "{alg:?}");
            assert!(div(Posit::zero(n), Posit::zero(n)).is_nar(), "{alg:?}");
        }
    }
}

#[test]
fn quotient_monotonicity_in_dividend() {
    // for fixed positive divisor, x1 <= x2 => x1/d <= x2/d (posit order)
    let ctx = Divider::new(16, Algorithm::DEFAULT).expect("valid width");
    testkit::forall_ns(Config::cases(10_000), |rng| {
        let d = gen::nonzero_posit(rng, 16).abs();
        let a = gen::real_posit(rng, 16);
        let b = gen::real_posit(rng, 16);
        (a, b, d)
    }, |&(a, b, d)| {
        let (lo, hi) = if a.total_cmp(b).is_le() { (a, b) } else { (b, a) };
        let qlo = ctx.divide(lo, d).expect("width matches").result;
        let qhi = ctx.divide(hi, d).expect("width matches").result;
        if qlo.total_cmp(qhi).is_gt() {
            return Err(format!("monotonicity violated: {lo:?}/{d:?} > {hi:?}/{d:?}"));
        }
        Ok(())
    });
}

#[test]
fn multiplication_division_roundtrip_within_ulp() {
    // (x/d)*d is within 1 ulp of x when no saturation occurred (two
    // roundings) — a sanity link between the arithmetic and division.
    let ctx = Divider::new(32, Algorithm::DEFAULT).expect("valid width");
    testkit::forall_ns(Config::cases(10_000), |rng| {
        let x = gen::nonzero_posit(rng, 32);
        let d = gen::nonzero_posit(rng, 32);
        (x, d)
    }, |&(x, d)| {
        let n = 32;
        let q = ctx.divide(x, d).expect("width matches").result;
        if q == Posit::maxpos(n) || q == Posit::maxpos(n).neg()
            || q == Posit::minpos(n) || q == Posit::minpos(n).neg()
        {
            return Ok(()); // saturated
        }
        // restrict to the band where q keeps most fraction bits: outside
        // it, the quotient's long regime makes the round-trip legitimately
        // coarse in x's (denser) ulp scale.
        let qv = q.to_f64().abs();
        if !(2.0f64.powi(-16)..2.0f64.powi(16)).contains(&qv) {
            return Ok(());
        }
        let back = q.mul(d);
        let dist = back.ulp_distance(x);
        // two nearest-roundings: within a couple of ulp except at regime
        // boundaries where ulp sizes jump
        if dist > 8 {
            return Err(format!("(x/d)*d drifted {dist} ulp: {x:?} {d:?}"));
        }
        Ok(())
    });
}
