//! Explicit vector-ISA batch kernels — the widest layer of the Fast tier.
//!
//! The SWAR kernels ([`super::simd`]) pack lanes into `u128` words but
//! still run the fraction arithmetic one lane at a time; real hardware
//! offers 128–256-bit vector units that can retire 4–8 of those lane
//! operations per instruction. This module is the `core::arch` analogue:
//! AVX2 kernels on x86_64 and NEON kernels on aarch64 for div/mul/add/sub
//! at n ∈ {8, 16}, behind one-time runtime CPU detection.
//!
//! **Structure.** Each block reuses the SWAR special pre-pass
//! (`simd::special_prepass`) verbatim — classification is the
//! part of the Fast tier where bit-identity bugs hide, so there is exactly
//! one implementation of it — then runs a vectorized mid-section over the
//! compacted real lanes and the shared [`encode_round`] post-pass:
//!
//! * **Div** — lanes decode into `i32` numerator/denominator arrays
//!   (`num = sig << n` ≤ 2^14 at P8, ≤ 2^29 at P16; `den = sig` < 2^13,
//!   so both widths fit `i32` losslessly). The quotient comes from
//!   hardware float division — `f32` 8-wide for P8 (num < 2^14 is exact
//!   in 24 mantissa bits), `f64` 4-wide for P16 (num < 2^29 is exact in
//!   53) — truncated back to integer. IEEE division is correctly rounded
//!   and every non-integer quotient is ≥ 1/den > one float ulp away from
//!   an integer, so the truncation already equals the integer floor; a
//!   branch-free ±1 remainder fix-up in the same vector registers keeps
//!   the kernel correct even on that analysis' margin, and the exact
//!   remainder doubles as the sticky bit. Same quotient normal form as
//!   the SWAR kernel, hence bit-identical rounding.
//! * **Mul** — significand products fit `i32` at both widths (≤ 2^12 /
//!   ≤ 2^26), so the mid-section is one vector `mullo` per 4–8 lanes
//!   feeding the shared renormalize-and-round tail.
//! * **Add/Sub** — the packed special pre-pass plus the exact posit
//!   library routine per surviving lane, compiled inside the
//!   target-feature region so the decode/align/encode straight-line code
//!   can use the wider ISA. (Their cancellation path is data-dependent
//!   enough that a hand-vectorized version would need its own bit-identity
//!   argument; the shared routine keeps that argument trivial.)
//!
//! **Gating.** Everything here compiles whenever the target architecture
//! matches (so the portable build type-checks the kernels), but
//! [`available`] only returns `true` when the default-off `vsimd` cargo
//! feature is enabled *and* runtime detection
//! (`is_x86_feature_detected!("avx2")` / `is_aarch64_feature_detected!
//! ("neon")`, cached in a [`OnceLock`]) confirms the ISA. The dispatcher
//! ([`super::fastpath::FastKernel::resolve`]) consults [`available`]
//! before ever selecting [`super::fastpath::FastPath::Vector`], and
//! forced-path construction re-checks it, so the `unsafe`
//! `#[target_feature]` kernels are unreachable on CPUs that lack the ISA.
//!
//! Sqrt and mul-add stay on the table/SWAR/scalar paths: sqrt needs a
//! per-lane integer square root with no vector equivalent cheap enough to
//! win, and mul-add's double rounding hazard keeps it on the fused
//! library routine ([`supports`] excludes both).

use std::sync::OnceLock;

use crate::posit::{frac_bits, mask, round::encode_round, Posit};

use super::fastpath::Kind;
use super::simd::{special_prepass, window, BLOCK};

/// True when `(n, kind)` has a vector kernel: div/mul/add/sub at
/// n ∈ {8, 16}. Capability of the *code*, not the *machine* — the
/// dispatch layer combines this with [`available`].
#[inline]
pub const fn supports(n: u32, kind: Kind) -> bool {
    (n == 8 || n == 16) && matches!(kind, Kind::Div | Kind::Mul | Kind::Add | Kind::Sub)
}

/// True when the vector kernels may run on this machine: the `vsimd`
/// cargo feature is enabled and the CPU reports the required ISA (AVX2 on
/// x86_64, NEON on aarch64; always false elsewhere). Detection runs once
/// per process and is cached in a [`OnceLock`].
pub fn available() -> bool {
    if cfg!(not(feature = "vsimd")) {
        return false;
    }
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(detect)
}

#[cfg(target_arch = "x86_64")]
fn detect() -> bool {
    std::is_x86_feature_detected!("avx2")
}

#[cfg(target_arch = "aarch64")]
fn detect() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> bool {
    false
}

/// Vector batch execution: `out[i] = kind(a[i], b[i], c[i])` for every
/// lane, bit-identical to the scalar Fast kernel. Callers must hold
/// [`supports`]`(n, kind)` and [`available`]`()` — the dispatch layer
/// guarantees both before routing a batch here.
pub fn run_batch(n: u32, kind: Kind, a: &[u64], b: &[u64], c: &[u64], out: &mut [u64]) {
    debug_assert!(supports(n, kind), "no vector kernel for n={n} {kind:?}");
    debug_assert!(available(), "vector kernels dispatched without ISA support");
    match n {
        8 => batch::<8, 16>(kind, a, b, c, out),
        _ => batch::<16, 8>(kind, a, b, c, out),
    }
}

fn batch<const N: u32, const L: usize>(
    kind: Kind,
    a: &[u64],
    b: &[u64],
    c: &[u64],
    out: &mut [u64],
) {
    let len = out.len();
    let mut start = 0usize;
    while start < len {
        let m = (len - start).min(BLOCK);
        block::<N, L>(
            kind,
            &a[start..start + m],
            window(b, start, m),
            window(c, start, m),
            &mut out[start..start + m],
        );
        start += m;
    }
}

/// One block: shared packed special pre-pass, vectorized mid-section over
/// the compacted real lanes, shared encode post-pass.
fn block<const N: u32, const L: usize>(
    kind: Kind,
    a: &[u64],
    b: &[u64],
    c: &[u64],
    out: &mut [u64],
) {
    let mut real_idx = [0u8; BLOCK];
    let r = special_prepass::<N, L>(kind, a, b, c, out, &mut real_idx);
    if r == 0 {
        return;
    }
    match kind {
        Kind::Div => div_block(N, a, b, out, &real_idx, r),
        Kind::Mul => mul_block(N, a, b, out, &real_idx, r),
        Kind::Add | Kind::Sub => add_sub_block(N, kind == Kind::Sub, a, b, out, &real_idx, r),
        // excluded by `supports`; the dispatcher never routes them here
        Kind::Sqrt | Kind::MulAdd => unreachable!("no vector kernel for {kind:?}"),
    }
}

/// Division mid-section: decode to `i32` SoA buffers, vector float
/// divide with integer fix-up, shared rounding. Identical normal form to
/// the SWAR kernel (`q = (sig_a << n) / sig_b`, sticky from the exact
/// remainder), so the encode post-pass sees the same integers.
fn div_block(n: u32, a: &[u64], b: &[u64], out: &mut [u64], real_idx: &[u8; BLOCK], r: usize) {
    let msk = mask(n);
    let mut sign = [false; BLOCK];
    let mut scale = [0i32; BLOCK];
    let mut num = [0i32; BLOCK];
    // 1, not 0: the vector loops step 4–8 lanes past `r` inside the
    // block-sized buffers, and defined dead lanes keep those tails
    // trivially harmless.
    let mut den = [1i32; BLOCK];
    for t in 0..r {
        let i = real_idx[t] as usize;
        let da = Posit::from_bits(n, a[i] & msk).decode();
        let db = Posit::from_bits(n, b[i] & msk).decode();
        sign[t] = da.sign ^ db.sign;
        scale[t] = da.scale - db.scale;
        num[t] = (da.sig << n) as i32; // < 2^29 at n = 16: exact in f64
        den[t] = db.sig as i32;
    }
    let mut q = [0i32; BLOCK];
    let mut rem = [0i32; BLOCK];
    div_q_rem(n, &num, &den, &mut q, &mut rem, r);
    for t in 0..r {
        // normalize q ∈ (1/2, 2) to [1, 2) — same as the SWAR kernel
        let (sc, sfb) =
            if (q[t] as u64) >> n != 0 { (scale[t], n) } else { (scale[t] - 1, n - 1) };
        out[real_idx[t] as usize] =
            encode_round(n, sign[t], sc, q[t] as u128, sfb, rem[t] != 0).to_bits();
    }
}

/// Multiply mid-section: significand products via vector `mullo`, shared
/// renormalize-and-round tail (same normal form as the SWAR kernel).
fn mul_block(n: u32, a: &[u64], b: &[u64], out: &mut [u64], real_idx: &[u8; BLOCK], r: usize) {
    let msk = mask(n);
    let fb = frac_bits(n);
    let mut sign = [false; BLOCK];
    let mut scale = [0i32; BLOCK];
    let mut sa = [0i32; BLOCK];
    let mut sb = [0i32; BLOCK];
    for t in 0..r {
        let i = real_idx[t] as usize;
        let da = Posit::from_bits(n, a[i] & msk).decode();
        let db = Posit::from_bits(n, b[i] & msk).decode();
        sign[t] = da.sign ^ db.sign;
        scale[t] = da.scale + db.scale;
        sa[t] = da.sig as i32;
        sb[t] = db.sig as i32;
    }
    let mut prod = [0i32; BLOCK];
    mullo(&sa, &sb, &mut prod, r);
    for t in 0..r {
        let p = prod[t] as u64; // ≤ 2^26 at n = 16: fits i32, positive
        // value = prod / 2^(2fb) ∈ [1, 4): renormalize like Posit::mul
        let (sc, sfb) = if p >> (2 * fb + 1) != 0 {
            (scale[t] + 1, 2 * fb + 1)
        } else {
            (scale[t], 2 * fb)
        };
        out[real_idx[t] as usize] = encode_round(n, sign[t], sc, p as u128, sfb, false).to_bits();
    }
}

/// Add/sub mid-section: the exact posit library routine per real lane,
/// compiled inside the target-feature region on vector-capable targets.
fn add_sub_scalar(
    n: u32,
    sub: bool,
    a: &[u64],
    b: &[u64],
    out: &mut [u64],
    real_idx: &[u8; BLOCK],
    r: usize,
) {
    let msk = mask(n);
    for &t in &real_idx[..r] {
        let i = t as usize;
        let x = Posit::from_bits(n, a[i] & msk);
        let y = Posit::from_bits(n, b[i] & msk);
        out[i] = if sub { x.sub(y) } else { x.add(y) }.to_bits();
    }
}

// ---------------------------------------------------------------------
// Arch dispatch: one same-named shim per target, so the portable callers
// above stay architecture-free. The `unsafe` blocks are sound because
// `run_batch` is only reachable when `available()` confirmed the ISA.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
fn div_q_rem(
    n: u32,
    num: &[i32; BLOCK],
    den: &[i32; BLOCK],
    q: &mut [i32; BLOCK],
    rem: &mut [i32; BLOCK],
    r: usize,
) {
    // Safety: dispatch is gated on `available()` ⇒ AVX2 present.
    unsafe {
        if n == 8 {
            x86::div_q_rem_f32(num, den, q, rem, r);
        } else {
            x86::div_q_rem_f64(num, den, q, rem, r);
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn mullo(x: &[i32; BLOCK], y: &[i32; BLOCK], out: &mut [i32; BLOCK], r: usize) {
    // Safety: dispatch is gated on `available()` ⇒ AVX2 present.
    unsafe { x86::mullo(x, y, out, r) }
}

#[cfg(target_arch = "x86_64")]
fn add_sub_block(
    n: u32,
    sub: bool,
    a: &[u64],
    b: &[u64],
    out: &mut [u64],
    real_idx: &[u8; BLOCK],
    r: usize,
) {
    // Safety: dispatch is gated on `available()` ⇒ AVX2 present.
    unsafe { x86::add_sub_lanes(n, sub, a, b, out, real_idx, r) }
}

#[cfg(target_arch = "aarch64")]
fn div_q_rem(
    n: u32,
    num: &[i32; BLOCK],
    den: &[i32; BLOCK],
    q: &mut [i32; BLOCK],
    rem: &mut [i32; BLOCK],
    r: usize,
) {
    // Safety: dispatch is gated on `available()` ⇒ NEON present.
    unsafe {
        if n == 8 {
            arm::div_q_rem_f32(num, den, q, rem, r);
        } else {
            arm::div_q_rem_f64(num, den, q, rem, r);
        }
    }
}

#[cfg(target_arch = "aarch64")]
fn mullo(x: &[i32; BLOCK], y: &[i32; BLOCK], out: &mut [i32; BLOCK], r: usize) {
    // Safety: dispatch is gated on `available()` ⇒ NEON present.
    unsafe { arm::mullo(x, y, out, r) }
}

#[cfg(target_arch = "aarch64")]
fn add_sub_block(
    n: u32,
    sub: bool,
    a: &[u64],
    b: &[u64],
    out: &mut [u64],
    real_idx: &[u8; BLOCK],
    r: usize,
) {
    // Safety: dispatch is gated on `available()` ⇒ NEON present.
    unsafe { arm::add_sub_lanes(n, sub, a, b, out, real_idx, r) }
}

// Portable shims for other architectures: `available()` is always false
// there, so these only exist to keep the module compiling; exact integer
// forms, trivially bit-identical.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn div_q_rem(
    _n: u32,
    num: &[i32; BLOCK],
    den: &[i32; BLOCK],
    q: &mut [i32; BLOCK],
    rem: &mut [i32; BLOCK],
    r: usize,
) {
    for t in 0..r {
        q[t] = num[t] / den[t];
        rem[t] = num[t] % den[t];
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn mullo(x: &[i32; BLOCK], y: &[i32; BLOCK], out: &mut [i32; BLOCK], r: usize) {
    for t in 0..r {
        out[t] = x[t] * y[t];
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn add_sub_block(
    n: u32,
    sub: bool,
    a: &[u64],
    b: &[u64],
    out: &mut [u64],
    real_idx: &[u8; BLOCK],
    r: usize,
) {
    add_sub_scalar(n, sub, a, b, out, real_idx, r);
}

/// AVX2 kernels. The loops step 8 (f32/mullo) or 4 (f64) lanes and may
/// read/write up to one full vector past `r` — always inside the
/// `BLOCK`-sized buffers (`r` ≤ 64, steps divide 64), over dead lanes the
/// callers initialized to defined values.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use super::BLOCK;

    /// 8-wide P8 division: `q = ⌊num/den⌋`, `rem = num − q·den` via f32
    /// division (exact for num < 2^14, den < 2^6) plus a branch-free ±1
    /// remainder fix-up.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn div_q_rem_f32(
        num: &[i32; BLOCK],
        den: &[i32; BLOCK],
        q: &mut [i32; BLOCK],
        rem: &mut [i32; BLOCK],
        r: usize,
    ) {
        let mut t = 0;
        while t < r {
            unsafe {
                let vn = _mm256_loadu_si256(num.as_ptr().add(t) as *const __m256i);
                let vd = _mm256_loadu_si256(den.as_ptr().add(t) as *const __m256i);
                let fq = _mm256_div_ps(_mm256_cvtepi32_ps(vn), _mm256_cvtepi32_ps(vd));
                let mut vq = _mm256_cvttps_epi32(fq);
                let mut vr = _mm256_sub_epi32(vn, _mm256_mullo_epi32(vq, vd));
                // rem < 0 → q -= 1, rem += den (cmp mask is −1 per lane)
                let neg = _mm256_cmpgt_epi32(_mm256_setzero_si256(), vr);
                vq = _mm256_add_epi32(vq, neg);
                vr = _mm256_add_epi32(vr, _mm256_and_si256(neg, vd));
                // rem ≥ den → q += 1, rem -= den
                let lt = _mm256_cmpgt_epi32(vd, vr); // den > rem
                let over = _mm256_andnot_si256(lt, _mm256_set1_epi32(-1));
                vq = _mm256_sub_epi32(vq, over);
                vr = _mm256_sub_epi32(vr, _mm256_and_si256(over, vd));
                _mm256_storeu_si256(q.as_mut_ptr().add(t) as *mut __m256i, vq);
                _mm256_storeu_si256(rem.as_mut_ptr().add(t) as *mut __m256i, vr);
            }
            t += 8;
        }
    }

    /// 4-wide P16 division: same shape through f64 lanes (exact for
    /// num < 2^29, den < 2^13); `cvtepi32_pd`/`cvttpd_epi32` move between
    /// the 128-bit integer and 256-bit double registers.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn div_q_rem_f64(
        num: &[i32; BLOCK],
        den: &[i32; BLOCK],
        q: &mut [i32; BLOCK],
        rem: &mut [i32; BLOCK],
        r: usize,
    ) {
        let mut t = 0;
        while t < r {
            unsafe {
                let vn = _mm_loadu_si128(num.as_ptr().add(t) as *const __m128i);
                let vd = _mm_loadu_si128(den.as_ptr().add(t) as *const __m128i);
                let fq = _mm256_div_pd(_mm256_cvtepi32_pd(vn), _mm256_cvtepi32_pd(vd));
                let mut vq = _mm256_cvttpd_epi32(fq);
                let mut vr = _mm_sub_epi32(vn, _mm_mullo_epi32(vq, vd));
                let neg = _mm_cmpgt_epi32(_mm_setzero_si128(), vr);
                vq = _mm_add_epi32(vq, neg);
                vr = _mm_add_epi32(vr, _mm_and_si128(neg, vd));
                let lt = _mm_cmpgt_epi32(vd, vr);
                let over = _mm_andnot_si128(lt, _mm_set1_epi32(-1));
                vq = _mm_sub_epi32(vq, over);
                vr = _mm_sub_epi32(vr, _mm_and_si128(over, vd));
                _mm_storeu_si128(q.as_mut_ptr().add(t) as *mut __m128i, vq);
                _mm_storeu_si128(rem.as_mut_ptr().add(t) as *mut __m128i, vr);
            }
            t += 4;
        }
    }

    /// 8-wide significand product (products fit `i32` at both widths).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mullo(
        x: &[i32; BLOCK],
        y: &[i32; BLOCK],
        out: &mut [i32; BLOCK],
        r: usize,
    ) {
        let mut t = 0;
        while t < r {
            unsafe {
                let vx = _mm256_loadu_si256(x.as_ptr().add(t) as *const __m256i);
                let vy = _mm256_loadu_si256(y.as_ptr().add(t) as *const __m256i);
                _mm256_storeu_si256(
                    out.as_mut_ptr().add(t) as *mut __m256i,
                    _mm256_mullo_epi32(vx, vy),
                );
            }
            t += 8;
        }
    }

    /// Add/sub real lanes inside the AVX2 target-feature region.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add_sub_lanes(
        n: u32,
        sub: bool,
        a: &[u64],
        b: &[u64],
        out: &mut [u64],
        real_idx: &[u8; BLOCK],
        r: usize,
    ) {
        super::add_sub_scalar(n, sub, a, b, out, real_idx, r);
    }
}

/// NEON kernels: 4-wide f32 for P8 (`vdivq_f32` is correctly rounded on
/// aarch64), scalar f64 for P16 (no 4-wide i32↔f64 path worth the
/// shuffle), 4-wide `vmulq_s32` products.
#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    use super::BLOCK;

    /// 4-wide P8 division via f32 lanes plus the ±1 remainder fix-up.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn div_q_rem_f32(
        num: &[i32; BLOCK],
        den: &[i32; BLOCK],
        q: &mut [i32; BLOCK],
        rem: &mut [i32; BLOCK],
        r: usize,
    ) {
        let mut t = 0;
        while t < r {
            unsafe {
                let vn = vld1q_s32(num.as_ptr().add(t));
                let vd = vld1q_s32(den.as_ptr().add(t));
                let fq = vdivq_f32(vcvtq_f32_s32(vn), vcvtq_f32_s32(vd));
                let mut vq = vcvtq_s32_f32(fq); // truncates toward zero
                let mut vr = vsubq_s32(vn, vmulq_s32(vq, vd));
                // rem < 0 → q -= 1, rem += den (cmp mask is −1 per lane)
                let neg = vreinterpretq_s32_u32(vcltq_s32(vr, vdupq_n_s32(0)));
                vq = vaddq_s32(vq, neg);
                vr = vaddq_s32(vr, vandq_s32(neg, vd));
                // rem ≥ den → q += 1, rem -= den
                let over = vreinterpretq_s32_u32(vcgeq_s32(vr, vd));
                vq = vsubq_s32(vq, over);
                vr = vsubq_s32(vr, vandq_s32(over, vd));
                vst1q_s32(q.as_mut_ptr().add(t), vq);
                vst1q_s32(rem.as_mut_ptr().add(t), vr);
            }
            t += 4;
        }
    }

    /// P16 division: scalar f64 per lane inside the NEON region (the
    /// i32→f64 widening shuffle costs more than it saves at 2 lanes per
    /// register); same float-divide-plus-fix-up contract as the x86 f64
    /// kernel.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn div_q_rem_f64(
        num: &[i32; BLOCK],
        den: &[i32; BLOCK],
        q: &mut [i32; BLOCK],
        rem: &mut [i32; BLOCK],
        r: usize,
    ) {
        for t in 0..r {
            let (n, d) = (num[t], den[t]);
            let mut qq = (n as f64 / d as f64) as i32;
            let mut rr = n - qq * d;
            if rr < 0 {
                qq -= 1;
                rr += d;
            }
            if rr >= d {
                qq += 1;
                rr -= d;
            }
            q[t] = qq;
            rem[t] = rr;
        }
    }

    /// 4-wide significand product.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn mullo(
        x: &[i32; BLOCK],
        y: &[i32; BLOCK],
        out: &mut [i32; BLOCK],
        r: usize,
    ) {
        let mut t = 0;
        while t < r {
            unsafe {
                let vx = vld1q_s32(x.as_ptr().add(t));
                let vy = vld1q_s32(y.as_ptr().add(t));
                vst1q_s32(out.as_mut_ptr().add(t), vmulq_s32(vx, vy));
            }
            t += 4;
        }
    }

    /// Add/sub real lanes inside the NEON target-feature region.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn add_sub_lanes(
        n: u32,
        sub: bool,
        a: &[u64],
        b: &[u64],
        out: &mut [u64],
        real_idx: &[u8; BLOCK],
        r: usize,
    ) {
        super::add_sub_scalar(n, sub, a, b, out, real_idx, r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::fastpath::scalar_bits;
    use crate::testkit::Rng;

    const KINDS: [Kind; 4] = [Kind::Div, Kind::Mul, Kind::Add, Kind::Sub];

    #[test]
    fn supports_is_div_mul_add_sub_at_8_and_16() {
        for n in [8u32, 16] {
            for kind in KINDS {
                assert!(supports(n, kind), "n={n} {kind:?}");
            }
            assert!(!supports(n, Kind::Sqrt));
            assert!(!supports(n, Kind::MulAdd));
        }
        for n in [4u32, 10, 32, 64] {
            assert!(!supports(n, Kind::Div), "n={n}");
        }
    }

    #[test]
    fn available_implies_feature_and_isa() {
        // Without the cargo feature this must be constant false; with it,
        // whatever detection said is cached and stable across calls.
        let first = available();
        if cfg!(not(feature = "vsimd")) {
            assert!(!first);
        }
        assert_eq!(available(), first);
    }

    /// Random lanes with specials sprinkled in, vector vs scalar kernel,
    /// at lengths covering dense words, partial blocks and ragged tails.
    /// Skips (passes vacuously) when the CPU lacks the ISA.
    #[test]
    fn vector_batch_matches_scalar_kernel() {
        if !available() {
            return;
        }
        let mut rng = Rng::seeded(0x7EC7);
        for n in [8u32, 16] {
            for kind in KINDS {
                for len in [1usize, 3, 7, 16, 17, 63, 64, 65, 257] {
                    let make_lane = |rng: &mut Rng, sprinkle: bool| -> Vec<u64> {
                        (0..len)
                            .map(|i| {
                                if sprinkle && i % 5 == 0 {
                                    [0u64, 1 << (n - 1)][i / 5 % 2]
                                } else {
                                    rng.next_u64() & mask(n)
                                }
                            })
                            .collect()
                    };
                    for sprinkle in [false, true] {
                        let a = make_lane(&mut rng, sprinkle);
                        let b = make_lane(&mut rng, sprinkle);
                        let mut out = vec![0u64; len];
                        run_batch(n, kind, &a, &b, &[], &mut out);
                        for i in 0..len {
                            assert_eq!(
                                out[i],
                                scalar_bits(n, kind, a[i], b[i], 0),
                                "{kind:?} n={n} len={len} i={i} sprinkle={sprinkle}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Exhaustive Posit8 pattern pairs through the vector kernels.
    /// Skips (passes vacuously) when the CPU lacks the ISA.
    #[test]
    fn vector_exhaustive_p8_binary_ops() {
        if !available() {
            return;
        }
        for kind in KINDS {
            let b: Vec<u64> = (0..=mask(8)).collect();
            let mut out = vec![0u64; b.len()];
            for a in 0..=mask(8) {
                let av = vec![a; b.len()];
                run_batch(8, kind, &av, &b, &[], &mut out);
                for (i, &got) in out.iter().enumerate() {
                    assert_eq!(
                        got,
                        scalar_bits(8, kind, a, b[i], 0),
                        "{kind:?} {a:#04x} {:#04x}",
                        b[i]
                    );
                }
            }
        }
    }

    /// P16 seeded sweep pinning the f64 division kernel's fix-up range
    /// (every decodable num/den pair must produce the exact floor and
    /// remainder through whatever float path the target uses).
    #[test]
    fn vector_p16_division_quotients_are_exact() {
        if !available() {
            return;
        }
        let mut rng = Rng::seeded(0x16D1);
        let f = frac_bits(16);
        for _ in 0..200_000 {
            let sa = (1u64 << f) | (rng.next_u64() & mask(f));
            let sb = (1u64 << f) | (rng.next_u64() & mask(f));
            let mut num = [0i32; BLOCK];
            let mut den = [1i32; BLOCK];
            num[0] = (sa << 16) as i32;
            den[0] = sb as i32;
            let mut q = [0i32; BLOCK];
            let mut rem = [0i32; BLOCK];
            div_q_rem(16, &num, &den, &mut q, &mut rem, 1);
            assert_eq!(q[0] as u64, (sa << 16) / sb, "sa={sa:#x} sb={sb:#x}");
            assert_eq!(rem[0] as u64, (sa << 16) % sb, "sa={sa:#x} sb={sb:#x}");
        }
    }
}
