//! `posit-div` — command-line front end for the digit-recurrence posit
//! division framework and its operation-generic unit.

use std::time::Instant;

use posit_div::bench::report::Report;
use posit_div::bench::{harness, suites, Config, Profile};
use posit_div::cli::Args;
use posit_div::coordinator::{Backend, BatchPolicy, DivisionService, ServiceConfig};
use posit_div::division::{golden, Algorithm};
use posit_div::hardware::{report, Mode, TSMC28};
use posit_div::posit::Posit;
use posit_div::service::{
    BreakerConfig, ConnectOptions, ResilientClient, RetryPolicy, Server, ServiceClient,
    ShardConfig,
};
use posit_div::unit::{Accuracy, ExecTier, FastPath, Op, Unit};
use posit_div::workload::{self, OpMix, OpenLoop, Workload};
use posit_div::PositError;

const USAGE: &str = "usage: posit-div <subcommand> [flags]

subcommands:
  synth [--csv] [--n 16|32|64] [--mode comb|pipe]   synthesis model (Figs. 4-9)
  table2                                            iteration/latency table
  divide <x> <d> [--n N] [--alg NAME] [--bits] [--tier fast|datapath|approx|auto]
         [--path auto|table|vector|simd|scalar]     one division, all metadata
                                                    (--path pins the fast kernel)
  sqrt <v> [--n N] [--bits] [--tier T]              one square root, all metadata
  verify [--n N] [--cases N]                        engines + fast tier vs golden cross-check
  serve [--n N] [--backend native|pjrt] [--requests N] [--batch N] [--threads N]
        [--mix div:6,sqrt:2,dot:2,fsum:1,axpy:1,...]
        [--tier T] [--accuracy exact|ulp:K]         serve division or mixed-op traffic
                                                    (dot/fsum/axpy = quire reductions;
                                                    ulp:K routes eligible ops approx)
  serve --listen HOST:PORT [--shards K] [--queue-cap Q] [--soft-cap S]
        [--idle-ms MS] [--json P]
        [--n N] [--backend B] [--batch N] [--threads N] [--tier T]
                                                    sharded TCP server (docs/SERVING.md);
                                                    runs until a client sends --shutdown;
                                                    --soft-cap sets the brown-out
                                                    watermark, --idle-ms the idle-client
                                                    reap timeout (0 disables)
  client --connect HOST:PORT [--n N] [--requests N] [--mix M] [--rate R]
         [--window W] [--verify-every K] [--accuracy exact|ulp:K]
         [--deadline-ms D] [--shutdown]             drive a server over TCP: closed-loop
                                                    pipelined, or open-loop with --rate
                                                    (arrivals/s); --shutdown stops it
  client --endpoints A,B,C [--retries N] [--deadline-ms D] [--json P]
         [--n N] [--requests N] [--mix M] [--verify-every K]
         [--accuracy exact|ulp:K] [--shutdown]      fault-tolerant client: fan one stream
                                                    over N endpoints with circuit breakers
                                                    + bounded seeded retry; --json writes
                                                    the resilience report
  engines                                           list algorithm variants
  bench <suite> [--json P] [--baseline P] [--write-baseline] [--quick|--full]
        [--threshold PCT] [--advisory] [--tier T] [--path P]
                                                    run a bench suite + regression gate
  bench list                                        list bench suites
  bench validate <report.json>                      schema-check a bench report
  bench compare <a.json> <b.json> [--threshold PCT] [--advisory]
                                                    delta two report files (a = baseline)";

fn alg_by_name(name: &str) -> Option<Algorithm> {
    Algorithm::ALL.iter().copied().find(|a| {
        a.label().eq_ignore_ascii_case(name)
            || a.label().replace(' ', "-").eq_ignore_ascii_case(name)
            || format!("{a:?}").eq_ignore_ascii_case(name)
    })
}

/// `--tier fast|datapath|approx|auto` (default auto).
fn tier_flag(args: &Args) -> ExecTier {
    match args.flag("tier") {
        None => ExecTier::Auto,
        Some(s) => ExecTier::parse(s).unwrap_or_else(|| {
            eprintln!("invalid --tier {s:?} (expected fast|datapath|approx|auto)");
            std::process::exit(2);
        }),
    }
}

/// `--path auto|table|vector|simd|scalar` (default auto): pin the
/// fast-tier batch kernel ([`Unit::with_exec`] validates the pin, so an
/// unsupported combination is a typed refusal, not a silent fallback).
fn path_flag(args: &Args) -> FastPath {
    match args.flag("path") {
        None => FastPath::Auto,
        Some(s) => FastPath::parse(s).unwrap_or_else(|| {
            eprintln!("invalid --path {s:?} (expected auto|table|vector|simd|scalar)");
            std::process::exit(2);
        }),
    }
}

/// `--accuracy exact|ulp:K` (default exact). `ulp:K` marks generated
/// traffic as tolerating up to K ulps of error, which lets the service
/// route eligible ops to the approx tier.
fn accuracy_flag(args: &Args) -> Accuracy {
    match args.flag("accuracy") {
        None => Accuracy::Exact,
        Some(s) => Accuracy::parse(s).unwrap_or_else(|| {
            eprintln!("invalid --accuracy {s:?} (expected exact|ulp:K)");
            std::process::exit(2);
        }),
    }
}

/// The ulp tolerance a verified result is allowed against golden.
fn ulp_tolerance(accuracy: Accuracy) -> u64 {
    match accuracy {
        Accuracy::Exact => 0,
        Accuracy::Ulp(k) => u64::from(k),
    }
}

fn main() {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("synth") => cmd_synth(&args),
        Some("table2") => print!("{}", report::render_table2()),
        Some("divide") => cmd_divide(&args),
        Some("sqrt") => cmd_sqrt(&args),
        Some("verify") => cmd_verify(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("bench") => cmd_bench(&args),
        Some("engines") => {
            for a in Algorithm::ALL {
                println!("{:<18} radix={:?}", a.label(), a.radix());
            }
        }
        Some(unknown) => {
            eprintln!("unknown subcommand {unknown:?}\n\n{USAGE}");
            std::process::exit(2);
        }
        None => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_synth(args: &Args) {
    let csv = args.has("csv");
    let modes: Vec<Mode> = match args.flag("mode") {
        Some("comb") => vec![Mode::Combinational],
        Some("pipe") => vec![Mode::Pipelined],
        _ => vec![Mode::Combinational, Mode::Pipelined],
    };
    let formats: Vec<u32> = match args.flag("n") {
        Some(n) => vec![n.parse().expect("--n")],
        None => report::FORMATS.to_vec(),
    };
    for mode in modes {
        for &n in &formats {
            if csv {
                print!("{}", report::sweep_csv(n, mode, &TSMC28));
            } else {
                println!("{}", report::render_figure(n, mode, &TSMC28));
            }
        }
    }
    if !csv {
        print!("{}", report::render_asap23(&TSMC28));
    }
}

/// Parse a positional operand: decimal, or a raw hex pattern with
/// `--bits`.
fn parse_operand(args: &Args, n: u32, s: &str) -> Posit {
    if args.has("bits") {
        let raw = s.trim_start_matches("0x");
        Posit::from_bits(n, u64::from_str_radix(raw, 16).expect("hex pattern"))
    } else {
        Posit::from_f64(n, s.parse().expect("number"))
    }
}

fn cmd_divide(args: &Args) {
    let n: u32 = args.get("n", 32);
    let alg = alg_by_name(args.flag("alg").unwrap_or("Srt4CsOfFr")).unwrap_or_else(|| {
        eprintln!("unknown algorithm (try `posit-div engines`)");
        std::process::exit(2);
    });
    if args.positional.len() != 2 {
        eprintln!("usage: posit-div divide <x> <d> [--n N] [--alg NAME] [--bits]");
        std::process::exit(2);
    }
    let x = parse_operand(args, n, &args.positional[0]);
    let d = parse_operand(args, n, &args.positional[1]);
    let tier = tier_flag(args);
    let path = path_flag(args);
    let unit = Unit::with_exec(n, Op::Div { alg }, tier, path).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    // a pinned kernel serves through the batch/bit-level entry point (the
    // metadata-bearing scalar path never consults the fast-path layer)
    let div = unit.run(&[x, d]).expect("operands constructed at the context width");
    if path == FastPath::Auto {
        println!(
            "Posit{n} {} / {} = {}  (bits {:#x}, {} iterations, {} cycles, alg {}, tier {})",
            x,
            d,
            div.result,
            div.result.to_bits(),
            div.iterations,
            div.cycles,
            alg.label(),
            unit.scalar_tier()
        );
    } else {
        // the batch entry point is the one that honors a pinned kernel
        let mut out = [0u64; 1];
        unit.run_batch(&[x.to_bits()], &[d.to_bits()], &[], &mut out)
            .expect("1-lane batch with matched lanes");
        let bits = out[0];
        assert_eq!(bits, div.result.to_bits(), "pinned kernel diverged from the scalar tier");
        println!(
            "Posit{n} {} / {} = {}  (bits {bits:#x}, alg {}, tier {}, path {})",
            x,
            d,
            Posit::from_bits(n, bits),
            alg.label(),
            unit.batch_tier(),
            unit.resolve_fast_path(1).map_or("-", FastPath::name)
        );
    }
}

fn cmd_sqrt(args: &Args) {
    let n: u32 = args.get("n", 32);
    if args.positional.len() != 1 {
        eprintln!("usage: posit-div sqrt <v> [--n N] [--bits]");
        std::process::exit(2);
    }
    let v = parse_operand(args, n, &args.positional[0]);
    let unit = Unit::with_tier(n, Op::Sqrt, tier_flag(args)).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let r = unit.run(&[v]).expect("operand constructed at the context width");
    println!(
        "Posit{n} sqrt({}) = {}  (bits {:#x}, {} iterations, {} cycles, engine {}, tier {})",
        v,
        r.result,
        r.result.to_bits(),
        r.iterations,
        r.cycles,
        unit.engine_name(),
        unit.scalar_tier()
    );
}

fn cmd_verify(args: &Args) {
    let n: u32 = args.get("n", 16);
    let cases: u64 = args.get("cases", 100_000);
    let mut w = workload::Uniform::new(n, 0xF00D);
    let units: Vec<Unit> = Algorithm::ALL
        .iter()
        .map(|&alg| {
            Unit::with_tier(n, Op::Div { alg }, ExecTier::Datapath).unwrap_or_else(|e| {
                eprintln!("{e}");
                std::process::exit(2);
            })
        })
        .collect();
    let fast = Unit::with_tier(n, Op::DIV, ExecTier::Fast).expect("width validated above");
    let t0 = Instant::now();
    for i in 0..cases {
        let (x, d) = w.next_pair();
        let want = golden::divide(x, d).result;
        for unit in &units {
            let got = unit.run(&[x, d]).expect("workload width matches").result;
            assert_eq!(got, want, "{} diverges at case {i}: {x:?}/{d:?}", unit.engine_name());
        }
        let got = fast.run_bits(x.to_bits(), d.to_bits(), 0);
        assert_eq!(got, want.to_bits(), "fast tier diverges at case {i}: {x:?}/{d:?}");
    }
    println!(
        "verified {} engines + the fast tier x {} cases on Posit{} against the golden model \
         in {:?} - all bit-exact",
        units.len(),
        cases,
        n,
        t0.elapsed()
    );
}

fn cmd_bench(args: &Args) {
    // Every flag the bench harness understands; used to detect a suite
    // name swallowed by the greedy flag grammar.
    const BENCH_FLAGS: [&str; 10] = [
        "quick", "full", "advisory", "write-baseline", "json", "baseline", "profile", "threshold",
        "tier", "path",
    ];
    let code = match args.positional.first().map(String::as_str) {
        None => {
            // Flags without a suite name mean the grammar likely swallowed
            // it (`bench --quick engine_throughput` parses as
            // quick="engine_throughput", `bench --json engine_throughput`
            // as json="engine_throughput"): refuse rather than silently
            // listing suites with exit 0, which would green a CI step
            // that never benchmarked anything.
            match BENCH_FLAGS.iter().find(|f| args.has(f)) {
                Some(sw) => {
                    eprintln!(
                        "no suite named but `--{sw}` given — a flag may have swallowed the \
                         suite name; put the suite first: `posit-div bench <suite> --{sw} ...`"
                    );
                    2
                }
                None => {
                    print!("{}", suites::render_list());
                    0
                }
            }
        }
        Some("list") => {
            print!("{}", suites::render_list());
            0
        }
        Some("validate") => match args.positional.get(1) {
            Some(path) => harness::validate_report(std::path::Path::new(path)),
            None => {
                eprintln!("usage: posit-div bench validate <report.json>");
                2
            }
        },
        Some("compare") => match (args.positional.get(1), args.positional.get(2)) {
            (Some(a), Some(b)) => harness::compare_command(
                std::path::Path::new(a),
                std::path::Path::new(b),
                args,
            ),
            _ => {
                eprintln!(
                    "usage: posit-div bench compare <baseline.json> <new.json> \
                     [--threshold PCT] [--advisory]"
                );
                2
            }
        },
        Some(name) => harness::run_suite(name, args),
    };
    std::process::exit(code);
}

fn cmd_serve(args: &Args) {
    if let Some(listen) = args.flag("listen") {
        cmd_serve_listen(args, listen);
        return;
    }
    let n: u32 = args.get("n", 16);
    let requests: usize = args.get("requests", 100_000);
    let batch: usize = args.get("batch", 256);
    let threads: usize = args.get("threads", 4);
    let mix = args.flag("mix").map(|s| {
        OpMix::parse(s).unwrap_or_else(|| {
            eprintln!("invalid --mix {s:?} (expected e.g. div:6,sqrt:2,mul:4,dot:2,fsum:1,axpy:1)");
            std::process::exit(2);
        })
    });
    let backend = match args.flag("backend").unwrap_or("native") {
        "pjrt" => Backend::Pjrt { artifacts_dir: "artifacts".into() },
        _ => Backend::Native { alg: Algorithm::DEFAULT, threads },
    };
    let svc = DivisionService::start(ServiceConfig {
        n,
        backend,
        policy: BatchPolicy { max_batch: batch, max_wait: std::time::Duration::from_micros(200) },
        tier: tier_flag(args),
    })
    .unwrap_or_else(|e| {
        eprintln!("service start failed: {e}");
        std::process::exit(1);
    });

    let client = svc.client();
    let accuracy = accuracy_flag(args);
    let (wall, what) = if let Some(mix) = mix {
        let mut w = workload::MixedOps::new(n, mix, 0x5E12).with_accuracy(accuracy);
        let reqs = workload::take_requests(&mut w, requests);
        let t0 = Instant::now();
        let results = client.submit_ops(&reqs).expect("service running").wait().expect("running");
        let wall = t0.elapsed();
        // verify a sample against the golden references, within the
        // tolerance the accuracy policy grants
        for (i, req) in reqs.iter().enumerate().step_by(101) {
            let dist = results[i].ulp_distance(req.golden());
            assert!(
                dist <= ulp_tolerance(req.accuracy()),
                "{} sample {i}: {dist} ulp from golden under {}",
                req.op,
                req.accuracy()
            );
        }
        (wall, "mixed ops")
    } else {
        let mut w = workload::DspTrace::new(n, 0x5E12);
        let pairs = workload::take(&mut w, requests);
        let t0 = Instant::now();
        let results = client.divide_batch(&pairs).expect("service running");
        let wall = t0.elapsed();
        // verify a sample against the golden model
        for (i, &(x, d)) in pairs.iter().enumerate().step_by(101) {
            assert_eq!(results[i], golden::divide(x, d).result, "{x:?}/{d:?}");
        }
        (wall, "divisions")
    };
    let m = svc.metrics();
    println!("served {requests} Posit{n} {what} in {wall:?}");
    println!("  throughput: {:.0} op/s", requests as f64 / wall.as_secs_f64());
    println!("  request latency: {}", m.request_latency.summary());
    println!("  batch latency:   {}", m.batch_latency.summary());
    println!(
        "  batches: {} (mean fill {:.1}%)",
        m.batches.load(std::sync::atomic::Ordering::Relaxed),
        100.0 * m.mean_batch_fill(batch)
    );
    println!("  ops: {}", m.ops.summary());
    println!("  tiers: {}", m.tiers.summary());
    println!("  approx audit:");
    for line in m.approx_errors.summary().lines() {
        println!("    {line}");
    }
    svc.shutdown();
}

/// `serve --listen HOST:PORT`: the sharded TCP serving tier. Runs until
/// a client sends a SHUTDOWN frame (`posit-div client --connect ADDR
/// --shutdown`), then prints per-shard counters and the merged SLO
/// latency panel — and, with `--json P`, writes the panel as a
/// `service_live` bench report (`posit-div bench validate` checks it).
fn cmd_serve_listen(args: &Args, listen: &str) {
    let n: u32 = args.get("n", 16);
    let batch: usize = args.get("batch", 256);
    let threads: usize = args.get("threads", 4);
    let shards: usize = args.get("shards", 2);
    let queue_capacity: usize = args.get("queue-cap", 4096);
    // soft watermark defaults to 3/4 of the hard cap; --soft-cap equal to
    // --queue-cap disables brown-out (shed happens first)
    let soft_capacity: usize = args.get("soft-cap", queue_capacity - queue_capacity / 4);
    let idle_ms: u64 = args.get("idle-ms", 30_000);
    let backend = match args.flag("backend").unwrap_or("native") {
        "pjrt" => Backend::Pjrt { artifacts_dir: "artifacts".into() },
        _ => Backend::Native { alg: Algorithm::DEFAULT, threads },
    };
    let cfg = ShardConfig {
        shards,
        queue_capacity,
        soft_capacity,
        idle_timeout: std::time::Duration::from_millis(idle_ms),
        service: ServiceConfig {
            n,
            backend,
            policy: BatchPolicy {
                max_batch: batch,
                max_wait: std::time::Duration::from_micros(200),
            },
            tier: tier_flag(args),
        },
    };
    let server = Server::bind(listen, cfg).unwrap_or_else(|e| {
        eprintln!("bind {listen} failed: {e}");
        std::process::exit(1);
    });
    let addr = server.local_addr();
    println!(
        "listening on {addr} (Posit{n}, {shards} shards, queue {queue_capacity}, \
         soft cap {soft_capacity}); \
         stop with `posit-div client --connect {addr} --shutdown`"
    );
    let svc = server.wait(); // blocks until a SHUTDOWN frame arrives
    println!("shutdown requested; connections drained");
    print!("{}", svc.counters_render());
    let panel = svc.latency_snapshot();
    print!("{}", panel.render());
    println!(
        "total: requests={} shed={} degraded={} deadline_drops={}",
        svc.total_requests(),
        svc.shed_total(),
        svc.degraded_total(),
        svc.deadline_drops_total()
    );
    if let Some(path) = args.flag("json") {
        let rows = suites::latency_rows(n, &panel);
        let rep = Report::new("service_live", Profile::Quick, Config::quick(), rows);
        match rep.save(std::path::Path::new(path)) {
            Ok(()) => println!("wrote {} latency rows to {path}", rep.measurements.len()),
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                svc.shutdown();
                std::process::exit(1);
            }
        }
    }
    svc.shutdown();
}

/// `client --connect HOST:PORT`: drive a serving tier over TCP.
/// Closed-loop (windowed pipelining) by default; `--rate R` switches to
/// an open-loop Poisson arrival process, the way an SLO sees latency.
/// Exits non-zero on transport failure, golden-verification mismatch,
/// or non-shed request errors.
fn cmd_client(args: &Args) {
    let n: u32 = args.get("n", 16);
    let requests: usize = args.get("requests", 10_000);
    let verify_every: usize = args.get("verify-every", 101);
    let deadline_ms: u32 = args.get("deadline-ms", 0);
    let mix_s =
        args.flag("mix").unwrap_or("div:6,sqrt:2,mul:4,add:4,sub:2,fma:2,dot:1,fsum:1,axpy:1");
    let mix = OpMix::parse(mix_s).unwrap_or_else(|| {
        eprintln!("invalid --mix {mix_s:?} (expected e.g. div:6,sqrt:2,mul:4,dot:2,fsum:1,axpy:1)");
        std::process::exit(2);
    });
    if let Some(endpoints) = args.flag("endpoints") {
        cmd_client_resilient(args, endpoints, n, requests, verify_every, deadline_ms, mix);
        return;
    }
    let addr = args.flag("connect").unwrap_or_else(|| {
        eprintln!("usage: posit-div client --connect HOST:PORT [flags]\n\n{USAGE}");
        std::process::exit(2);
    });
    let mut client = ServiceClient::connect(addr, n).unwrap_or_else(|e| {
        eprintln!("connect {addr} failed: {e}");
        std::process::exit(1);
    });
    if let Some(w) = args.flag("window") {
        client.set_window(w.parse().expect("--window"));
    }
    println!("connected to {addr}: Posit{} across {} shards", client.width(), client.shards());
    let accuracy = accuracy_flag(args);
    if requests > 0 {
        if let Some(rate) = args.flag("rate") {
            let rate: f64 = rate.parse().expect("--rate");
            let mut wl =
                OpenLoop::new(n, mix, rate, 0x5E12).with_accuracy(accuracy).with_deadline_ms(deadline_ms);
            let rep = client.run_open_loop(&mut wl, requests, verify_every).unwrap_or_else(|e| {
                eprintln!("open loop failed: {e}");
                std::process::exit(1);
            });
            println!(
                "open loop @ {:.0}/s nominal, {:.0}/s achieved",
                wl.rate(),
                rep.achieved_rate()
            );
            println!("  {}", rep.summary());
            if rep.verify_failures > 0 || rep.errors > 0 {
                eprintln!(
                    "{} verification failures, {} request errors",
                    rep.verify_failures, rep.errors
                );
                std::process::exit(1);
            }
        } else {
            let mut wl = workload::MixedOps::new(n, mix, 0x5E12)
                .with_accuracy(accuracy)
                .with_deadline_ms(deadline_ms);
            let reqs = workload::take_requests(&mut wl, requests);
            let t0 = Instant::now();
            let results = client.run_ops(&reqs).unwrap_or_else(|e| {
                eprintln!("transport failed: {e}");
                std::process::exit(1);
            });
            let wall = t0.elapsed();
            let (mut ok, mut shed, mut dropped, mut errors, mut bad) =
                (0usize, 0usize, 0usize, 0usize, 0usize);
            for (i, (req, res)) in reqs.iter().zip(&results).enumerate() {
                match res {
                    Ok(p) => {
                        ok += 1;
                        if verify_every != 0
                            && i % verify_every == 0
                            && p.ulp_distance(req.golden()) > ulp_tolerance(req.accuracy())
                        {
                            bad += 1;
                        }
                    }
                    Err(PositError::ServiceOverloaded { .. }) => shed += 1,
                    Err(PositError::DeadlineExceeded { .. }) => dropped += 1,
                    Err(_) => errors += 1,
                }
            }
            println!(
                "closed loop: {requests} requests in {wall:?} ({:.0} op/s) \
                 ok={ok} shed={shed} deadline_drops={dropped} errors={errors} \
                 verify_failures={bad}",
                requests as f64 / wall.as_secs_f64()
            );
            if bad > 0 || errors > 0 {
                std::process::exit(1);
            }
        }
    }
    let closed = if args.has("shutdown") {
        println!("sending SHUTDOWN");
        client.shutdown_server()
    } else {
        client.bye()
    };
    if let Err(e) = closed {
        eprintln!("close failed: {e}");
        std::process::exit(1);
    }
}

/// `client --endpoints A,B,C`: the fault-tolerant path. One logical
/// request stream fans over every endpoint with per-endpoint circuit
/// breakers and bounded seeded retry; a request is lost only when its
/// whole retry budget fails. `--json P` writes the resilience report
/// (the CI chaos leg asserts `"lost": 0` and a non-zero
/// `"breaker_opens"` from it). Exits non-zero on lost requests or
/// golden-verification failures.
fn cmd_client_resilient(
    args: &Args,
    endpoints: &str,
    n: u32,
    requests: usize,
    verify_every: usize,
    deadline_ms: u32,
    mix: OpMix,
) {
    let addrs: Vec<std::net::SocketAddr> = endpoints
        .split(',')
        .map(|s| {
            s.trim().parse().unwrap_or_else(|e| {
                eprintln!("invalid endpoint {s:?} in --endpoints: {e}");
                std::process::exit(2);
            })
        })
        .collect();
    let policy = RetryPolicy { max_retries: args.get("retries", 8), ..RetryPolicy::default() };
    let opts = ConnectOptions {
        connect_timeout: Some(std::time::Duration::from_millis(1000)),
        read_timeout: Some(std::time::Duration::from_millis(2000)),
    };
    let mut rc = ResilientClient::new(&addrs, n, policy, BreakerConfig::default(), opts)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    let accuracy = accuracy_flag(args);
    let mut wl = workload::MixedOps::new(n, mix, 0x5E12)
        .with_accuracy(accuracy)
        .with_deadline_ms(deadline_ms);
    let reqs = workload::take_requests(&mut wl, requests);
    let t0 = Instant::now();
    let rep = rc.run_requests(&reqs, verify_every);
    let wall = t0.elapsed();
    let lost = rep.offered - rep.completed;
    println!(
        "resilient: {} requests over {} endpoints in {wall:?} ({:.0} op/s)",
        requests,
        addrs.len(),
        requests as f64 / wall.as_secs_f64()
    );
    println!("  {}", rep.summary());
    if let Some(path) = args.flag("json") {
        let json = format!(
            "{{\n  \"endpoints\": {},\n  \"offered\": {},\n  \"completed\": {},\n  \
             \"lost\": {},\n  \"retries\": {},\n  \"connects\": {},\n  \
             \"breaker_opens\": {},\n  \"duplicates_discarded\": {},\n  \
             \"degraded\": {},\n  \"shed_retries\": {},\n  \"deadline_retries\": {},\n  \
             \"verify_failures\": {}\n}}\n",
            addrs.len(),
            rep.offered,
            rep.completed,
            lost,
            rep.retries,
            rep.connects,
            rep.breaker_opens,
            rep.duplicates_discarded,
            rep.degraded,
            rep.shed_retries,
            rep.deadline_retries,
            rep.verify_failures,
        );
        if let Err(e) = std::fs::write(path, json) {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1);
        }
        println!("wrote resilience report to {path}");
    }
    if args.has("shutdown") {
        println!("sending SHUTDOWN to every endpoint");
        rc.shutdown_endpoints();
    } else {
        rc.close_connections();
    }
    if lost > 0 || rep.verify_failures > 0 {
        eprintln!("{lost} lost requests, {} verification failures", rep.verify_failures);
        std::process::exit(1);
    }
}
