//! Bench: Table II — iteration counts and pipelined latency, *measured*
//! from the executing engines (not just the formula), plus wall-clock
//! division rates per radix.

use posit_div::bench::{bench_batched, black_box, Config, Runner};
use posit_div::division::{iterations, latency_cycles, Algorithm, DivEngine, Divider};
use posit_div::posit::{mask, Posit};
use posit_div::testkit::Rng;

fn main() {
    println!("Table II — iterations and latency (measured from engines)");
    println!(
        "{:<8} {:>9} {:>11} {:>9} {:>11}",
        "format", "r2 iters", "r2 latency", "r4 iters", "r4 latency"
    );
    for n in [16u32, 32, 64] {
        let mut rng = Rng::seeded(n as u64);
        let x = Posit::from_bits(n, rng.next_u64() & mask(n));
        let d = Posit::from_bits(n, (rng.next_u64() & mask(n)) | 1);
        let (x, d) = (x.abs().next_up(), d.abs().next_up()); // avoid specials
        let ctx_r2 = Divider::new(n, Algorithm::Srt2Cs).expect("width");
        let ctx_r4 = Divider::new(n, Algorithm::Srt4Cs).expect("width");
        let r2 = ctx_r2.divide(x, d).expect("width matches");
        let r4 = ctx_r4.divide(x, d).expect("width matches");
        assert_eq!(r2.iterations, iterations(n, 2));
        assert_eq!(r4.iterations, iterations(n, 4));
        assert_eq!(r2.iterations, ctx_r2.iterations()); // cached in the context
        assert_eq!(r4.iterations, ctx_r4.iterations());
        assert_eq!(r2.cycles, latency_cycles(n, Algorithm::Srt2Cs));
        assert_eq!(r4.cycles, latency_cycles(n, Algorithm::Srt4Cs));
        println!(
            "Posit{:<4} {:>8} {:>11} {:>9} {:>11}",
            n, r2.iterations, r2.cycles, r4.iterations, r4.cycles
        );
    }

    // Wall-clock counterpart: the software engines' division rate tracks
    // the iteration count.
    let mut runner = Runner::new("software division rate (iterations dominate)");
    let mut rng = Rng::seeded(42);
    for n in [16u32, 32, 64] {
        for alg in [Algorithm::Srt2Cs, Algorithm::Srt4Cs] {
            let ctx = Divider::new(n, alg).expect("width");
            let xs: Vec<u64> = (0..256).map(|_| rng.next_u64() & mask(n)).collect();
            let ds: Vec<u64> = (0..256).map(|_| (rng.next_u64() & mask(n)) | 1).collect();
            let mut out = vec![0u64; xs.len()];
            let m = bench_batched(
                &format!("Posit{n} {}", ctx.name()),
                Config::default(),
                xs.len() as u64,
                || {
                    ctx.divide_batch(&xs, &ds, &mut out).expect("equal lengths");
                    black_box(&out);
                },
            );
            runner.add(m);
        }
    }
    runner.finish();
}
