//! L3 coordinator: a batched posit functional-unit service.
//!
//! The paper's contribution is the arithmetic unit, so the coordinator is
//! the thin-but-real driver the architecture calls for: a leader thread
//! owns a dynamic [`batcher`] (size + deadline policy) and a backend, and
//! serves **op-tagged** requests ([`crate::unit::OpRequest`]: division by
//! any Table IV engine, square root, mul, add/sub, mul-add, and the
//! quire-backed reductions dot/fused-sum/axpy). Mixed
//! batches are split per operation ([`batcher::group_indices`]) and each
//! group runs through a cached per-op [`crate::unit::Unit`] at the
//! configured [`crate::unit::ExecTier`] — reduction requests carry their
//! vector lanes with them and are served one result per request by the
//! same cached units — the native backend spreads
//! every group over the shared crate-level worker pool
//! ([`crate::pool::global`]; no per-batch thread spawning), while the
//! PJRT backend executes division groups on the AOT-compiled JAX/Pallas
//! graph ([`crate::runtime`]) and falls back to the native units for the
//! other operations. [`metrics`] counts how many requests each tier
//! served.
//!
//! Clients talk to the service through the typed [`Client`] handle:
//! `submit_op`/`submit_ops` (and the division conveniences
//! `submit`/`submit_batch`) return [`Pending`]/[`BatchHandle`]
//! futures-by-hand that resolve to typed results — the raw mpsc plumbing
//! is not part of the public surface. [`metrics`] tracks request/batch
//! latency and per-op counts.
//!
//! Python never runs here: the PJRT backend executes the pre-compiled
//! HLO artifact in-process.

pub mod batcher;
pub mod metrics;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Weak};
use std::thread::JoinHandle;
use std::time::Instant;

pub use batcher::BatchPolicy;
pub use metrics::{
    ApproxErrorPanel, ApproxErrorStats, Histogram, LatencyPanel, Metrics, OpCounters, ServedBy,
    TierCounters,
};
// The worker pool is a crate-level module now ([`crate::pool`]), shared
// by every parallel batch path; these re-exports keep the old
// `coordinator::{pool, Pool}` paths working.
pub use crate::pool::{self, Pool};

use crate::division::Algorithm;
use crate::error::{PositError, Result};
use crate::posit::{Posit, MAX_N, MIN_N};
use crate::runtime::Runtime;
use crate::unit::{Accuracy, ExecTier, FastPath, Op, OpRequest, Unit};

/// Audit sampling stride for approx-served groups: every k-th lane is
/// recomputed on the exact tier and its observed ulp error recorded in
/// [`Metrics::approx_errors`]. A stride of 8 keeps the audit overhead
/// near 1/8 of one exact pass while still catching contract drift fast.
const APPROX_AUDIT_INTERVAL: usize = 8;

/// Which execution engine serves the batches.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Bit-exact Rust engines, `threads`-way parallel per op group. `alg`
    /// is the division algorithm used for requests submitted through the
    /// division conveniences (`submit`/`divide`); explicit
    /// `Op::Div { alg }` requests pick their own engine.
    Native { alg: Algorithm, threads: usize },
    /// AOT-compiled JAX/Pallas graph via PJRT (artifacts from `make
    /// artifacts`) for division; other ops fall back to the native units.
    Pjrt { artifacts_dir: PathBuf },
}

impl Backend {
    /// The division op used by the legacy division entry points.
    fn default_div(&self) -> Op {
        match self {
            Backend::Native { alg, .. } => Op::Div { alg: *alg },
            Backend::Pjrt { .. } => Op::DIV,
        }
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub n: u32,
    pub backend: Backend,
    pub policy: BatchPolicy,
    /// Execution tier for the native units (the PJRT graph, when used for
    /// division groups, is its own path). The default `Auto` serves batch
    /// traffic from the Fast kernels; pin `Datapath` to serve from the
    /// cycle-accurate engines. Pinning `Approx` serves every op that has
    /// a registered bounded-error kernel from the Approx tier regardless
    /// of per-request policy (ops without one fall back to `Auto`);
    /// under any other tier, only requests whose [`Accuracy::Ulp`]
    /// policy a registered kernel satisfies route approx.
    pub tier: ExecTier,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            n: 32,
            backend: Backend::Native { alg: Algorithm::DEFAULT, threads: 4 },
            policy: BatchPolicy::default(),
            tier: ExecTier::Auto,
        }
    }
}

struct Request {
    op: Op,
    /// Routed to the Approx tier: the request's accuracy policy is
    /// satisfied by a registered kernel's declared bound (resolved at
    /// enqueue time by [`Op::routes_approx`], so grouping stays a cheap
    /// key compare).
    approx: bool,
    a: u64,
    b: u64,
    c: u64,
    /// Vector lanes of a reduction request (`Dot`/`FusedSum`/`Axpy`):
    /// the `a`/`b` element vectors, boxed so the common scalar request
    /// stays two words smaller. The `Axpy` coefficient rides in `c`.
    vec: Option<Box<(Vec<u64>, Vec<u64>)>>,
    enqueued: Instant,
    respond: Sender<u64>,
}

/// An in-flight operation submitted through a [`Client`].
pub struct Pending {
    n: u32,
    rx: Receiver<u64>,
}

impl Pending {
    /// Block until the service responds.
    pub fn wait(self) -> Result<Posit> {
        let bits = self.rx.recv().map_err(|_| PositError::ServiceStopped)?;
        Ok(Posit::from_bits(self.n, bits))
    }
}

/// A set of in-flight operations; results come back in submission order.
pub struct BatchHandle {
    n: u32,
    rxs: Vec<Receiver<u64>>,
}

impl BatchHandle {
    /// Block until every response arrives.
    pub fn wait(self) -> Result<Vec<Posit>> {
        let n = self.n;
        self.rxs
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .map(|bits| Posit::from_bits(n, bits))
                    .map_err(|_| PositError::ServiceStopped)
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.rxs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rxs.is_empty()
    }
}

/// A cheap, cloneable handle for submitting operations to a running
/// [`DivisionService`]. Holding a `Client` does not keep the service
/// alive: once the service shuts down, submissions return
/// [`PositError::ServiceStopped`] (already-queued requests still drain).
#[derive(Clone)]
pub struct Client {
    n: u32,
    div_op: Op,
    tx: Weak<Sender<Request>>,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Posit width served.
    pub fn width(&self) -> u32 {
        self.n
    }

    fn sender(&self) -> Result<Arc<Sender<Request>>> {
        self.tx.upgrade().ok_or(PositError::ServiceStopped)
    }

    fn check_request(&self, req: &OpRequest) -> Result<()> {
        // `OpRequest` constructors already guarantee one width across all
        // operand lanes (scalar slots and reduction vectors alike), so
        // the service only has to match that width against its own.
        if req.width() != self.n {
            return Err(PositError::WidthMismatch { expected: self.n, got: req.width() });
        }
        Ok(())
    }

    fn enqueue(
        &self,
        tx: &Sender<Request>,
        req: &OpRequest,
        enqueued: Instant,
        force_approx: bool,
    ) -> Result<Pending> {
        let (rtx, rrx) = channel();
        let [a, b, c] = req.bits();
        let vec = req.vector_lanes().map(|(va, vb, _)| {
            Box::new((
                va.iter().map(|p| p.to_bits()).collect(),
                vb.iter().map(|p| p.to_bits()).collect(),
            ))
        });
        let approx = req.op.routes_approx(self.n, req.accuracy())
            || (force_approx && req.op.degrades_approx(self.n, req.accuracy()));
        tx.send(Request { op: req.op, approx, a, b, c, vec, enqueued, respond: rtx })
            .map_err(|_| PositError::ServiceStopped)?;
        Ok(Pending { n: self.n, rx: rrx })
    }

    /// Submit one op-tagged request; returns immediately with a
    /// [`Pending`].
    pub fn submit_op(&self, req: OpRequest) -> Result<Pending> {
        self.submit_op_forced(req, false)
    }

    /// Submit one op-tagged request, optionally forcing brown-out
    /// degradation: when `force_approx` is set and the request is
    /// degrade-eligible ([`Op::degrades_approx`] — it declared *any* ulp
    /// tolerance and a bounded-error kernel is registered), it is routed
    /// to the Approx tier even if the kernel's declared bound exceeds
    /// the requested tolerance. Exact traffic and kernel-less ops ignore
    /// the flag and route normally. Used by the sharded router's soft
    /// watermark; plain clients want [`Client::submit_op`].
    pub fn submit_op_forced(&self, req: OpRequest, force_approx: bool) -> Result<Pending> {
        self.check_request(&req)?;
        let tx = self.sender()?;
        self.enqueue(&tx, &req, Instant::now(), force_approx)
    }

    /// Submit many op-tagged requests (any mix of operations); returns
    /// immediately with a [`BatchHandle`] whose results preserve
    /// submission order. A bad request anywhere rejects the whole batch
    /// up front — nothing is enqueued.
    pub fn submit_ops(&self, reqs: &[OpRequest]) -> Result<BatchHandle> {
        for req in reqs {
            self.check_request(req)?;
        }
        let tx = self.sender()?;
        let now = Instant::now();
        let mut rxs = Vec::with_capacity(reqs.len());
        for req in reqs {
            rxs.push(self.enqueue(&tx, req, now, false)?.rx);
        }
        Ok(BatchHandle { n: self.n, rxs })
    }

    /// Blocking op-tagged request.
    pub fn run_op(&self, req: OpRequest) -> Result<Posit> {
        self.submit_op(req)?.wait()
    }

    /// Submit one division (the service's default engine); returns
    /// immediately with a [`Pending`].
    pub fn submit(&self, x: Posit, d: Posit) -> Result<Pending> {
        self.submit_op(OpRequest::new(self.div_op, &[x, d])?)
    }

    /// Submit many divisions; returns immediately with a [`BatchHandle`]
    /// whose results preserve submission order.
    pub fn submit_batch(&self, pairs: &[(Posit, Posit)]) -> Result<BatchHandle> {
        let reqs: Vec<OpRequest> = pairs
            .iter()
            .map(|&(x, d)| OpRequest::new(self.div_op, &[x, d]))
            .collect::<Result<_>>()?;
        self.submit_ops(&reqs)
    }

    /// Blocking division.
    pub fn divide(&self, x: Posit, d: Posit) -> Result<Posit> {
        self.submit(x, d)?.wait()
    }

    /// Blocking batch division (keeps ordering).
    pub fn divide_batch(&self, pairs: &[(Posit, Posit)]) -> Result<Vec<Posit>> {
        self.submit_batch(pairs)?.wait()
    }

    /// Service metrics (shared with every other client).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

/// The native execution state: one cached [`Unit`] per (op, approx)
/// pair, built lazily as traffic arrives (the width is validated at
/// service start, so construction cannot fail afterwards).
struct NativeUnits {
    n: u32,
    threads: usize,
    tier: ExecTier,
    units: HashMap<(Op, bool), Unit>,
}

impl NativeUnits {
    fn new(n: u32, threads: usize, tier: ExecTier) -> NativeUnits {
        NativeUnits { n, threads, tier, units: HashMap::new() }
    }

    /// The exact-lane tier: a config-pinned `Approx` still serves its
    /// exact traffic (and its audit recomputations) from `Auto`.
    fn exact_tier(&self) -> ExecTier {
        if self.tier == ExecTier::Approx {
            ExecTier::Auto
        } else {
            self.tier
        }
    }

    /// The cached unit for one (op, approx-eligible) group. A group is
    /// served approx when the requests asked for it (or the service tier
    /// pins it) *and* a registered kernel exists — otherwise it falls
    /// back to the exact lane, which satisfies every accuracy policy.
    fn unit(&mut self, op: Op, approx: bool) -> (&Unit, bool) {
        let approx = (approx || self.tier == ExecTier::Approx) && op.approx_spec(self.n).is_some();
        let (n, tier) =
            (self.n, if approx { ExecTier::Approx } else { self.exact_tier() });
        let unit = self.units.entry((op, approx)).or_insert_with(|| {
            Unit::with_tier(n, op, tier).expect("width validated at service start")
        });
        (unit, approx)
    }

    /// Execute one op group (spread over the shared crate pool) and
    /// report which tier — and, on the fast tier, which kernel
    /// (table/SWAR/scalar) — served it.
    fn run(
        &mut self,
        op: Op,
        approx: bool,
        a: &[u64],
        b: &[u64],
        c: &[u64],
        out: &mut [u64],
    ) -> (ExecTier, Option<FastPath>) {
        let threads = self.threads;
        let (unit, _) = self.unit(op, approx);
        let path = unit.resolve_fast_path(out.len());
        unit.run_batch_parallel(a, b, c, out, threads)
            .expect("lanes are same-length by construction");
        (unit.batch_tier(), path)
    }

    /// One exact-lane recomputation, for the sampled approx audit.
    fn exact_bits(&mut self, op: Op, a: u64, b: u64, c: u64) -> u64 {
        let (n, tier) = (self.n, self.exact_tier());
        let unit = self.units.entry((op, false)).or_insert_with(|| {
            Unit::with_tier(n, op, tier).expect("width validated at service start")
        });
        unit.run_bits(a, b, c)
    }
}

/// Sampled accuracy audit for an approx-served group: every
/// [`APPROX_AUDIT_INTERVAL`]-th lane is recomputed on the exact tier and
/// the observed ulp distance recorded against the kernel's declared
/// bound in [`Metrics::approx_errors`].
fn audit_approx_group(
    native: &mut NativeUnits,
    m: &Metrics,
    n: u32,
    op: Op,
    a: &[u64],
    b: &[u64],
    c: &[u64],
    out: &[u64],
) {
    let Some(spec) = op.approx_spec(n) else { return };
    let lane = |l: &[u64], i: usize| if l.is_empty() { 0 } else { l[i] };
    let mut i = 0;
    while i < out.len() {
        let exact = native.exact_bits(op, lane(a, i), lane(b, i), lane(c, i));
        let ulp = Posit::from_bits(n, out[i]).ulp_distance(Posit::from_bits(n, exact));
        m.approx_errors.record(op, ulp, spec.max_ulp);
        i += APPROX_AUDIT_INTERVAL;
    }
}

enum Exec {
    Native(NativeUnits),
    /// PJRT serves division on the AOT graph; everything else falls back
    /// to the native units (the graph is division-only).
    Pjrt { rt: Runtime, native: NativeUnits },
}

/// A handle to a running posit-unit service. (The name predates the
/// operation-generic redesign; it serves every [`Op`], not just
/// division.)
pub struct DivisionService {
    n: u32,
    div_op: Op,
    tx: Option<Arc<Sender<Request>>>,
    metrics: Arc<Metrics>,
    leader: Option<JoinHandle<()>>,
}

/// Alias matching what the service actually is since the op-generic
/// redesign.
pub type UnitService = DivisionService;

impl DivisionService {
    /// Start the leader thread (and backend) for `cfg`.
    pub fn start(cfg: ServiceConfig) -> Result<DivisionService> {
        if !(MIN_N..=MAX_N).contains(&cfg.n) {
            return Err(PositError::WidthOutOfRange { n: cfg.n });
        }
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let n = cfg.n;
        let div_op = cfg.backend.default_div();

        // The PJRT client is thread-affine (Rc internally), so the backend
        // is constructed *inside* the leader thread; a ready-channel
        // surfaces startup errors to the caller synchronously.
        let backend = cfg.backend.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let policy = cfg.policy;
        let tier = cfg.tier;
        let leader = std::thread::Builder::new()
            .name("posit-div-leader".into())
            .spawn(move || {
                let mut exec = match &backend {
                    Backend::Native { alg, threads } => {
                        let mut native = NativeUnits::new(n, *threads, tier);
                        // pre-build the default division unit (pays the
                        // Newton LUT etc. before traffic arrives)
                        let mut warm = [0u64; 0];
                        native.run(Op::Div { alg: *alg }, false, &[], &[], &[], &mut warm);
                        Exec::Native(native)
                    }
                    Backend::Pjrt { artifacts_dir } => {
                        match Runtime::load(artifacts_dir)
                            .and_then(|rt| rt.warmup(n).map(|()| rt))
                        {
                            Ok(rt) => Exec::Pjrt { rt, native: NativeUnits::new(n, 1, tier) },
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        }
                    }
                };
                let _ = ready_tx.send(Ok(()));
                while let Some(batch) = batcher::collect_batch(&rx, policy) {
                    let t0 = Instant::now();
                    let mut results = vec![0u64; batch.len()];
                    // which lane served each request, for the SLO panel
                    let mut lanes = vec![ServedBy::Fast; batch.len()];
                    for ((op, approx), idxs) in
                        batcher::group_indices(&batch, |r| (r.op, r.approx))
                    {
                        let mut out = vec![0u64; idxs.len()];
                        if op.is_reduction() {
                            // Reductions carry per-request vector lanes,
                            // so the group is served request by request
                            // (each produces exactly one result lane);
                            // PJRT has no reduction graph — both backends
                            // go through the native quire units.
                            let native = match &mut exec {
                                Exec::Native(native) => native,
                                Exec::Pjrt { native, .. } => native,
                            };
                            for (k, &i) in idxs.iter().enumerate() {
                                let req = &batch[i];
                                let (va, vb) = req
                                    .vec
                                    .as_deref()
                                    .map_or((&[][..], &[][..]), |v| (&v.0[..], &v.1[..]));
                                let alpha = [req.c];
                                let lc: &[u64] =
                                    if op.arity() >= 3 { &alpha } else { &[] };
                                let (served, path) =
                                    native.run(op, false, va, vb, lc, &mut out[k..k + 1]);
                                lanes[i] = ServedBy::from_tier(served);
                                m.tiers.record(served, 1);
                                if let Some(p) = path {
                                    m.tiers.record_fast_path(p, 1);
                                }
                            }
                            for (&i, q) in idxs.iter().zip(out) {
                                results[i] = q;
                            }
                            continue;
                        }
                        let gather = |lane: fn(&Request) -> u64, used: bool| -> Vec<u64> {
                            if used {
                                idxs.iter().map(|&i| lane(&batch[i])).collect()
                            } else {
                                Vec::new()
                            }
                        };
                        let a = gather(|r| r.a, true);
                        let b = gather(|r| r.b, op.arity() >= 2);
                        let c = gather(|r| r.c, op.arity() >= 3);
                        match &mut exec {
                            Exec::Native(native) => {
                                let (served, path) = native.run(op, approx, &a, &b, &c, &mut out);
                                for &i in &idxs {
                                    lanes[i] = ServedBy::from_tier(served);
                                }
                                m.tiers.record(served, idxs.len() as u64);
                                if let Some(p) = path {
                                    m.tiers.record_fast_path(p, idxs.len() as u64);
                                }
                                if served == ExecTier::Approx {
                                    audit_approx_group(native, &m, n, op, &a, &b, &c, &out);
                                }
                            }
                            Exec::Pjrt { rt, native } => {
                                if matches!(op, Op::Div { .. }) && !approx {
                                    match rt.divide_bits(n, &a, &b) {
                                        Ok(q) => out = q,
                                        Err(e) => {
                                            // fail the whole group as NaR
                                            // and keep serving (errors are
                                            // per-group)
                                            eprintln!("pjrt batch failed: {e}");
                                            out = vec![1u64 << (n - 1); idxs.len()];
                                        }
                                    }
                                    for &i in &idxs {
                                        lanes[i] = ServedBy::Pjrt;
                                    }
                                    m.tiers.record_pjrt(idxs.len() as u64);
                                } else {
                                    let (served, path) =
                                        native.run(op, approx, &a, &b, &c, &mut out);
                                    for &i in &idxs {
                                        lanes[i] = ServedBy::from_tier(served);
                                    }
                                    m.tiers.record(served, idxs.len() as u64);
                                    if let Some(p) = path {
                                        m.tiers.record_fast_path(p, idxs.len() as u64);
                                    }
                                    if served == ExecTier::Approx {
                                        audit_approx_group(native, &m, n, op, &a, &b, &c, &out);
                                    }
                                }
                            }
                        }
                        for (&i, q) in idxs.iter().zip(out) {
                            results[i] = q;
                        }
                    }
                    m.batch_latency.record(t0.elapsed());
                    m.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    for ((req, q), lane) in batch.into_iter().zip(results).zip(lanes) {
                        if q == 1u64 << (n - 1) {
                            m.special_results
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        m.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        m.ops.record(req.op);
                        let waited = req.enqueued.elapsed();
                        m.request_latency.record(waited);
                        m.latency.record(req.op, lane, waited);
                        let _ = req.respond.send(q); // receiver may have gone
                    }
                }
            })
            .map_err(|e| PositError::Execution { detail: format!("spawn leader: {e}") })?;

        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e),
            Err(_) => {
                return Err(PositError::Execution {
                    detail: "leader thread died during startup".into(),
                })
            }
        }
        Ok(DivisionService { n, div_op, tx: Some(Arc::new(tx)), metrics, leader: Some(leader) })
    }

    /// Posit width served.
    pub fn width(&self) -> u32 {
        self.n
    }

    /// A cloneable submission handle.
    pub fn client(&self) -> Client {
        let tx = self.tx.as_ref().expect("service running");
        Client {
            n: self.n,
            div_op: self.div_op,
            tx: Arc::downgrade(tx),
            metrics: self.metrics.clone(),
        }
    }

    /// Blocking division (convenience over [`DivisionService::client`]).
    pub fn divide(&self, x: Posit, d: Posit) -> Result<Posit> {
        self.client().divide(x, d)
    }

    /// Submit many and wait for all (keeps ordering).
    pub fn divide_many(&self, pairs: &[(Posit, Posit)]) -> Result<Vec<Posit>> {
        self.client().divide_batch(pairs)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting requests and join the leader. Queued requests are
    /// drained first; clients outliving the service get
    /// [`PositError::ServiceStopped`] on new submissions.
    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DivisionService {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;
    use crate::posit::mask;
    use crate::testkit::Rng;
    use crate::workload;

    fn native_cfg(n: u32) -> ServiceConfig {
        ServiceConfig {
            n,
            backend: Backend::Native { alg: Algorithm::DEFAULT, threads: 2 },
            policy: BatchPolicy { max_batch: 64, max_wait: std::time::Duration::from_micros(100) },
            tier: ExecTier::Auto,
        }
    }

    #[test]
    fn native_service_matches_golden() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let mut rng = Rng::seeded(0xE2E);
        let pairs: Vec<(Posit, Posit)> = (0..500)
            .map(|_| {
                (
                    Posit::from_bits(16, rng.next_u64() & mask(16)),
                    Posit::from_bits(16, rng.next_u64() & mask(16)),
                )
            })
            .collect();
        let got = svc.divide_many(&pairs).unwrap();
        for (i, &(x, d)) in pairs.iter().enumerate() {
            assert_eq!(got[i], golden::divide(x, d).result, "{x:?}/{d:?}");
        }
        assert!(svc.metrics().requests.load(std::sync::atomic::Ordering::Relaxed) >= 500);
        svc.shutdown();
    }

    #[test]
    fn service_handles_specials() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let n = 16;
        let c = svc.client();
        assert!(c.divide(Posit::one(n), Posit::zero(n)).unwrap().is_nar());
        assert!(c.divide(Posit::zero(n), Posit::one(n)).unwrap().is_zero());
        assert!(c.divide(Posit::nar(n), Posit::one(n)).unwrap().is_nar());
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let svc = DivisionService::start(native_cfg(32)).unwrap();
        std::thread::scope(|s| {
            for t in 0..8 {
                let client = svc.client();
                s.spawn(move || {
                    let mut rng = Rng::seeded(t);
                    for _ in 0..200 {
                        let x = Posit::from_bits(32, rng.next_u64() & mask(32));
                        let d = Posit::from_bits(32, rng.next_u64() & mask(32));
                        let q = client.divide(x, d).unwrap();
                        assert_eq!(q, golden::divide(x, d).result);
                    }
                });
            }
        });
        assert!(svc.metrics().batches.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let pending = svc.client().submit(Posit::one(16), Posit::one(16)).unwrap();
        svc.shutdown();
        assert_eq!(pending.wait().unwrap(), Posit::one(16));
    }

    #[test]
    fn client_after_shutdown_is_typed_error() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let client = svc.client();
        svc.shutdown();
        assert_eq!(
            client.submit(Posit::one(16), Posit::one(16)).err(),
            Some(PositError::ServiceStopped)
        );
        assert_eq!(
            client.divide_batch(&[(Posit::one(16), Posit::one(16))]).err(),
            Some(PositError::ServiceStopped)
        );
        assert_eq!(
            client.submit_op(OpRequest::sqrt(Posit::one(16))).err(),
            Some(PositError::ServiceStopped)
        );
    }

    #[test]
    fn width_mismatch_is_typed_error() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let client = svc.client();
        assert_eq!(
            client.submit(Posit::one(32), Posit::one(32)).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 32 })
        );
        // a bad pair anywhere in a batch rejects the whole batch up front
        let pairs = [(Posit::one(16), Posit::one(16)), (Posit::one(8), Posit::one(8))];
        assert_eq!(
            client.submit_batch(&pairs).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 8 })
        );
        assert_eq!(
            client.submit_op(OpRequest::sqrt(Posit::one(32))).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 32 })
        );
        svc.shutdown();
    }

    #[test]
    fn submit_batch_preserves_order() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let client = svc.client();
        let pairs: Vec<(Posit, Posit)> = (1..=64u64)
            .map(|k| (Posit::from_f64(16, k as f64), Posit::one(16)))
            .collect();
        let got = client.submit_batch(&pairs).unwrap().wait().unwrap();
        for (k, q) in (1..=64u64).zip(&got) {
            assert_eq!(q.to_f64(), k as f64);
        }
        svc.shutdown();
    }

    #[test]
    fn every_op_served_end_to_end() {
        let n = 16;
        let svc = DivisionService::start(native_cfg(n)).unwrap();
        let client = svc.client();
        let two = Posit::from_f64(n, 2.0);
        let three = Posit::from_f64(n, 3.0);
        let nine = Posit::from_f64(n, 9.0);
        assert_eq!(client.run_op(OpRequest::div(nine, three)).unwrap(), three);
        assert_eq!(client.run_op(OpRequest::sqrt(nine)).unwrap(), three);
        assert_eq!(client.run_op(OpRequest::mul(two, three)).unwrap().to_f64(), 6.0);
        assert_eq!(client.run_op(OpRequest::add(two, three)).unwrap().to_f64(), 5.0);
        assert_eq!(client.run_op(OpRequest::sub(two, three)).unwrap().to_f64(), -1.0);
        assert_eq!(client.run_op(OpRequest::mul_add(two, three, nine)).unwrap().to_f64(), 15.0);
        // explicit per-algorithm division routes through its own unit
        assert_eq!(
            client
                .run_op(OpRequest::div_with(Algorithm::Nrd, nine, three))
                .unwrap(),
            three
        );
        let m = svc.metrics();
        assert_eq!(m.ops.get(Op::DIV), 2);
        assert_eq!(m.ops.get(Op::Sqrt), 1);
        assert_eq!(m.ops.get(Op::MulAdd), 1);
        svc.shutdown();
    }

    #[test]
    fn tier_config_routes_and_counts() {
        // Auto (default): requests served by the fast tier.
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let client = svc.client();
        let pairs: Vec<(Posit, Posit)> = (1..=32u64)
            .map(|k| (Posit::from_f64(16, k as f64), Posit::from_f64(16, 3.0)))
            .collect();
        let fast_out = client.divide_batch(&pairs).unwrap();
        let m = svc.metrics();
        assert_eq!(m.tiers.get(ExecTier::Fast), 32);
        assert_eq!(m.tiers.get(ExecTier::Datapath), 0);
        // the per-kernel split never exceeds the fast total (the exact
        // table/vector/SWAR/scalar split depends on dynamic batch sizes)
        let table = m.tiers.fast_table.load(std::sync::atomic::Ordering::Relaxed);
        let vector = m.tiers.fast_vector.load(std::sync::atomic::Ordering::Relaxed);
        let simd = m.tiers.fast_simd.load(std::sync::atomic::Ordering::Relaxed);
        assert!(table + vector + simd <= 32, "table={table} vector={vector} simd={simd}");
        assert!(m.tiers.summary().contains("table="), "{}", m.tiers.summary());
        svc.shutdown();

        // Pinned Datapath: same results, counted on the other tier.
        let cfg = ServiceConfig { tier: ExecTier::Datapath, ..native_cfg(16) };
        let svc = DivisionService::start(cfg).unwrap();
        let dp_out = svc.divide_many(&pairs).unwrap();
        assert_eq!(fast_out, dp_out, "tiers must be bit-identical end to end");
        let m = svc.metrics();
        assert_eq!(m.tiers.get(ExecTier::Datapath), 32);
        assert_eq!(m.tiers.get(ExecTier::Fast), 0);
        assert!(m.tiers.summary().contains("datapath=32"), "{}", m.tiers.summary());
        svc.shutdown();
    }

    /// Per-request accuracy policy: `Ulp(k)` traffic that a registered
    /// kernel satisfies routes to the Approx tier (within its declared
    /// bound, counted on its own lane, audited into the error panel);
    /// `Exact` traffic stays bit-identical on the exact tiers.
    #[test]
    fn accuracy_policy_routes_audits_and_bounds() {
        let n = 16;
        let svc = DivisionService::start(native_cfg(n)).unwrap();
        let client = svc.client();
        let mut rng = Rng::seeded(0xACC);
        let mut reqs = Vec::new();
        for _ in 0..64 {
            let x = Posit::from_bits(n, rng.next_u64() & mask(n));
            let d = Posit::from_bits(n, rng.next_u64() & mask(n));
            reqs.push(OpRequest::div(x, d).with_accuracy(Accuracy::Ulp(50)));
            reqs.push(OpRequest::div(x, d));
        }
        let got = client.submit_ops(&reqs).unwrap().wait().unwrap();
        for (req, q) in reqs.iter().zip(&got) {
            let golden = req.golden();
            match req.accuracy() {
                Accuracy::Exact => assert_eq!(*q, golden, "exact lane must stay bit-identical"),
                Accuracy::Ulp(k) => assert!(
                    q.ulp_distance(golden) <= u64::from(k),
                    "approx result {q:?} beyond ulp:{k} of {golden:?}"
                ),
            }
        }
        let m = svc.metrics();
        assert_eq!(m.tiers.get(ExecTier::Approx), 64);
        assert_eq!(m.tiers.get(ExecTier::Fast), 64);
        assert_eq!(m.latency.get(Op::DIV, ServedBy::Approx).count(), 64);
        // the sampled audit populated the error panel, within contract
        let stats = m.approx_errors.get(Op::DIV);
        assert!(stats.count > 0, "audit must sample approx groups");
        assert_eq!(stats.over, 0, "observed error exceeded the declared bound");
        assert!(stats.max <= Op::DIV.approx_spec(n).unwrap().max_ulp);
        // a policy tighter than every registered kernel runs exact
        let x = Posit::one(n);
        let d = Posit::from_f64(n, 3.0);
        let tight = OpRequest::div(x, d).with_accuracy(Accuracy::Ulp(1));
        assert_eq!(client.run_op(tight).unwrap(), golden::divide(x, d).result);
        assert_eq!(m.tiers.get(ExecTier::Approx), 64, "tight policy must not route approx");
        svc.shutdown();
    }

    /// A service pinned to `ExecTier::Approx` serves every kernel-backed
    /// op approx (whatever the request policy) and falls back to the
    /// exact tiers for the rest.
    #[test]
    fn approx_tier_config_serves_eligible_ops() {
        let n = 16;
        let cfg = ServiceConfig { tier: ExecTier::Approx, ..native_cfg(n) };
        let svc = DivisionService::start(cfg).unwrap();
        let client = svc.client();
        let nine = Posit::from_f64(n, 9.0);
        let three = Posit::from_f64(n, 3.0);
        let spec = Op::DIV.approx_spec(n).unwrap().max_ulp;
        let q = client.run_op(OpRequest::div(nine, three)).unwrap();
        assert!(q.ulp_distance(three) <= spec);
        let s = client.run_op(OpRequest::sqrt(nine)).unwrap();
        assert!(s.ulp_distance(three) <= Op::Sqrt.approx_spec(n).unwrap().max_ulp);
        // no registered add kernel: exact fallback, bit-identical
        assert_eq!(client.run_op(OpRequest::add(nine, three)).unwrap().to_f64(), 12.0);
        let m = svc.metrics();
        assert_eq!(m.tiers.get(ExecTier::Approx), 2);
        assert_eq!(m.tiers.get(ExecTier::Fast), 1);
        assert!(m.tiers.summary().contains("approx=2"), "{}", m.tiers.summary());
        assert!(m.approx_errors.summary().contains("div: audited="), "{}",
                m.approx_errors.summary());
        svc.shutdown();
    }

    /// Brown-out forcing: `submit_op_forced(.., true)` routes a
    /// degrade-eligible request (any `Ulp(k)` + registered kernel) to
    /// the Approx tier even when the kernel's declared bound exceeds
    /// `k`; exact traffic and kernel-less ops ignore the flag.
    #[test]
    fn forced_degradation_routes_approx() {
        let n = 16;
        let svc = DivisionService::start(native_cfg(n)).unwrap();
        let client = svc.client();
        let nine = Posit::from_f64(n, 9.0);
        let three = Posit::from_f64(n, 3.0);
        let spec = Op::DIV.approx_spec(n).unwrap().max_ulp;
        let m = svc.metrics();

        // Ulp(1) is tighter than the declared bound: normal routing keeps
        // it exact, forcing serves it approx within the *declared* bound
        let tight = OpRequest::div(nine, three).with_accuracy(Accuracy::Ulp(1));
        assert_eq!(client.run_op(tight.clone()).unwrap(), three);
        assert_eq!(m.tiers.get(ExecTier::Approx), 0);
        let q = client.submit_op_forced(tight, true).unwrap().wait().unwrap();
        assert!(q.ulp_distance(three) <= spec);
        assert_eq!(m.tiers.get(ExecTier::Approx), 1);

        // exact traffic ignores the flag
        let q = client.submit_op_forced(OpRequest::div(nine, three), true).unwrap();
        assert_eq!(q.wait().unwrap(), three);
        assert_eq!(m.tiers.get(ExecTier::Approx), 1);

        // so does an op without a registered kernel
        let s = client
            .submit_op_forced(OpRequest::add(nine, three).with_accuracy(Accuracy::Ulp(1)), true)
            .unwrap();
        assert_eq!(s.wait().unwrap().to_f64(), 12.0);
        assert_eq!(m.tiers.get(ExecTier::Approx), 1);
        svc.shutdown();
    }

    /// Acceptance gate: the quire reductions run end to end through the
    /// coordinator `Client`, bit-exact against the exact-rational golden.
    #[test]
    fn reductions_served_end_to_end() {
        use crate::testkit::rational;
        let n = 16;
        let svc = DivisionService::start(native_cfg(n)).unwrap();
        let client = svc.client();
        let mut rng = Rng::seeded(0xD07_E2E);
        let rand_vec = |rng: &mut Rng, k: usize| -> Vec<Posit> {
            (0..k).map(|_| Posit::from_bits(n, rng.next_u64() & mask(n))).collect()
        };
        for _ in 0..40 {
            let k = 1 + (rng.next_u64() % 12) as usize;
            let a = rand_vec(&mut rng, k);
            let b = rand_vec(&mut rng, k);
            let alpha = Posit::from_bits(n, rng.next_u64() & mask(n));
            let reqs = [
                OpRequest::dot(&a, &b).unwrap(),
                OpRequest::fused_sum(&a).unwrap(),
                OpRequest::axpy(alpha, &a, &b).unwrap(),
            ];
            let got = client.submit_ops(&reqs).unwrap().wait().unwrap();
            assert_eq!(got[0], rational::dot(&a, &b), "dot k={k}");
            assert_eq!(got[1], rational::fused_sum(&a), "fsum k={k}");
            assert_eq!(got[2], rational::axpy(alpha, &a, &b), "axpy k={k}");
        }
        let m = svc.metrics();
        assert_eq!(m.ops.get(Op::Dot), 40);
        assert_eq!(m.ops.get(Op::FusedSum), 40);
        assert_eq!(m.ops.get(Op::Axpy), 40);
        assert!(m.ops.summary().contains("dot=40"), "{}", m.ops.summary());
        // width mismatches are rejected up front, vectors included
        assert_eq!(
            client.submit_op(OpRequest::fused_sum(&[Posit::one(8)]).unwrap()).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 8 })
        );
        svc.shutdown();
    }

    #[test]
    fn latency_panel_records_per_op_and_lane() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let client = svc.client();
        let nine = Posit::from_f64(16, 9.0);
        for _ in 0..10 {
            client.run_op(OpRequest::sqrt(nine)).unwrap();
            client.divide(nine, Posit::from_f64(16, 3.0)).unwrap();
        }
        let m = svc.metrics();
        // Auto config serves batch traffic from the fast lane
        assert_eq!(m.latency.get(Op::Sqrt, ServedBy::Fast).count(), 10);
        assert_eq!(m.latency.get(Op::DIV, ServedBy::Fast).count(), 10);
        assert_eq!(m.latency.get(Op::DIV, ServedBy::Datapath).count(), 0);
        assert!(
            m.latency.get(Op::DIV, ServedBy::Fast).quantile(0.999) > std::time::Duration::ZERO
        );
        assert!(m.latency.render().contains("sqrt x fast"), "{}", m.latency.render());

        // pinning Datapath moves the same traffic to the other lane
        let cfg = ServiceConfig { tier: ExecTier::Datapath, ..native_cfg(16) };
        let dp = DivisionService::start(cfg).unwrap();
        dp.client().run_op(OpRequest::sqrt(nine)).unwrap();
        assert_eq!(dp.metrics().latency.get(Op::Sqrt, ServedBy::Datapath).count(), 1);
        assert_eq!(dp.metrics().latency.get(Op::Sqrt, ServedBy::Fast).count(), 0);
        dp.shutdown();
        svc.shutdown();
    }

    #[test]
    fn mixed_op_batches_route_per_op() {
        let n = 16;
        let svc = DivisionService::start(native_cfg(n)).unwrap();
        let client = svc.client();
        let mut wl = workload::MixedOps::new(n, workload::OpMix::DEFAULT, 0xA11);
        let reqs = workload::take_requests(&mut wl, 400);
        let results = client.submit_ops(&reqs).unwrap().wait().unwrap();
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(results[i], req.golden(), "{} i={i}", req.op);
        }
        let m = svc.metrics();
        let total: u64 = Op::DEFAULTS.iter().map(|&op| m.ops.get(op)).sum();
        assert_eq!(total, 400, "per-op counters must cover every request");
        assert!(m.ops.get(Op::Sqrt) > 0, "mixed stream must contain sqrt traffic");
        svc.shutdown();
    }
}
