//! Hardware cost model — the synthesis substrate (see DESIGN.md
//! §Substitutions: this stands in for Synopsys DC + a 28 nm TSMC library).
//!
//! * [`tech`] — unit-gate ↔ 28 nm physical calibration.
//! * [`components`] — gate-level cost/delay of datapath building blocks.
//! * [`designs`] — elaboration of every Table IV divider into stages.
//! * [`synth`] — combinational & pipelined evaluation (area / delay /
//!   power / energy), regenerating Figs. 4–9.
//! * [`pipeline_sim`] — cycle-accurate simulator of the pipelined units
//!   (dynamic validation of the Table II latencies and II=1 throughput).
//! * [`report`] — text/CSV rendering of the paper's tables and figures.

pub mod components;
pub mod designs;
pub mod pipeline_sim;
pub mod report;
pub mod synth;
pub mod tech;

pub use components::Cost;
pub use synth::{combinational, pipelined, Mode, SynthReport};
pub use tech::{Tech, TSMC28};
