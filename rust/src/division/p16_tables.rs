//! Posit16 reciprocal / square-root seed tables — the constant-time
//! treatment for the one width where exhaustive operation tables are
//! impossible.
//!
//! At n = 8 the Fast tier memoizes whole operations
//! ([`super::p8_tables`]); at n = 16 a binary-op table would be
//! 2³² entries, but the *significand space* is tiny: a decoded Posit16
//! significand is a 13-bit value `sig ∈ [2^12, 2^13)` — 4096 distinct
//! patterns. So instead of memoizing the operation we memoize the only
//! expensive step of each lane:
//!
//! * **division** — a 4096-entry Q30 reciprocal table indexed by the
//!   divisor significand (the exhaustive limit of the approx tier's
//!   256-entry *seed* table, so no Newton step is needed: with
//!   `y = rnd(2^30/den)` the estimate `(num·y) ≫ 30` is within ±1 of
//!   the true quotient `⌊(sig_a ≪ 16)/den⌋`, and one signed remainder
//!   fix-up per direction lands it exactly — the same seed-plus-
//!   correction shape the approximate multiply-divide unit literature
//!   uses, here driven to bit-exactness);
//! * **square root** — an 8192-entry table of exact integer square roots
//!   `⌊√(sig ≪ (16+odd))⌋` indexed by (scale parity, significand),
//!   replacing the per-lane `isqrt` iteration with one load (sticky is
//!   recomputed from the entry: `s² ≠ rad`).
//!
//! Both tables are built **lazily** (one [`std::sync::OnceLock`] each)
//! and **verified at construction**: every reciprocal entry must satisfy
//! the round-half-up contract `2·|y·den − 2^30| ≤ den` that the ±1
//! fix-up bound is proved from, and every root entry must be the exact
//! integer square root (`s² ≤ rad < (s+1)²`). The build panics on the
//! first violation, so a table can never serve a wrong seed — the same
//! policy as the Posit8 tables.
//!
//! Memory footprint when both tables are faulted in: 16 KiB + 32 KiB =
//! 48 KiB per process ([`total_bytes`]), inside the 64 KiB budget the
//! Posit8 tables spend per single binary op. Mul/add/sub/mul-add have no
//! seed worth tabulating at this width (their lane cost is the multiply
//! or alignment itself); they stay on the vector/SWAR/scalar kernels
//! ([`supports`]).

use std::sync::OnceLock;

use crate::posit::{frac_bits, mask, round::encode_round, Posit};

use super::approx::fixed_recip;
use super::fastpath::{special, Kind};
use super::sqrt::isqrt_u128;

/// The tabulated width.
pub const N: u32 = 16;

/// Fraction bits at n = 16 (`frac_bits(16)`), fixed so the table
/// geometry is const; the builders assert it matches the library.
const F: u32 = 12;

/// Distinct Posit16 significands (`sig ∈ [2^F, 2^(F+1))`).
const SIGS: usize = 1 << F;

/// Bytes of the reciprocal table (4096 × `u32`).
pub const RECIP_TABLE_BYTES: usize = SIGS * 4;

/// Bytes of the square-root table (2 parities × 4096 × `u32`).
pub const ROOT_TABLE_BYTES: usize = 2 * SIGS * 4;

/// True when `kind` has a Posit16 seed table (division and square root —
/// the two ops whose lane cost is dominated by a step a 13-bit-indexed
/// table can replace).
#[inline]
pub const fn supports(kind: Kind) -> bool {
    matches!(kind, Kind::Div | Kind::Sqrt)
}

/// Total bytes of table storage once both tables are built.
pub const fn total_bytes() -> usize {
    RECIP_TABLE_BYTES + ROOT_TABLE_BYTES
}

/// The lazily-built Q30 reciprocal table: entry `den − 2^F` is
/// `rnd(2^30/den)` ∈ (2^17, 2^18], construction-verified against the
/// round-half-up contract.
fn recip_table() -> &'static [u32] {
    static RECIP: OnceLock<Box<[u32]>> = OnceLock::new();
    RECIP.get_or_init(|| {
        debug_assert_eq!(F, frac_bits(N));
        let mut t = vec![0u32; SIGS].into_boxed_slice();
        for (i, slot) in t.iter_mut().enumerate() {
            let den = (SIGS + i) as u64;
            let y = fixed_recip(30, den);
            // |y·den − 2^30| ≤ den/2: the bound the ±1 quotient fix-up
            // is proved from (numerators are < 2^29, so the estimate
            // error is < 2^29·(den/2)/(den·2^30) = 1/4 quotient ulp).
            let err = (y * den) as i64 - (1i64 << 30);
            assert!(
                err.unsigned_abs() * 2 <= den,
                "p16 recip table build: den={den} y={y} err={err}"
            );
            *slot = y as u32;
        }
        t
    })
}

/// The lazily-built square-root table: entry `odd·4096 + (sig − 2^F)` is
/// the exact `⌊√(sig ≪ (16+odd))⌋`, construction-verified as such.
fn root_table() -> &'static [u32] {
    static ROOT: OnceLock<Box<[u32]>> = OnceLock::new();
    ROOT.get_or_init(|| {
        debug_assert_eq!(F, frac_bits(N));
        let mut t = vec![0u32; 2 * SIGS].into_boxed_slice();
        for odd in 0..2u32 {
            for i in 0..SIGS {
                let sig = (SIGS + i) as u64;
                // the sqrt kernels' radicand normal form at n = 16:
                // rad = sig << (2(F+2) + odd − F) = sig << (16 + odd)
                let rad = sig << (16 + odd);
                let s = isqrt_u128(rad as u128) as u64;
                assert!(
                    s * s <= rad && (s + 1) * (s + 1) > rad,
                    "p16 root table build: sig={sig} odd={odd} s={s}"
                );
                t[odd as usize * SIGS + i] = s as u32;
            }
        }
        t
    })
}

/// Division for one real (non-special) lane: table reciprocal, ±1
/// remainder fix-up, the Fast tier's shared quotient normal form.
#[inline(always)]
fn div_real(recip: &[u32], ab: u64, bb: u64) -> u64 {
    let da = Posit::from_bits(N, ab).decode();
    let db = Posit::from_bits(N, bb).decode();
    let num = (da.sig << N) as i64; // < 2^29
    let den = db.sig as i64; // ∈ [2^12, 2^13)
    let y = recip[(db.sig - SIGS as u64) as usize] as i64;
    // q = ⌊num·y / 2^30⌋ is within ±1 of ⌊num/den⌋ (see recip_table);
    // the signed remainder pins it and doubles as the sticky bit.
    let mut q = (num * y) >> 30;
    let mut rem = num - q * den;
    if rem < 0 {
        q -= 1;
        rem += den;
    }
    if rem >= den {
        q += 1;
        rem -= den;
    }
    let t = da.scale - db.scale;
    // normalize q ∈ (1/2, 2) to [1, 2) — same as every other div kernel
    let (sc, sfb) = if (q as u64) >> N != 0 { (t, N) } else { (t - 1, N - 1) };
    encode_round(N, da.sign ^ db.sign, sc, q as u128, sfb, rem != 0).to_bits()
}

/// Square root for one real lane: one table load replaces the `isqrt`
/// iteration; sticky is recomputed exactly from the entry.
#[inline(always)]
fn sqrt_real(root: &[u32], ab: u64) -> u64 {
    let d = Posit::from_bits(N, ab).decode();
    let odd = (d.scale & 1) as u32;
    let rad = d.sig << (16 + odd);
    let s = root[(odd as usize * SIGS) + (d.sig - SIGS as u64) as usize] as u64;
    encode_round(N, false, d.scale >> 1, s as u128, F + 2, s * s != rad).to_bits()
}

/// Batch execution: `out[i] = kind(a[i], b[i])` (lane `b` empty or
/// ignored for sqrt), bit-identical to the scalar Fast kernel. `kind`
/// must satisfy [`supports`]; used operand lanes must match `out` —
/// checked with a hard assert once per batch, the same contract as the
/// Posit8 tables.
pub fn run_batch(kind: Kind, a: &[u64], b: &[u64], out: &mut [u64]) {
    assert_eq!(a.len(), out.len(), "table lane a must match out");
    let m = mask(N);
    match kind {
        Kind::Div => {
            assert_eq!(b.len(), out.len(), "p16 div table needs lane b");
            let recip = recip_table();
            for i in 0..out.len() {
                let (x, y) = (a[i] & m, b[i] & m);
                out[i] = match special(N, Kind::Div, x, y, 0) {
                    Some(r) => r,
                    None => div_real(recip, x, y),
                };
            }
        }
        Kind::Sqrt => {
            let root = root_table();
            for i in 0..out.len() {
                let x = a[i] & m;
                out[i] = match special(N, Kind::Sqrt, x, 0, 0) {
                    Some(r) => r,
                    None => sqrt_real(root, x),
                };
            }
        }
        _ => unreachable!("no p16 table for {kind:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::fastpath::scalar_bits;
    use crate::division::golden;
    use crate::division::sqrt::golden_sqrt;
    use crate::testkit::Rng;

    #[test]
    fn supported_kinds_and_sizes() {
        assert!(supports(Kind::Div));
        assert!(supports(Kind::Sqrt));
        for kind in [Kind::Mul, Kind::Add, Kind::Sub, Kind::MulAdd] {
            assert!(!supports(kind), "{kind:?}");
        }
        assert_eq!(RECIP_TABLE_BYTES, 16 * 1024);
        assert_eq!(ROOT_TABLE_BYTES, 32 * 1024);
        assert_eq!(total_bytes(), 48 * 1024);
    }

    /// Entry ranges on top of the construction contracts (which already
    /// ran, and panicked on violation, when the tables were built).
    #[test]
    fn table_entries_are_in_range() {
        for (i, &y) in recip_table().iter().enumerate() {
            assert!((1 << 17) < y && y <= (1 << 18), "recip[{i}] = {y}");
        }
        for (i, &s) in root_table().iter().enumerate() {
            assert!((1 << 13) < s && s < (1 << 15), "root[{i}] = {s}");
        }
    }

    /// Exhaustive Posit16 sqrt: all 65 536 bit patterns through the
    /// table path vs the scalar Fast kernel (which is itself golden-
    /// verified); the specials (NaR, zero, negatives) ride along.
    #[test]
    fn exhaustive_p16_sqrt_matches_scalar_kernel() {
        let a: Vec<u64> = (0..=mask(N)).collect();
        let mut out = vec![0u64; a.len()];
        run_batch(Kind::Sqrt, &a, &[], &mut out);
        for (i, &got) in out.iter().enumerate() {
            assert_eq!(got, scalar_bits(N, Kind::Sqrt, a[i], 0, 0), "sqrt {:#06x}", a[i]);
        }
    }

    /// Exhaustive over every divisor bit pattern (so every reciprocal
    /// entry that any posit can index is exercised) against random
    /// dividends, vs the scalar Fast kernel.
    #[test]
    fn every_divisor_pattern_matches_scalar_kernel() {
        let mut rng = Rng::seeded(0x16DE);
        let b: Vec<u64> = (0..=mask(N)).collect();
        let a: Vec<u64> = (0..b.len()).map(|_| rng.next_u64() & mask(N)).collect();
        let mut out = vec![0u64; b.len()];
        run_batch(Kind::Div, &a, &b, &mut out);
        for i in 0..b.len() {
            assert_eq!(
                out[i],
                scalar_bits(N, Kind::Div, a[i], b[i], 0),
                "{:#06x}/{:#06x}",
                a[i],
                b[i]
            );
        }
    }

    /// Seeded sweep vs the *golden* references directly — independent of
    /// the Fast kernels the other tests compare against.
    #[test]
    fn seeded_sweep_matches_golden_references() {
        let mut rng = Rng::seeded(0x16D9);
        let p = |bits: u64| Posit::from_bits(N, bits);
        for _ in 0..5_000 {
            let (a, b) = (rng.next_u64() & mask(N), rng.next_u64() & mask(N));
            let mut out = [0u64; 1];
            run_batch(Kind::Div, &[a], &[b], &mut out);
            assert_eq!(out[0], golden::divide(p(a), p(b)).result.to_bits(), "{a:#06x}/{b:#06x}");
            let mut out = [0u64; 1];
            run_batch(Kind::Sqrt, &[a], &[], &mut out);
            assert_eq!(out[0], golden_sqrt(p(a)).result.to_bits(), "sqrt {a:#06x}");
        }
    }
}
