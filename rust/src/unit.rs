//! Operation-generic posit functional unit — the single batch-first
//! execution surface for every operation this crate implements.
//!
//! The paper's related work ([11], [12] and the authors' companion sqrt
//! paper [13]) pairs division with square root in one digit-recurrence
//! unit, and vector-unit designs (FPPU, PVU) go further: one posit
//! functional unit serving a stream of op-tagged requests. This module is
//! that surface in software:
//!
//! * [`Op`] — the request model: `Div { alg }`, `Sqrt`, `Mul`, `Add`,
//!   `Sub`, `MulAdd`, plus the quire-backed reductions `Dot`, `FusedSum`
//!   and `Axpy` ([`crate::quire`]: slice operands, exact accumulation,
//!   one rounding).
//! * [`OpRequest`] — one op plus its operands (scalar lanes of arity
//!   1–3, or vector lanes for the reductions), the unit of traffic for
//!   the coordinator and the mixed workloads.
//! * [`Unit`] — a reusable, zero-alloc execution context for one
//!   `(width, op)` pair. Built once, it owns the concrete engine state
//!   (enum dispatch, no heap indirection on the call path) and the
//!   width-derived caches, and exposes [`Unit::run`], [`Unit::run_batch`]
//!   and [`Unit::run_batch_parallel`] as the one hot path shared by the
//!   coordinator's native backend, the benches and the examples.
//!
//! Division semantics are bit-identical to the former division-only
//! context (`Divider`, now a thin deprecated wrapper over a `Unit` with
//! `Op::Div`): the same per-algorithm engines run behind the same shared
//! [`exec`] front/back end.
//!
//! Execution is **tiered** ([`ExecTier`]): the paper-faithful
//! cycle-accurate engines form the *Datapath* tier, and the
//! width-specialized direct kernels of [`crate::division::fastpath`] form
//! the *Fast* tier — bit-identical by construction and by test
//! (tier-equivalence sweeps, exhaustive at Posit8). The default `Auto`
//! tier serves batch/bit-level traffic from the Fast kernels and switches
//! to the Datapath whenever cycle metadata is requested ([`Unit::run`]).
//!
//! Inside the Fast tier, batches dispatch over a vectorized serving
//! layer ([`FastPath`]): construction-verified lookup tables
//! ([`crate::division::p8_tables`] whole-op at Posit8,
//! [`crate::division::p16_tables`] div/sqrt seeds at Posit16), explicit
//! AVX2/NEON vector kernels ([`crate::division::vector`], runtime-detected
//! behind the `vsimd` feature) and SWAR lane-packed kernels
//! ([`crate::division::simd`], 16×Posit8 / 8×Posit16 lanes per `u128`
//! word). `Auto` resolves **table > vector > SWAR > scalar-fast** by
//! width and batch length; [`Unit::with_exec`] forces one kernel, and
//! every choice is bit-identical.

use std::fmt;

use crate::division::approx;
use crate::division::fastpath::{self, FastKernel};

pub use crate::division::fastpath::FastPath;
use crate::division::sqrt::{golden_sqrt, SqrtEngine};
use crate::division::{
    exec, golden, iterations, latency_cycles, newton::Newton, nrd::Nrd, srt2::Srt2,
    srt2_cs::Srt2Cs, srt4_cs::Srt4Cs, srt4_scaled::Srt4Scaled, Algorithm, DivEngine, Division,
    FracQuotient,
};
use crate::error::{PositError, Result};
use crate::posit::{mask, Posit, MAX_N, MIN_N};
use crate::quire;
use crate::testkit::rational;

/// Modeled pipeline cycles for the single-pass arithmetic ops: the
/// decode/detect/encode cost of the special path ([`exec::SPECIAL_CYCLES`])
/// plus one datapath stage.
const ARITH_CYCLES: u32 = exec::SPECIAL_CYCLES + 1;

/// Which execution tier serves a [`Unit`]'s requests.
///
/// Both tiers are bit-identical for every operation and every division
/// algorithm (verified by the tier-equivalence sweeps and the exhaustive
/// Posit8 gates); they differ in *how* the result is produced and in what
/// the execution metadata means.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ExecTier {
    /// The paper-faithful cycle-accurate engines: per-iteration
    /// carry-save/OTF state emulation, exact `iterations`/`cycles`
    /// metadata straight from the recurrence. The golden serving path for
    /// verification, ablations and anything that asks "what would the
    /// hardware do".
    Datapath,
    /// The width-specialized direct kernels
    /// ([`crate::division::fastpath`]): one fixed-point `u128` division /
    /// integer square root / native integer op per lane, monomorphized
    /// over n ∈ {8, 16, 32, 64} with a dynamic-width fallback. Scalar
    /// metadata is *modeled* from the unit's cached per-format counts
    /// (identical to what the datapath reports, without stepping it).
    Fast,
    /// The serving default: Fast for the batch/bit-level entry points
    /// ([`Unit::run_batch`], [`Unit::run_bits`]), Datapath whenever cycle
    /// metadata is requested ([`Unit::run`]).
    #[default]
    Auto,
    /// The bounded-error kernels of [`crate::division::approx`]:
    /// reciprocal/rsqrt-seeded single-Newton-step division and square
    /// root plus truncated-fraction multiplication. **Not**
    /// bit-identical — each `(op, width)` kernel carries a declared
    /// max-ulp contract ([`crate::division::approx::ApproxSpec`]),
    /// machine-checked exhaustively at Posit8 and by seeded sweeps at
    /// the wider widths. Only `div`/`sqrt`/`mul` at n ∈ {8, 16, 32}
    /// have registered kernels; constructing any other unit on this
    /// tier is a typed [`PositError::UnsupportedApprox`]. Special
    /// patterns (zero, NaR, negative radicand) stay bit-exact through
    /// the shared special pre-pass.
    Approx,
}

impl ExecTier {
    /// Parse a CLI-style tier name (`fast`, `datapath`, `auto`,
    /// `approx`).
    pub fn parse(s: &str) -> Option<ExecTier> {
        match s.to_ascii_lowercase().as_str() {
            "datapath" => Some(ExecTier::Datapath),
            "fast" => Some(ExecTier::Fast),
            "auto" => Some(ExecTier::Auto),
            "approx" => Some(ExecTier::Approx),
            _ => None,
        }
    }

    /// Stable lowercase name (`datapath`, `fast`, `auto`, `approx`).
    pub fn name(self) -> &'static str {
        match self {
            ExecTier::Datapath => "datapath",
            ExecTier::Fast => "fast",
            ExecTier::Auto => "auto",
            ExecTier::Approx => "approx",
        }
    }
}

impl fmt::Display for ExecTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The operations a [`Unit`] can serve.
///
/// Operand convention (`a`, `b`, `c` are the request lanes, in order):
///
/// | op | result | arity |
/// |----|--------|-------|
/// | `Div { alg }` | `a / b` via the chosen Table IV engine | 2 |
/// | `Sqrt` | `√a` (negative → NaR) | 1 |
/// | `Mul` | `a · b` | 2 |
/// | `Add` | `a + b` | 2 |
/// | `Sub` | `a − b` | 2 |
/// | `MulAdd` | `a · b + c` (mul+add, two roundings — not a quire) | 3 |
///
/// The **reduction ops** take vector lanes instead of scalar slots
/// (`a`/`b` are equal-length slices, `c` the scalar coefficient) and
/// accumulate in the posit-standard quire ([`crate::quire`]) — exact
/// until one final rounding:
///
/// | op | result | lanes |
/// |----|--------|-------|
/// | `Dot` | `round(Σ aᵢ·bᵢ)` | 2 |
/// | `FusedSum` | `round(Σ aᵢ)` | 1 |
/// | `Axpy` | `round(Σᵢ (c·aᵢ + bᵢ))` | 3 |
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// Division through one of the paper's engines.
    Div { alg: Algorithm },
    /// Digit-recurrence square root (radix-2).
    Sqrt,
    /// Correctly-rounded multiplication.
    Mul,
    /// Correctly-rounded addition.
    Add,
    /// Correctly-rounded subtraction.
    Sub,
    /// Fused-style `a·b + c` built from mul+add (two roundings).
    MulAdd,
    /// Quire dot product: `round(Σ aᵢ·bᵢ)`, one rounding total.
    Dot,
    /// Quire vector sum: `round(Σ aᵢ)`, permutation invariant.
    FusedSum,
    /// Quire fused scale-and-add: `round(Σᵢ (α·xᵢ + yᵢ))`.
    Axpy,
}

impl Op {
    /// Division with the paper's default serving engine
    /// ([`Algorithm::DEFAULT`], SRT r4 CS OF FR).
    pub const DIV: Op = Op::Div { alg: Algorithm::DEFAULT };

    /// One representative of every *scalar* operation kind (division at
    /// the default algorithm) — what "every op" sweeps iterate. The
    /// reduction ops live in [`Op::REDUCTIONS`]; they take vector
    /// operands, so sweeps drive them separately.
    pub const DEFAULTS: [Op; 6] = [Op::DIV, Op::Sqrt, Op::Mul, Op::Add, Op::Sub, Op::MulAdd];

    /// The quire-backed reduction ops (vector operands, exact
    /// accumulation, one rounding).
    pub const REDUCTIONS: [Op; 3] = [Op::Dot, Op::FusedSum, Op::Axpy];

    /// One representative per operation *kind* (scalar ops then
    /// reductions, division at the default algorithm) — the index space
    /// for kind-keyed telemetry ([`Op::kind_index`]).
    pub const KINDS: [Op; 9] = [
        Op::DIV,
        Op::Sqrt,
        Op::Mul,
        Op::Add,
        Op::Sub,
        Op::MulAdd,
        Op::Dot,
        Op::FusedSum,
        Op::Axpy,
    ];

    /// Dense index of this op's kind into [`Op::KINDS`] (division maps to
    /// one slot regardless of algorithm) — used by kind-keyed metric
    /// storage such as the coordinator latency panel.
    #[inline]
    pub fn kind_index(self) -> usize {
        match self {
            Op::Div { .. } => 0,
            Op::Sqrt => 1,
            Op::Mul => 2,
            Op::Add => 3,
            Op::Sub => 4,
            Op::MulAdd => 5,
            Op::Dot => 6,
            Op::FusedSum => 7,
            Op::Axpy => 8,
        }
    }

    /// Number of operand lanes the op consumes (for the reductions these
    /// are vector lanes: `Dot` reads `a`/`b`, `FusedSum` reads `a`,
    /// `Axpy` reads `a`/`b` plus the scalar coefficient in `c`).
    #[inline]
    pub fn arity(self) -> usize {
        match self {
            Op::Sqrt | Op::FusedSum => 1,
            Op::MulAdd | Op::Axpy => 3,
            _ => 2,
        }
    }

    /// True for the quire-backed vector-operand ops.
    #[inline]
    pub fn is_reduction(self) -> bool {
        matches!(self, Op::Dot | Op::FusedSum | Op::Axpy)
    }

    /// Stable short name of the operation kind (ignores the division
    /// algorithm): `div`, `sqrt`, `mul`, `add`, `sub`, `mul_add`,
    /// `dot`, `fsum`, `axpy`.
    pub fn name(self) -> &'static str {
        match self {
            Op::Div { .. } => "div",
            Op::Sqrt => "sqrt",
            Op::Mul => "mul",
            Op::Add => "add",
            Op::Sub => "sub",
            Op::MulAdd => "mul_add",
            Op::Dot => "dot",
            Op::FusedSum => "fsum",
            Op::Axpy => "axpy",
        }
    }

    /// Full label including the division algorithm, for reports.
    pub fn label(self) -> String {
        match self {
            Op::Div { alg } => format!("div[{}]", alg.label()),
            other => other.name().to_string(),
        }
    }

    /// The fast-tier kernel kind serving this op (the division algorithm
    /// is irrelevant there: every engine is correctly rounded). The
    /// reductions never execute through a [`FastKernel`] — they carry a
    /// placeholder kind only so the kernel handle can be constructed;
    /// their Fast tier is the in-register quire in [`crate::quire`].
    fn fast_kind(self) -> fastpath::Kind {
        match self {
            Op::Div { .. } => fastpath::Kind::Div,
            Op::Sqrt => fastpath::Kind::Sqrt,
            Op::Mul | Op::Dot | Op::FusedSum | Op::Axpy => fastpath::Kind::Mul,
            Op::Add => fastpath::Kind::Add,
            Op::Sub => fastpath::Kind::Sub,
            Op::MulAdd => fastpath::Kind::MulAdd,
        }
    }

    /// The declared ulp contract of the Approx-tier kernel serving this
    /// op at width `n`, or `None` when no bounded-error kernel is
    /// registered (reductions, `add`/`sub`/`mul_add`, and widths outside
    /// {8, 16, 32} always route exact).
    pub fn approx_spec(self, n: u32) -> Option<approx::ApproxSpec> {
        if self.is_reduction() {
            return None;
        }
        approx::spec(self.fast_kind(), n)
    }

    /// Whether a request for this op at width `n` under `accuracy` is
    /// eligible for the Approx tier: the policy must tolerate error
    /// (`Accuracy::Ulp(k)`) *and* a registered kernel's declared bound
    /// must satisfy it (`max_ulp <= k`). `Accuracy::Exact` never routes
    /// approx.
    pub fn routes_approx(self, n: u32, accuracy: Accuracy) -> bool {
        match accuracy {
            Accuracy::Exact => false,
            Accuracy::Ulp(k) => {
                self.approx_spec(n).is_some_and(|s| s.max_ulp <= u64::from(k))
            }
        }
    }

    /// Whether brown-out degradation may *force* this request onto the
    /// Approx tier: the requester declared **any** error tolerance
    /// (`Accuracy::Ulp(k)`, whatever `k`) and a bounded-error kernel is
    /// registered for `(op, width)`. Unlike [`Op::routes_approx`], the
    /// kernel's declared bound need not satisfy `k` — under overload the
    /// service stretches the tolerance rather than shedding the request,
    /// and the response is still within the kernel's declared
    /// [`crate::division::approx::ApproxSpec`] bound. `Exact` traffic is
    /// never degraded.
    pub fn degrades_approx(self, n: u32, accuracy: Accuracy) -> bool {
        matches!(accuracy, Accuracy::Ulp(_)) && self.approx_spec(n).is_some()
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Op::Div { alg } => write!(f, "div[{}]", alg.label()),
            other => f.write_str(other.name()),
        }
    }
}

/// Per-request accuracy policy: how much rounding error the requester
/// tolerates on this one operation.
///
/// `Exact` (the default) demands the correctly-rounded result — bit
/// identical to the Datapath reference — and never routes to the Approx
/// tier. `Ulp(k)` accepts any result within `k` ulps of correct
/// rounding, which makes the request *eligible* for a bounded-error
/// kernel: the coordinator routes it approx only when a registered
/// [`crate::division::approx::ApproxSpec`] for the `(op, width)` pair
/// declares `max_ulp <= k` ([`Op::routes_approx`]); otherwise the
/// request silently runs exact (exact always satisfies `Ulp(k)`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Accuracy {
    /// Correctly rounded, bit-identical to the Datapath tier.
    #[default]
    Exact,
    /// Up to `k` ulps of error tolerated; routes approx only when a
    /// registered kernel's declared bound satisfies `k`.
    Ulp(u32),
}

impl Accuracy {
    /// Parse a CLI-style accuracy policy: `exact`, or `ulp:K` with a
    /// decimal tolerance (e.g. `ulp:4`).
    pub fn parse(s: &str) -> Option<Accuracy> {
        let s = s.to_ascii_lowercase();
        if s == "exact" {
            return Some(Accuracy::Exact);
        }
        let k = s.strip_prefix("ulp:")?;
        k.parse::<u32>().ok().map(Accuracy::Ulp)
    }

    /// Stable label (`exact`, `ulp:K`) matching [`Accuracy::parse`].
    pub fn label(self) -> String {
        match self {
            Accuracy::Exact => "exact".to_string(),
            Accuracy::Ulp(k) => format!("ulp:{k}"),
        }
    }
}

impl fmt::Display for Accuracy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// One op-tagged request: the operation plus its operands — three scalar
/// slots for the scalar ops, vector lanes for the reductions — the
/// accuracy policy the requester tolerates ([`Accuracy`], default
/// `Exact`), and an optional end-to-end deadline budget in milliseconds
/// (0 = none; carried on the wire, enforced at shard admission). The
/// traffic unit of the coordinator ([`crate::coordinator::Client`]) and
/// the mixed workloads ([`crate::workload::MixedOps`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OpRequest {
    pub op: Op,
    operands: Operands,
    accuracy: Accuracy,
    deadline_ms: u32,
}

/// Operand storage: the constructors guarantee internal consistency
/// (equal widths, matched lane lengths, nonempty `a`), so holders of an
/// `OpRequest` never need to re-validate its shape.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Operands {
    /// Fixed three slots; only the first [`Op::arity`] are meaningful
    /// (the rest are zero posits of the same width).
    Scalar([Posit; 3]),
    /// Reduction lanes: `a` (nonempty), `b` (same length, or empty when
    /// the op ignores it) and the scalar coefficient `c` (zero when the
    /// op ignores it).
    Vector { a: Vec<Posit>, b: Vec<Posit>, c: Posit },
}

impl OpRequest {
    /// Build a request, checking arity and that all operands share one
    /// width. For scalar ops `operands` are the 1–3 operand lanes in
    /// order; a reduction op here builds the single-element reduction
    /// (`Dot`: `[a₀, b₀]`, `FusedSum`: `[x₀]`, `Axpy`: `[x₀, y₀, α]`) —
    /// use [`OpRequest::dot`], [`OpRequest::fused_sum`] and
    /// [`OpRequest::axpy`] to pass real slices.
    pub fn new(op: Op, operands: &[Posit]) -> Result<OpRequest> {
        if operands.len() != op.arity() {
            return Err(PositError::ArityMismatch {
                op: op.name(),
                expected: op.arity(),
                got: operands.len(),
            });
        }
        let w = operands[0].width();
        for p in operands {
            if p.width() != w {
                return Err(PositError::WidthMismatch { expected: w, got: p.width() });
            }
        }
        Ok(match op {
            Op::Dot => Self::vector(op, vec![operands[0]], vec![operands[1]], None),
            Op::FusedSum => Self::vector(op, vec![operands[0]], Vec::new(), None),
            Op::Axpy => {
                Self::vector(op, vec![operands[0]], vec![operands[1]], Some(operands[2]))
            }
            _ => {
                let mut slots = [Posit::zero(w); 3];
                slots[..operands.len()].copy_from_slice(operands);
                OpRequest {
                    op,
                    operands: Operands::Scalar(slots),
                    accuracy: Accuracy::Exact,
                    deadline_ms: 0,
                }
            }
        })
    }

    fn unary(op: Op, a: Posit) -> OpRequest {
        let z = Posit::zero(a.width());
        OpRequest {
            op,
            operands: Operands::Scalar([a, z, z]),
            accuracy: Accuracy::Exact,
            deadline_ms: 0,
        }
    }

    fn binary(op: Op, a: Posit, b: Posit) -> OpRequest {
        debug_assert_eq!(a.width(), b.width(), "mixed-width {op:?} request");
        OpRequest {
            op,
            operands: Operands::Scalar([a, b, Posit::zero(a.width())]),
            accuracy: Accuracy::Exact,
            deadline_ms: 0,
        }
    }

    fn vector(op: Op, a: Vec<Posit>, b: Vec<Posit>, c: Option<Posit>) -> OpRequest {
        let w = c.map_or_else(|| a[0].width(), |p| p.width());
        OpRequest {
            op,
            operands: Operands::Vector { a, b, c: c.unwrap_or(Posit::zero(w)) },
            accuracy: Accuracy::Exact,
            deadline_ms: 0,
        }
    }

    /// Validated reduction-request builder: `a` nonempty, `b` matched
    /// when the op reads it, every operand (and `alpha`) at one width.
    fn reduction(
        op: Op,
        a: &[Posit],
        b: &[Posit],
        alpha: Option<Posit>,
    ) -> Result<OpRequest> {
        if a.is_empty() {
            return Err(PositError::BatchLaneMismatch { lane: "a", expected: 1, got: 0 });
        }
        if matches!(op, Op::Dot | Op::Axpy) && b.len() != a.len() {
            return Err(PositError::BatchLaneMismatch {
                lane: "b",
                expected: a.len(),
                got: b.len(),
            });
        }
        let w = alpha.map_or_else(|| a[0].width(), |p| p.width());
        for p in a.iter().chain(b.iter()) {
            if p.width() != w {
                return Err(PositError::WidthMismatch { expected: w, got: p.width() });
            }
        }
        Ok(Self::vector(op, a.to_vec(), b.to_vec(), alpha))
    }

    /// Exact dot product `round(Σ aᵢ·bᵢ)` over equal-length slices.
    pub fn dot(a: &[Posit], b: &[Posit]) -> Result<OpRequest> {
        Self::reduction(Op::Dot, a, b, None)
    }

    /// Exact vector sum `round(Σ xᵢ)`.
    pub fn fused_sum(xs: &[Posit]) -> Result<OpRequest> {
        Self::reduction(Op::FusedSum, xs, &[], None)
    }

    /// Exact fused scale-and-add `round(Σᵢ (α·xᵢ + yᵢ))`.
    pub fn axpy(alpha: Posit, xs: &[Posit], ys: &[Posit]) -> Result<OpRequest> {
        Self::reduction(Op::Axpy, xs, ys, Some(alpha))
    }

    /// `x / d` with the default engine.
    pub fn div(x: Posit, d: Posit) -> OpRequest {
        Self::binary(Op::DIV, x, d)
    }

    /// `x / d` with a specific Table IV engine.
    pub fn div_with(alg: Algorithm, x: Posit, d: Posit) -> OpRequest {
        Self::binary(Op::Div { alg }, x, d)
    }

    /// `√v`.
    pub fn sqrt(v: Posit) -> OpRequest {
        Self::unary(Op::Sqrt, v)
    }

    /// `a · b`.
    pub fn mul(a: Posit, b: Posit) -> OpRequest {
        Self::binary(Op::Mul, a, b)
    }

    /// `a + b`.
    pub fn add(a: Posit, b: Posit) -> OpRequest {
        Self::binary(Op::Add, a, b)
    }

    /// `a − b`.
    pub fn sub(a: Posit, b: Posit) -> OpRequest {
        Self::binary(Op::Sub, a, b)
    }

    /// `a · b + c`.
    pub fn mul_add(a: Posit, b: Posit, c: Posit) -> OpRequest {
        debug_assert_eq!(a.width(), b.width(), "mixed-width MulAdd request");
        debug_assert_eq!(a.width(), c.width(), "mixed-width MulAdd request");
        OpRequest {
            op: Op::MulAdd,
            operands: Operands::Scalar([a, b, c]),
            accuracy: Accuracy::Exact,
            deadline_ms: 0,
        }
    }

    /// Attach an accuracy policy (builder style; constructors default to
    /// [`Accuracy::Exact`]). `Ulp(k)` marks the request eligible for the
    /// Approx tier when a registered kernel's declared bound satisfies
    /// `k` — see [`Op::routes_approx`].
    pub fn with_accuracy(mut self, accuracy: Accuracy) -> OpRequest {
        self.accuracy = accuracy;
        self
    }

    /// The accuracy policy attached to this request.
    #[inline]
    pub fn accuracy(&self) -> Accuracy {
        self.accuracy
    }

    /// Attach an end-to-end deadline budget in milliseconds (builder
    /// style; 0 — the constructors' default — means no deadline). The
    /// budget travels in the wire-v3 REQUEST frame and is enforced at
    /// shard admission: a request whose budget has already elapsed when
    /// the router looks at it is dropped with the typed
    /// [`crate::PositError::DeadlineExceeded`] *before* it consumes an
    /// admission slot.
    pub fn with_deadline_ms(mut self, deadline_ms: u32) -> OpRequest {
        self.deadline_ms = deadline_ms;
        self
    }

    /// The deadline budget in milliseconds (0 = no deadline).
    #[inline]
    pub fn deadline_ms(&self) -> u32 {
        self.deadline_ms
    }

    /// The deadline budget as a [`Duration`], or `None` when unset.
    #[inline]
    pub fn deadline(&self) -> Option<core::time::Duration> {
        (self.deadline_ms > 0)
            .then(|| core::time::Duration::from_millis(u64::from(self.deadline_ms)))
    }

    /// The meaningful scalar operands (first `arity` slots). Reduction
    /// requests have no scalar slots — this returns the empty slice for
    /// them; read their lanes through [`OpRequest::vector_lanes`].
    #[inline]
    pub fn operands(&self) -> &[Posit] {
        match &self.operands {
            Operands::Scalar(slots) => &slots[..self.op.arity()],
            Operands::Vector { .. } => &[],
        }
    }

    /// The vector lanes `(a, b, α)` of a reduction request (`b` is empty
    /// when the op ignores it, `α` is meaningful for `Axpy` only);
    /// `None` for scalar requests.
    #[inline]
    pub fn vector_lanes(&self) -> Option<(&[Posit], &[Posit], Posit)> {
        match &self.operands {
            Operands::Vector { a, b, c } => Some((a, b, *c)),
            Operands::Scalar(_) => None,
        }
    }

    /// Posit width of the request's operands. [`OpRequest::new`] and the
    /// reduction constructors reject mixed-width operand sets (the named
    /// scalar constructors `debug_assert` it), and [`Unit::run`] / the
    /// coordinator re-check the request against the serving width, so a
    /// mixed-width request surfaces as a typed
    /// [`PositError::WidthMismatch`] at execution.
    #[inline]
    pub fn width(&self) -> u32 {
        match &self.operands {
            Operands::Scalar(slots) => slots[0].width(),
            Operands::Vector { a, .. } => a[0].width(),
        }
    }

    /// The three scalar operand slots as raw bit patterns (unused slots
    /// are 0). Reduction requests surface only their scalar coefficient
    /// (in slot `c`); their vectors travel via
    /// [`OpRequest::vector_lanes`].
    #[inline]
    pub fn bits(&self) -> [u64; 3] {
        match &self.operands {
            Operands::Scalar(s) => [s[0].to_bits(), s[1].to_bits(), s[2].to_bits()],
            Operands::Vector { c, .. } => [0, 0, c.to_bits()],
        }
    }

    /// The exact expected result for this request, from the crate's
    /// golden references: the exact-rational division/sqrt models, the
    /// correctly-rounded arithmetic library for the scalar ops, and the
    /// bignum-rational reduction golden ([`crate::testkit::rational`] —
    /// no quire, no floats) for the reductions. The one verification
    /// table shared by the serve drivers, the bench suites and the tests
    /// — independent of the [`Unit`] execution path.
    pub fn golden(&self) -> Posit {
        match &self.operands {
            Operands::Vector { a, b, c } => match self.op {
                Op::Dot => rational::dot(a, b),
                Op::FusedSum => rational::fused_sum(a),
                Op::Axpy => rational::axpy(*c, a, b),
                _ => unreachable!("vector operands on a scalar op"),
            },
            Operands::Scalar(slots) => {
                let ops = &slots[..self.op.arity()];
                match self.op {
                    Op::Div { .. } => golden::divide(ops[0], ops[1]).result,
                    Op::Sqrt => golden_sqrt(ops[0]).result,
                    Op::Mul => ops[0].mul(ops[1]),
                    Op::Add => ops[0].add(ops[1]),
                    Op::Sub => ops[0].sub(ops[1]),
                    Op::MulAdd => ops[0].mul_add(ops[1], ops[2]),
                    _ => unreachable!("scalar operands on a reduction op"),
                }
            }
        }
    }
}

/// Concrete division-engine storage: static dispatch, no `Box`.
pub(crate) enum EngineAny {
    Nrd(Nrd),
    Srt2(Srt2),
    Srt2Cs(Srt2Cs),
    Srt4Cs(Srt4Cs),
    Srt4Scaled(Srt4Scaled),
    Newton(Newton),
}

impl EngineAny {
    fn for_algorithm(alg: Algorithm) -> EngineAny {
        match alg {
            Algorithm::Nrd => EngineAny::Nrd(Nrd::new()),
            Algorithm::NrdAsap23 => EngineAny::Nrd(Nrd::asap23()),
            Algorithm::Srt2 => EngineAny::Srt2(Srt2::new()),
            Algorithm::Srt2Cs => EngineAny::Srt2Cs(Srt2Cs::plain()),
            Algorithm::Srt2CsOf => EngineAny::Srt2Cs(Srt2Cs::with_otf()),
            Algorithm::Srt2CsOfFr => EngineAny::Srt2Cs(Srt2Cs::with_otf_fr()),
            Algorithm::Srt4Cs => EngineAny::Srt4Cs(Srt4Cs::plain()),
            Algorithm::Srt4CsOf => EngineAny::Srt4Cs(Srt4Cs::with_otf()),
            Algorithm::Srt4CsOfFr => EngineAny::Srt4Cs(Srt4Cs::with_otf_fr()),
            Algorithm::Srt4Scaled => EngineAny::Srt4Scaled(Srt4Scaled::new()),
            Algorithm::Newton => EngineAny::Newton(Newton::new()),
        }
    }
}

/// `EngineAny` is itself a [`DivEngine`] (static dispatch inside), so the
/// shared [`exec`] wrapper and every API taking a `&dyn DivEngine` accept
/// it directly.
impl DivEngine for EngineAny {
    fn name(&self) -> &'static str {
        match self {
            EngineAny::Nrd(e) => e.name(),
            EngineAny::Srt2(e) => e.name(),
            EngineAny::Srt2Cs(e) => e.name(),
            EngineAny::Srt4Cs(e) => e.name(),
            EngineAny::Srt4Scaled(e) => e.name(),
            EngineAny::Newton(e) => e.name(),
        }
    }

    fn algorithm(&self) -> Algorithm {
        match self {
            EngineAny::Nrd(e) => e.algorithm(),
            EngineAny::Srt2(e) => e.algorithm(),
            EngineAny::Srt2Cs(e) => e.algorithm(),
            EngineAny::Srt4Cs(e) => e.algorithm(),
            EngineAny::Srt4Scaled(e) => e.algorithm(),
            EngineAny::Newton(e) => e.algorithm(),
        }
    }

    fn fraction_divide(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
        match self {
            EngineAny::Nrd(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Srt2(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Srt2Cs(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Srt4Cs(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Srt4Scaled(e) => e.fraction_divide(n, x_sig, d_sig),
            EngineAny::Newton(e) => e.fraction_divide(n, x_sig, d_sig),
        }
    }
}

/// Per-op engine state held by a [`Unit`].
enum Core {
    Div { engine: EngineAny },
    Sqrt { engine: SqrtEngine },
    Mul,
    Add,
    Sub,
    MulAdd,
    /// All three quire reductions: the op tag picks the kernel.
    Reduce,
}

/// A reusable execution context for one posit width and one [`Op`].
///
/// All width-derived state (iteration count, latency model, operand mask,
/// and — for the Newton division baseline — its seed-reciprocal table, the
/// only allocation) is computed once at construction; the run entry points
/// allocate nothing.
///
/// ```
/// use posit_div::posit::Posit;
/// use posit_div::unit::{Op, Unit};
///
/// let div = Unit::new(32, Op::DIV)?;
/// let q = div.run(&[Posit::from_f64(32, 355.0), Posit::from_f64(32, 113.0)])?;
/// assert!((q.result.to_f64() - 355.0 / 113.0).abs() < 1e-6);
///
/// let sqrt = Unit::new(32, Op::Sqrt)?;
/// let r = sqrt.run(&[Posit::from_f64(32, 9.0)])?;
/// assert_eq!(r.result.to_f64(), 3.0);
/// # Ok::<(), posit_div::PositError>(())
/// ```
pub struct Unit {
    n: u32,
    op: Op,
    core: Core,
    tier: ExecTier,
    fast: FastKernel,
    iterations: u32,
    /// Iterations a *real* (non-special) lane reports — what the datapath
    /// engine would count. Equal to `iterations` except for the Newton
    /// baseline (whose public count is 0 but whose engine reports its NR
    /// step count); used by the fast tier's modeled scalar metadata.
    real_iters: u32,
    cycles: u32,
    mask: u64,
}

impl Unit {
    /// Build a context for `Posit<n, 2>` serving `op` at the default
    /// [`ExecTier::Auto`]. All width-derived state is computed here, once.
    pub fn new(n: u32, op: Op) -> Result<Unit> {
        Unit::with_tier(n, op, ExecTier::Auto)
    }

    /// Build a context for `Posit<n, 2>` serving `op` from a specific
    /// execution tier (fast-tier batches keep the default
    /// [`FastPath::Auto`] dispatch).
    pub fn with_tier(n: u32, op: Op, tier: ExecTier) -> Result<Unit> {
        Unit::with_exec(n, op, tier, FastPath::Auto)
    }

    /// Build a context with both the execution tier and the fast-tier
    /// batch kernel pinned. `path` must be able to serve `(n, op)`
    /// ([`FastPath::Table`] needs a tabulated `(n, op)` — any Posit8 op
    /// but `MulAdd`, or Posit16 div/sqrt; [`FastPath::Vector`] needs
    /// n ∈ {8, 16}, a non-`Sqrt`/`MulAdd` op *and* a runtime-detected
    /// vector ISA under the `vsimd` feature; [`FastPath::Simd`] needs
    /// n ∈ {8, 16}), and a Datapath-pinned unit never consults the fast
    /// path, so forcing one there is rejected too. Either mismatch is a
    /// typed [`PositError::UnsupportedFastPath`], not a silent fallback —
    /// benches and tests that force a kernel must never measure a
    /// different one.
    pub fn with_exec(n: u32, op: Op, tier: ExecTier, path: FastPath) -> Result<Unit> {
        if !(MIN_N..=MAX_N).contains(&n) {
            return Err(PositError::WidthOutOfRange { n });
        }
        // The Approx tier bypasses the fast-path serving layer entirely,
        // so forcing a table/SWAR kernel there could never be honored.
        let approx_pinned = tier == ExecTier::Approx && path != FastPath::Auto;
        let datapath_pinned = tier == ExecTier::Datapath && path != FastPath::Auto;
        // The reductions never run through a FastKernel (their Fast tier
        // is the in-register quire), so a forced table/vector/SWAR kernel
        // has nothing to serve them — reject it rather than silently
        // ignore.
        let reduction_forced = op.is_reduction()
            && matches!(path, FastPath::Table | FastPath::Vector | FastPath::Simd);
        if approx_pinned
            || datapath_pinned
            || reduction_forced
            || !fastpath::path_supported(n, op.fast_kind(), path)
        {
            return Err(PositError::UnsupportedFastPath { path: path.name(), op: op.name(), n });
        }
        // The Approx tier serves only the (op, width) grid with declared
        // ulp contracts — anything else is a typed rejection, never a
        // silent exact fallback (a unit pinned approx must measure the
        // bounded-error kernel it asked for).
        if tier == ExecTier::Approx && op.approx_spec(n).is_none() {
            return Err(PositError::UnsupportedApprox { op: op.name(), n });
        }
        let (core, iters, real_iters, cycles) = match op {
            Op::Div { alg } => {
                let engine = EngineAny::for_algorithm(alg);
                let iters = match alg.radix() {
                    Some(r) => iterations(n, r),
                    None => 0,
                };
                // `latency_cycles` would build a throwaway Newton (and its
                // seed LUT) just to ask for the cycle count — use the
                // engine we already hold instead.
                let (real_iters, cycles) = match &engine {
                    EngineAny::Newton(e) => (e.nr_steps(n), e.cycles(n)),
                    // the [14] decode costs the recurrence one extra
                    // iteration beyond the Table II count
                    _ => (
                        iters + (alg == Algorithm::NrdAsap23) as u32,
                        latency_cycles(n, alg),
                    ),
                };
                (Core::Div { engine }, iters, real_iters, cycles)
            }
            Op::Sqrt => {
                let engine = SqrtEngine::new();
                let iters = engine.iterations(n);
                (Core::Sqrt { engine }, iters, iters, iters + exec::SPECIAL_CYCLES)
            }
            Op::Mul => (Core::Mul, 0, 0, ARITH_CYCLES),
            Op::Add => (Core::Add, 0, 0, ARITH_CYCLES),
            Op::Sub => (Core::Sub, 0, 0, ARITH_CYCLES),
            Op::MulAdd => (Core::MulAdd, 0, 0, ARITH_CYCLES + 1),
            // reductions: one multiply-accumulate stage into the quire,
            // modeled per request (the per-element cost is what the
            // linalg bench suite measures)
            Op::Dot | Op::FusedSum | Op::Axpy => (Core::Reduce, 0, 0, ARITH_CYCLES + 1),
        };
        Ok(Unit {
            n,
            op,
            core,
            tier,
            fast: FastKernel::with_path(n, op.fast_kind(), path),
            iterations: iters,
            real_iters,
            cycles,
            mask: mask(n),
        })
    }

    /// The configured execution tier.
    #[inline]
    pub fn tier(&self) -> ExecTier {
        self.tier
    }

    /// The tier that actually serves the batch/bit-level entry points
    /// (`Auto` resolves to `Fast`): never `Auto`.
    #[inline]
    pub fn batch_tier(&self) -> ExecTier {
        match self.tier {
            ExecTier::Datapath => ExecTier::Datapath,
            ExecTier::Approx => ExecTier::Approx,
            _ => ExecTier::Fast,
        }
    }

    /// The tier that serves metadata-bearing scalar calls ([`Unit::run`];
    /// `Auto` resolves to `Datapath`): never `Auto`.
    #[inline]
    pub fn scalar_tier(&self) -> ExecTier {
        match self.tier {
            ExecTier::Fast => ExecTier::Fast,
            ExecTier::Approx => ExecTier::Approx,
            _ => ExecTier::Datapath,
        }
    }

    /// The configured fast-tier batch dispatch (`Auto` unless the unit
    /// was built through [`Unit::with_exec`]).
    #[inline]
    pub fn fast_path(&self) -> FastPath {
        self.fast.path()
    }

    /// The concrete Fast kernel that serves a batch of `len` lanes
    /// (table, vector, SWAR or scalar-fast; never `Auto`), or `None` when
    /// the unit's batches run on the Datapath or Approx tier (neither
    /// dispatches through the fast-path serving layer). This is what the
    /// coordinator's per-path metrics count.
    #[inline]
    pub fn resolve_fast_path(&self, len: usize) -> Option<FastPath> {
        if self.batch_tier() != ExecTier::Fast {
            return None;
        }
        if self.op.is_reduction() {
            // the in-register quire is the reductions' scalar-fast kernel;
            // they never dispatch to the table/SWAR serving layer
            return Some(FastPath::Scalar);
        }
        Some(self.fast.resolve(len))
    }

    /// Posit width this context serves.
    #[inline]
    pub fn width(&self) -> u32 {
        self.n
    }

    /// The operation this context serves.
    #[inline]
    pub fn op(&self) -> Op {
        self.op
    }

    /// Number of operands per request ([`Op::arity`]).
    #[inline]
    pub fn arity(&self) -> usize {
        self.op.arity()
    }

    /// The division algorithm, for `Op::Div` units.
    #[inline]
    pub fn algorithm(&self) -> Option<Algorithm> {
        match &self.core {
            Core::Div { engine } => Some(engine.algorithm()),
            _ => None,
        }
    }

    /// Engine name for reports: the Table IV label for division units
    /// (`"SRT r4 CS OF FR"`, …), the op name otherwise.
    pub fn engine_name(&self) -> &'static str {
        match &self.core {
            Core::Div { engine } => engine.name(),
            Core::Sqrt { .. } => "sqrt r2",
            Core::Mul => "mul",
            Core::Add => "add",
            Core::Sub => "sub",
            Core::MulAdd => "mul+add",
            Core::Reduce => "quire",
        }
    }

    /// The division engine of an `Op::Div` unit as a [`DivEngine`], so it
    /// drops into every API that takes one (the DSP example, the
    /// cross-check harnesses) with static dispatch inside. `None` for
    /// non-division units.
    pub fn as_div_engine(&self) -> Option<&(dyn DivEngine + Send + Sync)> {
        match &self.core {
            Core::Div { engine } => Some(engine),
            _ => None,
        }
    }

    /// Cached recurrence iteration count per operation: Table II for
    /// division (0 for the Newton baseline), one per result bit for sqrt,
    /// 0 for the single-pass arithmetic ops.
    #[inline]
    pub fn iterations(&self) -> u32 {
        self.iterations
    }

    /// Cached pipelined latency model in cycles (paper §III-E3 for
    /// division; iterations + decode/encode for sqrt; a single datapath
    /// stage for mul/add/sub).
    #[inline]
    pub fn latency_cycles(&self) -> u32 {
        self.cycles
    }

    /// One scalar operation with metadata. `operands.len()` must equal
    /// [`Unit::arity`] and every operand must be at the context width;
    /// both misuses are typed errors, not panics.
    ///
    /// Under [`ExecTier::Auto`] this entry point runs the Datapath tier
    /// (cycle metadata is being requested). Under an explicit
    /// [`ExecTier::Fast`] the result comes from the fast kernel and the
    /// metadata is modeled from the cached per-format counts — the same
    /// values the datapath reports, without stepping it.
    pub fn run(&self, operands: &[Posit]) -> Result<Division> {
        if operands.len() != self.op.arity() {
            return Err(PositError::ArityMismatch {
                op: self.op.name(),
                expected: self.op.arity(),
                got: operands.len(),
            });
        }
        for p in operands {
            if p.width() != self.n {
                return Err(PositError::WidthMismatch { expected: self.n, got: p.width() });
            }
        }
        if let Core::Reduce = self.core {
            // a scalar reduction call is the single-element reduction;
            // both tiers are exact, so metadata is the flat model either way
            return Ok(self.arith_division(self.reduce_scalar(operands)));
        }
        if self.scalar_tier() == ExecTier::Approx {
            return Ok(self.approx_run(operands));
        }
        if self.scalar_tier() == ExecTier::Fast {
            return Ok(self.fast_run(operands));
        }
        Ok(match &self.core {
            Core::Div { engine } => exec::divide_with(engine, operands[0], operands[1]),
            Core::Sqrt { engine } => {
                let r = engine.sqrt(operands[0]);
                Division {
                    result: r.result,
                    iterations: r.iterations,
                    cycles: if r.iterations == 0 { exec::SPECIAL_CYCLES } else { self.cycles },
                }
            }
            Core::Mul => self.arith_division(operands[0].mul(operands[1])),
            Core::Add => self.arith_division(operands[0].add(operands[1])),
            Core::Sub => self.arith_division(operands[0].sub(operands[1])),
            Core::MulAdd => self.arith_division(operands[0].mul_add(operands[1], operands[2])),
            Core::Reduce => unreachable!("reductions return above"),
        })
    }

    /// Single-element reduction for the scalar [`Unit::run`] entry point
    /// (`Dot`: `[a₀, b₀]`, `FusedSum`: `[x₀]`, `Axpy`: `[x₀, y₀, α]`).
    fn reduce_scalar(&self, operands: &[Posit]) -> Posit {
        let lane = |i: usize| [operands[i].to_bits()];
        let bits = match self.op {
            Op::Dot => self.reduction_bits(&lane(0), &lane(1), &[]),
            Op::FusedSum => self.reduction_bits(&lane(0), &[], &[]),
            Op::Axpy => self.reduction_bits(&lane(0), &lane(1), &lane(2)),
            _ => unreachable!("reduce_scalar on a scalar op"),
        };
        Posit::from_bits(self.n, bits)
    }

    /// Fast-tier scalar execution with modeled metadata (bit-identical to
    /// what the datapath tier reports for the same request).
    fn fast_run(&self, operands: &[Posit]) -> Division {
        let lane = |i: usize| operands.get(i).map_or(0, |p| p.to_bits());
        let (a, b, c) = (lane(0), lane(1), lane(2));
        let special = self.fast.classify(a, b, c);
        let bits = special.unwrap_or_else(|| self.fast.real_bits(a, b, c));
        let result = Posit::from_bits(self.n, bits);
        match self.op {
            // recurrence ops: specials skip the datapath entirely
            Op::Div { .. } | Op::Sqrt if special.is_some() => {
                Division { result, iterations: 0, cycles: exec::SPECIAL_CYCLES }
            }
            Op::Div { .. } | Op::Sqrt => {
                Division { result, iterations: self.real_iters, cycles: self.cycles }
            }
            // single-pass arithmetic ops model one flat latency
            _ => self.arith_division(result),
        }
    }

    /// Approx-tier scalar execution: the bounded-error kernel of
    /// [`crate::division::approx`], with modeled single-pass metadata —
    /// one Newton refinement for div/sqrt (`iterations = 1`), none for
    /// the truncated multiply, one datapath stage either way. Specials
    /// resolve through the shared exact pre-pass and report the same
    /// metadata as the other tiers.
    fn approx_run(&self, operands: &[Posit]) -> Division {
        let lane = |i: usize| operands.get(i).map_or(0, |p| p.to_bits());
        let (a, b) = (lane(0), lane(1));
        let bits = approx::scalar_bits(self.n, self.op.fast_kind(), a, b, 0);
        let result = Posit::from_bits(self.n, bits);
        if self.fast.classify(a, b, 0).is_some() {
            return Division { result, iterations: 0, cycles: exec::SPECIAL_CYCLES };
        }
        let iterations = match self.op {
            Op::Div { .. } | Op::Sqrt => 1,
            _ => 0,
        };
        Division { result, iterations, cycles: ARITH_CYCLES }
    }

    #[inline]
    fn arith_division(&self, result: Posit) -> Division {
        Division { result, iterations: 0, cycles: self.cycles }
    }

    /// One operation over raw `n`-bit patterns (high garbage bits are
    /// masked off — the same contract as the PJRT graph). Lanes beyond the
    /// op's arity are ignored. This is the batch-path inner loop; it runs
    /// on [`Unit::batch_tier`] (the Fast kernels unless the unit was
    /// pinned to `Datapath`).
    #[inline]
    pub fn run_bits(&self, a: u64, b: u64, c: u64) -> u64 {
        if let Core::Reduce = self.core {
            // the single-element reduction; the FastKernel serves only
            // the scalar ops
            return self.reduction_bits(&[a], &[b], &[c]);
        }
        match self.batch_tier() {
            ExecTier::Fast => self.fast.op_bits(a, b, c),
            ExecTier::Approx => approx::scalar_bits(self.n, self.op.fast_kind(), a, b, c),
            _ => self.datapath_bits(a, b, c),
        }
    }

    /// Reduction execution over raw bit-pattern lanes (one output):
    /// Datapath accumulates in the limb quire, Fast keeps the quire in a
    /// register where the width allows — bit-identical by construction
    /// ([`crate::quire`]).
    fn reduction_bits(&self, a: &[u64], b: &[u64], c: &[u64]) -> u64 {
        let fast = self.batch_tier() == ExecTier::Fast;
        match self.op {
            Op::Dot if fast => quire::dot_bits_fast(self.n, a, b),
            Op::Dot => quire::dot_bits(self.n, a, b),
            Op::FusedSum if fast => quire::fused_sum_bits_fast(self.n, a),
            Op::FusedSum => quire::fused_sum_bits(self.n, a),
            Op::Axpy => {
                let alpha = c.first().copied().unwrap_or(0) & self.mask;
                if fast {
                    quire::axpy_bits_fast(self.n, alpha, a, b)
                } else {
                    quire::axpy_bits(self.n, alpha, a, b)
                }
            }
            _ => unreachable!("reduction_bits on a scalar op"),
        }
    }

    /// Datapath-tier bit-level execution (the cycle-accurate engines).
    #[inline]
    fn datapath_bits(&self, a: u64, b: u64, c: u64) -> u64 {
        let p = |bits: u64| Posit::from_bits(self.n, bits & self.mask);
        match &self.core {
            Core::Div { engine } => exec::divide_with(engine, p(a), p(b)).result.to_bits(),
            Core::Sqrt { engine } => engine.sqrt(p(a)).result.to_bits(),
            Core::Mul => p(a).mul(p(b)).to_bits(),
            Core::Add => p(a).add(p(b)).to_bits(),
            Core::Sub => p(a).sub(p(b)).to_bits(),
            Core::MulAdd => p(a).mul_add(p(b), p(c)).to_bits(),
            Core::Reduce => self.reduction_bits(&[a & self.mask], &[b & self.mask], &[c]),
        }
    }

    /// Lanes the op uses must match `out`'s length; unused lanes may be
    /// empty (or padded to the same length). Lane `a`/`b` violations
    /// report [`PositError::BatchShapeMismatch`] (lanes map to the old
    /// `xs`/`ds` fields), lane `c` [`PositError::BatchLaneMismatch`].
    fn check_lanes(&self, a: &[u64], b: &[u64], c: &[u64], len: usize) -> Result<()> {
        let arity = self.op.arity();
        let bad = |lane: &[u64], used: bool| {
            if used {
                lane.len() != len
            } else {
                !lane.is_empty() && lane.len() != len
            }
        };
        if bad(a, true) || bad(b, arity >= 2) {
            return Err(PositError::BatchShapeMismatch { xs: a.len(), ds: b.len(), out: len });
        }
        if bad(c, arity >= 3) {
            return Err(PositError::BatchLaneMismatch { lane: "c", expected: len, got: c.len() });
        }
        Ok(())
    }

    /// Lane shape for a reduction batch: one output, a nonempty `a`
    /// vector, `b` matched element-for-element when the op reads it, and
    /// for `Axpy` exactly one coefficient in `c`. Violations are typed
    /// [`PositError::BatchLaneMismatch`] / [`PositError::BatchShapeMismatch`]
    /// errors, mirroring the scalar-batch checks.
    fn check_reduction_lanes(&self, a: &[u64], b: &[u64], c: &[u64], out_len: usize) -> Result<()> {
        if out_len != 1 {
            return Err(PositError::BatchShapeMismatch { xs: a.len(), ds: b.len(), out: out_len });
        }
        if a.is_empty() {
            return Err(PositError::BatchLaneMismatch { lane: "a", expected: 1, got: 0 });
        }
        if matches!(self.op, Op::Dot | Op::Axpy) && b.len() != a.len() {
            return Err(PositError::BatchLaneMismatch {
                lane: "b",
                expected: a.len(),
                got: b.len(),
            });
        }
        if matches!(self.op, Op::Axpy) && c.len() != 1 {
            return Err(PositError::BatchLaneMismatch { lane: "c", expected: 1, got: c.len() });
        }
        Ok(())
    }

    /// Batch-first execution over raw bit patterns:
    /// `out[i] = op(a[i], b[i], c[i])`, taking only the lanes the op uses
    /// (pass `&[]` for the rest). Bit-identical to calling [`Unit::run`]
    /// element-wise; the coordinator's native backend, the benches and the
    /// examples all go through this one loop.
    ///
    /// Runs on [`Unit::batch_tier`]: under `Auto`/`Fast` the batch decode
    /// is hoisted into a lane-splitting pre-pass (special patterns
    /// resolved in bulk, real lanes through the width-monomorphized
    /// kernel loop); under `Datapath` every lane steps the cycle-accurate
    /// engine.
    ///
    /// **Reduction units** invert the shape: `a`/`b` are the k-element
    /// input vectors (plus the single `Axpy` coefficient in `c`) and
    /// `out` is exactly one lane holding the rounded accumulation —
    /// Datapath batches walk the limb quire, Fast batches keep the quire
    /// in a register where the width allows, bit-identically.
    pub fn run_batch(&self, a: &[u64], b: &[u64], c: &[u64], out: &mut [u64]) -> Result<()> {
        if let Core::Reduce = self.core {
            self.check_reduction_lanes(a, b, c, out.len())?;
            out[0] = self.reduction_bits(a, b, c);
            return Ok(());
        }
        self.check_lanes(a, b, c, out.len())?;
        if self.batch_tier() == ExecTier::Approx {
            approx::run_batch(self.n, self.op.fast_kind(), a, b, out);
            return Ok(());
        }
        if self.batch_tier() == ExecTier::Fast {
            self.fast.run_batch(a, b, c, out);
            return Ok(());
        }
        match self.op.arity() {
            1 => {
                for (&x, o) in a.iter().zip(out.iter_mut()) {
                    *o = self.datapath_bits(x, 0, 0);
                }
            }
            2 => {
                for ((&x, &y), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
                    *o = self.datapath_bits(x, y, 0);
                }
            }
            _ => {
                for (((&x, &y), &z), o) in
                    a.iter().zip(b.iter()).zip(c.iter()).zip(out.iter_mut())
                {
                    *o = self.datapath_bits(x, y, z);
                }
            }
        }
        Ok(())
    }

    /// Rough per-lane serving cost on the tier/kernel a batch of `len`
    /// lanes resolves to, in nanoseconds. Coarse calibration constants —
    /// they only steer the parallel chunking heuristic
    /// ([`Unit::parallel_chunk`]), so being within ~2× is enough.
    fn batch_lane_ns(&self, len: usize) -> f64 {
        if self.batch_tier() == ExecTier::Datapath {
            // per-iteration register emulation dominates; decode/encode
            // and the iteration body both grow with the width
            return 30.0 + 16.0 * self.real_iters as f64 + 0.4 * self.n as f64;
        }
        if self.batch_tier() == ExecTier::Approx {
            // straight-line seed + one Newton step (div/sqrt) or one
            // truncated multiply — cheaper than the scalar-fast kernels,
            // costlier than a table lookup
            return match self.op {
                Op::Div { .. } => 18.0,
                Op::Sqrt => 22.0,
                _ => 12.0,
            };
        }
        match self.fast.resolve(len) {
            // Posit8 whole-op lookup vs the Posit16 seed-table kernels
            // (one table read + a fix-up division step per lane)
            FastPath::Table if self.n == 8 => 3.0,
            FastPath::Table => 6.0,
            FastPath::Vector => match self.op {
                Op::Div { .. } => 10.0,
                _ => 6.0,
            },
            FastPath::Simd => match self.op {
                Op::Div { .. } => 16.0,
                Op::Sqrt => 30.0,
                Op::MulAdd => 25.0,
                _ => 10.0,
            },
            _ => match self.op {
                Op::Div { .. } => 40.0,
                Op::Sqrt => 60.0,
                Op::MulAdd => 55.0,
                _ => 25.0,
            },
        }
    }

    /// Chunk size [`Unit::run_batch_parallel`] uses to split a batch of
    /// `len` lanes across `threads` workers: an even split, floored so
    /// every chunk carries roughly [`crate::pool::TARGET_CHUNK_NS`] of
    /// work on this unit's `(op, width, tier)` — small batches therefore
    /// collapse to fewer chunks (down to one, which runs inline) instead
    /// of paying pool fan-out for microscopic pieces. When the batch
    /// resolves to a block kernel (SWAR or explicit vector), the chunk is
    /// rounded up to the kernel's [`fastpath::LANE_BLOCK`] so chunk
    /// boundaries land on block boundaries — a misaligned chunk would
    /// leave every worker a partially-filled trailing block. Public so
    /// tests and capacity planning can inspect the policy.
    pub fn parallel_chunk(&self, len: usize, threads: usize) -> usize {
        let chunk = crate::pool::chunk_size(self.batch_lane_ns(len), len, threads);
        if self.batch_tier() == ExecTier::Fast
            && matches!(self.fast.resolve(len), FastPath::Vector | FastPath::Simd)
        {
            crate::pool::align_chunk(chunk, len, fastpath::LANE_BLOCK)
        } else {
            chunk
        }
    }

    /// [`Unit::run_batch`] split into contiguous chunks (sized by the
    /// [`Unit::parallel_chunk`] heuristic, at most one per `threads`) and
    /// spread over the shared crate-level worker pool
    /// ([`crate::pool::global`] — persistent workers, no per-call thread
    /// spawning); results are written in place, ordering preserved.
    /// Batches below roughly one chunk of work run inline on the caller.
    pub fn run_batch_parallel(
        &self,
        a: &[u64],
        b: &[u64],
        c: &[u64],
        out: &mut [u64],
        threads: usize,
    ) -> Result<()> {
        if let Core::Reduce = self.core {
            // a reduction is one sequential accumulation; serve it inline
            return self.run_batch(a, b, c, out);
        }
        self.check_lanes(a, b, c, out.len())?;
        let threads = threads.max(1);
        let chunk = self.parallel_chunk(out.len(), threads);
        if threads == 1 || out.len() <= chunk {
            return self.run_batch(a, b, c, out);
        }
        // Resolve the fast kernel once on the full batch length: every
        // chunk runs the same kernel the batch (and the per-path metrics,
        // via `resolve_fast_path` on the same length) resolved to, even
        // when a ragged tail chunk falls below a dispatch threshold.
        let fast_path = self.resolve_fast_path(out.len());
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(out.len().div_ceil(chunk));
        let mut start = 0usize;
        for co in out.chunks_mut(chunk) {
            let end = start + co.len();
            let ca = &a[start..end];
            let cb = if b.is_empty() { b } else { &b[start..end] };
            let cc = if c.is_empty() { c } else { &c[start..end] };
            jobs.push(Box::new(move || match fast_path {
                Some(p) => self.fast.run_batch_with(p, ca, cb, cc, co),
                None => self.run_batch(ca, cb, cc, co).expect("equal chunk lanes"),
            }));
            start = end;
        }
        crate::pool::global().run_scoped(jobs);
        Ok(())
    }
}

impl fmt::Debug for Unit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Unit")
            .field("n", &self.n)
            .field("op", &self.op)
            .field("tier", &self.tier)
            .field("engine", &self.engine_name())
            .field("iterations", &self.iterations)
            .field("latency_cycles", &self.cycles)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    #[test]
    fn op_metadata() {
        assert_eq!(Op::Sqrt.arity(), 1);
        assert_eq!(Op::DIV.arity(), 2);
        assert_eq!(Op::MulAdd.arity(), 3);
        assert_eq!(Op::DIV.name(), "div");
        assert_eq!(Op::MulAdd.name(), "mul_add");
        assert_eq!(Op::DIV.label(), "div[SRT r4 CS OF FR]");
        assert_eq!(Op::Sqrt.label(), "sqrt");
        assert_eq!(Op::Sqrt.to_string(), "sqrt");
        assert_eq!(Op::DEFAULTS.len(), 6);
        // kind indices are dense, stable and algorithm-blind
        for (i, op) in Op::KINDS.iter().enumerate() {
            assert_eq!(op.kind_index(), i, "{op}");
        }
        assert_eq!(
            Op::Div { alg: Algorithm::Nrd }.kind_index(),
            Op::DIV.kind_index(),
            "division kinds ignore the algorithm"
        );
    }

    #[test]
    fn rejects_bad_width() {
        assert_eq!(Unit::new(3, Op::DIV).err(), Some(PositError::WidthOutOfRange { n: 3 }));
        assert_eq!(Unit::new(65, Op::Sqrt).err(), Some(PositError::WidthOutOfRange { n: 65 }));
        assert!(Unit::new(4, Op::Mul).is_ok());
        assert!(Unit::new(64, Op::DIV).is_ok());
    }

    #[test]
    fn rejects_arity_and_width_misuse() {
        let unit = Unit::new(16, Op::Sqrt).unwrap();
        assert_eq!(
            unit.run(&[Posit::one(16), Posit::one(16)]).err(),
            Some(PositError::ArityMismatch { op: "sqrt", expected: 1, got: 2 })
        );
        assert_eq!(
            unit.run(&[Posit::one(32)]).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 32 })
        );
        let div = Unit::new(16, Op::DIV).unwrap();
        assert_eq!(
            div.run(&[Posit::one(16)]).err(),
            Some(PositError::ArityMismatch { op: "div", expected: 2, got: 1 })
        );
    }

    #[test]
    fn rejects_batch_lane_mismatch() {
        let div = Unit::new(16, Op::DIV).unwrap();
        let mut out = [0u64; 2];
        assert_eq!(
            div.run_batch(&[1, 2, 3], &[1, 2, 3], &[], &mut out).err(),
            Some(PositError::BatchShapeMismatch { xs: 3, ds: 3, out: 2 })
        );
        assert_eq!(
            div.run_batch(&[1, 2], &[1], &[], &mut out).err(),
            Some(PositError::BatchShapeMismatch { xs: 2, ds: 1, out: 2 })
        );
        let fma = Unit::new(16, Op::MulAdd).unwrap();
        assert_eq!(
            fma.run_batch(&[1, 2], &[1, 2], &[1], &mut out).err(),
            Some(PositError::BatchLaneMismatch { lane: "c", expected: 2, got: 1 })
        );
        let sqrt = Unit::new(16, Op::Sqrt).unwrap();
        // unused lanes may be empty or padded to the batch length
        assert!(sqrt.run_batch(&[1, 2], &[], &[], &mut out).is_ok());
        assert!(sqrt.run_batch(&[1, 2], &[0, 0], &[0, 0], &mut out).is_ok());
        assert_eq!(
            sqrt.run_batch(&[1, 2], &[0], &[], &mut out).err(),
            Some(PositError::BatchShapeMismatch { xs: 2, ds: 1, out: 2 })
        );
    }

    #[test]
    fn every_op_batch_matches_scalar_references() {
        let mut rng = Rng::seeded(0x017);
        for n in [8u32, 16, 32] {
            let a: Vec<u64> = (0..200).map(|_| rng.next_u64() & mask(n)).collect();
            let b: Vec<u64> = (0..200).map(|_| rng.next_u64() & mask(n)).collect();
            let c: Vec<u64> = (0..200).map(|_| rng.next_u64() & mask(n)).collect();
            for op in Op::DEFAULTS {
                let unit = Unit::new(n, op).unwrap();
                let (lb, lc): (&[u64], &[u64]) = match op.arity() {
                    1 => (&[], &[]),
                    2 => (&b, &[]),
                    _ => (&b, &c),
                };
                let mut out = vec![0u64; a.len()];
                unit.run_batch(&a, lb, lc, &mut out).unwrap();
                for i in 0..a.len() {
                    let pa = Posit::from_bits(n, a[i]);
                    let pb = Posit::from_bits(n, b[i]);
                    let pc = Posit::from_bits(n, c[i]);
                    let want = match op {
                        Op::Div { .. } => golden::divide(pa, pb).result,
                        Op::Sqrt => golden_sqrt(pa).result,
                        Op::Mul => pa.mul(pb),
                        Op::Add => pa.add(pb),
                        Op::Sub => pa.sub(pb),
                        Op::MulAdd => pa.mul_add(pb, pc),
                    };
                    assert_eq!(out[i], want.to_bits(), "{op} n={n} i={i}");
                    let operands: Vec<Posit> =
                        [pa, pb, pc].into_iter().take(op.arity()).collect();
                    let scalar = unit.run(&operands).unwrap();
                    assert_eq!(scalar.result.to_bits(), want.to_bits(), "{op} scalar n={n}");
                    // the shared reference helper agrees with this test's
                    // independent per-op table
                    let req = OpRequest::new(op, &operands).unwrap();
                    assert_eq!(req.golden(), want, "{op} golden() n={n}");
                }
            }
        }
    }

    #[test]
    fn parallel_batch_is_bit_identical_for_every_op() {
        let mut rng = Rng::seeded(0x9B);
        let n = 16;
        let a: Vec<u64> = (0..777).map(|_| rng.next_u64() & mask(n)).collect();
        let b: Vec<u64> = (0..777).map(|_| rng.next_u64() & mask(n)).collect();
        let c: Vec<u64> = (0..777).map(|_| rng.next_u64() & mask(n)).collect();
        for op in Op::DEFAULTS {
            let unit = Unit::new(n, op).unwrap();
            let (lb, lc): (&[u64], &[u64]) = match op.arity() {
                1 => (&[], &[]),
                2 => (&b, &[]),
                _ => (&b, &c),
            };
            let mut serial = vec![0u64; a.len()];
            let mut parallel = vec![0u64; a.len()];
            unit.run_batch(&a, lb, lc, &mut serial).unwrap();
            unit.run_batch_parallel(&a, lb, lc, &mut parallel, 4).unwrap();
            assert_eq!(serial, parallel, "{op}");
        }
    }

    #[test]
    fn division_metadata_matches_free_functions() {
        for n in [8u32, 16, 32, 64] {
            for alg in Algorithm::TABLE_IV {
                let unit = Unit::new(n, Op::Div { alg }).unwrap();
                assert_eq!(unit.iterations(), iterations(n, alg.radix().unwrap()));
                assert_eq!(unit.latency_cycles(), latency_cycles(n, alg));
                assert_eq!(unit.width(), n);
                assert_eq!(unit.algorithm(), Some(alg));
                assert_eq!(unit.op(), Op::Div { alg });
            }
        }
        let sqrt = Unit::new(16, Op::Sqrt).unwrap();
        assert_eq!(sqrt.iterations(), SqrtEngine::new().iterations(16));
        assert_eq!(sqrt.latency_cycles(), sqrt.iterations() + exec::SPECIAL_CYCLES);
        assert_eq!(sqrt.algorithm(), None);
        assert!(sqrt.as_div_engine().is_none());
    }

    #[test]
    fn sqrt_metadata_and_specials() {
        let unit = Unit::new(16, Op::Sqrt).unwrap();
        let real = unit.run(&[Posit::from_f64(16, 2.25)]).unwrap();
        assert_eq!(real.result.to_f64(), 1.5);
        assert_eq!(real.iterations, unit.iterations());
        assert_eq!(real.cycles, unit.latency_cycles());
        let nar = unit.run(&[Posit::one(16).neg()]).unwrap();
        assert!(nar.result.is_nar());
        assert_eq!(nar.iterations, 0);
        assert_eq!(nar.cycles, exec::SPECIAL_CYCLES);
    }

    #[test]
    fn div_unit_is_a_div_engine() {
        let unit = Unit::new(16, Op::Div { alg: Algorithm::Srt4CsOfFr }).unwrap();
        let e = unit.as_div_engine().expect("division unit");
        assert_eq!(e.name(), "SRT r4 CS OF FR");
        assert_eq!(e.algorithm(), Algorithm::Srt4CsOfFr);
        assert_eq!(e.divide(Posit::one(16), Posit::one(16)).result, Posit::one(16));
        assert_eq!(unit.engine_name(), "SRT r4 CS OF FR");
    }

    #[test]
    fn exec_tier_parse_and_names() {
        assert_eq!(ExecTier::parse("fast"), Some(ExecTier::Fast));
        assert_eq!(ExecTier::parse("DATAPATH"), Some(ExecTier::Datapath));
        assert_eq!(ExecTier::parse("Auto"), Some(ExecTier::Auto));
        assert_eq!(ExecTier::parse("approx"), Some(ExecTier::Approx));
        assert_eq!(ExecTier::parse("warp"), None);
        assert_eq!(ExecTier::Fast.name(), "fast");
        assert_eq!(ExecTier::Approx.name(), "approx");
        assert_eq!(ExecTier::Datapath.to_string(), "datapath");
        assert_eq!(ExecTier::default(), ExecTier::Auto);
    }

    #[test]
    fn accuracy_parse_labels_and_routing() {
        assert_eq!(Accuracy::parse("exact"), Some(Accuracy::Exact));
        assert_eq!(Accuracy::parse("ULP:4"), Some(Accuracy::Ulp(4)));
        assert_eq!(Accuracy::parse("ulp:0"), Some(Accuracy::Ulp(0)));
        assert_eq!(Accuracy::parse("ulp:"), None);
        assert_eq!(Accuracy::parse("ulp:x"), None);
        assert_eq!(Accuracy::parse("loose"), None);
        assert_eq!(Accuracy::default(), Accuracy::Exact);
        assert_eq!(Accuracy::Ulp(4).to_string(), "ulp:4");
        assert_eq!(Accuracy::parse(&Accuracy::Ulp(9).label()), Some(Accuracy::Ulp(9)));

        // Exact never routes approx; Ulp(k) routes iff a registered spec
        // satisfies k.
        assert!(!Op::DIV.routes_approx(16, Accuracy::Exact));
        assert!(Op::DIV.routes_approx(16, Accuracy::Ulp(4)));
        assert!(!Op::DIV.routes_approx(16, Accuracy::Ulp(3)));
        assert!(Op::Sqrt.routes_approx(8, Accuracy::Ulp(1)));
        assert!(Op::Mul.routes_approx(32, Accuracy::Ulp(10_000)));
        // no registered kernel → never eligible, however loose the policy
        assert!(!Op::Add.routes_approx(16, Accuracy::Ulp(u32::MAX)));
        assert!(!Op::Dot.routes_approx(16, Accuracy::Ulp(u32::MAX)));
        assert!(!Op::DIV.routes_approx(24, Accuracy::Ulp(u32::MAX)));
        // spec metadata round-trips through the Op surface
        let spec = Op::DIV.approx_spec(32).unwrap();
        assert_eq!((spec.n, spec.max_ulp), (32, 4096));
        assert_eq!(Op::FusedSum.approx_spec(16), None);

        // brown-out degradation: any Ulp(k) with a registered kernel is
        // force-eligible, even when k is below the declared bound; Exact
        // and kernel-less ops never are.
        assert!(Op::DIV.degrades_approx(16, Accuracy::Ulp(1)));
        assert!(Op::DIV.degrades_approx(16, Accuracy::Ulp(u32::MAX)));
        assert!(!Op::DIV.degrades_approx(16, Accuracy::Exact));
        assert!(!Op::Add.degrades_approx(16, Accuracy::Ulp(u32::MAX)));
        assert!(!Op::Dot.degrades_approx(16, Accuracy::Ulp(u32::MAX)));
        assert!(!Op::DIV.degrades_approx(24, Accuracy::Ulp(u32::MAX)));
    }

    #[test]
    fn deadline_budget_on_requests() {
        let one = Posit::one(16);
        let req = OpRequest::div(one, one);
        assert_eq!(req.deadline_ms(), 0);
        assert_eq!(req.deadline(), None);
        let req = req.with_deadline_ms(250);
        assert_eq!(req.deadline_ms(), 250);
        assert_eq!(req.deadline(), Some(core::time::Duration::from_millis(250)));
        // builder order does not matter and accuracy is preserved
        let req = OpRequest::sqrt(one)
            .with_deadline_ms(5)
            .with_accuracy(Accuracy::Ulp(3));
        assert_eq!((req.deadline_ms(), req.accuracy()), (5, Accuracy::Ulp(3)));
    }

    #[test]
    fn auto_tier_resolution() {
        let unit = Unit::new(16, Op::DIV).unwrap();
        assert_eq!(unit.tier(), ExecTier::Auto);
        assert_eq!(unit.batch_tier(), ExecTier::Fast);
        assert_eq!(unit.scalar_tier(), ExecTier::Datapath);
        let fast = Unit::with_tier(16, Op::DIV, ExecTier::Fast).unwrap();
        assert_eq!((fast.batch_tier(), fast.scalar_tier()), (ExecTier::Fast, ExecTier::Fast));
        let dp = Unit::with_tier(16, Op::DIV, ExecTier::Datapath).unwrap();
        assert_eq!((dp.batch_tier(), dp.scalar_tier()), (ExecTier::Datapath, ExecTier::Datapath));
        let ap = Unit::with_tier(16, Op::DIV, ExecTier::Approx).unwrap();
        assert_eq!((ap.batch_tier(), ap.scalar_tier()), (ExecTier::Approx, ExecTier::Approx));
        assert_eq!(ap.resolve_fast_path(256), None);
        assert_eq!(
            Unit::with_tier(3, Op::DIV, ExecTier::Fast).err(),
            Some(PositError::WidthOutOfRange { n: 3 })
        );
    }

    #[test]
    fn fast_scalar_metadata_matches_datapath() {
        let mut rng = Rng::seeded(0x7137);
        let ops = [
            Op::DIV,
            Op::Div { alg: Algorithm::Nrd },
            Op::Div { alg: Algorithm::NrdAsap23 },
            Op::Div { alg: Algorithm::Newton },
            Op::Sqrt,
            Op::Mul,
            Op::Add,
            Op::Sub,
            Op::MulAdd,
        ];
        for n in [8u32, 16, 32] {
            for op in ops {
                let fast = Unit::with_tier(n, op, ExecTier::Fast).unwrap();
                let dp = Unit::with_tier(n, op, ExecTier::Datapath).unwrap();
                let mut cases: Vec<Vec<Posit>> = (0..60)
                    .map(|_| {
                        (0..op.arity())
                            .map(|_| Posit::from_bits(n, rng.next_u64() & mask(n)))
                            .collect()
                    })
                    .collect();
                // directed specials in every operand slot
                for s in [Posit::zero(n), Posit::nar(n), Posit::one(n).neg()] {
                    for slot in 0..op.arity() {
                        let mut ops_v = vec![Posit::one(n); op.arity()];
                        ops_v[slot] = s;
                        cases.push(ops_v);
                    }
                }
                for operands in cases {
                    let f = fast.run(&operands).unwrap();
                    let d = dp.run(&operands).unwrap();
                    assert_eq!(
                        (f.result, f.iterations, f.cycles),
                        (d.result, d.iterations, d.cycles),
                        "{op} n={n} operands={operands:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn auto_fast_path_dispatch_order() {
        // table > vector > SWAR > scalar-fast, by width and batch length
        let div8 = Unit::new(8, Op::DIV).unwrap();
        assert_eq!(div8.fast_path(), FastPath::Auto);
        assert_eq!(div8.resolve_fast_path(256), Some(FastPath::Table));
        assert_eq!(div8.resolve_fast_path(2), Some(FastPath::Scalar));
        // ternary op has no table or vector kernel: SWAR is next in line
        let fma8 = Unit::new(8, Op::MulAdd).unwrap();
        assert_eq!(fma8.resolve_fast_path(256), Some(FastPath::Simd));
        assert_eq!(fma8.resolve_fast_path(4), Some(FastPath::Scalar));
        // Posit16 division has a seed table: constant-time above the
        // (small) table threshold, scalar below
        let div16 = Unit::new(16, Op::DIV).unwrap();
        assert_eq!(div16.resolve_fast_path(256), Some(FastPath::Table));
        assert_eq!(div16.resolve_fast_path(8), Some(FastPath::Table));
        assert_eq!(div16.resolve_fast_path(2), Some(FastPath::Scalar));
        // Posit16 mul has no table: the explicit vector kernel serves it
        // when the ISA is detected, SWAR otherwise
        let mul16 = Unit::new(16, Op::Mul).unwrap();
        let big = if crate::division::vector::available() {
            FastPath::Vector
        } else {
            FastPath::Simd
        };
        assert_eq!(mul16.resolve_fast_path(256), Some(big));
        assert_eq!(mul16.resolve_fast_path(fastpath::SIMD_MIN_LANES), Some(FastPath::Simd));
        assert_eq!(mul16.resolve_fast_path(8), Some(FastPath::Scalar));
        // wide formats stay scalar at any length
        let div32 = Unit::new(32, Op::DIV).unwrap();
        assert_eq!(div32.resolve_fast_path(1 << 20), Some(FastPath::Scalar));
        // datapath-pinned units have no fast path to resolve
        let dp = Unit::with_tier(16, Op::DIV, ExecTier::Datapath).unwrap();
        assert_eq!(dp.resolve_fast_path(256), None);
    }

    #[test]
    fn with_exec_rejects_unsupported_paths() {
        // Posit16 mul has no table (only div/sqrt carry seed tables)
        assert_eq!(
            Unit::with_exec(16, Op::Mul, ExecTier::Fast, FastPath::Table).err(),
            Some(PositError::UnsupportedFastPath { path: "table", op: "mul", n: 16 })
        );
        assert_eq!(
            Unit::with_exec(8, Op::MulAdd, ExecTier::Fast, FastPath::Table).err(),
            Some(PositError::UnsupportedFastPath { path: "table", op: "mul_add", n: 8 })
        );
        assert_eq!(
            Unit::with_exec(32, Op::DIV, ExecTier::Fast, FastPath::Simd).err(),
            Some(PositError::UnsupportedFastPath { path: "simd", op: "div", n: 32 })
        );
        // the vector kernels never serve sqrt or wide formats, detected
        // ISA or not
        assert_eq!(
            Unit::with_exec(16, Op::Sqrt, ExecTier::Fast, FastPath::Vector).err(),
            Some(PositError::UnsupportedFastPath { path: "vector", op: "sqrt", n: 16 })
        );
        assert_eq!(
            Unit::with_exec(32, Op::DIV, ExecTier::Fast, FastPath::Vector).err(),
            Some(PositError::UnsupportedFastPath { path: "vector", op: "div", n: 32 })
        );
        // forcing Vector at a supported (n, op) succeeds exactly when the
        // ISA is detected under the `vsimd` feature
        let forced_vec = Unit::with_exec(16, Op::DIV, ExecTier::Fast, FastPath::Vector);
        assert_eq!(forced_vec.is_ok(), crate::division::vector::available());
        // a Datapath-pinned unit never consults the fast path: forcing
        // one is rejected instead of silently serving from the datapath
        assert_eq!(
            Unit::with_exec(8, Op::DIV, ExecTier::Datapath, FastPath::Table).err(),
            Some(PositError::UnsupportedFastPath { path: "table", op: "div", n: 8 })
        );
        assert!(Unit::with_exec(16, Op::DIV, ExecTier::Datapath, FastPath::Auto).is_ok());
        // the Approx tier never consults the fast-path layer either
        assert_eq!(
            Unit::with_exec(8, Op::DIV, ExecTier::Approx, FastPath::Table).err(),
            Some(PositError::UnsupportedFastPath { path: "table", op: "div", n: 8 })
        );
        // ...and serves only the (op, width) grid with declared specs
        assert_eq!(
            Unit::with_tier(16, Op::Add, ExecTier::Approx).err(),
            Some(PositError::UnsupportedApprox { op: "add", n: 16 })
        );
        assert_eq!(
            Unit::with_tier(64, Op::DIV, ExecTier::Approx).err(),
            Some(PositError::UnsupportedApprox { op: "div", n: 64 })
        );
        assert_eq!(
            Unit::with_tier(16, Op::Dot, ExecTier::Approx).err(),
            Some(PositError::UnsupportedApprox { op: "dot", n: 16 })
        );
        assert!(Unit::with_tier(32, Op::Sqrt, ExecTier::Approx).is_ok());
        // supported combinations build and resolve to the forced kernel
        let t = Unit::with_exec(8, Op::DIV, ExecTier::Fast, FastPath::Table).unwrap();
        assert_eq!((t.fast_path(), t.resolve_fast_path(1)), (FastPath::Table, Some(FastPath::Table)));
        let s = Unit::with_exec(16, Op::Sqrt, ExecTier::Fast, FastPath::Simd).unwrap();
        assert_eq!(s.resolve_fast_path(1), Some(FastPath::Simd));
    }

    /// Every forced fast path serves bit-identically through the Unit
    /// batch entry point.
    #[test]
    fn forced_paths_are_bit_identical_through_unit() {
        let mut rng = Rng::seeded(0xFA7);
        for n in [8u32, 16] {
            for op in Op::DEFAULTS {
                let a: Vec<u64> = (0..100).map(|_| rng.next_u64() & mask(n)).collect();
                let b: Vec<u64> = (0..100).map(|_| rng.next_u64() & mask(n)).collect();
                let c: Vec<u64> = (0..100).map(|_| rng.next_u64() & mask(n)).collect();
                let (lb, lc): (&[u64], &[u64]) = match op.arity() {
                    1 => (&[], &[]),
                    2 => (&b, &[]),
                    _ => (&b, &c),
                };
                let scalar =
                    Unit::with_exec(n, op, ExecTier::Fast, FastPath::Scalar).unwrap();
                let mut want = vec![0u64; a.len()];
                scalar.run_batch(&a, lb, lc, &mut want).unwrap();
                for path in
                    [FastPath::Table, FastPath::Vector, FastPath::Simd, FastPath::Auto]
                {
                    // unsupported (n, op, path) combinations — including
                    // Vector on hosts without a detected ISA — skip
                    let Ok(unit) = Unit::with_exec(n, op, ExecTier::Fast, path) else {
                        continue;
                    };
                    let mut got = vec![0u64; a.len()];
                    unit.run_batch(&a, lb, lc, &mut got).unwrap();
                    assert_eq!(got, want, "{op} n={n} {path:?}");
                }
            }
        }
    }

    #[test]
    fn parallel_chunk_heuristic_scales_with_cost() {
        // cheap fast-tier lanes: small batches collapse to one chunk
        let fast = Unit::with_tier(16, Op::DIV, ExecTier::Fast).unwrap();
        let len = 1000;
        assert!(fast.parallel_chunk(len, 8) >= len, "small cheap batch must not fan out");
        // the datapath is ~an order of magnitude costlier per lane: the
        // same batch splits into real chunks
        let dp = Unit::with_tier(16, Op::DIV, ExecTier::Datapath).unwrap();
        let chunk = dp.parallel_chunk(10_000, 8);
        assert!(chunk < 10_000, "expensive lanes must fan out, got {chunk}");
        assert!(chunk >= 10_000 / 8, "never smaller than the even split");
        // huge batches reach the even split on any tier
        assert_eq!(fast.parallel_chunk(8_000_000, 8), 1_000_000);
        // block-kernel batches (SWAR / vector) round the chunk up to the
        // 64-lane block so chunk boundaries land on block boundaries:
        // the even split 1_000_000/8 = 125_000 is not a block multiple
        let mul16 = Unit::with_tier(16, Op::Mul, ExecTier::Fast).unwrap();
        let chunk = mul16.parallel_chunk(1_000_000, 8);
        assert_eq!(chunk, 125_056, "even split 125_000 rounds up to the next block");
        assert_eq!(chunk % fastpath::LANE_BLOCK, 0);
        // and the parallel entry point stays bit-identical either way
        let mut rng = Rng::seeded(0xC43);
        let a: Vec<u64> = (0..30_000).map(|_| rng.next_u64() & mask(16)).collect();
        let b: Vec<u64> = (0..30_000).map(|_| rng.next_u64() & mask(16)).collect();
        let mut serial = vec![0u64; a.len()];
        let mut parallel = vec![0u64; a.len()];
        dp.run_batch(&a, &b, &[], &mut serial).unwrap();
        dp.run_batch_parallel(&a, &b, &[], &mut parallel, 4).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn op_request_model() {
        let r = OpRequest::div(Posit::one(16), Posit::one(16));
        assert_eq!(r.op, Op::DIV);
        assert_eq!(r.operands().len(), 2);
        assert_eq!(r.width(), 16);
        assert_eq!(r.bits(), [Posit::one(16).to_bits(), Posit::one(16).to_bits(), 0]);
        let s = OpRequest::sqrt(Posit::from_f64(32, 2.0));
        assert_eq!(s.operands().len(), 1);
        assert_eq!(
            OpRequest::new(Op::Sqrt, &[Posit::one(16), Posit::one(16)]).err(),
            Some(PositError::ArityMismatch { op: "sqrt", expected: 1, got: 2 })
        );
        assert_eq!(
            OpRequest::new(Op::Mul, &[Posit::one(16), Posit::one(32)]).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 32 })
        );
        let ok = OpRequest::new(Op::MulAdd, &[Posit::one(8); 3]).unwrap();
        assert_eq!(ok.operands(), &[Posit::one(8); 3]);
        // accuracy policy: Exact by default, carried by the builder,
        // preserved across clones and equality
        assert_eq!(r.accuracy(), Accuracy::Exact);
        let loose = r.clone().with_accuracy(Accuracy::Ulp(4));
        assert_eq!(loose.accuracy(), Accuracy::Ulp(4));
        assert_eq!(loose.operands(), r.operands());
        assert_ne!(loose, r);
        let red = OpRequest::dot(&[Posit::one(16)], &[Posit::one(16)])
            .unwrap()
            .with_accuracy(Accuracy::Ulp(8));
        assert_eq!(red.accuracy(), Accuracy::Ulp(8));
    }

    /// The Approx tier stays within its declared ulp contracts through
    /// the Unit surface (scalar, bit-level and batch entry points agree),
    /// specials are bit-exact, and the modeled metadata is single-pass.
    #[test]
    fn approx_tier_through_unit_surface() {
        let mut rng = Rng::seeded(0xA9_0C);
        for n in [8u32, 16, 32] {
            for op in [Op::DIV, Op::Sqrt, Op::Mul] {
                let unit = Unit::with_tier(n, op, ExecTier::Approx).unwrap();
                let spec = op.approx_spec(n).unwrap();
                let lanes = 257;
                let a: Vec<u64> = (0..lanes).map(|_| rng.next_u64() & mask(n)).collect();
                let b: Vec<u64> = if op.arity() == 2 {
                    (0..lanes).map(|_| rng.next_u64() & mask(n)).collect()
                } else {
                    Vec::new()
                };
                let mut out = vec![0u64; lanes];
                unit.run_batch(&a, &b, &[], &mut out).unwrap();
                for i in 0..lanes {
                    let bi = if b.is_empty() { 0 } else { b[i] };
                    // batch == scalar bit path
                    assert_eq!(out[i], unit.run_bits(a[i], bi, 0), "{op} n={n} lane {i}");
                    // within the declared contract against the golden
                    let operands: Vec<Posit> = (0..op.arity())
                        .map(|j| Posit::from_bits(n, if j == 0 { a[i] } else { bi }))
                        .collect();
                    let req = OpRequest::new(op, &operands).unwrap();
                    let golden = req.golden();
                    let got = Posit::from_bits(n, out[i]);
                    assert!(
                        got.ulp_distance(golden) <= spec.max_ulp,
                        "{op} n={n}: |{got:?} - {golden:?}| > {} ulp",
                        spec.max_ulp
                    );
                }
                // scalar entry point: within contract, modeled metadata
                let one = Posit::one(n);
                let operands = vec![one; op.arity()];
                let d = unit.run(&operands).unwrap();
                assert!(d.result.ulp_distance(one) <= spec.max_ulp, "{op} n={n} at 1");
                assert_eq!(d.cycles, ARITH_CYCLES);
                // specials bypass the approx kernel bit-exactly
                let nar = vec![Posit::nar(n); op.arity()];
                let d = unit.run(&nar).unwrap();
                assert_eq!(d.result, Posit::nar(n));
                assert_eq!((d.iterations, d.cycles), (0, exec::SPECIAL_CYCLES));
            }
        }
    }

    /// Exact-policy traffic through an Approx-capable op still matches
    /// the Datapath bit-for-bit when served by the exact tiers — the
    /// routing predicate is what keeps them apart.
    #[test]
    fn approx_batches_run_in_parallel_too() {
        let n = 16;
        let unit = Unit::with_tier(n, Op::DIV, ExecTier::Approx).unwrap();
        let mut rng = Rng::seeded(0x9A11);
        let len = 4096;
        let a: Vec<u64> = (0..len).map(|_| rng.next_u64() & mask(n)).collect();
        let b: Vec<u64> = (0..len).map(|_| rng.next_u64() & mask(n)).collect();
        let mut seq = vec![0u64; len];
        let mut par = vec![0u64; len];
        unit.run_batch(&a, &b, &[], &mut seq).unwrap();
        unit.run_batch_parallel(&a, &b, &[], &mut par, 4).unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn reduction_op_metadata() {
        assert_eq!(Op::REDUCTIONS.len(), 3);
        assert_eq!(Op::Dot.arity(), 2);
        assert_eq!(Op::FusedSum.arity(), 1);
        assert_eq!(Op::Axpy.arity(), 3);
        assert_eq!(Op::Dot.name(), "dot");
        assert_eq!(Op::FusedSum.name(), "fsum");
        assert_eq!(Op::Axpy.name(), "axpy");
        assert_eq!(Op::Axpy.label(), "axpy");
        assert_eq!(Op::Dot.to_string(), "dot");
        for op in Op::REDUCTIONS {
            assert!(op.is_reduction());
        }
        for op in Op::DEFAULTS {
            assert!(!op.is_reduction());
        }
        let unit = Unit::new(16, Op::Dot).unwrap();
        assert_eq!(unit.engine_name(), "quire");
        assert_eq!(unit.algorithm(), None);
        assert!(unit.as_div_engine().is_none());
    }

    /// Satellite regression: the vector constructors report typed shape
    /// errors — mismatched `Dot` lanes are a `BatchLaneMismatch`, not an
    /// arity error, and `OpRequest::new` keeps covering the reductions
    /// through the singleton convention.
    #[test]
    fn reduction_request_model_and_shape_errors() {
        let n = 16;
        let one = Posit::one(n);
        let two = Posit::from_f64(n, 2.0);
        assert_eq!(
            OpRequest::dot(&[one, two], &[one]).err(),
            Some(PositError::BatchLaneMismatch { lane: "b", expected: 2, got: 1 })
        );
        assert_eq!(
            OpRequest::dot(&[], &[]).err(),
            Some(PositError::BatchLaneMismatch { lane: "a", expected: 1, got: 0 })
        );
        assert_eq!(
            OpRequest::fused_sum(&[]).err(),
            Some(PositError::BatchLaneMismatch { lane: "a", expected: 1, got: 0 })
        );
        assert_eq!(
            OpRequest::axpy(one, &[one], &[one, two]).err(),
            Some(PositError::BatchLaneMismatch { lane: "b", expected: 1, got: 2 })
        );
        assert_eq!(
            OpRequest::dot(&[one], &[Posit::one(8)]).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 8 })
        );
        assert_eq!(
            OpRequest::new(Op::Dot, &[one]).err(),
            Some(PositError::ArityMismatch { op: "dot", expected: 2, got: 1 })
        );
        let r = OpRequest::dot(&[one, two], &[two, one]).unwrap();
        assert_eq!(r.op, Op::Dot);
        assert_eq!(r.width(), n);
        assert!(r.operands().is_empty(), "reductions have no scalar slots");
        let (a, b, _) = r.vector_lanes().unwrap();
        assert_eq!((a.len(), b.len()), (2, 2));
        assert_eq!(r.bits(), [0, 0, 0]);
        let ax = OpRequest::axpy(two, &[one], &[one]).unwrap();
        assert_eq!(ax.bits(), [0, 0, two.to_bits()]);
        assert_eq!(ax.vector_lanes().unwrap().2, two);
        // singleton convention through `new`
        let single = OpRequest::new(Op::Dot, &[one, two]).unwrap();
        assert_eq!(single.golden(), one.mul(two));
    }

    #[test]
    fn reduction_batches_match_rational_golden_on_both_tiers() {
        let mut rng = Rng::seeded(0xD0717);
        for n in [8u32, 16, 32] {
            for op in Op::REDUCTIONS {
                for tier in [ExecTier::Datapath, ExecTier::Fast, ExecTier::Auto] {
                    let unit = Unit::with_tier(n, op, tier).unwrap();
                    for _ in 0..24 {
                        let k = 1 + rng.below(9) as usize;
                        let a: Vec<u64> = (0..k).map(|_| rng.next_u64() & mask(n)).collect();
                        let b: Vec<u64> = (0..k).map(|_| rng.next_u64() & mask(n)).collect();
                        let alpha = [rng.next_u64() & mask(n)];
                        let (lb, lc): (&[u64], &[u64]) = match op {
                            Op::Dot => (&b, &[]),
                            Op::FusedSum => (&[], &[]),
                            _ => (&b, &alpha),
                        };
                        let mut out = [0u64];
                        unit.run_batch(&a, lb, lc, &mut out).unwrap();
                        let pv = |bits: &[u64]| -> Vec<Posit> {
                            bits.iter().map(|&x| Posit::from_bits(n, x)).collect()
                        };
                        let want = match op {
                            Op::Dot => rational::dot(&pv(&a), &pv(&b)),
                            Op::FusedSum => rational::fused_sum(&pv(&a)),
                            _ => rational::axpy(Posit::from_bits(n, alpha[0]), &pv(&a), &pv(&b)),
                        };
                        assert_eq!(out[0], want.to_bits(), "{op} n={n} {tier:?} k={k}");
                        // parallel entry point serves reductions inline
                        let mut par = [0u64];
                        unit.run_batch_parallel(&a, lb, lc, &mut par, 4).unwrap();
                        assert_eq!(par, out, "{op} n={n} {tier:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn reduction_batch_lane_checks_and_scalar_run() {
        let dot = Unit::new(16, Op::Dot).unwrap();
        let mut out = [0u64];
        assert_eq!(
            dot.run_batch(&[1, 2], &[1], &[], &mut out).err(),
            Some(PositError::BatchLaneMismatch { lane: "b", expected: 2, got: 1 })
        );
        assert_eq!(
            dot.run_batch(&[], &[], &[], &mut out).err(),
            Some(PositError::BatchLaneMismatch { lane: "a", expected: 1, got: 0 })
        );
        let mut wide = [0u64; 2];
        assert!(matches!(
            dot.run_batch(&[1, 2], &[1, 2], &[], &mut wide).err(),
            Some(PositError::BatchShapeMismatch { out: 2, .. })
        ));
        let axpy = Unit::new(16, Op::Axpy).unwrap();
        assert_eq!(
            axpy.run_batch(&[1], &[1], &[], &mut out).err(),
            Some(PositError::BatchLaneMismatch { lane: "c", expected: 1, got: 0 })
        );
        // forced table/SWAR kernels have nothing to serve reductions
        assert_eq!(
            Unit::with_exec(8, Op::Dot, ExecTier::Fast, FastPath::Table).err(),
            Some(PositError::UnsupportedFastPath { path: "table", op: "dot", n: 8 })
        );
        assert_eq!(
            Unit::with_exec(16, Op::FusedSum, ExecTier::Fast, FastPath::Simd).err(),
            Some(PositError::UnsupportedFastPath { path: "simd", op: "fsum", n: 16 })
        );
        assert_eq!(
            Unit::with_exec(16, Op::Dot, ExecTier::Fast, FastPath::Vector).err(),
            Some(PositError::UnsupportedFastPath { path: "vector", op: "dot", n: 16 })
        );
        assert_eq!(dot.resolve_fast_path(1 << 12), Some(FastPath::Scalar));
        // scalar run: the single-element reduction with flat metadata
        let one = Posit::one(16);
        let two = Posit::from_f64(16, 2.0);
        let r = dot.run(&[two, two]).unwrap();
        assert_eq!(r.result, two.mul(two));
        assert_eq!((r.iterations, r.cycles), (0, dot.latency_cycles()));
        assert_eq!(
            dot.run(&[one]).err(),
            Some(PositError::ArityMismatch { op: "dot", expected: 2, got: 1 })
        );
        let fsum = Unit::new(16, Op::FusedSum).unwrap();
        assert_eq!(fsum.run(&[two]).unwrap().result, two);
        let ax = Unit::new(16, Op::Axpy).unwrap();
        assert_eq!(ax.run(&[two, one, two]).unwrap().result, two.mul_add(two, one));
    }
}
