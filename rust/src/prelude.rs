//! One-stop import for the public API.
//!
//! ```
//! use posit_div::prelude::*;
//!
//! // typed posits with operators (division and sqrt route through the
//! // paper's digit-recurrence engines)
//! let q = P32::round_from(355.0) / P32::round_from(113.0);
//! assert!((q.to_f64() - 355.0 / 113.0).abs() < 1e-6);
//! assert_eq!(P32::round_from(9.0).sqrt().to_f64(), 3.0);
//!
//! // an operation-generic, zero-alloc unit with a batch-first API
//! let sqrt = Unit::new(16, Op::Sqrt)?;
//! let mut out = [0u64; 2];
//! sqrt.run_batch(&[P16::round_from(9.0).to_bits(); 2], &[], &[], &mut out)?;
//! assert_eq!(out, [P16::round_from(3.0).to_bits(); 2]);
//! # Ok::<(), posit_div::PositError>(())
//! ```

pub use crate::coordinator::{
    Backend, BatchHandle, BatchPolicy, Client, DivisionService, Histogram, LatencyPanel, Metrics,
    Pending, ServedBy, ServiceConfig, UnitService,
};
// Deprecated division-only wrapper; prefer `Unit` (see the crate docs).
#[allow(deprecated)]
pub use crate::division::Divider;
pub use crate::division::sqrt::{golden_sqrt, SqrtEngine, SqrtResult};
pub use crate::division::{Algorithm, DivEngine, Division};
pub use crate::error::{PositError, Result};
pub use crate::pool::Pool;
pub use crate::posit::{Posit, RoundFrom, RoundInto, P16, P32, P64, P8};
pub use crate::quire::{axpy, dot, fused_sum, gemm, Quire};
pub use crate::service::{
    shard_for, BreakerConfig, ConnectOptions, FaultNet, FaultPlan, OpenLoopReport,
    ResilientClient, ResilientReport, RetryPolicy, Server, ServiceClient, ShardConfig,
    ShardTicket, ShardedClient, ShardedService,
};
pub use crate::division::approx::ApproxSpec;
pub use crate::unit::{Accuracy, ExecTier, FastPath, Op, OpRequest, Unit};
pub use crate::workload::{MixedOps, OpMix, OpenLoop};
