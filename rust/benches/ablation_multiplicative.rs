//! Ablation C2: digit recurrence vs multiplicative (Newton–Raphson)
//! division — the [16] energy-efficiency claim the paper builds on, from
//! the hardware model, plus measured software throughput.

use posit_div::bench::{bench_batched, black_box, Config, Runner};
use posit_div::division::{Algorithm, DivEngine, Divider};
use posit_div::hardware::{combinational, pipelined, TSMC28};
use posit_div::posit::mask;
use posit_div::testkit::Rng;

fn main() {
    println!("digit recurrence (SRT r4 CS OF FR) vs multiplicative (Newton-Raphson)\n");
    println!(
        "{:<8} {:<14} {:>12} {:>10} {:>12} {:>12}",
        "format", "design", "area[µm²]", "delay[ns]", "power[mW]", "energy[pJ]"
    );
    for n in [16u32, 32, 64] {
        for (label, alg) in
            [("SRT r4", Algorithm::Srt4CsOfFr), ("Newton", Algorithm::Newton)]
        {
            let c = combinational(alg, n, &TSMC28);
            println!(
                "Posit{:<3} {:<14} {:>12.0} {:>10.2} {:>12.3} {:>12.2}",
                n, format!("{label} comb"), c.area_um2, c.delay_ns, c.power_mw, c.energy_pj
            );
            let p = pipelined(alg, n, &TSMC28);
            println!(
                "Posit{:<3} {:<14} {:>12.0} {:>10.2} {:>12.3} {:>12.2}{}",
                n,
                format!("{label} pipe"),
                p.area_um2,
                p.delay_ns,
                p.power_mw,
                p.energy_pj,
                if p.timing_met { "" } else { " (!timing)" }
            );
        }
    }

    let mut runner = Runner::new("software throughput");
    let mut rng = Rng::seeded(16);
    for n in [16u32, 32, 64] {
        let xs: Vec<u64> = (0..256).map(|_| rng.next_u64() & mask(n)).collect();
        let ds: Vec<u64> = (0..256).map(|_| (rng.next_u64() & mask(n)) | 1).collect();
        let mut out = vec![0u64; xs.len()];
        for alg in [Algorithm::Srt4CsOfFr, Algorithm::Newton] {
            let ctx = Divider::new(n, alg).expect("width");
            runner.add(bench_batched(
                &format!("Posit{n} {}", ctx.name()),
                Config::default(),
                xs.len() as u64,
                || {
                    ctx.divide_batch(&xs, &ds, &mut out).expect("equal lengths");
                    black_box(&out);
                },
            ));
        }
    }
    runner.finish();
}
