//! Shared entry point for every `harness = false` bench target and for
//! the `posit-div bench` subcommand: flag parsing, profile selection,
//! structured-report emission, baseline comparison and the regression
//! gate. One suite body in [`super::suites`] therefore runs identically
//! under `cargo bench --bench <suite> -- <flags>` and
//! `posit-div bench <suite> <flags>`.
//!
//! Flags:
//!
//! * `--profile quick|full` — timing profile (default: `$POSIT_BENCH_PROFILE`,
//!   then `full`). `--quick` / `--full` are shorthands. Profiles change
//!   only timing budgets, never the row set, so any profile can be
//!   compared against any baseline.
//! * `--json <path>` — also write the structured report to `<path>`.
//! * `--baseline <path>` — compare against this report instead of the
//!   default `BENCH_<suite>.json`.
//! * `--write-baseline` — record the run as the new baseline and exit.
//! * `--threshold <pct>` — regression threshold on ops/sec (default 15,
//!   or `$POSIT_BENCH_THRESHOLD`).
//! * `--advisory` — print the verdict but always exit 0 (also
//!   `$POSIT_BENCH_ADVISORY=1`; forced when the baseline is provisional).

use std::path::{Path, PathBuf};

use super::baseline::Comparison;
use super::report::Report;
use super::{suites, Config, Profile, Runner};
use crate::cli::Args;
use crate::unit::{ExecTier, FastPath};

/// Parsed bench-harness options for one suite run.
pub struct BenchCli {
    pub suite: &'static str,
    pub profile: Profile,
    /// Timing configuration derived from the profile.
    pub cfg: Config,
    /// `--tier fast|datapath|approx|auto` — restricts tier-aware suites
    /// (`unit_throughput`) to one execution tier. `None`/`auto` runs the
    /// full tier-tagged row set; note that unlike profiles, an explicit
    /// single-tier run *does* shrink the row set (the baseline compare
    /// treats the missing rows as removed, which never fails).
    pub tier: Option<ExecTier>,
    /// `--path auto|table|vector|simd|scalar` — restricts the tier-aware
    /// suites' forced fast-kernel rows to one [`FastPath`] (and pins the
    /// kernel on `posit-div divide`). `None`/`auto` keeps the full
    /// forced-path row set; like `--tier`, a pinned run shrinks the row
    /// set, which the baseline compare treats as removed rows.
    pub path: Option<FastPath>,
    json_out: Option<PathBuf>,
    baseline: Option<PathBuf>,
    write_baseline: bool,
    threshold_pct: f64,
    advisory: bool,
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).is_ok_and(|v| v == "1" || v.eq_ignore_ascii_case("true"))
}

/// Default regression threshold: `$POSIT_BENCH_THRESHOLD`, then 15%.
fn default_threshold() -> f64 {
    std::env::var("POSIT_BENCH_THRESHOLD")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(15.0)
}

/// The shared gate epilogue: exit code for a rendered comparison
/// (regressions fail unless the run is advisory or the baseline is
/// provisional). Used identically by the post-suite gate and `bench
/// compare` so the two can never drift apart.
fn gate_verdict(cmp: &Comparison, advisory: bool) -> i32 {
    if cmp.passed() {
        0
    } else if advisory || cmp.baseline_provisional {
        println!("regression gate: advisory — not failing this run");
        0
    } else {
        1
    }
}

impl BenchCli {
    pub fn from_args(suite: &'static str, args: &Args) -> BenchCli {
        let profile = if args.has("full") {
            Profile::Full
        } else if args.has("quick") {
            Profile::Quick
        } else if let Some(p) = args.flag("profile") {
            Profile::parse(p).unwrap_or_else(|| {
                eprintln!("invalid --profile {p:?} (expected quick|full)");
                std::process::exit(2);
            })
        } else {
            Profile::from_env().unwrap_or(Profile::Full)
        };
        BenchCli {
            suite,
            profile,
            cfg: profile.config(),
            tier: args.flag("tier").map(|t| {
                ExecTier::parse(t).unwrap_or_else(|| {
                    eprintln!("invalid --tier {t:?} (expected fast|datapath|approx|auto)");
                    std::process::exit(2);
                })
            }),
            path: args.flag("path").map(|p| {
                FastPath::parse(p).unwrap_or_else(|| {
                    eprintln!("invalid --path {p:?} (expected auto|table|vector|simd|scalar)");
                    std::process::exit(2);
                })
            }),
            json_out: args.flag("json").map(PathBuf::from),
            baseline: args.flag("baseline").map(PathBuf::from),
            write_baseline: args.has("write-baseline"),
            threshold_pct: args.get("threshold", default_threshold()),
            advisory: args.has("advisory") || env_flag("POSIT_BENCH_ADVISORY"),
        }
    }

    /// Where the baseline for this suite lives. Without `--baseline`,
    /// `BENCH_<suite>.json` is resolved against the enclosing cargo
    /// project, not the bare cwd — `cargo bench`/`cargo run` preserve the
    /// invoker's directory, and a subdirectory run must neither skip the
    /// gate nor write a stray baseline.
    pub fn baseline_path(&self) -> PathBuf {
        if let Some(explicit) = &self.baseline {
            return explicit.clone();
        }
        let file = format!("BENCH_{}.json", self.suite);
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            if dir.join(&file).exists() || dir.join("Cargo.toml").exists() {
                return dir.join(file);
            }
            if !dir.pop() {
                return PathBuf::from(file);
            }
        }
    }

    /// Post-run bookkeeping: JSON emission, baseline write/compare, gate.
    /// Returns the process exit code.
    pub fn finish(&self, runner: &Runner) -> i32 {
        let report = Report::new(self.suite, self.profile, self.cfg, runner.entries().to_vec());
        // Fail at the source, not when a later run trips over the saved
        // file: names are the baseline join key, so a duplicate here
        // would poison every subsequent load of this report.
        let mut seen = std::collections::HashSet::new();
        if let Some(dup) = report.measurements.iter().find(|e| !seen.insert(e.name.as_str())) {
            eprintln!(
                "suite {:?} registered duplicate row name {:?} — fix the suite",
                self.suite, dup.name
            );
            return 1;
        }
        if let Some(path) = &self.json_out {
            match report.save(path) {
                Ok(()) => println!("report written: {}", path.display()),
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            }
        }
        let path = self.baseline_path();
        if self.write_baseline {
            return match report.save(&path) {
                Ok(()) => {
                    println!("baseline written: {}", path.display());
                    0
                }
                Err(e) => {
                    eprintln!("{e}");
                    1
                }
            };
        }
        if !path.exists() {
            println!(
                "no baseline at {} (record one with --write-baseline)",
                path.display()
            );
            return 0;
        }
        let base = match Report::load(&path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("baseline invalid: {e}");
                return 1;
            }
        };
        if base.suite != report.suite {
            eprintln!(
                "baseline {} is for suite {:?}, not {:?}",
                path.display(),
                base.suite,
                report.suite
            );
            return 1;
        }
        let cmp = Comparison::compare(&base, &report, self.threshold_pct);
        print!("{}", cmp.render(&path.display().to_string()));
        gate_verdict(&cmp, self.advisory)
    }
}

/// Run one named suite with flags from `args`; returns the exit code.
/// Shared by the `bench` subcommand and [`bench_main`].
pub fn run_suite(name: &str, args: &Args) -> i32 {
    let Some(suite) = suites::find(name) else {
        eprintln!("unknown bench suite {name:?}\n{}", suites::render_list());
        return 2;
    };
    let cli = BenchCli::from_args(suite.name, args);
    if (cli.tier.is_some() || cli.path.is_some()) && !suite.tier_aware {
        // Refuse rather than mislabel: the per-engine suites pin the
        // Datapath tier by design, so honoring `--tier fast` (or a forced
        // `--path`) silently would record datapath numbers under a
        // fast-tier run.
        eprintln!(
            "suite {:?} is not tier-aware (it pins the Datapath tier by design); \
             drop --tier/--path, or use `unit_throughput` for the tier comparison",
            suite.name
        );
        return 2;
    }
    let mut runner = Runner::new(suite.title);
    (suite.run)(&cli, &mut runner);
    runner.finish();
    cli.finish(&runner)
}

/// `main` for the thin `rust/benches/*.rs` shims: parse the process
/// arguments (dropping the `--bench` marker `cargo bench` appends), run
/// the suite, exit with the gate's code.
pub fn bench_main(suite: &str) -> ! {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    std::process::exit(run_suite(suite, &args));
}

/// Compare two arbitrary report files (`posit-div bench compare <a.json>
/// <b.json>`): the same per-row delta table and regression verdict the
/// post-suite gate prints, but between any two saved reports — e.g. a
/// before/after pair from one machine, or two CI artifacts — instead of
/// only against the committed `BENCH_<suite>.json`. `a` plays the
/// baseline, `b` the candidate. Returns the process exit code (0 pass or
/// advisory, 1 regression/invalid input).
pub fn compare_reports(base: &Path, new: &Path, threshold_pct: f64, advisory: bool) -> i32 {
    let load = |p: &Path| -> Result<Report, i32> {
        Report::load(p).map_err(|e| {
            eprintln!("{e}");
            1
        })
    };
    let (b, n) = match (load(base), load(new)) {
        (Ok(b), Ok(n)) => (b, n),
        _ => return 1,
    };
    if b.suite != n.suite {
        eprintln!(
            "note: comparing reports from different suites ({:?} vs {:?}) — rows join by name",
            b.suite, n.suite
        );
    }
    let cmp = Comparison::compare(&b, &n, threshold_pct);
    print!("{}", cmp.render(&base.display().to_string()));
    gate_verdict(&cmp, advisory)
}

/// Flag handling for the `bench compare` subcommand (shares the suite
/// gate's `--threshold`/`--advisory` semantics and environment
/// defaults).
pub fn compare_command(base: &Path, new: &Path, args: &Args) -> i32 {
    let threshold = args.get("threshold", default_threshold());
    let advisory = args.has("advisory") || env_flag("POSIT_BENCH_ADVISORY");
    compare_reports(base, new, threshold, advisory)
}

/// Validate a report file on disk; returns the exit code. Used by the
/// `posit-div bench validate <path>` schema gate in CI.
pub fn validate_report(path: &Path) -> i32 {
    match Report::load(path) {
        Ok(rep) => {
            println!(
                "{}: valid {} report — suite {}, profile {}, rev {}, {} measurement(s){}",
                path.display(),
                super::report::SCHEMA,
                rep.suite,
                rep.profile,
                rep.git_rev,
                rep.measurements.len(),
                if rep.provisional { " (provisional)" } else { "" }
            );
            0
        }
        Err(e) => {
            eprintln!("schema-invalid report: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn profile_flag_resolution() {
        let c = BenchCli::from_args("t", &args("--quick"));
        assert_eq!(c.profile, Profile::Quick);
        let c = BenchCli::from_args("t", &args("--profile quick"));
        assert_eq!(c.profile, Profile::Quick);
        let c = BenchCli::from_args("t", &args("--profile full"));
        assert_eq!(c.profile, Profile::Full);
        // explicit shorthand wins over the flag
        let c = BenchCli::from_args("t", &args("--full --profile quick"));
        assert_eq!(c.profile, Profile::Full);
    }

    #[test]
    fn baseline_path_defaults_to_suite_name_at_project_root() {
        let c = BenchCli::from_args("engine_throughput", &args(""));
        let path = c.baseline_path();
        assert!(path.ends_with("BENCH_engine_throughput.json"), "{path:?}");
        // resolved against the cargo project, not a bare relative path
        assert!(path.parent().is_some_and(|d| d.join("Cargo.toml").exists()), "{path:?}");
        let c = BenchCli::from_args("engine_throughput", &args("--baseline other.json"));
        assert_eq!(c.baseline_path(), PathBuf::from("other.json"));
    }

    #[test]
    fn threshold_and_modes() {
        let c = BenchCli::from_args("t", &args("--threshold 30 --advisory --json out.json"));
        assert!((c.threshold_pct - 30.0).abs() < 1e-12);
        assert!(c.advisory);
        assert_eq!(c.json_out, Some(PathBuf::from("out.json")));
        assert!(!c.write_baseline);
        let c = BenchCli::from_args("t", &args("--write-baseline"));
        assert!(c.write_baseline);
    }

    #[test]
    fn tier_flag_resolution() {
        assert_eq!(BenchCli::from_args("t", &args("")).tier, None);
        assert_eq!(BenchCli::from_args("t", &args("--tier fast")).tier, Some(ExecTier::Fast));
        assert_eq!(
            BenchCli::from_args("t", &args("--tier datapath")).tier,
            Some(ExecTier::Datapath)
        );
        assert_eq!(BenchCli::from_args("t", &args("--tier auto")).tier, Some(ExecTier::Auto));
    }

    #[test]
    fn path_flag_resolution() {
        assert_eq!(BenchCli::from_args("t", &args("")).path, None);
        assert_eq!(
            BenchCli::from_args("t", &args("--path vector")).path,
            Some(FastPath::Vector)
        );
        assert_eq!(BenchCli::from_args("t", &args("--path table")).path, Some(FastPath::Table));
        assert_eq!(
            BenchCli::from_args("t", &args("--path scalar")).path,
            Some(FastPath::Scalar)
        );
        // --path and --tier compose
        let c = BenchCli::from_args("t", &args("--tier fast --path simd"));
        assert_eq!((c.tier, c.path), (Some(ExecTier::Fast), Some(FastPath::Simd)));
    }

    #[test]
    fn unknown_suite_exits_2() {
        assert_eq!(run_suite("no_such_suite", &args("")), 2);
    }

    #[test]
    fn tier_flag_on_datapath_pinned_suite_is_refused() {
        // engine_throughput pins the Datapath tier; honoring --tier
        // silently would mislabel the measurements.
        assert_eq!(run_suite("engine_throughput", &args("--tier fast")), 2);
    }

    #[test]
    fn validate_rejects_missing_file() {
        assert_eq!(validate_report(Path::new("/nonexistent/BENCH_x.json")), 1);
    }

    #[test]
    fn compare_reports_on_two_files() {
        use crate::bench::report::Entry;
        use crate::bench::{Config, Measurement, Profile};
        use std::time::Duration;

        let row = |name: &str, ops: f64| -> Entry {
            Entry::from_measurement(&Measurement {
                name: name.into(),
                per_op: Duration::from_secs_f64(1.0 / ops),
                ops_per_sec: ops,
                samples: 3,
                iters_per_sample: 10,
            })
        };
        let report = |rows: Vec<Entry>| Report::new("t", Profile::Quick, Config::quick(), rows);
        let dir = std::env::temp_dir().join(format!("posit_cmp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a_path = dir.join("a.json");
        let b_path = dir.join("b.json");
        report(vec![row("x", 1000.0), row("y", 1000.0)]).save(&a_path).unwrap();

        // within threshold: pass
        report(vec![row("x", 950.0), row("y", 1200.0)]).save(&b_path).unwrap();
        assert_eq!(compare_reports(&a_path, &b_path, 15.0, false), 0);
        // regression past threshold: fail — unless advisory
        report(vec![row("x", 500.0), row("y", 1000.0)]).save(&b_path).unwrap();
        assert_eq!(compare_reports(&a_path, &b_path, 15.0, false), 1);
        assert_eq!(compare_reports(&a_path, &b_path, 15.0, true), 0);
        // a looser threshold tolerates the drop
        assert_eq!(compare_reports(&a_path, &b_path, 60.0, false), 0);
        // provisional baseline downgrades the gate to advisory
        let mut prov = report(vec![row("x", 1000.0)]);
        prov.provisional = true;
        prov.save(&a_path).unwrap();
        report(vec![row("x", 100.0)]).save(&b_path).unwrap();
        assert_eq!(compare_reports(&a_path, &b_path, 15.0, false), 0);
        // unreadable input: exit 1
        assert_eq!(compare_reports(Path::new("/nonexistent.json"), &b_path, 15.0, false), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compare_command_reads_flags() {
        // bad files exercise only the flag plumbing (exit 1 either way)
        let args = args("--threshold 30 --advisory");
        assert_eq!(
            compare_command(Path::new("/nonexistent_a.json"), Path::new("/nonexistent_b.json"), &args),
            1
        );
    }
}
