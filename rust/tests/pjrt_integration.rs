//! End-to-end integration: the AOT-compiled JAX/Pallas graph executed via
//! PJRT from Rust must agree bit-for-bit with the native Rust golden model.
//! Requires `make artifacts`.

use posit_div::division::golden;
use posit_div::posit::{mask, Posit};
use posit_div::runtime::Runtime;
use posit_div::testkit::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn pjrt_graph_matches_rust_golden() {
    let rt = Runtime::load(artifacts_dir()).expect("run `make artifacts` first");
    let mut rng = Rng::seeded(0x9187);
    for &n in &[16u32, 32] {
        for round in 0..4 {
            let len = [256usize, 100, 1024, 2500][round];
            let x: Vec<u64> = (0..len).map(|_| rng.next_u64() & mask(n)).collect();
            let d: Vec<u64> = (0..len).map(|_| rng.next_u64() & mask(n)).collect();
            let got = rt.divide_bits(n, &x, &d).unwrap();
            for i in 0..len {
                let want = golden::divide(
                    Posit::from_bits(n, x[i]),
                    Posit::from_bits(n, d[i]),
                )
                .result
                .to_bits();
                assert_eq!(got[i], want, "n={n} x={:#x} d={:#x}", x[i], d[i]);
            }
        }
    }
}

#[test]
fn pjrt_specials() {
    let rt = Runtime::load(artifacts_dir()).expect("run `make artifacts` first");
    let n = 16;
    let nar = 1u64 << (n - 1);
    let one = 1u64 << (n - 2);
    let x = vec![0, 0, nar, one, one];
    let d = vec![one, 0, one, nar, 0];
    let q = rt.divide_bits(n, &x, &d).unwrap();
    assert_eq!(q, vec![0, nar, nar, nar, nar]);
}
