//! Perf probe: scalar vs batch hot-path timings for the optimized radix-4
//! engine, plus the u128-vs-u64 fraction-recurrence ablation tracked in
//! EXPERIMENTS.md §Perf.

use posit_div::division::srt4_cs::Srt4Cs;
use posit_div::division::{Algorithm, DivEngine};
use posit_div::posit::{frac_bits, mask, Posit};
use posit_div::testkit::Rng;
use posit_div::unit::{ExecTier, Op, Unit};
use std::time::Instant;

fn main() {
    let mut rng = Rng::seeded(1);
    for n in [16u32, 32] {
        let pairs: Vec<(Posit, Posit)> = (0..4096).map(|_| {
            (Posit::from_bits(n, rng.next_u64() & mask(n)),
             Posit::from_bits(n, (rng.next_u64() & mask(n)) | 1))
        }).collect();
        // datapath-pinned: this probe times the engine itself
        let ctx = Unit::with_tier(n, Op::Div { alg: Algorithm::Srt4CsOfFr }, ExecTier::Datapath)
            .expect("width");
        // warm
        for &(x, d) in &pairs {
            std::hint::black_box(ctx.run(&[x, d]).expect("width").result);
        }
        let mut best = f64::MAX;
        for _ in 0..40 {
            let t0 = Instant::now();
            for &(x, d) in &pairs {
                std::hint::black_box(ctx.run(&[x, d]).expect("width").result);
            }
            best = best.min(t0.elapsed().as_secs_f64() / pairs.len() as f64);
        }
        println!("Posit{n} srt4csoffr scalar: {:.0} ns/div ({:.2} Mdiv/s)", best * 1e9, 1e-6 / best);

        // batch path over the same working set (the coordinator's loop)
        let xs: Vec<u64> = pairs.iter().map(|p| p.0.to_bits()).collect();
        let ds: Vec<u64> = pairs.iter().map(|p| p.1.to_bits()).collect();
        let mut out = vec![0u64; xs.len()];
        let mut best_b = f64::MAX;
        for _ in 0..40 {
            let t0 = Instant::now();
            ctx.run_batch(&xs, &ds, &[], &mut out).expect("equal lanes");
            std::hint::black_box(&out);
            best_b = best_b.min(t0.elapsed().as_secs_f64() / xs.len() as f64);
        }
        println!("Posit{n} srt4csoffr batch : {:.0} ns/div ({:.2} Mdiv/s)", best_b * 1e9, 1e-6 / best_b);

        // fast-tier batch over the same working set (what the serving
        // default `Auto` actually runs)
        let fast = Unit::with_tier(n, Op::Div { alg: Algorithm::Srt4CsOfFr }, ExecTier::Fast)
            .expect("width");
        let mut best_f = f64::MAX;
        for _ in 0..40 {
            let t0 = Instant::now();
            fast.run_batch(&xs, &ds, &[], &mut out).expect("equal lanes");
            std::hint::black_box(&out);
            best_f = best_f.min(t0.elapsed().as_secs_f64() / xs.len() as f64);
        }
        println!("Posit{n} fast-tier  batch : {:.0} ns/div ({:.2} Mdiv/s)", best_f * 1e9, 1e-6 / best_f);

        // u128 reference recurrence (the pre-optimization path), fraction
        // stage only, for the §Perf before/after ablation
        let wide = Srt4Cs::with_otf_fr();
        let f = frac_bits(n);
        let sigs: Vec<(u64, u64)> = (0..4096)
            .map(|_| ((1 << f) | (rng.next_u64() & ((1 << f) - 1)), (1 << f) | (rng.next_u64() & ((1 << f) - 1))))
            .collect();
        for (name, use_wide) in [("u128 ref", true), ("u64 fast", false)] {
            let mut best = f64::MAX;
            for _ in 0..20 {
                let t0 = Instant::now();
                for &(x, d) in &sigs {
                    if use_wide {
                        std::hint::black_box(wide.frac_divide_wide_for_bench(n, x, d));
                    } else {
                        std::hint::black_box(wide.fraction_divide(n, x, d));
                    }
                }
                best = best.min(t0.elapsed().as_secs_f64() / sigs.len() as f64);
            }
            println!("  fraction stage ({name}): {:.0} ns", best * 1e9);
        }
    }
}
