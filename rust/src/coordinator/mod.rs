//! L3 coordinator: a batched posit-division service.
//!
//! The paper's contribution is the arithmetic unit, so the coordinator is
//! the thin-but-real driver the architecture calls for: a leader thread
//! owns a dynamic [`batcher`] (size + deadline policy) and a backend —
//! either the native bit-exact Rust engines spread over a worker [`pool`],
//! or the AOT-compiled JAX/Pallas graph executed through PJRT
//! ([`crate::runtime`]). Clients submit `(x, d)` pairs and block on (or
//! poll) a response channel; [`metrics`] tracks request/batch latency.
//!
//! Python never runs here: the PJRT backend executes the pre-compiled
//! HLO artifact in-process.

pub mod batcher;
pub mod metrics;
pub mod pool;

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

pub use batcher::BatchPolicy;
pub use metrics::{Histogram, Metrics};
pub use pool::Pool;

use crate::division::{Algorithm, DivEngine};
use crate::posit::Posit;
use crate::runtime::Runtime;

/// Which execution engine serves the batches.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Bit-exact Rust digit-recurrence engines, `threads`-way parallel.
    Native { alg: Algorithm, threads: usize },
    /// AOT-compiled JAX/Pallas graph via PJRT (artifacts from `make artifacts`).
    Pjrt { artifacts_dir: PathBuf },
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub n: u32,
    pub backend: Backend,
    pub policy: BatchPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            n: 32,
            backend: Backend::Native { alg: Algorithm::Srt4CsOfFr, threads: 4 },
            policy: BatchPolicy::default(),
        }
    }
}

struct Request {
    x: u64,
    d: u64,
    enqueued: Instant,
    respond: Sender<u64>,
}

/// A handle to a running division service.
pub struct DivisionService {
    n: u32,
    tx: Option<Sender<Request>>,
    metrics: Arc<Metrics>,
    leader: Option<JoinHandle<()>>,
}

impl DivisionService {
    /// Start the leader thread (and backend) for `cfg`.
    pub fn start(cfg: ServiceConfig) -> Result<DivisionService> {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let n = cfg.n;

        enum Exec {
            Native { engine: Box<dyn DivEngine + Send + Sync>, pool_threads: usize },
            Pjrt(Runtime),
        }

        // The PJRT client is thread-affine (Rc internally), so the backend
        // is constructed *inside* the leader thread; a ready-channel
        // surfaces startup errors to the caller synchronously.
        let backend = cfg.backend.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let policy = cfg.policy;
        let leader = std::thread::Builder::new()
            .name("posit-div-leader".into())
            .spawn(move || {
                let exec = match &backend {
                    Backend::Native { alg, threads } => {
                        Exec::Native { engine: alg.engine(), pool_threads: *threads }
                    }
                    Backend::Pjrt { artifacts_dir } => {
                        match Runtime::load(artifacts_dir)
                            .and_then(|rt| rt.warmup(n).map(|()| rt))
                        {
                            Ok(rt) => Exec::Pjrt(rt),
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        }
                    }
                };
                let _ = ready_tx.send(Ok(()));
                while let Some(batch) = batcher::collect_batch(&rx, policy) {
                    let t0 = Instant::now();
                    let results: Vec<u64> = match &exec {
                        Exec::Native { engine, pool_threads } => {
                            let chunk =
                                batch.len().div_ceil((*pool_threads).max(1)).max(1);
                            let pairs: Vec<(u64, u64)> =
                                batch.iter().map(|r| (r.x, r.d)).collect();
                            let mut out = vec![0u64; pairs.len()];
                            std::thread::scope(|s| {
                                for (inp, outp) in
                                    pairs.chunks(chunk).zip(out.chunks_mut(chunk))
                                {
                                    s.spawn(|| {
                                        for (i, o) in inp.iter().zip(outp.iter_mut()) {
                                            *o = engine
                                                .divide(
                                                    Posit::from_bits(n, i.0),
                                                    Posit::from_bits(n, i.1),
                                                )
                                                .result
                                                .to_bits();
                                        }
                                    });
                                }
                            });
                            out
                        }
                        Exec::Pjrt(rt) => {
                            let x: Vec<u64> = batch.iter().map(|r| r.x).collect();
                            let d: Vec<u64> = batch.iter().map(|r| r.d).collect();
                            match rt.divide_bits(n, &x, &d) {
                                Ok(q) => q,
                                Err(e) => {
                                    // fail the whole batch as NaR and keep
                                    // serving (errors are per-batch)
                                    eprintln!("pjrt batch failed: {e:#}");
                                    vec![1u64 << (n - 1); batch.len()]
                                }
                            }
                        }
                    };
                    m.batch_latency.record(t0.elapsed());
                    m.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    for (req, q) in batch.into_iter().zip(results) {
                        if q == 1u64 << (n - 1) {
                            m.special_results
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        m.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        m.request_latency.record(req.enqueued.elapsed());
                        let _ = req.respond.send(q); // receiver may have gone
                    }
                }
            })?;

        ready_rx.recv().expect("leader thread died during startup")?;
        Ok(DivisionService { n, tx: Some(tx), metrics, leader: Some(leader) })
    }

    /// Posit width served.
    pub fn width(&self) -> u32 {
        self.n
    }

    /// Submit a division; returns the response channel immediately.
    pub fn submit(&self, x: Posit, d: Posit) -> Receiver<u64> {
        assert_eq!(x.width(), self.n);
        let (rtx, rrx) = channel();
        self.tx
            .as_ref()
            .unwrap()
            .send(Request { x: x.to_bits(), d: d.to_bits(), enqueued: Instant::now(), respond: rtx })
            .expect("service stopped");
        rrx
    }

    /// Blocking division.
    pub fn divide(&self, x: Posit, d: Posit) -> Posit {
        let bits = self.submit(x, d).recv().expect("service stopped");
        Posit::from_bits(self.n, bits)
    }

    /// Submit many and wait for all (keeps ordering).
    pub fn divide_many(&self, pairs: &[(Posit, Posit)]) -> Vec<Posit> {
        let rxs: Vec<Receiver<u64>> =
            pairs.iter().map(|&(x, d)| self.submit(x, d)).collect();
        rxs.into_iter()
            .map(|r| Posit::from_bits(self.n, r.recv().expect("service stopped")))
            .collect()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting requests and join the leader.
    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DivisionService {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;
    use crate::posit::mask;
    use crate::testkit::Rng;

    fn native_cfg(n: u32) -> ServiceConfig {
        ServiceConfig {
            n,
            backend: Backend::Native { alg: Algorithm::Srt4CsOfFr, threads: 2 },
            policy: BatchPolicy { max_batch: 64, max_wait: std::time::Duration::from_micros(100) },
        }
    }

    #[test]
    fn native_service_matches_golden() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let mut rng = Rng::seeded(0xE2E);
        let pairs: Vec<(Posit, Posit)> = (0..500)
            .map(|_| {
                (
                    Posit::from_bits(16, rng.next_u64() & mask(16)),
                    Posit::from_bits(16, rng.next_u64() & mask(16)),
                )
            })
            .collect();
        let got = svc.divide_many(&pairs);
        for (i, &(x, d)) in pairs.iter().enumerate() {
            assert_eq!(got[i], golden::divide(x, d).result, "{x:?}/{d:?}");
        }
        assert!(svc.metrics().requests.load(std::sync::atomic::Ordering::Relaxed) >= 500);
        svc.shutdown();
    }

    #[test]
    fn service_handles_specials() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let n = 16;
        assert!(svc.divide(Posit::one(n), Posit::zero(n)).is_nar());
        assert!(svc.divide(Posit::zero(n), Posit::one(n)).is_zero());
        assert!(svc.divide(Posit::nar(n), Posit::one(n)).is_nar());
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let svc = std::sync::Arc::new(DivisionService::start(native_cfg(32)).unwrap());
        std::thread::scope(|s| {
            for t in 0..8 {
                let svc = svc.clone();
                s.spawn(move || {
                    let mut rng = Rng::seeded(t);
                    for _ in 0..200 {
                        let x = Posit::from_bits(32, rng.next_u64() & mask(32));
                        let d = Posit::from_bits(32, rng.next_u64() & mask(32));
                        let q = svc.divide(x, d);
                        assert_eq!(q, golden::divide(x, d).result);
                    }
                });
            }
        });
        assert!(svc.metrics().batches.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }

    #[test]
    fn shutdown_drains() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let rx = svc.submit(Posit::one(16), Posit::one(16));
        svc.shutdown();
        assert_eq!(rx.recv().unwrap(), Posit::one(16).to_bits());
    }
}
