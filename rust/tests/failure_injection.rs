//! Failure injection: the service and runtime must fail loudly at startup
//! on bad artifacts and keep serving through client-side misbehavior.

use std::time::Duration;

use posit_div::coordinator::{Backend, BatchPolicy, DivisionService, ServiceConfig};
use posit_div::division::Algorithm;
use posit_div::posit::Posit;
use posit_div::runtime::Runtime;

#[test]
fn runtime_missing_dir_errors() {
    let err = match Runtime::load("/nonexistent/artifacts") {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(format!("{err:#}").contains("artifact"), "{err:#}");
}

#[test]
fn runtime_empty_dir_errors() {
    let dir = std::env::temp_dir().join("posit-div-empty-artifacts");
    let _ = std::fs::create_dir_all(&dir);
    let err = match Runtime::load(&dir) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(format!("{err:#}").contains("no artifacts"), "{err:#}");
}

#[test]
fn service_startup_fails_on_corrupt_artifact() {
    let dir = std::env::temp_dir().join("posit-div-corrupt-artifacts");
    let _ = std::fs::create_dir_all(&dir);
    std::fs::write(dir.join("div_p16_b256.hlo.txt"), "this is not HLO").unwrap();
    let res = DivisionService::start(ServiceConfig {
        n: 16,
        backend: Backend::Pjrt { artifacts_dir: dir.clone() },
        policy: BatchPolicy::default(),
    });
    assert!(res.is_err(), "corrupt artifact must fail startup");
}

#[test]
fn service_survives_dropped_response_receivers() {
    let svc = DivisionService::start(ServiceConfig {
        n: 16,
        backend: Backend::Native { alg: Algorithm::Srt2Cs, threads: 2 },
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(50) },
    })
    .unwrap();
    // submit and immediately drop receivers: the leader must not panic
    for _ in 0..100 {
        drop(svc.submit(Posit::one(16), Posit::one(16)));
    }
    // service still works afterwards
    assert_eq!(svc.divide(Posit::one(16), Posit::one(16)), Posit::one(16));
    svc.shutdown();
}

#[test]
fn service_width_mismatch_panics_on_submit() {
    let svc = DivisionService::start(ServiceConfig {
        n: 16,
        backend: Backend::Native { alg: Algorithm::Srt2Cs, threads: 1 },
        policy: BatchPolicy::default(),
    })
    .unwrap();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        svc.submit(Posit::one(32), Posit::one(32))
    }));
    assert!(res.is_err());
    svc.shutdown();
}
