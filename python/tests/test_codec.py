"""Codec tests: the jnp posit decode/encode must agree with itself
(round-trip) and with hand-computed patterns, over *every* pattern for
small widths and property-sampled patterns for wide ones."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import posit_codec as codec


def all_patterns(n):
    return np.arange(1 << n, dtype=np.int64)


@pytest.mark.parametrize("n", [8, 10, 12, 16])
def test_roundtrip_exhaustive(n):
    bits = all_patterns(n)
    z, na, s, sc, sig = codec.decode(bits, n)
    enc = codec.encode(s, sc, sig, codec.frac_bits(n), jnp.zeros(bits.shape, bool), n)
    real = ~(np.array(z) | np.array(na))
    np.testing.assert_array_equal(np.array(enc)[real], bits[real])


@pytest.mark.parametrize("n", [8, 16, 32])
def test_specials(n):
    bits = np.array([0, 1 << (n - 1)], dtype=np.int64)
    z, na, _, _, _ = codec.decode(bits, n)
    assert np.array(z).tolist() == [True, False]
    assert np.array(na).tolist() == [False, True]


def test_known_values_p8():
    # 1.0 = 0|10|00|000; 1.5 = 0|10|00|100; 0.5 = 0|01|11|000 (k=-1,e=3)
    bits = np.array([0b01000000, 0b01000100, 0b00111000, 0b01111111, 1], dtype=np.int64)
    _, _, s, sc, sig = codec.decode(bits, 8)
    f = codec.frac_bits(8)
    vals = np.array(sig, dtype=float) / (1 << f) * 2.0 ** np.array(sc, dtype=float)
    np.testing.assert_allclose(vals, [1.0, 1.5, 0.5, 2.0**24, 2.0**-24])


def test_encode_saturates():
    n = 16
    ones = jnp.ones((4,), jnp.int64)
    big = codec.encode(
        jnp.zeros((4,), bool), jnp.asarray([400, 60, -400, -60]), ones << codec.frac_bits(n),
        codec.frac_bits(n), jnp.zeros((4,), bool), n,
    )
    maxpos = (1 << (n - 1)) - 1
    assert np.array(big).tolist() == [maxpos, maxpos, 1, 1]


def test_encode_never_zero_or_nar():
    n = 10
    rng = np.random.default_rng(7)
    sc = rng.integers(-40, 40, size=4096)
    f = codec.frac_bits(n)
    sig = (1 << f) | rng.integers(0, 1 << f, size=4096)
    sign = rng.integers(0, 2, size=4096).astype(bool)
    enc = np.array(
        codec.encode(jnp.asarray(sign), jnp.asarray(sc), jnp.asarray(sig), f,
                     jnp.ones((4096,), bool), n)
    )
    assert (enc != 0).all()
    assert (enc != 1 << (n - 1)).all()


@settings(max_examples=200, deadline=None)
@given(st.integers(0, (1 << 32) - 1))
def test_roundtrip_p32_sampled(pattern):
    n = 32
    bits = np.array([pattern], dtype=np.int64)
    z, na, s, sc, sig = codec.decode(bits, n)
    if bool(np.array(z)[0]) or bool(np.array(na)[0]):
        return
    enc = codec.encode(s, sc, sig, codec.frac_bits(n), jnp.zeros((1,), bool), n)
    assert int(np.array(enc)[0]) == pattern


@settings(max_examples=50, deadline=None)
@given(st.integers(6, 30), st.data())
def test_roundtrip_arbitrary_widths(n, data):
    pattern = data.draw(st.integers(0, (1 << n) - 1))
    bits = np.array([pattern], dtype=np.int64)
    z, na, s, sc, sig = codec.decode(bits, n)
    if bool(np.array(z)[0]) or bool(np.array(na)[0]):
        return
    enc = codec.encode(s, sc, sig, codec.frac_bits(n), jnp.zeros((1,), bool), n)
    assert int(np.array(enc)[0]) == pattern
