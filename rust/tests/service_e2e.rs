//! End-to-end soak of the sharded TCP serving tier: sustained mixed
//! traffic across shards over loopback with bounded tail latency,
//! typed overload shedding, open-loop (arrival-rate) driving, consistent
//! `(op, width)` shard affinity, typed rejection of malformed wire
//! frames, brown-out degradation, per-request deadlines, and a seeded
//! chaos soak through the fault-injecting proxy. Everything here goes
//! through the real socket path — the same bytes `posit-div
//! serve`/`client` exchange (docs/SERVING.md).

use std::io::Write;
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use posit_div::coordinator::{Backend, BatchPolicy, ServedBy, ServiceConfig};
use posit_div::division::Algorithm;
use posit_div::posit::Posit;
use posit_div::service::wire::{self, FrameKind};
use posit_div::service::{
    shard_for, BreakerConfig, ConnectOptions, FaultNet, FaultPlan, ResilientClient, RetryPolicy,
    Server, ServiceClient, ShardConfig,
};
use posit_div::unit::{Accuracy, ExecTier, Op, OpRequest};
use posit_div::workload::{take_requests, MixedOps, OpMix, OpenLoop};
use posit_div::PositError;

fn cfg(n: u32, shards: usize, queue_capacity: usize) -> ShardConfig {
    ShardConfig {
        shards,
        queue_capacity,
        soft_capacity: queue_capacity, // == hard cap: brown-out off unless a test opts in
        idle_timeout: ShardConfig::DEFAULT_IDLE_TIMEOUT,
        service: ServiceConfig {
            n,
            backend: Backend::Native { alg: Algorithm::DEFAULT, threads: 2 },
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) },
            tier: ExecTier::Auto,
        },
    }
}

/// The full op mix: every kind the wire protocol can carry, including
/// the quire reductions.
fn full_mix() -> OpMix {
    OpMix::parse("div:4,sqrt:2,mul:3,add:3,sub:2,fma:2,dot:1,fsum:1,axpy:1").expect("static mix")
}

#[test]
fn soak_mixed_traffic_across_shards_with_bounded_tail() {
    let server = Server::bind("127.0.0.1:0", cfg(16, 2, 4096)).unwrap();
    let mut client = ServiceClient::connect(server.local_addr(), 16).unwrap();

    let reqs = take_requests(&mut MixedOps::new(16, full_mix(), 0xABCD), 4_000);
    let results = client.run_ops(&reqs).unwrap();
    assert_eq!(results.len(), reqs.len());
    for (i, (req, res)) in reqs.iter().zip(&results).enumerate() {
        let got = res.as_ref().expect("queue capacity exceeds the pipeline window");
        assert_eq!(*got, req.golden(), "{} sample {i}", req.op);
    }

    client.shutdown_server().unwrap();
    let svc = server.wait();
    assert_eq!(svc.total_requests(), reqs.len() as u64);
    assert_eq!(svc.shed_total(), 0);

    // affinity spreads a full mix over both shards
    let per_shard = svc.shard_requests();
    assert_eq!(per_shard.len(), 2);
    assert!(per_shard.iter().all(|&r| r > 0), "one shard sat idle: {per_shard:?}");

    // the SLO panel saw every op kind, every request, and nothing hung
    let panel = svc.latency_snapshot();
    let cells = panel.nonempty();
    let kinds: std::collections::BTreeSet<&str> =
        cells.iter().map(|(op, _, _)| op.name()).collect();
    assert_eq!(kinds.len(), 9, "op kinds with latency cells: {kinds:?}");
    let mut measured = 0;
    for (op, lane, h) in &cells {
        assert!(h.count() > 0);
        assert!(
            h.quantile(0.999) < Duration::from_secs(5),
            "{} x {} p999 unbounded",
            op.name(),
            lane.name()
        );
        measured += h.count();
    }
    assert_eq!(measured, reqs.len() as u64);

    let render = svc.counters_render();
    assert!(render.contains("shard 0: requests="), "{render}");
    assert!(render.contains("shard 1: requests="), "{render}");
    svc.shutdown();
}

#[test]
fn overload_sheds_typed_over_tcp_and_recovers() {
    // One admission slot per shard: holding it from the in-process
    // router handle makes the next TCP request for the same op a
    // deterministic shed — no timing involved.
    let server = Server::bind("127.0.0.1:0", cfg(16, 2, 1)).unwrap();
    let router = server.client();
    let mut client = ServiceClient::connect(server.local_addr(), 16).unwrap();

    let one = Posit::one(16);
    let shard = shard_for(Op::Sqrt, 16, 2);
    let ticket = router.submit_op(OpRequest::sqrt(one)).unwrap();
    assert_eq!(ticket.shard(), shard);

    let e = client.run_op(&OpRequest::sqrt(one)).unwrap_err();
    assert_eq!(e, PositError::ServiceOverloaded { shard, inflight: 1, capacity: 1 });

    // draining the held ticket frees the slot; the same request succeeds
    assert_eq!(ticket.wait().unwrap(), one);
    assert_eq!(client.run_op(&OpRequest::sqrt(one)).unwrap(), one);

    client.shutdown_server().unwrap();
    let svc = server.wait();
    assert_eq!(svc.shed_total(), 1);
    assert_eq!(svc.total_requests(), 2);
    svc.shutdown();
}

#[test]
fn open_loop_drive_is_verified_and_accounted() {
    let server = Server::bind("127.0.0.1:0", cfg(16, 2, 4096)).unwrap();
    let mut client = ServiceClient::connect(server.local_addr(), 16).unwrap();

    let mut wl = OpenLoop::new(16, full_mix(), 25_000.0, 42);
    let rep = client.run_open_loop(&mut wl, 2_000, 7).unwrap();

    assert_eq!(rep.offered, 2_000);
    assert_eq!(rep.completed + rep.shed + rep.errors, rep.offered, "every request accounted");
    assert_eq!(rep.errors, 0);
    assert_eq!(rep.shed, 0, "2000 in flight cannot overrun a 4096 budget");
    assert_eq!(rep.verify_failures, 0);
    assert_eq!(rep.latency.count(), 2_000);
    assert!(rep.latency.quantile(0.999) < Duration::from_secs(10), "open-loop p999 unbounded");
    assert!(rep.achieved_rate() > 0.0);
    assert_eq!(rep.width, 16);

    client.shutdown_server().unwrap();
    server.shutdown().shutdown();
}

/// Mixed per-request accuracy on one server over TCP: interleaved
/// `exact` and `ulp:50` traffic through the same wire connection.
/// Exact responses stay bit-identical to golden; tolerant responses for
/// ops with a registered bounded-error kernel land within the kernel's
/// declared ulp bound; and the merged metrics account for it all —
/// per-tier serve counters, per-op approx error telemetry from the
/// audit sampler, and the approx lane of the SLO latency panel.
#[test]
fn mixed_accuracy_traffic_routes_approx_and_audits_over_tcp() {
    let n = 16;
    let server = Server::bind("127.0.0.1:0", cfg(n, 2, 4096)).unwrap();
    let mut client = ServiceClient::connect(server.local_addr(), n).unwrap();

    let exact = take_requests(&mut MixedOps::new(n, full_mix(), 0xE1), 600);
    let tolerant = take_requests(
        &mut MixedOps::new(n, full_mix(), 0xE2).with_accuracy(Accuracy::Ulp(50)),
        600,
    );
    // interleave so individual dynamic batches carry both policies
    let reqs: Vec<OpRequest> =
        exact.into_iter().zip(tolerant).flat_map(|(e, t)| [e, t]).collect();
    let results = client.run_ops(&reqs).unwrap();
    let mut approx_eligible = 0u64;
    for (i, (req, res)) in reqs.iter().zip(&results).enumerate() {
        let got = res.as_ref().expect("4096-deep queues cannot shed this drive");
        let want = req.golden();
        if req.op.routes_approx(n, req.accuracy()) {
            approx_eligible += 1;
            let spec = req.op.approx_spec(n).expect("routing implies a registered spec");
            assert!(
                got.ulp_distance(want) <= spec.max_ulp,
                "{} sample {i}: {} ulp from golden exceeds declared {}",
                req.op,
                got.ulp_distance(want),
                spec.max_ulp
            );
        } else {
            assert_eq!(*got, want, "{} sample {i} must be bit-exact", req.op);
        }
    }
    assert!(approx_eligible > 0, "the mix must exercise the approx tier");

    client.shutdown_server().unwrap();
    let svc = server.wait();
    let (mut approx_served, mut exact_served, mut audited, mut over) = (0u64, 0u64, 0u64, 0u64);
    let mut max_seen = 0u64;
    for shard in 0..svc.shards() {
        let m = svc.metrics(shard);
        approx_served += m.tiers.get(ExecTier::Approx);
        exact_served += m.tiers.get(ExecTier::Fast) + m.tiers.get(ExecTier::Datapath);
        for op in [Op::DIV, Op::Sqrt, Op::Mul] {
            let s = m.approx_errors.get(op);
            audited += s.count;
            over += s.over;
            max_seen = max_seen.max(s.max);
        }
    }
    assert_eq!(approx_served, approx_eligible, "per-tier counters account the approx lane");
    assert!(exact_served > 0, "exact traffic keeps serving on the exact tiers");
    assert!(audited > 0, "the audit sampler must have recomputed lanes");
    assert_eq!(over, 0, "no audited lane exceeded its declared bound");
    assert!(max_seen <= 4, "P16 div/sqrt declare max_ulp 4 (mul 1): observed {max_seen}");

    // the SLO latency panel's approx lane saw exactly the routed traffic
    let panel = svc.latency_snapshot();
    let approx_lane: u64 = [Op::DIV, Op::Sqrt, Op::Mul]
        .iter()
        .map(|&op| panel.get(op, ServedBy::Approx).count())
        .sum();
    assert_eq!(approx_lane, approx_eligible);
    svc.shutdown();
}

#[test]
fn affinity_routes_an_op_to_its_shard_over_tcp() {
    let server = Server::bind("127.0.0.1:0", cfg(16, 2, 1024)).unwrap();
    let mut client = ServiceClient::connect(server.local_addr(), 16).unwrap();

    let one = Posit::one(16);
    let reqs = vec![OpRequest::mul(one, one); 50];
    let results = client.run_ops(&reqs).unwrap();
    assert!(results.iter().all(|r| *r.as_ref().unwrap() == one));

    client.shutdown_server().unwrap();
    let svc = server.wait();
    let shard = shard_for(Op::Mul, 16, 2);
    let per_shard = svc.shard_requests();
    assert_eq!(per_shard[shard], 50, "all mul traffic on its home shard");
    assert_eq!(per_shard[1 - shard], 0, "the other shard stayed idle");
    svc.shutdown();
}

#[test]
fn malformed_frames_get_typed_error_replies() {
    let server = Server::bind("127.0.0.1:0", cfg(16, 1, 1024)).unwrap();
    let addr = server.local_addr();

    let handshake = |s: &mut TcpStream| {
        wire::write_frame(s, FrameKind::Hello, &wire::encode_hello(16)).unwrap();
        let f = wire::read_frame(s).unwrap();
        assert_eq!(f.kind, FrameKind::Welcome);
    };
    let expect_protocol_error = |s: &mut TcpStream| {
        let f = wire::read_frame(s).unwrap();
        assert_eq!(f.kind, FrameKind::Error);
        let (id, e) = wire::decode_error(&f.payload).unwrap();
        assert_eq!(id, 0, "no request id recoverable from broken framing");
        assert!(matches!(e, PositError::Protocol { .. }), "{e}");
    };

    // broken framing (bad magic): typed error, then the server hangs up
    let mut s = TcpStream::connect(addr).unwrap();
    handshake(&mut s);
    s.write_all(&[0xFF; 8]).unwrap();
    expect_protocol_error(&mut s);
    assert!(wire::read_frame(&mut s).is_err(), "connection stays closed after a framing break");

    // oversized declared length: rejected from the header alone
    let mut s = TcpStream::connect(addr).unwrap();
    handshake(&mut s);
    s.write_all(&wire::header_bytes(FrameKind::Request, wire::MAX_FRAME + 1)).unwrap();
    expect_protocol_error(&mut s);

    // garbage *payload* in a well-formed frame: typed error, but the
    // connection survives and serves the next request normally
    let mut s = TcpStream::connect(addr).unwrap();
    handshake(&mut s);
    wire::write_frame(&mut s, FrameKind::Request, &[1, 2, 3]).unwrap();
    expect_protocol_error(&mut s);
    let one = Posit::one(16);
    let req = wire::encode_request(9, &OpRequest::sqrt(one));
    wire::write_frame(&mut s, FrameKind::Request, &req).unwrap();
    let f = wire::read_frame(&mut s).unwrap();
    assert_eq!(f.kind, FrameKind::Response);
    assert_eq!(wire::decode_response(&f.payload).unwrap(), (9, one.to_bits(), 0));

    server.shutdown().shutdown();
}

/// A request whose deadline expired on the wire (header at t0, payload
/// trickling in 200 ms later against a 50 ms budget) is dropped at
/// admission with a typed error — without consuming a shard slot — and
/// the connection keeps serving.
#[test]
fn expired_deadline_is_dropped_typed_over_tcp() {
    let server = Server::bind("127.0.0.1:0", cfg(16, 1, 64)).unwrap();
    let addr = server.local_addr();
    let one = Posit::one(16);

    let mut s = TcpStream::connect(addr).unwrap();
    wire::write_frame(&mut s, FrameKind::Hello, &wire::encode_hello(16)).unwrap();
    assert_eq!(wire::read_frame(&mut s).unwrap().kind, FrameKind::Welcome);

    // the admission clock starts when the header lands; stall the
    // payload past the request's own 50 ms budget
    let payload = wire::encode_request(3, &OpRequest::sqrt(one).with_deadline_ms(50));
    s.write_all(&wire::header_bytes(FrameKind::Request, payload.len())).unwrap();
    thread::sleep(Duration::from_millis(200));
    s.write_all(&payload).unwrap();

    let f = wire::read_frame(&mut s).unwrap();
    assert_eq!(f.kind, FrameKind::Error);
    let (id, e) = wire::decode_error(&f.payload).unwrap();
    assert_eq!(id, 3);
    match e {
        PositError::DeadlineExceeded { deadline_ms, waited_ms } => {
            assert_eq!(deadline_ms, 50);
            assert!(waited_ms >= 150, "stalled ~200 ms, reported {waited_ms} ms");
        }
        other => panic!("expected DeadlineExceeded, got {other}"),
    }

    // a deadline drop is per-request: the same connection still serves,
    // and a generous live deadline passes admission
    let ok = wire::encode_request(4, &OpRequest::sqrt(one).with_deadline_ms(5_000));
    wire::write_frame(&mut s, FrameKind::Request, &ok).unwrap();
    let f = wire::read_frame(&mut s).unwrap();
    assert_eq!(f.kind, FrameKind::Response);
    assert_eq!(wire::decode_response(&f.payload).unwrap(), (4, one.to_bits(), 0));

    let svc = server.shutdown();
    assert_eq!(svc.deadline_drops_total(), 1);
    assert_eq!(svc.total_requests(), 1, "the dropped request never took a slot");
    svc.shutdown();
}

/// Brown-out over the wire: past the soft watermark, ulp-tolerant
/// traffic with a registered bounded-error kernel degrades to the
/// approx tier — flagged in the RESPONSE frame, counted in the metrics,
/// within the kernel's declared bound — while bit-exact traffic is
/// never degraded, and nothing sheds.
#[test]
fn brown_out_degrades_over_tcp_before_shedding() {
    let n = 16;
    let base = cfg(n, 1, 64);
    let server = Server::bind("127.0.0.1:0", ShardConfig { soft_capacity: 1, ..base }).unwrap();
    let router = server.client();
    let mut client = ServiceClient::connect(server.local_addr(), n).unwrap();

    let one = Posit::one(16);
    let x = Posit::from_f64(n, 9.0);
    let d = Posit::from_f64(n, 3.0);
    let tolerant = OpRequest::div(x, d).with_accuracy(Accuracy::Ulp(1));

    // calm service: the tolerant request serves exact, nothing degrades
    let calm = client.run_op(&tolerant).unwrap();
    assert_eq!(calm, tolerant.golden());
    assert_eq!(client.degraded_replies(), 0);

    // hold one admission slot from the in-process handle: depth >= soft
    // watermark (1), deterministically — no timing involved
    let ticket = router.submit_op(OpRequest::sqrt(one)).unwrap();

    let spec = Op::DIV.approx_spec(n).expect("P16 div has a registered kernel");
    let got = client.run_op(&tolerant).unwrap();
    assert!(
        got.ulp_distance(tolerant.golden()) <= spec.max_ulp,
        "degraded reply drifted {} ulp, declared bound {}",
        got.ulp_distance(tolerant.golden()),
        spec.max_ulp
    );
    assert_eq!(client.degraded_replies(), 1, "the RESPONSE frame carried the degraded flag");

    // bit-exact traffic under the same pressure is never degraded
    let exact = OpRequest::div(x, d);
    assert_eq!(client.run_op(&exact).unwrap(), exact.golden());
    // tolerant traffic without a registered kernel stays exact too
    let add = OpRequest::add(one, one).with_accuracy(Accuracy::Ulp(1));
    assert_eq!(client.run_op(&add).unwrap(), add.golden());
    assert_eq!(client.degraded_replies(), 1);

    assert_eq!(ticket.wait().unwrap(), one);
    client.shutdown_server().unwrap();
    let svc = server.wait();
    assert_eq!(svc.degraded_total(), 1);
    assert_eq!(svc.shed_total(), 0, "brown-out absorbed the pressure before any shed");
    assert!(svc.metrics(0).tiers.get(ExecTier::Approx) >= 1);
    assert!(svc.counters_render().contains("degraded=1"), "{}", svc.counters_render());
    svc.shutdown();
}

/// The seeded chaos soak: two servers behind two fault-injecting
/// proxies (`FaultPlan::chaos` — delays, duplicates, black holes,
/// truncations, dropped connections), one resilient client fanning a
/// golden-verified stream over both. At fixed seeds the outcome is the
/// contract itself: every logical request completes exactly once —
/// 100% success, zero duplicate completions, zero verification
/// failures — whatever the fault schedule did to individual attempts.
#[test]
fn chaos_soak_completes_every_request_exactly_once() {
    let n = 16;
    let server_a = Server::bind("127.0.0.1:0", cfg(n, 2, 4096)).unwrap();
    let server_b = Server::bind("127.0.0.1:0", cfg(n, 2, 4096)).unwrap();
    let mut net_a = FaultNet::start(server_a.local_addr(), FaultPlan::chaos(0xC4A0)).unwrap();
    let mut net_b = FaultNet::start(server_b.local_addr(), FaultPlan::chaos(0xC4A1)).unwrap();

    let policy = RetryPolicy {
        max_retries: 16,
        base_backoff: Duration::from_millis(2),
        max_backoff: Duration::from_millis(50),
        seed: 0x50AC,
    };
    let breaker = BreakerConfig { failure_threshold: 3, open_cooldown: Duration::from_millis(50) };
    let opts = ConnectOptions {
        connect_timeout: Some(Duration::from_millis(1_000)),
        // generous against loopback latency, short enough that a
        // black-holed frame retries quickly
        read_timeout: Some(Duration::from_millis(400)),
    };
    let mut rc =
        ResilientClient::new(&[net_a.local_addr(), net_b.local_addr()], n, policy, breaker, opts)
            .unwrap();

    let reqs = take_requests(&mut MixedOps::new(n, full_mix(), 0x0DD5), 300);
    let rep = rc.run_requests(&reqs, 5);

    assert_eq!(rep.offered, 300);
    assert_eq!(rep.completed, 300, "chaos must not lose requests: {}", rep.summary());
    assert_eq!(rep.failed, 0, "{}", rep.summary());
    assert_eq!(rep.verify_failures, 0, "a duplicate or corrupt completion would show here");
    // the proxies really did inject faults — on both paths
    assert!(net_a.counters().faulted() > 0, "endpoint A saw no faults");
    assert!(net_b.counters().faulted() > 0, "endpoint B saw no faults");
    // with ~12% of frames faulted, the client must have retried
    assert!(rep.retries > 0, "{}", rep.summary());
    assert!(rep.connects >= 2, "both endpoints served: {}", rep.summary());

    rc.close_connections();
    net_a.stop();
    net_b.stop();
    server_a.shutdown().shutdown();
    server_b.shutdown().shutdown();
}
