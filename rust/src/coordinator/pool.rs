//! Minimal fixed-size worker pool (no tokio/rayon offline): a shared
//! injector queue of boxed jobs, used by the native backend to spread a
//! batch across cores.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed worker pool. Dropping it joins all workers.
pub struct Pool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("posit-div-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool { tx: Some(tx), workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().unwrap().send(Box::new(job)).expect("pool closed");
    }

    /// Run `f` over chunks of `items` in parallel, writing results in
    /// order; blocks until done.
    pub fn map_chunks<T, R, F>(&self, items: &[T], chunk: usize, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send + Default + Clone,
        F: Fn(&T) -> R + Sync,
    {
        let mut out = vec![R::default(); items.len()];
        std::thread::scope(|s| {
            for (inp, outp) in items.chunks(chunk.max(1)).zip(out.chunks_mut(chunk.max(1))) {
                s.spawn(|| {
                    for (i, o) in inp.iter().zip(outp.iter_mut()) {
                        *o = f(i);
                    }
                });
            }
        });
        out
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = counter.clone();
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_chunks_preserves_order() {
        let pool = Pool::new(3);
        let items: Vec<u64> = (0..1000).collect();
        let out = pool.map_chunks(&items, 64, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = Pool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
