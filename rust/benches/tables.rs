//! Bench target covering Tables I and III: live recomputation of the
//! scaling-factor table and the termination/rounding worked examples.

use posit_div::division::{scaling, Algorithm, Divider};
use posit_div::posit::Posit;

fn main() {
    println!("Table I (scaling factors, radix-4 a=2):");
    for idx in 0..8 {
        let (s1, s2) = scaling::COMPONENTS[idx];
        println!(
            "  d=0.1{:03b}xxx  M={:<6} components: 1 + 1/{}{}",
            idx,
            scaling::M8[idx] as f64 / 8.0,
            1u32 << s1,
            if s2 != 0 { format!(" + 1/{}", 1u32 << s2) } else { String::new() }
        );
    }

    println!("\nTable III (Posit10 termination/rounding examples):");
    // Posit10 — the runtime-n Divider covers the paper's odd widths too.
    let ctx = Divider::new(10, Algorithm::Srt4CsOfFr).expect("width");
    let x = Posit::from_bits(10, 0b0011010111);
    for (d_bits, expect) in [(0b0001001100u64, 0b0110011111u64), (0b0000100110, 0b0111010000)] {
        let d = Posit::from_bits(10, d_bits);
        let q = ctx.divide(x, d).expect("width matches").result;
        println!(
            "  X=0011010111 D={:010b} -> Q={:010b} (paper {:010b}) {}",
            d_bits,
            q.to_bits(),
            expect,
            if q.to_bits() == expect { "MATCH" } else { "MISMATCH" }
        );
        assert_eq!(q.to_bits(), expect);
    }
}
