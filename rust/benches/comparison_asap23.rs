//! Bench: the §IV comparison against [14] (ASAP'23 two's-complement NRD):
//! hardware-model deltas plus measured software-engine latency deltas
//! (the extra iteration of [14] is real and measurable).

use posit_div::bench::{bench_batched, black_box, Config};
use posit_div::division::{Algorithm, Divider};
use posit_div::hardware::{report, TSMC28};
use posit_div::posit::mask;
use posit_div::testkit::Rng;

fn main() {
    print!("{}", report::render_asap23(&TSMC28));
    println!("\npaper reference points: NRD ≈ -7% area, -4.2%..-21.5% delay;");
    println!("SRT-CS delay -40.6/-62.1/-75.6%, area +16.8/13.8/12%, energy -50.2/-70.9/-81.4%\n");

    let mut rng = Rng::seeded(14);
    for n in [16u32, 32, 64] {
        let xs: Vec<u64> = (0..256).map(|_| rng.next_u64() & mask(n)).collect();
        let ds: Vec<u64> = (0..256).map(|_| (rng.next_u64() & mask(n)) | 1).collect();
        let time = |alg: Algorithm| {
            let ctx = Divider::new(n, alg).expect("width");
            let mut out = vec![0u64; xs.len()];
            bench_batched(alg.label(), Config::default(), xs.len() as u64, || {
                ctx.divide_batch(&xs, &ds, &mut out).expect("equal lengths");
                black_box(&out);
            })
            .per_op
        };
        let ours = time(Algorithm::Nrd);
        let theirs = time(Algorithm::NrdAsap23);
        println!(
            "Posit{n}: NRD {:?}/div vs NRD[14] {:?}/div ({:+.1}% software latency)",
            ours,
            theirs,
            (ours.as_secs_f64() / theirs.as_secs_f64() - 1.0) * 100.0
        );
    }
}
