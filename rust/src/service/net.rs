//! Networked serving over TCP: [`Server`] wraps a [`ShardedService`]
//! behind a `TcpListener`; [`ServiceClient`] speaks the [`super::wire`]
//! protocol from another process (or another machine).
//!
//! Threading model, per server:
//!
//! * one accept thread (`posit-div-accept`), woken from blocking
//!   `accept` on shutdown by a loopback self-connect;
//! * per connection, a reader thread (the accepted thread itself) that
//!   decodes frames, routes through the [`ShardedClient`], and hands
//!   admitted tickets to
//! * a writer thread (`posit-div-conn-writer`) that waits tickets **in
//!   submission order** and streams responses back — so responses and
//!   typed error frames arrive strictly in request order per
//!   connection, and a slow shard never blocks frame *reading*
//!   (admission control stays responsive under overload).
//!
//! Reads poll a 250 ms timeout so a server with idle connections still
//! notices shutdown promptly, and a connection that produces no complete
//! frame for [`ShardConfig::idle_timeout`] is presumed vanished
//! (half-open TCP) and closed, releasing its threads and any admission
//! state. All failure paths are typed: malformed frames get
//! [`PositError::Protocol`] error frames, admission sheds get
//! [`PositError::ServiceOverloaded`], expired deadlines get
//! [`PositError::DeadlineExceeded`] (stamped from the instant the server
//! starts reading the frame, so time on the wire counts), and a dead
//! peer just ends the connection's threads — the server never panics on
//! client input.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use super::wire::{self, Frame, FrameKind};
use super::{ShardConfig, ShardTicket, ShardedClient, ShardedService};
use crate::coordinator::Histogram;
use crate::error::{PositError, Result};
use crate::posit::{mask, Posit};
use crate::unit::{Accuracy, OpRequest};
use crate::workload::OpenLoop;

/// How long a server-side read blocks before re-checking the stop flag.
const READ_POLL: Duration = Duration::from_millis(250);

fn io_err(what: &str, e: std::io::Error) -> PositError {
    PositError::Execution { detail: format!("{what}: {e}") }
}

/// A TCP front-end over a [`ShardedService`]. Bind with
/// [`Server::bind`], then either [`Server::wait`] for a client's
/// `SHUTDOWN` frame (the `posit-div serve` loop) or stop it yourself
/// with [`Server::shutdown`]. Both return the inner service so the
/// caller can read counters and latency panels before tearing it down.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    svc: Option<ShardedService>,
}

impl Server {
    /// Start the sharded service and listen on `addr` (use port 0 for an
    /// OS-assigned port, then read [`Server::local_addr`]).
    pub fn bind(addr: impl ToSocketAddrs, cfg: ShardConfig) -> Result<Server> {
        let idle = (!cfg.idle_timeout.is_zero()).then_some(cfg.idle_timeout);
        let svc = ShardedService::start(cfg)?;
        let listener = TcpListener::bind(addr).map_err(|e| io_err("bind", e))?;
        let addr = listener.local_addr().map_err(|e| io_err("local_addr", e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let router = svc.client();
        let accept = {
            let (stop, conns) = (stop.clone(), conns.clone());
            thread::Builder::new()
                .name("posit-div-accept".into())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            break; // a shutdown self-connect lands here
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        let (stop, router) = (stop.clone(), router.clone());
                        let handle = thread::Builder::new()
                            .name("posit-div-conn".into())
                            .spawn(move || handle_conn(stream, router, stop, addr, idle))
                            .expect("spawn connection thread");
                        conns.lock().expect("connection registry lock").push(handle);
                    }
                })
                .map_err(|e| io_err("spawn accept thread", e))?
        };
        Ok(Server { addr, stop, accept: Some(accept), conns, svc: Some(svc) })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// An in-process routing handle to the same shards the TCP
    /// connections use — local and networked traffic share admission
    /// budgets and metrics.
    pub fn client(&self) -> ShardedClient {
        self.svc.as_ref().expect("service runs until wait/shutdown").client()
    }

    /// Ask the server to stop: no new connections, existing connection
    /// threads wind down at their next read poll. Idempotent.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        // wake the accept thread out of its blocking accept
        let _ = TcpStream::connect(self.addr);
    }

    /// Block until the server stops (a client `SHUTDOWN` frame, or
    /// [`Server::stop`] from another thread), join every connection, and
    /// return the inner [`ShardedService`] for final metrics.
    pub fn wait(mut self) -> ShardedService {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = {
            let mut guard = self.conns.lock().expect("connection registry lock");
            std::mem::take(&mut *guard)
        };
        for h in handles {
            let _ = h.join();
        }
        self.svc.take().expect("service present until wait/shutdown")
    }

    /// [`Server::stop`] + [`Server::wait`].
    pub fn shutdown(self) -> ShardedService {
        self.stop();
        self.wait()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if self.accept.is_some() {
            let _ = TcpStream::connect(self.addr);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = {
            let mut guard = self.conns.lock().expect("connection registry lock");
            std::mem::take(&mut *guard)
        };
        for h in handles {
            let _ = h.join();
        }
        // self.svc (if wait() was never called) drops here, joining the
        // shard leaders.
    }
}

/// What the connection's reader hands its writer. Channel order == wire
/// order: the writer waits tickets FIFO, so per-connection responses are
/// strictly in request order.
enum Reply {
    /// An admitted request: wait the shard, then write the response (or
    /// the typed error the shard produced).
    Ticket(u64, ShardTicket),
    /// Rejected before admission (shed, malformed, width mismatch):
    /// write the typed error frame immediately.
    Reject(u64, PositError),
}

fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<Reply>) {
    let mut w = BufWriter::new(stream);
    while let Ok(reply) = rx.recv() {
        let mut next = Some(reply);
        while let Some(r) = next {
            if write_reply(&mut w, r).is_err() {
                return; // peer gone; the reader thread notices on its own
            }
            next = rx.try_recv().ok();
        }
        if w.flush().is_err() {
            return;
        }
    }
}

fn write_reply(w: &mut impl Write, reply: Reply) -> Result<()> {
    match reply {
        Reply::Ticket(id, ticket) => {
            let flags = if ticket.degraded() { wire::RESPONSE_FLAG_DEGRADED } else { 0 };
            match ticket.wait() {
                Ok(p) => wire::write_frame(
                    w,
                    FrameKind::Response,
                    &wire::encode_response(id, p.to_bits(), flags),
                ),
                Err(e) => wire::write_frame(w, FrameKind::Error, &wire::encode_error(id, &e)),
            }
        }
        Reply::Reject(id, e) => {
            wire::write_frame(w, FrameKind::Error, &wire::encode_error(id, &e))
        }
    }
}

enum Step {
    /// A complete frame, stamped with the instant its header finished
    /// arriving — the request's admission clock starts here, so a
    /// slow-trickled payload counts against its deadline.
    Frame(Frame, Instant),
    /// Clean end of stream at a frame boundary.
    Eof,
    /// The server's stop flag went up while we were waiting.
    Stopped,
    /// No complete frame arrived within the connection's idle budget —
    /// the peer is presumed vanished (half-open TCP).
    Idle,
}

enum Fill {
    Done,
    Eof,
    Stopped,
    Idle,
}

/// Fill `buf` from a timeout-polling stream without losing partial
/// progress (unlike `read_exact`, which discards it on `WouldBlock`).
/// `give_up` is the idle deadline: if it passes while we are still
/// waiting, the read abandons the connection with [`Fill::Idle`].
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    at_boundary: bool,
    give_up: Option<Instant>,
) -> Result<Fill> {
    let mut pos = 0;
    while pos < buf.len() {
        match stream.read(&mut buf[pos..]) {
            Ok(0) => {
                return if pos == 0 && at_boundary {
                    Ok(Fill::Eof)
                } else {
                    Err(PositError::Protocol {
                        detail: "truncated frame: connection closed mid-frame".into(),
                    })
                }
            }
            Ok(k) => pos += k,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Acquire) {
                    return Ok(Fill::Stopped);
                }
                if give_up.is_some_and(|at| Instant::now() >= at) {
                    return Ok(Fill::Idle);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(io_err("socket read", e)),
        }
    }
    Ok(Fill::Done)
}

/// Read one frame; `idle` bounds how long the *whole frame* (header and
/// payload together) may take to arrive before the connection is
/// declared idle.
fn read_step(stream: &mut TcpStream, stop: &AtomicBool, idle: Option<Duration>) -> Result<Step> {
    let give_up = idle.map(|d| Instant::now() + d);
    let mut header = [0u8; wire::HEADER_LEN];
    match read_full(stream, &mut header, stop, true, give_up)? {
        Fill::Done => {}
        Fill::Eof => return Ok(Step::Eof),
        Fill::Stopped => return Ok(Step::Stopped),
        Fill::Idle => return Ok(Step::Idle),
    }
    let arrival = Instant::now();
    let (kind, len) = wire::parse_header(&header)?;
    let mut payload = vec![0u8; len];
    match read_full(stream, &mut payload, stop, false, give_up)? {
        Fill::Done => Ok(Step::Frame(Frame { kind, payload }, arrival)),
        Fill::Stopped => Ok(Step::Stopped),
        Fill::Idle => Ok(Step::Idle),
        Fill::Eof => unreachable!("payload reads are never at a frame boundary"),
    }
}

fn handle_conn(
    mut stream: TcpStream,
    router: ShardedClient,
    stop: Arc<AtomicBool>,
    server_addr: SocketAddr,
    idle: Option<Duration>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let n = router.width();

    // Handshake: HELLO(n) must match the service width before any
    // request is admitted.
    let hello = match read_step(&mut stream, &stop, idle) {
        Ok(Step::Frame(f, _)) if f.kind == FrameKind::Hello => f,
        Ok(_) => return,
        Err(e) => {
            let _ = wire::write_frame(&mut stream, FrameKind::Error, &wire::encode_error(0, &e));
            return;
        }
    };
    match wire::decode_hello(&hello.payload) {
        Ok(got) if got == n => {}
        Ok(got) => {
            let e = PositError::WidthMismatch { expected: n, got };
            let _ = wire::write_frame(&mut stream, FrameKind::Error, &wire::encode_error(0, &e));
            return;
        }
        Err(e) => {
            let _ = wire::write_frame(&mut stream, FrameKind::Error, &wire::encode_error(0, &e));
            return;
        }
    }
    if wire::write_frame(
        &mut stream,
        FrameKind::Welcome,
        &wire::encode_welcome(n, router.shards()),
    )
    .is_err()
    {
        return;
    }

    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = mpsc::channel::<Reply>();
    let writer = thread::Builder::new()
        .name("posit-div-conn-writer".into())
        .spawn(move || writer_loop(writer_stream, rx))
        .expect("spawn connection writer thread");

    loop {
        match read_step(&mut stream, &stop, idle) {
            Ok(Step::Frame(f, arrival)) => match f.kind {
                FrameKind::Request => {
                    let reply = match wire::decode_request(&f.payload, n) {
                        Ok((id, req)) => match router.submit_op_at(req, arrival) {
                            Ok(ticket) => Reply::Ticket(id, ticket),
                            Err(e) => Reply::Reject(id, e),
                        },
                        Err(e) => Reply::Reject(wire::request_id(&f.payload).unwrap_or(0), e),
                    };
                    if tx.send(reply).is_err() {
                        break;
                    }
                }
                FrameKind::Bye => break,
                FrameKind::Shutdown => {
                    stop.store(true, Ordering::Release);
                    // wake the accept thread so the whole server drains
                    let _ = TcpStream::connect(server_addr);
                    break;
                }
                other => {
                    let e = PositError::Protocol {
                        detail: format!("unexpected {other:?} frame from a client"),
                    };
                    let _ = tx.send(Reply::Reject(0, e));
                    break;
                }
            },
            // Idle: the peer went quiet past the configured budget —
            // close the connection so its threads (and, via the drained
            // writer below, any in-flight admission slots) are released
            // instead of leaking on a half-open socket.
            Ok(Step::Eof) | Ok(Step::Stopped) | Ok(Step::Idle) => break,
            Err(e) => {
                // framing is broken; answer typed, then drop the stream
                let _ = tx.send(Reply::Reject(0, e));
                break;
            }
        }
    }
    drop(tx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

/// Default pipelining window of [`ServiceClient::run_ops`]: how many
/// requests may be on the wire before the client reads a response.
pub const DEFAULT_WINDOW: usize = 512;

/// Like [`wire::read_frame`] but over a stream with an OS read timeout:
/// a `WouldBlock`/`TimedOut` expiry surfaces as the typed
/// [`PositError::Timeout`] instead of an opaque execution error. A
/// half-read frame may remain buffered afterwards — the connection is
/// poisoned and must be discarded.
fn read_frame_or_timeout(
    r: &mut impl Read,
    timeout: Option<Duration>,
    what: &str,
) -> Result<Frame> {
    fn exact(
        r: &mut impl Read,
        buf: &mut [u8],
        timeout: Option<Duration>,
        what: &str,
        part: &str,
    ) -> Result<()> {
        r.read_exact(buf).map_err(|e| match e.kind() {
            std::io::ErrorKind::UnexpectedEof => PositError::Protocol {
                detail: format!("truncated frame: stream ended inside the {part}"),
            },
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                PositError::Timeout {
                    what: format!("socket read ({what})"),
                    after: timeout.unwrap_or_default(),
                }
            }
            _ => io_err("socket read", e),
        })
    }
    let mut header = [0u8; wire::HEADER_LEN];
    exact(r, &mut header, timeout, what, "header")?;
    let (kind, len) = wire::parse_header(&header)?;
    let mut payload = vec![0u8; len];
    exact(r, &mut payload, timeout, what, "payload")?;
    Ok(Frame { kind, payload })
}

/// Socket timeouts for [`ServiceClient::connect_with`]. After a
/// [`PositError::Timeout`] the connection's stream state is
/// indeterminate (a frame may be half-read): discard the client and
/// reconnect — ops are pure, so replay is safe.
#[derive(Clone, Copy, Debug)]
pub struct ConnectOptions {
    /// TCP connect budget. `None` blocks as long as the OS does.
    pub connect_timeout: Option<Duration>,
    /// Per-read budget while waiting for a reply frame. `None` blocks
    /// forever (the pre-timeout behavior).
    pub read_timeout: Option<Duration>,
}

impl Default for ConnectOptions {
    fn default() -> Self {
        ConnectOptions {
            connect_timeout: Some(Duration::from_secs(5)),
            read_timeout: Some(Duration::from_secs(30)),
        }
    }
}

/// A blocking client for one server connection. Not thread-safe by
/// design — open one connection per driver thread; the server handles
/// each concurrently.
pub struct ServiceClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    n: u32,
    shards: usize,
    next_id: u64,
    window: usize,
    read_timeout: Option<Duration>,
    degraded_replies: u64,
    stale_replies: u64,
}

impl ServiceClient {
    /// Connect and handshake at posit width `n` with the default
    /// timeouts ([`ConnectOptions::default`]: 5 s connect, 30 s read). A
    /// width the server does not serve fails here with
    /// [`PositError::WidthMismatch`]; an unresponsive endpoint with
    /// [`PositError::Timeout`].
    pub fn connect(addr: impl ToSocketAddrs, n: u32) -> Result<ServiceClient> {
        ServiceClient::connect_with(addr, n, ConnectOptions::default())
    }

    /// [`ServiceClient::connect`] with explicit socket timeouts.
    pub fn connect_with(
        addr: impl ToSocketAddrs,
        n: u32,
        opts: ConnectOptions,
    ) -> Result<ServiceClient> {
        let stream = match opts.connect_timeout {
            Some(t) => {
                let mut last = None;
                let addrs = addr
                    .to_socket_addrs()
                    .map_err(|e| io_err("resolve address", e))?;
                let mut stream = None;
                for a in addrs {
                    match TcpStream::connect_timeout(&a, t) {
                        Ok(s) => {
                            stream = Some(s);
                            break;
                        }
                        Err(e) => last = Some((a, e)),
                    }
                }
                match (stream, last) {
                    (Some(s), _) => s,
                    (None, Some((a, e)))
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        return Err(PositError::Timeout {
                            what: format!("connect {a}"),
                            after: t,
                        })
                    }
                    (None, Some((_, e))) => return Err(io_err("connect", e)),
                    (None, None) => {
                        return Err(PositError::Execution {
                            detail: "connect: address resolved to nothing".into(),
                        })
                    }
                }
            }
            None => TcpStream::connect(addr).map_err(|e| io_err("connect", e))?,
        };
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(opts.read_timeout)
            .map_err(|e| io_err("set read timeout", e))?;
        let read_half = stream.try_clone().map_err(|e| io_err("clone stream", e))?;
        let mut client = ServiceClient {
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
            n,
            shards: 0,
            next_id: 1,
            window: DEFAULT_WINDOW,
            read_timeout: opts.read_timeout,
            degraded_replies: 0,
            stale_replies: 0,
        };
        client.send(FrameKind::Hello, &wire::encode_hello(n))?;
        client.flush()?;
        let f = client.read_frame_timed("reply frame (handshake)")?;
        match f.kind {
            FrameKind::Welcome => {
                let (served, shards) = wire::decode_welcome(&f.payload)?;
                if served != n {
                    return Err(PositError::WidthMismatch { expected: served, got: n });
                }
                client.shards = shards;
                Ok(client)
            }
            FrameKind::Error => Err(wire::decode_error(&f.payload)?.1),
            other => Err(PositError::Protocol {
                detail: format!("expected WELCOME, got {other:?}"),
            }),
        }
    }

    /// Read one frame, mapping a socket-timeout expiry to the typed
    /// [`PositError::Timeout`] (the stream may hold a half-read frame
    /// afterwards — callers must treat the connection as poisoned).
    fn read_frame_timed(&mut self, what: &str) -> Result<Frame> {
        read_frame_or_timeout(&mut self.reader, self.read_timeout, what)
    }

    /// Posit width negotiated with the server.
    pub fn width(&self) -> u32 {
        self.n
    }

    /// Shard count the server reported at handshake.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Cap on in-flight pipelined requests (min 1).
    pub fn set_window(&mut self, window: usize) {
        self.window = window.max(1);
    }

    /// Replies that arrived flagged [`wire::RESPONSE_FLAG_DEGRADED`]
    /// (brown-out served on the Approx tier) over this connection's
    /// lifetime.
    pub fn degraded_replies(&self) -> u64 {
        self.degraded_replies
    }

    /// Replies for already-settled request ids that were discarded
    /// (duplicates from a retransmitted frame the server answered twice)
    /// — the client-side half of the safe-replay contract.
    pub fn stale_replies(&self) -> u64 {
        self.stale_replies
    }

    fn send(&mut self, kind: FrameKind, payload: &[u8]) -> Result<()> {
        wire::write_frame(&mut self.writer, kind, payload)
    }

    fn flush(&mut self) -> Result<()> {
        self.writer.flush().map_err(|e| io_err("socket write", e))
    }

    /// Read one RESPONSE/ERROR frame: `(id, per-request result)`.
    /// Transport-level failures are the outer error.
    fn read_reply(&mut self) -> Result<(u64, Result<Posit>)> {
        let f = self.read_frame_timed("reply frame")?;
        match f.kind {
            FrameKind::Response => {
                let (id, bits, flags) = wire::decode_response(&f.payload)?;
                if bits & !mask(self.n) != 0 {
                    return Err(PositError::Protocol {
                        detail: format!("response bits {bits:#x} exceed the Posit{} mask", self.n),
                    });
                }
                if flags & wire::RESPONSE_FLAG_DEGRADED != 0 {
                    self.degraded_replies += 1;
                }
                Ok((id, Ok(Posit::from_bits(self.n, bits))))
            }
            FrameKind::Error => {
                let (id, e) = wire::decode_error(&f.payload)?;
                Ok((id, Err(e)))
            }
            other => Err(PositError::Protocol {
                detail: format!("unexpected {other:?} frame from the server"),
            }),
        }
    }

    /// Send one REQUEST frame and flush, without waiting for the reply.
    /// Returns the wire id the reply will carry — pair with
    /// [`ServiceClient::read_reply_for`]. This is the building block the
    /// resilient layer uses to keep send and receive separable across
    /// retries.
    pub fn send_request(&mut self, req: &OpRequest) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(FrameKind::Request, &wire::encode_request(id, req))?;
        self.flush()?;
        Ok(id)
    }

    /// Read replies until the one for `id` arrives; returns its
    /// per-request result. Replies for *earlier* ids are duplicates of
    /// already-settled requests (e.g. a frame the network delivered
    /// twice, answered twice) — they are discarded and counted in
    /// [`ServiceClient::stale_replies`], never surfaced, so one logical
    /// request can never complete twice through this path. A reply for a
    /// *later* id is a protocol violation.
    pub fn read_reply_for(&mut self, id: u64) -> Result<Result<Posit>> {
        loop {
            let (rid, result) = self.read_reply()?;
            if rid == id {
                return Ok(result);
            }
            if rid < id {
                self.stale_replies += 1;
                continue;
            }
            return Err(PositError::Protocol {
                detail: format!("response id {rid} ahead of request {id}"),
            });
        }
    }

    /// One blocking request round-trip.
    pub fn run_op(&mut self, req: &OpRequest) -> Result<Posit> {
        let id = self.send_request(req)?;
        self.read_reply_for(id)?
    }

    /// Run a batch with windowed pipelining (closed loop): up to the
    /// configured window rides the wire at once, results come back in
    /// submission order. Per-request failures (sheds, width problems)
    /// land in the inner `Result`s; a transport failure aborts the call.
    pub fn run_ops(&mut self, reqs: &[OpRequest]) -> Result<Vec<Result<Posit>>> {
        let mut out = Vec::with_capacity(reqs.len());
        let mut inflight: VecDeque<u64> = VecDeque::with_capacity(self.window);
        for req in reqs {
            if inflight.len() >= self.window {
                self.flush()?;
                self.pop_reply(&mut inflight, &mut out)?;
            }
            let id = self.next_id;
            self.next_id += 1;
            self.send(FrameKind::Request, &wire::encode_request(id, req))?;
            inflight.push_back(id);
        }
        self.flush()?;
        while !inflight.is_empty() {
            self.pop_reply(&mut inflight, &mut out)?;
        }
        Ok(out)
    }

    fn pop_reply(
        &mut self,
        inflight: &mut VecDeque<u64>,
        out: &mut Vec<Result<Posit>>,
    ) -> Result<()> {
        let expected =
            *inflight.front().expect("pop_reply called with requests in flight");
        loop {
            let (id, result) = self.read_reply()?;
            if id == expected {
                inflight.pop_front();
                out.push(result);
                return Ok(());
            }
            if id < expected {
                // duplicate reply for an already-settled id — discard
                self.stale_replies += 1;
                continue;
            }
            return Err(PositError::Protocol {
                detail: format!("out-of-order response: id {id}, expected {expected}"),
            });
        }
    }

    /// Drive an arrival-rate-paced open loop (latency measured the way
    /// an SLO sees it: from intended arrival time, unthrottled by slow
    /// responses). A writer paces requests off `wl`'s Poisson clock
    /// while a scoped reader thread drains responses concurrently.
    ///
    /// Every `verify_every`-th request (0 = never) is checked against
    /// its [`OpRequest::golden`] result, within the ulp tolerance its
    /// accuracy policy grants (`Exact` traffic must match bit-exactly,
    /// `Ulp(k)` may land up to `k` ulps away); violations count in
    /// [`OpenLoopReport::verify_failures`].
    pub fn run_open_loop(
        &mut self,
        wl: &mut OpenLoop,
        requests: usize,
        verify_every: usize,
    ) -> Result<OpenLoopReport> {
        let start = Instant::now();
        let latency = Histogram::new();
        let n = self.n;
        let read_timeout = self.read_timeout;
        let mut next_id = self.next_id;
        let mut offered = 0usize;
        // id, intended-arrival stamp, (golden bits, ulp tolerance) to
        // verify (sampled)
        let (meta_tx, meta_rx) = mpsc::channel::<(u64, Instant, Option<(u64, u64)>)>();
        let reader = &mut self.reader;
        let writer = &mut self.writer;
        let counts = thread::scope(|s| {
            let latency = &latency;
            let collector = s.spawn(move || -> Result<(usize, usize, usize, usize, usize)> {
                let (mut completed, mut shed, mut errors, mut verify_failures) = (0, 0, 0, 0);
                let mut degraded = 0;
                while let Ok((id, sent, golden)) = meta_rx.recv() {
                    let f = read_frame_or_timeout(reader, read_timeout, "open-loop reply")?;
                    let mut was_degraded = false;
                    let (rid, result) = match f.kind {
                        FrameKind::Response => {
                            let (rid, bits, flags) = wire::decode_response(&f.payload)?;
                            if flags & wire::RESPONSE_FLAG_DEGRADED != 0 {
                                degraded += 1;
                                was_degraded = true;
                            }
                            (rid, Ok(bits))
                        }
                        FrameKind::Error => {
                            let (rid, e) = wire::decode_error(&f.payload)?;
                            (rid, Err(e))
                        }
                        other => {
                            return Err(PositError::Protocol {
                                detail: format!("unexpected {other:?} frame from the server"),
                            })
                        }
                    };
                    if rid != id {
                        return Err(PositError::Protocol {
                            detail: format!("out-of-order response: id {rid}, expected {id}"),
                        });
                    }
                    latency.record(sent.elapsed());
                    match result {
                        Ok(bits) => {
                            completed += 1;
                            // a brown-out-degraded reply is bounded by the
                            // kernel's *declared* spec, not the request's
                            // own tolerance — the server-side audit panel
                            // checks that bound, so skip the client check
                            if !was_degraded
                                && golden.is_some_and(|(g, tol)| {
                                    Posit::from_bits(n, bits)
                                        .ulp_distance(Posit::from_bits(n, g))
                                        > tol
                                })
                            {
                                verify_failures += 1;
                            }
                        }
                        Err(PositError::ServiceOverloaded { .. }) => shed += 1,
                        Err(_) => errors += 1,
                    }
                }
                Ok((completed, shed, errors, verify_failures, degraded))
            });
            for i in 0..requests {
                let (at, req) = wl.next_arrival();
                loop {
                    let now = start.elapsed();
                    if now >= at {
                        break;
                    }
                    thread::sleep((at - now).min(Duration::from_millis(2)));
                }
                let id = next_id;
                next_id += 1;
                let golden = (verify_every != 0 && i % verify_every == 0).then(|| {
                    let tol = match req.accuracy() {
                        Accuracy::Exact => 0u64,
                        Accuracy::Ulp(k) => u64::from(k),
                    };
                    (req.golden().to_bits(), tol)
                });
                if meta_tx.send((id, Instant::now(), golden)).is_err() {
                    break; // collector bailed on a transport error
                }
                if wire::write_frame(writer, FrameKind::Request, &wire::encode_request(id, &req))
                    .is_err()
                    || writer.flush().is_err()
                {
                    break;
                }
                offered += 1;
            }
            drop(meta_tx);
            collector.join().expect("open-loop collector thread panicked")
        });
        self.next_id = next_id;
        let (completed, shed, errors, verify_failures, degraded) = counts?;
        self.degraded_replies += degraded as u64;
        if offered < requests {
            return Err(PositError::Execution {
                detail: format!("open-loop send aborted after {offered}/{requests} requests"),
            });
        }
        Ok(OpenLoopReport {
            offered,
            completed,
            shed,
            errors,
            verify_failures,
            degraded,
            wall: start.elapsed(),
            latency,
            width: n,
        })
    }

    /// Close this connection politely (the server keeps running).
    pub fn bye(mut self) -> Result<()> {
        self.send(FrameKind::Bye, &[])?;
        self.flush()
    }

    /// Ask the server process to stop accepting and drain — the whole
    /// server, not just this connection.
    pub fn shutdown_server(mut self) -> Result<()> {
        self.send(FrameKind::Shutdown, &[])?;
        self.flush()
    }
}

/// What an open-loop drive observed, client side.
#[derive(Debug)]
pub struct OpenLoopReport {
    /// Requests actually sent (== the requested count unless the
    /// transport died).
    pub offered: usize,
    /// Successful responses.
    pub completed: usize,
    /// Typed [`PositError::ServiceOverloaded`] sheds.
    pub shed: usize,
    /// Other per-request errors.
    pub errors: usize,
    /// Sampled responses that disagreed with [`OpRequest::golden`].
    pub verify_failures: usize,
    /// Responses flagged brown-out-degraded (served approx under load).
    pub degraded: usize,
    /// Wall-clock time of the whole drive.
    pub wall: Duration,
    /// Client-observed latency from intended arrival to response — the
    /// open-loop (SLO) view, which includes queueing delay the server
    /// cannot see.
    pub latency: Histogram,
    /// Posit width driven.
    pub width: u32,
}

impl OpenLoopReport {
    /// Achieved throughput in responses (of any kind) per second.
    pub fn achieved_rate(&self) -> f64 {
        let done = (self.completed + self.shed + self.errors) as f64;
        done / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "offered={} completed={} shed={} errors={} verify_failures={} degraded={} \
             wall={:?} rtt: {}",
            self.offered,
            self.completed,
            self.shed,
            self.errors,
            self.verify_failures,
            self.degraded,
            self.wall,
            self.latency.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, BatchPolicy, ServiceConfig};
    use crate::division::Algorithm;
    use crate::unit::ExecTier;
    use crate::workload::{take_requests, MixedOps, OpMix};

    fn shard_cfg(n: u32) -> ShardConfig {
        ShardConfig {
            shards: 2,
            queue_capacity: 1024,
            soft_capacity: 1024,
            idle_timeout: ShardConfig::DEFAULT_IDLE_TIMEOUT,
            service: ServiceConfig {
                n,
                backend: Backend::Native { alg: Algorithm::DEFAULT, threads: 2 },
                policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) },
                tier: ExecTier::Auto,
            },
        }
    }

    #[test]
    fn loopback_roundtrip_and_shutdown() {
        let server = Server::bind("127.0.0.1:0", shard_cfg(16)).unwrap();
        let mut client = ServiceClient::connect(server.local_addr(), 16).unwrap();
        assert_eq!(client.width(), 16);
        assert_eq!(client.shards(), 2);

        let one = Posit::one(16);
        assert_eq!(client.run_op(&OpRequest::sqrt(one)).unwrap(), one);

        // pipelined mixed traffic, golden-verified end to end
        let mix = OpMix::parse("div:3,sqrt:1,mul:2,add:2,dot:1,fsum:1,axpy:1").unwrap();
        let reqs = take_requests(&mut MixedOps::new(16, mix, 7), 200);
        let results = client.run_ops(&reqs).unwrap();
        assert_eq!(results.len(), reqs.len());
        for (req, r) in reqs.iter().zip(&results) {
            assert_eq!(*r.as_ref().unwrap(), req.golden(), "op {}", req.op);
        }

        client.shutdown_server().unwrap();
        let svc = server.wait();
        assert_eq!(svc.total_requests(), 201);
        assert_eq!(svc.shed_total(), 0);
        assert!(svc.counters_render().contains("shard 0: requests="));
        svc.shutdown();
    }

    #[test]
    fn handshake_rejects_width_mismatch() {
        let server = Server::bind("127.0.0.1:0", shard_cfg(16)).unwrap();
        let e = ServiceClient::connect(server.local_addr(), 32).unwrap_err();
        assert_eq!(e, PositError::WidthMismatch { expected: 16, got: 32 });
        let svc = server.shutdown();
        assert_eq!(svc.total_requests(), 0);
        svc.shutdown();
    }

    /// Regression for the half-open-connection leak: a client that
    /// vanishes without `BYE` (no FIN reaches the server, or it stops
    /// sending mid-stream) must not pin its connection threads forever —
    /// the idle timeout reaps it, and the server stays healthy for new
    /// connections.
    #[test]
    fn idle_connection_is_reaped() {
        let mut cfg = shard_cfg(16);
        cfg.idle_timeout = Duration::from_millis(300);
        let server = Server::bind("127.0.0.1:0", cfg).unwrap();
        let addr = server.local_addr();

        // a raw handshaken connection that then goes silent
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        wire::write_frame(&mut stream, FrameKind::Hello, &wire::encode_hello(16)).unwrap();
        let f = wire::read_frame(&mut stream).unwrap();
        assert_eq!(f.kind, FrameKind::Welcome);

        // the server must close it once the idle budget passes: the next
        // read sees EOF (not a hang)
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut probe = [0u8; 1];
        match stream.read(&mut probe) {
            Ok(0) => {} // clean server-side close
            Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset => {}
            other => panic!("expected server-side close of the idle conn, got {other:?}"),
        }

        // the server still serves fresh connections afterwards
        let mut client = ServiceClient::connect(addr, 16).unwrap();
        assert_eq!(client.run_op(&OpRequest::sqrt(Posit::one(16))).unwrap(), Posit::one(16));
        client.shutdown_server().unwrap();
        server.wait().shutdown();
    }

    /// A server that accepts but never answers must surface as the typed
    /// [`PositError::Timeout`], not a forever-blocked client.
    #[test]
    fn unresponsive_endpoint_times_out_typed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = thread::spawn(move || {
            // accept, read the HELLO, never reply
            let (mut s, _) = listener.accept().unwrap();
            let mut sink = [0u8; 64];
            let _ = s.read(&mut sink);
            thread::sleep(Duration::from_millis(600));
        });
        let opts = ConnectOptions {
            connect_timeout: Some(Duration::from_secs(5)),
            read_timeout: Some(Duration::from_millis(150)),
        };
        let t0 = Instant::now();
        match ServiceClient::connect_with(addr, 16, opts).unwrap_err() {
            PositError::Timeout { what, after } => {
                assert!(what.contains("socket read"), "{what}");
                assert_eq!(after, Duration::from_millis(150));
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "timeout did not bound the wait");
        hold.join().unwrap();
    }
}
