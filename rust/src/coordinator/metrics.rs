//! Service metrics: counters and a log-bucketed latency histogram
//! (hand-rolled — no external metrics crates in the offline build).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::unit::{ExecTier, FastPath, Op};

/// Power-of-two-bucketed latency histogram, lock-free on the record path.
/// Bucket i counts samples in [2^i, 2^(i+1)) nanoseconds, i < 48.
pub struct Histogram {
    buckets: [AtomicU64; 48],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let idx = (63 - ns.max(1).leading_zeros()).min(47) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }

    /// Approximate quantile from the bucket distribution (upper bound of
    /// the bucket containing the q-th sample).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(1u64 << (i + 1));
            }
        }
        self.max()
    }

    pub fn summary(&self) -> String {
        format!(
            "count={} mean={:?} p50<={:?} p99<={:?} max={:?}",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.99),
            self.max()
        )
    }
}

/// Per-operation-kind request counters (division counts one bucket
/// regardless of algorithm).
#[derive(Default)]
pub struct OpCounters {
    pub div: AtomicU64,
    pub sqrt: AtomicU64,
    pub mul: AtomicU64,
    pub add: AtomicU64,
    pub sub: AtomicU64,
    pub mul_add: AtomicU64,
    pub dot: AtomicU64,
    pub fused_sum: AtomicU64,
    pub axpy: AtomicU64,
}

impl OpCounters {
    fn counter(&self, op: Op) -> &AtomicU64 {
        match op {
            Op::Div { .. } => &self.div,
            Op::Sqrt => &self.sqrt,
            Op::Mul => &self.mul,
            Op::Add => &self.add,
            Op::Sub => &self.sub,
            Op::MulAdd => &self.mul_add,
            Op::Dot => &self.dot,
            Op::FusedSum => &self.fused_sum,
            Op::Axpy => &self.axpy,
        }
    }

    pub fn record(&self, op: Op) {
        self.counter(op).fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self, op: Op) -> u64 {
        self.counter(op).load(Ordering::Relaxed)
    }

    pub fn summary(&self) -> String {
        format!(
            "div={} sqrt={} mul={} add={} sub={} mul_add={} dot={} fsum={} axpy={}",
            self.div.load(Ordering::Relaxed),
            self.sqrt.load(Ordering::Relaxed),
            self.mul.load(Ordering::Relaxed),
            self.add.load(Ordering::Relaxed),
            self.sub.load(Ordering::Relaxed),
            self.mul_add.load(Ordering::Relaxed),
            self.dot.load(Ordering::Relaxed),
            self.fused_sum.load(Ordering::Relaxed),
            self.axpy.load(Ordering::Relaxed),
        )
    }
}

/// Requests served per execution tier: the fast kernels, the
/// cycle-accurate datapath engines, or the PJRT graph. The fast tier is
/// further split per serving kernel (`fast_table`/`fast_simd` — the
/// Posit8 lookup tables and the SWAR lane-packed kernels; the remainder
/// of `fast` ran on the scalar-fast kernels).
#[derive(Default)]
pub struct TierCounters {
    pub fast: AtomicU64,
    /// Fast-tier requests served by the exhaustive Posit8 tables
    /// (a subset of `fast`).
    pub fast_table: AtomicU64,
    /// Fast-tier requests served by the SWAR lane-packed kernels
    /// (a subset of `fast`).
    pub fast_simd: AtomicU64,
    pub datapath: AtomicU64,
    pub pjrt: AtomicU64,
}

impl TierCounters {
    /// Record `count` requests served by a *resolved* native tier
    /// (`Auto` is resolved by the unit before it gets here).
    pub fn record(&self, tier: ExecTier, count: u64) {
        debug_assert_ne!(tier, ExecTier::Auto, "record the resolved tier");
        match tier {
            ExecTier::Fast | ExecTier::Auto => self.fast.fetch_add(count, Ordering::Relaxed),
            ExecTier::Datapath => self.datapath.fetch_add(count, Ordering::Relaxed),
        };
    }

    /// Record which Fast kernel served `count` already-`record`ed
    /// fast-tier requests (`Unit::resolve_fast_path`); scalar-fast
    /// requests are the `fast` remainder and need no sub-counter.
    pub fn record_fast_path(&self, path: FastPath, count: u64) {
        match path {
            FastPath::Table => {
                self.fast_table.fetch_add(count, Ordering::Relaxed);
            }
            FastPath::Simd => {
                self.fast_simd.fetch_add(count, Ordering::Relaxed);
            }
            _ => {}
        }
    }

    /// Record `count` requests served by the PJRT graph.
    pub fn record_pjrt(&self, count: u64) {
        self.pjrt.fetch_add(count, Ordering::Relaxed);
    }

    /// Requests served by a native tier (`Auto` reads the fast counter).
    pub fn get(&self, tier: ExecTier) -> u64 {
        match tier {
            ExecTier::Fast | ExecTier::Auto => self.fast.load(Ordering::Relaxed),
            ExecTier::Datapath => self.datapath.load(Ordering::Relaxed),
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "fast={} (table={} simd={}) datapath={} pjrt={}",
            self.fast.load(Ordering::Relaxed),
            self.fast_table.load(Ordering::Relaxed),
            self.fast_simd.load(Ordering::Relaxed),
            self.datapath.load(Ordering::Relaxed),
            self.pjrt.load(Ordering::Relaxed),
        )
    }
}

/// Aggregated service counters.
#[derive(Default)]
pub struct Metrics {
    /// Per-request end-to-end latency (enqueue → response).
    pub request_latency: Histogram,
    /// Per-batch execution latency at the backend.
    pub batch_latency: Histogram,
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub special_results: AtomicU64,
    /// Requests served, split by operation kind.
    pub ops: OpCounters,
    /// Requests served, split by execution tier.
    pub tiers: TierCounters,
}

impl Metrics {
    pub fn mean_batch_fill(&self, max_batch: usize) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        let r = self.requests.load(Ordering::Relaxed);
        r as f64 / b as f64 / max_batch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_nanos(i * 100));
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.quantile(0.99) <= h.max() * 2);
        assert!(h.mean().as_nanos() > 0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn op_counters_bucket_by_kind() {
        let c = OpCounters::default();
        c.record(Op::DIV);
        c.record(Op::Div { alg: crate::division::Algorithm::Nrd });
        c.record(Op::Sqrt);
        c.record(Op::MulAdd);
        c.record(Op::Dot);
        c.record(Op::Dot);
        c.record(Op::FusedSum);
        c.record(Op::Axpy);
        assert_eq!(c.get(Op::DIV), 2, "division buckets ignore the algorithm");
        assert_eq!(c.get(Op::Sqrt), 1);
        assert_eq!(c.get(Op::Mul), 0);
        assert_eq!(c.get(Op::MulAdd), 1);
        assert_eq!(c.get(Op::Dot), 2);
        assert_eq!(c.get(Op::FusedSum), 1);
        assert_eq!(c.get(Op::Axpy), 1);
        let s = c.summary();
        assert!(s.contains("div=2") && s.contains("mul_add=1"), "{s}");
        assert!(s.contains("dot=2") && s.contains("fsum=1") && s.contains("axpy=1"), "{s}");
    }

    #[test]
    fn tier_counters_bucket_and_summarize() {
        let t = TierCounters::default();
        t.record(ExecTier::Fast, 100);
        t.record(ExecTier::Datapath, 7);
        t.record_pjrt(3);
        assert_eq!(t.get(ExecTier::Fast), 100);
        assert_eq!(t.get(ExecTier::Datapath), 7);
        assert_eq!(t.pjrt.load(Ordering::Relaxed), 3);
        let s = t.summary();
        assert!(s.contains("fast=100") && s.contains("datapath=7") && s.contains("pjrt=3"), "{s}");
    }

    #[test]
    fn fast_path_counters_split_the_fast_tier() {
        let t = TierCounters::default();
        t.record(ExecTier::Fast, 90);
        t.record_fast_path(FastPath::Table, 50);
        t.record_fast_path(FastPath::Simd, 30);
        // scalar-fast requests are the remainder; recording them is a no-op
        t.record_fast_path(FastPath::Scalar, 10);
        assert_eq!(t.fast.load(Ordering::Relaxed), 90);
        assert_eq!(t.fast_table.load(Ordering::Relaxed), 50);
        assert_eq!(t.fast_simd.load(Ordering::Relaxed), 30);
        let s = t.summary();
        assert!(s.contains("table=50") && s.contains("simd=30"), "{s}");
    }

    #[test]
    fn record_is_thread_safe() {
        let h = std::sync::Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..10_000 {
                        h.record(Duration::from_nanos(i));
                    }
                });
            }
        });
        assert_eq!(h.count(), 80_000);
    }
}
