//! The `posit-div` wire protocol: length-prefixed binary frames over a
//! byte stream (TCP in production, any `Read`/`Write` in tests).
//!
//! This module is the **normative implementation** of the frame format
//! documented in `docs/SERVING.md`; the two must not drift. Everything
//! is `std`-only and little-endian on the wire.
//!
//! ```text
//! frame   := header payload
//! header  := magic(2) version(1) kind(1) len(4, u32 LE)   ; 8 bytes
//! payload := len bytes, len <= MAX_FRAME
//! ```
//!
//! Frame kinds and payload layouts (all integers little-endian):
//!
//! | kind | code | payload |
//! |------|------|---------|
//! | `HELLO`    | 0x01 | `n: u8` — the client's posit width |
//! | `WELCOME`  | 0x02 | `n: u8, shards: u16` |
//! | `REQUEST`  | 0x03 | `id: u64, opcode: u8, alg: u8, a: u64, b: u64, c: u64, va_len: u32, vb_len: u32, accuracy: u8, max_ulp: u32, deadline_ms: u32, va: u64 × va_len, vb: u64 × vb_len` |
//! | `RESPONSE` | 0x04 | `id: u64, bits: u64, flags: u8` |
//! | `ERROR`    | 0x05 | `id: u64, code: u8, aux0: u32, aux1: u32, aux2: u32, msg_len: u16, msg: utf-8 × msg_len` |
//! | `BYE`      | 0x06 | empty |
//! | `SHUTDOWN` | 0x07 | empty |
//!
//! `REQUEST` opcodes are [`crate::unit::Op::kind_index`] values (div=0 …
//! axpy=8); `alg` indexes [`Algorithm::ALL`] for division and must be 0
//! otherwise. Scalar ops put their 1–3 operands in slots `a`/`b`/`c`
//! (unused slots must be 0) with `va_len = vb_len = 0`; reductions put
//! their vectors in `va`/`vb` with `a = b = 0` and the `Axpy`
//! coefficient in `c`. Operand words must fit the negotiated width's
//! bit mask. Violations are [`PositError::Protocol`] — never a panic.
//!
//! `accuracy` (new in version 2) carries the per-request accuracy
//! policy ([`crate::unit::Accuracy`]): `0` = exact (`max_ulp` must be
//! 0), `1` = tolerate up to `max_ulp` ulps of rounding error, making
//! the request eligible for the server's bounded-error Approx tier.
//! Any other `accuracy` byte is a [`PositError::Protocol`] rejection.
//!
//! Version 3 adds the failure-semantics plumbing. `deadline_ms` (offset
//! 47 of `REQUEST`, `u32`, 0 = none) is the request's end-to-end budget
//! in milliseconds, measured from the moment the server starts reading
//! the frame: a request whose budget has elapsed by admission time is
//! answered with `ERROR` code 7 without consuming a shard slot.
//! `RESPONSE` grows a trailing `flags` byte whose only defined bit is
//! [`RESPONSE_FLAG_DEGRADED`] (0x01) — set when brown-out forced the
//! request onto the Approx tier; all other bits must be zero. The
//! response `id` field (offset 0, unchanged since v1) is the normative
//! request-id echo that retry deduplication keys on: a client that
//! replays a request after a timeout must discard any late reply whose
//! echoed id it has already completed.
//!
//! `ERROR` codes (`aux0..aux2` meaning depends on the code):
//!
//! | code | error | aux |
//! |------|-------|-----|
//! | 1 | [`PositError::ServiceOverloaded`] | shard, inflight, capacity |
//! | 2 | [`PositError::WidthMismatch`] | expected, got, 0 |
//! | 3 | [`PositError::Protocol`] | 0 (detail in `msg`) |
//! | 4 | [`PositError::ServiceStopped`] | 0 |
//! | 5 | other server-side failure (surfaces as [`PositError::Execution`]) | 0 (detail in `msg`) |
//! | 6 | [`PositError::WidthOutOfRange`] | n, 0, 0 |
//! | 7 | [`PositError::DeadlineExceeded`] | deadline_ms, waited_ms, 0 |
//! | 8 | [`PositError::Timeout`] | after_ms, 0, 0 (what in `msg`) |

use std::io::{Read, Write};
use std::time::Duration;

use crate::division::Algorithm;
use crate::error::{PositError, Result};
use crate::posit::{mask, Posit};
use crate::unit::{Accuracy, Op, OpRequest};

/// Leading frame bytes: `b"PD"` (posit-div).
pub const MAGIC: [u8; 2] = *b"PD";
/// Protocol version carried in every frame header. Version 2 added the
/// per-request accuracy policy (`accuracy`/`max_ulp`) to `REQUEST`;
/// version 3 added `deadline_ms` to `REQUEST` and the `flags` byte
/// (degraded-serve marker) to `RESPONSE`.
pub const VERSION: u8 = 3;

/// `RESPONSE.flags` bit: the reply was served by the Approx tier because
/// brown-out degradation forced it there (soft watermark crossed and the
/// request declared an ulp tolerance). Clear on normally-routed replies,
/// including policy-routed approx serves.
pub const RESPONSE_FLAG_DEGRADED: u8 = 0x01;
/// Header size in bytes: magic + version + kind + payload length.
pub const HEADER_LEN: usize = 8;
/// Largest accepted payload. Caps a `Dot`/`Axpy` request at ~65k lanes
/// per vector; anything larger is a [`PositError::Protocol`] rejection
/// *before* allocation.
pub const MAX_FRAME: usize = 1 << 20;

/// Frame kind tag (the header's `kind` byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    Hello,
    Welcome,
    Request,
    Response,
    Error,
    Bye,
    Shutdown,
}

impl FrameKind {
    pub fn code(self) -> u8 {
        match self {
            FrameKind::Hello => 0x01,
            FrameKind::Welcome => 0x02,
            FrameKind::Request => 0x03,
            FrameKind::Response => 0x04,
            FrameKind::Error => 0x05,
            FrameKind::Bye => 0x06,
            FrameKind::Shutdown => 0x07,
        }
    }

    pub fn from_code(code: u8) -> Option<FrameKind> {
        match code {
            0x01 => Some(FrameKind::Hello),
            0x02 => Some(FrameKind::Welcome),
            0x03 => Some(FrameKind::Request),
            0x04 => Some(FrameKind::Response),
            0x05 => Some(FrameKind::Error),
            0x06 => Some(FrameKind::Bye),
            0x07 => Some(FrameKind::Shutdown),
            _ => None,
        }
    }
}

/// One parsed frame: kind plus raw payload (decode with the typed
/// helpers below).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

fn protocol(detail: impl Into<String>) -> PositError {
    PositError::Protocol { detail: detail.into() }
}

/// Build the 8-byte header for a frame of `kind` with `len` payload
/// bytes.
pub fn header_bytes(kind: FrameKind, len: usize) -> [u8; HEADER_LEN] {
    let l = (len as u32).to_le_bytes();
    [MAGIC[0], MAGIC[1], VERSION, kind.code(), l[0], l[1], l[2], l[3]]
}

/// Parse and validate a frame header (magic, version, kind, length cap).
pub fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(FrameKind, usize)> {
    if h[0..2] != MAGIC {
        return Err(protocol(format!("bad magic {:02x}{:02x} (expected \"PD\")", h[0], h[1])));
    }
    if h[2] != VERSION {
        return Err(protocol(format!("unsupported protocol version {} (expected {VERSION})", h[2])));
    }
    let kind = FrameKind::from_code(h[3])
        .ok_or_else(|| protocol(format!("unknown frame kind {:#04x}", h[3])))?;
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]) as usize;
    if len > MAX_FRAME {
        return Err(protocol(format!("oversized frame: {len} bytes (cap {MAX_FRAME})")));
    }
    Ok((kind, len))
}

/// Write one frame (header + payload) to `w`.
pub fn write_frame(w: &mut impl Write, kind: FrameKind, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(protocol(format!(
            "refusing to send oversized frame: {} bytes (cap {MAX_FRAME})",
            payload.len()
        )));
    }
    let io = |e: std::io::Error| PositError::Execution { detail: format!("socket write: {e}") };
    w.write_all(&header_bytes(kind, payload.len())).map_err(io)?;
    w.write_all(payload).map_err(io)
}

/// Read one frame from `r`. Malformed framing (bad magic/version/kind,
/// oversized length, stream truncated mid-frame) is a typed
/// [`PositError::Protocol`]; other I/O failures surface as
/// [`PositError::Execution`].
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let mut header = [0u8; HEADER_LEN];
    read_exactly(r, &mut header, "header")?;
    let (kind, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    read_exactly(r, &mut payload, "payload")?;
    Ok(Frame { kind, payload })
}

fn read_exactly(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    r.read_exact(buf).map_err(|e| match e.kind() {
        std::io::ErrorKind::UnexpectedEof => {
            protocol(format!("truncated frame: stream ended inside the {what}"))
        }
        _ => PositError::Execution { detail: format!("socket read: {e}") },
    })
}

// ---- HELLO / WELCOME ----------------------------------------------------

pub fn encode_hello(n: u32) -> Vec<u8> {
    vec![n as u8]
}

pub fn decode_hello(p: &[u8]) -> Result<u32> {
    match p {
        [n] => Ok(*n as u32),
        _ => Err(protocol(format!("HELLO payload must be 1 byte, got {}", p.len()))),
    }
}

pub fn encode_welcome(n: u32, shards: usize) -> Vec<u8> {
    let s = (shards as u16).to_le_bytes();
    vec![n as u8, s[0], s[1]]
}

pub fn decode_welcome(p: &[u8]) -> Result<(u32, usize)> {
    match p {
        [n, s0, s1] => Ok((*n as u32, u16::from_le_bytes([*s0, *s1]) as usize)),
        _ => Err(protocol(format!("WELCOME payload must be 3 bytes, got {}", p.len()))),
    }
}

// ---- REQUEST ------------------------------------------------------------

/// Fixed-size prefix of a `REQUEST` payload (before the vector lanes):
/// id, opcode, alg, three operand words, two vector lengths, the
/// version-2 accuracy policy (`accuracy: u8` at offset 42, `max_ulp:
/// u32` at 43), and the version-3 deadline budget (`deadline_ms: u32`
/// at 47).
pub const REQUEST_PREFIX: usize = 8 + 1 + 1 + 3 * 8 + 2 * 4 + 1 + 4 + 4;

fn alg_index(alg: Algorithm) -> u8 {
    Algorithm::ALL
        .iter()
        .position(|&a| a == alg)
        .expect("every Algorithm value is listed in Algorithm::ALL") as u8
}

/// An op's wire identity: `(opcode, algorithm index)`. The router's
/// affinity hash ([`crate::service::shard_for`]) keys on exactly these
/// bytes, so "same wire identity" and "same shard" coincide.
pub fn op_code(op: Op) -> (u8, u8) {
    let alg = match op {
        Op::Div { alg } => alg_index(alg),
        _ => 0,
    };
    (op.kind_index() as u8, alg)
}

fn op_from_code(opcode: u8, alg: u8) -> Result<Op> {
    if opcode == 0 {
        return Algorithm::ALL
            .get(alg as usize)
            .map(|&a| Op::Div { alg: a })
            .ok_or_else(|| protocol(format!("unknown division algorithm index {alg}")));
    }
    if alg != 0 {
        return Err(protocol(format!("non-division opcode {opcode} with algorithm byte {alg}")));
    }
    match opcode {
        1 => Ok(Op::Sqrt),
        2 => Ok(Op::Mul),
        3 => Ok(Op::Add),
        4 => Ok(Op::Sub),
        5 => Ok(Op::MulAdd),
        6 => Ok(Op::Dot),
        7 => Ok(Op::FusedSum),
        8 => Ok(Op::Axpy),
        _ => Err(protocol(format!("unknown opcode {opcode}"))),
    }
}

/// Encode one op-tagged request under client-chosen `id`.
pub fn encode_request(id: u64, req: &OpRequest) -> Vec<u8> {
    let (opcode, alg) = op_code(req.op);
    let [a, b, c] = req.bits();
    let (va, vb): (Vec<u64>, Vec<u64>) = match req.vector_lanes() {
        Some((la, lb, _)) => (
            la.iter().map(|p| p.to_bits()).collect(),
            lb.iter().map(|p| p.to_bits()).collect(),
        ),
        None => (Vec::new(), Vec::new()),
    };
    let mut p = Vec::with_capacity(REQUEST_PREFIX + 8 * (va.len() + vb.len()));
    p.extend_from_slice(&id.to_le_bytes());
    p.push(opcode);
    p.push(alg);
    for w in [a, b, c] {
        p.extend_from_slice(&w.to_le_bytes());
    }
    p.extend_from_slice(&(va.len() as u32).to_le_bytes());
    p.extend_from_slice(&(vb.len() as u32).to_le_bytes());
    let (acc, max_ulp) = match req.accuracy() {
        Accuracy::Exact => (0u8, 0u32),
        Accuracy::Ulp(k) => (1u8, k),
    };
    p.push(acc);
    p.extend_from_slice(&max_ulp.to_le_bytes());
    p.extend_from_slice(&req.deadline_ms().to_le_bytes());
    for w in va.iter().chain(vb.iter()) {
        p.extend_from_slice(&w.to_le_bytes());
    }
    p
}

/// The request id of a `REQUEST` payload, if the prefix is present —
/// lets the server address an error frame even when the rest of the
/// payload is garbage.
pub fn request_id(p: &[u8]) -> Option<u64> {
    p.get(0..8).map(|b| u64::from_le_bytes(b.try_into().expect("8-byte slice")))
}

fn u64_at(p: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(p[off..off + 8].try_into().expect("8-byte slice"))
}

fn checked_posit(n: u32, bits: u64, what: &str) -> Result<Posit> {
    if bits & !mask(n) != 0 {
        return Err(protocol(format!("{what} bits {bits:#x} exceed the Posit{n} mask")));
    }
    Ok(Posit::from_bits(n, bits))
}

/// Decode a `REQUEST` payload against the connection's negotiated width
/// `n`. Structural garbage (bad lengths, nonzero must-be-zero slots,
/// out-of-mask operand words, unknown opcodes) is
/// [`PositError::Protocol`]; shape errors the [`OpRequest`] constructors
/// detect (mismatched reduction lanes, empty `FusedSum`) keep their own
/// typed variants.
pub fn decode_request(p: &[u8], n: u32) -> Result<(u64, OpRequest)> {
    if p.len() < REQUEST_PREFIX {
        return Err(protocol(format!(
            "REQUEST payload too short: {} bytes (prefix is {REQUEST_PREFIX})",
            p.len()
        )));
    }
    let id = u64_at(p, 0);
    let (opcode, alg) = (p[8], p[9]);
    let (a, b, c) = (u64_at(p, 10), u64_at(p, 18), u64_at(p, 26));
    let va_len = u32::from_le_bytes(p[34..38].try_into().expect("4-byte slice")) as usize;
    let vb_len = u32::from_le_bytes(p[38..42].try_into().expect("4-byte slice")) as usize;
    let max_ulp = u32::from_le_bytes(p[43..47].try_into().expect("4-byte slice"));
    let accuracy = match (p[42], max_ulp) {
        (0, 0) => Accuracy::Exact,
        (0, k) => {
            return Err(protocol(format!("exact REQUEST with nonzero ulp tolerance {k}")))
        }
        (1, k) => Accuracy::Ulp(k),
        (other, _) => return Err(protocol(format!("unknown accuracy policy byte {other}"))),
    };
    let deadline_ms = u32::from_le_bytes(p[47..51].try_into().expect("4-byte slice"));
    let expected = REQUEST_PREFIX + 8 * (va_len + vb_len);
    if p.len() != expected {
        return Err(protocol(format!(
            "REQUEST length mismatch: {} bytes for va_len={va_len} vb_len={vb_len} \
             (expected {expected})",
            p.len()
        )));
    }
    let op = op_from_code(opcode, alg)?;
    let req = if op.is_reduction() {
        if a != 0 || b != 0 {
            return Err(protocol("reduction REQUEST must zero scalar slots a/b"));
        }
        let lane = |k: usize, count: usize, what: &str| -> Result<Vec<Posit>> {
            (0..count)
                .map(|i| checked_posit(n, u64_at(p, REQUEST_PREFIX + 8 * (k + i)), what))
                .collect()
        };
        let va = lane(0, va_len, "vector lane a")?;
        let vb = lane(va_len, vb_len, "vector lane b")?;
        match op {
            Op::Dot => {
                if c != 0 {
                    return Err(protocol("Dot REQUEST must zero scalar slot c"));
                }
                OpRequest::dot(&va, &vb)?
            }
            Op::FusedSum => {
                if c != 0 {
                    return Err(protocol("FusedSum REQUEST must zero scalar slot c"));
                }
                if vb_len != 0 {
                    return Err(protocol("FusedSum REQUEST must have an empty vector lane b"));
                }
                OpRequest::fused_sum(&va)?
            }
            _ => OpRequest::axpy(checked_posit(n, c, "axpy coefficient")?, &va, &vb)?,
        }
    } else {
        if va_len != 0 || vb_len != 0 {
            return Err(protocol(format!("scalar op {} with vector lanes", op.name())));
        }
        let slots = [a, b, c];
        let arity = op.arity();
        for (k, &s) in slots.iter().enumerate().skip(arity) {
            if s != 0 {
                return Err(protocol(format!(
                    "scalar op {} uses {arity} slot(s); slot {k} must be 0",
                    op.name()
                )));
            }
        }
        let operands: Vec<Posit> = slots[..arity]
            .iter()
            .map(|&s| checked_posit(n, s, "operand"))
            .collect::<Result<_>>()?;
        OpRequest::new(op, &operands)?
    };
    Ok((id, req.with_accuracy(accuracy).with_deadline_ms(deadline_ms)))
}

// ---- RESPONSE -----------------------------------------------------------

/// Encode a `RESPONSE`: the echoed request id, the result bits, and the
/// version-3 `flags` byte ([`RESPONSE_FLAG_DEGRADED`] is the only
/// defined bit).
pub fn encode_response(id: u64, bits: u64, flags: u8) -> Vec<u8> {
    let mut p = Vec::with_capacity(17);
    p.extend_from_slice(&id.to_le_bytes());
    p.extend_from_slice(&bits.to_le_bytes());
    p.push(flags);
    p
}

/// Decode a `RESPONSE` into `(id, bits, flags)`. Undefined flag bits are
/// a [`PositError::Protocol`] rejection — a v4 server cannot silently
/// smuggle semantics past a v3 client.
pub fn decode_response(p: &[u8]) -> Result<(u64, u64, u8)> {
    if p.len() != 17 {
        return Err(protocol(format!("RESPONSE payload must be 17 bytes, got {}", p.len())));
    }
    let flags = p[16];
    if flags & !RESPONSE_FLAG_DEGRADED != 0 {
        return Err(protocol(format!("RESPONSE with undefined flag bits {flags:#04x}")));
    }
    Ok((u64_at(p, 0), u64_at(p, 8), flags))
}

// ---- ERROR --------------------------------------------------------------

fn error_code_aux(e: &PositError) -> (u8, [u32; 3], String) {
    match e {
        PositError::ServiceOverloaded { shard, inflight, capacity } => {
            (1, [*shard as u32, *inflight as u32, *capacity as u32], String::new())
        }
        PositError::WidthMismatch { expected, got } => (2, [*expected, *got, 0], String::new()),
        PositError::Protocol { detail } => (3, [0; 3], detail.clone()),
        PositError::ServiceStopped => (4, [0; 3], String::new()),
        PositError::WidthOutOfRange { n } => (6, [*n, 0, 0], String::new()),
        PositError::DeadlineExceeded { deadline_ms, waited_ms } => {
            (7, [*deadline_ms, *waited_ms, 0], String::new())
        }
        PositError::Timeout { what, after } => {
            let ms = after.as_millis().min(u128::from(u32::MAX)) as u32;
            (8, [ms, 0, 0], what.clone())
        }
        other => (5, [0; 3], other.to_string()),
    }
}

/// Encode a typed error against request `id` (0 when the error is not
/// tied to one request, e.g. a handshake failure).
pub fn encode_error(id: u64, e: &PositError) -> Vec<u8> {
    let (code, aux, msg) = error_code_aux(e);
    let msg = msg.as_bytes();
    let msg = &msg[..msg.len().min(u16::MAX as usize)];
    let mut p = Vec::with_capacity(23 + msg.len());
    p.extend_from_slice(&id.to_le_bytes());
    p.push(code);
    for a in aux {
        p.extend_from_slice(&a.to_le_bytes());
    }
    p.extend_from_slice(&(msg.len() as u16).to_le_bytes());
    p.extend_from_slice(msg);
    p
}

pub fn decode_error(p: &[u8]) -> Result<(u64, PositError)> {
    if p.len() < 23 {
        return Err(protocol(format!("ERROR payload too short: {} bytes", p.len())));
    }
    let id = u64_at(p, 0);
    let code = p[8];
    let aux = |k: usize| u32::from_le_bytes(p[9 + 4 * k..13 + 4 * k].try_into().expect("4 bytes"));
    let msg_len = u16::from_le_bytes(p[21..23].try_into().expect("2 bytes")) as usize;
    if p.len() != 23 + msg_len {
        return Err(protocol(format!(
            "ERROR length mismatch: {} bytes for msg_len={msg_len}",
            p.len()
        )));
    }
    let msg = String::from_utf8_lossy(&p[23..]).into_owned();
    let e = match code {
        1 => PositError::ServiceOverloaded {
            shard: aux(0) as usize,
            inflight: aux(1) as usize,
            capacity: aux(2) as usize,
        },
        2 => PositError::WidthMismatch { expected: aux(0), got: aux(1) },
        3 => PositError::Protocol { detail: msg },
        4 => PositError::ServiceStopped,
        5 => PositError::Execution { detail: msg },
        6 => PositError::WidthOutOfRange { n: aux(0) },
        7 => PositError::DeadlineExceeded { deadline_ms: aux(0), waited_ms: aux(1) },
        8 => PositError::Timeout { what: msg, after: Duration::from_millis(u64::from(aux(0))) },
        other => return Err(protocol(format!("unknown ERROR code {other}"))),
    };
    Ok((id, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;
    use crate::workload::{MixedOps, OpMix};
    use std::io::Cursor;

    fn roundtrip_frame(kind: FrameKind, payload: &[u8]) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind, payload).unwrap();
        read_frame(&mut Cursor::new(buf)).unwrap()
    }

    #[test]
    fn frame_roundtrip_all_kinds() {
        for kind in [
            FrameKind::Hello,
            FrameKind::Welcome,
            FrameKind::Request,
            FrameKind::Response,
            FrameKind::Error,
            FrameKind::Bye,
            FrameKind::Shutdown,
        ] {
            assert_eq!(FrameKind::from_code(kind.code()), Some(kind));
            let f = roundtrip_frame(kind, b"xyz");
            assert_eq!(f.kind, kind);
            assert_eq!(f.payload, b"xyz");
        }
        assert_eq!(roundtrip_frame(FrameKind::Bye, &[]).payload, b"");
    }

    #[test]
    fn malformed_headers_are_typed_protocol_errors() {
        // bad magic
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Bye, &[]).unwrap();
        buf[0] = b'X';
        let e = read_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert!(matches!(e, PositError::Protocol { .. }), "{e}");
        assert!(e.to_string().contains("magic"), "{e}");

        // bad version
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Bye, &[]).unwrap();
        buf[2] = 99;
        let e = read_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");

        // unknown kind
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Bye, &[]).unwrap();
        buf[3] = 0x7f;
        let e = read_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert!(e.to_string().contains("kind"), "{e}");

        // oversized declared length is rejected before allocating
        let mut buf = header_bytes(FrameKind::Request, 0).to_vec();
        buf[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = read_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert!(e.to_string().contains("oversized"), "{e}");

        // truncated: header promises more payload than the stream holds
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Response, &encode_response(1, 2, 0)).unwrap();
        buf.truncate(buf.len() - 5);
        let e = read_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert!(matches!(e, PositError::Protocol { .. }), "{e}");
        assert!(e.to_string().contains("truncated"), "{e}");

        // truncated mid-header
        let e = read_frame(&mut Cursor::new(&buf[..3])).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");
    }

    #[test]
    fn hello_welcome_roundtrip() {
        assert_eq!(decode_hello(&encode_hello(16)).unwrap(), 16);
        assert_eq!(decode_welcome(&encode_welcome(32, 4)).unwrap(), (32, 4));
        assert!(decode_hello(&[1, 2]).is_err());
        assert!(decode_welcome(&[16]).is_err());
    }

    /// Property: every request the mixed generator can produce (scalar
    /// ops, every division algorithm, reductions with vector lanes)
    /// round-trips bit-exactly through encode/decode.
    #[test]
    fn request_roundtrip_property() {
        let mix = OpMix::parse("div:4,sqrt:2,mul:2,add:2,sub:1,fma:1,dot:2,fsum:1,axpy:1").unwrap();
        for n in [8u32, 16, 32] {
            let mut wl = MixedOps::new(n, mix, 0x31BE ^ n as u64);
            let mut rng = Rng::seeded(n as u64);
            for i in 0..500u32 {
                let accuracy = match i % 3 {
                    0 => Accuracy::Exact,
                    1 => Accuracy::Ulp(i),
                    _ => Accuracy::Ulp(u32::MAX),
                };
                let deadline_ms = match i % 4 {
                    0 => 0,
                    1 => i,
                    2 => 1,
                    _ => u32::MAX,
                };
                let req = wl.next_request().with_accuracy(accuracy).with_deadline_ms(deadline_ms);
                let id = rng.next_u64();
                let (rid, back) = decode_request(&encode_request(id, &req), n).unwrap();
                assert_eq!(rid, id);
                assert_eq!(back.op, req.op);
                assert_eq!(back.accuracy(), req.accuracy());
                assert_eq!(back.deadline_ms(), deadline_ms);
                assert_eq!(back.bits(), req.bits());
                assert_eq!(
                    back.vector_lanes().map(|(a, b, c)| (a.to_vec(), b.to_vec(), c)),
                    req.vector_lanes().map(|(a, b, c)| (a.to_vec(), b.to_vec(), c)),
                );
                assert_eq!(back.golden(), req.golden());
            }
        }
    }

    #[test]
    fn garbage_requests_are_typed_errors() {
        let n = 16;
        let ok = encode_request(7, &OpRequest::sqrt(Posit::one(n)));

        // too short
        let e = decode_request(&ok[..20], n).unwrap_err();
        assert!(e.to_string().contains("too short"), "{e}");

        // unknown opcode
        let mut p = ok.clone();
        p[8] = 42;
        assert!(decode_request(&p, n).unwrap_err().to_string().contains("opcode"));

        // algorithm byte on a non-division op
        let mut p = ok.clone();
        p[9] = 3;
        assert!(decode_request(&p, n).unwrap_err().to_string().contains("algorithm"));

        // division with an out-of-range algorithm index
        let mut p = ok.clone();
        p[8] = 0;
        p[9] = Algorithm::ALL.len() as u8;
        assert!(decode_request(&p, n).unwrap_err().to_string().contains("algorithm"));

        // operand bits outside the Posit16 mask
        let mut p = ok.clone();
        p[10..18].copy_from_slice(&u64::MAX.to_le_bytes());
        let e = decode_request(&p, n).unwrap_err();
        assert!(matches!(e, PositError::Protocol { .. }) && e.to_string().contains("mask"), "{e}");

        // unused scalar slot must be zero (sqrt is unary)
        let mut p = ok.clone();
        p[18..26].copy_from_slice(&1u64.to_le_bytes());
        assert!(decode_request(&p, n).unwrap_err().to_string().contains("slot"));

        // declared vector lanes on a scalar op / length mismatch
        let mut p = ok.clone();
        p[34..38].copy_from_slice(&2u32.to_le_bytes());
        assert!(decode_request(&p, n).unwrap_err().to_string().contains("length mismatch"));
        let mut p = ok;
        p[34..38].copy_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&Posit::one(n).to_bits().to_le_bytes());
        assert!(decode_request(&p, n).unwrap_err().to_string().contains("vector lanes"));

        // reduction shape errors keep their own typed variants
        let a = [Posit::one(n); 2];
        let b = [Posit::one(n); 2];
        let dot = encode_request(9, &OpRequest::dot(&a, &b).unwrap());
        let mut p = dot.clone();
        // chop one trailing lane element and fix up vb_len to match
        p.truncate(p.len() - 8);
        p[38..42].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(
            decode_request(&p, n).unwrap_err(),
            PositError::BatchLaneMismatch { .. }
        ));
    }

    /// The accuracy policy occupies fixed byte positions (42 and 43..47)
    /// so mixed-version tooling can inspect it without a full decode, and
    /// inconsistent encodings are rejected as Protocol errors.
    #[test]
    fn accuracy_policy_bytes_and_rejections() {
        let n = 16;
        let exact = encode_request(1, &OpRequest::sqrt(Posit::one(n)));
        assert_eq!(exact[42], 0);
        assert_eq!(&exact[43..47], &[0u8; 4]);

        let bounded =
            encode_request(2, &OpRequest::sqrt(Posit::one(n)).with_accuracy(Accuracy::Ulp(7)));
        assert_eq!(bounded[42], 1);
        assert_eq!(&bounded[43..47], &7u32.to_le_bytes());
        let (_, back) = decode_request(&bounded, n).unwrap();
        assert_eq!(back.accuracy(), Accuracy::Ulp(7));

        // exact byte with a nonzero tolerance is contradictory
        let mut p = exact.clone();
        p[43..47].copy_from_slice(&9u32.to_le_bytes());
        let e = decode_request(&p, n).unwrap_err();
        assert!(matches!(e, PositError::Protocol { .. }), "{e}");
        assert!(e.to_string().contains("ulp tolerance"), "{e}");

        // unknown policy byte
        let mut p = exact;
        p[42] = 9;
        let e = decode_request(&p, n).unwrap_err();
        assert!(e.to_string().contains("accuracy policy"), "{e}");
    }

    /// The v3 deadline occupies fixed bytes 47..51 of the REQUEST prefix
    /// (after `max_ulp`, before the vector lanes), defaulting to 0 =
    /// no deadline; every earlier field keeps its v2 offset.
    #[test]
    fn deadline_bytes_and_roundtrip() {
        let n = 16;
        assert_eq!(REQUEST_PREFIX, 51);
        let plain = encode_request(1, &OpRequest::sqrt(Posit::one(n)));
        assert_eq!(plain.len(), REQUEST_PREFIX);
        assert_eq!(&plain[47..51], &[0u8; 4]);

        let stamped =
            encode_request(2, &OpRequest::sqrt(Posit::one(n)).with_deadline_ms(12_345));
        assert_eq!(&stamped[47..51], &12_345u32.to_le_bytes());
        let (_, back) = decode_request(&stamped, n).unwrap();
        assert_eq!(back.deadline_ms(), 12_345);
        assert_eq!(back.accuracy(), Accuracy::Exact, "deadline is orthogonal to accuracy");

        // a reduction carries the deadline in the same prefix slot, with
        // lanes following it
        let a = [Posit::one(n); 3];
        let dot = OpRequest::dot(&a, &a).unwrap().with_deadline_ms(7);
        let p = encode_request(3, &dot);
        assert_eq!(p.len(), REQUEST_PREFIX + 8 * 6);
        assert_eq!(&p[47..51], &7u32.to_le_bytes());
        let (_, back) = decode_request(&p, n).unwrap();
        assert_eq!(back.deadline_ms(), 7);
        assert_eq!(back.golden(), dot.golden());

        // a v2-length payload (prefix without the deadline word) is a
        // typed rejection, not a misparse
        let e = decode_request(&plain[..47], n).unwrap_err();
        assert!(matches!(e, PositError::Protocol { .. }), "{e}");
    }

    #[test]
    fn response_roundtrip() {
        let (id, bits, flags) = decode_response(&encode_response(0xDEAD, 0xBEEF, 0)).unwrap();
        assert_eq!((id, bits, flags), (0xDEAD, 0xBEEF, 0));
        // v2-shaped (16-byte) responses are rejected
        assert!(decode_response(&[0; 16]).is_err());
        assert!(decode_response(&[0; 15]).is_err());

        // the degraded marker round-trips; undefined bits are typed
        // Protocol rejections
        let p = encode_response(5, 9, RESPONSE_FLAG_DEGRADED);
        let (id, bits, flags) = decode_response(&p).unwrap();
        assert_eq!((id, bits), (5, 9));
        assert_eq!(flags & RESPONSE_FLAG_DEGRADED, RESPONSE_FLAG_DEGRADED);
        let mut p = encode_response(5, 9, 0);
        p[16] = 0x82;
        let e = decode_response(&p).unwrap_err();
        assert!(matches!(e, PositError::Protocol { .. }), "{e}");
        assert!(e.to_string().contains("flag bits"), "{e}");
    }

    #[test]
    fn error_roundtrip_preserves_types() {
        let cases = [
            PositError::ServiceOverloaded { shard: 2, inflight: 4096, capacity: 4096 },
            PositError::WidthMismatch { expected: 16, got: 32 },
            PositError::Protocol { detail: "bad magic".into() },
            PositError::ServiceStopped,
            PositError::WidthOutOfRange { n: 3 },
            PositError::DeadlineExceeded { deadline_ms: 50, waited_ms: 321 },
            PositError::Timeout {
                what: "socket read (header)".into(),
                after: Duration::from_millis(1500),
            },
        ];
        for e in cases {
            let (id, back) = decode_error(&encode_error(11, &e)).unwrap();
            assert_eq!(id, 11);
            assert_eq!(back, e);
        }
        // errors without a wire shape surface as Execution with the message
        let e = PositError::ArityMismatch { op: "sqrt", expected: 1, got: 2 };
        let (_, back) = decode_error(&encode_error(0, &e)).unwrap();
        assert!(matches!(back, PositError::Execution { .. }));
        assert!(back.to_string().contains("sqrt"));
        // garbage error payloads are themselves typed
        assert!(decode_error(&[0; 10]).is_err());
        let mut p = encode_error(1, &PositError::ServiceStopped);
        p[8] = 99;
        assert!(decode_error(&p).unwrap_err().to_string().contains("code"));
    }

    #[test]
    fn request_id_recovers_from_partial_garbage() {
        let p = encode_request(0x1234_5678, &OpRequest::sqrt(Posit::one(16)));
        assert_eq!(request_id(&p), Some(0x1234_5678));
        assert_eq!(request_id(&p[..4]), None);
    }
}
