//! Bench: Figs. 7–9 — pipelined synthesis sweeps at the paper's 1.5 GHz
//! target for all Table IV designs at Posit16/32/64.

use posit_div::hardware::{report, synth, Mode, TSMC28};
use posit_div::division::Algorithm;

fn main() {
    for n in report::FORMATS {
        println!("{}", report::render_figure(n, Mode::Pipelined, &TSMC28));
    }
    // critical-path attribution (the §IV observation)
    println!("critical stages @1.5GHz:");
    for n in report::FORMATS {
        for alg in Algorithm::TABLE_IV {
            let r = synth::pipelined(alg, n, &TSMC28);
            println!(
                "  Posit{:<3} {:<18} critical={:<12} cycle={:.3}ns timing_met={}",
                n, alg.label(), r.critical_stage, r.delay_ns, r.timing_met
            );
        }
    }
    println!("\nCSV:\n");
    for n in report::FORMATS {
        print!("{}", report::sweep_csv(n, Mode::Pipelined, &TSMC28));
    }
}
