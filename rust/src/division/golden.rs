//! Exact reference ("golden") division model.
//!
//! Computes the correctly-rounded posit quotient through exact integer long
//! division — no digit recurrence, no truncated estimates. Every engine in
//! this crate must match it bit-for-bit; the test-suite checks that
//! exhaustively for small widths and on millions of random cases for large
//! ones.

use super::{Division, FracQuotient};
use crate::posit::{frac_bits, round::encode_round, Posit, Unpacked};

/// Exact fraction quotient: `⌊(x_sig / d_sig) · 2^prec⌋` with sticky from
/// the remainder, delivered in the same normal form the engines use.
///
/// `prec` is chosen as `n` fractional bits — strictly more than any
/// rounding position needs (worst case requires F+1 bits below the hidden
/// one plus sticky).
pub fn frac_divide(n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
    let fb = frac_bits(n);
    debug_assert!(x_sig >> fb == 1 && d_sig >> fb == 1, "significands must be in [1,2)");
    let prec = n; // quotient fraction bits
    let num = (x_sig as u128) << prec;
    let q = num / d_sig as u128;
    let rem = num % d_sig as u128;
    // q = x/d · 2^prec ∈ (2^(prec-1), 2^(prec+1))
    FracQuotient { mag: q, frac_bits: prec, sticky: rem != 0, iterations: 0 }
}

/// Correctly-rounded posit division, fully independent of the engines'
/// recurrence machinery (shares only the posit codec).
pub fn divide(x: Posit, d: Posit) -> Division {
    assert_eq!(x.width(), d.width());
    let n = x.width();
    let result = match (x.unpack(), d.unpack()) {
        // NaR propagates; division by zero is NaR (paper §II-A).
        (Unpacked::NaR, _) | (_, Unpacked::NaR) | (_, Unpacked::Zero) => Posit::nar(n),
        (Unpacked::Zero, _) => Posit::zero(n),
        (Unpacked::Real(a), Unpacked::Real(b)) => {
            let fq = frac_divide(n, a.sig, b.sig);
            let t = a.scale - b.scale;
            // Normalize q ∈ (1/2,2) to [1,2): Fig. 2's normalization step.
            let (scale, sfb) = if fq.mag >> fq.frac_bits != 0 {
                (t, fq.frac_bits)
            } else {
                (t - 1, fq.frac_bits - 1)
            };
            encode_round(n, a.sign ^ b.sign, scale, fq.mag, sfb, fq.sticky)
        }
    };
    Division { result, iterations: 0, cycles: 0 }
}

impl FracQuotient {
    /// Reduce this quotient to `fb ≤ self.frac_bits` fraction bits,
    /// folding dropped bits into sticky — used to compare engines that
    /// produce different precisions against the golden model.
    pub fn refine_to(&self, fb: u32) -> (u128, bool) {
        assert!(fb <= self.frac_bits);
        let drop = self.frac_bits - fb;
        let mag = self.mag >> drop;
        let sticky = self.sticky || self.mag & ((1u128 << drop) - 1) != 0;
        (mag, sticky)
    }
}


/// Assert `q` is the correctly rounded posit quotient of `x/d` per the
/// 2022 standard's *pattern-space* round-to-nearest-even — the strongest
/// independent check in the suite, used by unit, integration and property
/// tests.
///
/// Key fact: the rounding boundary between two adjacent width-n posits is
/// exactly representable as the width-(n+1) posit whose pattern is
/// `(t ≪ 1) | 1` (t = the truncated pattern) — pattern-space midpoints are
/// NOT value-space midpoints across regime boundaries. All value
/// comparisons are exact integer rationals (supports n ≤ 32).
///
/// Panics on any deviation.
pub fn verify_nearest(x: Posit, d: Posit, q: Posit) {
    use core::cmp::Ordering;
    let n = x.width();
    assert!(n <= 32, "verify_nearest supports n <= 32");
    assert_eq!(
        q.is_negative(),
        x.is_negative() ^ d.is_negative(),
        "sign wrong: {x:?}/{d:?} -> {q:?}"
    );
    let (xa, da, qa) = (x.abs(), d.abs(), q.abs());
    assert!(!qa.is_zero() && !qa.is_nar(), "|q| must be a positive real");
    let (a, b) = (xa.decode(), da.decode());

    // compare x/d (positive) against posit `p` (any width) exactly:
    // A·2^(sa−sb) / B  vs  sig_p·2^(scale_p − fb_p)
    // ⇔ A·2^(sa−sb−scale_p+fb_p) vs sig_p·B (shift clamped: magnitudes
    // stay far below the clamp for n ≤ 32).
    let cmp_qd = |p: Posit| -> Ordering {
        let dp = p.decode();
        let e = a.scale - b.scale - dp.scale + crate::posit::frac_bits(p.width()) as i32;
        let lhs = a.sig as i128;
        let rhs = dp.sig as i128 * b.sig as i128;
        // Shift clamps preserve the ordering: beyond them one side
        // strictly dominates (lhs < 2^29 and rhs < 2^58 for n ≤ 32),
        // and equality is impossible in the clamped regime.
        if e >= 0 {
            (lhs << e.min(90) as u32).cmp(&rhs)
        } else {
            lhs.cmp(&(rhs << (-e).min(35) as u32))
        }
    };

    // Below minpos: standard rounds up to minpos, never to zero.
    if cmp_qd(Posit::minpos(n)) == Ordering::Less {
        assert_eq!(qa, Posit::minpos(n), "{x:?}/{d:?} must round to minpos");
        return;
    }

    // floor posit: largest magnitude pattern with value ≤ x/d
    // (patterns are monotone in value: binary search).
    let (mut lo, mut hi) = (1u64, crate::posit::mask(n - 1)); // minpos..maxpos
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if cmp_qd(Posit::from_bits(n, mid)) != Ordering::Less {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    let t = Posit::from_bits(n, lo);

    // Pattern-space midpoint: width-(n+1) posit (t ≪ 1) | 1.
    let m = Posit::from_bits(n + 1, (t.to_bits() << 1) | 1);
    let up = t.next_up(); // saturates at maxpos
    let want = match cmp_qd(m) {
        Ordering::Less => t,
        Ordering::Greater => up,
        Ordering::Equal => {
            // tie: even pattern among {t, up}; when up saturates back
            // onto maxpos (t = maxpos) the clamp keeps maxpos.
            if t.to_bits() & 1 == 0 {
                t
            } else {
                up
            }
        }
    };
    assert_eq!(qa, want, "{x:?}/{d:?}: got {q:?}, correctly rounded is {want:?}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::mask;

    #[test]
    fn frac_divide_basics() {
        // n=16, F=11: 1.0 / 1.0 = 1.0 exactly.
        let one = 1u64 << 11;
        let q = frac_divide(16, one, one);
        assert_eq!(q.mag, 1u128 << 16);
        assert!(!q.sticky);
        // 1.5 / 1.0
        let q = frac_divide(16, one | (1 << 10), one);
        assert_eq!(q.mag, 3u128 << 15);
        assert!(!q.sticky);
        // 1.0 / 1.5 = 0.666… inexact
        let q = frac_divide(16, one, one | (1 << 10));
        assert!(q.sticky);
        assert!(q.mag < (1 << 16)); // < 1: needs normalization
    }

    #[test]
    fn specials() {
        let n = 16;
        let one = Posit::one(n);
        assert!(divide(one, Posit::zero(n)).result.is_nar());
        assert!(divide(Posit::nar(n), one).result.is_nar());
        assert!(divide(one, Posit::nar(n)).result.is_nar());
        assert!(divide(Posit::zero(n), one).result.is_zero());
        assert!(divide(Posit::zero(n), Posit::zero(n)).result.is_nar());
        assert_eq!(divide(one, one).result, one);
    }

    /// Exhaustive *independent* check of the golden model for Posit⟨8,2⟩:
    /// round-to-nearest correctness is verified with exact rational
    /// midpoint comparisons (no shared code with the encode path beyond
    /// the codec itself).
    #[test]
    fn golden_p8_exhaustive_nearest_value() {
        let n = 8;
        for xb in 0..=mask(n) {
            let x = Posit::from_bits(n, xb);
            for db in 0..=mask(n) {
                let d = Posit::from_bits(n, db);
                let got = divide(x, d).result;
                if x.is_nar() || d.is_nar() || d.is_zero() {
                    assert!(got.is_nar());
                    continue;
                }
                if x.is_zero() {
                    assert!(got.is_zero());
                    continue;
                }
                verify_nearest(x, d, got);
            }
        }
    }


    #[test]
    fn refine_to_folds_sticky() {
        let fq = FracQuotient { mag: 0b10110, frac_bits: 4, sticky: false, iterations: 0 };
        let (m, s) = fq.refine_to(2);
        assert_eq!(m, 0b101);
        assert!(s);
        let (m2, s2) = fq.refine_to(4);
        assert_eq!(m2, 0b10110);
        assert!(!s2);
    }
}
