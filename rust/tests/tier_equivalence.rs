//! Tier-equivalence gates: the Fast tier (width-monomorphized direct
//! kernels) must be bit-identical to the Datapath tier (cycle-accurate
//! engines) — and both to the exact golden references — for every
//! operation, every division algorithm, and every width class, specials
//! and NaR included. These sweeps run un-`#[ignore]`d as part of tier-1
//! `cargo test`; the exhaustive Posit8 fast-tier gate lives in
//! `p8_exhaustive.rs`.

use posit_div::division::golden;
use posit_div::posit::mask;
use posit_div::prelude::*;
use posit_div::testkit::Rng;

/// Standard widths (monomorphized kernels) plus odd widths (dynamic
/// fallback) — Posit10 is the paper's worked-example format.
const WIDTHS: [u32; 5] = [8, 10, 16, 32, 64];

/// Directed operand patterns: both specials, the saturation endpoints,
/// ±1, and values with extreme regimes.
fn directed(n: u32) -> Vec<u64> {
    let one = Posit::one(n);
    vec![
        Posit::zero(n).to_bits(),
        Posit::nar(n).to_bits(),
        one.to_bits(),
        one.neg().to_bits(),
        Posit::maxpos(n).to_bits(),
        Posit::maxpos(n).neg().to_bits(),
        Posit::minpos(n).to_bits(),
        Posit::minpos(n).neg().to_bits(),
    ]
}

/// Seeded lanes: every directed×directed pair, then random patterns.
fn lanes(n: u32, rng: &mut Rng, random: usize) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
    let d = directed(n);
    let mut a = Vec::new();
    let mut b = Vec::new();
    for &x in &d {
        for &y in &d {
            a.push(x);
            b.push(y);
        }
    }
    for _ in 0..random {
        a.push(rng.next_u64() & mask(n));
        b.push(rng.next_u64() & mask(n));
    }
    let c: Vec<u64> = (0..a.len()).map(|_| rng.next_u64() & mask(n)).collect();
    (a, b, c)
}

#[test]
fn fast_tier_division_matches_datapath_and_golden_for_every_algorithm() {
    let mut rng = Rng::seeded(0x7151);
    for n in WIDTHS {
        let (xs, ds, _) = lanes(n, &mut rng, 200);
        let golden_bits: Vec<u64> = xs
            .iter()
            .zip(&ds)
            .map(|(&x, &d)| {
                golden::divide(Posit::from_bits(n, x), Posit::from_bits(n, d)).result.to_bits()
            })
            .collect();
        for alg in Algorithm::ALL {
            let fast = Unit::with_tier(n, Op::Div { alg }, ExecTier::Fast).expect("valid width");
            let dp =
                Unit::with_tier(n, Op::Div { alg }, ExecTier::Datapath).expect("valid width");
            let mut fast_out = vec![0u64; xs.len()];
            let mut dp_out = vec![0u64; xs.len()];
            fast.run_batch(&xs, &ds, &[], &mut fast_out).expect("equal lanes");
            dp.run_batch(&xs, &ds, &[], &mut dp_out).expect("equal lanes");
            for i in 0..xs.len() {
                assert_eq!(
                    fast_out[i], dp_out[i],
                    "{} n={n} lane {i}: fast != datapath ({:#x}/{:#x})",
                    alg.label(),
                    xs[i],
                    ds[i]
                );
                assert_eq!(
                    fast_out[i], golden_bits[i],
                    "{} n={n} lane {i}: tiers != golden ({:#x}/{:#x})",
                    alg.label(),
                    xs[i],
                    ds[i]
                );
            }
        }
    }
}

#[test]
fn fast_tier_matches_datapath_for_every_op() {
    let mut rng = Rng::seeded(0x7152);
    for n in WIDTHS {
        let (a, b, c) = lanes(n, &mut rng, 200);
        for op in Op::DEFAULTS {
            let fast = Unit::with_tier(n, op, ExecTier::Fast).expect("valid width");
            let dp = Unit::with_tier(n, op, ExecTier::Datapath).expect("valid width");
            let (lb, lc): (&[u64], &[u64]) = match op.arity() {
                1 => (&[], &[]),
                2 => (&b, &[]),
                _ => (&b, &c),
            };
            let mut fast_out = vec![0u64; a.len()];
            let mut dp_out = vec![0u64; a.len()];
            fast.run_batch(&a, lb, lc, &mut fast_out).expect("equal lanes");
            dp.run_batch(&a, lb, lc, &mut dp_out).expect("equal lanes");
            assert_eq!(fast_out, dp_out, "{op} n={n}");
            // and both against the shared exact-reference table
            for i in 0..a.len() {
                let operands: Vec<Posit> = [a[i], b[i], c[i]]
                    .iter()
                    .take(op.arity())
                    .map(|&bits| Posit::from_bits(n, bits))
                    .collect();
                let want = OpRequest::new(op, &operands).expect("arity matches").golden();
                assert_eq!(fast_out[i], want.to_bits(), "{op} n={n} lane {i} vs golden");
            }
        }
    }
}

#[test]
fn auto_tier_serves_batches_from_the_fast_kernels_bit_identically() {
    // `Unit::new` (Auto) must agree with both pinned tiers on the batch
    // path, and its scalar path (datapath) must agree with the fast
    // scalar path including metadata.
    let mut rng = Rng::seeded(0x7153);
    for n in [8u32, 16, 32] {
        let (a, b, _) = lanes(n, &mut rng, 100);
        for alg in [Algorithm::DEFAULT, Algorithm::Newton] {
            let auto = Unit::new(n, Op::Div { alg }).expect("valid width");
            let fast = Unit::with_tier(n, Op::Div { alg }, ExecTier::Fast).expect("valid width");
            let mut auto_out = vec![0u64; a.len()];
            let mut fast_out = vec![0u64; a.len()];
            auto.run_batch(&a, &b, &[], &mut auto_out).expect("equal lanes");
            fast.run_batch(&a, &b, &[], &mut fast_out).expect("equal lanes");
            assert_eq!(auto_out, fast_out, "{} n={n}", alg.label());
            for i in (0..a.len()).step_by(7) {
                let x = Posit::from_bits(n, a[i]);
                let d = Posit::from_bits(n, b[i]);
                let s_auto = auto.run(&[x, d]).expect("width matches");
                let s_fast = fast.run(&[x, d]).expect("width matches");
                assert_eq!(
                    (s_auto.result, s_auto.iterations, s_auto.cycles),
                    (s_fast.result, s_fast.iterations, s_fast.cycles),
                    "{} n={n} lane {i}: fast metadata must model the datapath",
                    alg.label()
                );
            }
        }
    }
}

#[test]
fn fast_tier_parallel_batches_are_bit_identical_on_the_shared_pool() {
    let mut rng = Rng::seeded(0x7154);
    let n = 16;
    // large enough that the per-(op, width, tier) chunk heuristic
    // actually fans out over the pool for every fast kernel (small
    // batches now deliberately run inline)
    let (a, b, c) = lanes(n, &mut rng, 24_936);
    for op in Op::DEFAULTS {
        let fast = Unit::with_tier(n, op, ExecTier::Fast).expect("valid width");
        let (lb, lc): (&[u64], &[u64]) = match op.arity() {
            1 => (&[], &[]),
            2 => (&b, &[]),
            _ => (&b, &c),
        };
        let mut serial = vec![0u64; a.len()];
        let mut parallel = vec![0u64; a.len()];
        fast.run_batch(&a, lb, lc, &mut serial).expect("equal lanes");
        fast.run_batch_parallel(&a, lb, lc, &mut parallel, 4).expect("equal lanes");
        assert_eq!(serial, parallel, "{op}");
    }
}

/// SWAR vs scalar-fast vs Datapath bit-identity: seeded sweeps with the
/// batch kernel *forced*, at batch lengths around the Auto dispatch
/// thresholds (16, 32) and across SoA block/ragged-tail boundaries,
/// specials and NaR included, for every op at both SWAR widths.
#[test]
fn swar_path_matches_scalar_fast_and_datapath_for_every_op() {
    let mut rng = Rng::seeded(0x7156);
    for n in [8u32, 16] {
        for len in [16usize, 32, 300] {
            let (full_a, full_b, full_c) = lanes(n, &mut rng, 300);
            let a = &full_a[..len];
            let b = &full_b[..len];
            let c = &full_c[..len];
            for op in Op::DEFAULTS {
                let simd = Unit::with_exec(n, op, ExecTier::Fast, FastPath::Simd)
                    .expect("SWAR width");
                let scalar = Unit::with_exec(n, op, ExecTier::Fast, FastPath::Scalar)
                    .expect("always valid");
                let dp = Unit::with_tier(n, op, ExecTier::Datapath).expect("valid width");
                let (lb, lc): (&[u64], &[u64]) = match op.arity() {
                    1 => (&[], &[]),
                    2 => (b, &[]),
                    _ => (b, c),
                };
                let mut simd_out = vec![0u64; len];
                let mut scalar_out = vec![0u64; len];
                let mut dp_out = vec![0u64; len];
                simd.run_batch(a, lb, lc, &mut simd_out).expect("equal lanes");
                scalar.run_batch(a, lb, lc, &mut scalar_out).expect("equal lanes");
                dp.run_batch(a, lb, lc, &mut dp_out).expect("equal lanes");
                assert_eq!(simd_out, scalar_out, "{op} n={n} len={len}: SWAR != scalar-fast");
                assert_eq!(simd_out, dp_out, "{op} n={n} len={len}: SWAR != datapath");
            }
        }
    }
}

/// Explicit vector ISA (AVX2/NEON) vs SWAR vs scalar-fast vs Datapath
/// bit-identity: seeded sweeps with the kernel *forced*, at batch
/// lengths around the `VECTOR_MIN_LANES` threshold and across the
/// 64-lane block/ragged-tail boundaries, specials and NaR included. On
/// hosts without the `vsimd` feature or a detected vector ISA,
/// `Unit::with_exec(.., FastPath::Vector)` is a typed refusal and every
/// combination skips gracefully — the sweep then degenerates to the SWAR
/// half, which still runs.
#[test]
fn vector_path_matches_swar_scalar_fast_and_datapath_for_every_op() {
    let mut rng = Rng::seeded(0x7159);
    for n in [8u32, 16] {
        for len in [16usize, 64, 300] {
            let (full_a, full_b, full_c) = lanes(n, &mut rng, 300);
            let a = &full_a[..len];
            let b = &full_b[..len];
            let c = &full_c[..len];
            for op in Op::DEFAULTS {
                // skip when the host has no detected vector ISA, and for
                // the ops the vector family never serves (sqrt, mul_add)
                let Ok(vector) = Unit::with_exec(n, op, ExecTier::Fast, FastPath::Vector) else {
                    continue;
                };
                let simd =
                    Unit::with_exec(n, op, ExecTier::Fast, FastPath::Simd).expect("SWAR width");
                let scalar = Unit::with_exec(n, op, ExecTier::Fast, FastPath::Scalar)
                    .expect("always valid");
                let dp = Unit::with_tier(n, op, ExecTier::Datapath).expect("valid width");
                let (lb, lc): (&[u64], &[u64]) = match op.arity() {
                    1 => (&[], &[]),
                    2 => (b, &[]),
                    _ => (b, c),
                };
                let mut v_out = vec![0u64; len];
                let mut simd_out = vec![0u64; len];
                let mut s_out = vec![0u64; len];
                let mut d_out = vec![0u64; len];
                vector.run_batch(a, lb, lc, &mut v_out).expect("equal lanes");
                simd.run_batch(a, lb, lc, &mut simd_out).expect("equal lanes");
                scalar.run_batch(a, lb, lc, &mut s_out).expect("equal lanes");
                dp.run_batch(a, lb, lc, &mut d_out).expect("equal lanes");
                assert_eq!(v_out, simd_out, "{op} n={n} len={len}: vector != SWAR");
                assert_eq!(v_out, s_out, "{op} n={n} len={len}: vector != scalar-fast");
                assert_eq!(v_out, d_out, "{op} n={n} len={len}: vector != datapath");
            }
        }
    }
}

/// Exhaustive-Posit8 lookup-table path vs scalar-fast vs Datapath on
/// the same seeded sweeps (the exhaustive all-pairs gate lives in
/// `p8_exhaustive.rs`; the Posit16 seed-table sweep is the next test).
#[test]
fn table_path_matches_scalar_fast_and_datapath_p8() {
    let mut rng = Rng::seeded(0x7157);
    let n = 8;
    for len in [16usize, 32, 300] {
        let (full_a, full_b, _) = lanes(n, &mut rng, 300);
        let a = &full_a[..len];
        let b = &full_b[..len];
        for op in Op::DEFAULTS {
            let Ok(table) = Unit::with_exec(n, op, ExecTier::Fast, FastPath::Table) else {
                continue; // mul_add has no table
            };
            let scalar =
                Unit::with_exec(n, op, ExecTier::Fast, FastPath::Scalar).expect("always valid");
            let dp = Unit::with_tier(n, op, ExecTier::Datapath).expect("valid width");
            let (lb, lc): (&[u64], &[u64]) = match op.arity() {
                1 => (&[], &[]),
                _ => (b, &[]),
            };
            let mut t_out = vec![0u64; len];
            let mut s_out = vec![0u64; len];
            let mut d_out = vec![0u64; len];
            table.run_batch(a, lb, lc, &mut t_out).expect("equal lanes");
            scalar.run_batch(a, lb, lc, &mut s_out).expect("equal lanes");
            dp.run_batch(a, lb, lc, &mut d_out).expect("equal lanes");
            assert_eq!(t_out, s_out, "{op} len={len}: table != scalar-fast");
            assert_eq!(t_out, d_out, "{op} len={len}: table != datapath");
        }
    }
}

/// Posit16 seed-table path (div/sqrt) vs scalar-fast vs Datapath on
/// seeded sweeps: the reciprocal/rsqrt seed tables must never change a
/// bit relative to the exact kernels.
#[test]
fn table_path_matches_scalar_fast_and_datapath_p16() {
    let mut rng = Rng::seeded(0x715A);
    let n = 16;
    for len in [16usize, 64, 300] {
        let (full_a, full_b, _) = lanes(n, &mut rng, 300);
        let a = &full_a[..len];
        let b = &full_b[..len];
        for op in [Op::DIV, Op::Sqrt] {
            let table = Unit::with_exec(n, op, ExecTier::Fast, FastPath::Table)
                .expect("Posit16 div/sqrt carry seed tables");
            let scalar =
                Unit::with_exec(n, op, ExecTier::Fast, FastPath::Scalar).expect("always valid");
            let dp = Unit::with_tier(n, op, ExecTier::Datapath).expect("valid width");
            let lb: &[u64] = if op == Op::Sqrt { &[] } else { b };
            let mut t_out = vec![0u64; len];
            let mut s_out = vec![0u64; len];
            let mut d_out = vec![0u64; len];
            table.run_batch(a, lb, &[], &mut t_out).expect("equal lanes");
            scalar.run_batch(a, lb, &[], &mut s_out).expect("equal lanes");
            dp.run_batch(a, lb, &[], &mut d_out).expect("equal lanes");
            assert_eq!(t_out, s_out, "{op} len={len}: p16 table != scalar-fast");
            assert_eq!(t_out, d_out, "{op} len={len}: p16 table != datapath");
        }
    }
}

/// The Auto dispatch can pick different kernels on either side of its
/// thresholds — the results must stay bit-identical across the seam.
#[test]
fn auto_dispatch_is_bit_identical_across_length_thresholds() {
    let mut rng = Rng::seeded(0x7158);
    for n in [8u32, 16] {
        let (a, b, _) = lanes(n, &mut rng, 100);
        for op in [Op::DIV, Op::Mul, Op::Sqrt] {
            let auto = Unit::with_tier(n, op, ExecTier::Fast).expect("valid width");
            let scalar =
                Unit::with_exec(n, op, ExecTier::Fast, FastPath::Scalar).expect("always valid");
            let (lb, _lc): (&[u64], &[u64]) = match op.arity() {
                1 => (&[], &[]),
                _ => (&b, &[]),
            };
            // lengths straddling TABLE_MIN_LANES (4), SIMD_MIN_LANES (16)
            // and VECTOR_MIN_LANES (32)
            for len in [1usize, 3, 4, 5, 15, 16, 17, 31, 32, 33, 64, 65] {
                let la = &a[..len];
                let lb2: &[u64] = if lb.is_empty() { lb } else { &lb[..len] };
                let mut auto_out = vec![0u64; len];
                let mut scalar_out = vec![0u64; len];
                auto.run_batch(la, lb2, &[], &mut auto_out).expect("equal lanes");
                scalar.run_batch(la, lb2, &[], &mut scalar_out).expect("equal lanes");
                assert_eq!(auto_out, scalar_out, "{op} n={n} len={len}");
            }
        }
    }
}
