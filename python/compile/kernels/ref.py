"""Pure-jnp correctness oracle for the fraction-division kernel.

Exact integer long division (no recurrence, no truncated estimates): the
same contract as the Rust `division::golden` model. The kernel must match
this bit-for-bit after precision refinement.
"""

import jax
import jax.numpy as jnp

from .posit_codec import frac_bits

jax.config.update("jax_enable_x64", True)


def fraction_divide(x_sig, d_sig, n: int, prec: int | None = None):
    """Exact truncated quotient of significand lanes.

    Returns (q_mag, sticky): q_mag = floor(x/d * 2^prec) with `prec`
    fraction bits (default n), sticky = (remainder != 0).
    Requires sig width + prec <= 62 (true for n <= 32 with prec = n).
    """
    if prec is None:
        prec = n
    f = frac_bits(n)
    assert f + 1 + prec <= 62, "int64 overflow"
    x = jnp.asarray(x_sig, jnp.int64)
    d = jnp.asarray(d_sig, jnp.int64)
    num = x << prec
    q = num // d
    rem = num - q * d
    return q, rem != 0


def refine(q_mag, sticky, from_bits: int, to_bits: int):
    """Drop precision from `from_bits` to `to_bits` fraction bits, folding
    the dropped bits into sticky (the Rust `FracQuotient::refine_to`)."""
    assert to_bits <= from_bits
    drop = from_bits - to_bits
    if drop == 0:
        return q_mag, sticky
    return q_mag >> drop, sticky | ((q_mag & ((1 << drop) - 1)) != 0)
