"""L1 Pallas kernel: radix-4 SRT fraction division (CS + OF + FR).

The paper's hot loop — the digit recurrence of §III — re-expressed as a
batched, lane-parallel Pallas kernel. Every lane carries one division's
hardware state in int64 registers:

  ws, wc : the carry-save residual pair (datapath width F+7 bits,
           two's-complement, wrapping — exactly the masked words the RTL
           holds),
  q, qd  : the on-the-fly-converted quotient registers (Eqs. 18-19),

and the It-step loop (Table II) is a `fori_loop` whose body does the 7-bit
slice estimate, the m_k(d-hat) table selection (Eq. 28), the divisor
multiple generation and one 3:2 compression. Digit selections are
bit-identical to the Rust `division::srt4_cs` engine.

TPU mapping notes (DESIGN.md §Hardware-Adaptation): the batch is tiled by
BlockSpec so each block's lane state (6 int64 vectors x BLOCK lanes = 6KiB
at BLOCK=128) stays in VMEM; the loop is sequential per block, lanes are
VPU-parallel. The MXU is idle by design - division is shift/add bound.
interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU performance is estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .posit_codec import frac_bits

jax.config.update("jax_enable_x64", True)

# Default lane-block size: 6 state vectors * 128 lanes * 8 B = 6 KiB VMEM.
BLOCK = 128

# The derived m_k(d-hat) selection table (units of 1/16), one row per
# divisor interval d in [i/16, (i+1)/16), i = 8..15; thresholds for digits
# k = -1, 0, 1, 2. Identical to rust/src/division/selection.rs::derive
# (spot-checked in tests against the Rust engine digit-for-digit).
SEL_M = (
    (-13, -5, 3, 12),
    (-15, -6, 4, 14),
    (-16, -6, 4, 15),
    (-18, -7, 4, 16),
    (-20, -8, 5, 18),
    (-21, -8, 5, 19),
    (-23, -9, 5, 20),
    (-25, -10, 6, 22),
)


def selection_thresholds(dhat):
    """Compute the m_k(d-hat) thresholds arithmetically (no gather!).

    Same containment formula as the Rust derivation
    (`selection::Srt4Table::derive`): m_k = ceil((3k-2) * d16 / 3) in 1/16
    units, with d16 the interval endpoint that maximizes L_k. Produces
    exactly the SEL_M table for dhat in [8, 15].

    Why not a table gather: xla_extension 0.5.1 (behind the Rust `xla`
    crate) mis-executes the s64 gather ops emitted by jax >= 0.8, so the
    exported graph must avoid gather entirely (aot.py enforces this).
    """
    d16 = dhat + 8  # interval lower endpoint in 1/16 units

    def ceil_div3(a):
        return -((-a) // 3)

    cols = []
    for k in (-1, 0, 1, 2):
        lnum = 3 * k - 2
        endpoint = d16 + (1 if lnum > 0 else 0)
        cols.append(ceil_div3(lnum * endpoint))
    return cols  # [m_-1, m_0, m_1, m_2] lanes


def iterations(n: int) -> int:
    """Radix-4 iteration count (Table II): ceil((n-1)/2)."""
    return (n - 1 + 1) // 2


def _sext(v, bits: int):
    """Sign-extend the low `bits` of int64 lanes."""
    sign = 1 << (bits - 1)
    return ((v & ((1 << bits) - 1)) ^ sign) - sign


def _kernel(x_ref, d_ref, m_ref, q_ref, sticky_ref, *, n: int):
    f = frac_bits(n)
    fw = f + 3           # fractional bits of w: w(0) = x/4 = x_sig exactly
    width = fw + 4       # datapath width (sign + 3 integer bits)
    wmask = (1 << width) - 1
    it = iterations(n)

    x = x_ref[...].astype(jnp.int64)
    d = d_ref[...].astype(jnp.int64)
    m_lane = m_ref[...]  # (lanes, 4): per-lane m_k(d-hat) thresholds

    d_fp = d << 2

    def body(_, st):
        ws, wc, q, qd = st
        # r*w(i): wired shift, dropping overflow (mod 2^width)
        s_ws = (ws << 2) & wmask
        s_wc = (wc << 2) & wmask
        # 7-bit slice estimate: per-word truncation + wrapping slice add
        t = _sext((s_ws >> (fw - 4)) + (s_wc >> (fw - 4)), width - (fw - 4))
        # digit = -2 + #(thresholds <= t)
        digit = (
            (t >= m_lane[:, 0]).astype(jnp.int64)
            + (t >= m_lane[:, 1])
            + (t >= m_lane[:, 2])
            + (t >= m_lane[:, 3])
            - 2
        )  # digit = -2 + #(thresholds <= t)
        # divisor multiple: 0, ±d, ±2d as (conditional shift, conditional
        # invert + carry-in) — the hardware's multiple generation
        mag = jnp.where(jnp.abs(digit) == 2, d_fp << 1, d_fp)
        mag = jnp.where(digit == 0, 0, mag)
        neg = digit > 0  # subtracting positive multiples
        addend = jnp.where(neg, ~mag, mag) & wmask
        cin = neg.astype(jnp.int64)
        # 3:2 compression
        ws2 = (s_ws ^ s_wc ^ addend) & wmask
        wc2 = ((((s_ws & s_wc) | (s_ws & addend) | (s_wc & addend)) << 1) | cin) & wmask
        # on-the-fly conversion (Eqs. 18-19)
        q2 = jnp.where(digit >= 0, (q << 2) | digit, (qd << 2) | (4 + digit))
        qd2 = jnp.where(digit > 0, (q << 2) | (digit - 1), (qd << 2) | (3 + digit))
        return ws2, wc2, q2, qd2

    zero = jnp.zeros_like(x)
    ws, wc, q, qd = jax.lax.fori_loop(0, it, body, (x, zero, zero, zero))

    # Termination: sign / zero of the final residual (values identical to
    # the FR lookahead networks, which the Rust engines model gate-level).
    w_final = _sext(ws + wc, width)
    negr = w_final < 0
    rem = jnp.where(negr, w_final + d_fp, w_final)
    q_ref[...] = jnp.where(negr, qd, q)
    sticky_ref[...] = (rem != 0).astype(jnp.int64)


@functools.partial(jax.jit, static_argnames=("n", "block"))
def fraction_divide(x_sig, d_sig, n: int, block: int = BLOCK):
    """Divide significand batches: returns (q_mag, sticky).

    q_mag has 2*It - 2 fraction bits; value in (1/2, 2). Exactly the Rust
    `FracQuotient` of the `Srt4CsOfFr` engine.
    """
    assert 8 <= n <= 32, "kernel supports Posit8..Posit32 (int64 datapath)"
    (lanes,) = x_sig.shape
    assert lanes % block == 0, f"batch {lanes} not a multiple of block {block}"
    grid = lanes // block

    # Eq. (28) divisor truncation: 4 MSBs of d in [1/2,1) -> index 8..15;
    # compute each lane's m_k threshold row before entering the kernel
    # (the hardware's d-hat-indexed PLA, evaluated once per division).
    f = frac_bits(n)
    d64 = d_sig.astype(jnp.int64)
    dhat = (d64 >> (f - 3)) - 8
    m_lane = jnp.stack(selection_thresholds(dhat), axis=-1)  # (lanes, 4)

    out = pl.pallas_call(
        functools.partial(_kernel, n=n),
        out_shape=(
            jax.ShapeDtypeStruct((lanes,), jnp.int64),
            jax.ShapeDtypeStruct((lanes,), jnp.int64),
        ),
        in_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
        ),
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        grid=(grid,),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x_sig.astype(jnp.int64), d64, m_lane)
    return out
