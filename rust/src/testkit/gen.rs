//! Posit-aware generators and shrinkers for property tests.

use super::Rng;
use crate::posit::{mask, Posit};

/// A random bit pattern of width `n` (may be zero or NaR).
pub fn any_posit(rng: &mut Rng, n: u32) -> Posit {
    Posit::from_bits(n, rng.next_u64() & mask(n))
}

/// A random *real* posit (excludes NaR; may be zero).
pub fn real_posit(rng: &mut Rng, n: u32) -> Posit {
    loop {
        let p = any_posit(rng, n);
        if !p.is_nar() {
            return p;
        }
    }
}

/// A random non-zero, non-NaR posit.
pub fn nonzero_posit(rng: &mut Rng, n: u32) -> Posit {
    loop {
        let p = any_posit(rng, n);
        if !p.is_nar() && !p.is_zero() {
            return p;
        }
    }
}

/// A posit biased toward "interesting" patterns: specials, extremes,
/// boundary regimes, then uniform fill.
pub fn tricky_posit(rng: &mut Rng, n: u32) -> Posit {
    match rng.below(10) {
        0 => Posit::zero(n),
        1 => Posit::nar(n),
        2 => Posit::one(n),
        3 => Posit::one(n).neg(),
        4 => Posit::maxpos(n),
        5 => Posit::minpos(n),
        6 => Posit::maxpos(n).neg(),
        7 => Posit::minpos(n).neg(),
        // near-1 values: long fraction, regime 10
        8 => {
            let frac = rng.next_u64() & mask(crate::posit::frac_bits(n));
            Posit::from_bits(n, (0b10 << (n - 3)) >> 1 | frac)
        }
        _ => any_posit(rng, n),
    }
}

/// A dividend/divisor pair with both operands real and divisor non-zero —
/// the domain of the fraction recurrence.
pub fn division_operands(rng: &mut Rng, n: u32) -> (Posit, Posit) {
    (real_posit(rng, n), nonzero_posit(rng, n))
}

/// Shrinker for posit patterns: toward zero / one / shorter patterns.
pub fn shrink_posit(p: &Posit) -> Vec<Posit> {
    let n = p.width();
    let bits = p.to_bits();
    let mut out = Vec::new();
    for cand in [0u64, 1 << (n - 2), bits >> 1, bits & (bits - 1).max(0)] {
        let c = Posit::from_bits(n, cand);
        if c != *p {
            out.push(c);
        }
    }
    out
}

/// Shrinker for operand pairs (shrinks one side at a time).
pub fn shrink_pair(pair: &(Posit, Posit)) -> Vec<(Posit, Posit)> {
    let mut out = Vec::new();
    for a in shrink_posit(&pair.0) {
        out.push((a, pair.1));
    }
    for b in shrink_posit(&pair.1) {
        if !b.is_zero() {
            out.push((pair.0, b));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_constraints() {
        let mut rng = Rng::seeded(99);
        for _ in 0..2000 {
            let n = *rng.choose(&[8u32, 16, 32, 64]);
            assert!(!real_posit(&mut rng, n).is_nar());
            let nz = nonzero_posit(&mut rng, n);
            assert!(!nz.is_nar() && !nz.is_zero());
            let (_, d) = division_operands(&mut rng, n);
            assert!(!d.is_zero() && !d.is_nar());
        }
    }

    #[test]
    fn tricky_hits_specials() {
        let mut rng = Rng::seeded(1);
        let mut saw_nar = false;
        let mut saw_zero = false;
        let mut saw_maxpos = false;
        for _ in 0..200 {
            let p = tricky_posit(&mut rng, 16);
            saw_nar |= p.is_nar();
            saw_zero |= p.is_zero();
            saw_maxpos |= p == Posit::maxpos(16);
        }
        assert!(saw_nar && saw_zero && saw_maxpos);
    }

    #[test]
    fn shrinkers_move_toward_simpler() {
        let p = Posit::from_bits(16, 0x5A5A);
        for c in shrink_posit(&p) {
            assert_ne!(c, p);
        }
    }
}
