#!/usr/bin/env bash
# Tier-1 verification plus lint gates. Run from anywhere in the repo.
#
#   scripts/verify.sh               # build + tests + clippy + fmt + doc
#   SKIP_CLIPPY=1 scripts/verify.sh # skip the clippy gate (e.g. toolchains
#                                   # without a clippy component)
#   SKIP_FMT=1 scripts/verify.sh    # skip the rustfmt gate
#   SKIP_DOC=1 scripts/verify.sh    # skip the warn-free rustdoc gate
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== build every target (benches/examples compile too) =="
cargo build --release --all-targets

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== service soak (sharded TCP serving over loopback) =="
# also part of `cargo test` above; named so a serving regression (hang,
# shed miscount, wire break) fails as its own step with its own output
cargo test --release --test service_e2e

echo "== approx-tier ulp-contract gate (exhaustive Posit8) =="
# also part of `cargo test` above (un-ignored); named so a bounded-error
# kernel drifting past its declared ApproxSpec fails as its own step
cargo test --release --test p8_exhaustive p8_approx_tier_stays_within_declared_ulp_bounds

if [ "${SKIP_FMT:-0}" != "1" ]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== cargo fmt --check =="
        cargo fmt --all -- --check
    else
        echo "== rustfmt not installed; skipping format gate =="
    fi
fi

if [ "${SKIP_CLIPPY:-0}" != "1" ]; then
    if command -v cargo-clippy >/dev/null 2>&1 || cargo clippy --version >/dev/null 2>&1; then
        echo "== clippy -D warnings (lib + bin: the redesigned surface) =="
        cargo clippy --lib --bins -- -D warnings
    else
        echo "== clippy not installed; skipping lint gate =="
    fi
fi

if [ "${SKIP_DOC:-0}" != "1" ]; then
    echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
fi

echo "verify.sh: all green"
