//! Crate-level fixed worker pool — the one pool every parallel batch
//! path shares (no tokio/rayon offline).
//!
//! The pool used to live under the coordinator and, worse, every
//! `run_batch_parallel` call spawned a fresh set of `thread::scope`
//! workers: one OS thread spawn + join per chunk per batch, paid again on
//! every dynamic batch the service executed. It is now a crate-level
//! module with a lazily-initialized process-wide instance
//! ([`global`]); [`crate::unit::Unit::run_batch_parallel`], the
//! coordinator's native backend and the bench suites all reuse the same
//! persistent workers.
//!
//! Borrowed (non-`'static`) work runs through [`Pool::run_scoped`], which
//! blocks until every submitted job has finished — the submitting thread
//! helps drain the queue while it waits, so nested `run_scoped` calls
//! from inside a pool job cannot deadlock.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Countdown latch: [`Pool::run_scoped`] blocks on it until every
/// submitted job has finished (or unwound).
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch { remaining: Mutex::new(count), done: Condvar::new() }
    }

    fn complete_one(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    /// Wait for completion, bounded so the waiter can go back to helping
    /// drain the queue.
    fn wait_timeout(&self, d: Duration) {
        let g = self.remaining.lock().unwrap();
        if *g > 0 {
            drop(self.done.wait_timeout(g, d).unwrap());
        }
    }
}

/// Fixed worker pool over a shared injector queue. Dropping it joins all
/// workers. Panics inside jobs are contained: they never kill a worker
/// (`execute` jobs have their panic swallowed; `run_scoped` re-raises it
/// on the submitting thread).
pub struct Pool {
    tx: Option<Sender<Job>>,
    rx: Arc<Mutex<Receiver<Job>>>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `threads` workers (at least one).
    pub fn new(threads: usize) -> Pool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("posit-div-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            // contain panics so one bad job cannot
                            // silently shrink the pool
                            Ok(job) => drop(catch_unwind(AssertUnwindSafe(job))),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool { tx: Some(tx), rx, workers }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget `'static` job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().unwrap().send(Box::new(job)).expect("pool closed");
    }

    /// Run a set of borrowed jobs on the persistent workers and block
    /// until all of them have finished. The submitting thread helps drain
    /// the queue while waiting (so it stays productive, and nested
    /// `run_scoped` calls from inside a pool job cannot deadlock). If any
    /// job panicked, the panic is re-raised here after all jobs settle.
    pub fn run_scoped<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if jobs.is_empty() {
            return;
        }
        if jobs.len() == 1 {
            // nothing to overlap with: run inline, no cross-thread cost
            for job in jobs {
                job();
            }
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        let panicked = Arc::new(AtomicBool::new(false));
        for job in jobs {
            // SAFETY: this function does not return until `latch` reports
            // every wrapped job has completed (or unwound), so the `'env`
            // borrows captured by `job` strictly outlive its execution.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
            };
            let latch = latch.clone();
            let panicked = panicked.clone();
            self.execute(move || {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panicked.store(true, Ordering::Relaxed);
                }
                latch.complete_one();
            });
        }
        loop {
            if latch.is_done() {
                break;
            }
            // help: steal queued work (ours or anyone's) while waiting
            let job = { self.rx.lock().unwrap().try_recv() };
            match job {
                Ok(job) => drop(catch_unwind(AssertUnwindSafe(job))),
                Err(_) => latch.wait_timeout(Duration::from_micros(200)),
            }
        }
        if panicked.load(Ordering::Relaxed) {
            panic!("pool job panicked");
        }
    }

    /// Run `f` over chunks of `items` on the pool's workers, writing
    /// results in order; blocks until done. No `Default`/`Clone` bound:
    /// results are written directly into the output's spare capacity.
    /// Pick `chunk` with [`chunk_size`] when a per-item cost estimate is
    /// available (or use [`Pool::map_chunks_auto`]).
    pub fn map_chunks<T, R, F>(&self, items: &[T], chunk: usize, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        let len = items.len();
        let chunk = chunk.max(1);
        let mut out: Vec<R> = Vec::with_capacity(len);
        let spare = &mut out.spare_capacity_mut()[..len];
        let f = &f;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = items
            .chunks(chunk)
            .zip(spare.chunks_mut(chunk))
            .map(|(inp, outp)| {
                Box::new(move || {
                    for (i, o) in inp.iter().zip(outp.iter_mut()) {
                        o.write(f(i));
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        self.run_scoped(jobs);
        // SAFETY: run_scoped returned without panicking, so every one of
        // the `len` slots was initialized by exactly one job. (If a job
        // panics, run_scoped panics and `out` drops at length 0 — the
        // already-written elements leak rather than double-drop.)
        unsafe { out.set_len(len) };
        out
    }

    /// [`Pool::map_chunks`] with the chunk size chosen by the
    /// [`chunk_size`] heuristic from an estimated per-item cost in
    /// nanoseconds.
    pub fn map_chunks_auto<T, R, F>(&self, items: &[T], per_item_ns: f64, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        let chunk = chunk_size(per_item_ns, items.len(), self.threads());
        self.map_chunks(items, chunk, f)
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Target amount of work per parallel chunk, in nanoseconds (~128 µs —
/// the middle of the 64–256 µs band where per-chunk fan-out cost, queue
/// contention and load-balancing granularity are all comfortably
/// amortized on this pool).
pub const TARGET_CHUNK_NS: f64 = 128_000.0;

/// Heuristic chunk size for splitting `len` items of roughly
/// `per_item_ns` each across `threads` workers: an even split
/// (`⌈len/threads⌉`), floored so no chunk carries less than about
/// [`TARGET_CHUNK_NS`] of work. Small or cheap batches therefore produce
/// *fewer* chunks than workers — down to a single chunk, which callers
/// run inline — instead of paying cross-thread fan-out for microscopic
/// pieces; large batches keep the even split.
pub fn chunk_size(per_item_ns: f64, len: usize, threads: usize) -> usize {
    let per = if per_item_ns.is_finite() && per_item_ns > 0.01 { per_item_ns } else { 0.01 };
    let min_items = (TARGET_CHUNK_NS / per).ceil() as usize;
    let fair = len.div_ceil(threads.max(1)).max(1);
    fair.max(min_items)
}

/// Round `chunk` up to a multiple of `block`, so chunk boundaries land on
/// kernel block boundaries. The block kernels (SWAR, explicit vector)
/// process [`crate::division::fastpath::LANE_BLOCK`] lanes per block; a
/// chunk size that is not a multiple of the block leaves every chunk with
/// a partially-filled trailing block — up to `threads - 1` extra block
/// passes per batch. Chunks already covering the whole batch (`chunk >=
/// len`) are returned unchanged: the caller runs those inline and the
/// kernel's own tail handling applies once.
pub fn align_chunk(chunk: usize, len: usize, block: usize) -> usize {
    if block < 2 || chunk >= len {
        chunk
    } else {
        chunk.div_ceil(block) * block
    }
}

/// Default worker count for the shared pool: the machine's available
/// parallelism, capped at 16 (the batch kernels saturate memory bandwidth
/// long before that).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// The process-wide shared pool, created on first use. Every parallel
/// batch path in the crate (unit, coordinator, benches) submits here
/// instead of spawning scoped threads per call.
pub fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| Pool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = counter.clone();
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_chunks_preserves_order() {
        let pool = Pool::new(3);
        let items: Vec<u64> = (0..1000).collect();
        let out = pool.map_chunks(&items, 64, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    /// The result type needs neither `Default` nor `Clone` anymore.
    #[test]
    fn map_chunks_without_default_or_clone() {
        struct NoDefault(u64);
        let pool = Pool::new(2);
        let items: Vec<u64> = (0..301).collect();
        let out = pool.map_chunks(&items, 10, |&x| NoDefault(x + 1));
        assert_eq!(out.len(), 301);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.0, i as u64 + 1);
        }
        // empty input: no jobs, empty output
        let empty: Vec<u64> = Vec::new();
        assert!(pool.map_chunks(&empty, 8, |&x| NoDefault(x)).is_empty());
    }

    #[test]
    fn run_scoped_sees_borrowed_state() {
        let pool = Pool::new(4);
        let data: Vec<u64> = (0..64).collect();
        let mut out = vec![0u64; 64];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks(16)
            .zip(out.chunks_mut(16))
            .map(|(inp, outp)| {
                Box::new(move || {
                    for (i, o) in inp.iter().zip(outp.iter_mut()) {
                        *o = i * 3;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
        }
    }

    #[test]
    fn nested_run_scoped_does_not_deadlock() {
        // A job running on a worker submits its own scoped batch to the
        // same (fully busy) pool: the waiters help drain, so this
        // completes instead of deadlocking.
        let pool = Arc::new(Pool::new(2));
        let total = Arc::new(AtomicU64::new(0));
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let pool = pool.clone();
                let total = total.clone();
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            let total = total.clone();
                            Box::new(move || {
                                total.fetch_add(1, Ordering::SeqCst);
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    pool.run_scoped(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(outer);
        assert_eq!(total.load(Ordering::SeqCst), 16);
    }

    #[test]
    #[should_panic(expected = "pool job panicked")]
    fn run_scoped_propagates_job_panics() {
        let pool = Pool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
            .map(|i| {
                Box::new(move || {
                    if i == 1 {
                        panic!("boom");
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = Pool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn chunk_size_targets_work_per_chunk() {
        // expensive items: the even split already exceeds the target
        // (1000 ns/item × 2500 items/chunk = 2.5 ms >> 128 µs)
        assert_eq!(chunk_size(1000.0, 10_000, 4), 2500);
        // cheap items: the floor kicks in (128 µs / 16 ns = 8000 items)
        assert_eq!(chunk_size(16.0, 10_000, 4), 8000);
        // tiny batch: one chunk covering everything (callers run inline)
        assert!(chunk_size(16.0, 100, 4) >= 100);
        // degenerate inputs stay sane
        assert!(chunk_size(0.0, 100, 0) >= 1);
        assert!(chunk_size(f64::NAN, 100, 4) >= 1);
        assert!(chunk_size(1e9, 0, 4) >= 1);
        // the even split is exact when it dominates
        assert_eq!(chunk_size(1e6, 1001, 4), 251);
    }

    #[test]
    fn align_chunk_rounds_to_block_multiples() {
        // mid-batch chunks round up to the block
        assert_eq!(align_chunk(100, 10_000, 64), 128);
        assert_eq!(align_chunk(64, 10_000, 64), 64);
        assert_eq!(align_chunk(65, 10_000, 64), 128);
        assert_eq!(align_chunk(1, 10_000, 64), 64);
        // chunks covering the whole batch are untouched
        assert_eq!(align_chunk(10_000, 10_000, 64), 10_000);
        assert_eq!(align_chunk(500, 300, 64), 500);
        // degenerate block sizes are a no-op
        assert_eq!(align_chunk(100, 10_000, 1), 100);
        assert_eq!(align_chunk(100, 10_000, 0), 100);
    }

    #[test]
    fn map_chunks_auto_matches_map_chunks() {
        let pool = Pool::new(3);
        let items: Vec<u64> = (0..5000).collect();
        // cheap per-item cost -> few large chunks; results identical
        let auto = pool.map_chunks_auto(&items, 10.0, |&x| x + 7);
        let manual = pool.map_chunks(&items, chunk_size(10.0, items.len(), 3), |&x| x + 7);
        assert_eq!(auto, manual);
        assert_eq!(auto.len(), 5000);
        assert_eq!(auto[4999], 5006);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = global() as *const Pool;
        let b = global() as *const Pool;
        assert_eq!(a, b);
        assert!(global().threads() >= 1 && global().threads() <= 16);
    }
}
