//! Tiny MLP inference on the exact quire — the posit literature's
//! flagship workload for 8/16-bit formats: every layer is a blocked
//! `gemm` of deferred-rounding dot products, so each pre-activation is
//! rounded exactly **once**, after the whole accumulation.
//!
//! The example runs a 4-8-3 perceptron at Posit8 and Posit16 and checks
//! three things per neuron:
//!   1. the quire `gemm` result is bit-exact against the independent
//!      exact-rational reference (`testkit::rational::dot`),
//!   2. the same dot served through the op-generic `Unit` surface
//!      (`Op::Dot` + `run_batch` — the loop the coordinator runs) is
//!      bit-identical,
//!   3. how often a naive fold (`mul_add` per term, rounding every step)
//!      differs from the exact result — the error the quire removes.
//!
//! ```sh
//! cargo run --release --example mlp_inference
//! ```

use posit_div::prelude::*;
use posit_div::testkit::{rational, Rng};

/// Rectifier on posits: negative pre-activations clamp to zero.
fn relu(p: Posit) -> Posit {
    if p.is_negative() {
        Posit::zero(p.width())
    } else {
        p
    }
}

/// The rounding-per-step baseline the quire replaces: one `mul_add`
/// (itself correctly rounded) per term.
fn naive_dot(w: &[Posit], x: &[Posit]) -> Posit {
    let mut acc = Posit::zero(w[0].width());
    for (wi, xi) in w.iter().zip(x) {
        acc = wi.mul_add(*xi, acc);
    }
    acc
}

fn run(n: u32) -> (usize, usize) {
    let dims = [4usize, 8, 3];
    let mut rng = Rng::seeded(0x31A9 + n as u64);
    // operands around 1, where posits are dense — the normalized-network
    // regime the quire is designed for
    let mut sample = |rng: &mut Rng| Posit::from_f64(n, rng.f64_unit() * 4.0 - 2.0);
    let mut x: Vec<Posit> = (0..dims[0]).map(|_| sample(&mut rng)).collect();

    let unit = Unit::new(n, Op::Dot).expect("standard width");
    let mut neurons = 0usize;
    let mut naive_diverged = 0usize;
    for l in 1..dims.len() {
        let (m, k) = (dims[l], dims[l - 1]);
        let w: Vec<Posit> = (0..m * k).map(|_| sample(&mut rng)).collect();

        // the whole layer as one blocked-quire GEMM: (m x k) · (k x 1)
        let pre = gemm(&w, &x, m, k, 1).expect("shapes match");

        let xb: Vec<u64> = x.iter().map(|p| p.to_bits()).collect();
        for i in 0..m {
            let row = &w[i * k..(i + 1) * k];
            // 1. exact-rational reference, computed with no quire and no
            //    floats: the accumulation really is error-free
            let want = rational::dot(row, &x);
            assert_eq!(pre[i].to_bits(), want.to_bits(), "n={n} layer {l} neuron {i}");
            // 2. the serving surface: Op::Dot through Unit::run_batch
            let rb: Vec<u64> = row.iter().map(|p| p.to_bits()).collect();
            let mut out = [0u64];
            unit.run_batch(&rb, &xb, &[], &mut out).expect("matched lanes");
            assert_eq!(out[0], want.to_bits(), "n={n} layer {l} neuron {i} (unit)");
            // 3. the baseline the quire replaces
            if naive_dot(row, &x).to_bits() != want.to_bits() {
                naive_diverged += 1;
            }
            neurons += 1;
        }
        x = pre.into_iter().map(relu).collect();
    }

    print!("Posit{n}: 4-8-3 MLP output  [");
    for (i, p) in x.iter().enumerate() {
        print!("{}{:.4}", if i > 0 { ", " } else { "" }, p.to_f64());
    }
    println!("]");
    println!(
        "  {neurons}/{neurons} neurons bit-exact vs the rational reference \
         (gemm AND Unit::run_batch); naive fold differed on {naive_diverged}"
    );
    (neurons, naive_diverged)
}

fn main() {
    println!("=== exact quire MLP inference (deferred rounding) ===");
    let mut diverged_total = 0;
    for n in [8u32, 16] {
        diverged_total += run(n).1;
    }
    println!(
        "\nevery accumulation exact; rounding-per-step lost bits on \
         {diverged_total} neuron(s) across both widths"
    );
}
