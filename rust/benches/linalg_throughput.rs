//! Quire reduction throughput (dot/fsum/axpy element rates per width ×
//! tier, plus blocked GEMM) — thin shim over
//! [`posit_div::bench::suites`], where the suite body lives so the same
//! code runs under `cargo bench --bench linalg_throughput` and
//! `posit-div bench linalg_throughput` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("linalg_throughput");
}
