//! Tables I and III: scaling factors and Posit10 worked examples —
//! thin shim over [`posit_div::bench::suites`], where the suite body
//! lives so the same code runs under `cargo bench --bench tables`
//! and `posit-div bench tables` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("tables");
}
