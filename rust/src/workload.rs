//! Workload generators shared by the examples, benches and the e2e driver.

use crate::posit::{mask, Posit};
use crate::testkit::Rng;

/// A stream of division operand pairs of a fixed posit width.
pub trait Workload {
    fn next_pair(&mut self) -> (Posit, Posit);
    fn name(&self) -> &'static str;
}

/// Uniform random bit patterns (the synthesis-style stimulus): every
/// operand pattern equally likely, including extremes; divisor zero and
/// NaR excluded (special-path rates are measured separately).
pub struct Uniform {
    pub n: u32,
    rng: Rng,
}

impl Uniform {
    pub fn new(n: u32, seed: u64) -> Self {
        Uniform { n, rng: Rng::seeded(seed) }
    }
}

impl Workload for Uniform {
    fn next_pair(&mut self) -> (Posit, Posit) {
        let x = Posit::from_bits(self.n, self.rng.next_u64() & mask(self.n));
        let d = loop {
            let d = Posit::from_bits(self.n, self.rng.next_u64() & mask(self.n));
            if !d.is_zero() && !d.is_nar() {
                break d;
            }
        };
        (if x.is_nar() { Posit::one(self.n) } else { x }, d)
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// DSP-style operands: magnitudes concentrated around 1 (the regime where
/// posits are dense), as produced by normalized signal-processing kernels
/// — the workload the paper's introduction motivates.
pub struct DspTrace {
    pub n: u32,
    rng: Rng,
}

impl DspTrace {
    pub fn new(n: u32, seed: u64) -> Self {
        DspTrace { n, rng: Rng::seeded(seed) }
    }
    fn sample(&mut self) -> Posit {
        // log2-uniform in [2^-8, 2^8), random sign, dense fraction
        let scale = self.rng.range_i64(-8, 8) as f64;
        let frac = 1.0 + self.rng.f64_unit();
        let v = frac * scale.exp2();
        let v = if self.rng.chance(1, 2) { -v } else { v };
        Posit::from_f64(self.n, v)
    }
}

impl Workload for DspTrace {
    fn next_pair(&mut self) -> (Posit, Posit) {
        let x = self.sample();
        let mut d = self.sample();
        while d.is_zero() {
            d = self.sample();
        }
        (x, d)
    }

    fn name(&self) -> &'static str {
        "dsp-trace"
    }
}

/// Mixed traffic including special cases (zero dividends, zero divisors,
/// NaR) at a configurable per-mille rate — exercises the fast path.
pub struct MixedSpecials {
    pub n: u32,
    pub special_per_mille: u64,
    rng: Rng,
}

impl MixedSpecials {
    pub fn new(n: u32, special_per_mille: u64, seed: u64) -> Self {
        MixedSpecials { n, special_per_mille, rng: Rng::seeded(seed) }
    }
}

impl Workload for MixedSpecials {
    fn next_pair(&mut self) -> (Posit, Posit) {
        if self.rng.chance(self.special_per_mille, 1000) {
            match self.rng.below(3) {
                0 => (Posit::zero(self.n), Posit::one(self.n)),
                1 => (Posit::one(self.n), Posit::zero(self.n)),
                _ => (Posit::nar(self.n), Posit::one(self.n)),
            }
        } else {
            let x = Posit::from_bits(self.n, self.rng.next_u64() & mask(self.n));
            let d = Posit::from_bits(self.n, (self.rng.next_u64() & mask(self.n)) | 1);
            (x, d)
        }
    }

    fn name(&self) -> &'static str {
        "mixed-specials"
    }
}

/// Collect `count` pairs from a workload.
pub fn take(w: &mut dyn Workload, count: usize) -> Vec<(Posit, Posit)> {
    (0..count).map(|_| w.next_pair()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_never_yields_invalid_divisor() {
        let mut w = Uniform::new(16, 1);
        for _ in 0..5000 {
            let (x, d) = w.next_pair();
            assert!(!d.is_zero() && !d.is_nar());
            assert!(!x.is_nar());
        }
    }

    #[test]
    fn dsp_trace_is_centered() {
        let mut w = DspTrace::new(32, 2);
        let mut in_band = 0;
        for _ in 0..2000 {
            let (x, _) = w.next_pair();
            let v = x.to_f64().abs();
            if (2.0f64.powi(-10)..2.0f64.powi(10)).contains(&v) {
                in_band += 1;
            }
        }
        assert!(in_band > 1900, "{in_band}");
    }

    #[test]
    fn mixed_specials_rate() {
        let mut w = MixedSpecials::new(16, 100, 3);
        let mut specials = 0;
        for _ in 0..10_000 {
            let (x, d) = w.next_pair();
            if x.is_zero() || x.is_nar() || d.is_zero() {
                specials += 1;
            }
        }
        assert!((700..1300).contains(&specials), "{specials}");
    }
}
