//! L3 coordinator: a batched posit-division service.
//!
//! The paper's contribution is the arithmetic unit, so the coordinator is
//! the thin-but-real driver the architecture calls for: a leader thread
//! owns a dynamic [`batcher`] (size + deadline policy) and a backend —
//! either the native bit-exact Rust engines (one pre-built
//! [`crate::division::Divider`], batch spread over scoped workers), or
//! the AOT-compiled JAX/Pallas graph executed through PJRT
//! ([`crate::runtime`]). Clients talk to the service through the typed
//! [`Client`] handle: `submit`/`submit_batch` return [`Pending`]/
//! [`BatchHandle`] futures-by-hand that resolve to typed results — the
//! raw mpsc plumbing is no longer part of the public surface.
//! [`metrics`] tracks request/batch latency.
//!
//! Python never runs here: the PJRT backend executes the pre-compiled
//! HLO artifact in-process.

pub mod batcher;
pub mod metrics;
pub mod pool;

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Weak};
use std::thread::JoinHandle;
use std::time::Instant;

pub use batcher::BatchPolicy;
pub use metrics::{Histogram, Metrics};
pub use pool::Pool;

use crate::division::{Algorithm, Divider};
use crate::error::{PositError, Result};
use crate::posit::Posit;
use crate::runtime::Runtime;

/// Which execution engine serves the batches.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Bit-exact Rust digit-recurrence engines, `threads`-way parallel.
    Native { alg: Algorithm, threads: usize },
    /// AOT-compiled JAX/Pallas graph via PJRT (artifacts from `make artifacts`).
    Pjrt { artifacts_dir: PathBuf },
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub n: u32,
    pub backend: Backend,
    pub policy: BatchPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            n: 32,
            backend: Backend::Native { alg: Algorithm::DEFAULT, threads: 4 },
            policy: BatchPolicy::default(),
        }
    }
}

struct Request {
    x: u64,
    d: u64,
    enqueued: Instant,
    respond: Sender<u64>,
}

/// An in-flight division submitted through a [`Client`].
pub struct Pending {
    n: u32,
    rx: Receiver<u64>,
}

impl Pending {
    /// Block until the service responds.
    pub fn wait(self) -> Result<Posit> {
        let bits = self.rx.recv().map_err(|_| PositError::ServiceStopped)?;
        Ok(Posit::from_bits(self.n, bits))
    }
}

/// A set of in-flight divisions; results come back in submission order.
pub struct BatchHandle {
    n: u32,
    rxs: Vec<Receiver<u64>>,
}

impl BatchHandle {
    /// Block until every response arrives.
    pub fn wait(self) -> Result<Vec<Posit>> {
        let n = self.n;
        self.rxs
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .map(|bits| Posit::from_bits(n, bits))
                    .map_err(|_| PositError::ServiceStopped)
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.rxs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rxs.is_empty()
    }
}

/// A cheap, cloneable handle for submitting divisions to a running
/// [`DivisionService`]. Holding a `Client` does not keep the service
/// alive: once the service shuts down, submissions return
/// [`PositError::ServiceStopped`] (already-queued requests still drain).
#[derive(Clone)]
pub struct Client {
    n: u32,
    tx: Weak<Sender<Request>>,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Posit width served.
    pub fn width(&self) -> u32 {
        self.n
    }

    fn sender(&self) -> Result<Arc<Sender<Request>>> {
        self.tx.upgrade().ok_or(PositError::ServiceStopped)
    }

    fn check_width(&self, p: Posit) -> Result<()> {
        if p.width() != self.n {
            return Err(PositError::WidthMismatch { expected: self.n, got: p.width() });
        }
        Ok(())
    }

    /// Submit one division; returns immediately with a [`Pending`].
    pub fn submit(&self, x: Posit, d: Posit) -> Result<Pending> {
        self.check_width(x)?;
        self.check_width(d)?;
        let tx = self.sender()?;
        let (rtx, rrx) = channel();
        tx.send(Request { x: x.to_bits(), d: d.to_bits(), enqueued: Instant::now(), respond: rtx })
            .map_err(|_| PositError::ServiceStopped)?;
        Ok(Pending { n: self.n, rx: rrx })
    }

    /// Submit many divisions; returns immediately with a [`BatchHandle`]
    /// whose results preserve submission order.
    pub fn submit_batch(&self, pairs: &[(Posit, Posit)]) -> Result<BatchHandle> {
        for &(x, d) in pairs {
            self.check_width(x)?;
            self.check_width(d)?;
        }
        let tx = self.sender()?;
        let now = Instant::now();
        let mut rxs = Vec::with_capacity(pairs.len());
        for &(x, d) in pairs {
            let (rtx, rrx) = channel();
            tx.send(Request { x: x.to_bits(), d: d.to_bits(), enqueued: now, respond: rtx })
                .map_err(|_| PositError::ServiceStopped)?;
            rxs.push(rrx);
        }
        Ok(BatchHandle { n: self.n, rxs })
    }

    /// Blocking division.
    pub fn divide(&self, x: Posit, d: Posit) -> Result<Posit> {
        self.submit(x, d)?.wait()
    }

    /// Blocking batch division (keeps ordering).
    pub fn divide_batch(&self, pairs: &[(Posit, Posit)]) -> Result<Vec<Posit>> {
        self.submit_batch(pairs)?.wait()
    }

    /// Service metrics (shared with every other client).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

/// A handle to a running division service.
pub struct DivisionService {
    n: u32,
    tx: Option<Arc<Sender<Request>>>,
    metrics: Arc<Metrics>,
    leader: Option<JoinHandle<()>>,
}

impl DivisionService {
    /// Start the leader thread (and backend) for `cfg`.
    pub fn start(cfg: ServiceConfig) -> Result<DivisionService> {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let n = cfg.n;

        enum Exec {
            Native { divider: Divider, threads: usize },
            Pjrt(Runtime),
        }

        // The PJRT client is thread-affine (Rc internally), so the backend
        // is constructed *inside* the leader thread; a ready-channel
        // surfaces startup errors to the caller synchronously.
        let backend = cfg.backend.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let policy = cfg.policy;
        let leader = std::thread::Builder::new()
            .name("posit-div-leader".into())
            .spawn(move || {
                let exec = match &backend {
                    Backend::Native { alg, threads } => match Divider::new(n, *alg) {
                        Ok(divider) => Exec::Native { divider, threads: *threads },
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    },
                    Backend::Pjrt { artifacts_dir } => {
                        match Runtime::load(artifacts_dir)
                            .and_then(|rt| rt.warmup(n).map(|()| rt))
                        {
                            Ok(rt) => Exec::Pjrt(rt),
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        }
                    }
                };
                let _ = ready_tx.send(Ok(()));
                while let Some(batch) = batcher::collect_batch(&rx, policy) {
                    let t0 = Instant::now();
                    let x: Vec<u64> = batch.iter().map(|r| r.x).collect();
                    let d: Vec<u64> = batch.iter().map(|r| r.d).collect();
                    let results: Vec<u64> = match &exec {
                        Exec::Native { divider, threads } => {
                            let mut out = vec![0u64; x.len()];
                            divider
                                .divide_batch_parallel(&x, &d, &mut out, *threads)
                                .expect("batch slices are same-length by construction");
                            out
                        }
                        Exec::Pjrt(rt) => match rt.divide_bits(n, &x, &d) {
                            Ok(q) => q,
                            Err(e) => {
                                // fail the whole batch as NaR and keep
                                // serving (errors are per-batch)
                                eprintln!("pjrt batch failed: {e}");
                                vec![1u64 << (n - 1); batch.len()]
                            }
                        },
                    };
                    m.batch_latency.record(t0.elapsed());
                    m.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    for (req, q) in batch.into_iter().zip(results) {
                        if q == 1u64 << (n - 1) {
                            m.special_results
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        m.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        m.request_latency.record(req.enqueued.elapsed());
                        let _ = req.respond.send(q); // receiver may have gone
                    }
                }
            })
            .map_err(|e| PositError::Execution { detail: format!("spawn leader: {e}") })?;

        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e),
            Err(_) => {
                return Err(PositError::Execution {
                    detail: "leader thread died during startup".into(),
                })
            }
        }
        Ok(DivisionService { n, tx: Some(Arc::new(tx)), metrics, leader: Some(leader) })
    }

    /// Posit width served.
    pub fn width(&self) -> u32 {
        self.n
    }

    /// A cloneable submission handle.
    pub fn client(&self) -> Client {
        let tx = self.tx.as_ref().expect("service running");
        Client { n: self.n, tx: Arc::downgrade(tx), metrics: self.metrics.clone() }
    }

    /// Blocking division (convenience over [`DivisionService::client`]).
    pub fn divide(&self, x: Posit, d: Posit) -> Result<Posit> {
        self.client().divide(x, d)
    }

    /// Submit many and wait for all (keeps ordering).
    pub fn divide_many(&self, pairs: &[(Posit, Posit)]) -> Result<Vec<Posit>> {
        self.client().divide_batch(pairs)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting requests and join the leader. Queued requests are
    /// drained first; clients outliving the service get
    /// [`PositError::ServiceStopped`] on new submissions.
    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DivisionService {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;
    use crate::posit::mask;
    use crate::testkit::Rng;

    fn native_cfg(n: u32) -> ServiceConfig {
        ServiceConfig {
            n,
            backend: Backend::Native { alg: Algorithm::DEFAULT, threads: 2 },
            policy: BatchPolicy { max_batch: 64, max_wait: std::time::Duration::from_micros(100) },
        }
    }

    #[test]
    fn native_service_matches_golden() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let mut rng = Rng::seeded(0xE2E);
        let pairs: Vec<(Posit, Posit)> = (0..500)
            .map(|_| {
                (
                    Posit::from_bits(16, rng.next_u64() & mask(16)),
                    Posit::from_bits(16, rng.next_u64() & mask(16)),
                )
            })
            .collect();
        let got = svc.divide_many(&pairs).unwrap();
        for (i, &(x, d)) in pairs.iter().enumerate() {
            assert_eq!(got[i], golden::divide(x, d).result, "{x:?}/{d:?}");
        }
        assert!(svc.metrics().requests.load(std::sync::atomic::Ordering::Relaxed) >= 500);
        svc.shutdown();
    }

    #[test]
    fn service_handles_specials() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let n = 16;
        let c = svc.client();
        assert!(c.divide(Posit::one(n), Posit::zero(n)).unwrap().is_nar());
        assert!(c.divide(Posit::zero(n), Posit::one(n)).unwrap().is_zero());
        assert!(c.divide(Posit::nar(n), Posit::one(n)).unwrap().is_nar());
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let svc = DivisionService::start(native_cfg(32)).unwrap();
        std::thread::scope(|s| {
            for t in 0..8 {
                let client = svc.client();
                s.spawn(move || {
                    let mut rng = Rng::seeded(t);
                    for _ in 0..200 {
                        let x = Posit::from_bits(32, rng.next_u64() & mask(32));
                        let d = Posit::from_bits(32, rng.next_u64() & mask(32));
                        let q = client.divide(x, d).unwrap();
                        assert_eq!(q, golden::divide(x, d).result);
                    }
                });
            }
        });
        assert!(svc.metrics().batches.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let pending = svc.client().submit(Posit::one(16), Posit::one(16)).unwrap();
        svc.shutdown();
        assert_eq!(pending.wait().unwrap(), Posit::one(16));
    }

    #[test]
    fn client_after_shutdown_is_typed_error() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let client = svc.client();
        svc.shutdown();
        assert_eq!(
            client.submit(Posit::one(16), Posit::one(16)).err(),
            Some(PositError::ServiceStopped)
        );
        assert_eq!(
            client.divide_batch(&[(Posit::one(16), Posit::one(16))]).err(),
            Some(PositError::ServiceStopped)
        );
    }

    #[test]
    fn width_mismatch_is_typed_error() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let client = svc.client();
        assert_eq!(
            client.submit(Posit::one(32), Posit::one(32)).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 32 })
        );
        // a bad pair anywhere in a batch rejects the whole batch up front
        let pairs = [(Posit::one(16), Posit::one(16)), (Posit::one(8), Posit::one(8))];
        assert_eq!(
            client.submit_batch(&pairs).err(),
            Some(PositError::WidthMismatch { expected: 16, got: 8 })
        );
        svc.shutdown();
    }

    #[test]
    fn submit_batch_preserves_order() {
        let svc = DivisionService::start(native_cfg(16)).unwrap();
        let client = svc.client();
        let pairs: Vec<(Posit, Posit)> = (1..=64u64)
            .map(|k| (Posit::from_f64(16, k as f64), Posit::one(16)))
            .collect();
        let got = client.submit_batch(&pairs).unwrap().wait().unwrap();
        for (k, q) in (1..=64u64).zip(&got) {
            assert_eq!(q.to_f64(), k as f64);
        }
        svc.shutdown();
    }
}
