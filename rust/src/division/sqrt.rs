//! Digit-recurrence posit square root — the extension feature.
//!
//! The paper's related work ([11], [12]) pairs division with square root
//! in one unit (the recurrences share the residual datapath), and the
//! authors' companion paper [13] is a posit sqrt unit; this module
//! provides the matching capability: a bit-serial digit-recurrence square
//! root on posit significands plus an exact golden reference, with the
//! same correctness discipline as the dividers (bit-exact vs golden,
//! exhaustive at Posit8, exact-rational nearest-value verification).
//!
//! Exponent path: `v = 2^T · m`, `m ∈ [1,2)`. With `q = ⌊T/2⌋` and
//! `a = m · 2^(T mod 2) ∈ [1,4)`, `√v = 2^q · √a` and `√a ∈ [1,2)` — the
//! posit regime/exponent split then happens in the shared encoder.
//! Negative values and NaR return NaR; zero returns zero.

use crate::posit::{frac_bits, round::encode_round, Posit, Unpacked};

/// Exact integer square root (golden): `⌊√A⌋` for u128.
pub fn isqrt_u128(a: u128) -> u128 {
    if a < 2 {
        return a;
    }
    // Newton on integers, seeded from the float estimate.
    let mut x = ((a as f64).sqrt() as u128).max(1);
    loop {
        let y = (x + a / x) >> 1;
        if y >= x {
            break;
        }
        x = y;
    }
    // floor fix-up (float seed can be off by one either way)
    while (x + 1) * (x + 1) <= a {
        x += 1;
    }
    while x * x > a {
        x -= 1;
    }
    x
}

/// Result of a posit square root.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SqrtResult {
    pub result: Posit,
    /// Digit-recurrence iterations (one result bit per iteration).
    pub iterations: u32,
}

/// Common wrapper: specials + exponent path + encode. `frac_sqrt` maps the
/// radicand `A = a·2^(2P)` to `(⌊√A⌋, sticky)` with P = F+2.
fn sqrt_with(v: Posit, frac_sqrt: impl Fn(u128, u32) -> (u128, bool, u32)) -> SqrtResult {
    let n = v.width();
    match v.unpack() {
        Unpacked::NaR => return SqrtResult { result: Posit::nar(n), iterations: 0 },
        Unpacked::Zero => return SqrtResult { result: Posit::zero(n), iterations: 0 },
        Unpacked::Real(d) if d.sign => {
            // √negative = NaR
            return SqrtResult { result: Posit::nar(n), iterations: 0 };
        }
        Unpacked::Real(d) => {
            let f = frac_bits(n);
            let p = f + 2; // result precision: F fraction + guard + round
            let t = d.scale;
            let q = t >> 1; // ⌊T/2⌋ (arithmetic shift)
            let odd = (t & 1) as u32;
            // A = a · 2^(2P), a = m·2^odd ∈ [1,4): exact integer radicand
            let a = (d.sig as u128) << (2 * p + odd - f);
            let (s, sticky, iterations) = frac_sqrt(a, p);
            debug_assert!(s >> p == 1, "√a must be in [1,2)");
            SqrtResult { result: encode_round(n, false, q, s, p, sticky), iterations }
        }
    }
}

/// Golden posit square root (exact integer isqrt + one rounding).
pub fn golden_sqrt(v: Posit) -> SqrtResult {
    sqrt_with(v, |a, _p| {
        let s = isqrt_u128(a);
        (s, s * s != a, 0)
    })
}

/// Digit-recurrence square root engine (radix-2, one result bit per
/// iteration — the classic non-restoring schoolbook recurrence on the
/// residual `w(j) = A − S(j)²` with partial result `S(j)`).
pub struct SqrtEngine;

impl SqrtEngine {
    pub fn new() -> Self {
        SqrtEngine
    }

    /// Posit square root, bit-exact with [`golden_sqrt`].
    pub fn sqrt(&self, v: Posit) -> SqrtResult {
        sqrt_with(v, |a, p| {
            // Compute ⌊√A⌋ for A ∈ [2^(2p), 2^(2p+2)) one bit per step:
            // try-bit from MSB down, keep the square ≤ A invariant — the
            // software form of the non-restoring S(j)/w(j) recurrence.
            let mut s: u128 = 0;
            let mut rem: u128 = 0; // w(j) = A − S(j)², maintained incrementally
            let mut iterations = 0;
            // consume A two bits at a time, MSB first (digit pairs)
            let total_bits = 2 * p + 2;
            for j in (0..total_bits / 2).rev() {
                iterations += 1;
                // bring down the next two radicand bits
                rem = (rem << 2) | ((a >> (2 * j)) & 0b11);
                let trial = (s << 2) | 1; // 2S(j)·2 + 1, the subtract term
                s <<= 1;
                if rem >= trial {
                    rem -= trial;
                    s |= 1;
                }
            }
            (s, rem != 0, iterations)
        })
    }

    /// Iterations for a Posit⟨n,2⟩ sqrt: one per result bit, P+1 = n−2.
    pub fn iterations(&self, n: u32) -> u32 {
        frac_bits(n) + 3
    }
}

impl Default for SqrtEngine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::mask;
    use crate::testkit::Rng;

    #[test]
    fn isqrt_exact() {
        let mut rng = Rng::seeded(0x50);
        for _ in 0..100_000 {
            let a = (rng.next_u64() as u128) << rng.range_inclusive(0, 40);
            let s = isqrt_u128(a);
            assert!(s * s <= a && (s + 1) * (s + 1) > a, "a={a}");
        }
        for a in 0..2000u128 {
            let s = isqrt_u128(a);
            assert!(s * s <= a && (s + 1) * (s + 1) > a);
        }
    }

    #[test]
    fn engine_equals_golden_exhaustive_p8_p10() {
        let e = SqrtEngine::new();
        for n in [8u32, 10] {
            for bits in 0..=mask(n) {
                let v = Posit::from_bits(n, bits);
                assert_eq!(e.sqrt(v).result, golden_sqrt(v).result, "n={n} {v:?}");
            }
        }
    }

    #[test]
    fn engine_equals_golden_random_wide() {
        let e = SqrtEngine::new();
        let mut rng = Rng::seeded(0x5017);
        for &n in &[16u32, 32, 64] {
            for _ in 0..20_000 {
                let v = Posit::from_bits(n, rng.next_u64() & mask(n));
                assert_eq!(e.sqrt(v).result, golden_sqrt(v).result, "n={n} {v:?}");
            }
        }
    }

    #[test]
    fn specials_and_negatives() {
        let e = SqrtEngine::new();
        for n in [8u32, 16, 32] {
            assert!(e.sqrt(Posit::nar(n)).result.is_nar());
            assert!(e.sqrt(Posit::zero(n)).result.is_zero());
            assert!(e.sqrt(Posit::one(n).neg()).result.is_nar());
            assert_eq!(e.sqrt(Posit::one(n)).result, Posit::one(n));
        }
    }

    #[test]
    fn known_values() {
        let e = SqrtEngine::new();
        let n = 32;
        for (v, want) in [(4.0, 2.0), (9.0, 3.0), (2.25, 1.5), (1e4, 1e2), (0.25, 0.5)] {
            let r = e.sqrt(Posit::from_f64(n, v)).result;
            assert_eq!(r.to_f64(), want, "sqrt({v})");
        }
        // irrational: within 1 ulp of the f64-rounded value
        let r = e.sqrt(Posit::from_f64(n, 2.0)).result;
        let want = Posit::from_f64(n, 2.0f64.sqrt());
        assert!(r.ulp_distance(want) <= 1);
    }

    /// Independent nearest-value verification: the returned posit r must
    /// satisfy mid_lo² ≤ v < mid_hi² at the pattern-space midpoints —
    /// exact integer comparisons only.
    #[test]
    fn nearest_value_verification_p16_random() {
        let e = SqrtEngine::new();
        let mut rng = Rng::seeded(0x9E);
        let n = 16;
        let f = frac_bits(n);
        for _ in 0..40_000 {
            let v = Posit::from_bits(n, rng.next_u64() & mask(n));
            if v.is_nar() || v.is_zero() || v.is_negative() {
                continue;
            }
            let r = e.sqrt(v).result;
            let dv = v.decode();
            // compare v vs mid² exactly: v = sig·2^(scale−f);
            // mid = msig·2^(mscale−mf) (width n+1 posit).
            let cmp_v_vs_sq = |mid: Posit| -> core::cmp::Ordering {
                let dm = mid.decode();
                let mf = frac_bits(n + 1) as i32;
                // v vs mid²  ⇔  sig·2^(scale−f) vs msig²·2^(2(mscale−mf))
                let e1 = dv.scale - f as i32;
                let e2 = 2 * (dm.scale - mf);
                let lhs = dv.sig as u128;
                let rhs = (dm.sig as u128) * (dm.sig as u128);
                let sh = e1 - e2;
                if sh >= 0 {
                    (lhs << sh.min(100) as u32).cmp(&rhs)
                } else {
                    lhs.cmp(&(rhs << (-sh).min(50) as u32))
                }
            };
            // upper midpoint (skip at maxpos saturation)
            if r != Posit::maxpos(n) {
                let mid_hi = Posit::from_bits(n + 1, (r.to_bits() << 1) | 1);
                assert_ne!(
                    cmp_v_vs_sq(mid_hi),
                    core::cmp::Ordering::Greater,
                    "{v:?}: √ rounds above {r:?}"
                );
            }
            if r != Posit::minpos(n) {
                let lo = r.next_down();
                let mid_lo = Posit::from_bits(n + 1, (lo.to_bits() << 1) | 1);
                assert_ne!(
                    cmp_v_vs_sq(mid_lo),
                    core::cmp::Ordering::Less,
                    "{v:?}: √ rounds below {r:?}"
                );
            }
        }
    }

    #[test]
    fn sqrt_squared_roundtrip() {
        let e = SqrtEngine::new();
        let mut rng = Rng::seeded(0x2705);
        for _ in 0..20_000 {
            let v = Posit::from_bits(32, rng.next_u64() & mask(32)).abs();
            if v.is_nar() || v.is_zero() {
                continue;
            }
            let r = e.sqrt(v).result;
            let back = r.mul(r);
            let vv = v.to_f64();
            if vv > 1e-30 && vv < 1e30 {
                let rel = (back.to_f64() - vv).abs() / vv;
                assert!(rel < 1e-6, "{v:?} -> {r:?} -> {back:?}");
            }
        }
    }
}
