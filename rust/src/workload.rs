//! Workload generators shared by the examples, benches and the e2e driver:
//! division-pair streams ([`Workload`]) and op-tagged mixed streams
//! ([`MixedOps`]) for the operation-generic unit service.

use std::time::Duration;

use crate::posit::{mask, Posit};
use crate::testkit::Rng;
use crate::unit::{Accuracy, Op, OpRequest};

/// A stream of division operand pairs of a fixed posit width.
pub trait Workload {
    fn next_pair(&mut self) -> (Posit, Posit);
    fn name(&self) -> &'static str;
}

/// Uniform random bit patterns (the synthesis-style stimulus): every
/// operand pattern equally likely, including extremes; divisor zero and
/// NaR excluded (special-path rates are measured separately).
pub struct Uniform {
    pub n: u32,
    rng: Rng,
}

impl Uniform {
    pub fn new(n: u32, seed: u64) -> Self {
        Uniform { n, rng: Rng::seeded(seed) }
    }
}

impl Workload for Uniform {
    fn next_pair(&mut self) -> (Posit, Posit) {
        let x = Posit::from_bits(self.n, self.rng.next_u64() & mask(self.n));
        let d = loop {
            let d = Posit::from_bits(self.n, self.rng.next_u64() & mask(self.n));
            if !d.is_zero() && !d.is_nar() {
                break d;
            }
        };
        (if x.is_nar() { Posit::one(self.n) } else { x }, d)
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// DSP-style operands: magnitudes concentrated around 1 (the regime where
/// posits are dense), as produced by normalized signal-processing kernels
/// — the workload the paper's introduction motivates.
pub struct DspTrace {
    pub n: u32,
    rng: Rng,
}

impl DspTrace {
    pub fn new(n: u32, seed: u64) -> Self {
        DspTrace { n, rng: Rng::seeded(seed) }
    }
    fn sample(&mut self) -> Posit {
        // log2-uniform in [2^-8, 2^8), random sign, dense fraction
        let scale = self.rng.range_i64(-8, 8) as f64;
        let frac = 1.0 + self.rng.f64_unit();
        let v = frac * scale.exp2();
        let v = if self.rng.chance(1, 2) { -v } else { v };
        Posit::from_f64(self.n, v)
    }
}

impl Workload for DspTrace {
    fn next_pair(&mut self) -> (Posit, Posit) {
        let x = self.sample();
        let mut d = self.sample();
        while d.is_zero() {
            d = self.sample();
        }
        (x, d)
    }

    fn name(&self) -> &'static str {
        "dsp-trace"
    }
}

/// Mixed traffic including special cases (zero dividends, zero divisors,
/// NaR) at a configurable per-mille rate — exercises the fast path.
pub struct MixedSpecials {
    pub n: u32,
    pub special_per_mille: u64,
    rng: Rng,
}

impl MixedSpecials {
    pub fn new(n: u32, special_per_mille: u64, seed: u64) -> Self {
        MixedSpecials { n, special_per_mille, rng: Rng::seeded(seed) }
    }
}

impl Workload for MixedSpecials {
    fn next_pair(&mut self) -> (Posit, Posit) {
        if self.rng.chance(self.special_per_mille, 1000) {
            match self.rng.below(3) {
                0 => (Posit::zero(self.n), Posit::one(self.n)),
                1 => (Posit::one(self.n), Posit::zero(self.n)),
                _ => (Posit::nar(self.n), Posit::one(self.n)),
            }
        } else {
            let x = Posit::from_bits(self.n, self.rng.next_u64() & mask(self.n));
            let d = Posit::from_bits(self.n, (self.rng.next_u64() & mask(self.n)) | 1);
            (x, d)
        }
    }

    fn name(&self) -> &'static str {
        "mixed-specials"
    }
}

/// Collect `count` pairs from a workload.
pub fn take(w: &mut dyn Workload, count: usize) -> Vec<(Posit, Posit)> {
    (0..count).map(|_| w.next_pair()).collect()
}

/// Relative weights of each operation in a mixed stream (division runs
/// the default engine; `dot`/`fsum`/`axpy` are the quire reductions,
/// drawn with short random vectors). All-zero weights degenerate to
/// division-only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpMix {
    pub div: u32,
    pub sqrt: u32,
    pub mul: u32,
    pub add: u32,
    pub sub: u32,
    pub mul_add: u32,
    pub dot: u32,
    pub fsum: u32,
    pub axpy: u32,
}

impl OpMix {
    /// A DSP-flavored default: division-heavy with an arithmetic
    /// background and some sqrt (normalization) traffic. No reduction
    /// traffic — ask for it explicitly (`dot:2,fsum:1,axpy:1`).
    pub const DEFAULT: OpMix = OpMix {
        div: 6,
        sqrt: 2,
        mul: 4,
        add: 4,
        sub: 2,
        mul_add: 2,
        dot: 0,
        fsum: 0,
        axpy: 0,
    };

    /// Pure division traffic (the pre-redesign workload).
    pub const DIV_ONLY: OpMix = OpMix {
        div: 1,
        sqrt: 0,
        mul: 0,
        add: 0,
        sub: 0,
        mul_add: 0,
        dot: 0,
        fsum: 0,
        axpy: 0,
    };

    pub fn total(&self) -> u32 {
        self.div
            + self.sqrt
            + self.mul
            + self.add
            + self.sub
            + self.mul_add
            + self.dot
            + self.fsum
            + self.axpy
    }

    /// Parse a `name:weight` list, e.g. `div:6,sqrt:2,dot:2` (ops not
    /// named get weight 0; `mul_add`/`muladd`/`fma` are synonyms, as are
    /// `fsum`/`fused_sum`). Returns `None` on unknown names, bad weights,
    /// an all-zero mix, or a repeated op — naming the same op twice
    /// (under any synonym) is almost certainly an operator typo, so it
    /// is rejected rather than letting the last entry silently win.
    pub fn parse(s: &str) -> Option<OpMix> {
        let mut mix = OpMix {
            div: 0,
            sqrt: 0,
            mul: 0,
            add: 0,
            sub: 0,
            mul_add: 0,
            dot: 0,
            fsum: 0,
            axpy: 0,
        };
        let mut seen = [false; 9];
        for part in s.split(',') {
            let (name, weight) = part.split_once(':')?;
            let weight: u32 = weight.trim().parse().ok()?;
            let (slot, field) = match name.trim() {
                "div" => (0, &mut mix.div),
                "sqrt" => (1, &mut mix.sqrt),
                "mul" => (2, &mut mix.mul),
                "add" => (3, &mut mix.add),
                "sub" => (4, &mut mix.sub),
                "mul_add" | "muladd" | "fma" => (5, &mut mix.mul_add),
                "dot" => (6, &mut mix.dot),
                "fsum" | "fused_sum" => (7, &mut mix.fsum),
                "axpy" => (8, &mut mix.axpy),
                _ => return None,
            };
            if std::mem::replace(&mut seen[slot], true) {
                return None;
            }
            *field = weight;
        }
        if mix.total() == 0 {
            return None;
        }
        Some(mix)
    }

    /// Sample an op according to the weights.
    fn pick(&self, rng: &mut Rng) -> Op {
        let total = self.total() as u64;
        if total == 0 {
            return Op::DIV;
        }
        let mut r = rng.below(total);
        for (weight, op) in [
            (self.div, Op::DIV),
            (self.sqrt, Op::Sqrt),
            (self.mul, Op::Mul),
            (self.add, Op::Add),
            (self.sub, Op::Sub),
            (self.mul_add, Op::MulAdd),
            (self.dot, Op::Dot),
            (self.fsum, Op::FusedSum),
            (self.axpy, Op::Axpy),
        ] {
            if r < weight as u64 {
                return op;
            }
            r -= weight as u64;
        }
        Op::DIV
    }
}

/// Op-tagged mixed traffic for the unit service: uniform random real
/// operands with per-op sanitization (no NaR inputs, nonzero divisors,
/// non-negative radicands) so the stream measures the datapaths rather
/// than the special-case fast path.
pub struct MixedOps {
    pub n: u32,
    pub mix: OpMix,
    accuracy: Accuracy,
    deadline_ms: u32,
    rng: Rng,
}

impl MixedOps {
    pub fn new(n: u32, mix: OpMix, seed: u64) -> Self {
        MixedOps { n, mix, accuracy: Accuracy::Exact, deadline_ms: 0, rng: Rng::seeded(seed) }
    }

    /// Stamp every generated request with an accuracy policy (the
    /// default is [`Accuracy::Exact`]). `Ulp(k)` traffic is what the
    /// service routes to the approx tier when a bounded-error kernel's
    /// declared spec satisfies `k`.
    pub fn with_accuracy(mut self, accuracy: Accuracy) -> Self {
        self.accuracy = accuracy;
        self
    }

    /// Stamp every generated request with an end-to-end deadline budget
    /// in milliseconds (0 = none, the default): the service drops the
    /// request with [`crate::error::PositError::DeadlineExceeded`] if
    /// the budget expires before admission.
    pub fn with_deadline_ms(mut self, deadline_ms: u32) -> Self {
        self.deadline_ms = deadline_ms;
        self
    }

    fn real(&mut self) -> Posit {
        loop {
            let p = Posit::from_bits(self.n, self.rng.next_u64() & mask(self.n));
            if !p.is_nar() {
                return p;
            }
        }
    }

    fn nonzero(&mut self) -> Posit {
        loop {
            let p = self.real();
            if !p.is_zero() {
                return p;
            }
        }
    }

    /// A short random reduction vector (2–8 elements keeps mixed batches
    /// latency-comparable to the scalar ops).
    fn real_vec(&mut self) -> Vec<Posit> {
        let k = 2 + self.rng.below(7) as usize;
        (0..k).map(|_| self.real()).collect()
    }

    /// The next op-tagged request of the stream.
    pub fn next_request(&mut self) -> OpRequest {
        let req = match self.mix.pick(&mut self.rng) {
            Op::Div { alg } => {
                let (x, d) = (self.real(), self.nonzero());
                OpRequest::div_with(alg, x, d)
            }
            Op::Sqrt => {
                let v = self.real().abs();
                OpRequest::sqrt(v)
            }
            Op::Mul => {
                let (a, b) = (self.real(), self.real());
                OpRequest::mul(a, b)
            }
            Op::Add => {
                let (a, b) = (self.real(), self.real());
                OpRequest::add(a, b)
            }
            Op::Sub => {
                let (a, b) = (self.real(), self.real());
                OpRequest::sub(a, b)
            }
            Op::MulAdd => {
                let (a, b, c) = (self.real(), self.real(), self.real());
                OpRequest::mul_add(a, b, c)
            }
            Op::Dot => {
                let a = self.real_vec();
                let b: Vec<Posit> = (0..a.len()).map(|_| self.real()).collect();
                OpRequest::dot(&a, &b).expect("generated lanes match")
            }
            Op::FusedSum => {
                let xs = self.real_vec();
                OpRequest::fused_sum(&xs).expect("generated lane is nonempty")
            }
            Op::Axpy => {
                let alpha = self.real();
                let xs = self.real_vec();
                let ys: Vec<Posit> = (0..xs.len()).map(|_| self.real()).collect();
                OpRequest::axpy(alpha, &xs, &ys).expect("generated lanes match")
            }
        };
        req.with_accuracy(self.accuracy).with_deadline_ms(self.deadline_ms)
    }

    pub fn name(&self) -> &'static str {
        "mixed-ops"
    }
}

/// Collect `count` requests from a mixed stream.
pub fn take_requests(w: &mut MixedOps, count: usize) -> Vec<OpRequest> {
    (0..count).map(|_| w.next_request()).collect()
}

/// Open-loop traffic: a [`MixedOps`] stream paced by a Poisson arrival
/// process at a fixed offered rate. Unlike the closed-loop generators
/// above (which produce the next request whenever the consumer is
/// ready), arrivals here carry *timestamps* that do not care whether
/// the service keeps up — the drive that exposes queueing delay and
/// tail latency, which closed loops structurally hide.
///
/// Inter-arrival gaps are exponential (`-ln(1-U)·mean`), so bursts
/// happen naturally; the service sees realistic short-term overload
/// even when the average rate is sustainable.
pub struct OpenLoop {
    ops: MixedOps,
    mean_gap_ns: f64,
    clock_ns: f64,
    rng: Rng,
}

impl OpenLoop {
    /// A stream of `mix`-distributed Posit-`n` requests arriving at
    /// `rate_per_sec` on average (clamped below at 1 req/s).
    pub fn new(n: u32, mix: OpMix, rate_per_sec: f64, seed: u64) -> Self {
        let rate = if rate_per_sec.is_finite() { rate_per_sec.max(1.0) } else { 1.0 };
        OpenLoop {
            ops: MixedOps::new(n, mix, seed),
            mean_gap_ns: 1e9 / rate,
            clock_ns: 0.0,
            rng: Rng::seeded(seed ^ 0x9E37_79B9_7F4A_7C15),
        }
    }

    /// Stamp every arrival with an accuracy policy (default Exact).
    pub fn with_accuracy(mut self, accuracy: Accuracy) -> Self {
        self.ops = self.ops.with_accuracy(accuracy);
        self
    }

    /// Stamp every arrival with a deadline budget in ms (default 0 = none).
    pub fn with_deadline_ms(mut self, deadline_ms: u32) -> Self {
        self.ops = self.ops.with_deadline_ms(deadline_ms);
        self
    }

    /// The configured mean arrival rate, in requests per second.
    pub fn rate(&self) -> f64 {
        1e9 / self.mean_gap_ns
    }

    pub fn width(&self) -> u32 {
        self.ops.n
    }

    /// The next arrival: its offset from the start of the drive (a
    /// strictly advancing clock) and the request itself.
    pub fn next_arrival(&mut self) -> (Duration, OpRequest) {
        let u = self.rng.f64_unit();
        self.clock_ns += -(1.0 - u).ln() * self.mean_gap_ns;
        (Duration::from_nanos(self.clock_ns as u64), self.ops.next_request())
    }

    pub fn name(&self) -> &'static str {
        "open-loop"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_never_yields_invalid_divisor() {
        let mut w = Uniform::new(16, 1);
        for _ in 0..5000 {
            let (x, d) = w.next_pair();
            assert!(!d.is_zero() && !d.is_nar());
            assert!(!x.is_nar());
        }
    }

    #[test]
    fn dsp_trace_is_centered() {
        let mut w = DspTrace::new(32, 2);
        let mut in_band = 0;
        for _ in 0..2000 {
            let (x, _) = w.next_pair();
            let v = x.to_f64().abs();
            if (2.0f64.powi(-10)..2.0f64.powi(10)).contains(&v) {
                in_band += 1;
            }
        }
        assert!(in_band > 1900, "{in_band}");
    }

    #[test]
    fn op_mix_parse() {
        let m = OpMix::parse("div:6,sqrt:2,mul:4").unwrap();
        assert_eq!(
            m,
            OpMix {
                div: 6,
                sqrt: 2,
                mul: 4,
                add: 0,
                sub: 0,
                mul_add: 0,
                dot: 0,
                fsum: 0,
                axpy: 0
            }
        );
        assert_eq!(OpMix::parse("fma:3").unwrap().mul_add, 3);
        let r = OpMix::parse("dot:2,fsum:1,axpy:1").unwrap();
        assert_eq!((r.dot, r.fsum, r.axpy), (2, 1, 1));
        assert_eq!(OpMix::parse("fused_sum:4").unwrap().fsum, 4, "fsum synonym");
        assert!(OpMix::parse("frobnicate:1").is_none());
        assert!(OpMix::parse("div:x").is_none());
        assert!(OpMix::parse("div:0").is_none(), "all-zero mixes are rejected");
        assert!(OpMix::parse("div").is_none(), "missing weight");
    }

    #[test]
    fn op_mix_parse_rejects_duplicate_keys() {
        assert!(OpMix::parse("div:1,div:2").is_none(), "repeated key");
        assert!(OpMix::parse("div:6,sqrt:2,div:1").is_none(), "repeat after others");
        assert!(OpMix::parse("fma:1,muladd:2").is_none(), "duplicate via synonym");
        assert!(OpMix::parse("fsum:1,fused_sum:1").is_none(), "duplicate via synonym");
        // distinct keys still parse, whatever the synonym spelling
        assert_eq!(OpMix::parse("muladd:2,fsum:1").map(|m| (m.mul_add, m.fsum)), Some((2, 1)));
    }

    #[test]
    fn mixed_ops_stamp_accuracy() {
        let mut w = MixedOps::new(16, OpMix::DEFAULT, 7);
        assert_eq!(w.next_request().accuracy(), Accuracy::Exact);
        let mut w = MixedOps::new(16, OpMix::DEFAULT, 7).with_accuracy(Accuracy::Ulp(3));
        for _ in 0..100 {
            assert_eq!(w.next_request().accuracy(), Accuracy::Ulp(3));
        }
        let mut wl = OpenLoop::new(16, OpMix::DEFAULT, 1000.0, 7).with_accuracy(Accuracy::Ulp(9));
        let (_, req) = wl.next_arrival();
        assert_eq!(req.accuracy(), Accuracy::Ulp(9));
    }

    #[test]
    fn mixed_ops_stamp_deadline() {
        let mut w = MixedOps::new(16, OpMix::DEFAULT, 7);
        assert_eq!(w.next_request().deadline_ms(), 0, "no deadline by default");
        let mut w = MixedOps::new(16, OpMix::DEFAULT, 7).with_deadline_ms(250);
        for _ in 0..100 {
            assert_eq!(w.next_request().deadline_ms(), 250);
        }
        let mut wl = OpenLoop::new(16, OpMix::DEFAULT, 1000.0, 7).with_deadline_ms(9);
        let (_, req) = wl.next_arrival();
        assert_eq!(req.deadline_ms(), 9);
    }

    #[test]
    fn mixed_ops_stream_is_sane() {
        let mut w = MixedOps::new(16, OpMix::DEFAULT, 0x55);
        let mut sqrt_seen = 0u32;
        let mut fma_seen = 0u32;
        for _ in 0..4000 {
            let req = w.next_request();
            assert_eq!(req.width(), 16);
            if req.op.is_reduction() {
                let (a, _, _) = req.vector_lanes().expect("reductions carry vectors");
                assert!(!a.is_empty());
            } else {
                assert_eq!(req.operands().len(), req.op.arity());
            }
            for p in req.operands() {
                assert!(!p.is_nar(), "{:?}", req.op);
            }
            match req.op {
                Op::Div { .. } => assert!(!req.operands()[1].is_zero()),
                Op::Sqrt => {
                    assert!(!req.operands()[0].is_negative());
                    sqrt_seen += 1;
                }
                Op::MulAdd => fma_seen += 1,
                _ => {}
            }
        }
        // with weights 2/20 and 2/20, both must show up in 4000 draws
        assert!(sqrt_seen > 100, "{sqrt_seen}");
        assert!(fma_seen > 100, "{fma_seen}");
    }

    #[test]
    fn mixed_ops_respects_degenerate_mixes() {
        let mut w = MixedOps::new(16, OpMix::DIV_ONLY, 1);
        for _ in 0..200 {
            assert!(matches!(w.next_request().op, Op::Div { .. }));
        }
        let only_sqrt = OpMix {
            div: 0,
            sqrt: 5,
            mul: 0,
            add: 0,
            sub: 0,
            mul_add: 0,
            dot: 0,
            fsum: 0,
            axpy: 0,
        };
        let mut w = MixedOps::new(16, only_sqrt, 2);
        for _ in 0..200 {
            assert_eq!(w.next_request().op, Op::Sqrt);
        }
    }

    #[test]
    fn mixed_reduction_stream_is_sane() {
        let mix = OpMix::parse("dot:2,fsum:1,axpy:1").unwrap();
        let mut w = MixedOps::new(16, mix, 0xABC);
        let mut seen = [0u32; 3];
        for _ in 0..600 {
            let req = w.next_request();
            assert!(req.op.is_reduction());
            let (a, b, alpha) = req.vector_lanes().expect("reductions carry vectors");
            assert!((2..=8).contains(&a.len()), "{}", a.len());
            for p in a.iter().chain(b.iter()).chain([&alpha]) {
                assert!(!p.is_nar());
                assert_eq!(p.width(), 16);
            }
            match req.op {
                Op::Dot => {
                    assert_eq!(b.len(), a.len());
                    seen[0] += 1;
                }
                Op::FusedSum => {
                    assert!(b.is_empty());
                    seen[1] += 1;
                }
                _ => {
                    assert_eq!(b.len(), a.len());
                    seen[2] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&s| s > 50), "{seen:?}");
    }

    #[test]
    fn open_loop_arrivals_are_poisson_paced() {
        let mut wl = OpenLoop::new(16, OpMix::DEFAULT, 50_000.0, 9);
        assert_eq!(wl.rate(), 50_000.0);
        assert_eq!(wl.width(), 16);
        let mut last = Duration::ZERO;
        let count = 10_000;
        let mut final_at = Duration::ZERO;
        for _ in 0..count {
            let (at, req) = wl.next_arrival();
            assert!(at >= last, "arrival clock must not run backwards");
            assert_eq!(req.width(), 16);
            last = at;
            final_at = at;
        }
        // mean gap of an exponential at 50k/s is 20µs; over 10k draws
        // the total should land near 200ms (±30%)
        let total_ms = final_at.as_secs_f64() * 1e3;
        assert!((140.0..260.0).contains(&total_ms), "{total_ms}ms");
        // same seed → identical schedule (resumable, shardable drives)
        let mut again = OpenLoop::new(16, OpMix::DEFAULT, 50_000.0, 9);
        for _ in 0..count {
            again.next_arrival();
        }
        let (a1, _) = wl.next_arrival();
        let (a2, _) = again.next_arrival();
        assert_eq!(a1, a2);
    }

    #[test]
    fn open_loop_clamps_degenerate_rates() {
        assert_eq!(OpenLoop::new(16, OpMix::DEFAULT, 0.0, 1).rate(), 1.0);
        assert_eq!(OpenLoop::new(16, OpMix::DEFAULT, f64::NAN, 1).rate(), 1.0);
        assert_eq!(OpenLoop::new(16, OpMix::DEFAULT, f64::INFINITY, 1).rate(), 1.0);
    }

    #[test]
    fn mixed_specials_rate() {
        let mut w = MixedSpecials::new(16, 100, 3);
        let mut specials = 0;
        for _ in 0..10_000 {
            let (x, d) = w.next_pair();
            if x.is_zero() || x.is_nar() || d.is_zero() {
                specials += 1;
            }
        }
        assert!((700..1300).contains(&specials), "{specials}");
    }
}
