"""AOT export: lower the L2 division graph to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot --out-dir ../artifacts
Emits one artifact per (format, batch): div_p{16,32}_b{B}.hlo.txt plus a
manifest the Rust runtime reads to discover shapes.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

# (posit width, batch) variants exported by `make artifacts`.
VARIANTS = [(16, 256), (32, 256), (16, 1024), (32, 1024)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n: int, batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch,), jnp.int64)

    def fn(x, d):
        return (model.divide_batch(x, d, n),)

    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    # xla_extension 0.5.1 (the Rust runtime's XLA) mis-executes the s64
    # gather ops jax >= 0.8 emits: refuse to ship a graph containing one.
    assert " gather(" not in text, "exported graph contains gather - unsupported by XLA 0.5.1"
    return text


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(legacy single-file mode marker)")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    for n, batch in VARIANTS:
        text = lower_variant(n, batch)
        name = f"div_p{n}_b{batch}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest[name] = {"n": n, "batch": batch, "dtype": "s64", "inputs": 2}
        print(f"wrote {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # legacy marker expected by the Makefile dependency rule
    if args.out:
        with open(args.out, "w") as f:
            f.write(json.dumps({"see": "manifest.json"}))
    print(f"wrote manifest.json ({len(manifest)} variants)")


if __name__ == "__main__":
    main()
