//! Batch throughput of the operation-generic unit (every `Op` × width,
//! plus mixed-op coordinator rows) — thin shim over
//! [`posit_div::bench::suites`], where the suite body lives so the same
//! code runs under `cargo bench --bench unit_throughput` and
//! `posit-div bench unit_throughput` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("unit_throughput");
}
