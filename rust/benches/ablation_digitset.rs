//! Radix-4 digit-set ablation: a=2 (the paper's choice) vs a=3 —
//! thin shim over [`posit_div::bench::suites`], where the suite body
//! lives so the same code runs under `cargo bench --bench ablation_digitset`
//! and `posit-div bench ablation_digitset` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("ablation_digitset");
}
