//! SRT radix-4 with operand scaling (§III-B4, Table I, Eq. (29)).
//!
//! Both operands are pre-multiplied by the Table I factor `M ≈ 1/d`
//! (a shift-add, one extra cycle), bringing the divisor into
//! `[1 − 1/64, 1 + 1/8]` so the quotient-digit selection becomes
//! divisor-independent: five constants on a 6-bit estimate (Eq. (29))
//! instead of the 8-row `m_k(d̂)` table. The quotient is unchanged
//! (`Mx/Md = x/d`); the residual datapath carries three extra fractional
//! bits for the exact scaled operands.
//!
//! This engine always includes the CS + OF + FR optimizations (the paper
//! evaluates scaling as an addition on top of the optimized radix-4 unit).

use super::carry_save::CsPair;
use super::otf::Otf;
use super::scaling::{scale, table_index};
use super::selection::sel_srt4_scaled;
use super::{iterations, Algorithm, DivEngine, FracQuotient};
use crate::posit::frac_bits;

/// Radix-4 divider with operand scaling.
pub struct Srt4Scaled;

impl Srt4Scaled {
    pub fn new() -> Self {
        Srt4Scaled
    }
}

impl Default for Srt4Scaled {
    fn default() -> Self {
        Self::new()
    }
}

impl DivEngine for Srt4Scaled {
    fn name(&self) -> &'static str {
        "SRT r4 scaled"
    }

    fn algorithm(&self) -> Algorithm {
        Algorithm::Srt4Scaled
    }

    fn fraction_divide(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
        let f = frac_bits(n);
        assert!(n >= 8, "scaled radix-4 requires n >= 8 (3 divisor fraction bits)");
        debug_assert!(x_sig >> f == 1 && d_sig >> f == 1);
        let it = iterations(n, 4);

        // FW = F+6 fractional bits: F+1 significand bits ([1/2,1)
        // convention) + 3 for the exact ×M shift-add + 2 for the ÷4
        // initialization. Headroom: sign + 3 integer bits.
        let fw = f + 6;
        let width = fw + 4;

        // Scaling step (the +1 cycle): idx from the 3 fraction bits of d.
        let idx = table_index(d_sig as u128, f + 1);
        let zd = scale((d_sig as u128) << 5, idx); // M·d, exact in FW units
        let zx = scale((x_sig as u128) << 5, idx); // M·x
        debug_assert!(zx & 0b11 == 0, "M·x has two spare LSBs (multiple of 4)");

        // Scaled-divisor guarantee of [33],[34]: M·d ∈ [1 − 1/64, 1 + 1/8].
        debug_assert!(
            zd >= (63u128 << (fw - 6)) && zd <= (9u128 << (fw - 3)),
            "scaled divisor out of [63/64, 9/8]"
        );

        let mut w = CsPair::from_value((zx >> 2) as i128, width); // w(0) = Mx/4
        let mut otf = Otf::new(2);

        for _ in 0..it {
            let shifted = w.shl(2);
            // Eq. (29): 6-bit estimate — 3 integer + 3 fractional bits.
            let t = shifted.estimate(fw - 3);
            debug_assert!((-32..32).contains(&t), "estimate {t} overflows 6-bit slice");
            let digit = sel_srt4_scaled(t);
            w = match digit {
                2 => shifted.csa(!(zd << 1), true),
                1 => shifted.csa(!zd, true),
                -1 => shifted.csa(zd, false),
                -2 => shifted.csa(zd << 1, false),
                _ => shifted,
            };
            otf.push(digit);
            // ρ = 2/3 bound w.r.t. the *scaled* divisor.
            debug_assert!(
                3 * w.resolve().unsigned_abs() <= 2 * zd,
                "scaled residual out of bound"
            );
        }

        // FR termination on the scaled remainder (zero iff true remainder
        // is zero: M > 0 and the scaling is exact).
        let neg = w.sign_lookahead();
        let rem_zero = if neg { w.is_zero_with_addend(zd) } else { w.is_zero_lookahead() };

        FracQuotient {
            mag: otf.result(neg),
            frac_bits: 2 * it - 2,
            sticky: !rem_zero,
            iterations: it,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;
    use crate::posit::mask;

    #[test]
    fn scaled_equals_golden_random_all_widths() {
        let mut rng = crate::testkit::Rng::seeded(0x5CA1ED);
        let e = Srt4Scaled::new();
        for &n in &[8u32, 10, 16, 24, 32, 48, 64] {
            let f = frac_bits(n);
            for _ in 0..4000 {
                let x = (1 << f) | (rng.next_u64() & mask(f));
                let d = (1 << f) | (rng.next_u64() & mask(f));
                let q = e.fraction_divide(n, x, d);
                let (g, gs) = golden::frac_divide(n, x, d).refine_to(q.frac_bits);
                assert_eq!((q.mag, q.sticky), (g, gs), "n={n} x={x:#x} d={d:#x}");
            }
        }
    }

    #[test]
    fn scaled_full_divide_p8_exhaustive() {
        let e = Srt4Scaled::new();
        let n = 8;
        for xb in 0..=mask(n) {
            for db in 0..=mask(n) {
                let x = crate::posit::Posit::from_bits(n, xb);
                let d = crate::posit::Posit::from_bits(n, db);
                assert_eq!(e.divide(x, d).result, golden::divide(x, d).result, "{x:?}/{d:?}");
            }
        }
    }

    #[test]
    fn scaled_matches_unscaled_radix4() {
        // Same quotients as the unscaled radix-4 engine (both are exact).
        let mut rng = crate::testkit::Rng::seeded(0x5C2);
        let a = Srt4Scaled::new();
        let b = crate::division::srt4_cs::Srt4Cs::with_otf_fr();
        for _ in 0..10_000 {
            let n = 32;
            let f = frac_bits(n);
            let x = (1 << f) | (rng.next_u64() & mask(f));
            let d = (1 << f) | (rng.next_u64() & mask(f));
            assert_eq!(
                a.fraction_divide(n, x, d),
                b.fraction_divide(n, x, d),
                "x={x:#x} d={d:#x}"
            );
        }
    }
}
