//! SWAR lane-packed batch kernels — the portable vectorized layer of the
//! Fast tier.
//!
//! The scalar Fast kernels ([`super::fastpath`]) already replaced the
//! cycle-accurate recurrence with direct fixed-point arithmetic, but they
//! still classify, decode, divide and round one lane at a time. Posit
//! vector-unit proposals (PVU, FPPU) get their throughput from lanes, not
//! from a faster scalar datapath; this module is the software analogue of
//! that idea, structured as three passes over a batch:
//!
//! 1. **SWAR pre-pass** — 16×Posit8 or 8×Posit16 lanes are packed into
//!    one `u128` word and the decode-time special patterns (zero, NaR,
//!    negative radicand, zero addend) are detected *per word* with
//!    branch-free bit tricks (carry-contained zero-lane detection, mask
//!    expansion by multiplication, lane-wise two's complement). Special
//!    lanes are resolved in bulk straight from the masks; a word with no
//!    special lane costs one compare.
//! 2. **SoA mid-section** — surviving real lanes are decoded into
//!    structure-of-arrays buffers (sign/scale/significand as contiguous
//!    `i32`/`u64` arrays) and the fraction arithmetic runs in tight,
//!    branch-free loops over those arrays: one native `u64` division per
//!    division lane (the generic kernel pays a `u128` libcall), one
//!    integer square root per sqrt lane, one widening multiply per mul
//!    lane. Add/sub/mul-add lanes reuse the exact posit library routines
//!    (their alignment/cancellation path is already a single pass and is
//!    the bit-identity reference).
//! 3. **Encode post-pass** — the shared regime-aware rounding
//!    ([`crate::posit::round::encode_round`]) runs over the SoA results
//!    and scatters into the output.
//!
//! Every pass computes the *same* integer math as the scalar kernels, so
//! the results are bit-identical by construction — and by test: the SWAR
//! path is swept against the scalar-fast and Datapath paths (specials and
//! NaR included) in `tests/tier_equivalence.rs` and exhaustively at
//! Posit8 in the module tests below.
//!
//! The special pre-pass (pass 1) is shared with the explicit vector-ISA
//! kernels in [`super::vector`] through `special_prepass`: both kernel
//! families classify the same way and differ only in how the surviving
//! real lanes compute their fraction arithmetic.
//!
//! Supported widths: n ∈ {8, 16} ([`supports`]); wider formats stay on
//! the width-monomorphized scalar kernels, where even a `u128` word holds
//! too few lanes for the packed pre-pass to pay for itself.

use crate::posit::{frac_bits, mask, round::encode_round, Posit};

use super::fastpath::{scalar_bits, Kind};
use super::sqrt::isqrt_u128;

/// Lanes processed per SoA block (a multiple of the per-word lane count
/// for both supported widths, sized so the scratch buffers stay on the
/// stack). Shared with [`super::vector`], whose widest kernels also step
/// inside one block, and exported to the dispatch layer as
/// `fastpath::LANE_BLOCK` so parallel chunking can align to it.
pub(crate) const BLOCK: usize = 64;

/// True when `n` has a SWAR kernel (16 lanes of Posit8 or 8 lanes of
/// Posit16 per `u128` word).
#[inline]
pub const fn supports(n: u32) -> bool {
    n == 8 || n == 16
}

/// Splat an `N`-bit lane value across the `L` lanes of a word.
const fn splat<const N: u32, const L: usize>(v: u64) -> u128 {
    let mut w = 0u128;
    let mut i = 0;
    while i < L {
        w |= (v as u128) << (i as u32 * N);
        i += 1;
    }
    w
}

/// SWAR batch execution: `out[i] = kind(a[i], b[i], c[i])` for every
/// lane, bit-identical to the scalar Fast kernel. `n` must satisfy
/// [`supports`]; unused operand lanes may be empty or padded, used lanes
/// must match `out` (the callers pre-validate, exactly as for the scalar
/// batch kernels).
pub fn run_batch(n: u32, kind: Kind, a: &[u64], b: &[u64], c: &[u64], out: &mut [u64]) {
    debug_assert!(supports(n), "no SWAR kernel for n={n}");
    match n {
        8 => batch::<8, 16>(kind, a, b, c, out),
        _ => batch::<16, 8>(kind, a, b, c, out),
    }
}

/// Slice a possibly-empty operand lane to a block window.
#[inline(always)]
pub(crate) fn window(lane: &[u64], start: usize, len: usize) -> &[u64] {
    if lane.is_empty() {
        lane
    } else {
        &lane[start..start + len]
    }
}

fn batch<const N: u32, const L: usize>(
    kind: Kind,
    a: &[u64],
    b: &[u64],
    c: &[u64],
    out: &mut [u64],
) {
    let len = out.len();
    let mut start = 0usize;
    while start < len {
        let m = (len - start).min(BLOCK);
        block::<N, L>(
            kind,
            &a[start..start + m],
            window(b, start, m),
            window(c, start, m),
            &mut out[start..start + m],
        );
        start += m;
    }
}

/// Special-detection result for one packed word: `mask` has every bit of
/// each special lane set, `bits` holds those lanes' resolved results
/// (real lanes are zero in both).
struct SpecialWord {
    mask: u128,
    bits: u128,
}

/// The packed special pre-pass for one word of `L` lanes: the SWAR
/// mirror of the scalar `special()` table, including its precedence
/// (NaR-producing patterns first, then zero/pass-through patterns).
#[inline(always)]
fn special_word<const N: u32, const L: usize>(
    kind: Kind,
    wa: u128,
    wb: u128,
    wc: u128,
) -> SpecialWord {
    // Lane-geometry constants (const-folded per monomorphization).
    let low = splat::<N, L>(mask(N - 1)); // low N-1 bits of every lane
    let msb = splat::<N, L>(1u64 << (N - 1)); // sign/NaR bit of every lane
    let one = splat::<N, L>(1);

    // MSB-flag set in every zero lane, exactly (the naive `(w - 1) & !w`
    // borrow trick has false positives across lanes; this carry-contained
    // form does not: `(x & low) + low` cannot carry out of a lane).
    let zero_msb = |w: u128| !(((w & low) + low) | w | low) & msb;
    // Expand MSB flags to full-lane masks: move each flag to its lane's
    // LSB, then multiply by the all-ones lane value (lane products cannot
    // overlap, so the multiply is a lane-wise fill).
    let expand = |flags: u128| (flags >> (N - 1)).wrapping_mul(mask(N) as u128);
    // Lane-wise two's complement: bitwise NOT, then +1 per lane through
    // the carry-contained SWAR add (MSBs recombined by XOR so a full lane
    // cannot carry into its neighbor).
    let lane_neg = |w: u128| {
        let x = !w;
        ((x & !msb).wrapping_add(one)) ^ ((x ^ one) & msb)
    };

    let za = expand(zero_msb(wa));
    let na = expand(zero_msb(wa ^ msb));
    let (mask_, bits) = match kind {
        Kind::Div => {
            let zb = expand(zero_msb(wb));
            let nb = expand(zero_msb(wb ^ msb));
            let nar = na | nb | zb;
            (nar | za, msb & nar)
        }
        Kind::Sqrt => {
            // NaR and every negative real have the sign bit set.
            let nar = expand(wa & msb);
            (nar | za, msb & nar)
        }
        Kind::Mul => {
            let zb = expand(zero_msb(wb));
            let nb = expand(zero_msb(wb ^ msb));
            let nar = na | nb;
            (nar | za | zb, msb & nar)
        }
        Kind::Add | Kind::Sub => {
            let zb = expand(zero_msb(wb));
            let nb = expand(zero_msb(wb ^ msb));
            let nar = na | nb;
            // b == 0 -> a; else a == 0 -> b (Add) / -b (Sub); the scalar
            // table checks b first, so a == 0 only fires when b != 0.
            let b_zero = zb & !nar;
            let a_zero = za & !nar & !zb;
            let other = if kind == Kind::Sub { lane_neg(wb) } else { wb };
            (nar | zb | (za & !nar), (msb & nar) | (wa & b_zero) | (other & a_zero))
        }
        Kind::MulAdd => {
            let zb = expand(zero_msb(wb));
            let nb = expand(zero_msb(wb ^ msb));
            let nc = expand(zero_msb(wc ^ msb));
            let nar = na | nb | nc;
            // exact-zero product: a·b + c = c
            let pass_c = (za | zb) & !nar;
            (nar | pass_c, (msb & nar) | (wc & pass_c))
        }
    };
    SpecialWord { mask: mask_, bits }
}

/// The packed special pre-pass over one block: packs `L` lanes per
/// `u128` word, resolves every special lane straight into `out`, serves
/// the ragged tail (block length not a multiple of `L`) through the
/// scalar kernel, and compacts the surviving real-lane positions into
/// `real_idx`. Returns the number of real lanes.
///
/// Shared between the SWAR mid-sections below and the explicit vector
/// kernels in [`super::vector`] — both consume the same compacted
/// real-lane list, so classification stays bit-identical across the
/// whole Fast tier by construction.
pub(crate) fn special_prepass<const N: u32, const L: usize>(
    kind: Kind,
    a: &[u64],
    b: &[u64],
    c: &[u64],
    out: &mut [u64],
    real_idx: &mut [u8; BLOCK],
) -> usize {
    let m = out.len();
    let msk = mask(N);
    let lane = |l: &[u64], i: usize| if l.is_empty() { 0 } else { l[i] & msk };

    let mut r = 0usize;
    let words = m / L;
    for wi in 0..words {
        let base = wi * L;
        let mut wa = 0u128;
        let mut wb = 0u128;
        let mut wc = 0u128;
        for j in 0..L {
            wa |= (lane(a, base + j) as u128) << (j as u32 * N);
            wb |= (lane(b, base + j) as u128) << (j as u32 * N);
            wc |= (lane(c, base + j) as u128) << (j as u32 * N);
        }
        let sp = special_word::<N, L>(kind, wa, wb, wc);
        if sp.mask == 0 {
            // dense word: every lane is real
            for j in 0..L {
                real_idx[r] = (base + j) as u8;
                r += 1;
            }
        } else {
            for j in 0..L {
                let sh = j as u32 * N;
                if (sp.mask >> sh) as u64 & msk != 0 {
                    out[base + j] = (sp.bits >> sh) as u64 & msk;
                } else {
                    real_idx[r] = (base + j) as u8;
                    r += 1;
                }
            }
        }
    }
    // ragged tail (batch length not a multiple of the lane count): the
    // scalar kernel serves the leftover lanes — bit-identical by
    // construction.
    for i in words * L..m {
        out[i] = scalar_bits(N, kind, lane(a, i), lane(b, i), lane(c, i));
    }
    r
}

/// One SoA block: packed pre-pass, compacted real-lane mid-section,
/// encode post-pass.
fn block<const N: u32, const L: usize>(
    kind: Kind,
    a: &[u64],
    b: &[u64],
    c: &[u64],
    out: &mut [u64],
) {
    let msk = mask(N);
    let lane = |l: &[u64], i: usize| if l.is_empty() { 0 } else { l[i] & msk };

    // --- pass 1: SWAR special pre-pass over packed words ---------------
    let mut real_idx = [0u8; BLOCK]; // compacted real-lane positions
    let r = special_prepass::<N, L>(kind, a, b, c, out, &mut real_idx);

    if r == 0 {
        return;
    }

    // --- pass 2 + 3: SoA mid-section and encode post-pass --------------
    match kind {
        Kind::Div => {
            // decode into SoA buffers
            let mut sign = [false; BLOCK];
            let mut scale = [0i32; BLOCK];
            let mut num = [0u64; BLOCK];
            let mut den = [0u64; BLOCK];
            for t in 0..r {
                let i = real_idx[t] as usize;
                let da = Posit::from_bits(N, lane(a, i)).decode();
                let db = Posit::from_bits(N, lane(b, i)).decode();
                sign[t] = da.sign ^ db.sign;
                scale[t] = da.scale - db.scale;
                num[t] = da.sig << N;
                den[t] = db.sig;
            }
            // fraction divide: native u64 division (the generic kernel's
            // u128 form is a libcall), same integer math, same quotient
            // normal form
            let mut q = [0u64; BLOCK];
            let mut sticky = [false; BLOCK];
            for t in 0..r {
                q[t] = num[t] / den[t];
                sticky[t] = num[t] % den[t] != 0;
            }
            for t in 0..r {
                // normalize q ∈ (1/2, 2) to [1, 2)
                let (sc, sfb) = if q[t] >> N != 0 { (scale[t], N) } else { (scale[t] - 1, N - 1) };
                out[real_idx[t] as usize] =
                    encode_round(N, sign[t], sc, q[t] as u128, sfb, sticky[t]).to_bits();
            }
        }
        Kind::Sqrt => {
            let f = frac_bits(N);
            let p = f + 2;
            let mut scale = [0i32; BLOCK];
            let mut rad = [0u64; BLOCK];
            for t in 0..r {
                let i = real_idx[t] as usize;
                let d = Posit::from_bits(N, lane(a, i)).decode();
                scale[t] = d.scale >> 1; // ⌊T/2⌋ (arithmetic shift)
                let odd = (d.scale & 1) as u32;
                rad[t] = d.sig << (2 * p + odd - f);
            }
            let mut s = [0u64; BLOCK];
            let mut sticky = [false; BLOCK];
            for t in 0..r {
                s[t] = isqrt_u128(rad[t] as u128) as u64;
                sticky[t] = s[t] * s[t] != rad[t];
            }
            for t in 0..r {
                out[real_idx[t] as usize] =
                    encode_round(N, false, scale[t], s[t] as u128, p, sticky[t]).to_bits();
            }
        }
        Kind::Mul => {
            let fb = frac_bits(N);
            let mut sign = [false; BLOCK];
            let mut scale = [0i32; BLOCK];
            let mut prod = [0u64; BLOCK];
            for t in 0..r {
                let i = real_idx[t] as usize;
                let da = Posit::from_bits(N, lane(a, i)).decode();
                let db = Posit::from_bits(N, lane(b, i)).decode();
                sign[t] = da.sign ^ db.sign;
                scale[t] = da.scale + db.scale;
                prod[t] = da.sig * db.sig; // ≤ 2^(2(N-3)): fits u64 at n ≤ 16
            }
            for t in 0..r {
                // value = prod / 2^(2fb) ∈ [1, 4): renormalize like Posit::mul
                let (sc, sfb) = if prod[t] >> (2 * fb + 1) != 0 {
                    (scale[t] + 1, 2 * fb + 1)
                } else {
                    (scale[t], 2 * fb)
                };
                out[real_idx[t] as usize] =
                    encode_round(N, sign[t], sc, prod[t] as u128, sfb, false).to_bits();
            }
        }
        // The remaining ops keep the posit library routine per real lane
        // behind the packed special pre-pass: their alignment/cancellation
        // datapath is already a single pass, and reusing it keeps the
        // bit-identity argument trivial.
        Kind::Add => {
            for &t in &real_idx[..r] {
                let i = t as usize;
                out[i] =
                    Posit::from_bits(N, lane(a, i)).add(Posit::from_bits(N, lane(b, i))).to_bits();
            }
        }
        Kind::Sub => {
            for &t in &real_idx[..r] {
                let i = t as usize;
                out[i] =
                    Posit::from_bits(N, lane(a, i)).sub(Posit::from_bits(N, lane(b, i))).to_bits();
            }
        }
        Kind::MulAdd => {
            for &t in &real_idx[..r] {
                let i = t as usize;
                out[i] = Posit::from_bits(N, lane(a, i))
                    .mul_add(Posit::from_bits(N, lane(b, i)), Posit::from_bits(N, lane(c, i)))
                    .to_bits();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::fastpath::FastKernel;
    use crate::testkit::Rng;

    const KINDS: [Kind; 6] =
        [Kind::Div, Kind::Sqrt, Kind::Mul, Kind::Add, Kind::Sub, Kind::MulAdd];

    fn rand_u128(rng: &mut Rng) -> u128 {
        ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128
    }

    #[test]
    fn splat_fills_every_lane() {
        assert_eq!(splat::<8, 16>(0x01), 0x0101_0101_0101_0101_0101_0101_0101_0101);
        assert_eq!(splat::<8, 16>(0x80), 0x8080_8080_8080_8080_8080_8080_8080_8080);
        assert_eq!(splat::<16, 8>(1), 0x0001_0001_0001_0001_0001_0001_0001_0001);
        assert_eq!(splat::<16, 8>(0x8000), 0x8000_8000_8000_8000_8000_8000_8000_8000);
    }

    /// The carry-contained zero-lane detector must be exact — including
    /// the pattern the naive borrow trick gets wrong (a lane of value 1
    /// above a zero lane).
    #[test]
    fn swar_zero_detection_is_exact() {
        let low = splat::<8, 16>(mask(7));
        let msb = splat::<8, 16>(0x80);
        let zero_msb = |w: u128| !(((w & low) + low) | w | low) & msb;
        let mut rng = Rng::seeded(0x5A);
        for _ in 0..100_000 {
            let w = rand_u128(&mut rng);
            let got = zero_msb(w);
            for j in 0..16 {
                let lane = (w >> (8 * j)) & 0xFF;
                let flag = (got >> (8 * j + 7)) & 1;
                assert_eq!(flag == 1, lane == 0, "w={w:#034x} lane {j}");
            }
        }
        // the classic false-positive shape: [0x00, 0x01] low-to-high
        let w = 0x0100u128;
        let got = zero_msb(w);
        assert_eq!(got, 0x80, "only the zero lane may flag, {got:#x}");
    }

    #[test]
    fn swar_lane_negation_matches_scalar() {
        let mut rng = Rng::seeded(0x9E6);
        let msb = splat::<8, 16>(0x80);
        let one = splat::<8, 16>(1);
        let lane_neg = |w: u128| {
            let x = !w;
            ((x & !msb).wrapping_add(one)) ^ ((x ^ one) & msb)
        };
        for _ in 0..100_000 {
            let w = rand_u128(&mut rng);
            let got = lane_neg(w);
            for j in 0..16 {
                let lane = (w >> (8 * j)) & 0xFF;
                let want = lane.wrapping_neg() & 0xFF;
                assert_eq!((got >> (8 * j)) & 0xFF, want, "w={w:#034x} lane {j}");
            }
        }
    }

    /// Every lane the pre-pass claims special must resolve exactly as the
    /// scalar special table does — exhaustive at Posit8 per packed word.
    #[test]
    fn special_word_matches_scalar_table_p8() {
        let mut rng = Rng::seeded(0x57EC);
        for kind in KINDS {
            let k = FastKernel::new(8, kind);
            for _ in 0..20_000 {
                // bias toward specials so every branch is exercised
                let pack_word = |rng: &mut Rng| -> u128 {
                    let mut w = 0u128;
                    for j in 0..16 {
                        let v = match rng.range_inclusive(0, 5) {
                            0 => 0,
                            1 => 0x80,
                            _ => rng.next_u64() & 0xFF,
                        };
                        w |= (v as u128) << (8 * j);
                    }
                    w
                };
                let (wa, wb, wc) = (pack_word(&mut rng), pack_word(&mut rng), pack_word(&mut rng));
                let sp = special_word::<8, 16>(kind, wa, wb, wc);
                for j in 0..16 {
                    let sh = 8 * j;
                    let (a, b, c) = (
                        (wa >> sh) as u64 & 0xFF,
                        (wb >> sh) as u64 & 0xFF,
                        (wc >> sh) as u64 & 0xFF,
                    );
                    let scalar = k.classify(a, b, c);
                    let lane_mask = (sp.mask >> sh) as u64 & 0xFF;
                    assert!(
                        lane_mask == 0 || lane_mask == 0xFF,
                        "{kind:?} lane {j}: partial mask {lane_mask:#x}"
                    );
                    match scalar {
                        Some(want) => {
                            assert_eq!(lane_mask, 0xFF, "{kind:?} lane {j} must be special");
                            assert_eq!((sp.bits >> sh) as u64 & 0xFF, want, "{kind:?} lane {j}");
                        }
                        None => assert_eq!(lane_mask, 0, "{kind:?} lane {j} must be real"),
                    }
                }
            }
        }
    }

    /// The full SWAR batch vs the scalar kernel: random lanes with
    /// specials sprinkled in, at lengths that cover dense words, partial
    /// blocks and ragged tails.
    #[test]
    fn swar_batch_matches_scalar_kernel() {
        let mut rng = Rng::seeded(0x51AD);
        for n in [8u32, 16] {
            for kind in KINDS {
                for len in [1usize, 3, 4, 7, 8, 15, 16, 17, 63, 64, 65, 257] {
                    let make_lane = |rng: &mut Rng, sprinkle: bool| -> Vec<u64> {
                        (0..len)
                            .map(|i| {
                                if sprinkle && i % 5 == 0 {
                                    [0u64, 1 << (n - 1)][i / 5 % 2]
                                } else {
                                    rng.next_u64() & mask(n)
                                }
                            })
                            .collect()
                    };
                    for sprinkle in [false, true] {
                        let a = make_lane(&mut rng, sprinkle);
                        let b = make_lane(&mut rng, sprinkle);
                        let c = make_lane(&mut rng, false);
                        let mut out = vec![0u64; len];
                        run_batch(n, kind, &a, &b, &c, &mut out);
                        for i in 0..len {
                            assert_eq!(
                                out[i],
                                scalar_bits(n, kind, a[i], b[i], c[i]),
                                "{kind:?} n={n} len={len} i={i} sprinkle={sprinkle}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Exhaustive Posit8 pattern pairs through the SWAR kernels (the
    /// batch analogue of the scalar kernels' exhaustive gate).
    #[test]
    fn swar_exhaustive_p8_binary_ops() {
        for kind in [Kind::Div, Kind::Mul, Kind::Add, Kind::Sub] {
            let b: Vec<u64> = (0..=mask(8)).collect();
            let mut out = vec![0u64; b.len()];
            for a in 0..=mask(8) {
                let av = vec![a; b.len()];
                run_batch(8, kind, &av, &b, &[], &mut out);
                for (i, &got) in out.iter().enumerate() {
                    assert_eq!(
                        got,
                        scalar_bits(8, kind, a, b[i], 0),
                        "{kind:?} {a:#04x} {:#04x}",
                        b[i]
                    );
                }
            }
        }
        // sqrt: all 256 patterns in one batch
        let a: Vec<u64> = (0..=mask(8)).collect();
        let mut out = vec![0u64; a.len()];
        run_batch(8, Kind::Sqrt, &a, &[], &[], &mut out);
        for (i, &got) in out.iter().enumerate() {
            assert_eq!(got, scalar_bits(8, Kind::Sqrt, a[i], 0, 0), "sqrt {:#04x}", a[i]);
        }
    }

    #[test]
    fn empty_and_padded_unused_lanes() {
        let mut rng = Rng::seeded(0x17AD);
        let n = 16;
        let a: Vec<u64> = (0..90).map(|_| rng.next_u64() & mask(n)).collect();
        let pad = vec![0u64; a.len()];
        let mut with_empty = vec![0u64; a.len()];
        let mut with_pad = vec![0u64; a.len()];
        run_batch(n, Kind::Sqrt, &a, &[], &[], &mut with_empty);
        run_batch(n, Kind::Sqrt, &a, &pad, &pad, &mut with_pad);
        assert_eq!(with_empty, with_pad);
    }

    #[test]
    fn high_garbage_bits_are_masked() {
        let one = Posit::one(16).to_bits();
        let garbage = 0xDEAD_0000_0000_0000u64;
        let a = vec![one | garbage; 20];
        let b = vec![one | garbage; 20];
        let mut out = vec![0u64; 20];
        run_batch(16, Kind::Div, &a, &b, &[], &mut out);
        assert!(out.iter().all(|&q| q == one), "{out:?}");
    }
}
