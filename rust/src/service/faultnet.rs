//! Deterministic in-process network fault injection: a TCP chaos proxy
//! between a [`super::ServiceClient`] and a [`super::net::Server`].
//!
//! [`FaultNet`] listens on a loopback port, relays every connection to
//! the real server, and — on the client→server direction only, where it
//! can see frame boundaries — injects faults decided by a pure,
//! seed-keyed function of `(connection, frame)` ([`FaultPlan::decide`]).
//! Equal seeds produce byte-identical fault schedules on every run and
//! every machine: chaos tests assert exact convergence properties
//! instead of flaky probabilities.
//!
//! The injectable faults, per client frame:
//!
//! | fault | what the server sees | what the client sees |
//! |-------|----------------------|----------------------|
//! | [`Fault::Delay`] | the frame, late | a slow reply (deadline pressure) |
//! | [`Fault::Duplicate`] | the frame twice (two replies!) | a duplicate reply to discard |
//! | [`Fault::BlackHole`] | nothing (conn stays up) | a read timeout |
//! | [`Fault::Truncate`] | header + half the payload, then close | a dead connection |
//! | [`Fault::DropConn`] | the connection close, frame never sent | a dead connection |
//!
//! The first [`FaultPlan::warmup_frames`] frames of every connection
//! pass clean so the HELLO/WELCOME handshake always completes — the
//! faults under test are request-path faults, not connect storms (the
//! breaker tests cover those separately by pointing at dead ports).
//!
//! Server→client bytes are relayed verbatim: response-side corruption
//! would only re-test the same client decode paths the wire tests
//! already cover, while request-side faults exercise the full
//! retry/dedup/breaker machinery.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use super::wire;
use crate::error::{PositError, Result};
use crate::testkit::Rng;

/// How long a proxy-side read blocks before re-checking the stop flag.
const POLL: Duration = Duration::from_millis(50);

/// What to do with one client→server frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Fault {
    /// Relay unchanged.
    Forward,
    /// Sleep [`FaultPlan::delay_ms`], then relay.
    Delay,
    /// Relay the frame twice (the server will answer twice).
    Duplicate,
    /// Swallow the frame; the connection stays up.
    BlackHole,
    /// Relay the header plus half the payload, then close both sides.
    Truncate,
    /// Close both sides without relaying the frame.
    DropConn,
}

/// A seeded fault schedule. Rates are per-mille (‰) of non-warmup
/// frames; the remainder forwards clean. The decision for a given
/// `(seed, connection, frame)` is pure — see [`FaultPlan::decide`].
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Seed keying the whole schedule.
    pub seed: u64,
    /// ‰ of frames delayed by [`FaultPlan::delay_ms`].
    pub delay_per_mille: u32,
    /// ‰ of frames relayed twice.
    pub duplicate_per_mille: u32,
    /// ‰ of frames swallowed (connection kept).
    pub black_hole_per_mille: u32,
    /// ‰ of frames truncated mid-payload (connection closed).
    pub truncate_per_mille: u32,
    /// ‰ of frames replaced by a connection close.
    pub drop_conn_per_mille: u32,
    /// Delay applied by [`Fault::Delay`].
    pub delay_ms: u64,
    /// Leading frames per connection that always forward clean (keep
    /// >= 1 so the HELLO handshake survives).
    pub warmup_frames: u32,
}

impl FaultPlan {
    /// A transparent plan: every frame forwards clean.
    pub fn clean(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            delay_per_mille: 0,
            duplicate_per_mille: 0,
            black_hole_per_mille: 0,
            truncate_per_mille: 0,
            drop_conn_per_mille: 0,
            delay_ms: 0,
            warmup_frames: 1,
        }
    }

    /// The standard chaos mix the soak tests run: ~12% of frames
    /// faulted, every fault kind represented, delays short enough to
    /// keep the test fast but long enough to cross deadline budgets.
    pub fn chaos(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            delay_per_mille: 30,
            duplicate_per_mille: 30,
            black_hole_per_mille: 20,
            truncate_per_mille: 20,
            drop_conn_per_mille: 20,
            delay_ms: 20,
            warmup_frames: 1,
        }
    }

    fn budget(&self) -> u64 {
        u64::from(
            self.delay_per_mille
                + self.duplicate_per_mille
                + self.black_hole_per_mille
                + self.truncate_per_mille
                + self.drop_conn_per_mille,
        )
    }

    /// The fault for frame `frame` of connection `conn` — a pure
    /// function of `(seed, conn, frame)`, so a schedule can be replayed
    /// (or predicted in a test) without running the proxy.
    pub fn decide(&self, conn: u64, frame: u64) -> Fault {
        if frame < u64::from(self.warmup_frames) {
            return Fault::Forward;
        }
        let key = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(conn.wrapping_mul(0xD6E8_FEB8_6659_FD93))
            .wrapping_add(frame);
        let mut rng = Rng::seeded(key);
        let draw = rng.below(1000);
        let mut edge = u64::from(self.delay_per_mille);
        if draw < edge {
            return Fault::Delay;
        }
        edge += u64::from(self.duplicate_per_mille);
        if draw < edge {
            return Fault::Duplicate;
        }
        edge += u64::from(self.black_hole_per_mille);
        if draw < edge {
            return Fault::BlackHole;
        }
        edge += u64::from(self.truncate_per_mille);
        if draw < edge {
            return Fault::Truncate;
        }
        edge += u64::from(self.drop_conn_per_mille);
        if draw < edge {
            return Fault::DropConn;
        }
        Fault::Forward
    }
}

/// Counts of faults actually injected (after warmup exclusion), for
/// asserting a chaos run really exercised every kind.
#[derive(Default, Debug)]
pub struct FaultCounters {
    pub delayed: AtomicU64,
    pub duplicated: AtomicU64,
    pub black_holed: AtomicU64,
    pub truncated: AtomicU64,
    pub dropped_conns: AtomicU64,
    pub forwarded: AtomicU64,
}

impl FaultCounters {
    /// Total faulted (non-forward) frames.
    pub fn faulted(&self) -> u64 {
        self.delayed.load(Ordering::Relaxed)
            + self.duplicated.load(Ordering::Relaxed)
            + self.black_holed.load(Ordering::Relaxed)
            + self.truncated.load(Ordering::Relaxed)
            + self.dropped_conns.load(Ordering::Relaxed)
    }
}

/// The running chaos proxy. Connect clients to
/// [`FaultNet::local_addr`]; stop it with [`FaultNet::stop`] (also runs
/// on drop).
pub struct FaultNet {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    counters: Arc<FaultCounters>,
}

impl FaultNet {
    /// Listen on an OS-assigned loopback port, relaying every connection
    /// to `upstream` under `plan`.
    pub fn start(upstream: SocketAddr, plan: FaultPlan) -> Result<FaultNet> {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| PositError::Execution { detail: format!("faultnet bind: {e}") })?;
        let addr = listener
            .local_addr()
            .map_err(|e| PositError::Execution { detail: format!("faultnet local_addr: {e}") })?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let counters = Arc::new(FaultCounters::default());
        let accept = {
            let (stop, conns, counters) = (stop.clone(), conns.clone(), counters.clone());
            thread::Builder::new()
                .name("faultnet-accept".into())
                .spawn(move || {
                    let mut conn_id = 0u64;
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let client = match incoming {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        let id = conn_id;
                        conn_id += 1;
                        let (stop, counters) = (stop.clone(), counters.clone());
                        let handle = thread::Builder::new()
                            .name("faultnet-conn".into())
                            .spawn(move || relay_conn(client, upstream, plan, id, stop, counters))
                            .expect("spawn faultnet connection thread");
                        conns.lock().expect("faultnet conn registry").push(handle);
                    }
                })
                .map_err(|e| PositError::Execution {
                    detail: format!("spawn faultnet accept thread: {e}"),
                })?
        };
        Ok(FaultNet { addr, stop, accept: Some(accept), conns, counters })
    }

    /// The proxy's listen address — point clients here instead of at the
    /// real server.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Injected-fault counters (live; the proxy keeps counting until
    /// stopped).
    pub fn counters(&self) -> &FaultCounters {
        &self.counters
    }

    /// Stop accepting and tear down every relay.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = {
            let mut guard = self.conns.lock().expect("faultnet conn registry");
            std::mem::take(&mut *guard)
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for FaultNet {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Read one raw frame (header + payload bytes, unparsed beyond the
/// length) from a timeout-polling stream. `None` ends the relay: EOF,
/// a malformed header, an I/O error, or the stop flag.
fn read_raw_frame(stream: &mut TcpStream, stop: &AtomicBool) -> Option<(Vec<u8>, Vec<u8>)> {
    let mut header = vec![0u8; wire::HEADER_LEN];
    read_raw_full(stream, &mut header, stop)?;
    let hdr: &[u8; wire::HEADER_LEN] = header.as_slice().try_into().expect("fixed length");
    let (_, len) = wire::parse_header(hdr).ok()?;
    let mut payload = vec![0u8; len];
    read_raw_full(stream, &mut payload, stop)?;
    Some((header, payload))
}

fn read_raw_full(stream: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> Option<()> {
    let mut pos = 0;
    while pos < buf.len() {
        match stream.read(&mut buf[pos..]) {
            Ok(0) => return None,
            Ok(k) => pos += k,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Acquire) {
                    return None;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return None,
        }
    }
    Some(())
}

fn relay_conn(
    mut client: TcpStream,
    upstream: SocketAddr,
    plan: FaultPlan,
    conn_id: u64,
    stop: Arc<AtomicBool>,
    counters: Arc<FaultCounters>,
) {
    let Ok(mut server) = TcpStream::connect(upstream) else {
        let _ = client.shutdown(Shutdown::Both);
        return;
    };
    let _ = client.set_nodelay(true);
    let _ = server.set_nodelay(true);
    let _ = client.set_read_timeout(Some(POLL));

    // server→client: a dumb byte pipe (responses relay verbatim)
    let pipe = {
        let (mut server_r, mut client_w) = match (server.try_clone(), client.try_clone()) {
            (Ok(s), Ok(c)) => (s, c),
            _ => {
                let _ = client.shutdown(Shutdown::Both);
                let _ = server.shutdown(Shutdown::Both);
                return;
            }
        };
        let stop = stop.clone();
        thread::Builder::new()
            .name("faultnet-pipe".into())
            .spawn(move || {
                let _ = server_r.set_read_timeout(Some(POLL));
                let mut buf = [0u8; 8192];
                loop {
                    match server_r.read(&mut buf) {
                        Ok(0) => break,
                        Ok(k) => {
                            if client_w.write_all(&buf[..k]).is_err() {
                                break;
                            }
                        }
                        Err(e)
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                            ) =>
                        {
                            if stop.load(Ordering::Acquire) {
                                break;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                        Err(_) => break,
                    }
                }
                let _ = client_w.shutdown(Shutdown::Both);
            })
            .expect("spawn faultnet pipe thread")
    };

    // client→server: frame-aware, faults injected per the plan
    let mut frame_idx = 0u64;
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        let Some((header, payload)) = read_raw_frame(&mut client, &stop) else {
            break;
        };
        let fault = plan.decide(conn_id, frame_idx);
        frame_idx += 1;
        let forward =
            |server: &mut TcpStream, header: &[u8], payload: &[u8]| -> std::io::Result<()> {
                server.write_all(header)?;
                server.write_all(payload)
            };
        let ok = match fault {
            Fault::Forward => {
                counters.forwarded.fetch_add(1, Ordering::Relaxed);
                forward(&mut server, &header, &payload).is_ok()
            }
            Fault::Delay => {
                counters.delayed.fetch_add(1, Ordering::Relaxed);
                thread::sleep(Duration::from_millis(plan.delay_ms));
                forward(&mut server, &header, &payload).is_ok()
            }
            Fault::Duplicate => {
                counters.duplicated.fetch_add(1, Ordering::Relaxed);
                forward(&mut server, &header, &payload).is_ok()
                    && forward(&mut server, &header, &payload).is_ok()
            }
            Fault::BlackHole => {
                counters.black_holed.fetch_add(1, Ordering::Relaxed);
                true
            }
            Fault::Truncate => {
                counters.truncated.fetch_add(1, Ordering::Relaxed);
                let _ = server
                    .write_all(&header)
                    .and_then(|()| server.write_all(&payload[..payload.len() / 2]));
                false
            }
            Fault::DropConn => {
                counters.dropped_conns.fetch_add(1, Ordering::Relaxed);
                false
            }
        };
        if !ok {
            break;
        }
    }
    let _ = client.shutdown(Shutdown::Both);
    let _ = server.shutdown(Shutdown::Both);
    let _ = pipe.join();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The schedule is a pure function: same (seed, conn, frame) ⇒ same
    /// fault, different seeds ⇒ different schedules, warmup always
    /// forwards.
    #[test]
    fn plans_are_deterministic_and_seed_keyed() {
        let plan = FaultPlan::chaos(42);
        for conn in 0..4u64 {
            assert_eq!(plan.decide(conn, 0), Fault::Forward, "warmup frame must pass");
            for frame in 0..64u64 {
                assert_eq!(plan.decide(conn, frame), plan.decide(conn, frame));
            }
        }
        let other = FaultPlan::chaos(43);
        let differs = (0..256u64).any(|f| plan.decide(0, f) != other.decide(0, f));
        assert!(differs, "seed must key the schedule");
        // rates roughly honor the per-mille budget over a long horizon
        let faulted = (1..4001u64)
            .filter(|&f| plan.decide(7, f) != Fault::Forward)
            .count();
        let expect = (plan.budget() as usize * 4000) / 1000;
        assert!(
            faulted > expect / 2 && faulted < expect * 2,
            "faulted {faulted} of 4000, budget {expect}"
        );
        // a clean plan never faults
        let clean = FaultPlan::clean(42);
        assert!((0..4000u64).all(|f| clean.decide(0, f) == Fault::Forward));
    }

    /// Every fault kind must actually occur under the chaos preset —
    /// otherwise the soak test exercises less than it claims.
    #[test]
    fn chaos_preset_reaches_every_fault_kind() {
        let plan = FaultPlan::chaos(7);
        let mut seen = std::collections::HashSet::new();
        for conn in 0..8u64 {
            for frame in 1..512u64 {
                seen.insert(plan.decide(conn, frame));
            }
        }
        for fault in [
            Fault::Forward,
            Fault::Delay,
            Fault::Duplicate,
            Fault::BlackHole,
            Fault::Truncate,
            Fault::DropConn,
        ] {
            assert!(seen.contains(&fault), "{fault:?} never scheduled");
        }
    }
}
