//! Property-based integration tests over the full division pipeline,
//! including the strongest check in the suite: round-to-nearest
//! correctness verified by exact rational comparison against
//! pattern-space midpoints (independent of the encode path).

use posit_div::division::{golden, Algorithm, Divider};
use posit_div::posit::Posit;
use posit_div::testkit::{self, gen, Config};

#[test]
fn golden_is_correctly_rounded_p16_random() {
    // verify_nearest does an exact rational nearest-posit check.
    testkit::forall(
        Config::cases(20_000).with_seed(0x4EA1),
        |rng| gen::division_operands(rng, 16),
        gen::shrink_pair,
        |&(x, d)| {
            if x.is_zero() {
                return Ok(());
            }
            let q = golden::divide(x, d).result;
            golden::verify_nearest(x, d, q);
            Ok(())
        },
    );
}

#[test]
fn division_identities() {
    // one pre-built context per width, like a real caller would hold
    let ctxs: Vec<Divider> = [8u32, 16, 32]
        .iter()
        .map(|&n| Divider::new(n, Algorithm::DEFAULT).expect("valid width"))
        .collect();
    testkit::forall(
        Config::cases(20_000),
        |rng| {
            let i = *rng.choose(&[0usize, 1, 2]);
            gen::division_operands(rng, [8u32, 16, 32][i])
        },
        gen::shrink_pair,
        |&(x, d)| {
            let n = x.width();
            let ctx = ctxs.iter().find(|c| c.width() == n).expect("width covered");
            let div = |a: Posit, b: Posit| ctx.divide(a, b).expect("width matches").result;
            // x / 1 = x
            if div(x, Posit::one(n)) != x {
                return Err("x/1 != x".into());
            }
            // x / x = 1 for nonzero x
            if !x.is_zero() && div(x, x) != Posit::one(n) {
                return Err("x/x != 1".into());
            }
            // (-x)/d = -(x/d) — negation is exact in posits
            let q = div(x, d);
            if div(x.neg(), d) != q.neg() {
                return Err("(-x)/d != -(x/d)".into());
            }
            if div(x, d.neg()) != q.neg() {
                return Err("x/(-d) != -(x/d)".into());
            }
            Ok(())
        },
    );
}

#[test]
fn division_by_powers_of_two_is_exact_shift() {
    // x / 2^k only changes the scale: exact unless it saturates.
    let ctx = Divider::new(16, Algorithm::Srt2Cs).expect("valid width");
    testkit::forall(
        Config::cases(5_000),
        |rng| {
            let x = gen::nonzero_posit(rng, 16);
            let k = rng.range_i64(-8, 8);
            (x, k)
        },
        |_| Vec::new(),
        |&(x, k)| {
            let n = 16;
            let d = Posit::from_f64(n, (k as f64).exp2());
            let q = ctx.divide(x, d).expect("width matches").result;
            let want = golden::divide(x, d).result;
            if q != want {
                return Err(format!("mismatch for 2^{k}"));
            }
            // and the value matches the f64 shift when in range
            let expect = x.to_f64() / (k as f64).exp2();
            let via = Posit::from_f64(n, expect);
            if via != q {
                return Err(format!("2^{k} shift not exact: {} vs {}", q, via));
            }
            Ok(())
        },
    );
}

#[test]
fn nar_and_zero_propagation_all_engines() {
    for alg in Algorithm::ALL {
        for n in [8u32, 16, 32] {
            let ctx = Divider::new(n, alg).expect("valid width");
            let div = |a: Posit, b: Posit| ctx.divide(a, b).expect("width matches").result;
            let one = Posit::one(n);
            assert!(div(one, Posit::zero(n)).is_nar(), "{alg:?}");
            assert!(div(Posit::nar(n), one).is_nar(), "{alg:?}");
            assert!(div(one, Posit::nar(n)).is_nar(), "{alg:?}");
            assert!(div(Posit::zero(n), one).is_zero(), "{alg:?}");
            assert!(div(Posit::zero(n), Posit::zero(n)).is_nar(), "{alg:?}");
        }
    }
}

#[test]
fn quotient_monotonicity_in_dividend() {
    // for fixed positive divisor, x1 <= x2 => x1/d <= x2/d (posit order)
    let ctx = Divider::new(16, Algorithm::DEFAULT).expect("valid width");
    testkit::forall_ns(Config::cases(10_000), |rng| {
        let d = gen::nonzero_posit(rng, 16).abs();
        let a = gen::real_posit(rng, 16);
        let b = gen::real_posit(rng, 16);
        (a, b, d)
    }, |&(a, b, d)| {
        let (lo, hi) = if a.total_cmp(b).is_le() { (a, b) } else { (b, a) };
        let qlo = ctx.divide(lo, d).expect("width matches").result;
        let qhi = ctx.divide(hi, d).expect("width matches").result;
        if qlo.total_cmp(qhi).is_gt() {
            return Err(format!("monotonicity violated: {lo:?}/{d:?} > {hi:?}/{d:?}"));
        }
        Ok(())
    });
}

#[test]
fn multiplication_division_roundtrip_within_ulp() {
    // (x/d)*d is within 1 ulp of x when no saturation occurred (two
    // roundings) — a sanity link between the arithmetic and division.
    let ctx = Divider::new(32, Algorithm::DEFAULT).expect("valid width");
    testkit::forall_ns(Config::cases(10_000), |rng| {
        let x = gen::nonzero_posit(rng, 32);
        let d = gen::nonzero_posit(rng, 32);
        (x, d)
    }, |&(x, d)| {
        let n = 32;
        let q = ctx.divide(x, d).expect("width matches").result;
        if q == Posit::maxpos(n) || q == Posit::maxpos(n).neg()
            || q == Posit::minpos(n) || q == Posit::minpos(n).neg()
        {
            return Ok(()); // saturated
        }
        // restrict to the band where q keeps most fraction bits: outside
        // it, the quotient's long regime makes the round-trip legitimately
        // coarse in x's (denser) ulp scale.
        let qv = q.to_f64().abs();
        if !(2.0f64.powi(-16)..2.0f64.powi(16)).contains(&qv) {
            return Ok(());
        }
        let back = q.mul(d);
        let dist = back.ulp_distance(x);
        // two nearest-roundings: within a couple of ulp except at regime
        // boundaries where ulp sizes jump
        if dist > 8 {
            return Err(format!("(x/d)*d drifted {dist} ulp: {x:?} {d:?}"));
        }
        Ok(())
    });
}
