//! Dynamic batcher: collects op-tagged requests into batches bounded by
//! size and age — the standard serving-system policy (first request in a
//! batch waits at most `max_wait`; a full batch flushes immediately).
//! Mixed-op batches are then split per operation with [`group_indices`]
//! so each group runs through its own execution unit.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 256, max_wait: Duration::from_micros(200) }
    }
}

/// Drain `rx` into a batch according to `policy`. Blocks for the first
/// item (or returns None when the channel is closed), then fills until
/// the batch is full or the deadline passes.
pub fn collect_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut batch = Vec::with_capacity(policy.max_batch);
    batch.push(first);
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Split a batch into per-key index groups, preserving first-seen key
/// order and, within each group, submission order. Linear scan over the
/// (small) set of distinct keys — a mixed batch has at most a handful of
/// operations.
pub fn group_indices<T, K, F>(items: &[T], key: F) -> Vec<(K, Vec<usize>)>
where
    K: PartialEq + Copy,
    F: Fn(&T) -> K,
{
    let mut groups: Vec<(K, Vec<usize>)> = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let k = key(item);
        match groups.iter_mut().find(|(g, _)| *g == k) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((k, vec![i])),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn full_batch_flushes_without_waiting() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let t0 = Instant::now();
        let b = collect_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(1), "must not wait when full");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(20) };
        let t0 = Instant::now();
        let b = collect_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![42]);
        let e = t0.elapsed();
        assert!(e >= Duration::from_millis(15), "waited for the deadline: {e:?}");
        drop(tx);
    }

    #[test]
    fn group_indices_preserves_orders() {
        let items = ["a", "b", "a", "c", "b", "a"];
        let groups = group_indices(&items, |s| *s);
        assert_eq!(
            groups,
            vec![("a", vec![0, 2, 5]), ("b", vec![1, 4]), ("c", vec![3])]
        );
        let empty: [&str; 0] = [];
        assert!(group_indices(&empty, |s| *s).is_empty());
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(collect_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn drains_remaining_after_close() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        let b = collect_batch(&rx, BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(5) })
            .unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(collect_batch(&rx, BatchPolicy::default()).is_none());
    }
}
