//! `f64` ↔ posit conversion.
//!
//! `from_f64` is correctly rounded (the f64 is exact input; the posit
//! rounding happens once, in pattern space). `to_f64` is exact for n ≤ 32
//! (≤ 27 fraction bits always fit f64's 52); for n up to 64 it incurs one
//! f64 rounding — fine for display, while exact checks in the test-suite go
//! through integer/rational paths instead.

use super::{frac_bits, round::encode_round, Posit, Unpacked};

impl Posit {
    /// Convert an `f64` to the nearest Posit⟨n,2⟩.
    ///
    /// NaN and ±∞ map to NaR; ±0.0 maps to zero (posits have a single zero).
    pub fn from_f64(n: u32, v: f64) -> Posit {
        if v == 0.0 {
            return Posit::zero(n);
        }
        if !v.is_finite() {
            return Posit::nar(n);
        }
        let bits = v.to_bits();
        let sign = bits >> 63 == 1;
        let biased = ((bits >> 52) & 0x7ff) as i32;
        let mant = bits & ((1u64 << 52) - 1);
        let (scale, sig, sfb) = if biased != 0 {
            // Normal: 1.mant * 2^(biased-1023)
            (biased - 1023, (1u64 << 52) | mant, 52u32)
        } else {
            // Subnormal: mant * 2^-1074, normalize to hidden-1 form.
            let hb = 63 - mant.leading_zeros(); // position of top set bit
            (hb as i32 - 1074, mant, hb)
        };
        encode_round(n, sign, scale, sig as u128, sfb, false)
    }

    /// Convert to `f64`. NaR maps to NaN.
    pub fn to_f64(self) -> f64 {
        match self.unpack() {
            Unpacked::Zero => 0.0,
            Unpacked::NaR => f64::NAN,
            Unpacked::Real(d) => {
                let fb = frac_bits(self.n);
                let mag = d.sig as f64 * ((d.scale - fb as i32) as f64).exp2();
                if d.sign {
                    -mag
                } else {
                    mag
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::mask;

    #[test]
    fn roundtrip_exhaustive_p8_p10_p12() {
        // f64 holds every posit≤32 exactly, so to_f64 -> from_f64 must be
        // the identity on every real pattern.
        for n in [8u32, 10, 12, 16] {
            for bits in 0..=mask(n) {
                let p = Posit::from_bits(n, bits);
                if p.is_nar() {
                    continue;
                }
                let back = Posit::from_f64(n, p.to_f64());
                assert_eq!(back, p, "n={n} bits={bits:#x} v={}", p.to_f64());
            }
        }
    }

    #[test]
    fn roundtrip_random_p32() {
        let mut rng = crate::testkit::Rng::seeded(0xC0417);
        for _ in 0..100_000 {
            let bits = rng.next_u64() & mask(32);
            let p = Posit::from_bits(32, bits);
            if p.is_nar() {
                continue;
            }
            assert_eq!(Posit::from_f64(32, p.to_f64()), p);
        }
    }

    #[test]
    fn specials() {
        assert!(Posit::from_f64(16, f64::NAN).is_nar());
        assert!(Posit::from_f64(16, f64::INFINITY).is_nar());
        assert!(Posit::from_f64(16, f64::NEG_INFINITY).is_nar());
        assert!(Posit::from_f64(16, 0.0).is_zero());
        assert!(Posit::from_f64(16, -0.0).is_zero());
        assert!(Posit::nar(16).to_f64().is_nan());
    }

    #[test]
    fn known_values() {
        assert_eq!(Posit::from_f64(32, 1.0), Posit::one(32));
        assert_eq!(Posit::from_f64(8, 1.0).to_bits(), 0b0100_0000);
        assert_eq!(Posit::from_f64(8, -1.0).to_bits(), 0b1100_0000);
        assert_eq!(Posit::from_f64(8, 0.5).to_bits(), 0b0011_1000);
        assert_eq!(Posit::from_f64(16, 1.0e30), Posit::maxpos(16)); // saturate
        assert_eq!(Posit::from_f64(16, 1.0e-30), Posit::minpos(16));
        assert_eq!(Posit::from_f64(16, -1.0e30), Posit::maxpos(16).neg());
    }

    #[test]
    fn subnormal_f64_input() {
        // A subnormal f64 is far below minpos for n<=32 -> minpos.
        let sub = f64::from_bits(1); // 2^-1074
        assert_eq!(Posit::from_f64(16, sub), Posit::minpos(16));
        assert_eq!(Posit::from_f64(16, -sub), Posit::minpos(16).neg());
        // For n=64, minpos = 2^-248, still above any subnormal.
        assert_eq!(Posit::from_f64(64, sub), Posit::minpos(64));
    }

    #[test]
    fn rounding_to_nearest() {
        // Posit8 around 1.0: representable neighbors are 1.0 and 1.125.
        assert_eq!(Posit::from_f64(8, 1.05).to_f64(), 1.0);
        assert_eq!(Posit::from_f64(8, 1.07).to_f64(), 1.125);
        // Exactly halfway: 1.0625 -> ties to even pattern (1.0 has even lsb).
        assert_eq!(Posit::from_f64(8, 1.0625).to_f64(), 1.0);
        // Halfway between 1.125 (odd pattern) and 1.25: rounds up to even.
        assert_eq!(Posit::from_f64(8, 1.1875).to_f64(), 1.25);
    }
}
