//! Baseline comparison and the regression gate.
//!
//! A baseline is just a committed [`Report`] (conventionally
//! `BENCH_<suite>.json` at the repo root). Comparison joins rows by
//! measurement name, computes the throughput delta for every common row,
//! and fails any row whose ops/sec dropped more than the threshold. Rows
//! present on only one side are reported but never fail the gate — suite
//! row sets may grow across PRs without invalidating old baselines.

use super::report::Report;

/// One compared row.
#[derive(Clone, Debug)]
pub struct RowDelta {
    pub name: String,
    pub base_ops: f64,
    pub new_ops: f64,
    /// Throughput change in percent (negative = slower than baseline).
    pub delta_pct: f64,
    pub regressed: bool,
}

/// Result of comparing a fresh report against a baseline.
#[derive(Clone, Debug)]
pub struct Comparison {
    pub threshold_pct: f64,
    /// Rows present in both reports, in the new report's order.
    pub rows: Vec<RowDelta>,
    /// Row names only in the new report.
    pub added: Vec<String>,
    /// Row names only in the baseline.
    pub removed: Vec<String>,
    /// The baseline was recorded without a trustworthy measurement
    /// environment; callers should downgrade the gate to advisory.
    pub baseline_provisional: bool,
}

impl Comparison {
    /// Join `new` against `baseline` with a regression threshold in
    /// percent (e.g. 15.0 fails rows that lost >15% ops/sec).
    pub fn compare(baseline: &Report, new: &Report, threshold_pct: f64) -> Comparison {
        let mut rows = Vec::new();
        let mut added = Vec::new();
        for e in &new.measurements {
            match baseline.measurements.iter().find(|b| b.name == e.name) {
                Some(b) => {
                    let delta_pct = (e.ops_per_sec / b.ops_per_sec - 1.0) * 100.0;
                    rows.push(RowDelta {
                        name: e.name.clone(),
                        base_ops: b.ops_per_sec,
                        new_ops: e.ops_per_sec,
                        delta_pct,
                        regressed: delta_pct < -threshold_pct,
                    });
                }
                None => added.push(e.name.clone()),
            }
        }
        let removed = baseline
            .measurements
            .iter()
            .filter(|b| !new.measurements.iter().any(|e| e.name == b.name))
            .map(|b| b.name.clone())
            .collect();
        Comparison {
            threshold_pct,
            rows,
            added,
            removed,
            baseline_provisional: baseline.provisional,
        }
    }

    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.regressed).count()
    }

    /// True iff no compared row regressed past the threshold.
    pub fn passed(&self) -> bool {
        self.regressions() == 0
    }

    /// Render the per-row delta table plus the verdict line.
    pub fn render(&self, baseline_label: &str) -> String {
        let mut out = format!(
            "\n== baseline comparison vs {} (fail below -{:.1}%) ==\n",
            baseline_label, self.threshold_pct
        );
        out.push_str(&format!(
            "{:<44} {:>16} {:>16} {:>9}\n",
            "row", "baseline op/s", "current op/s", "delta"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:<44} {:>16.0} {:>16.0} {:>8.1}%{}\n",
                r.name,
                r.base_ops,
                r.new_ops,
                r.delta_pct,
                if r.regressed { "  REGRESSION" } else { "" }
            ));
        }
        if !self.added.is_empty() {
            out.push_str(&format!("new rows without a baseline: {}\n", self.added.len()));
        }
        if !self.removed.is_empty() {
            out.push_str(&format!(
                "baseline rows missing from this run: {}\n",
                self.removed.len()
            ));
        }
        if self.baseline_provisional {
            out.push_str("note: baseline is PROVISIONAL — gate is advisory until refreshed\n");
        }
        out.push_str(&format!(
            "verdict: {} ({} regression(s) in {} compared row(s))\n",
            if self.passed() { "PASS" } else { "FAIL" },
            self.regressions(),
            self.rows.len()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::report::Entry;
    use crate::bench::{Config, Measurement, Profile};
    use std::time::Duration;

    fn row(name: &str, ops: f64) -> Entry {
        let m = Measurement {
            name: name.into(),
            per_op: Duration::from_secs_f64(1.0 / ops),
            ops_per_sec: ops,
            samples: 3,
            iters_per_sample: 10,
        };
        Entry::from_measurement(&m)
    }

    fn report(rows: Vec<Entry>) -> Report {
        Report::new("t", Profile::Quick, Config::quick(), rows)
    }

    #[test]
    fn within_threshold_passes() {
        let base = report(vec![row("a", 1000.0), row("b", 2000.0)]);
        let new = report(vec![row("a", 900.0), row("b", 2400.0)]);
        let cmp = Comparison::compare(&base, &new, 15.0);
        assert!(cmp.passed());
        assert_eq!(cmp.rows.len(), 2);
        assert!((cmp.rows[0].delta_pct - -10.0).abs() < 1e-9);
        assert!((cmp.rows[1].delta_pct - 20.0).abs() < 1e-9);
    }

    #[test]
    fn drop_past_threshold_fails() {
        let base = report(vec![row("a", 1000.0)]);
        let new = report(vec![row("a", 800.0)]);
        let cmp = Comparison::compare(&base, &new, 15.0);
        assert_eq!(cmp.regressions(), 1);
        assert!(!cmp.passed());
        assert!(cmp.render("BENCH_t.json").contains("REGRESSION"));
        assert!(cmp.render("BENCH_t.json").contains("FAIL"));
        // a looser threshold tolerates the same drop
        assert!(Comparison::compare(&base, &new, 25.0).passed());
    }

    #[test]
    fn added_and_removed_rows_never_fail() {
        let base = report(vec![row("old", 1000.0), row("both", 1000.0)]);
        let new = report(vec![row("both", 1000.0), row("fresh", 50.0)]);
        let cmp = Comparison::compare(&base, &new, 15.0);
        assert!(cmp.passed());
        assert_eq!(cmp.added, vec!["fresh".to_string()]);
        assert_eq!(cmp.removed, vec!["old".to_string()]);
        let text = cmp.render("BENCH_t.json");
        assert!(text.contains("new rows"));
        assert!(text.contains("missing from this run"));
    }

    #[test]
    fn provisional_baseline_is_flagged() {
        let mut base = report(vec![row("a", 1000.0)]);
        base.provisional = true;
        let new = report(vec![row("a", 100.0)]);
        let cmp = Comparison::compare(&base, &new, 15.0);
        assert!(cmp.baseline_provisional);
        assert!(!cmp.passed()); // still reports FAIL; the gate decides advisory
        assert!(cmp.render("x").contains("PROVISIONAL"));
    }
}
