//! The structured bench-report model: what a suite measured, where it
//! ran, and under which profile — serialized as stable, diffable JSON so
//! baselines can be committed (`BENCH_<suite>.json`) and regressions
//! gated in CI. See EXPERIMENTS.md §Perf for the workflow.
//!
//! Schema `posit-div/bench-report/v1`:
//!
//! ```json
//! {
//!   "schema": "posit-div/bench-report/v1",
//!   "suite": "engine_throughput",
//!   "git_rev": "d198d87c1a2b",
//!   "profile": "quick",
//!   "provisional": false,
//!   "note": "",
//!   "config": { "warmup_ms": 30, "sample_time_ms": 30, "samples": 3 },
//!   "measurements": [
//!     {
//!       "name": "Posit16 SRT r4 CS OF FR batch",
//!       "width": 16,
//!       "algorithm": "SRT r4 CS OF FR",
//!       "path": "batch",
//!       "per_op_ns": 171.4,
//!       "ops_per_sec": 5834208,
//!       "samples": 3,
//!       "iters_per_sample": 683
//!     }
//!   ]
//! }
//! ```
//!
//! `width`/`algorithm`/`path` are `null` when a row has no natural value
//! for them (e.g. a selection-table derivation). `path` is a free-form
//! producer tag (`batch`, `batch:fast-simd`, `service:datapath`, …) —
//! validation only requires it to be non-empty when present, so new
//! execution paths never need a schema change. `per_op_ns` is wall time
//! for measured rows and modeled latency for `hw-*` rows. Measurement
//! names are unique within a report — they are the join key for baseline
//! comparison ([`super::baseline`]).

use std::path::Path;

use super::json::Json;
use super::{Config, Measurement, Profile};

/// Schema identifier embedded in (and required of) every report.
pub const SCHEMA: &str = "posit-div/bench-report/v1";

/// One report row. See the module docs for field semantics.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub name: String,
    pub width: Option<u32>,
    pub algorithm: Option<String>,
    pub path: Option<String>,
    pub per_op_ns: f64,
    pub ops_per_sec: f64,
    pub samples: u64,
    pub iters_per_sample: u64,
}

impl Entry {
    /// An untagged row straight from a [`Measurement`].
    pub fn from_measurement(m: &Measurement) -> Entry {
        Entry {
            name: m.name.clone(),
            width: None,
            algorithm: None,
            path: None,
            per_op_ns: m.per_op.as_secs_f64() * 1e9,
            ops_per_sec: m.ops_per_sec,
            samples: m.samples as u64,
            iters_per_sample: m.iters_per_sample,
        }
    }

    /// A row with format/algorithm/path metadata attached.
    pub fn tagged(
        m: &Measurement,
        width: Option<u32>,
        algorithm: Option<&str>,
        path: &str,
    ) -> Entry {
        Entry {
            width,
            algorithm: algorithm.map(str::to_string),
            path: Some(path.to_string()),
            ..Entry::from_measurement(m)
        }
    }

    fn to_json(&self) -> Json {
        let opt_num = |v: Option<u32>| v.map_or(Json::Null, |x| Json::Num(x as f64));
        let opt_str = |v: &Option<String>| v.as_ref().map_or(Json::Null, |s| Json::Str(s.clone()));
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("width".into(), opt_num(self.width)),
            ("algorithm".into(), opt_str(&self.algorithm)),
            ("path".into(), opt_str(&self.path)),
            ("per_op_ns".into(), Json::Num(self.per_op_ns)),
            ("ops_per_sec".into(), Json::Num(self.ops_per_sec)),
            ("samples".into(), Json::Num(self.samples as f64)),
            ("iters_per_sample".into(), Json::Num(self.iters_per_sample as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<Entry, String> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .filter(|s| !s.is_empty())
            .ok_or("name: required non-empty string")?
            .to_string();
        let width = match v.get("width") {
            None | Some(Json::Null) => None,
            Some(w) => Some(
                w.as_u64()
                    .map(|x| x as u32)
                    .filter(|x| (crate::posit::MIN_N..=crate::posit::MAX_N).contains(x))
                    .ok_or("width: must be an integer posit width or null")?,
            ),
        };
        let opt_str = |key: &str| -> Result<Option<String>, String> {
            match v.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(s) => Ok(Some(
                    s.as_str().ok_or(format!("{key}: must be a string or null"))?.to_string(),
                )),
            }
        };
        let pos_num = |key: &str| -> Result<f64, String> {
            v.get(key)
                .and_then(Json::as_f64)
                .filter(|x| x.is_finite() && *x > 0.0)
                .ok_or(format!("{key}: required positive finite number"))
        };
        let count = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(Json::as_u64)
                .filter(|x| *x >= 1)
                .ok_or(format!("{key}: required integer >= 1"))
        };
        // `path` is a free-form producer tag (`batch`, `batch:fast-simd`,
        // `service:datapath`, …) — new execution paths must not require a
        // schema change, so the only constraint is non-emptiness (an
        // empty tag is always a producer bug).
        let path = match opt_str("path")? {
            Some(p) if p.is_empty() => {
                return Err("path: must be a non-empty string or null".into())
            }
            p => p,
        };
        Ok(Entry {
            name,
            width,
            algorithm: opt_str("algorithm")?,
            path,
            per_op_ns: pos_num("per_op_ns")?,
            ops_per_sec: pos_num("ops_per_sec")?,
            samples: count("samples")?,
            iters_per_sample: count("iters_per_sample")?,
        })
    }
}

/// Timing configuration as recorded in a report.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReportConfig {
    pub warmup_ms: f64,
    pub sample_time_ms: f64,
    pub samples: u64,
}

impl From<Config> for ReportConfig {
    fn from(cfg: Config) -> ReportConfig {
        ReportConfig {
            warmup_ms: cfg.warmup.as_secs_f64() * 1e3,
            sample_time_ms: cfg.sample_time.as_secs_f64() * 1e3,
            samples: cfg.samples as u64,
        }
    }
}

/// A complete suite report (the unit that `--json` writes, baselines
/// store, and CI uploads as an artifact).
#[derive(Clone, Debug, PartialEq)]
pub struct Report {
    pub suite: String,
    pub git_rev: String,
    pub profile: String,
    /// True for baselines recorded without a trustworthy measurement
    /// environment; the regression gate downgrades to advisory against
    /// them.
    pub provisional: bool,
    pub note: String,
    pub config: ReportConfig,
    pub measurements: Vec<Entry>,
}

impl Report {
    /// Assemble a report for a finished suite run.
    pub fn new(suite: &str, profile: Profile, cfg: Config, measurements: Vec<Entry>) -> Report {
        Report {
            suite: suite.to_string(),
            git_rev: current_git_rev(),
            profile: profile.name().to_string(),
            provisional: false,
            note: String::new(),
            config: ReportConfig::from(cfg),
            measurements,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("schema".into(), Json::Str(SCHEMA.into())),
            ("suite".into(), Json::Str(self.suite.clone())),
            ("git_rev".into(), Json::Str(self.git_rev.clone())),
            ("profile".into(), Json::Str(self.profile.clone())),
            ("provisional".into(), Json::Bool(self.provisional)),
            ("note".into(), Json::Str(self.note.clone())),
            (
                "config".into(),
                Json::Obj(vec![
                    ("warmup_ms".into(), Json::Num(self.config.warmup_ms)),
                    ("sample_time_ms".into(), Json::Num(self.config.sample_time_ms)),
                    ("samples".into(), Json::Num(self.config.samples as f64)),
                ]),
            ),
            (
                "measurements".into(),
                Json::Arr(self.measurements.iter().map(Entry::to_json).collect()),
            ),
        ])
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_pretty()
    }

    /// Parse and schema-validate a report value. Every deviation from the
    /// schema is an error, including duplicate measurement names (they
    /// would break baseline matching).
    pub fn from_json(v: &Json) -> Result<Report, String> {
        let schema = v.get("schema").and_then(Json::as_str).ok_or("schema: required string")?;
        if schema != SCHEMA {
            return Err(format!("schema: got {schema:?}, want {SCHEMA:?}"));
        }
        let suite = v
            .get("suite")
            .and_then(Json::as_str)
            .filter(|s| !s.is_empty())
            .ok_or("suite: required non-empty string")?
            .to_string();
        let git_rev =
            v.get("git_rev").and_then(Json::as_str).ok_or("git_rev: required string")?.to_string();
        let profile = v
            .get("profile")
            .and_then(Json::as_str)
            .filter(|p| Profile::parse(p).is_some())
            .ok_or("profile: required, one of \"quick\"/\"full\"")?
            .to_string();
        let provisional = match v.get("provisional") {
            None => false,
            Some(p) => p.as_bool().ok_or("provisional: must be a bool")?,
        };
        let note = match v.get("note") {
            None => String::new(),
            Some(s) => s.as_str().ok_or("note: must be a string")?.to_string(),
        };
        let cfg = v.get("config").ok_or("config: required object")?;
        let cfg_num = |key: &str| -> Result<f64, String> {
            cfg.get(key)
                .and_then(Json::as_f64)
                .filter(|x| x.is_finite() && *x >= 0.0)
                .ok_or(format!("config.{key}: required non-negative number"))
        };
        let config = ReportConfig {
            warmup_ms: cfg_num("warmup_ms")?,
            sample_time_ms: cfg_num("sample_time_ms")?,
            samples: cfg
                .get("samples")
                .and_then(Json::as_u64)
                .ok_or("config.samples: required integer")?,
        };
        let rows = v
            .get("measurements")
            .and_then(Json::as_arr)
            .ok_or("measurements: required array")?;
        let mut measurements = Vec::with_capacity(rows.len());
        let mut seen = std::collections::HashSet::new();
        for (i, row) in rows.iter().enumerate() {
            let e = Entry::from_json(row).map_err(|err| format!("measurements[{i}]: {err}"))?;
            if !seen.insert(e.name.clone()) {
                return Err(format!("measurements[{i}]: duplicate name {:?}", e.name));
            }
            measurements.push(e);
        }
        Ok(Report { suite, git_rev, profile, provisional, note, config, measurements })
    }

    /// Load and validate a report file.
    pub fn load(path: &Path) -> Result<Report, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        Report::from_json(&v).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Write the report as pretty JSON.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json_string())
            .map_err(|e| format!("{}: {e}", path.display()))
    }
}

/// Current commit id for report provenance: `$GITHUB_SHA` in CI, `git
/// rev-parse` locally, `"unknown"` without either.
pub fn current_git_rev() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        let sha = sha.trim().to_string();
        if sha.len() >= 12 && sha.is_ascii() {
            return sha[..12].to_string();
        }
        if !sha.is_empty() {
            return sha;
        }
    }
    match std::process::Command::new("git").args(["rev-parse", "--short=12", "HEAD"]).output() {
        Ok(out) if out.status.success() => {
            String::from_utf8_lossy(&out.stdout).trim().to_string()
        }
        _ => "unknown".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn sample_report() -> Report {
        let m = Measurement {
            name: "Posit16 SRT r4 CS OF FR batch".into(),
            per_op: Duration::from_nanos(171),
            ops_per_sec: 5.84e6,
            samples: 3,
            iters_per_sample: 683,
        };
        let rows = vec![
            Entry::tagged(&m, Some(16), Some("SRT r4 CS OF FR"), "batch"),
            Entry {
                name: "derive_radix4_thresholds a=2".into(),
                ..Entry::from_measurement(&m)
            },
        ];
        Report::new("engine_throughput", Profile::Quick, Config::quick(), rows)
    }

    #[test]
    fn round_trips_through_json() {
        let rep = sample_report();
        let text = rep.to_json_string();
        let back = Report::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rep);
        assert_eq!(back.measurements[0].width, Some(16));
        assert_eq!(back.measurements[0].path.as_deref(), Some("batch"));
        assert_eq!(back.measurements[1].width, None);
    }

    #[test]
    fn validation_rejects_schema_violations() {
        let rep = sample_report();
        let mutate = |f: &dyn Fn(&mut Report)| {
            let mut r = rep.clone();
            f(&mut r);
            let v = Json::parse(&r.to_json_string()).unwrap();
            Report::from_json(&v)
        };
        assert!(mutate(&|r| r.suite.clear()).is_err());
        assert!(mutate(&|r| r.measurements[0].name.clear()).is_err());
        assert!(mutate(&|r| r.measurements[0].per_op_ns = -1.0).is_err());
        assert!(mutate(&|r| r.measurements[0].width = Some(3)).is_err());
        assert!(mutate(&|r| r.profile = "warp".into()).is_err());
        // path is free-form but must be non-empty when present
        let err = mutate(&|r| r.measurements[0].path = Some(String::new())).unwrap_err();
        assert!(err.contains("path"), "{err}");
        // duplicate names break baseline matching
        let dup = mutate(&|r| {
            let row = r.measurements[0].clone();
            r.measurements.push(row);
        });
        assert!(dup.unwrap_err().contains("duplicate"));
    }

    /// Regression test: `path` is a free-form tag, not an enumerated
    /// list — new execution-path tags must validate without a schema
    /// change.
    #[test]
    fn novel_path_tags_are_accepted() {
        for tag in ["batch:fast-simd", "batch:fast-table", "service:fast", "anything/else"] {
            let mut rep = sample_report();
            rep.measurements[0].path = Some(tag.to_string());
            let back = Report::from_json(&Json::parse(&rep.to_json_string()).unwrap()).unwrap();
            assert_eq!(back.measurements[0].path.as_deref(), Some(tag));
        }
    }

    #[test]
    fn wrong_schema_id_is_rejected() {
        let v = Json::parse(r#"{"schema": "posit-div/bench-report/v0"}"#).unwrap();
        let err = Report::from_json(&v).unwrap_err();
        assert!(err.contains("schema"), "{err}");
    }

    #[test]
    fn save_and_load() {
        let rep = sample_report();
        let dir = std::env::temp_dir().join(format!("posit_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_roundtrip.json");
        rep.save(&path).unwrap();
        assert_eq!(Report::load(&path).unwrap(), rep);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn git_rev_is_nonempty() {
        assert!(!current_git_rev().is_empty());
    }
}
