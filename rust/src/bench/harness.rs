//! Shared entry point for every `harness = false` bench target and for
//! the `posit-div bench` subcommand: flag parsing, profile selection,
//! structured-report emission, baseline comparison and the regression
//! gate. One suite body in [`super::suites`] therefore runs identically
//! under `cargo bench --bench <suite> -- <flags>` and
//! `posit-div bench <suite> <flags>`.
//!
//! Flags:
//!
//! * `--profile quick|full` — timing profile (default: `$POSIT_BENCH_PROFILE`,
//!   then `full`). `--quick` / `--full` are shorthands. Profiles change
//!   only timing budgets, never the row set, so any profile can be
//!   compared against any baseline.
//! * `--json <path>` — also write the structured report to `<path>`.
//! * `--baseline <path>` — compare against this report instead of the
//!   default `BENCH_<suite>.json`.
//! * `--write-baseline` — record the run as the new baseline and exit.
//! * `--threshold <pct>` — regression threshold on ops/sec (default 15,
//!   or `$POSIT_BENCH_THRESHOLD`).
//! * `--advisory` — print the verdict but always exit 0 (also
//!   `$POSIT_BENCH_ADVISORY=1`; forced when the baseline is provisional).

use std::path::{Path, PathBuf};

use super::baseline::Comparison;
use super::report::Report;
use super::{suites, Config, Profile, Runner};
use crate::cli::Args;
use crate::unit::ExecTier;

/// Parsed bench-harness options for one suite run.
pub struct BenchCli {
    pub suite: &'static str,
    pub profile: Profile,
    /// Timing configuration derived from the profile.
    pub cfg: Config,
    /// `--tier fast|datapath|auto` — restricts tier-aware suites
    /// (`unit_throughput`) to one execution tier. `None`/`auto` runs the
    /// full tier-tagged row set; note that unlike profiles, an explicit
    /// single-tier run *does* shrink the row set (the baseline compare
    /// treats the missing rows as removed, which never fails).
    pub tier: Option<ExecTier>,
    json_out: Option<PathBuf>,
    baseline: Option<PathBuf>,
    write_baseline: bool,
    threshold_pct: f64,
    advisory: bool,
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).is_ok_and(|v| v == "1" || v.eq_ignore_ascii_case("true"))
}

impl BenchCli {
    pub fn from_args(suite: &'static str, args: &Args) -> BenchCli {
        let profile = if args.has("full") {
            Profile::Full
        } else if args.has("quick") {
            Profile::Quick
        } else if let Some(p) = args.flag("profile") {
            Profile::parse(p).unwrap_or_else(|| {
                eprintln!("invalid --profile {p:?} (expected quick|full)");
                std::process::exit(2);
            })
        } else {
            Profile::from_env().unwrap_or(Profile::Full)
        };
        let default_threshold = std::env::var("POSIT_BENCH_THRESHOLD")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap_or(15.0);
        BenchCli {
            suite,
            profile,
            cfg: profile.config(),
            tier: args.flag("tier").map(|t| {
                ExecTier::parse(t).unwrap_or_else(|| {
                    eprintln!("invalid --tier {t:?} (expected fast|datapath|auto)");
                    std::process::exit(2);
                })
            }),
            json_out: args.flag("json").map(PathBuf::from),
            baseline: args.flag("baseline").map(PathBuf::from),
            write_baseline: args.has("write-baseline"),
            threshold_pct: args.get("threshold", default_threshold),
            advisory: args.has("advisory") || env_flag("POSIT_BENCH_ADVISORY"),
        }
    }

    /// Where the baseline for this suite lives. Without `--baseline`,
    /// `BENCH_<suite>.json` is resolved against the enclosing cargo
    /// project, not the bare cwd — `cargo bench`/`cargo run` preserve the
    /// invoker's directory, and a subdirectory run must neither skip the
    /// gate nor write a stray baseline.
    pub fn baseline_path(&self) -> PathBuf {
        if let Some(explicit) = &self.baseline {
            return explicit.clone();
        }
        let file = format!("BENCH_{}.json", self.suite);
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            if dir.join(&file).exists() || dir.join("Cargo.toml").exists() {
                return dir.join(file);
            }
            if !dir.pop() {
                return PathBuf::from(file);
            }
        }
    }

    /// Post-run bookkeeping: JSON emission, baseline write/compare, gate.
    /// Returns the process exit code.
    pub fn finish(&self, runner: &Runner) -> i32 {
        let report = Report::new(self.suite, self.profile, self.cfg, runner.entries().to_vec());
        // Fail at the source, not when a later run trips over the saved
        // file: names are the baseline join key, so a duplicate here
        // would poison every subsequent load of this report.
        let mut seen = std::collections::HashSet::new();
        if let Some(dup) = report.measurements.iter().find(|e| !seen.insert(e.name.as_str())) {
            eprintln!(
                "suite {:?} registered duplicate row name {:?} — fix the suite",
                self.suite, dup.name
            );
            return 1;
        }
        if let Some(path) = &self.json_out {
            match report.save(path) {
                Ok(()) => println!("report written: {}", path.display()),
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            }
        }
        let path = self.baseline_path();
        if self.write_baseline {
            return match report.save(&path) {
                Ok(()) => {
                    println!("baseline written: {}", path.display());
                    0
                }
                Err(e) => {
                    eprintln!("{e}");
                    1
                }
            };
        }
        if !path.exists() {
            println!(
                "no baseline at {} (record one with --write-baseline)",
                path.display()
            );
            return 0;
        }
        let base = match Report::load(&path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("baseline invalid: {e}");
                return 1;
            }
        };
        if base.suite != report.suite {
            eprintln!(
                "baseline {} is for suite {:?}, not {:?}",
                path.display(),
                base.suite,
                report.suite
            );
            return 1;
        }
        let cmp = Comparison::compare(&base, &report, self.threshold_pct);
        print!("{}", cmp.render(&path.display().to_string()));
        if cmp.passed() {
            0
        } else if self.advisory || cmp.baseline_provisional {
            println!("regression gate: advisory — not failing this run");
            0
        } else {
            1
        }
    }
}

/// Run one named suite with flags from `args`; returns the exit code.
/// Shared by the `bench` subcommand and [`bench_main`].
pub fn run_suite(name: &str, args: &Args) -> i32 {
    let Some(suite) = suites::find(name) else {
        eprintln!("unknown bench suite {name:?}\n{}", suites::render_list());
        return 2;
    };
    let cli = BenchCli::from_args(suite.name, args);
    if cli.tier.is_some() && !suite.tier_aware {
        // Refuse rather than mislabel: the per-engine suites pin the
        // Datapath tier by design, so honoring `--tier fast` silently
        // would record datapath numbers under a fast-tier run.
        eprintln!(
            "suite {:?} is not tier-aware (it pins the Datapath tier by design); \
             drop --tier, or use `unit_throughput` for the tier comparison",
            suite.name
        );
        return 2;
    }
    let mut runner = Runner::new(suite.title);
    (suite.run)(&cli, &mut runner);
    runner.finish();
    cli.finish(&runner)
}

/// `main` for the thin `rust/benches/*.rs` shims: parse the process
/// arguments (dropping the `--bench` marker `cargo bench` appends), run
/// the suite, exit with the gate's code.
pub fn bench_main(suite: &str) -> ! {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    std::process::exit(run_suite(suite, &args));
}

/// Validate a report file on disk; returns the exit code. Used by the
/// `posit-div bench validate <path>` schema gate in CI.
pub fn validate_report(path: &Path) -> i32 {
    match Report::load(path) {
        Ok(rep) => {
            println!(
                "{}: valid {} report — suite {}, profile {}, rev {}, {} measurement(s){}",
                path.display(),
                super::report::SCHEMA,
                rep.suite,
                rep.profile,
                rep.git_rev,
                rep.measurements.len(),
                if rep.provisional { " (provisional)" } else { "" }
            );
            0
        }
        Err(e) => {
            eprintln!("schema-invalid report: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn profile_flag_resolution() {
        let c = BenchCli::from_args("t", &args("--quick"));
        assert_eq!(c.profile, Profile::Quick);
        let c = BenchCli::from_args("t", &args("--profile quick"));
        assert_eq!(c.profile, Profile::Quick);
        let c = BenchCli::from_args("t", &args("--profile full"));
        assert_eq!(c.profile, Profile::Full);
        // explicit shorthand wins over the flag
        let c = BenchCli::from_args("t", &args("--full --profile quick"));
        assert_eq!(c.profile, Profile::Full);
    }

    #[test]
    fn baseline_path_defaults_to_suite_name_at_project_root() {
        let c = BenchCli::from_args("engine_throughput", &args(""));
        let path = c.baseline_path();
        assert!(path.ends_with("BENCH_engine_throughput.json"), "{path:?}");
        // resolved against the cargo project, not a bare relative path
        assert!(path.parent().is_some_and(|d| d.join("Cargo.toml").exists()), "{path:?}");
        let c = BenchCli::from_args("engine_throughput", &args("--baseline other.json"));
        assert_eq!(c.baseline_path(), PathBuf::from("other.json"));
    }

    #[test]
    fn threshold_and_modes() {
        let c = BenchCli::from_args("t", &args("--threshold 30 --advisory --json out.json"));
        assert!((c.threshold_pct - 30.0).abs() < 1e-12);
        assert!(c.advisory);
        assert_eq!(c.json_out, Some(PathBuf::from("out.json")));
        assert!(!c.write_baseline);
        let c = BenchCli::from_args("t", &args("--write-baseline"));
        assert!(c.write_baseline);
    }

    #[test]
    fn tier_flag_resolution() {
        assert_eq!(BenchCli::from_args("t", &args("")).tier, None);
        assert_eq!(BenchCli::from_args("t", &args("--tier fast")).tier, Some(ExecTier::Fast));
        assert_eq!(
            BenchCli::from_args("t", &args("--tier datapath")).tier,
            Some(ExecTier::Datapath)
        );
        assert_eq!(BenchCli::from_args("t", &args("--tier auto")).tier, Some(ExecTier::Auto));
    }

    #[test]
    fn unknown_suite_exits_2() {
        assert_eq!(run_suite("no_such_suite", &args("")), 2);
    }

    #[test]
    fn tier_flag_on_datapath_pinned_suite_is_refused() {
        // engine_throughput pins the Datapath tier; honoring --tier
        // silently would mislabel the measurements.
        assert_eq!(run_suite("engine_throughput", &args("--tier fast")), 2);
    }

    #[test]
    fn validate_rejects_missing_file() {
        assert_eq!(validate_report(Path::new("/nonexistent/BENCH_x.json")), 1);
    }
}
