//! Quickstart: the public API in two minutes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use posit_div::division::{golden, Algorithm, DivEngine};
use posit_div::posit::Posit;

fn main() {
    // --- posits -----------------------------------------------------------
    let n = 32; // Posit⟨32,2⟩, the 2022-standard es=2
    let x = Posit::from_f64(n, 355.0);
    let d = Posit::from_f64(n, 113.0);
    println!("x = {x:?}");
    println!("d = {d:?}");

    // --- division through any of the paper's engines ----------------------
    for alg in [
        Algorithm::Nrd,        // Algorithm 1 baseline
        Algorithm::Srt2Cs,     // radix-2 SRT, carry-save residual
        Algorithm::Srt4CsOfFr, // the paper's optimized radix-4 unit
        Algorithm::Srt4Scaled, // radix-4 with Table I operand scaling
        Algorithm::Newton,     // the multiplicative baseline
    ] {
        let engine = alg.engine();
        let div = engine.divide(x, d);
        println!(
            "{:<18} -> {:<22} {:>2} iterations, {:>2} cycles",
            engine.name(),
            div.result.to_f64(),
            div.iterations,
            div.cycles
        );
    }

    // every engine is bit-identical to the exact golden model:
    let want = golden::divide(x, d).result;
    assert!(Algorithm::ALL.iter().all(|a| a.engine().divide(x, d).result == want));
    println!("all engines agree bit-exactly: 355/113 = {} (2 ulp from π)", want.to_f64());

    // --- posit arithmetic basics ------------------------------------------
    let a = Posit::from_f64(16, 0.3);
    let b = Posit::from_f64(16, 0.6);
    println!("\nPosit16: 0.3 + 0.6 = {}", a.add(b));
    println!("Posit16: 0.3 * 0.6 = {}", a.mul(b));
    println!("Posit16 has {} fraction bits at 1.0; maxpos = {:e}",
        posit_div::posit::frac_bits(16), Posit::maxpos(16).to_f64());

    // specials: a single NaR, no overflow
    assert!(Posit::from_f64(16, f64::NAN).is_nar());
    assert_eq!(Posit::maxpos(16).add(Posit::maxpos(16)), Posit::maxpos(16));
    println!("posit saturates instead of overflowing; NaR is the only special");
}
