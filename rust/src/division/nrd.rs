//! Non-restoring division (Algorithm 1) — the paper's radix-2 baseline.
//!
//! Digit set {−1, +1} (no zero digit), non-redundant residual, full-width
//! sign inspection per iteration. Also implements the [14] (ASAP'23)
//! comparison variant: that design decodes posits in two's complement,
//! producing signed significands in [−2,−1)∪[1,2), which costs the
//! recurrence one extra iteration (§IV) — the arithmetic is otherwise
//! identical, so we model it as `It + 1` iterations on the magnitude
//! datapath (results are bit-identical; only latency/cost differ).

use super::{iterations, Algorithm, DivEngine, FracQuotient};
use crate::posit::frac_bits;

/// Non-restoring radix-2 divider.
pub struct Nrd {
    extra_iteration: bool,
}

impl Nrd {
    /// The paper's NRD (sign-magnitude decode).
    pub fn new() -> Self {
        Nrd { extra_iteration: false }
    }

    /// The [14] variant: two's-complement decode ⇒ one extra iteration.
    pub fn asap23() -> Self {
        Nrd { extra_iteration: true }
    }
}

impl Default for Nrd {
    fn default() -> Self {
        Self::new()
    }
}

impl DivEngine for Nrd {
    fn name(&self) -> &'static str {
        if self.extra_iteration {
            "NRD [14]"
        } else {
            "NRD"
        }
    }

    fn algorithm(&self) -> Algorithm {
        if self.extra_iteration {
            Algorithm::NrdAsap23
        } else {
            Algorithm::Nrd
        }
    }

    fn fraction_divide(&self, n: u32, x_sig: u64, d_sig: u64) -> FracQuotient {
        let f = frac_bits(n);
        debug_assert!(x_sig >> f == 1 && d_sig >> f == 1);
        let it = iterations(n, 2) + self.extra_iteration as u32;

        // [1/2,1) convention: x = x_sig/2^(F+1), d = d_sig/2^(F+1).
        // Fixed point FW = F+2 fractional bits: w(0) = x/2 ⇒ exactly x_sig.
        let d_fp = (d_sig as i128) << 1;
        let mut w = x_sig as i128;
        let mut q: i128 = 0;
        for _ in 0..it {
            // Algorithm 1 line 3: digit from the residual sign only.
            let digit: i128 = if w >= 0 { 1 } else { -1 };
            w = 2 * w - digit * d_fp;
            q = 2 * q + digit;
            // datapath-width invariant: |w| ≤ d at all times
            debug_assert!(w.abs() <= d_fp, "NRD residual out of bound");
        }
        // Termination (Algorithm 1 lines 8-13).
        if w < 0 {
            q -= 1;
            w += d_fp;
        }
        debug_assert!(w >= 0 && w < d_fp);
        FracQuotient {
            mag: q as u128,
            frac_bits: it - 1, // q_total = 2·q(It) = q·2^−(It−1) ∈ (1/2,2)
            sticky: w != 0,
            iterations: it,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::division::golden;

    #[test]
    fn nrd_matches_golden_simple() {
        let n = 16;
        let f = frac_bits(n);
        let one = 1u64 << f;
        let e = Nrd::new();
        // 1/1 = 1
        let q = e.fraction_divide(n, one, one);
        let (g, gs) = golden::frac_divide(n, one, one).refine_to(q.frac_bits);
        assert_eq!((q.mag, q.sticky), (g, gs));
        // 1.5/1.25
        let q = e.fraction_divide(n, one | (1 << (f - 1)), one | (1 << (f - 2)));
        let (g, gs) =
            golden::frac_divide(n, one | (1 << (f - 1)), one | (1 << (f - 2))).refine_to(q.frac_bits);
        assert_eq!((q.mag, q.sticky), (g, gs));
    }

    #[test]
    fn nrd_equals_golden_random_all_widths() {
        let mut rng = crate::testkit::Rng::seeded(0x42D);
        let e = Nrd::new();
        let e14 = Nrd::asap23();
        for &n in &[8u32, 10, 16, 24, 32, 48, 64] {
            let f = frac_bits(n);
            for _ in 0..5000 {
                let x = (1 << f) | (rng.next_u64() & crate::posit::mask(f));
                let d = (1 << f) | (rng.next_u64() & crate::posit::mask(f));
                let q = e.fraction_divide(n, x, d);
                let (g, gs) = golden::frac_divide(n, x, d).refine_to(q.frac_bits);
                assert_eq!((q.mag, q.sticky), (g, gs), "n={n} x={x:#x} d={d:#x}");
                // the [14] variant is one bit more precise but must agree
                // after refinement as well
                let q14 = e14.fraction_divide(n, x, d);
                let (g14, gs14) = golden::frac_divide(n, x, d).refine_to(q14.frac_bits);
                assert_eq!((q14.mag, q14.sticky), (g14, gs14));
                assert_eq!(q14.iterations, q.iterations + 1);
            }
        }
    }

    #[test]
    fn nrd_full_divide_p8_exhaustive() {
        let n = 8;
        let e = Nrd::new();
        for xb in 0..=crate::posit::mask(n) {
            for db in 0..=crate::posit::mask(n) {
                let x = crate::posit::Posit::from_bits(n, xb);
                let d = crate::posit::Posit::from_bits(n, db);
                assert_eq!(
                    e.divide(x, d).result,
                    golden::divide(x, d).result,
                    "{x:?}/{d:?}"
                );
            }
        }
    }
}
