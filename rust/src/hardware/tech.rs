//! Technology model — the substitution for the paper's Synopsys DC +
//! 28 nm TSMC flow (see DESIGN.md §Substitutions).
//!
//! Everything is expressed in *unit-gate* terms (Ercegovac & Lang): area in
//! NAND2-gate equivalents (GE), delay in units of one loaded NAND2 delay
//! (τ). The constants below translate those into 28 nm physical numbers:
//! they are calibrated to published 28 nm HPM figures (NAND2X1 ≈ 0.63 µm²,
//! τ ≈ FO4/1.7 ≈ 15 ps, ~0.9 nW/MHz per GE at 15% switching activity).
//! Absolute values are *model* outputs; the paper-reproduction claims rest
//! on the relative orderings, which depend only on gate counts and logic
//! depth.

/// A process/flow calibration.
#[derive(Clone, Copy, Debug)]
pub struct Tech {
    /// µm² per gate-equivalent.
    pub area_um2_per_ge: f64,
    /// Nanoseconds per unit-gate delay τ.
    pub ns_per_tau: f64,
    /// Dynamic power: mW per GE per GHz of toggle-equivalent frequency at
    /// the reference activity.
    pub mw_per_ge_ghz: f64,
    /// Static (leakage) power: mW per GE.
    pub leak_mw_per_ge: f64,
    /// Default switching activity assumed by the power reports.
    pub activity: f64,
    /// Sequential overhead added to every pipeline stage (setup + clk→Q),
    /// in τ.
    pub reg_overhead_tau: f64,
}

/// 28 nm TSMC-class calibration (the paper's library).
pub const TSMC28: Tech = Tech {
    area_um2_per_ge: 0.63,
    ns_per_tau: 0.015,
    mw_per_ge_ghz: 0.9e-3,
    leak_mw_per_ge: 1.1e-6,
    activity: 0.15,
    reg_overhead_tau: 5.0,
};

impl Tech {
    /// Convert GE to µm².
    pub fn area_um2(&self, ge: f64) -> f64 {
        ge * self.area_um2_per_ge
    }

    /// Convert τ to ns.
    pub fn delay_ns(&self, tau: f64) -> f64 {
        tau * self.ns_per_tau
    }

    /// Dynamic + leakage power of `ge` gates toggling at `f_ghz`.
    pub fn power_mw(&self, ge: f64, f_ghz: f64) -> f64 {
        ge * self.mw_per_ge_ghz * f_ghz * (self.activity / 0.15) + ge * self.leak_mw_per_ge
    }

    /// 1.5 GHz — the paper's pipelined timing target.
    pub const PIPELINE_GHZ: f64 = 1.5;

    /// Clock period at the pipeline target, in τ.
    pub fn pipeline_period_tau(&self) -> f64 {
        (1.0 / Self::PIPELINE_GHZ) / self.ns_per_tau
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_sanity() {
        let t = TSMC28;
        // one thousand gates ≈ 0.6 kµm², sub-mW at 1 GHz
        assert!((t.area_um2(1000.0) - 630.0).abs() < 1.0);
        assert!(t.power_mw(1000.0, 1.0) < 1.5);
        // 1.5 GHz budget ≈ 44 τ: enough for a CS iteration, less than a
        // full 64-bit CPA chain + encode — i.e. the constraint is binding
        // exactly where the paper says it is.
        let budget = t.pipeline_period_tau();
        assert!(budget > 40.0 && budget < 50.0, "budget = {budget}");
    }
}
