//! Micro-benchmark substrate (criterion is unavailable offline).
//!
//! Adaptive-iteration timing with warmup, outlier-robust statistics
//! (median of sample means), and an aligned-table reporter. Used by every
//! `cargo bench` target (all declared `harness = false`).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Mean time per operation (median across samples).
    pub per_op: Duration,
    /// Operations per second.
    pub ops_per_sec: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub warmup: Duration,
    pub sample_time: Duration,
    pub samples: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            warmup: Duration::from_millis(100),
            sample_time: Duration::from_millis(60),
            samples: 7,
        }
    }
}

impl Config {
    /// Faster settings for long-running end-to-end benches.
    pub fn quick() -> Config {
        Config {
            warmup: Duration::from_millis(30),
            sample_time: Duration::from_millis(30),
            samples: 3,
        }
    }
}

/// Time `op` (which performs `batch` logical operations per call).
pub fn bench_batched<F: FnMut()>(name: &str, cfg: Config, batch: u64, mut op: F) -> Measurement {
    // Warmup + calibration: how many calls fit in sample_time?
    let w0 = Instant::now();
    let mut calls = 0u64;
    while w0.elapsed() < cfg.warmup {
        op();
        calls += 1;
    }
    let per_call = cfg.warmup.as_secs_f64() / calls.max(1) as f64;
    let iters = ((cfg.sample_time.as_secs_f64() / per_call).ceil() as u64).max(1);

    let mut means: Vec<f64> = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            op();
        }
        means.push(t0.elapsed().as_secs_f64() / (iters * batch) as f64);
    }
    means.sort_by(|a, b| a.total_cmp(b));
    let median = means[means.len() / 2];
    Measurement {
        name: name.to_string(),
        per_op: Duration::from_secs_f64(median),
        ops_per_sec: 1.0 / median,
        samples: cfg.samples,
        iters_per_sample: iters,
    }
}

/// Time a single-op closure.
pub fn bench<F: FnMut()>(name: &str, cfg: Config, op: F) -> Measurement {
    bench_batched(name, cfg, 1, op)
}

/// Collects measurements and renders an aligned report.
#[derive(Default)]
pub struct Runner {
    pub rows: Vec<Measurement>,
    title: String,
}

impl Runner {
    pub fn new(title: &str) -> Runner {
        Runner { rows: Vec::new(), title: title.to_string() }
    }

    pub fn add(&mut self, m: Measurement) {
        println!("  measured {:<40} {:>12.2?}/op {:>14.0} op/s", m.name, m.per_op, m.ops_per_sec);
        self.rows.push(m);
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, cfg: Config, op: F) {
        let m = bench(name, cfg, op);
        self.add(m);
    }

    pub fn report(&self) -> String {
        let mut out = format!("\n== {} ==\n{:<42} {:>14} {:>16}\n", self.title, "benchmark", "time/op", "ops/s");
        for m in &self.rows {
            out.push_str(&format!(
                "{:<42} {:>14.2?} {:>16.0}\n",
                m.name, m.per_op, m.ops_per_sec
            ));
        }
        out
    }

    pub fn finish(&self) {
        print!("{}", self.report());
    }
}

/// A compiler fence so the optimizer cannot delete benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = Config {
            warmup: Duration::from_millis(5),
            sample_time: Duration::from_millis(5),
            samples: 3,
        };
        let mut acc = 0u64;
        let m = bench("noop-ish", cfg, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.per_op < Duration::from_micros(10));
        assert!(m.ops_per_sec > 1e5);
    }

    #[test]
    fn batched_accounting() {
        let cfg = Config {
            warmup: Duration::from_millis(5),
            sample_time: Duration::from_millis(5),
            samples: 3,
        };
        let m = bench_batched("batch", cfg, 1000, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        // per-op must be ~1/1000 of the call time
        assert!(m.per_op < Duration::from_micros(1));
    }

    #[test]
    fn runner_report_contains_rows() {
        let mut r = Runner::new("t");
        r.add(Measurement {
            name: "x".into(),
            per_op: Duration::from_nanos(10),
            ops_per_sec: 1e8,
            samples: 1,
            iters_per_sample: 1,
        });
        assert!(r.report().contains("x"));
    }
}
