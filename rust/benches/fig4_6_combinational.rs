//! Bench: Figs. 4–6 — combinational synthesis sweeps (area / delay /
//! power / energy) for all Table IV designs at Posit16/32/64, from the
//! 28 nm unit-gate model.

use posit_div::hardware::{report, Mode, TSMC28};

fn main() {
    for n in report::FORMATS {
        println!("{}", report::render_figure(n, Mode::Combinational, &TSMC28));
    }
    println!("CSV:\n");
    for n in report::FORMATS {
        print!("{}", report::sweep_csv(n, Mode::Combinational, &TSMC28));
    }
}
