//! End-to-end service bench: coordinator throughput across batch sizes and
//! backends (native engines vs the AOT PJRT graph). PJRT rows need
//! `make artifacts` and a build with the `xla` feature (skipped otherwise).

use std::time::Duration;

use posit_div::coordinator::{Backend, BatchPolicy, DivisionService, ServiceConfig};
use posit_div::division::Algorithm;
use posit_div::workload::{self, Workload};

const REQUESTS: usize = 30_000;

fn run(n: u32, backend: Backend, label: &str, batch: usize) {
    let svc = match DivisionService::start(ServiceConfig {
        n,
        backend,
        policy: BatchPolicy { max_batch: batch, max_wait: Duration::from_micros(200) },
    }) {
        Ok(s) => s,
        Err(e) => {
            println!("{label:<28} batch={batch:<5} SKIP ({e})");
            return;
        }
    };
    let client = svc.client();
    let mut wl = workload::Uniform::new(n, batch as u64);
    let pairs = workload::take(&mut wl, REQUESTS);
    let t0 = std::time::Instant::now();
    let _ = client.divide_batch(&pairs).expect("service running");
    let wall = t0.elapsed();
    let m = svc.metrics();
    println!(
        "{label:<28} batch={batch:<5} {:>10.0} div/s   batch_lat {}",
        REQUESTS as f64 / wall.as_secs_f64(),
        m.batch_latency.summary()
    );
    svc.shutdown();
}

fn main() {
    for n in [16u32, 32] {
        println!("\n=== Posit{n}, {REQUESTS} requests ===");
        for batch in [64usize, 256, 1024] {
            run(
                n,
                Backend::Native { alg: Algorithm::DEFAULT, threads: 4 },
                "native srt4 (4 threads)",
                batch,
            );
        }
        for batch in [256usize, 1024] {
            run(n, Backend::Pjrt { artifacts_dir: "artifacts".into() }, "pjrt jax/pallas", batch);
        }
    }
}
