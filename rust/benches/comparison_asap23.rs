//! §IV comparison against the ASAP'23 two's-complement NRD —
//! thin shim over [`posit_div::bench::suites`], where the suite body
//! lives so the same code runs under `cargo bench --bench comparison_asap23`
//! and `posit-div bench comparison_asap23` (flags: `--json`, `--baseline`,
//! `--write-baseline`, `--quick`/`--full`, `--threshold`, `--advisory`).

fn main() {
    posit_div::bench::harness::bench_main("comparison_asap23");
}
